"""Paper Fig. 9: effective KV bandwidth under mapping/scheduling options —
dense baseline / interleaved + reuse / token-wise + reuse / +invariance
buffer / paged entry-stream (±on-chip history) — from the transaction
model in kvcache/layout.py (the same row-buffer/burst accounting the
paper's memory system analysis uses), plus the history-buffer hit
accounting that backs the serve engine's live hit-rate stat."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import Rows
from repro.kvcache.layout import (TokenWiseLayout, history_hit_accounting,
                                  transaction_model)


def run(quick: bool = False) -> Rows:
    rows = Rows()
    rng = np.random.default_rng(0)
    L, T = (8, 64) if quick else (16, 256)
    keep = 0.75
    gates = (rng.random((L, T)) < keep).astype(np.float32)
    gates[0] = 1.0                                # dense base layer
    layout = TokenWiseLayout(num_ports=16)
    t0 = time.perf_counter()
    eff = transaction_model(gates, layout)
    dt = (time.perf_counter() - t0) * 1e6
    peak = 460.0                                  # GB/s (paper's U280 HBM2)
    for name, frac in eff.items():
        rows.add(f"fig9/{name}", dt / len(eff),
                 f"eff_frac={frac:.3f};eff_GBps={frac * peak:.1f}")
    # the paper's ordering must hold: invariance > tokenwise > interleaved
    assert eff["invariance_buffer"] >= eff["tokenwise_reuse"] >= \
        eff["interleaved_reuse"], eff
    # paging alone re-walks the stream per layer (bandwidth < memory win);
    # the on-chip history buffer reads each entry once, matching the
    # invariance buffer (modulo partial-page rounding) and beating every
    # off-chip option
    assert eff["paged_history"] >= 0.95 * eff["invariance_buffer"], eff
    assert eff["paged_history"] >= eff["tokenwise_reuse"], eff
    assert eff["paged_history"] > eff["paged_tokenwise"], eff

    hits = history_hit_accounting(gates)
    rows.add("fig9/history_hits", 0.0,
             f"hit_rate={hits['hit_rate']:.3f};"
             f"layer1={hits['per_layer'][1]:.3f};"
             f"analytic={1.0 - (1.0 + (L - 1) * keep) / L:.3f}")
    # deterministic (seeded transaction model) — gated by bench_compare
    rows.meta = {
        "eff_frac": {name: float(frac) for name, frac in eff.items()},
        "history_hit_rate": float(hits["hit_rate"]),
    }
    return rows


if __name__ == "__main__":
    run().emit()
