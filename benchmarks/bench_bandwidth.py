"""Paper Fig. 9: effective KV bandwidth under mapping/scheduling options —
dense baseline / interleaved + reuse / token-wise + reuse / +invariance
buffer — from the transaction model in kvcache/layout.py (the same
row-buffer/burst accounting the paper's memory system analysis uses)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import Rows, time_fn
from repro.kvcache.layout import TokenWiseLayout, transaction_model


def run(quick: bool = False) -> Rows:
    rows = Rows()
    rng = np.random.default_rng(0)
    L, T = (8, 64) if quick else (16, 256)
    keep = 0.75
    gates = (rng.random((L, T)) < keep).astype(np.float32)
    gates[0] = 1.0                                # dense base layer
    layout = TokenWiseLayout(num_ports=16)
    us = time_fn if False else None
    import time
    t0 = time.perf_counter()
    eff = transaction_model(gates, layout)
    dt = (time.perf_counter() - t0) * 1e6
    peak = 460.0                                  # GB/s (paper's U280 HBM2)
    for name, frac in eff.items():
        rows.add(f"fig9/{name}", dt / len(eff),
                 f"eff_frac={frac:.3f};eff_GBps={frac * peak:.1f}")
    # the paper's ordering must hold: invariance > tokenwise > interleaved
    assert eff["invariance_buffer"] >= eff["tokenwise_reuse"] >= \
        eff["interleaved_reuse"], eff
    return rows


if __name__ == "__main__":
    run().emit()
