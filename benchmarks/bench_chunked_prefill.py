"""Chunked prefill vs eager monolithic prefill on mixed traffic.

The quantity chunked prefill buys is *bounded decode stalls*: with eager
monolithic prefill, every resident decode slot freezes for the whole
prompt whenever a long request is admitted mid-stream (head-of-line
blocking — the scheduler-level violation of SkipOPU's no-unit-idles
principle).  With ``prefill_chunk > 0`` the step planner interleaves one
fixed-size chunk per engine iteration with a full resident decode step,
so the worst inter-token gap a resident sees shrinks from one *prompt*
of prefill work to one *chunk* of it.

Workload: two short-prompt residents generating long outputs, plus two
long prompts arriving behind them — the second long prompt is admitted
while the residents are mid-decode, which is exactly the stall event.
Both engines run the same requests; reported are the worst resident
decode stall (``RequestResult.max_decode_stall_s``) and goodput (useful
requested tokens per wall second).

CI gate (bench-smoke job): the chunked engine's worst resident stall
must be strictly below the eager baseline's, with goodput no worse than
a noise-tolerant fraction of it.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import Rows
from repro.configs import get_config
from repro.models import model as M
from repro.serve.engine import ContinuousBatchingEngine

# Scale note: at CPU-smoke model sizes the per-call jit dispatch cost
# (~2-3 ms) rivals the math, so the chunk must be large enough to
# amortize dispatch yet a small fraction of the prompt — 1024-token
# prompts in 128-token chunks put the eager stall floor (~one whole
# prefill) far above a chunk iteration plus any host-noise outlier,
# while the residents' long decodes amortize the interleaving overhead.
MAX_LEN = 1040
SLOTS = 3
CHUNK = 128
SHORT_T0, SHORT_NEW = 4, 128
LONG_T0, LONG_NEW = 1024, 2


def _workload(cfg):
    rng = np.random.default_rng(0)
    shorts = [rng.integers(0, cfg.vocab_size, (SHORT_T0,), dtype=np.int32)
              for _ in range(2)]
    longs = [rng.integers(0, cfg.vocab_size, (LONG_T0,), dtype=np.int32)
             for _ in range(2)]
    work = [(p, SHORT_NEW) for p in shorts] + [(p, LONG_NEW) for p in longs]
    useful = sum(n for _, n in work)
    return work, useful


def _run(eng: ContinuousBatchingEngine, work):
    t0 = time.time()
    uids = [eng.submit(p, max_new_tokens=n) for p, n in work]
    out = eng.run()
    wall = time.time() - t0
    # residents = the short-prompt long-decode requests (first two)
    stall = max(out["results"][u].max_decode_stall_s for u in uids[:2])
    return wall, stall, out


def run(quick: bool = False) -> Rows:
    rows = Rows()
    cfg = get_config("llama2-7b").smoke()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    work, useful = _workload(cfg)
    passes = 2 if quick else 4

    eager = ContinuousBatchingEngine(cfg, params, max_slots=SLOTS,
                                     max_len=MAX_LEN)
    chunked = ContinuousBatchingEngine(cfg, params, max_slots=SLOTS,
                                       max_len=MAX_LEN,
                                       prefill_chunk=CHUNK)
    # warm pass compiles every prefill bucket / chunk / decode shape;
    # timed passes are steady-state, min-of-N against host noise; the
    # goodput gate uses *paired* per-pass ratios (adjacent runs see the
    # same host conditions) so a noise burst cannot fail one side alone
    _run(eager, work)
    _, _, out_c = _run(chunked, work)
    e_walls, e_stalls, c_walls, c_stalls = [], [], [], []
    for _ in range(passes):
        w, s, _ = _run(eager, work)
        e_walls.append(w)
        e_stalls.append(s)
        w, s, out_c = _run(chunked, work)
        c_walls.append(w)
        c_stalls.append(s)
    e_wall, e_stall = float(np.min(e_walls)), float(np.min(e_stalls))
    c_wall, c_stall = float(np.min(c_walls)), float(np.min(c_stalls))
    e_good, c_good = useful / e_wall, useful / c_wall
    paired = float(np.max([ew / cw for ew, cw in zip(e_walls, c_walls)]))
    s = out_c["stats"]

    rows.add("chunked_prefill/eager", e_wall * 1e6 / useful,
             f"worst_stall_s={e_stall:.4f};goodput_tok_s={e_good:.1f}")
    rows.add("chunked_prefill/chunked", c_wall * 1e6 / useful,
             f"worst_stall_s={c_stall:.4f};goodput_tok_s={c_good:.1f};"
             f"stall_ratio={c_stall / e_stall:.3f}")
    rows.add("chunked_prefill/interleave", 0.0,
             f"prefill_chunks={s.prefill_chunks};"
             f"interleaved_steps={s.interleaved_steps}")
    rows.meta = {
        "chunk": CHUNK, "slots": SLOTS, "max_len": MAX_LEN,
        "worst_stall_s": {"eager": e_stall, "chunked": c_stall},
        "goodput_tok_s": {"eager": e_good, "chunked": c_good},
        "goodput_paired_ratio": paired,
        "prefill_chunks": s.prefill_chunks,
        "interleaved_steps": s.interleaved_steps,
    }

    # CI gates.  (1) the whole point of the feature: a resident's worst
    # decode stall shrinks from ~one prompt of prefill work to ~one
    # chunk of it (steady-state ratio here is ~0.3 — assert a margin).
    # (2) goodput no worse, modulo the chunk-dispatch tax: at CPU-smoke
    # scale each extra jitted call costs ~2-3 ms of pure host dispatch,
    # which bounds the interleaving overhead at ~10% of this run (on a
    # real accelerator with real model sizes the same dispatch cost is
    # noise); the best paired ratio must keep chunked within 0.8x.
    assert c_stall < 0.8 * e_stall, (
        f"chunked prefill did not reduce the worst resident decode stall "
        f"({c_stall:.4f}s vs eager {e_stall:.4f}s)")
    assert paired >= 0.8, (
        f"chunked prefill goodput regressed beyond the dispatch-tax "
        f"bound: paired eager/chunked wall ratio {paired:.3f} < 0.8")
    return rows


if __name__ == "__main__":
    run().emit()
