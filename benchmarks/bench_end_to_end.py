"""Paper Table 3: end-to-end decode throughput + bandwidth efficiency.

Two layers of evidence:
  * measured: ServeEngine tokens/s on the reduced llama2-7b (CPU — used for
    the relative dense-vs-skip comparison, the quantity SkipOPU's routing
    contributes);
  * derived: decode-roofline tokens/s for the FULL llama2-7b on the target
    memory system — decode is bandwidth-bound, so
    tok/s = eff_bw / bytes_per_token with bytes = W4 weights + KV reads,
    which is exactly how the paper normalizes Table 3.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

from benchmarks.common import Rows
from repro.configs import get_config
from repro.models import model as M
from repro.serve.engine import ServeEngine


def _derived_toks(bw_gbps: float, eff: float, keep: float,
                  w_bits: int, ctx: int) -> float:
    cfg = get_config("llama2-7b")
    n = cfg.param_count(active_only=True)
    w_bytes = n * w_bits / 8 * (keep if keep < 1 else 1.0)
    kv_bytes = (2 * cfg.num_layers * cfg.kv_inner_dim * ctx * 2)
    return bw_gbps * 1e9 * eff / (w_bytes + kv_bytes)


def run(quick: bool = False) -> Rows:
    rows = Rows()
    # --- measured (reduced model, dense vs skip) -------------------------
    base = get_config("llama2-7b").smoke()
    new_toks = 8 if quick else 24
    for mode in ("dense", "skip"):
        cfg = base if mode == "skip" else dataclasses.replace(
            base, skip=dataclasses.replace(base.skip, enabled=False))
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        eng = ServeEngine(cfg, params, max_len=64 + new_toks)
        prompts = np.random.default_rng(0).integers(
            0, cfg.vocab_size, (4, 64), dtype=np.int32)
        out = eng.generate(prompts, new_toks)
        s = out["stats"]
        rows.add(f"table3/measured/{mode}", s.decode_s * 1e6 / max(
            s.decode_tokens, 1), f"tok_s={s.decode_tok_per_s:.1f}")

    # --- derived (full model, paper's normalization) ---------------------
    # SkipOPU row: U280 460 GB/s, 88.4% eff, W4, 25% skip, ctx 128+1024
    cases = {
        "skipopu_u280": (460, 0.884, 0.75, 4),
        "vllm_a100": (1555, 0.315, 1.0, 16),
        "flightllm_u280": (460, 0.66, 1.0, 8),
        "dfx_u280": (460, 0.34, 1.0, 16),
        "ours_tpu_v5e_chip": (819, 0.80, 0.75, 4),
    }
    for name, (bw, eff, keep, bits) in cases.items():
        t = _derived_toks(bw, eff, keep, bits, ctx=1152)
        rows.add(f"table3/derived/{name}", 0.0,
                 f"norm_tok_s={t:.1f};bw_eff={eff:.3f}")
    return rows


if __name__ == "__main__":
    run().emit()
