"""Fault-storm goodput guard + kill/resume recovery time.

The robustness layer (``serve/faults.py``, ``serve/snapshot.py``) only
earns its place if surviving faults is *cheap*: a storm of injected
faults — dispatch errors, sync stalls, page-alloc OOMs — must keep
useful-token goodput at >= 0.85x the clean run on the same engine
(floor-gated as ``meta.fault_storm.goodput_ratio`` by
tools/bench_compare.py), and the survivors' tokens must stay
bit-identical (recorded as ``meta.fault_storm.bit_identical``).

The full (non ``--quick``) run also measures crash recovery: a run
killed at a step boundary (after its crash-consistent snapshot), then a
*fresh* engine resuming from the snapshot directory and draining the
survivors — ``meta.recovery.resume_s`` is the wall time from
``resume()`` to completion, dominated by the fresh process's compiles
(exactly the real restart cost; see docs/robustness.md).
"""
from __future__ import annotations

import tempfile
from time import perf_counter

import jax
import numpy as np

from benchmarks.common import Rows
from repro.configs import get_config
from repro.models import model as M
from repro.serve.engine import ContinuousBatchingEngine
from repro.serve.errors import SimulatedKill
from repro.serve.faults import Fault, FaultPlan, as_fault_plan

MAX_LEN = 64
SLOTS = 4
DECODE_STEPS = 8


def _workload(cfg, n: int = 8):
    rng = np.random.default_rng(0)
    lens = [44, 8, 12, 16, 40, 8, 12, 20][:n]
    news = [2, 16, 4, 16, 2, 16, 4, 12][:n]
    return [(rng.integers(0, cfg.vocab_size, (l,), dtype=np.int32), k)
            for l, k in zip(lens, news)]


def _storm():
    # one of each recoverable kind, spread across the run's iterations;
    # fresh plan per pass (faults fire exactly once per plan)
    return [Fault("dispatch_error", step=2),
            Fault("oom", step=3, pages=0),
            Fault("stall", step=4, stall_s=0.004),
            Fault("dispatch_error", step=6)]


def _engine(cfg, params, **kw):
    return ContinuousBatchingEngine(cfg, params, max_slots=SLOTS,
                                    max_len=MAX_LEN, kv_mode="paged",
                                    page_size=8,
                                    decode_steps=DECODE_STEPS, **kw)


def _run(eng, work, faults=None):
    eng.faults = as_fault_plan(faults)
    t0 = perf_counter()
    uids = [eng.submit(p, max_new_tokens=n) for p, n in work]
    out = eng.run()
    return perf_counter() - t0, out, uids


def _tokens(out, uids):
    return [np.asarray(out["results"][u].tokens) for u in uids]


def run(quick: bool = False) -> Rows:
    rows = Rows()
    cfg = get_config("llama2-7b").smoke()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    work = _workload(cfg)
    useful = sum(n for _, n in work)
    passes = 2 if quick else 5

    clean = _engine(cfg, params)
    stormy = _engine(cfg, params)
    # warm both engines (compiles), then interleave timed passes so host
    # drift hits both arms equally; min-of-N sheds interference noise
    _, ref_out, ref_uids = _run(clean, work)
    ref = _tokens(ref_out, ref_uids)
    _run(stormy, work)
    clean_ts, storm_ts = [], []
    identical, faults_fired = True, 0
    for _ in range(passes):
        s, _, _ = _run(clean, work)
        clean_ts.append(s)
        s, out, uids = _run(stormy, work, faults=_storm())
        storm_ts.append(s)
        faults_fired += len(stormy.faults.fired)
        identical &= all(np.array_equal(a, b)
                         for a, b in zip(_tokens(out, uids), ref))
    clean_s = float(np.min(clean_ts))
    storm_s = float(np.min(storm_ts))
    clean_tps = useful / clean_s
    storm_tps = useful / storm_s
    ratio = storm_tps / clean_tps

    rows.add("faults/clean", clean_s * 1e6 / useful,
             f"useful_tok_s={clean_tps:.1f}")
    rows.add("faults/storm", storm_s * 1e6 / useful,
             f"useful_tok_s={storm_tps:.1f};ratio={ratio:.3f};"
             f"identical={identical}")
    rows.meta["fault_storm"] = {
        "clean_tok_s": round(clean_tps, 2),
        "storm_tok_s": round(storm_tps, 2),
        # the floor-gated guard: a fault storm must keep >= 0.85x goodput
        "goodput_ratio": round(ratio, 4),
        "faults_per_pass": len(_storm()),
        "faults_fired": faults_fired,
        # int, not bool: bench_compare floors gate numerics only
        "bit_identical": int(identical),
    }

    if not quick:
        with tempfile.TemporaryDirectory() as snap_dir:
            victim = _engine(cfg, params, snapshot_dir=snap_dir)
            victim.faults = FaultPlan([Fault("kill", step=6)])
            for p, n in work:
                victim.submit(p, max_new_tokens=n)
            try:
                victim.run()
                raise RuntimeError("injected kill never fired")
            except SimulatedKill:
                pass
            fresh = _engine(cfg, params, snapshot_dir=snap_dir)
            t0 = perf_counter()
            at = fresh.resume()
            out = fresh.run()
            resume_s = perf_counter() - t0
            res = [np.asarray(r.tokens)
                   for _, r in sorted(out["results"].items())]
            rec_ok = all(np.array_equal(a, b) for a, b in zip(res, ref))
            rows.add("faults/kill_resume", resume_s * 1e6 / useful,
                     f"resume_s={resume_s:.2f};boundary={at};"
                     f"identical={rec_ok}")
            rows.meta["recovery"] = {
                "resume_s": round(resume_s, 3),
                "resumed_boundary": at,
                "bit_identical": int(rec_ok),
            }
    return rows


if __name__ == "__main__":
    run().emit()
