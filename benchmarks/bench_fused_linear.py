"""Fused hybrid linear pipeline (Alg. 1 fusion + §4.2 BFP path).

Two measurements per weight path (dense bf16 / int4-BFP):

  * wall-clock of the composed op-by-op dispatch (norm → separate q/k/v
    and gate/up matmuls → GLU combine → residual add → next reduction)
    vs the fused kernels — relative CPU timing, like the other benches;
  * the ``roofline.linear_bytes`` HBM accounting of one decode step:
    modeled activation round-trip bytes must drop ≥ 20 % and total bytes
    (weights included) must be strictly below the unfused dispatch —
    asserted here so bench-smoke CI fails on regression.

The per-step byte counts are exported via ``Rows.meta`` into
``BENCH_fused_linear.json`` (the CI perf artifact).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from benchmarks.common import Rows, time_fn
from repro.configs import get_config
from repro.kernels import ops
from repro.quant import quantize_rtn
from repro.roofline import fusion_report, tp_sweep

MIN_ACT_DROP = 0.20


def _unfused_block(x, ms, gamma, w_gu, w_down, res, gate, eps):
    """The composed dispatch the fused pipeline replaces (jnp ops):
    norm round-trip, one merged [gate|up] matmul, GLU combine, down
    projection, then the gate/residual write — mirroring
    ``layers.mlp_apply`` on merged weights."""
    xf = x.astype(jnp.float32)
    xn = (xf * jax.lax.rsqrt(ms[:, None] + eps)
          * gamma.astype(jnp.float32)).astype(x.dtype)
    F = w_gu.shape[1] // 2
    gu = xn @ w_gu.astype(x.dtype)
    h = jax.nn.silu(gu[:, :F]) * gu[:, F:]
    y = h @ w_down.astype(x.dtype)
    out = y * gate.astype(y.dtype)[:, None] + res
    of = out.astype(jnp.float32)
    return out, (of * of).mean(-1)


def _fused_block(x, ms, gamma, pg, pd, res, gate, eps):
    h, _ = ops.fused_linear(pg, x, mean_sq=ms, gamma=gamma, eps=eps,
                            glu=True, act="silu")
    out, sq = ops.fused_linear(pd, h, residual=res, gate_mul=gate,
                               emit_sq=True)
    return out, sq / x.shape[-1]


def run(quick: bool = False) -> Rows:
    rows = Rows()
    M, D, F = (64, 256, 512) if quick else (256, 1024, 2048)
    eps = 1e-5
    ks = jax.random.split(jax.random.PRNGKey(0), 6)
    x = jax.random.normal(ks[0], (M, D), jnp.float32).astype(jnp.bfloat16)
    gamma = 1.0 + 0.1 * jax.random.normal(ks[1], (D,))
    ms = (x.astype(jnp.float32) ** 2).mean(-1)
    w_gu = jax.random.normal(ks[2], (D, 2 * F), jnp.float32) * 0.03
    w_down = jax.random.normal(ks[3], (F, D), jnp.float32) * 0.03
    res = jax.random.normal(ks[4], (M, D), jnp.float32).astype(jnp.bfloat16)
    gate = (jax.random.uniform(ks[5], (M,)) > 0.25).astype(jnp.float32)

    # --- wall-clock: dense ---------------------------------------------------
    unf = jax.jit(lambda: _unfused_block(x, ms, gamma, w_gu, w_down, res,
                                         gate, eps))
    t_unf = time_fn(unf, iters=3)
    pg = {"w": w_gu}
    pd = {"w": w_down}
    fus = jax.jit(lambda: _fused_block(x, ms, gamma, pg, pd, res, gate, eps))
    t_fus = time_fn(fus, iters=3)
    o_u, sq_u = unf()
    o_f, sq_f = fus()
    err = float(jnp.abs(o_u.astype(jnp.float32)
                        - o_f.astype(jnp.float32)).max())
    # off-TPU the kernels execute in the Pallas *interpreter*, so absolute
    # wall-clock only validates correctness plumbing; the modeled HBM
    # bytes below are the metric that transfers to hardware.
    backend = jax.default_backend()
    rows.add("fused_linear/dense/unfused_us", t_unf, f"backend={backend}")
    rows.add("fused_linear/dense/fused_us", t_fus,
             f"backend={backend};interpreted={backend != 'tpu'};"
             f"max_err={err:.2e}")

    # --- wall-clock: int4-BFP ------------------------------------------------
    cg, sg = quantize_rtn(w_gu, 128, pow2_scales=True)
    cd, sd = quantize_rtn(w_down, 128, pow2_scales=True)
    pgq = {"w_int": cg, "scale": sg}
    pdq = {"w_int": cd, "scale": sd}
    fq = jax.jit(lambda: _fused_block(x, ms, gamma, pgq, pdq, res, gate, eps))
    t_fq = time_fn(fq, iters=3)
    rows.add("fused_linear/int4_bfp/fused_us", t_fq, "")

    # --- modeled HBM bytes per decode step (the measured win) ----------------
    meta = {"min_activation_drop": MIN_ACT_DROP, "reports": {}}
    for arch, quant in (("llama2-7b", False), ("llama2-7b", True),
                        ("qwen3-8b", False)):
        cfg = get_config(arch)
        cfg = dataclasses.replace(
            cfg, quant=dataclasses.replace(cfg.quant, enabled=quant))
        rep = fusion_report(cfg, batch=128)
        tag = f"{arch}{'/int4' if quant else ''}"
        meta["reports"][tag] = rep
        act_drop = rep["activation_bytes_drop_frac"]
        tot_drop = rep["total_bytes_drop_frac"]
        rows.add(f"fused_linear/bytes/{tag}", 0.0,
                 f"act_drop={act_drop:.3f};total_drop={tot_drop:.4f};"
                 f"fused_total={rep['fused']['total_bytes']:.3e};"
                 f"unfused_total={rep['unfused']['total_bytes']:.3e}")
        # CI gate: the fused dispatch must beat the unfused one
        assert rep["fused"]["total_bytes"] < rep["unfused"]["total_bytes"], \
            f"{tag}: fused total bytes not below unfused"
        assert act_drop >= MIN_ACT_DROP, \
            f"{tag}: activation-byte drop {act_drop:.3f} < {MIN_ACT_DROP}"

    # --- per-device view under tensor-parallel serving -----------------------
    # (the sharded engine's bandwidth story: weight bytes fall exactly
    # 1/TP, per-chip totals ~1/TP while decode stays weight-dominated)
    cfg = get_config("llama2-7b")
    sweep = tp_sweep(cfg, batch=128)
    meta["tp_sweep"] = {"llama2-7b": sweep}
    w1 = sweep["per_chip"]["1"]["weight_bytes"]
    prev_total = float("inf")
    for tp in sweep["tps"]:
        r = sweep["per_chip"][str(tp)]
        assert abs(r["weight_bytes"] - w1 / tp) < 1e-6 * w1, \
            f"tp={tp}: per-chip weight bytes not 1/TP"
        assert r["total_bytes"] < prev_total, \
            f"tp={tp}: per-chip total bytes not strictly decreasing"
        prev_total = r["total_bytes"]
        rows.add(f"fused_linear/tp_view/llama2-7b/tp{tp}", 0.0,
                 f"per_chip_total={r['total_bytes']:.3e};"
                 f"vs_tp1={r['total_vs_tp1']:.4f}")
    rows.meta = meta
    return rows


if __name__ == "__main__":
    run(quick=True).emit()
