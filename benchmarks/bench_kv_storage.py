"""Paper §5 claim: cross-layer KV reuse cuts KV storage by up to 25.4 %
across varying input/output sequence lengths.

Measures the compact store's saved fraction from *actual routing gates* of
a randomly-initialized SkipGPT model steered to ~25 % skipping, across the
paper's [prefill, decode] grid, plus the analytic bound 1-(1+(L-1)k)/L.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Rows
from repro.configs import get_config
from repro.core import kv_reuse, routing


def run(quick: bool = False) -> Rows:
    rows = Rows()
    cfg = get_config("llama2-7b")
    L = cfg.num_layers
    keep = cfg.skip.keep_prob
    grid = [(128, 512)] if quick else [(128, 512), (256, 512), (512, 1024),
                                       (1024, 1024)]
    rng = np.random.default_rng(0)
    for pre, dec in grid:
        T = pre + dec
        # gates drawn at the trained skip rate (router steered to keep=0.75)
        gates = (rng.random((L, 1, T)) < keep).astype(np.float32)
        gates[0] = 1.0
        measured = float(kv_reuse.storage_saved_fraction(jnp.asarray(gates)))
        analytic = 1.0 - (1.0 + (L - 1) * keep) / L
        rows.add(f"kv_storage/p{pre}d{dec}", 0.0,
                 f"saved={measured:.3f};analytic={analytic:.3f};paper=0.254")
        if not rows.meta:
            # deterministic (seeded) — gated by tools/bench_compare.py
            rows.meta = {"saved_fraction": measured, "analytic": analytic}
    return rows


if __name__ == "__main__":
    run().emit()
