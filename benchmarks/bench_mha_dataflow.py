"""Paper Fig. 8: normalized MHA speedup under the incremental dataflow
optimizations — Baseline → PartialSkip → KV-Reuse → KV-Reuse+OPT — across
[prefill:decode] workloads.

Two columns per configuration:
  * measured: wall-time of the jit'd MHA submodule pipeline on a reduced
    model (CPU; *relative* speedups are the quantity the paper reports);
  * flops-model: analytic arithmetic/byte reduction at the full llama2-7b
    scale (keep=0.75), which is mesh-independent.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Rows, time_fn
from repro.configs import get_config
from repro.core import skip_block
from repro.models import model as M
from repro.models import transformer

CONFIGS = ("baseline", "partial_skip", "kv_reuse", "kv_reuse_opt")


def _cfg_for(mode: str):
    base = get_config("llama2-7b").smoke()
    base = dataclasses.replace(base, num_layers=4, attn_chunk=64)
    sk = base.skip
    if mode == "baseline":
        sk = dataclasses.replace(sk, enabled=False)
    elif mode == "partial_skip":
        # router gates attention compute; KV still generated for all tokens
        sk = dataclasses.replace(sk, enabled=True, kv_reuse=False,
                                 mode="gather", route_mlp=False)
    elif mode == "kv_reuse":
        sk = dataclasses.replace(sk, enabled=True, kv_reuse=True,
                                 mode="gather", route_mlp=False)
    else:  # kv_reuse_opt: + fused router/stats dataflow (single-pass
        # reductions; on TPU the Pallas fusions — here the jnp-fused path)
        sk = dataclasses.replace(sk, enabled=True, kv_reuse=True,
                                 mode="gather", route_mlp=False)
        base = dataclasses.replace(base, attn_chunk=256)
    return dataclasses.replace(base, skip=sk)


def _mha_flops_model(mode: str, prefill: int, decode: int,
                     keep: float = 0.75) -> float:
    """Per-token MHA cost model at llama2-7b scale (normalized)."""
    cfg = get_config("llama2-7b")
    d, hq, dh = cfg.d_model, cfg.num_heads, cfg.resolved_head_dim
    L = prefill + decode
    qkvo = 4 * d * d                  # per executed token
    attn = 2 * 2 * hq * dh * L        # QK + SV against ~L context
    kv_gen = 2 * d * d
    if mode == "baseline":
        return qkvo + attn + kv_gen * 0
    if mode == "partial_skip":
        return keep * (qkvo - kv_gen * 2) + kv_gen * 2 + keep * attn
    # kv_reuse / opt: skipped tokens generate nothing
    return keep * (qkvo + attn)


def run(quick: bool = False) -> Rows:
    rows = Rows()
    workloads = [(128, 64)] if quick else [(128, 64), (256, 128)]
    for prefill, decode in workloads:
        base_us = None
        for mode in CONFIGS:
            cfg = _cfg_for(mode)
            params = M.init_params(jax.random.PRNGKey(0), cfg)
            toks = jax.random.randint(jax.random.PRNGKey(1), (1, prefill),
                                      0, cfg.vocab_size)

            pre = jax.jit(lambda p, b: M.prefill(p, b, cfg,
                                                 pad_to=prefill + decode))
            logits, cache, _ = pre(params, {"tokens": toks})
            dec = jax.jit(lambda p, c, b, t: M.decode_step(p, c, b, t, cfg))

            def pipeline():
                lg, c, _ = pre(params, {"tokens": toks})
                tok = jnp.argmax(lg, -1)[:, None]
                for i in range(min(decode, 16)):      # bounded decode loop
                    lg, c, _ = dec(params, c, {"tokens": tok},
                                   jnp.int32(prefill + i))
                    tok = jnp.argmax(lg, -1)[:, None]
                return lg

            us = time_fn(pipeline, iters=3, warmup=1)
            if mode == "baseline":
                base_us = us
            speedup = base_us / us if us else 0.0
            fl_base = _mha_flops_model("baseline", prefill, decode)
            fl = _mha_flops_model(mode, prefill, decode)
            rows.add(f"fig8/{mode}/p{prefill}d{decode}", us,
                     f"measured_speedup={speedup:.2f};"
                     f"model_speedup={fl_base / fl:.2f}")
    return rows


if __name__ == "__main__":
    run().emit()
