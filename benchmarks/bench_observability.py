"""Tracing/metrics overhead guard: traced goodput vs untraced goodput.

The observability subsystem (``repro/obs``) is threaded through the
engine run loops unconditionally — a ``NullTracer`` method call per span
site when tracing is off, real event recording when a ``Tracer`` is
installed.  That only stays acceptable if the cost is bounded, so this
bench runs the same mixed workload through the fused continuous engine
with tracing off and with tracing + full metrics on, min-of-N on both,
and exports ``meta.overhead.traced_goodput_ratio`` — floor-gated at
0.97 (<3% goodput cost) by tools/bench_compare.py.

Also recorded: event volume per generated token (a tracing run that
silently exploded its buffer would show here) and the traced run's
engine phase-time split, the same numbers ``tools/trace_summary.py``
reports from the trace file itself.
"""
from __future__ import annotations

from time import perf_counter

import jax
import numpy as np

from benchmarks.common import Rows
from repro.configs import get_config
from repro.models import model as M
from repro.obs import Tracer
from repro.serve.engine import ContinuousBatchingEngine

MAX_LEN = 64
SLOTS = 4


def _workload(cfg, n: int = 8):
    rng = np.random.default_rng(0)
    lens = [44, 8, 12, 16, 40, 8, 12, 20][:n]
    news = [2, 16, 4, 16, 2, 16, 4, 12][:n]
    return [(rng.integers(0, cfg.vocab_size, (l,), dtype=np.int32), k)
            for l, k in zip(lens, news)]


def _run(eng, work):
    t0 = perf_counter()
    for p, n in work:
        eng.submit(p, max_new_tokens=n)
    out = eng.run()
    return perf_counter() - t0, out


def run(quick: bool = False) -> Rows:
    rows = Rows()
    cfg = get_config("llama2-7b").smoke()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    work = _workload(cfg)
    useful = sum(n for _, n in work)
    passes = 3 if quick else 6

    plain = ContinuousBatchingEngine(cfg, params, max_slots=SLOTS,
                                     max_len=MAX_LEN, decode_steps=8)
    traced = ContinuousBatchingEngine(cfg, params, max_slots=SLOTS,
                                      max_len=MAX_LEN, decode_steps=8,
                                      trace=Tracer())
    # warm both engines (compile every epoch length / prefill bucket),
    # then interleave timed passes so slow drift on the shared host hits
    # both arms equally; min-of-N sheds interference noise
    _run(plain, work)
    _run(traced, work)
    plain_ts, traced_ts = [], []
    for _ in range(passes):
        s, _ = _run(plain, work)
        plain_ts.append(s)
        traced.tracer = Tracer()          # fresh buffer per timed pass
        s, outt = _run(traced, work)
        traced_ts.append(s)
    plain_s = float(np.min(plain_ts))
    traced_s = float(np.min(traced_ts))

    plain_tps = useful / plain_s
    traced_tps = useful / traced_s
    ratio = traced_tps / plain_tps
    st = outt["stats"]
    n_events = len(traced.tracer.events)

    rows.add("obs/untraced", plain_s * 1e6 / useful,
             f"useful_tok_s={plain_tps:.1f}")
    rows.add("obs/traced", traced_s * 1e6 / useful,
             f"useful_tok_s={traced_tps:.1f};ratio={ratio:.3f}")
    rows.add("obs/trace_volume", 0.0,
             f"events_per_tok={n_events / max(st.decode_tokens, 1):.1f}")

    rows.meta["overhead"] = {
        "untraced_tok_s": round(plain_tps, 2),
        "traced_tok_s": round(traced_tps, 2),
        # the floor-gated guard: tracing must keep >= 0.97x goodput
        "traced_goodput_ratio": round(ratio, 4),
        "trace_events": n_events,
        "decode_steps": traced.decode_steps,
    }
    rows.meta["phase_time"] = {
        "prefill_s": round(st.prefill_s, 4),
        "decode_s": round(st.decode_s, 4),
        "device_s": round(st.device_s, 4),
        "host_s": round(st.host_s, 4),
        "compiles": st.compiles,
    }
    return rows


if __name__ == "__main__":
    run().emit()
