"""Paged vs. dense KV pool on a mixed-length continuous-batching workload.

The dense slot pool preallocates ``max_slots × max_len`` KV rows per
attention layer — peak memory is independent of what the traffic actually
needs.  The paged engine allocates pages on demand and stores one entry
per (token, *executed* layer), so its live peak footprint tracks the real
context lengths *and* the router's pruning (the paper's 25.4 % KV-storage
claim, realized in decode memory).  Token output is identical by
construction (asserted here); the history-buffer hit rate is measured
from the live decode gate log, not estimated.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import Rows
from repro.configs import get_config
from repro.core import routing
from repro.kvcache.paged import entry_bytes as page_entry_bytes
from repro.models import model as M
from repro.serve.config import EngineConfig, KVConfig, SchedulingConfig
from repro.serve.engine import ContinuousBatchingEngine

MAX_LEN = 64
SLOTS = 4
PAGE_SIZE = 8

# warm-prefix TTFT section: long shared prefix, so the skipped prefill
# dominates the warm path's fixed costs (restore gather + suffix step)
PREFIX_MAX_LEN = 512
PREFIX_LEN = 448
PREFIX_PAGE = 16
# page budget sized so the resident record set never LRU-evicts the
# shared prefix mid-measurement (eviction would silently re-cold the
# "warm" runs and collapse the ratio)
PREFIX_PAGES = 384


def _workload(cfg, n: int):
    rng = np.random.default_rng(0)
    lens = [44, 8, 12, 16, 40, 8, 12, 20][:n]
    news = [2, 16, 4, 16, 2, 16, 4, 12][:n]
    prompts = [rng.integers(0, cfg.vocab_size, (l,), dtype=np.int32)
               for l in lens]
    return list(zip(prompts, news))


def _dense_pool_kv_bytes(cfg, max_slots: int, max_len: int) -> int:
    """The dense pool's KV footprint: per attention layer, k+v rows of
    [max_slots, max_len, Hkv, dh]."""
    nA = len(cfg.attention_layers)
    itemsize = np.dtype(cfg.dtype).itemsize
    return (2 * nA * max_slots * max_len
            * cfg.num_kv_heads * cfg.resolved_head_dim * itemsize)


def _paged_engine(cfg, params, **kv):
    return ContinuousBatchingEngine(cfg, params, config=EngineConfig(
        kv=KVConfig(kv_mode="paged", page_size=PAGE_SIZE, **kv),
        scheduling=SchedulingConfig(max_slots=SLOTS, max_len=MAX_LEN)))


def _warm_prefix_ttft(cfg, params, reps: int):
    """Median warm vs cold first-token latency with a shared prefix.

    One engine serves both sides: two warmup runs publish the prefix and
    compile the cold and warm prefill paths, then ``reps`` alternating
    cold (fresh random prompt, same length) and warm (shared prefix, new
    tail) single-request runs are timed.  Warm hits are asserted per run
    — a silent record eviction would re-cold the measurement."""
    rng = np.random.default_rng(1)
    prefix = rng.integers(0, cfg.vocab_size, (PREFIX_LEN,), dtype=np.int32)

    def warm_prompt():
        return np.concatenate(
            [prefix, rng.integers(0, cfg.vocab_size, (4,), dtype=np.int32)])

    eng = ContinuousBatchingEngine(cfg, params, config=EngineConfig(
        kv=KVConfig(kv_mode="paged", page_size=PREFIX_PAGE,
                    prefix_cache=True, prefix_block=64,
                    num_pages=PREFIX_PAGES),
        scheduling=SchedulingConfig(max_slots=2, max_len=PREFIX_MAX_LEN)))
    for _ in range(2):                      # publish + compile both paths
        eng.submit(warm_prompt(), max_new_tokens=2)
        eng.run()
    colds, warms = [], []
    for _ in range(reps):
        hc = eng.submit(rng.integers(0, cfg.vocab_size, (PREFIX_LEN + 4,),
                                     dtype=np.int32), max_new_tokens=2)
        out = eng.run()
        assert out["stats"].prefix_hits == 0, out["stats"].prefix_hits
        colds.append(out["results"][int(hc)].ttft_s)
        hw = eng.submit(warm_prompt(), max_new_tokens=2)
        out = eng.run()
        assert out["stats"].prefix_hits == 1, out["stats"].prefix_hits
        warms.append(out["results"][int(hw)].ttft_s)
    return float(np.median(colds)), float(np.median(warms))


def run(quick: bool = False) -> Rows:
    rows = Rows()
    cfg = get_config("llama2-7b").smoke()
    # neutral router bias => the router actually skips (the regime the
    # compact store exists for); warm-start keeps everything
    params = routing.neutral_router_bias(
        M.init_params(jax.random.PRNGKey(0), cfg))
    work = _workload(cfg, 4 if quick else 8)

    dense = ContinuousBatchingEngine(cfg, params, max_slots=SLOTS,
                                     max_len=MAX_LEN)
    paged = ContinuousBatchingEngine(cfg, params, max_slots=SLOTS,
                                     max_len=MAX_LEN, kv_mode="paged",
                                     page_size=PAGE_SIZE)
    t0 = time.time()
    ud = [dense.submit(p, max_new_tokens=n) for p, n in work]
    outd = dense.run()
    dense_s = time.time() - t0
    t0 = time.time()
    up = [paged.submit(p, max_new_tokens=n) for p, n in work]
    outp = paged.run()
    paged_s = time.time() - t0

    # identical tokens, request for request
    for a, b in zip(ud, up):
        np.testing.assert_array_equal(outd["results"][a].tokens,
                                      outp["results"][b].tokens)

    s = outp["stats"]
    itemsize = np.dtype(cfg.dtype).itemsize
    entry_bytes = 2 * cfg.num_kv_heads * cfg.resolved_head_dim * itemsize
    dense_bytes = _dense_pool_kv_bytes(cfg, SLOTS, MAX_LEN)
    paged_bytes = s.pages_peak * PAGE_SIZE * entry_bytes
    assert paged_bytes < dense_bytes, (paged_bytes, dense_bytes)
    assert s.history_hit_rate > 0.0, s.history_hit_rate

    rows.add("paged_kv/dense_pool", dense_s * 1e6,
             f"kv_bytes={dense_bytes}")
    rows.add("paged_kv/paged_pool", paged_s * 1e6,
             f"kv_bytes_peak={paged_bytes};"
             f"vs_dense={paged_bytes / dense_bytes:.3f};"
             f"pages_peak={s.pages_peak}/{s.pages_total}")
    rows.add("paged_kv/entries", 0.0,
             f"stored={s.kv_entries_stored};dense={s.kv_entries_dense};"
             f"saved={s.kv_entries_saved_fraction:.3f}")
    rows.add("paged_kv/history_hits", 0.0,
             f"hit_rate={s.history_hit_rate:.3f};"
             f"per_layer={'|'.join(f'{h:.3f}' for h in s.history_hits_per_layer)}")
    # -- quantized pages: same workload, int8 payloads ----------------------
    quant = _paged_engine(cfg, params, kv_dtype="int8")
    uq = [quant.submit(p, max_new_tokens=n) for p, n in work]
    t0 = time.time()
    outq = quant.run()
    quant_s = time.time() - t0
    sq = outq["stats"]
    assert sq.pages_peak == s.pages_peak, (sq.pages_peak, s.pages_peak)
    fp16_peak = s.pages_peak * PAGE_SIZE * page_entry_bytes(cfg)
    int8_peak = sq.pages_peak * PAGE_SIZE * page_entry_bytes(cfg, "int8")
    # greedy decode through int8 pages stays on the fp16 token path for
    # this workload; drift would surface here before it hit the floors
    agree = np.mean([
        float(np.mean(outp["results"][a].tokens == outq["results"][b].tokens))
        for a, b in zip(up, uq)])
    rows.add("paged_kv/quantized_int8", quant_s * 1e6,
             f"kv_bytes_peak={int8_peak};"
             f"vs_fp16={int8_peak / fp16_peak:.3f};"
             f"token_agreement={agree:.3f}")

    # -- warm-prefix admission: TTFT with the shared prefill skipped --------
    cold_ttft, warm_ttft = _warm_prefix_ttft(cfg, params, 3 if quick else 5)
    rows.add("paged_kv/prefix_ttft", warm_ttft * 1e6,
             f"cold_us={cold_ttft * 1e6:.0f};"
             f"cold_over_warm={cold_ttft / warm_ttft:.2f};"
             f"prefix_len={PREFIX_LEN}")

    # deterministic (seeded greedy decode) — gated by tools/bench_compare.py
    rows.meta = {
        "peak_kv_vs_dense": paged_bytes / dense_bytes,
        "live_entry_saving": s.kv_entries_saved_fraction,
        "history_hit_rate": s.history_hit_rate,
        "prefix": {"cold_over_warm_ttft": cold_ttft / warm_ttft},
        "quant": {"fp16_over_int8_peak_bytes": fp16_peak / int8_peak,
                  "token_agreement": agree},
    }
    return rows


if __name__ == "__main__":
    run().emit()
