"""Paged vs. dense KV pool on a mixed-length continuous-batching workload.

The dense slot pool preallocates ``max_slots × max_len`` KV rows per
attention layer — peak memory is independent of what the traffic actually
needs.  The paged engine allocates pages on demand and stores one entry
per (token, *executed* layer), so its live peak footprint tracks the real
context lengths *and* the router's pruning (the paper's 25.4 % KV-storage
claim, realized in decode memory).  Token output is identical by
construction (asserted here); the history-buffer hit rate is measured
from the live decode gate log, not estimated.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import Rows
from repro.configs import get_config
from repro.core import routing
from repro.models import model as M
from repro.serve.engine import ContinuousBatchingEngine

MAX_LEN = 64
SLOTS = 4
PAGE_SIZE = 8


def _workload(cfg, n: int):
    rng = np.random.default_rng(0)
    lens = [44, 8, 12, 16, 40, 8, 12, 20][:n]
    news = [2, 16, 4, 16, 2, 16, 4, 12][:n]
    prompts = [rng.integers(0, cfg.vocab_size, (l,), dtype=np.int32)
               for l in lens]
    return list(zip(prompts, news))


def _dense_pool_kv_bytes(cfg, max_slots: int, max_len: int) -> int:
    """The dense pool's KV footprint: per attention layer, k+v rows of
    [max_slots, max_len, Hkv, dh]."""
    nA = len(cfg.attention_layers)
    itemsize = np.dtype(cfg.dtype).itemsize
    return (2 * nA * max_slots * max_len
            * cfg.num_kv_heads * cfg.resolved_head_dim * itemsize)


def run(quick: bool = False) -> Rows:
    rows = Rows()
    cfg = get_config("llama2-7b").smoke()
    # neutral router bias => the router actually skips (the regime the
    # compact store exists for); warm-start keeps everything
    params = routing.neutral_router_bias(
        M.init_params(jax.random.PRNGKey(0), cfg))
    work = _workload(cfg, 4 if quick else 8)

    dense = ContinuousBatchingEngine(cfg, params, max_slots=SLOTS,
                                     max_len=MAX_LEN)
    paged = ContinuousBatchingEngine(cfg, params, max_slots=SLOTS,
                                     max_len=MAX_LEN, kv_mode="paged",
                                     page_size=PAGE_SIZE)
    t0 = time.time()
    ud = [dense.submit(p, max_new_tokens=n) for p, n in work]
    outd = dense.run()
    dense_s = time.time() - t0
    t0 = time.time()
    up = [paged.submit(p, max_new_tokens=n) for p, n in work]
    outp = paged.run()
    paged_s = time.time() - t0

    # identical tokens, request for request
    for a, b in zip(ud, up):
        np.testing.assert_array_equal(outd["results"][a].tokens,
                                      outp["results"][b].tokens)

    s = outp["stats"]
    itemsize = np.dtype(cfg.dtype).itemsize
    entry_bytes = 2 * cfg.num_kv_heads * cfg.resolved_head_dim * itemsize
    dense_bytes = _dense_pool_kv_bytes(cfg, SLOTS, MAX_LEN)
    paged_bytes = s.pages_peak * PAGE_SIZE * entry_bytes
    assert paged_bytes < dense_bytes, (paged_bytes, dense_bytes)
    assert s.history_hit_rate > 0.0, s.history_hit_rate

    rows.add("paged_kv/dense_pool", dense_s * 1e6,
             f"kv_bytes={dense_bytes}")
    rows.add("paged_kv/paged_pool", paged_s * 1e6,
             f"kv_bytes_peak={paged_bytes};"
             f"vs_dense={paged_bytes / dense_bytes:.3f};"
             f"pages_peak={s.pages_peak}/{s.pages_total}")
    rows.add("paged_kv/entries", 0.0,
             f"stored={s.kv_entries_stored};dense={s.kv_entries_dense};"
             f"saved={s.kv_entries_saved_fraction:.3f}")
    rows.add("paged_kv/history_hits", 0.0,
             f"hit_rate={s.history_hit_rate:.3f};"
             f"per_layer={'|'.join(f'{h:.3f}' for h in s.history_hits_per_layer)}")
    # deterministic (seeded greedy decode) — gated by tools/bench_compare.py
    rows.meta = {
        "peak_kv_vs_dense": paged_bytes / dense_bytes,
        "live_entry_saving": s.kv_entries_saved_fraction,
        "history_hit_rate": s.history_hit_rate,
    }
    return rows


if __name__ == "__main__":
    run().emit()
