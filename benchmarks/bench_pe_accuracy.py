"""Paper Table 1: mixed-precision computation-unit accuracy.

Compares the BFP fixed-point accumulation path (our PE array analogue)
against the fp64 oracle, for FP16(bf16)×FP16 and FP16×INT4 operand modes,
under (a) random N(0,1) data and (b) an empirical LLM-like distribution
(heavy-tailed weights, outlier-bearing activations — the Llama-2 regime
the paper samples)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Rows, time_fn
from repro.kernels import ops, ref
from repro.quant import quantize_rtn


def _empirical(key, shape, kind):
    """LLM-like: weights ~ laplace·0.02; activations with 1% 10x outliers."""
    k1, k2 = jax.random.split(key)
    if kind == "w":
        return jax.random.laplace(k1, shape) * 0.02
    x = jax.random.normal(k1, shape)
    mask = jax.random.uniform(k2, shape) < 0.01
    return jnp.where(mask, x * 10.0, x)


def _err(a, b):
    a = np.asarray(a, np.float64)
    b = np.asarray(b, np.float64)
    return float(np.linalg.norm(a - b) / (np.linalg.norm(b) + 1e-12))


def run(quick: bool = False) -> Rows:
    rows = Rows()
    M, K, N = (32, 512, 64) if quick else (64, 2048, 128)
    key = jax.random.PRNGKey(0)
    for dist in ("random", "empirical"):
        kx, kw = jax.random.split(jax.random.fold_in(key, hash(dist) % 97))
        if dist == "random":
            x64 = jax.random.normal(kx, (M, K), jnp.float32)
            w64 = jax.random.normal(kw, (K, N), jnp.float32) * 0.05
        else:
            x64 = _empirical(kx, (M, K), "x")
            w64 = _empirical(kw, (K, N), "w")
        oracle = np.asarray(x64, np.float64) @ np.asarray(w64, np.float64)

        # int4 weights via the BFP fixed-point-accumulation kernel
        codes8, scale8 = quantize_rtn(w64, min(128, K), pow2_scales=True)
        x_bf = x64.astype(jnp.bfloat16)
        t_bfp = time_fn(lambda: ops.int4_matmul(x_bf, codes8, scale8,
                                                use_kernel=True), iters=3)
        out_bfp = ops.int4_matmul(x_bf, codes8, scale8, use_kernel=True)
        # exact-dequant int4: same quantized weights, fp32 accumulation —
        # the difference isolates the ACCUMULATION-TREE error, which is the
        # quantity Table 1 compares across PE implementations.
        out_deq = ref.int4_matmul_ref(x_bf, codes8, scale8)
        rows.add(f"table1/bfp_pe/int4/{dist}", t_bfp,
                 f"total_err={_err(out_bfp, oracle):.4f};"
                 f"accum_err={_err(out_bfp, np.asarray(out_deq, np.float64)):.4f}")
        rows.add(f"table1/exact_dequant/int4/{dist}", 0.0,
                 f"total_err={_err(out_deq, oracle):.4f};accum_err=0")

        # plain bf16 matmul (cascade MAC IP analogue)
        t_mac = time_fn(lambda: x_bf @ w64.astype(jnp.bfloat16), iters=3)
        out_mac = x_bf @ w64.astype(jnp.bfloat16)
        rows.add(f"table1/cascade_mac/bf16/{dist}", t_mac,
                 f"total_err={_err(out_mac, oracle):.4f}")
    return rows


if __name__ == "__main__":
    run().emit()
