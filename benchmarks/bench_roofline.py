"""§Roofline aggregation: reads the dry-run JSON records and emits the
per-(arch × shape × mesh) three-term roofline rows (also consumed by
EXPERIMENTS.md generation)."""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import Rows

RESULTS = Path(__file__).resolve().parents[1] / "results" / "dryrun"


def load_cells():
    cells = []
    if RESULTS.exists():
        for p in sorted(RESULTS.glob("*.json")):
            cells.append(json.loads(p.read_text()))
    return cells


def run(quick: bool = False) -> Rows:
    rows = Rows()
    for c in load_cells():
        if c.get("status") != "ok" or c.get("variant", "baseline") != "baseline":
            continue
        name = f"roofline/{c['arch']}/{c['shape']}/{c['mesh']}"
        dom = c["bottleneck"]
        bound = max(c["compute_s"], c["memory_s"], c["collective_s"])
        frac = c["compute_s"] / bound if bound else 0.0
        rows.add(name, bound * 1e6,
                 f"bottleneck={dom};compute_s={c['compute_s']:.3e};"
                 f"memory_s={c['memory_s']:.3e};"
                 f"collective_s={c['collective_s']:.3e};"
                 f"useful_flops={c['useful_flops_ratio']:.2f};"
                 f"roofline_frac={frac:.3f}")
    if not rows.rows:
        rows.add("roofline/no_dryrun_results", 0.0,
                 "run: python -m repro.launch.dryrun --all")
    return rows


if __name__ == "__main__":
    run().emit()
