"""Continuous batching vs. lock-step serving on a mixed-length workload.

The quantity SkipOPU's dynamic allocation ultimately buys is *useful*
decode throughput under heterogeneous traffic: requests with different
prompt lengths and generation budgets.  The lock-step engine must pad
every prompt to the batch max and decode every row to the batch's longest
generation budget; the continuous engine retires each request the moment
it finishes and admits the next one into the freed KV slot, so no decode
step is spent on tokens nobody asked for.

Reported throughput counts only *requested* tokens (sum of per-request
``max_new``), so lock-step over-generation shows up as lost throughput —
the same normalization serving papers use for goodput.  The engine's
``kv_saved_fraction`` is *measured* from the execution-gate log — prompt
and decode phases both — not the analytic keep-rate estimate; the
warm-start router keeps everything (measured 0.000 is faithful, not a
logging gap), the neutral-bias row shows the skipping regime.

The ``continuous_fused`` row runs the same engine with
``decode_steps=8``: N decode iterations fused into one device-resident
dispatch, host scheduling overlapped with in-flight compute.  Its
goodput ratio over lock-step is the PR-6 headline, exported under
``meta.goodput`` and floor-gated by tools/bench_compare.py; the
dispatch/host-seconds counters under ``meta.host_overhead`` show where
the win comes from.
"""
from __future__ import annotations

from time import perf_counter

import jax
import numpy as np

from benchmarks.common import Rows
from repro.configs import get_config
from repro.core import routing
from repro.models import model as M
from repro.serve.engine import ContinuousBatchingEngine, ServeEngine

MAX_LEN = 64
SLOTS = 4


def _workload(cfg, n: int):
    """Heterogeneous traffic: prompt lengths and generation budgets both
    mixed, so lock-step batching pays for pad-to-max twice (prefill width
    and decode depth)."""
    rng = np.random.default_rng(0)
    lens = [44, 8, 12, 16, 40, 8, 12, 20][:n]
    news = [2, 16, 4, 16, 2, 16, 4, 12][:n]
    prompts = [rng.integers(0, cfg.vocab_size, (l,), dtype=np.int32)
               for l in lens]
    return list(zip(prompts, news))


def _run_lockstep(eng: ServeEngine, work) -> float:
    """Batches of SLOTS, prompts padded to the batch max, every row decoded
    to the batch's largest max_new.  Returns wall seconds."""
    t0 = perf_counter()
    for i in range(0, len(work), SLOTS):
        group = work[i:i + SLOTS]
        tmax = max(p.shape[0] for p, _ in group)
        batch = np.stack([np.pad(p, (0, tmax - p.shape[0])) for p, _ in group])
        eng.generate(batch, max(n for _, n in group))
    return perf_counter() - t0


def _run_continuous(eng: ContinuousBatchingEngine, work):
    t0 = perf_counter()
    for p, n in work:
        eng.submit(p, max_new_tokens=n)
    out = eng.run()
    return perf_counter() - t0, out


def run(quick: bool = False) -> Rows:
    rows = Rows()
    cfg = get_config("llama2-7b").smoke()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    # 8 requests over 4 slots: the queue-pressure regime continuous
    # batching exists for (requests > slots, heterogeneous budgets)
    work = _workload(cfg, 8)
    useful = sum(n for _, n in work)
    passes = 2 if quick else 5

    lock = ServeEngine(cfg, params, max_len=MAX_LEN)
    cont = ContinuousBatchingEngine(cfg, params, max_slots=SLOTS,
                                    max_len=MAX_LEN)
    fused = ContinuousBatchingEngine(cfg, params, max_slots=SLOTS,
                                     max_len=MAX_LEN, decode_steps=8)
    # warm pass compiles every prefill bucket / batch shape (and, for the
    # fused engine, every power-of-two epoch length); timed passes are
    # steady-state (the regime a resident server runs in), min-of-N to
    # shed interference noise from the shared host
    _run_lockstep(lock, work)
    _run_continuous(cont, work)
    _run_continuous(fused, work)
    lock_ts, cont_ts, fused_ts = [], [], []
    for _ in range(passes):
        lock_ts.append(_run_lockstep(lock, work))
        s, out = _run_continuous(cont, work)
        cont_ts.append(s)
        s, outf = _run_continuous(fused, work)
        fused_ts.append(s)
    lock_s = float(np.min(lock_ts))
    cont_s = float(np.min(cont_ts))
    fused_s = float(np.min(fused_ts))

    ttfts = [r.ttft_s for r in out["results"].values()]
    lock_tps = useful / lock_s
    cont_tps = useful / cont_s
    fused_tps = useful / fused_s
    rows.add("serve/lockstep", lock_s * 1e6 / useful,
             f"useful_tok_s={lock_tps:.1f}")
    rows.add("serve/continuous", cont_s * 1e6 / useful,
             f"useful_tok_s={cont_tps:.1f};speedup={cont_tps / lock_tps:.2f}")
    rows.add("serve/continuous_fused", fused_s * 1e6 / useful,
             f"useful_tok_s={fused_tps:.1f};"
             f"speedup={fused_tps / lock_tps:.2f};"
             f"vs_single={fused_tps / cont_tps:.2f}")
    rows.add("serve/continuous/ttft", np.mean(ttfts) * 1e6,
             f"max_ttft_s={max(ttfts):.3f}")
    rows.add("serve/continuous/kv_saved_warmstart", 0.0,
             f"measured={out['stats'].kv_saved_fraction:.3f};"
             f"analytic={out['stats'].kv_saved_analytic:.3f}")

    def _overhead(stats):
        return {"decode_dispatches": stats.decode_dispatches,
                "host_s": round(stats.host_s, 4),
                "device_s": round(stats.device_s, 4)}

    def _phase_time(stats):
        # where a run's wall time goes (prefill vs decode, and the
        # decode split between device-wait and host bookkeeping)
        return {"prefill_s": round(stats.prefill_s, 4),
                "decode_s": round(stats.decode_s, 4),
                "device_s": round(stats.device_s, 4),
                "host_s": round(stats.host_s, 4),
                "compiles": stats.compiles}

    rows.meta["goodput"] = {
        "lockstep_tok_s": round(lock_tps, 2),
        "continuous_tok_s": round(cont_tps, 2),
        "fused_tok_s": round(fused_tps, 2),
        # speedup: the headline continuous-vs-lockstep goodput ratio with
        # the fused epoch loop on (decode_steps=8); speedup_single is the
        # same engine at parity decode_steps=1
        "speedup": round(fused_tps / lock_tps, 3),
        "speedup_single": round(cont_tps / lock_tps, 3),
        "fused_vs_single": round(fused_tps / cont_tps, 3),
        "decode_steps": fused.decode_steps,
    }
    rows.meta["host_overhead"] = {
        "single": _overhead(out["stats"]),
        "fused": _overhead(outf["stats"]),
    }
    rows.meta["phase_time"] = {
        "single": _phase_time(out["stats"]),
        "fused": _phase_time(outf["stats"]),
    }

    # skipping-router regime: measured storage saving from logged gates
    eng = ContinuousBatchingEngine(cfg, routing.neutral_router_bias(params),
                                   max_slots=SLOTS, max_len=MAX_LEN)
    _, out2 = _run_continuous(eng, work[:4])
    rows.add("serve/continuous/kv_saved_skipping", 0.0,
             f"measured={out2['stats'].kv_saved_fraction:.3f};"
             f"analytic={out2['stats'].kv_saved_analytic:.3f}")
    return rows


if __name__ == "__main__":
    run().emit()
