"""Continuous batching vs. lock-step serving on a mixed-length workload.

The quantity SkipOPU's dynamic allocation ultimately buys is *useful*
decode throughput under heterogeneous traffic: requests with different
prompt lengths and generation budgets.  The lock-step engine must pad
every prompt to the batch max and decode every row to the batch's longest
generation budget; the continuous engine retires each request the moment
it finishes and admits the next one into the freed KV slot, so no decode
step is spent on tokens nobody asked for.

Reported throughput counts only *requested* tokens (sum of per-request
``max_new``), so lock-step over-generation shows up as lost throughput —
the same normalization serving papers use for goodput.  The engine's
``kv_saved_fraction`` is *measured* from the per-step execution-gate log
(kv_reuse.storage_saved_fraction), not the analytic keep-rate estimate;
the warm-start router keeps everything (saved = 0), the neutral-bias row
shows the skipping regime.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import Rows
from repro.configs import get_config
from repro.core import routing
from repro.models import model as M
from repro.serve.engine import ContinuousBatchingEngine, ServeEngine

MAX_LEN = 64
SLOTS = 4


def _workload(cfg, n: int):
    """Heterogeneous traffic: prompt lengths and generation budgets both
    mixed, so lock-step batching pays for pad-to-max twice (prefill width
    and decode depth)."""
    rng = np.random.default_rng(0)
    lens = [44, 8, 12, 16, 40, 8, 12, 20][:n]
    news = [2, 16, 4, 16, 2, 16, 4, 12][:n]
    prompts = [rng.integers(0, cfg.vocab_size, (l,), dtype=np.int32)
               for l in lens]
    return list(zip(prompts, news))


def _run_lockstep(eng: ServeEngine, work) -> float:
    """Batches of SLOTS, prompts padded to the batch max, every row decoded
    to the batch's largest max_new.  Returns wall seconds."""
    t0 = time.time()
    for i in range(0, len(work), SLOTS):
        group = work[i:i + SLOTS]
        tmax = max(p.shape[0] for p, _ in group)
        batch = np.stack([np.pad(p, (0, tmax - p.shape[0])) for p, _ in group])
        eng.generate(batch, max(n for _, n in group))
    return time.time() - t0


def _run_continuous(eng: ContinuousBatchingEngine, work):
    t0 = time.time()
    for p, n in work:
        eng.submit(p, max_new_tokens=n)
    out = eng.run()
    return time.time() - t0, out


def run(quick: bool = False) -> Rows:
    rows = Rows()
    cfg = get_config("llama2-7b").smoke()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    # 8 requests over 4 slots: the queue-pressure regime continuous
    # batching exists for (requests > slots, heterogeneous budgets)
    work = _workload(cfg, 8)
    useful = sum(n for _, n in work)
    passes = 2 if quick else 5

    lock = ServeEngine(cfg, params, max_len=MAX_LEN)
    cont = ContinuousBatchingEngine(cfg, params, max_slots=SLOTS,
                                    max_len=MAX_LEN)
    # warm pass compiles every prefill bucket / batch shape; timed passes
    # are steady-state (the regime a resident server runs in), min-of-N to
    # shed interference noise from the shared host
    _run_lockstep(lock, work)
    _run_continuous(cont, work)
    lock_ts, cont_ts = [], []
    for _ in range(passes):
        lock_ts.append(_run_lockstep(lock, work))
        s, out = _run_continuous(cont, work)
        cont_ts.append(s)
    lock_s = float(np.min(lock_ts))
    cont_s = float(np.min(cont_ts))

    ttfts = [r.ttft_s for r in out["results"].values()]
    lock_tps = useful / lock_s
    cont_tps = useful / cont_s
    rows.add("serve/lockstep", lock_s * 1e6 / useful,
             f"useful_tok_s={lock_tps:.1f}")
    rows.add("serve/continuous", cont_s * 1e6 / useful,
             f"useful_tok_s={cont_tps:.1f};speedup={cont_tps / lock_tps:.2f}")
    rows.add("serve/continuous/ttft", np.mean(ttfts) * 1e6,
             f"max_ttft_s={max(ttfts):.3f}")
    rows.add("serve/continuous/kv_saved_warmstart", 0.0,
             f"measured={out['stats'].kv_saved_fraction:.3f};"
             f"analytic={out['stats'].kv_saved_analytic:.3f}")

    # skipping-router regime: measured storage saving from logged gates
    eng = ContinuousBatchingEngine(cfg, routing.neutral_router_bias(params),
                                   max_slots=SLOTS, max_len=MAX_LEN)
    _, out2 = _run_continuous(eng, work[:4])
    rows.add("serve/continuous/kv_saved_skipping", 0.0,
             f"measured={out2['stats'].kv_saved_fraction:.3f};"
             f"analytic={out2['stats'].kv_saved_analytic:.3f}")
    return rows


if __name__ == "__main__":
    run().emit()
