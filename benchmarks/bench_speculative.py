"""Self-speculative decoding vs. plain continuous decoding.

The speculative loop (docs/speculative.md) buys decode throughput the
same way the fused-epoch loop does — fewer host round-trips per emitted
token — plus the layer-skip lever: the k-token draft runs device-resident
in ONE dispatch (a ``lax.scan``, like the fused loop) and the k+1-column
verify is one more, so a fully-accepted window emits k+1 tokens for 2
dispatches where the plain engine pays k+1.  Acceptance-friendly traffic
here means greedy decoding with an unbiased draft (``draft_keep=1``): the
draft pass IS the target pass, acceptance is 100%, and the window's
emitted chain is bit-identical to plain greedy decoding — asserted below
and exported as ``meta.speculative.temp0_identical`` so the CI floor
fails if speculation ever buys speed by changing tokens.

``meta.speculative.speedup`` — speculative vs. plain decode tok/s on the
same machine, decode-dominant workload — is floor-gated (>= 1.2) by
tools/bench_compare.py.
"""
from __future__ import annotations

from time import perf_counter

import jax
import numpy as np

from benchmarks.common import Rows
from repro.configs import get_config
from repro.models import model as M
from repro.serve.engine import ContinuousBatchingEngine

MAX_LEN = 64
SLOTS = 4
SPEC_K = 8


def _workload(cfg, n: int):
    """Decode-dominant traffic: short prompts, long generation budgets —
    the regime where per-token dispatch overhead dominates and windowed
    emission pays off."""
    rng = np.random.default_rng(0)
    lens = [8, 12, 6, 10, 8, 14, 6, 12][:n]
    news = [24, 20, 24, 16, 24, 20, 24, 16][:n]
    prompts = [rng.integers(0, cfg.vocab_size, (l,), dtype=np.int32)
               for l in lens]
    return list(zip(prompts, news))


def _run(eng: ContinuousBatchingEngine, work):
    t0 = perf_counter()
    for p, n in work:
        eng.submit(p, max_new_tokens=n)
    out = eng.run()
    return perf_counter() - t0, out


def _tokens(out, uids_sorted_by_submit_order):
    return [out["results"][u].tokens for u in uids_sorted_by_submit_order]


def run(quick: bool = False) -> Rows:
    rows = Rows()
    cfg = get_config("llama2-7b").smoke()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    work = _workload(cfg, 4 if quick else 8)
    useful = sum(n for _, n in work)
    passes = 2 if quick else 5

    plain = ContinuousBatchingEngine(cfg, params, max_slots=SLOTS,
                                     max_len=MAX_LEN)
    spec = ContinuousBatchingEngine(cfg, params, max_slots=SLOTS,
                                    max_len=MAX_LEN, spec_k=SPEC_K)
    # warm pass compiles the prefill buckets, the plain decode step and
    # the per-γ draft/verify variants; timed passes are steady-state
    _, out_p = _run(plain, work)
    _, out_s = _run(spec, work)

    # temperature-0 identity on the SAME engine path (dense vs dense):
    # speculation must never buy speed by changing tokens
    identical = True
    for (tp, ts) in zip(sorted(out_p["results"]), sorted(out_s["results"])):
        if not np.array_equal(out_p["results"][tp].tokens,
                              out_s["results"][ts].tokens):
            identical = False
    assert identical, "speculative greedy diverged from plain greedy"

    plain_ts, spec_ts = [], []
    for _ in range(passes):
        s, out_p = _run(plain, work)
        plain_ts.append(s)
        s, out_s = _run(spec, work)
        spec_ts.append(s)
    plain_s = float(np.min(plain_ts))
    spec_s = float(np.min(spec_ts))
    plain_tps = useful / plain_s
    spec_tps = useful / spec_s
    st = out_s["stats"]

    rows.add("speculative/plain", plain_s * 1e6 / useful,
             f"tok_s={plain_tps:.1f}")
    rows.add("speculative/spec_k8", spec_s * 1e6 / useful,
             f"tok_s={spec_tps:.1f};speedup={spec_tps / plain_tps:.2f};"
             f"acceptance={st.spec_acceptance_rate:.3f}")
    rows.add("speculative/windows", 0.0,
             f"windows={st.spec_windows};"
             f"dispatches={st.decode_dispatches};"
             f"rolled_back={st.spec_entries_rolled_back}")

    rows.meta["speculative"] = {
        "speedup": round(spec_tps / plain_tps, 3),
        "temp0_identical": int(identical),
        "acceptance_rate": round(st.spec_acceptance_rate, 4),
        "spec_k": SPEC_K,
        "windows": st.spec_windows,
        "tokens_drafted": st.spec_tokens_drafted,
        "tokens_accepted": st.spec_tokens_accepted,
        "decode_dispatches": st.decode_dispatches,
        "plain_tok_s": round(plain_tps, 2),
        "spec_tok_s": round(spec_tps, 2),
    }

    # paged twin: tentative-commit protocol on, acceptance unchanged,
    # identity is same-path (spec-paged vs plain-paged)
    pplain = ContinuousBatchingEngine(cfg, params, max_slots=SLOTS,
                                      max_len=MAX_LEN, kv_mode="paged")
    pspec = ContinuousBatchingEngine(cfg, params, max_slots=SLOTS,
                                     max_len=MAX_LEN, kv_mode="paged",
                                     spec_k=SPEC_K)
    _, pout = _run(pplain, work)
    _, sout = _run(pspec, work)
    paged_identical = all(
        np.array_equal(pout["results"][a].tokens, sout["results"][b].tokens)
        for a, b in zip(sorted(pout["results"]), sorted(sout["results"])))
    assert paged_identical, "paged speculative diverged from paged plain"
    ps, pout = _run(pplain, work)
    ss, sout = _run(pspec, work)
    rows.add("speculative/paged_spec_k8", ss * 1e6 / useful,
             f"tok_s={useful / ss:.1f};speedup={ps / ss:.2f};"
             f"acceptance={sout['stats'].spec_acceptance_rate:.3f}")
    rows.meta["speculative"]["paged_speedup"] = round(ps / ss, 3)
    rows.meta["speculative"]["paged_temp0_identical"] = int(paged_identical)
    rows.meta["speculative"]["paged_rolled_back"] = (
        sout["stats"].spec_entries_rolled_back)
    return rows


if __name__ == "__main__":
    run().emit()
