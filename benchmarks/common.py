"""Shared benchmark utilities."""
from __future__ import annotations

import time
from typing import Callable, List, Tuple

import jax


def time_fn(fn: Callable, *args, iters: int = 5, warmup: int = 2) -> float:
    """Median wall-time per call in microseconds (CPU timing — used for
    *relative* comparisons between configurations, mirroring the paper's
    normalized speedups; absolute TPU numbers come from the roofline)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


class Rows:
    def __init__(self):
        self.rows: List[Tuple[str, float, str]] = []
        # optional machine-readable payload (roofline byte counts etc.)
        # written alongside the CSV rows into BENCH_<name>.json
        self.meta: dict = {}

    def add(self, name: str, us: float, derived: str = ""):
        self.rows.append((name, us, derived))

    def emit(self):
        for name, us, derived in self.rows:
            print(f"{name},{us:.1f},{derived}")

    def to_json(self, suite: str) -> dict:
        return {
            "suite": suite,
            "rows": [{"name": n, "us_per_call": us, "derived": d}
                     for n, us, d in self.rows],
            "meta": self.meta,
        }
