"""Render §Dry-run / §Roofline markdown tables from results/dryrun/*.json.

  PYTHONPATH=src python -m benchmarks.render_experiments [--mesh 16x16]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

RESULTS = Path(__file__).resolve().parents[1] / "results" / "dryrun"

ARCH_ORDER = ["qwen3-8b", "stablelm-3b", "deepseek-coder-33b", "gemma3-12b",
              "musicgen-medium", "grok-1-314b", "arctic-480b", "qwen2-vl-2b",
              "jamba-v0.1-52b", "mamba2-2.7b"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def fmt(x, digits=3):
    if x == 0:
        return "0"
    if abs(x) < 1e-3 or abs(x) >= 1e4:
        return f"{x:.2e}"
    return f"{x:.{digits}g}"


def load(variant="baseline"):
    cells = {}
    for p in sorted(RESULTS.glob("*.json")):
        c = json.loads(p.read_text())
        if c.get("variant", "baseline") != variant:
            continue
        cells[(c["arch"], c["shape"], c["mesh"])] = c
    return cells


def roofline_table(cells, mesh):
    print(f"\n### Roofline — mesh {mesh} (per device, per step)\n")
    print("| arch | shape | compute s | memory s | collective s | bottleneck"
          " | MODEL/HLO flops | mem fit (args+temp GB) |")
    print("|---|---|---|---|---|---|---|---|")
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            c = cells.get((a, s, mesh))
            if c is None:
                continue
            if c["status"] == "skipped":
                print(f"| {a} | {s} | — | — | — | *skipped:"
                      f" full-attention @500k* | — | — |")
                continue
            ma = c.get("memory_analysis", {})
            args_gb = ma.get("argument_size_in_bytes", 0) / 1e9
            temp_gb = ma.get("temp_size_in_bytes", 0) / 1e9
            print(f"| {a} | {s} | {fmt(c['compute_s'])} | {fmt(c['memory_s'])}"
                  f" | {fmt(c['collective_s'])} | **{c['bottleneck']}** | "
                  f"{c['useful_flops_ratio']:.2f} | "
                  f"{args_gb:.1f}+{temp_gb:.1f} |")


def dryrun_table(cells):
    print("\n### Dry-run compile matrix (status × mesh)\n")
    print("| arch | " + " | ".join(SHAPE_ORDER) + " |")
    print("|---|" + "---|" * len(SHAPE_ORDER))
    for a in ARCH_ORDER:
        row = [a]
        for s in SHAPE_ORDER:
            marks = []
            for mesh, tag in (("16x16", "1pod"), ("2x16x16", "2pod")):
                c = cells.get((a, s, mesh))
                if c is None:
                    marks.append("?")
                elif c["status"] == "ok":
                    marks.append("✓")
                elif c["status"] == "skipped":
                    marks.append("skip")
                else:
                    marks.append("FAIL")
            row.append("/".join(marks))
        print("| " + " | ".join(row) + " |")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--variant", default="baseline")
    args = ap.parse_args()
    cells = load(args.variant)
    dryrun_table(cells)
    for mesh in ("16x16", "2x16x16"):
        roofline_table(cells, mesh)


if __name__ == "__main__":
    main()
