"""Benchmark harness — one module per paper table/figure.

  python -m benchmarks.run [--quick] [--only fig8,...] [--out-dir DIR]

Prints ``name,us_per_call,derived`` CSV rows and writes one
machine-readable ``BENCH_<name>.json`` per suite entry (per-bench
wall-clock + any roofline byte accounting the bench attaches via
``Rows.meta``) — the CI perf artifact, so the perf trajectory is
recorded run over run.
"""
import argparse
import json
import pathlib
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None)
    ap.add_argument("--out-dir", default=".",
                    help="directory for BENCH_<name>.json artifacts")
    args = ap.parse_args()

    from benchmarks import (bench_bandwidth, bench_chunked_prefill,
                            bench_end_to_end, bench_fault_tolerance,
                            bench_fused_linear, bench_kv_storage,
                            bench_mha_dataflow, bench_observability,
                            bench_paged_kv, bench_pe_accuracy,
                            bench_roofline, bench_serve,
                            bench_speculative)
    suite = {
        "table1_pe_accuracy": bench_pe_accuracy,
        "fig8_mha_dataflow": bench_mha_dataflow,
        "fig9_bandwidth": bench_bandwidth,
        "kv_storage_25pct": bench_kv_storage,
        "table3_end_to_end": bench_end_to_end,
        "serve_continuous": bench_serve,
        "paged_kv": bench_paged_kv,
        "fused_linear": bench_fused_linear,
        "chunked_prefill": bench_chunked_prefill,
        "observability": bench_observability,
        "fault_tolerance": bench_fault_tolerance,
        "speculative": bench_speculative,
        "roofline": bench_roofline,
    }
    only = set(args.only.split(",")) if args.only else None
    out_dir = pathlib.Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)
    failed = 0
    print("name,us_per_call,derived")
    for name, mod in suite.items():
        if only and name not in only:
            continue
        try:
            rows = mod.run(quick=args.quick)
            rows.emit()
            (out_dir / f"BENCH_{name}.json").write_text(
                json.dumps(rows.to_json(name), indent=1, sort_keys=True))
        except Exception:
            failed += 1
            print(f"{name},0.0,ERROR", file=sys.stdout)
            traceback.print_exc()
    sys.exit(1 if failed else 0)


if __name__ == "__main__":
    main()
