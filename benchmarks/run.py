"""Benchmark harness — one module per paper table/figure.

  python -m benchmarks.run [--quick] [--only fig8,...]

Prints ``name,us_per_call,derived`` CSV rows.
"""
import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    from benchmarks import (bench_bandwidth, bench_end_to_end,
                            bench_kv_storage, bench_mha_dataflow,
                            bench_paged_kv, bench_pe_accuracy,
                            bench_roofline, bench_serve)
    suite = {
        "table1_pe_accuracy": bench_pe_accuracy,
        "fig8_mha_dataflow": bench_mha_dataflow,
        "fig9_bandwidth": bench_bandwidth,
        "kv_storage_25pct": bench_kv_storage,
        "table3_end_to_end": bench_end_to_end,
        "serve_continuous": bench_serve,
        "paged_kv": bench_paged_kv,
        "roofline": bench_roofline,
    }
    only = set(args.only.split(",")) if args.only else None
    failed = 0
    print("name,us_per_call,derived")
    for name, mod in suite.items():
        if only and name not in only:
            continue
        try:
            mod.run(quick=args.quick).emit()
        except Exception:
            failed += 1
            print(f"{name},0.0,ERROR", file=sys.stdout)
            traceback.print_exc()
    sys.exit(1 if failed else 0)


if __name__ == "__main__":
    main()
