"""Fault-tolerance walkthrough: train on a 4×2 mesh, simulate preemption,
resume from the atomic checkpoint on a SHRUNK 2×2 mesh (elastic scaling via
reshard-on-restore).  Runs on 8 forced CPU host devices.

  PYTHONPATH=src python examples/elastic_restart.py
"""
import os

os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=8")

import dataclasses
import tempfile

import jax
import numpy as np

from repro.configs import get_config
from repro.distributed.sharding import ShardingPolicy
from repro.train import checkpoint as ck
from repro.train.fault_tolerance import ElasticPlan
from repro.train.trainer import Trainer, TrainerConfig


def main():
    cfg = dataclasses.replace(get_config("qwen3-8b").smoke(), num_layers=2)
    ckpt = tempfile.mkdtemp(prefix="elastic_")
    common = dict(seq_len=32, global_batch=8, lr=1e-3, log_every=2,
                  ckpt_every=4, ckpt_dir=ckpt)

    print("phase 1: train on 4x2 mesh (8 'chips'), checkpoint every 4 steps")
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    with mesh:
        tr = Trainer(cfg, TrainerConfig(steps=8, **common),
                     ShardingPolicy(mesh, cfg, mode="train"))
        tr.run()
    step = ck.latest_step(ckpt)
    print(f"  ... 'preempted' after checkpoint at step {step}")

    print("phase 2: one host lost -> ElasticPlan remaps the mesh")
    plan = ElasticPlan(model=2)
    new_mesh_shape = plan.mesh_for(surviving_chips=4)
    print(f"  surviving=4 chips -> mesh {new_mesh_shape}")

    mesh2 = jax.make_mesh(new_mesh_shape, ("data", "model"))
    with mesh2:
        tr2 = Trainer(cfg, TrainerConfig(steps=16, **common),
                      ShardingPolicy(mesh2, cfg, mode="train"))
        state = tr2.run(resume=True)   # reshard-on-restore
    print(f"  resumed from step {step} and finished at step "
          f"{int(state['data_step'])} on the {new_mesh_shape} mesh")
    for m in tr2.metrics_log:
        print(f"  step {m['step']:3d}  loss {m['loss']:.3f}")
    print("elastic restart complete — loss curve continued across meshes")


if __name__ == "__main__":
    main()
