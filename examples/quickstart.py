"""Quickstart: build a SkipGPT-routed model, take a few training steps,
then generate with the dynamic-computation pipeline (routing + cross-layer
KV reuse).  Runs on CPU in ~a minute.

  PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

import jax
import numpy as np

from repro.configs import get_config
from repro.models import model as M
from repro.serve.engine import ServeEngine
from repro.train.trainer import Trainer, TrainerConfig


def main():
    # a reduced Llama-2 (the paper's workload) with ~25% token skipping
    cfg = get_config("llama2-7b").smoke()
    cfg = dataclasses.replace(cfg, num_layers=2)
    print(f"arch={cfg.name}  layers={cfg.num_layers}  d={cfg.d_model}  "
          f"keep_prob={cfg.skip.keep_prob}")

    trainer = Trainer(cfg, TrainerConfig(seq_len=64, global_batch=4,
                                         steps=30, lr=1e-3, log_every=10))
    state = trainer.run()
    for m in trainer.metrics_log:
        print(f"  step {m['step']:3d}  loss {m['loss']:.3f}  "
              f"keep {m['keep_frac']:.2f}")

    eng = ServeEngine(cfg, state["params"], max_len=80)
    prompts = np.random.default_rng(0).integers(0, cfg.vocab_size, (2, 32),
                                                dtype=np.int32)
    out = eng.generate(prompts, 8)
    s = out["stats"]
    print(f"generated: {out['tokens'][0].tolist()}")
    print(f"decode {s.decode_tok_per_s:.1f} tok/s | "
          f"attention keep≈{s.attn_keep_frac:.2f} | "
          f"KV storage saved≈{s.kv_saved_fraction:.1%} measured / "
          f"{s.kv_saved_analytic:.1%} at target keep (paper: up to 25.4%)")


if __name__ == "__main__":
    main()
