"""Serving example: batched generation through the SkipOPU pipeline —
compacted (gather) prefill, routed decode with cross-layer KV reuse, int4
weights — with the ablation grid of paper Fig. 8.

  PYTHONPATH=src python examples/serve_skipgpt.py
"""
import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import model as M
from repro.quant import quantize_params
from repro.serve.engine import ServeEngine


def run_config(name, cfg, params, prompts, new_tokens=12):
    eng = ServeEngine(cfg, params, max_len=prompts.shape[1] + new_tokens)
    out = eng.generate(prompts, new_tokens)
    s = out["stats"]
    print(f"{name:24s} decode {s.decode_tok_per_s:7.1f} tok/s | "
          f"prefill {s.prefill_s:5.2f}s | KV saved {s.kv_saved_fraction:.1%}")
    return out


def main():
    base = get_config("llama2-7b").smoke()
    params = M.init_params(jax.random.PRNGKey(0), base)
    prompts = np.random.default_rng(0).integers(0, base.vocab_size, (4, 48),
                                                dtype=np.int32)

    # Fig. 8 ablation ladder
    dense = dataclasses.replace(
        base, skip=dataclasses.replace(base.skip, enabled=False))
    partial = dataclasses.replace(
        base, skip=dataclasses.replace(base.skip, kv_reuse=False))
    reuse = base
    opt = dataclasses.replace(
        base, skip=dataclasses.replace(base.skip, mode="gather"))

    run_config("baseline (dense)", dense, params, prompts)
    run_config("partial-skip", partial, params, prompts)
    run_config("kv-reuse", reuse, params, prompts)
    run_config("kv-reuse + gather OPT", opt, params, prompts)

    # paper §4.2: int4 weights (BFP domain)
    qparams = quantize_params(params, base.quant.group_size,
                              base.quant.pow2_scales, min_size=1 << 12)
    run_config("kv-reuse + int4 W", reuse, qparams, prompts)


if __name__ == "__main__":
    main()
