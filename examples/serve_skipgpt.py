"""Serving example: batched generation through the SkipOPU pipeline —
compacted (gather) prefill, routed decode with cross-layer KV reuse, int4
weights — with the ablation grid of paper Fig. 8.

  PYTHONPATH=src python examples/serve_skipgpt.py
"""
import dataclasses

import jax
import numpy as np

from repro.configs import get_config
from repro.models import model as M
from repro.quant import quantize_params
from repro.serve.engine import ContinuousBatchingEngine, ServeEngine


def run_config(name, cfg, params, prompts, new_tokens=12):
    eng = ServeEngine(cfg, params, max_len=prompts.shape[1] + new_tokens)
    out = eng.generate(prompts, new_tokens)
    s = out["stats"]
    print(f"{name:24s} decode {s.decode_tok_per_s:7.1f} tok/s | "
          f"prefill {s.prefill_s:5.2f}s | KV saved "
          f"{s.kv_saved_fraction:.1%} measured / "
          f"{s.kv_saved_analytic:.1%} at target keep")
    return out


def main():
    base = get_config("llama2-7b").smoke()
    params = M.init_params(jax.random.PRNGKey(0), base)
    prompts = np.random.default_rng(0).integers(0, base.vocab_size, (4, 48),
                                                dtype=np.int32)

    # Fig. 8 ablation ladder
    dense = dataclasses.replace(
        base, skip=dataclasses.replace(base.skip, enabled=False))
    partial = dataclasses.replace(
        base, skip=dataclasses.replace(base.skip, kv_reuse=False))
    reuse = base
    opt = dataclasses.replace(
        base, skip=dataclasses.replace(base.skip, mode="gather"))

    run_config("baseline (dense)", dense, params, prompts)
    run_config("partial-skip", partial, params, prompts)
    run_config("kv-reuse", reuse, params, prompts)
    run_config("kv-reuse + gather OPT", opt, params, prompts)

    # paper §4.2: int4 weights (BFP domain)
    qparams = quantize_params(params, base.quant.group_size,
                              base.quant.pow2_scales, min_size=1 << 12)
    run_config("kv-reuse + int4 W", reuse, qparams, prompts)

    # continuous batching: mixed-length requests through a 2-slot KV pool,
    # each decoding at its own position (docs/serving.md)
    rng = np.random.default_rng(1)
    eng = ContinuousBatchingEngine(base, params, max_slots=2, max_len=64)
    for ln, new in [(48, 6), (12, 12), (30, 8), (7, 12)]:
        eng.submit(rng.integers(0, base.vocab_size, (ln,), dtype=np.int32),
                   max_new_tokens=new)
    out = eng.run()
    s = out["stats"]
    print(f"{'continuous (2 slots)':24s} decode {s.decode_tok_per_s:7.1f} "
          f"tok/s | prefill {s.prefill_s:5.2f}s | "
          f"KV saved {s.kv_saved_fraction:.1%} (measured)")
    for uid, r in sorted(out["results"].items()):
        print(f"  req {uid}: T0={r.prompt_len:2d} +{r.decode_tokens:2d} tok "
              f"TTFT {r.ttft_s*1e3:6.1f}ms  {r.decode_tok_per_s:6.1f} tok/s "
              f"({r.finish_reason})")

    # chunked prefill: the same traffic with prompts processed 16 tokens
    # at a time, interleaved between resident decode steps so a long
    # prompt cannot stall every decode slot — token-identical output,
    # bounded per-request decode stalls (docs/serving.md)
    eng = ContinuousBatchingEngine(base, params, max_slots=2, max_len=64,
                                   prefill_chunk=16)
    for ln, new in [(48, 6), (12, 12), (30, 8), (7, 12)]:
        eng.submit(rng.integers(0, base.vocab_size, (ln,), dtype=np.int32),
                   max_new_tokens=new)
    out = eng.run()
    s = out["stats"]
    worst = max(r.max_decode_stall_s for r in out["results"].values())
    print(f"{'  + chunked prefill':24s} decode {s.decode_tok_per_s:7.1f} "
          f"tok/s | {s.prefill_chunks} chunks, {s.interleaved_steps} "
          f"interleaved steps | worst decode stall {worst*1e3:.1f}ms")

    # fused decode epochs: 8 decode steps per device dispatch — the
    # dispatch/host counters show where the win over per-token dispatch
    # comes from (docs/serving.md); `compiles` counts the pow2 epoch
    # lengths the run had to build (visible per step via trace=...)
    eng = ContinuousBatchingEngine(base, params, max_slots=2, max_len=64,
                                   decode_steps=8)
    for ln, new in [(48, 6), (12, 12), (30, 8), (7, 12)]:
        eng.submit(rng.integers(0, base.vocab_size, (ln,), dtype=np.int32),
                   max_new_tokens=new)
    out = eng.run()
    s = out["stats"]
    print(f"{'  + fused epochs (x8)':24s} decode {s.decode_tok_per_s:7.1f} "
          f"tok/s | {s.decode_dispatches} decode dispatches for "
          f"{s.decode_tokens} tokens | host {s.host_s:.2f}s vs "
          f"device-wait {s.device_s:.2f}s | {s.compiles} compiles")


if __name__ == "__main__":
    main()
