"""End-to-end training driver: train a SkipGPT model on the synthetic LM
stream with checkpointing + fault-tolerance hooks.

  PYTHONPATH=src python examples/train_skipgpt.py             # ~10M demo
  PYTHONPATH=src python examples/train_skipgpt.py --preset 100m --steps 300

The 100m preset is the deliverable's "~100M model for a few hundred steps"
configuration — sized for a single accelerator; the demo preset shows the
same curves in CPU-minutes.
"""
import argparse
import dataclasses

from repro.configs import get_config
from repro.configs.base import ModelConfig, SkipConfig
from repro.train.trainer import Trainer, TrainerConfig

PRESETS = {
    # ~10M params: CPU-minutes demo
    "demo": dict(num_layers=4, d_model=256, num_heads=4, num_kv_heads=4,
                 d_ff=1024, vocab_size=2048, seq=128, batch=4, steps=150,
                 lr=1e-3),
    # ~100M params: the deliverable configuration (single TPU/GPU class)
    "100m": dict(num_layers=12, d_model=768, num_heads=12, num_kv_heads=12,
                 d_ff=3072, vocab_size=32000, seq=512, batch=8, steps=300,
                 lr=6e-4),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=PRESETS, default="demo")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--ckpt-dir", default="/tmp/skipgpt_ckpt")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()
    p = PRESETS[args.preset]

    cfg = ModelConfig(
        name=f"skipgpt-{args.preset}", family="dense",
        num_layers=p["num_layers"], d_model=p["d_model"],
        num_heads=p["num_heads"], num_kv_heads=p["num_kv_heads"],
        d_ff=p["d_ff"], vocab_size=p["vocab_size"],
        skip=SkipConfig(enabled=True, keep_prob=0.75),
        attn_chunk=256, xent_chunk=256, remat=False)
    print(f"params ≈ {cfg.param_count()/1e6:.1f}M  "
          f"(SkipGPT routing on, target keep={cfg.skip.keep_prob})")

    steps = args.steps or p["steps"]
    tcfg = TrainerConfig(seq_len=p["seq"], global_batch=p["batch"],
                         steps=steps, lr=p["lr"], warmup=max(steps // 10, 5),
                         ckpt_dir=args.ckpt_dir, ckpt_every=max(steps // 4, 10),
                         log_every=max(steps // 15, 1))
    tr = Trainer(cfg, tcfg)
    state = tr.run(resume=args.resume)
    print("step   loss    xent    keep")
    for m in tr.metrics_log:
        print(f"{m['step']:5d}  {m['loss']:.3f}  {m['xent']:.3f}  "
              f"{m['keep_frac']:.2f}")
    d = tr.metrics_log
    print(f"\nloss {d[0]['loss']:.3f} -> {d[-1]['loss']:.3f} over "
          f"{int(state['data_step'])} steps; router keep converged to "
          f"{d[-1]['keep_frac']:.2f} (target {cfg.skip.keep_prob})")


if __name__ == "__main__":
    main()
