"""SkipOPU reproduction framework (JAX/TPU)."""
__version__ = "0.1.0"
