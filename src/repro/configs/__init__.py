"""Config registry: one module per assigned architecture (+ the paper's own
Llama-2 workload).  Importing this package populates the registry."""
from repro.configs.base import (  # noqa: F401
    SHAPES,
    ModelConfig,
    QuantConfig,
    SkipConfig,
    get_config,
    list_configs,
    register,
)

# Assigned architecture pool (10) + paper workload.
from repro.configs import (  # noqa: F401
    arctic_480b,
    deepseek_coder_33b,
    gemma3_12b,
    grok_1_314b,
    jamba_v0_1_52b,
    llama2_7b,
    mamba2_2_7b,
    musicgen_medium,
    qwen2_vl_2b,
    qwen3_8b,
    stablelm_3b,
)

ASSIGNED_ARCHS = (
    "qwen3-8b",
    "stablelm-3b",
    "deepseek-coder-33b",
    "gemma3-12b",
    "musicgen-medium",
    "grok-1-314b",
    "arctic-480b",
    "qwen2-vl-2b",
    "jamba-v0.1-52b",
    "mamba2-2.7b",
)
