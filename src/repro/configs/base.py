"""Model/config system for the SkipOPU reproduction framework.

A ``ModelConfig`` fully describes one architecture: the transformer (or hybrid)
backbone, the SkipGPT dynamic-computation settings, quantization, and the
distribution hints the sharding policy consumes.  Full-size configs are only
ever *lowered* (dry-run, ``jax.eval_shape``); every config also exposes
``smoke()`` which shrinks it to a CPU-runnable size with identical structure.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field, replace
from typing import Any, Dict, Optional, Sequence, Tuple

# ---------------------------------------------------------------------------
# Block kinds usable in ``layer_pattern`` (cycled over the layer stack).
ATTN = "attn"          # global causal attention
LOCAL = "local"        # sliding-window causal attention
MAMBA = "mamba"        # Mamba-2 SSD block (attention-free)

VALID_BLOCKS = (ATTN, LOCAL, MAMBA)

# Assigned input-shape grid (same 4 shapes for every LM arch).
SHAPES: Dict[str, Dict[str, int]] = {
    "train_4k": dict(seq_len=4096, global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32768, global_batch=32, kind="prefill"),
    "decode_32k": dict(seq_len=32768, global_batch=128, kind="decode"),
    "long_500k": dict(seq_len=524288, global_batch=1, kind="decode"),
}


@dataclass(frozen=True)
class SkipConfig:
    """SkipGPT dynamic-computation-allocation settings (the paper's technique)."""

    enabled: bool = True
    # Fraction of tokens that *keep* (execute) each routed submodule.  The paper
    # prunes ~25% => keep 0.75.
    keep_prob: float = 0.75
    # Straight-through Gumbel temperature used at training time.
    tau: float = 1.0
    # Execution realization: "masked" multiplies submodule output by the 0/1
    # gate (training-faithful; no FLOP savings), "gather" compacts the kept
    # tokens into a static-capacity tile (TPU-native FLOP savings).
    mode: str = "masked"
    # Cross-layer KV reuse for tokens that skip attention (paper §2.1/§4.4).
    kv_reuse: bool = True
    # Router aux-loss weight steering the average keep rate to ``keep_prob``.
    router_loss_weight: float = 1e-2
    # Route these submodules.  Mamba blocks use masked-contribution routing.
    route_attention: bool = True
    route_mlp: bool = True
    route_ssm: bool = True


@dataclass(frozen=True)
class QuantConfig:
    """Weight quantization (paper §4.2: INT4 weights, FP16/bf16 activations,
    BFP fixed-point accumulation)."""

    enabled: bool = False
    bits: int = 4
    group_size: int = 128
    # Use power-of-2 ("BFP") scales so accumulation happens in a shared-exponent
    # integer domain, mirroring the paper's accumulation tree.
    pow2_scales: bool = True


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | hybrid | ssm | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 => d_model // num_heads

    # --- attention details -------------------------------------------------
    layer_pattern: Tuple[str, ...] = (ATTN,)
    window_size: int = 0             # for LOCAL blocks
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    rotary_pct: float = 1.0
    pos_embedding: str = "rope"      # rope | mrope | sinusoidal | none
    mrope_sections: Tuple[int, int, int] = (0, 0, 0)
    norm_type: str = "rmsnorm"       # rmsnorm | layernorm
    norm_eps: float = 1e-5
    mlp_act: str = "swiglu"          # swiglu | geglu | gelu_mlp
    tie_embeddings: bool = False

    # --- MoE ----------------------------------------------------------------
    num_experts: int = 0
    top_k: int = 0
    moe_every: int = 0               # every n-th layer is MoE (0 => never)
    dense_residual: bool = False     # Arctic: dense MLP in parallel with MoE
    moe_capacity_factor: float = 1.25
    moe_lb_weight: float = 0.01

    # --- SSM (Mamba-2 / SSD) -------------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_groups: int = 1
    ssm_chunk: int = 64
    ssm_conv: int = 4

    # --- frontend ------------------------------------------------------------
    frontend: str = "token"          # token | audio_stub | vlm_stub

    # --- paper technique ------------------------------------------------------
    skip: SkipConfig = field(default_factory=SkipConfig)
    quant: QuantConfig = field(default_factory=QuantConfig)

    # --- numerics / execution -------------------------------------------------
    dtype: str = "bfloat16"
    # decode KV cache layout: "bthd" (default) or "bhtd" (head-major — the
    # attention dot consumes it transpose-free; §Perf hillclimb lever)
    kv_cache_layout: str = "bthd"
    attn_chunk: int = 1024           # KV-block size of the chunked attention scan
    xent_chunk: int = 1024           # sequence-block size of the chunked softmax-xent
    remat: bool = True
    use_kernels: bool = False        # Pallas kernels (TPU); False => pure-jnp path
    # Fused linear pipeline (norm-prologue × matmul × epilogue kernels +
    # the incremental-reduction carry).  Only meaningful with use_kernels;
    # False keeps the per-op kernel dispatch (parity/debug lever).
    fuse_linear: bool = True
    # Chunked (resumable) prefill for the continuous-batching engine:
    # prompts are processed ``prefill_chunk`` tokens at a time, scheduled
    # *between* resident decode steps so a long prompt cannot stall every
    # decode slot (head-of-line blocking).  0 = monolithic prefill — the
    # parity default; token output is identical either way.  Requires an
    # all-global-attention stack with masked-mode routing
    # (``serve.scheduler.can_chunk_prefill``); the engine's
    # ``prefill_chunk=`` argument overrides this per-deployment.
    prefill_chunk: int = 0
    # Device-resident multi-step decode for the continuous-batching engine:
    # N decode iterations (step + sampling + stop/length detection +
    # position advance) fuse into ONE jitted ``lax.scan`` dispatch, so the
    # host syncs once per N tokens instead of once per token and its
    # scheduling work (admission, page headroom, ``plan_step``) overlaps
    # the in-flight device epoch.  1 = the single-step engine (parity
    # default; token output is identical either way at temperature 0).
    # The engine's ``decode_steps=`` argument overrides per-deployment.
    decode_steps_per_dispatch: int = 1
    scan_layers: bool = True

    # ------------------------------------------------------------------ helpers
    def __post_init__(self):
        for b in self.layer_pattern:
            if b not in VALID_BLOCKS:
                raise ValueError(f"unknown block kind {b!r}")
        if self.num_layers % len(self.layer_pattern) != 0:
            raise ValueError(
                f"{self.name}: num_layers={self.num_layers} not divisible by "
                f"pattern length {len(self.layer_pattern)}"
            )
        if self.moe_every and len(self.layer_pattern) % self.moe_every != 0:
            # the scan super-block must contain a whole number of MoE periods
            if self.moe_every % len(self.layer_pattern) != 0 and \
               len(self.layer_pattern) % self.moe_every != 0:
                raise ValueError(f"{self.name}: moe_every incompatible with pattern")

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def attn_inner_dim(self) -> int:
        return self.num_heads * self.resolved_head_dim

    @property
    def kv_inner_dim(self) -> int:
        return self.num_kv_heads * self.resolved_head_dim

    @property
    def stage_len(self) -> int:
        """Layers per scan super-block: lcm(pattern, moe period)."""
        p = len(self.layer_pattern)
        if self.moe_every:
            p = math.lcm(p, self.moe_every)
        return p

    @property
    def num_stages(self) -> int:
        return self.num_layers // self.stage_len

    @property
    def d_inner_ssm(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner_ssm // self.ssm_headdim if self.ssm_state else 0

    def block_kind(self, layer_idx: int) -> str:
        return self.layer_pattern[layer_idx % len(self.layer_pattern)]

    def is_moe_layer(self, layer_idx: int) -> bool:
        if not self.moe_every or self.block_kind(layer_idx) == MAMBA:
            return False
        return (layer_idx % self.moe_every) == (self.moe_every - 1)

    @property
    def attention_layers(self) -> Tuple[int, ...]:
        return tuple(
            i for i in range(self.num_layers) if self.block_kind(i) in (ATTN, LOCAL)
        )

    @property
    def is_subquadratic(self) -> bool:
        """True when the arch can run 500k-token contexts (SSM/hybrid/local)."""
        return ATTN not in self.layer_pattern or (
            MAMBA in self.layer_pattern or LOCAL in self.layer_pattern
        )

    def supported_shapes(self) -> Tuple[str, ...]:
        names = ["train_4k", "prefill_32k", "decode_32k"]
        if self.is_subquadratic:
            names.append("long_500k")
        return tuple(names)

    # --- parameter counting (for roofline MODEL_FLOPS = 6·N·D) ----------------
    def param_count(self, active_only: bool = False) -> int:
        d, h = self.d_model, self.resolved_head_dim
        n = 0
        emb = self.vocab_size * d
        n += emb                                   # input embedding
        if not self.tie_embeddings:
            n += emb                               # lm head
        for i in range(self.num_layers):
            kind = self.block_kind(i)
            if kind in (ATTN, LOCAL):
                q = d * self.attn_inner_dim
                kv = 2 * d * self.kv_inner_dim
                o = self.attn_inner_dim * d
                n += q + kv + o + d                # + input norm
                if self.qk_norm:
                    n += 2 * h
            elif kind == MAMBA:
                di, g, ns = self.d_inner_ssm, self.ssm_groups, self.ssm_state
                nh = self.ssm_nheads
                in_proj = d * (2 * di + 2 * g * ns + nh)
                conv = (di + 2 * g * ns) * self.ssm_conv
                out_proj = di * d
                n += in_proj + conv + out_proj + 2 * nh + di + d  # A,dt_bias,D,norms
            # MLP / MoE
            if kind == MAMBA:
                continue
            glu = self.mlp_act in ("swiglu", "geglu")
            per_ffn = d * self.d_ff * (3 if glu else 2)
            if self.is_moe_layer(i):
                e = self.top_k if active_only else self.num_experts
                n += e * per_ffn + d * self.num_experts + d  # experts + gate + norm
                if self.dense_residual:
                    n += per_ffn
            elif self.d_ff:
                n += per_ffn + d
            if self.skip.enabled:
                n += 2 * d * 2                     # two routers (attn + mlp)
        n += d                                     # final norm
        return n

    # --- smoke config ----------------------------------------------------------
    def smoke(self) -> "ModelConfig":
        """Reduced config of the same family, runnable on CPU."""
        pat = self.layer_pattern
        layers = len(pat) * (2 if len(pat) <= 4 else 1)
        if self.moe_every:
            layers = max(layers, math.lcm(len(pat), self.moe_every))
        nh = min(self.num_heads, 4)
        nkv = min(self.num_kv_heads, nh)
        if nh % nkv:
            nkv = 1
        sections = self.mrope_sections
        if sum(sections):
            sections = (8, 12, 12)  # scaled to head_dim 64 (pairs: 32)
        return replace(
            self,
            name=self.name + "-smoke",
            num_layers=layers,
            d_model=128,
            num_heads=nh,
            num_kv_heads=nkv,
            head_dim=64 if (self.head_dim or sum(sections)) else 0,
            d_ff=256 if self.d_ff else 0,
            vocab_size=512,
            num_experts=min(self.num_experts, 4),
            top_k=min(self.top_k, 2),
            ssm_state=min(self.ssm_state, 16),
            ssm_headdim=32 if self.ssm_state else self.ssm_headdim,
            ssm_chunk=8,
            window_size=16 if self.window_size else 0,
            mrope_sections=sections,
            attn_chunk=32,
            xent_chunk=32,
            remat=False,
            use_kernels=False,
        )


# ---------------------------------------------------------------------------
# Registry
_REGISTRY: Dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    if cfg.name in _REGISTRY:
        raise ValueError(f"duplicate config {cfg.name}")
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str, **overrides: Any) -> ModelConfig:
    import repro.configs  # noqa: F401  (triggers per-arch module imports)

    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    cfg = _REGISTRY[name]
    return replace(cfg, **overrides) if overrides else cfg


def list_configs() -> Tuple[str, ...]:
    import repro.configs  # noqa: F401

    return tuple(sorted(_REGISTRY))
