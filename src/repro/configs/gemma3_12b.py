"""gemma3-12b  [dense]  48L d_model=3840 16H (GQA kv=8) d_ff=15360 vocab=262144
5:1 local:global sliding-window pattern, 128k context, qk-norm, head_dim=256.
[hf:google/gemma-3-1b-pt]"""
from repro.configs.base import ATTN, LOCAL, ModelConfig, register

CONFIG = register(ModelConfig(
    name="gemma3-12b",
    family="dense",
    num_layers=48,
    d_model=3840,
    num_heads=16,
    num_kv_heads=8,
    head_dim=256,
    d_ff=15360,
    vocab_size=262144,
    layer_pattern=(LOCAL, LOCAL, LOCAL, LOCAL, LOCAL, ATTN),
    window_size=1024,
    qk_norm=True,
    rope_theta=1_000_000.0,
    mlp_act="geglu",
    tie_embeddings=True,
))
