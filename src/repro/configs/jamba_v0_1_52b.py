"""jamba-v0.1-52b  [hybrid]  32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536, MoE 16e top-2 every 2 layers, Mamba+attn 1:7 interleave.
[arXiv:2403.19887]

Adaptation note (DESIGN.md §Arch-applicability): Jamba v0.1 uses Mamba-1 blocks;
we implement Mamba-2/SSD for all SSM layers (strict superset dataflow, better
TPU mapping). State size 16 matches the Jamba paper.
"""
from repro.configs.base import ATTN, MAMBA, ModelConfig, register

CONFIG = register(ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=65536,
    layer_pattern=(MAMBA, MAMBA, MAMBA, MAMBA, ATTN, MAMBA, MAMBA, MAMBA),
    num_experts=16,
    top_k=2,
    moe_every=2,
    ssm_state=16,
    ssm_headdim=64,
    ssm_chunk=64,
    pos_embedding="none",   # Jamba uses no explicit positional encoding
    mlp_act="swiglu",
))
