"""llama2-7b — the paper's own evaluation workload (§5.1): SkipGPT-pruned
Llama-2 with ~25% skipping, GPTQ int4 weights, FP16 activations.
[arXiv:2307.09288]"""
from repro.configs.base import ModelConfig, QuantConfig, SkipConfig, register

CONFIG = register(ModelConfig(
    name="llama2-7b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=32,
    d_ff=11008,
    vocab_size=32000,
    rope_theta=10_000.0,
    mlp_act="swiglu",
    skip=SkipConfig(enabled=True, keep_prob=0.75),
    quant=QuantConfig(enabled=True, bits=4, group_size=128, pow2_scales=True),
))
