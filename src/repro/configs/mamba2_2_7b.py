"""mamba2-2.7b  [ssm]  64L d_model=2560 (attention-free) vocab=50280
ssm_state=128 — SSD (state-space duality).  [arXiv:2405.21060]

KV reuse is inapplicable (no KV cache) — see DESIGN.md §Arch-applicability.
Token routing uses masked-contribution semantics on the SSD recurrence.
"""
import dataclasses

from repro.configs.base import MAMBA, ModelConfig, SkipConfig, register

CONFIG = register(ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    num_layers=64,
    d_model=2560,
    num_heads=1,           # unused (attention-free)
    num_kv_heads=1,
    d_ff=0,                # no MLP blocks: pure Mamba stack
    vocab_size=50280,
    layer_pattern=(MAMBA,),
    ssm_state=128,
    ssm_headdim=64,
    ssm_chunk=128,
    pos_embedding="none",
    tie_embeddings=True,
    skip=SkipConfig(kv_reuse=False, route_attention=False),
))
