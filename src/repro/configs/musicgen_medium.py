"""musicgen-medium  [audio]  48L d_model=1536 24H (MHA kv=24) d_ff=6144 vocab=2048
Decoder-only over EnCodec tokens; modality frontend is a STUB (precomputed frame
embeddings are the model input).  [arXiv:2306.05284]"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="musicgen-medium",
    family="audio",
    num_layers=48,
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    norm_type="layernorm",
    pos_embedding="sinusoidal",
    mlp_act="gelu_mlp",
    frontend="audio_stub",
))
