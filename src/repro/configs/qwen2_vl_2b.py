"""qwen2-vl-2b  [vlm]  28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936
M-RoPE (temporal/height/width sections), dynamic resolution.  The vision tower
is a STUB: the model consumes precomputed patch embeddings + 3D positions.
[arXiv:2409.12191]"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab_size=151936,
    pos_embedding="mrope",
    mrope_sections=(16, 24, 24),
    rope_theta=1_000_000.0,
    mlp_act="swiglu",
    frontend="vlm_stub",
    tie_embeddings=True,
))
