from repro.core import kv_reuse, routing, skip_block  # noqa: F401
