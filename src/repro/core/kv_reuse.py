"""Cross-layer KV reuse (paper §2.1 Eq. 2 and §4.4).

A token that skips attention at layer *l* inherits its K/V from the most
recent layer where it executed:  ``K_l[i] = K_{l-1}[i]`` recursively.  The
key hardware observation the paper exploits — *the KV of a skipped token is
invariant across layers until it re-executes* — maps onto TPU as a dense
scan-carried **view**:

    view_l = where(gate_l, kv_new_l, view_{l-1})

which is a fully regular select (the TPU analogue of serving reused entries
from the on-chip URAM buffer instead of issuing irregular cross-layer HBM
gathers).  Storage accounting for the *compact store* (the 25.4 % claim)
lives in ``repro/kvcache/cache.py``.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import routing

KVPair = Tuple[jnp.ndarray, jnp.ndarray]   # (k, v): [B, T, Hkv, dh]


def init_view(k_new: jnp.ndarray, v_new: jnp.ndarray) -> KVPair:
    """Base case of the recursion: at the first attention layer the view is
    the freshly computed KV for *all* tokens (the buffer is initialized
    dense; see DESIGN.md — recursion needs a base)."""
    return k_new, v_new


def merge_view(view: Optional[KVPair], k_new: jnp.ndarray, v_new: jnp.ndarray,
               gate: jnp.ndarray) -> KVPair:
    """Dense select realizing Eq. 2.  gate: [B, T] (1 = executed)."""
    if view is None:
        return init_view(k_new, v_new)
    g = gate.astype(bool)[:, :, None, None]
    k = jnp.where(g, k_new, view[0])
    v = jnp.where(g, v_new, view[1])
    return k, v


def merge_view_gathered(view: Optional[KVPair], k_new: jnp.ndarray,
                        v_new: jnp.ndarray, idx: jnp.ndarray, T: int
                        ) -> KVPair:
    """Gather-mode variant: KV was computed only for the compacted tokens
    (k_new/v_new: [B, C, Hkv, dh]); scatter them into the dense view at the
    original positions ``idx`` [B, C]."""
    if view is None:
        # base case: dense init requires full KV; caller guarantees the first
        # attention layer runs dense (idx == arange(T)).
        assert k_new.shape[1] == T, "first attention layer must be dense"
        return k_new, v_new
    scat = jax.vmap(lambda o, i, u: o.at[i].set(u))
    k = scat(view[0], idx, k_new)
    v = scat(view[1], idx, v_new)
    return k, v


def merge_token_view(kv_prev: Optional[KVPair], k_new: jnp.ndarray,
                     v_new: jnp.ndarray, gate: jnp.ndarray) -> KVPair:
    """Decode-time single-token view: the carried (k, v) of the *new* token
    as it flows through layers (the proactive invariance-buffer update —
    §4.4.2).  k_new/v_new: [B, 1, Hkv, dh]; gate: [B]."""
    if kv_prev is None:
        return k_new, v_new
    g = gate.astype(bool)[:, None, None, None]
    return (jnp.where(g, k_new, kv_prev[0]),
            jnp.where(g, v_new, kv_prev[1]))


def storage_saved_fraction(gates: jnp.ndarray) -> jnp.ndarray:
    """Fraction of per-layer KV slots the compact store avoids writing.

    gates: [L, B, T] execution masks over attention layers (layer 0 counts
    as dense — the view base case).  Saved = 1 − (stored / (L·T))."""
    L = gates.shape[0]
    stored = gates[1:].sum() + gates.shape[1] * gates.shape[2]  # layer0 dense
    total = L * gates.shape[1] * gates.shape[2]
    return 1.0 - stored / total
