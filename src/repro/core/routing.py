"""SkipGPT routing (the paper's §2.1): a per-submodule linear router
``r = W_θᵀ x ∈ ℝ²`` decides, per token, whether the submodule executes.

Training uses straight-through Gumbel-softmax (hard 0/1 forward, soft
gradient) — the paper's Alg. 1 line 8.  Inference uses deterministic argmax.
The *gather* realization (top-capacity compaction) is the TPU-native,
static-shape equivalent of the FPGA's bitmask-driven selective token fetch
(DESIGN.md §2).
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import Params, trunc_normal


def router_init(key, cfg: ModelConfig) -> Params:
    # Bias init toward keeping (logit_keep - logit_skip ≈ +1) so early
    # training is near-dense, mirroring SkipGPT's warm start.
    w = trunc_normal(key, (cfg.d_model, 2), 0.02, jnp.float32)
    return {"w": w, "b": jnp.array([0.0, 1.0], jnp.float32)}


def router_logits(params: Params, x: jnp.ndarray) -> jnp.ndarray:
    """x: [..., D] -> logits [..., 2] in fp32."""
    return x.astype(jnp.float32) @ params["w"] + params["b"]


def gate_from_logits(logits: jnp.ndarray, rng: Optional[jax.Array],
                     cfg: ModelConfig, train: bool
                     ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """-> (gate [...], p_keep [...]).  gate is 0/1 float with a
    straight-through gradient in training."""
    p = jax.nn.softmax(logits, axis=-1)
    p_keep = p[..., 1]
    if train and rng is not None:
        g = -jnp.log(-jnp.log(jax.random.uniform(rng, logits.shape) + 1e-9) + 1e-9)
        y = jax.nn.softmax((logits + g) / cfg.skip.tau, axis=-1)
        hard = (y[..., 1] > y[..., 0]).astype(jnp.float32)
        soft = y[..., 1]
        gate = hard + (soft - jax.lax.stop_gradient(soft))   # ST estimator
    else:
        gate = (logits[..., 1] > logits[..., 0]).astype(jnp.float32)
    return gate, p_keep


def capacity(T: int, keep_prob: float, multiple: int = 8) -> int:
    """Static per-sequence execution capacity for gather mode."""
    c = int(math.ceil(T * keep_prob))
    c = min(T, -(-c // multiple) * multiple)
    return max(c, min(T, multiple))


def select_topc(score: jnp.ndarray, cap: int) -> jnp.ndarray:
    """score: [B, T] -> idx [B, C] of the top-C tokens, sorted ascending so
    the gathered subsequence preserves temporal order (causality/SSD)."""
    _, idx = jax.lax.top_k(score, cap)
    return jnp.sort(idx, axis=-1)


def gather_tokens(x: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """x: [B, T, ...], idx: [B, C] -> [B, C, ...]."""
    return jnp.take_along_axis(
        x, idx.reshape(idx.shape + (1,) * (x.ndim - 2)), axis=1)


def scatter_tokens(y: jnp.ndarray, idx: jnp.ndarray, T: int) -> jnp.ndarray:
    """y: [B, C, ...] -> [B, T, ...] with zeros at unselected positions.

    vmapped per-row scatter: the batch dim lowers as a scatter *batch
    dimension*, which GSPMD partitions along the data axis instead of
    replicating the operands (a 100× collective difference at prefill_32k).
    """
    out = jnp.zeros((y.shape[0], T) + y.shape[2:], y.dtype)
    return jax.vmap(lambda o, i, u: o.at[i].set(u))(out, idx, y)


def scatter_set_tokens(x: jnp.ndarray, idx: jnp.ndarray,
                       u: jnp.ndarray) -> jnp.ndarray:
    """x: [B, T, ...] with rows ``idx`` [B, C] *replaced* by u [B, C, ...]
    (the fused-epilogue gather path: the kernel already produced
    ``y·gate + x_row``, so the scatter overwrites instead of adding)."""
    return jax.vmap(lambda o, i, v: o.at[i].set(v))(x, idx, u)


def neutral_router_bias(params: Params) -> Params:
    """Zero every router's keep-warm-start bias so an *untrained* model
    actually skips tokens (~50 % keep) — the regime the measured KV-storage
    accounting is about.  Tests and benchmarks use this; trained routers
    reach the target keep rate through the aux loss instead."""
    def one(path, leaf):
        names = [getattr(p, "key", "") for p in path]
        if len(names) >= 2 and names[-2] == "router" and names[-1] == "b":
            return jnp.zeros_like(leaf)
        return leaf

    return jax.tree_util.tree_map_with_path(one, params)


# Logit-units-per-unit-keep-drop for the draft lever below.  The router
# decision is ``logits[1] > logits[0]``, so shifting the *skip* bias up by
# a few logits flips most marginal keep decisions without retraining; 4.0
# saturates well past the trained decision margins.
DRAFT_BIAS_SCALE = 4.0


def draft_router_bias(params: Params, draft_keep: float) -> Params:
    """Speculative-draft lever: a *view* of ``params`` whose router skip
    biases are raised by ``DRAFT_BIAS_SCALE * (1 - draft_keep)``, making
    the routed forward skip more aggressively — the self-speculative
    draft model, sharing every weight leaf with the verifier (no copy).

    ``draft_keep = 1.0`` returns ``params`` unchanged (object identity),
    so the draft forward is bit-identical to the verifier — the all-accept
    extreme the differential tests pin down.  Lower values trade draft
    cost for acceptance rate (docs/speculative.md)."""
    shift = DRAFT_BIAS_SCALE * (1.0 - float(draft_keep))
    if shift == 0.0:
        return params

    def one(path, leaf):
        names = [getattr(p, "key", "") for p in path]
        if len(names) >= 2 and names[-2] == "router" and names[-1] == "b":
            return leaf + jnp.asarray([shift, 0.0], leaf.dtype)
        return leaf

    return jax.tree_util.tree_map_with_path(one, params)


def router_stats(p_keep: jnp.ndarray, gate: jnp.ndarray, cfg: ModelConfig
                 ) -> Dict[str, jnp.ndarray]:
    """Per-submodule routing statistics + the sparsity-control aux loss
    (steers the mean keep probability to cfg.skip.keep_prob)."""
    target = cfg.skip.keep_prob
    mean_p = p_keep.mean()
    return {
        "keep_frac": gate.mean(),
        "router_loss": (mean_p - target) ** 2,
    }
