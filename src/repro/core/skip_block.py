"""Skip-aware submodule composition: router → (gather) → norm → submodule →
scatter/mask → residual.

This is the paper's execution pipeline (Fig. 1 / Alg. 1) in JAX form:

  * the router logits and the norm's reduction statistics are computed in a
    single pass over the activations (the "deep-fused router + RMSNorm"
    dataflow — on TPU via the fused Pallas kernel, on the jnp path via two
    fusable reductions XLA merges);
  * only *kept* tokens are normalized and fed to the submodule (gather mode
    compacts them into a static-capacity tile — the bitmask analogue);
  * attention composes with the cross-layer KV view (kv_reuse.py).
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import kv_reuse, routing
from repro.distributed.sharding import hint
from repro.models import attention as attn_mod
from repro.models import layers
from repro.models.layers import Params

Stats = Dict[str, jnp.ndarray]


def _gather_positions(positions: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """positions: [B, T] or [3, B, T] (M-RoPE); idx: [B, C]."""
    if positions.ndim == 3:
        return jax.vmap(lambda p: jnp.take_along_axis(p, idx, axis=1))(positions)
    return jnp.take_along_axis(positions, idx, axis=1)


def _q_index_positions(positions: jnp.ndarray) -> jnp.ndarray:
    """Sequence-index positions used for causal masking ([B, T] even when the
    RoPE positions are 3-D M-RoPE: masking uses the temporal index)."""
    if positions.ndim == 3:
        return positions[0]
    return positions


def _router_and_stats(p: Params, x: jnp.ndarray, cfg: ModelConfig,
                      routed: bool,
                      carried_sq: Optional[jnp.ndarray] = None):
    """One pass producing (router logits, norm reduction stats) — Alg. 1
    lines 4–7.  Dispatches to the fused Pallas kernel when enabled.

    ``carried_sq``: the previous block's fused-epilogue Σy²/D carry (the
    incremental-reduction carry) — when present the norm reduction is
    free and only the (tiny) router matmul touches the activation."""
    if carried_sq is not None and cfg.norm_type == "rmsnorm":
        logits = routing.router_logits(p["router"], x) if routed else None
        return logits, carried_sq
    if cfg.use_kernels and routed and cfg.norm_type == "rmsnorm":
        from repro.kernels import ops as kops
        logits, stats = kops.fused_router_rmsnorm_stats(
            x, p["router"]["w"], p["router"]["b"])
    else:
        stats = layers.norm_stats(x, cfg)
        logits = routing.router_logits(p["router"], x) if routed else None
    return logits, stats


def _gate(logits, rng, cfg: ModelConfig, train: bool, shape, routed: bool):
    if not routed:
        ones = jnp.ones(shape, jnp.float32)
        return ones, ones
    return routing.gate_from_logits(logits, rng, cfg, train)


# ---------------------------------------------------------------------------
# Attention submodule (prefill / train)
# ---------------------------------------------------------------------------

def routed_attention(p: Params, x: jnp.ndarray,
                     view: Optional[kv_reuse.KVPair],
                     positions: jnp.ndarray, cfg: ModelConfig, *,
                     rng: Optional[jax.Array], train: bool,
                     window: int = 0,
                     carried_sq: Optional[jnp.ndarray] = None
                     ) -> Tuple[jnp.ndarray, kv_reuse.KVPair, Stats]:
    """x: [B, T, D].  Returns (x + routed_attn(x), new KV view, stats).

    On the fused pipeline (``layers.fuse_norm_linear``): the norm's
    elementwise phase runs inside the widened wqkv projection's k-loop,
    the o-projection fuses the gate/residual write, and the emitted Σy²/D
    rides out in ``stats['res_sq']`` — the next block consumes it via
    ``carried_sq`` so its own reduction pass disappears."""
    B, T, D = x.shape
    routed = cfg.skip.enabled and cfg.skip.route_attention
    logits, nstats = _router_and_stats(p, x, cfg, routed, carried_sq)
    gate, p_keep = _gate(logits, rng, cfg, train, (B, T), routed)
    gate = hint(gate, "gate")
    q_pos_idx = _q_index_positions(positions)
    inner = p["inner"]
    fuse = layers.fuse_norm_linear(cfg)
    out_sq = None

    use_gather = routed and cfg.skip.mode == "gather" and not train
    if use_gather:
        cap = routing.capacity(T, cfg.skip.keep_prob)
        score = logits[..., 1] - logits[..., 0]
        idx = routing.select_topc(score, cap)
        xg = hint(routing.gather_tokens(x, idx), "activation")
        sg = jax.tree_util.tree_map(
            lambda s: jnp.take_along_axis(s, idx, axis=1), nstats)
        pos_g = _gather_positions(positions, idx)
        if fuse:
            if view is None or not cfg.skip.kv_reuse:
                # dense KV base case / "PartialSkip" ablation: q from the
                # gathered tile, KV from all tokens — both norm-fused.
                q = attn_mod.project_q(inner, xg, pos_g, cfg,
                                       norm=p["norm"], stats=sg)
                k, v = attn_mod.project_kv(inner, x, positions, cfg,
                                           norm=p["norm"], stats=nstats)
                view = kv_reuse.init_view(k, v)
            else:
                q, kg, vg = attn_mod.project_qkv(inner, xg, pos_g, cfg,
                                                 norm=p["norm"], stats=sg)
                view = kv_reuse.merge_view_gathered(view, kg, vg, idx, T)
        else:
            xng = hint(layers.norm_apply(p["norm"], xg, cfg, stats=sg),
                       "activation")
            q = attn_mod.project_q(inner, xng, pos_g, cfg)
            if view is None or not cfg.skip.kv_reuse:
                # dense KV generation: view base case, or the paper's
                # "PartialSkip" ablation (KV recomputed for skipped tokens)
                xn = layers.norm_apply(p["norm"], x, cfg, stats=nstats)
                k, v = attn_mod.project_kv(inner, xn, positions, cfg)
                view = kv_reuse.init_view(k, v)
            else:
                kg, vg = attn_mod.project_kv(inner, xng, pos_g, cfg)
                view = kv_reuse.merge_view_gathered(view, kg, vg, idx, T)
        view = (hint(view[0], "kv_view"), hint(view[1], "kv_view"))
        o = attn_mod.attention_core(q, view[0], view[1],
                                    q_positions=jnp.take_along_axis(
                                        q_pos_idx, idx, axis=1),
                                    cfg=cfg, window=window)
        gate_g = jnp.take_along_axis(gate, idx, axis=1)
        if fuse:
            # gate/residual epilogue fused into the o-projection; the
            # unselected rows keep their carried reduction unchanged.
            yg, sq_g = attn_mod.output_proj_fused(
                inner, o, cfg, residual=xg, gate_mul=gate_g, emit_sq=True)
            x = hint(routing.scatter_set_tokens(x, idx, yg), "activation")
            out_sq = routing.scatter_set_tokens(nstats, idx, sq_g / D)
        else:
            y = attn_mod.output_proj(inner, o, cfg)
            y = hint(y * gate_g.astype(y.dtype)[..., None], "activation")
            x = x + hint(routing.scatter_tokens(y, idx, T), "activation")
    else:
        if fuse:
            q, k, v = attn_mod.project_qkv(inner, x, positions, cfg,
                                           norm=p["norm"], stats=nstats)
        else:
            xn = layers.norm_apply(p["norm"], x, cfg, stats=nstats)
            q = attn_mod.project_q(inner, xn, positions, cfg)
            k, v = attn_mod.project_kv(inner, xn, positions, cfg)
        if routed and cfg.skip.kv_reuse:
            view = kv_reuse.merge_view(view, k, v, gate)
        else:
            view = kv_reuse.init_view(k, v)
        view = (hint(view[0], "kv_view"), hint(view[1], "kv_view"))
        o = attn_mod.attention_core(q, view[0], view[1],
                                    q_positions=q_pos_idx, cfg=cfg,
                                    window=window)
        if fuse:
            x, sq = attn_mod.output_proj_fused(
                inner, o, cfg, residual=x,
                gate_mul=gate if routed else None, emit_sq=True)
            x = hint(x, "activation")
            out_sq = sq / D
        else:
            y = attn_mod.output_proj(inner, o, cfg)
            if routed:
                y = y * gate.astype(y.dtype)[..., None]
            x = x + hint(y, "activation")

    stats = routing.router_stats(p_keep, gate, cfg) if routed else {
        "keep_frac": jnp.float32(1.0), "router_loss": jnp.float32(0.0)}
    stats["attn_gate"] = gate
    if out_sq is not None:
        stats["res_sq"] = hint(out_sq, "res_sq")
    return x, view, stats


# ---------------------------------------------------------------------------
# MLP / MoE submodule (prefill / train)
# ---------------------------------------------------------------------------

def routed_mlp(p: Params, x: jnp.ndarray, cfg: ModelConfig, *,
               inner_fn: Callable[[Params, jnp.ndarray], Tuple[jnp.ndarray, Stats]],
               rng: Optional[jax.Array], train: bool,
               carried_sq: Optional[jnp.ndarray] = None
               ) -> Tuple[jnp.ndarray, Stats]:
    """inner_fn(params, xn) -> (y, aux); covers dense MLP and MoE.

    Dense MLPs on the fused pipeline skip inner_fn entirely: the
    norm-prologue × [gate|up] × GLU and down × gate/residual/Σy² kernels
    run instead (MoE keeps its scatter dispatch)."""
    B, T, D = x.shape
    routed = cfg.skip.enabled and cfg.skip.route_mlp
    logits, nstats = _router_and_stats(p, x, cfg, routed, carried_sq)
    gate, p_keep = _gate(logits, rng, cfg, train, (B, T), routed)
    fuse = layers.fuse_norm_linear(cfg) and layers.mlp_fusable(p["inner"])
    out_sq = None
    aux: Stats = {}

    use_gather = routed and cfg.skip.mode == "gather" and not train
    if use_gather:
        cap = routing.capacity(T, cfg.skip.keep_prob)
        score = logits[..., 1] - logits[..., 0]
        idx = routing.select_topc(score, cap)
        xg = hint(routing.gather_tokens(x, idx), "activation")
        sg = jax.tree_util.tree_map(
            lambda s: jnp.take_along_axis(s, idx, axis=1), nstats)
        gate_g = jnp.take_along_axis(gate, idx, axis=1)
        if fuse:
            yg, sq_g = layers.mlp_apply_fused(
                p["inner"], xg, cfg, norm=p["norm"], stats=sg,
                residual=xg, gate_mul=gate_g, emit_sq=True)
            x = hint(routing.scatter_set_tokens(x, idx, yg), "activation")
            out_sq = routing.scatter_set_tokens(nstats, idx, sq_g / D)
        else:
            xng = hint(layers.norm_apply(p["norm"], xg, cfg, stats=sg),
                       "activation")
            y, aux = inner_fn(p["inner"], xng)
            y = hint(y * gate_g.astype(y.dtype)[..., None], "activation")
            x = x + hint(routing.scatter_tokens(y, idx, T), "activation")
    else:
        if fuse:
            x, sq = layers.mlp_apply_fused(
                p["inner"], x, cfg, norm=p["norm"], stats=nstats,
                residual=x, gate_mul=gate if routed else None, emit_sq=True)
            x = hint(x, "activation")
            out_sq = sq / D
        else:
            xn = layers.norm_apply(p["norm"], x, cfg, stats=nstats)
            y, aux = inner_fn(p["inner"], xn)
            if routed:
                y = y * gate.astype(y.dtype)[..., None]
            x = x + hint(y, "activation")

    stats = routing.router_stats(p_keep, gate, cfg) if routed else {
        "keep_frac": jnp.float32(1.0), "router_loss": jnp.float32(0.0)}
    stats.update(aux)
    if out_sq is not None:
        stats["res_sq"] = hint(out_sq, "res_sq")
    return x, stats


# ---------------------------------------------------------------------------
# Decode-step variants (single new token, per-layer KV cache)
# ---------------------------------------------------------------------------

def _decode_output_epilogue(inner: Params, o: jnp.ndarray, x: jnp.ndarray,
                            gate: jnp.ndarray, routed: bool, fuse: bool,
                            cfg: ModelConfig, stats: Stats) -> jnp.ndarray:
    """Shared decode o-projection epilogue (dense / ring / paged paths):
    fused — (o·Wo)·gate + x in one kernel, Σy²/D carry into
    ``stats['res_sq']``; composed — the plain op sequence.  x: [B, 1, D];
    gate: [B]."""
    if fuse:
        x, sq = attn_mod.output_proj_fused(
            inner, o, cfg, residual=x,
            gate_mul=gate[:, None] if routed else None, emit_sq=True)
        stats["res_sq"] = hint(sq / x.shape[-1], "res_sq")
        return x
    y = attn_mod.output_proj(inner, o, cfg)
    if routed:
        y = y * gate.astype(y.dtype)[:, None, None]
    return x + y

def _row_update(cache: jnp.ndarray, new: jnp.ndarray, t: jnp.ndarray,
                time_axis: int) -> jnp.ndarray:
    """Write one new KV entry per batch row at its own position.
    cache: [B, ...] with the time dim at ``time_axis`` (batch excluded);
    new: cache row-shaped update of time-extent 1; t: [B] int32."""
    def one(c, u, ti):
        start = [jnp.int32(0)] * (c.ndim)
        start[time_axis] = ti
        return jax.lax.dynamic_update_slice(c, u, tuple(start))
    return jax.vmap(one)(cache, new, t)


def routed_attention_decode(p: Params, x: jnp.ndarray,
                            k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                            t: jnp.ndarray,
                            kv_prev: Optional[kv_reuse.KVPair],
                            positions: jnp.ndarray, cfg: ModelConfig, *,
                            window: int = 0,
                            carried_sq: Optional[jnp.ndarray] = None
                            ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray,
                                       kv_reuse.KVPair, Stats]:
    """One decode step.  x: [B, 1, D]; k/v_cache: [B, Tmax, Hkv, dh];
    t: [B] int32 per-sequence positions (a scalar broadcasts — lock-step);
    kv_prev: the carried single-token KV view (the proactive
    invariance-buffer update, §4.4.2).  On the fused pipeline the qkv
    projection carries the norm prologue and the o-projection emits the
    next block's reduction (``stats['res_sq']``)."""
    B = x.shape[0]
    t = jnp.broadcast_to(jnp.atleast_1d(jnp.asarray(t, jnp.int32)), (B,))
    routed = cfg.skip.enabled and cfg.skip.route_attention
    logits, nstats = _router_and_stats(p, x, cfg, routed, carried_sq)
    gate, p_keep = _gate(logits[:, 0] if logits is not None else None,
                         None, cfg, False, (B,), routed)
    inner = p["inner"]
    fuse = layers.fuse_norm_linear(cfg)

    if fuse:
        q, k_new, v_new = attn_mod.project_qkv(
            inner, x, positions, cfg, norm=p["norm"], stats=nstats)
    else:
        xn = layers.norm_apply(p["norm"], x, cfg, stats=nstats)
        q = attn_mod.project_q(inner, xn, positions, cfg)
        k_new, v_new = attn_mod.project_kv(inner, xn, positions, cfg)
    if routed and cfg.skip.kv_reuse:
        k_t, v_t = kv_reuse.merge_token_view(kv_prev, k_new, v_new, gate)
    else:
        k_t, v_t = k_new, v_new

    valid = t + 1                                        # [B]
    if cfg.kv_cache_layout == "bhtd":
        # head-major cache: write [Hkv, 1, dh] per row at its own t; the
        # attention dot consumes the cache with no relayout transpose.
        k_cache = _row_update(
            k_cache, k_t.swapaxes(1, 2).astype(k_cache.dtype), t, time_axis=1)
        v_cache = _row_update(
            v_cache, v_t.swapaxes(1, 2).astype(v_cache.dtype), t, time_axis=1)
        k_cache = hint(k_cache, "kv_cache_step_bhtd")
        v_cache = hint(v_cache, "kv_cache_step_bhtd")
        o = attn_mod.decode_attention_bhtd(
            q, k_cache, v_cache,
            q_positions=_q_index_positions(positions), cfg=cfg,
            kv_valid_len=valid)
    else:
        k_cache = _row_update(k_cache, k_t.astype(k_cache.dtype), t,
                              time_axis=0)
        v_cache = _row_update(v_cache, v_t.astype(v_cache.dtype), t,
                              time_axis=0)
        k_cache = hint(k_cache, "kv_cache_step")
        v_cache = hint(v_cache, "kv_cache_step")
        o = attn_mod.attention_core(
            q, k_cache, v_cache,
            q_positions=_q_index_positions(positions),
            cfg=cfg, window=window, kv_valid_len=valid)
    stats = routing.router_stats(p_keep, gate, cfg) if routed else {
        "keep_frac": jnp.float32(1.0), "router_loss": jnp.float32(0.0)}
    x = _decode_output_epilogue(inner, o, x, gate, routed, fuse, cfg, stats)
    stats["attn_gate"] = gate
    return x, k_cache, v_cache, (k_t, v_t), stats


def routed_attention_chunk(p: Params, x: jnp.ndarray,
                           k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                           t0: jnp.ndarray,
                           kv_prev: Optional[kv_reuse.KVPair],
                           positions: jnp.ndarray, cfg: ModelConfig, *,
                           carried_sq: Optional[jnp.ndarray] = None
                           ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray,
                                      kv_reuse.KVPair, Stats]:
    """One *chunk* of resumable prefill: the C-token generalization of
    ``routed_attention_decode`` (and the T-token restriction of masked-mode
    ``routed_attention`` to a suffix of the sequence).

    x: [B, C, D] — the chunk's activations; k/v_cache: [B, Tcap, Hkv, dh]
    dense per-layer views in *prefill layout* (time-major), already holding
    this layer's view of positions [0, t0); t0: [B] chunk start offsets;
    kv_prev: the previous layer's merged view of the *chunk* tokens (the
    cross-layer reuse recursion restricted to the chunk — the prefix part
    of the recursion is exactly what the cache rows store).

    The chunk's merged view is appended at [t0, t0+C) and attention runs
    over cached-prefix + chunk under ``kv_valid_len = t0 + C`` (causal
    masking makes any right-padding of the final chunk inert).  Token
    outputs are bit-compatible with monolithic prefill: the per-token
    router gates, view merges and Σy² carries only ever read that token's
    own column, and attention reads the same per-layer view values."""
    B, C, _ = x.shape
    t0 = jnp.broadcast_to(jnp.atleast_1d(jnp.asarray(t0, jnp.int32)), (B,))
    routed = cfg.skip.enabled and cfg.skip.route_attention
    logits, nstats = _router_and_stats(p, x, cfg, routed, carried_sq)
    gate, p_keep = _gate(logits, None, cfg, False, (B, C), routed)
    gate = hint(gate, "gate")
    inner = p["inner"]
    fuse = layers.fuse_norm_linear(cfg)

    if fuse:
        q, k_new, v_new = attn_mod.project_qkv(
            inner, x, positions, cfg, norm=p["norm"], stats=nstats)
    else:
        xn = layers.norm_apply(p["norm"], x, cfg, stats=nstats)
        q = attn_mod.project_q(inner, xn, positions, cfg)
        k_new, v_new = attn_mod.project_kv(inner, xn, positions, cfg)
    if routed and cfg.skip.kv_reuse:
        k_t, v_t = kv_reuse.merge_view(kv_prev, k_new, v_new, gate)
    else:
        k_t, v_t = kv_reuse.init_view(k_new, v_new)

    k_cache = _row_update(k_cache, k_t.astype(k_cache.dtype), t0, time_axis=0)
    v_cache = _row_update(v_cache, v_t.astype(v_cache.dtype), t0, time_axis=0)
    k_cache = hint(k_cache, "kv_cache_step")
    v_cache = hint(v_cache, "kv_cache_step")
    o = attn_mod.attention_core(
        q, k_cache, v_cache, q_positions=_q_index_positions(positions),
        cfg=cfg, window=0, kv_valid_len=t0 + C)

    stats = routing.router_stats(p_keep, gate, cfg) if routed else {
        "keep_frac": jnp.float32(1.0), "router_loss": jnp.float32(0.0)}
    if fuse:
        x, sq = attn_mod.output_proj_fused(
            inner, o, cfg, residual=x,
            gate_mul=gate if routed else None, emit_sq=True)
        x = hint(x, "activation")
        stats["res_sq"] = hint(sq / x.shape[-1], "res_sq")
    else:
        y = attn_mod.output_proj(inner, o, cfg)
        if routed:
            y = y * gate.astype(y.dtype)[..., None]
        x = x + hint(y, "activation")
    stats["attn_gate"] = gate
    return x, k_cache, v_cache, (k_t, v_t), stats


def routed_attention_decode_paged(p: Params, x: jnp.ndarray,
                                  t: jnp.ndarray,
                                  kv_prev: Optional[kv_reuse.KVPair],
                                  positions: jnp.ndarray, cfg: ModelConfig,
                                  *, paged: Dict, layer,
                                  carried_sq: Optional[jnp.ndarray] = None
                                  ) -> Tuple[jnp.ndarray, kv_reuse.KVPair,
                                             Stats]:
    """One decode step against the paged entry stream (paper §4.4).

    Instead of a per-layer dense cache, past tokens' KV lives in the shared
    store-once entry stream; ``paged`` carries the step's gathered view
    (metadata always, K/V on the jnp path) and this layer selects its valid
    entries by *effective position* — the history-buffer indirection
    (repro/kvcache/history.py).  The current token's view ``(k_t, v_t)``
    rides along explicitly (it is committed to the stream only at the end
    of the step) and is returned for the caller's commit buffer.

    ``layer``: this layer's index over the attention stack (traced OK)."""
    from repro.kvcache import history

    B = x.shape[0]
    t = jnp.broadcast_to(jnp.atleast_1d(jnp.asarray(t, jnp.int32)), (B,))
    routed = cfg.skip.enabled and cfg.skip.route_attention
    logits, nstats = _router_and_stats(p, x, cfg, routed, carried_sq)
    gate, p_keep = _gate(logits[:, 0] if logits is not None else None,
                         None, cfg, False, (B,), routed)
    inner = p["inner"]
    fuse = layers.fuse_norm_linear(cfg)

    if fuse:
        q, k_new, v_new = attn_mod.project_qkv(
            inner, x, positions, cfg, norm=p["norm"], stats=nstats)
    else:
        xn = layers.norm_apply(p["norm"], x, cfg, stats=nstats)
        q = attn_mod.project_q(inner, xn, positions, cfg)
        k_new, v_new = attn_mod.project_kv(inner, xn, positions, cfg)
    if routed and cfg.skip.kv_reuse:
        k_t, v_t = kv_reuse.merge_token_view(kv_prev, k_new, v_new, gate)
    else:
        k_t, v_t = k_new, v_new

    eff_pos = history.effective_positions(
        paged["pos"], paged["l0"], paged["l1"], paged["in_fill"], layer)
    q_pos = _q_index_positions(positions)
    if cfg.use_kernels:
        from repro.kernels import ops as kops
        # quantized stores carry scale pages; the payload's head dim says
        # int8 (full) vs nibble-packed int4 (halved)
        kv_dtype = None
        if "k_scales" in paged:
            kv_dtype = ("int8" if paged["k_pages"].shape[-1] == q.shape[-1]
                        else "int4")
        o = kops.paged_decode_attention(
            q, paged["k_pages"], paged["v_pages"], paged["block_table"],
            eff_pos, k_t, v_t, q_positions=q_pos,
            k_scales=paged.get("k_scales"), v_scales=paged.get("v_scales"),
            kv_dtype=kv_dtype)
    else:
        k_cat = jnp.concatenate(
            [paged["k"], k_t.astype(paged["k"].dtype)], axis=1)
        v_cat = jnp.concatenate(
            [paged["v"], v_t.astype(paged["v"].dtype)], axis=1)
        pos_cat = jnp.concatenate([eff_pos, t[:, None]], axis=1)
        o = attn_mod.chunked_attention(
            q, k_cat, v_cat, q_positions=q_pos, causal=True, window=0,
            chunk=k_cat.shape[1], kv_positions=pos_cat)
    stats = routing.router_stats(p_keep, gate, cfg) if routed else {
        "keep_frac": jnp.float32(1.0), "router_loss": jnp.float32(0.0)}
    x = _decode_output_epilogue(inner, o, x, gate, routed, fuse, cfg, stats)
    stats["attn_gate"] = gate
    return x, (k_t, v_t), stats


def routed_attention_chunk_paged(p: Params, x: jnp.ndarray,
                                 kv_prev: Optional[kv_reuse.KVPair],
                                 positions: jnp.ndarray, cfg: ModelConfig,
                                 *, paged: Dict, layer,
                                 carried_sq: Optional[jnp.ndarray] = None
                                 ) -> Tuple[jnp.ndarray, kv_reuse.KVPair,
                                            Stats]:
    """Speculative verify window against the paged entry stream: the
    C-token generalization of ``routed_attention_decode_paged`` (and the
    paged twin of ``routed_attention_chunk``).

    x: [B, C, D] — the window's activations [f0, d_1..d_k]; past tokens'
    KV resolves through the *committed* entry prefix in ``paged`` by
    effective position, while the window's own merged view ``(k_t, v_t)``
    rides along explicitly, concatenated after the stream — the store is
    never written here; the caller commits accepted columns afterwards
    (``model.commit_verified``).  Within-window causality comes from the
    shared position-comparison mask: window column j's position t0+j
    admits stream entries (pos < t0) and columns ≤ j only.  Always the
    jnp concat path — the Pallas paged kernel is single-query, and a
    k+1-wide window doesn't need it."""
    B, C, _ = x.shape
    routed = cfg.skip.enabled and cfg.skip.route_attention
    logits, nstats = _router_and_stats(p, x, cfg, routed, carried_sq)
    gate, p_keep = _gate(logits, None, cfg, False, (B, C), routed)
    gate = hint(gate, "gate")
    inner = p["inner"]
    fuse = layers.fuse_norm_linear(cfg)

    if fuse:
        q, k_new, v_new = attn_mod.project_qkv(
            inner, x, positions, cfg, norm=p["norm"], stats=nstats)
    else:
        xn = layers.norm_apply(p["norm"], x, cfg, stats=nstats)
        q = attn_mod.project_q(inner, xn, positions, cfg)
        k_new, v_new = attn_mod.project_kv(inner, xn, positions, cfg)
    if routed and cfg.skip.kv_reuse:
        k_t, v_t = kv_reuse.merge_view(kv_prev, k_new, v_new, gate)
    else:
        k_t, v_t = kv_reuse.init_view(k_new, v_new)

    from repro.kvcache import history
    eff_pos = history.effective_positions(
        paged["pos"], paged["l0"], paged["l1"], paged["in_fill"], layer)
    q_pos = _q_index_positions(positions)                        # [B, C]
    k_cat = jnp.concatenate(
        [paged["k"], k_t.astype(paged["k"].dtype)], axis=1)
    v_cat = jnp.concatenate(
        [paged["v"], v_t.astype(paged["v"].dtype)], axis=1)
    pos_cat = jnp.concatenate([eff_pos, q_pos], axis=1)
    o = attn_mod.chunked_attention(
        q, k_cat, v_cat, q_positions=q_pos, causal=True, window=0,
        chunk=k_cat.shape[1], kv_positions=pos_cat)

    stats = routing.router_stats(p_keep, gate, cfg) if routed else {
        "keep_frac": jnp.float32(1.0), "router_loss": jnp.float32(0.0)}
    if fuse:
        x, sq = attn_mod.output_proj_fused(
            inner, o, cfg, residual=x,
            gate_mul=gate if routed else None, emit_sq=True)
        x = hint(x, "activation")
        stats["res_sq"] = hint(sq / x.shape[-1], "res_sq")
    else:
        y = attn_mod.output_proj(inner, o, cfg)
        if routed:
            y = y * gate.astype(y.dtype)[..., None]
        x = x + hint(y, "activation")
    stats["attn_gate"] = gate
    return x, (k_t, v_t), stats


def routed_ssm(p: Params, x: jnp.ndarray, cfg: ModelConfig, *,
               rng: Optional[jax.Array], train: bool,
               conv_state=None, ssm_state=None,
               carried_sq: Optional[jnp.ndarray] = None
               ) -> Tuple[jnp.ndarray, Tuple, Stats]:
    """Mamba block with masked-contribution routing (DESIGN.md
    §Arch-applicability): a skipped token's dt is zeroed inside the SSD scan
    so it neither updates the state nor produces output.  Consumes (but
    does not produce) the incremental-reduction carry."""
    from repro.models import ssm as ssm_mod

    B, T, _ = x.shape
    routed = cfg.skip.enabled and cfg.skip.route_ssm
    logits, nstats = _router_and_stats(p, x, cfg, routed, carried_sq)
    gate, p_keep = _gate(logits, rng, cfg, train, (B, T), routed)
    xn = layers.norm_apply(p["norm"], x, cfg, stats=nstats)
    y, states = ssm_mod.ssm_apply(p["inner"], xn, cfg,
                                  gate_mask=gate if routed else None,
                                  conv_state=conv_state, ssm_state=ssm_state)
    x = x + hint(y, "activation")
    stats = routing.router_stats(p_keep, gate, cfg) if routed else {
        "keep_frac": jnp.float32(1.0), "router_loss": jnp.float32(0.0)}
    return x, states, stats


def routed_ssm_decode(p: Params, x: jnp.ndarray, cfg: ModelConfig, *,
                      conv_state, ssm_state,
                      carried_sq: Optional[jnp.ndarray] = None
                      ) -> Tuple[jnp.ndarray, Tuple, Stats]:
    from repro.models import ssm as ssm_mod

    B = x.shape[0]
    routed = cfg.skip.enabled and cfg.skip.route_ssm
    logits, nstats = _router_and_stats(p, x, cfg, routed, carried_sq)
    gate, p_keep = _gate(logits[:, 0] if logits is not None else None,
                         None, cfg, False, (B,), routed)
    xn = layers.norm_apply(p["norm"], x, cfg, stats=nstats)
    y, states = ssm_mod.ssm_step(p["inner"], xn, cfg, conv_state, ssm_state,
                                 gate_mask=gate if routed else None)
    stats = routing.router_stats(p_keep, gate, cfg) if routed else {
        "keep_frac": jnp.float32(1.0), "router_loss": jnp.float32(0.0)}
    return x + y, states, stats


def routed_mlp_decode(p: Params, x: jnp.ndarray, cfg: ModelConfig, *,
                      inner_fn,
                      carried_sq: Optional[jnp.ndarray] = None
                      ) -> Tuple[jnp.ndarray, Stats]:
    """Decode-time MLP routing is the masked path with T=1."""
    B, _, D = x.shape
    routed = cfg.skip.enabled and cfg.skip.route_mlp
    logits, nstats = _router_and_stats(p, x, cfg, routed, carried_sq)
    gate, p_keep = _gate(logits[:, 0] if logits is not None else None,
                         None, cfg, False, (B,), routed)
    stats = routing.router_stats(p_keep, gate, cfg) if routed else {
        "keep_frac": jnp.float32(1.0), "router_loss": jnp.float32(0.0)}
    if layers.fuse_norm_linear(cfg) and layers.mlp_fusable(p["inner"]):
        x, sq = layers.mlp_apply_fused(
            p["inner"], x, cfg, norm=p["norm"], stats=nstats, residual=x,
            gate_mul=gate[:, None] if routed else None, emit_sq=True)
        stats["res_sq"] = hint(sq / D, "res_sq")
        return x, stats
    xn = layers.norm_apply(p["norm"], x, cfg, stats=nstats)
    y, aux = inner_fn(p["inner"], xn)
    if routed:
        y = y * gate.astype(y.dtype)[:, None, None]
    stats.update(aux)
    return x + y, stats
