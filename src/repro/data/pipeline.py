"""Deterministic synthetic LM data pipeline.

Production shape: the dataset is addressed by a monotone *global step
cursor* — every host computes its shard of every batch purely from
(step, host_id), so (a) restarts resume exactly (the cursor lives in the
checkpoint), (b) elastic re-configuration just re-partitions the host range,
(c) no inter-host coordination is needed.

The token stream is a mixture of Zipf-distributed unigrams and short
Markov-ish repeats so losses decrease meaningfully during the example runs
(pure-uniform tokens give a constant-entropy floor).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


@dataclasses.dataclass
class SyntheticLMDataset:
    cfg: ModelConfig
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2
    repeat_prob: float = 0.5
    repeat_offset: int = 16

    def _tokens_for(self, step: int) -> np.ndarray:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step]))
        B, T = self.global_batch, self.seq_len + 1
        V = self.cfg.vocab_size
        base = rng.zipf(self.zipf_a, size=(B, T)).astype(np.int64)
        toks = (base - 1) % V
        # inject predictable structure: with prob p, token t repeats t-k
        rep = rng.random((B, T)) < self.repeat_prob
        rep[:, : self.repeat_offset] = False
        idx = np.arange(T)[None, :] - self.repeat_offset
        toks = np.where(rep, np.take_along_axis(
            toks, np.broadcast_to(idx, (B, T)), axis=1), toks)
        return toks.astype(np.int32)

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        toks = self._tokens_for(step)
        inputs, labels = toks[:, :-1], toks[:, 1:]
        if self.cfg.frontend == "token":
            out: Dict[str, np.ndarray] = {"tokens": inputs}
        else:
            # modality stub: embed the synthetic ids through a fixed random
            # projection (stands in for the frozen EnCodec/ViT frontend)
            rng = np.random.default_rng(self.seed + 7)
            table = rng.standard_normal(
                (min(self.cfg.vocab_size, 4096), self.cfg.d_model)).astype(
                    np.float32) * 0.02
            out = {"embeds": table[inputs % table.shape[0]]}
        if self.cfg.pos_embedding == "mrope":
            pos = np.broadcast_to(
                np.arange(inputs.shape[1], dtype=np.int32)[None],
                inputs.shape)
            out["positions"] = np.broadcast_to(pos[None], (3,) + inputs.shape).copy()
        out["labels"] = labels
        return out

    def host_batch(self, step: int, host_id: int, num_hosts: int
                   ) -> Dict[str, np.ndarray]:
        """This host's shard of the global batch (per-host loading)."""
        full = self.batch(step)
        B = self.global_batch
        assert B % num_hosts == 0
        lo, hi = host_id * B // num_hosts, (host_id + 1) * B // num_hosts

        def shard(k, v):
            return v[:, lo:hi] if k == "positions" else v[lo:hi]

        return {k: shard(k, v) for k, v in full.items()}


def make_batch_iterator(cfg: ModelConfig, seq_len: int, global_batch: int,
                        start_step: int = 0, seed: int = 0
                        ) -> Iterator[Dict[str, np.ndarray]]:
    ds = SyntheticLMDataset(cfg, seq_len, global_batch, seed)
    step = start_step
    while True:
        yield ds.batch(step)
        step += 1
