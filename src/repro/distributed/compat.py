"""Version-compat shims for the distributed APIs that moved across JAX
releases (the distributed tests run against whatever jax the host has):

* ``shard_map``: ``jax.experimental.shard_map.shard_map(..., check_rep=)``
  in 0.4.x, promoted to ``jax.shard_map(..., check_vma=)`` later;
* ``AbstractMesh``: ``AbstractMesh(((name, size), ...))`` in 0.4.x,
  ``AbstractMesh(axis_sizes, axis_names)`` later.
"""
from __future__ import annotations

from typing import Any, Sequence, Tuple

import jax


def shard_map(f, mesh, in_specs, out_specs, check: bool = False):
    """Map ``f`` over ``mesh`` shards; ``check`` toggles the replication /
    varying-manual-axes checker (named check_rep, then check_vma)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check)


def abstract_mesh(axes: Sequence[Tuple[str, int]]) -> Any:
    """AbstractMesh from ((axis_name, size), ...) pairs."""
    from jax.sharding import AbstractMesh
    try:
        return AbstractMesh(tuple(axes))
    except TypeError:
        names = tuple(n for n, _ in axes)
        sizes = tuple(s for _, s in axes)
        return AbstractMesh(sizes, names)
