"""Version-compat shims for the distributed APIs that moved across JAX
releases (the distributed tests run against whatever jax the host has,
and the CI tier-1 matrix pins the oldest supported release):

* ``shard_map``: ``jax.experimental.shard_map.shard_map(..., check_rep=)``
  in 0.4.x, promoted to ``jax.shard_map(..., check_vma=)`` later;
* ``AbstractMesh``: absent before 0.4.3x (``has_abstract_mesh``), then
  ``AbstractMesh(((name, size), ...))``, then
  ``AbstractMesh(axis_sizes, axis_names)``;
* ``make_mesh``: ``jax.make_mesh`` only exists from 0.4.35 — older
  releases build a ``Mesh`` over ``mesh_utils.create_device_mesh``.
"""
from __future__ import annotations

from typing import Any, Sequence, Tuple

import jax


def shard_map(f, mesh, in_specs, out_specs, check: bool = False):
    """Map ``f`` over ``mesh`` shards; ``check`` toggles the replication /
    varying-manual-axes checker (named check_rep, then check_vma)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check)


def make_mesh(shape: Sequence[int], axis_names: Sequence[str]):
    """``jax.make_mesh`` (0.4.35+) or the Mesh-over-device-grid spelling
    older releases require."""
    if hasattr(jax, "make_mesh"):
        return jax.make_mesh(tuple(shape), tuple(axis_names))
    from jax.experimental import mesh_utils
    from jax.sharding import Mesh
    return Mesh(mesh_utils.create_device_mesh(tuple(shape)),
                tuple(axis_names))


def has_abstract_mesh() -> bool:
    """True when this jax ships ``jax.sharding.AbstractMesh`` (the
    device-free mesh the spec-construction tests build production
    topologies from; tests skip it on older pins)."""
    try:
        from jax.sharding import AbstractMesh  # noqa: F401
        return True
    except ImportError:
        return False


def abstract_mesh(axes: Sequence[Tuple[str, int]]) -> Any:
    """AbstractMesh from ((axis_name, size), ...) pairs."""
    from jax.sharding import AbstractMesh
    try:
        return AbstractMesh(tuple(axes))
    except TypeError:
        names = tuple(n for n, _ in axes)
        sizes = tuple(s for _, s in axes)
        return AbstractMesh(sizes, names)
