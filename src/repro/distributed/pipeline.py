"""GPipe-style pipeline parallelism over a mesh axis (the ``pod`` axis of
the multi-pod mesh) via shard_map + collective_permute.

At 1000+-node scale, FSDP all-gathers across pods ride the slow inter-pod
links; placing pipeline *stages* on pods instead bounds every FSDP/TP
collective to a single pod and moves only microbatch activations across
pods (P2P ppermute) — the standard large-cluster composition
(PP-over-pods × FSDP×TP-within-pod).

The schedule below is the classic GPipe fill-drain loop: with S stages and
M microbatches, each device runs ``S + M - 1`` ticks; device s computes
microbatch (t - s) when 0 ≤ t - s < M, and activations hop s → s+1 between
ticks.  Bubble fraction = (S-1)/(S+M-1).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed.compat import shard_map


def pipeline_apply(stage_fn: Callable[[Any, jnp.ndarray], jnp.ndarray],
                   stage_params: Any, x: jnp.ndarray, mesh: Mesh,
                   axis: str = "pod") -> jnp.ndarray:
    """Run ``stage_fn`` as a pipeline over ``axis``.

    stage_params: pytree whose leaves have leading dim = #stages (sharded
      over ``axis`` — each device holds its own stage's slice).
    x: [M, mb, ...] microbatched input (M = #microbatches, replicated over
      ``axis``; other mesh axes may shard the trailing dims as usual).
    Returns [M, mb, ...] outputs.
    """
    S = mesh.shape[axis]
    M = x.shape[0]
    n_ticks = S + M - 1

    def per_stage(params_slice, xs):
        # params_slice: this device's stage params (leading dim 1)
        params_local = jax.tree_util.tree_map(lambda l: l[0], params_slice)
        s = jax.lax.axis_index(axis)
        mb_shape = xs.shape[1:]
        buf = jnp.zeros(mb_shape, xs.dtype)      # activation register
        outs = jnp.zeros_like(xs)

        def tick(t, carry):
            buf, outs = carry
            # stage 0 ingests microbatch t from xs; others use the buffer
            mb_idx = jnp.clip(t, 0, M - 1)
            x_in = jnp.where(s == 0,
                             jax.lax.dynamic_index_in_dim(
                                 xs, mb_idx, keepdims=False),
                             buf)
            active = (t - s >= 0) & (t - s < M)
            y = stage_fn(params_local, x_in)
            y = jnp.where(active, y, buf)
            # last stage banks its result at slot (t - s)
            out_idx = jnp.clip(t - s, 0, M - 1)
            outs = jnp.where(
                active & (s == S - 1),
                jax.lax.dynamic_update_index_in_dim(
                    outs, y, out_idx, axis=0),
                outs)
            # hop activations s -> s+1
            perm = [(i, i + 1) for i in range(S - 1)]
            buf = jax.lax.ppermute(y, axis, perm)
            return (buf, outs)

        buf, outs = jax.lax.fori_loop(0, n_ticks, tick, (buf, outs))
        # results live on the last stage; broadcast them to every stage so
        # the out_spec can be replicated over the pipeline axis
        outs = jax.lax.psum(
            jnp.where(s == S - 1, outs, jnp.zeros_like(outs)), axis)
        return outs

    pspec = jax.tree_util.tree_map(lambda _: P(axis), stage_params)
    other = tuple(a for a in mesh.axis_names if a != axis)
    xspec = P(*((None,) * x.ndim))
    return shard_map(
        per_stage, mesh, in_specs=(pspec, xspec),
        out_specs=xspec, check=False,
    )(stage_params, x)
