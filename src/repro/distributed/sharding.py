"""Sharding policy: how every parameter, activation, and cache tensor maps
onto the production mesh.

Axes:
  data  — batch / FSDP axis (16-way per pod)
  model — tensor/expert/sequence-parallel axis (16-way)
  pod   — optional pod axis (2-way): batch (and FSDP for the largest models)

Model code stays mesh-agnostic: it calls ``hint(x, name)`` at key points,
which applies ``with_sharding_constraint`` when a policy is active and is a
no-op otherwise (CPU tests).  Parameter specs are resolved from pytree paths
by ``param_specs`` — the same rules serve pjit in_shardings and checkpoint
resharding.
"""
from __future__ import annotations

import contextlib
import re
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig

# Parameters above this count get FSDP over (pod, data) instead of data only.
_POD_FSDP_PARAM_THRESHOLD = 60e9


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


_SERVE_HBM_BUDGET = 12e9   # per-chip bytes before serve mode re-shards weights


def _fitted_spec(mesh, shape, spec) -> P:
    """Drop spec axes whose mesh-axis product doesn't divide their dim
    (jit in/out shardings require exact divisibility; the surviving axes
    still pin the layout — param_specs, cache_specs and hint all share
    this partial-fit rule so constraints never fight each other)."""
    fixed = []
    for dim, ax in zip(shape, spec):
        if ax is not None:
            size = 1
            for a in (ax if isinstance(ax, tuple) else (ax,)):
                size *= mesh.shape[a]
            if not size or dim % size != 0:
                ax = None
        fixed.append(ax)
    return P(*fixed)


@dataclass
class ShardingPolicy:
    mesh: Mesh
    cfg: ModelConfig
    mode: str = "train"                 # train | serve
    fsdp_over_pod: Optional[bool] = None
    # ZeRO-1: replicate params over the data axis (weight-stationary
    # training — no per-layer weight all-gathers); optimizer state stays
    # data-sharded.  The §Perf hillclimb lever for collective-bound train.
    zero1: bool = False
    # name -> PartitionSpec for activation hints
    overrides: Dict[str, P] = field(default_factory=dict)

    def __post_init__(self):
        axes = self.mesh.axis_names
        self.has_pod = "pod" in axes
        if self.fsdp_over_pod is None:
            self.fsdp_over_pod = (self.has_pod and
                                  self.cfg.param_count() > _POD_FSDP_PARAM_THRESHOLD)
        self.dp: Tuple[str, ...] = (("pod", "data") if self.has_pod else ("data",))
        self.model_size = self.mesh.shape["model"]
        if self.mode == "serve":
            # weight-stationary inference: shard weights over `model` only
            # unless they don't fit, in which case spill onto the data axis
            # (re-gathered each step — the memory-capacity trade).
            per_chip = 2 * self.cfg.param_count() / self.model_size
            if per_chip <= _SERVE_HBM_BUDGET:
                self.fsdp: Any = None
            elif not self.has_pod or per_chip / self.mesh.shape["data"] \
                    <= _SERVE_HBM_BUDGET:
                self.fsdp = "data"
            else:
                self.fsdp = ("pod", "data")
        else:
            self.fsdp = (("pod", "data") if self.fsdp_over_pod else "data")
            self.opt_fsdp = self.fsdp
            if self.zero1:
                self.fsdp = None

    # ------------------------------------------------------------ activations
    def spec(self, name: str) -> Optional[P]:
        if name in self.overrides:
            return self.overrides[name]
        dp, fsdp = self.dp, self.fsdp
        E = self.cfg.num_experts
        ep = E and E % self.model_size == 0
        train = self.mode == "train"
        table = {
            # [B, T, D]
            "activation": P(dp, None, None),
            # [B, T, D] inter-stage residual carry: sequence-parallel in
            # training (the per-stage saved residuals dominate HBM
            # otherwise — Megatron-SP); replicated-T at inference.
            "residual": P(dp, "model", None) if train else P(dp, None, None),
            # [B, T, V]
            "logits": P(dp, None, "model"),
            # [B, T, Hq, dh]
            "q_heads": P(dp, None, "model", None),
            # [B, T, Hkv, dh] — serve mode head-shards (matching the
            # head-sharded attention split and the serve cache_specs);
            # training replicates (kv heads usually < model size there)
            "kv_heads": (P(dp, None, None, None) if train
                         else P(dp, None, "model", None)),
            # decode-step KV cache [B, T, Hkv, dh]: sequence-parallel over
            # model in training; head-sharded at serve time (each device
            # owns Hkv/TP heads of the whole history — no cross-device
            # traffic inside the attention dot)
            "kv_cache_step": (P(dp, "model", None, None) if train
                              else P(dp, None, "model", None)),
            # head-major decode cache [B, Hkv, T, dh]
            "kv_cache_step_bhtd": (P(dp, None, "model", None) if train
                                   else P(dp, "model", None, None)),
            # prefill/train KV view [B, T, Hkv, dh]: carried across the layer
            # scan — sequence-parallel in training for the same reason;
            # head-sharded at serve time like the caches it feeds.
            "kv_view": (P(dp, "model", None, None) if train
                        else P(dp, None, "model", None)),
            # [B, T] per-token Σy² carry (incremental-reduction): follows
            # the residual it accompanies — sequence-sharded in training,
            # replicated over model at serve time (every device needs the
            # full-row norm to take identical routing/sampling decisions)
            "res_sq": P(dp, "model") if train else P(dp, None),
            # [E, C, D]
            "moe_buffer": P("model", None, None) if ep else P(None, "model", None),
            # [B, T] routing masks
            "gate": P(dp, None),
            # mamba state [B, H, P, N]
            "ssm_state": P(dp, "model", None, None),
            # conv state [B, W-1, C]
            "conv_state": P(dp, None, None),
        }
        return table.get(name)

    def named(self, name: str) -> Optional[NamedSharding]:
        s = self.spec(name)
        return NamedSharding(self.mesh, s) if s is not None else None

    # ------------------------------------------------------------- parameters
    def _param_spec(self, path: str, shape: Tuple[int, ...]) -> P:
        cfg, fsdp = self.cfg, self.fsdp
        E = cfg.num_experts
        ep = E and E % self.model_size == 0
        # --- embeddings / unembedding ---
        if path.endswith("embed/table"):
            return P("model", fsdp)
        if "lm_head" in path:
            return P(fsdp, "model")
        # --- MoE experts ---
        if re.search(r"(^|/)(w_up|w_gate)$", path):
            return P("model", fsdp, None) if ep else P(None, fsdp, "model")
        if path.endswith("w_down"):
            return P("model", None, fsdp) if ep else P(None, "model", fsdp)
        if re.search(r"moe[^/]*/gate$", path) or path.endswith("/gate") and len(shape) == 2 \
                and shape[-1] == E:
            return P(fsdp, None)
        # --- routers (tiny) ---
        if "router" in path:
            return P(None, None)
        # --- attention ---
        if path.endswith("wqkv/w"):
            # merged [q|k|v]: the column split is (ai, ki, ki) — shard the
            # output dim only when every slice divides the axis cleanly
            if (cfg.attn_inner_dim % self.model_size == 0
                    and cfg.kv_inner_dim % self.model_size == 0
                    and cfg.num_kv_heads >= self.model_size):
                return P(fsdp, "model")
            # GQA fallback (kv heads < model axis): the q/k/v boundaries
            # can't split column-wise, so go row-parallel over the input
            # dim — memory-balanced (1/model_size per device) instead of
            # replicating the large q projection with the legacy split
            # layout's column rules.
            row = ((fsdp if isinstance(fsdp, tuple) else (fsdp,))
                   if fsdp else ()) + ("model",)
            return P(row, None)
        if path.endswith("wq/w"):                          # legacy split
            return P(fsdp, "model")
        if path.endswith(("wk/w", "wv/w")):
            # kv_inner usually < model size heads; shard when divisible
            if shape[-1] % self.model_size == 0 and cfg.num_kv_heads >= self.model_size:
                return P(fsdp, "model")
            return P(fsdp, None)
        if path.endswith("wo/w"):
            # training: Megatron row-parallel (input dim over model — one
            # psum per block).  serve: column split over the *output* dim —
            # the head-sharded attention output is all-gathered instead,
            # so no cross-device reduction ever reorders fp sums and the
            # sharded engine stays bit-identical to the unsharded one (the
            # serving identity contract tests/test_sharded_serve.py pins;
            # all-gathers move the same bytes as the psum at decode M).
            return (P("model", fsdp) if self.mode == "train"
                    else P(fsdp, "model"))
        # --- MLP ---
        if path.endswith(("gu/w", "up/w", "gate/w")):
            return P(fsdp, "model")
        if path.endswith("down/w"):
            # row-parallel in training, column split at serve time — same
            # bit-identity rationale as wo/w above.
            return (P("model", fsdp) if self.mode == "train"
                    else P(fsdp, "model"))
        # --- SSM ---
        if re.search(r"in_proj_(z|x)/w$", path):
            return P(fsdp, "model")
        if re.search(r"in_proj_(bc|dt)/w$", path):
            return P(fsdp, None)
        if path.endswith("out_proj/w"):
            # same train-row / serve-column split as wo/w and down/w: a
            # Mamba block's output projection must not psum at serve time
            # either, or hybrid-arch sharded serving loses bit-identity.
            return (P("model", fsdp) if self.mode == "train"
                    else P(fsdp, "model"))
        if path.endswith("conv_x_w"):
            return P(None, "model")
        # --- quantized variants: w_int/scale share the dense layout ---
        if path.endswith(("w_int", "scale")):
            base = path.rsplit("/", 1)[0] + "/w"
            return self._param_spec(base, shape)
        # --- norms, biases, scalars: replicate ---
        return P(*([None] * len(shape)))

    def param_specs(self, tree) -> Any:
        """tree: params pytree (arrays or ShapeDtypeStructs) -> NamedSharding tree."""
        def one(path, leaf):
            ps = _path_str(path)
            shape = leaf.shape
            stacked = "stages/" in ps or ps.startswith("stages")
            if stacked:
                shape = shape[1:]                 # scan-stacked leading dim
            spec = list(self._param_spec(ps, shape))
            if stacked:
                spec = [None] + spec
                shape = leaf.shape
            return NamedSharding(self.mesh,
                                 _fitted_spec(self.mesh, shape, spec))

        return jax.tree_util.tree_map_with_path(one, tree)

    def opt_state_specs(self, opt_shapes) -> Any:
        """AdamW moments mirror the param specs; the count is replicated.
        Under ZeRO-1 the moments keep their data-axis shard even though the
        params are replicated."""
        saved = self.fsdp
        if self.zero1 and self.mode == "train":
            self.fsdp = self.opt_fsdp
        try:
            m = self.param_specs(opt_shapes["m"])
            v = self.param_specs(opt_shapes["v"])
        finally:
            self.fsdp = saved
        return {"m": m, "v": v,
                "count": NamedSharding(self.mesh, P())}

    # ------------------------------------------------------------------ cache
    def cache_specs(self, cache_tree, seq_shard: bool = False,
                    layout: str = "bthd", seq_fallback: bool = True) -> Any:
        """Decode-cache sharding — covers the lock-step decode caches, the
        continuous-batching engine's slot pool (``serve/engine.init_pool``:
        the same tree with ``max_slots`` rows) and the paged ``KVStore``
        (``kvcache/paged.init_store``: the flat ``*_pages`` dict).

        ``mode == "serve"`` head-shards KV over ``model`` (each device owns
        ``Hkv/TP`` heads of every slot's whole history — the split matching
        head-sharded attention, so the decode dot is cross-device-silent and
        per-chip KV HBM drops ~1/TP); when the head count doesn't divide
        the model axis (GQA below TP) it falls back to the sequence split
        so per-chip KV still stays ~1/TP instead of replicating; training
        always uses the sequence-parallel split.  ``seq_fallback=False``
        replicates instead on non-dividing heads — for *transient*
        single-request prefill/staging caches, whose bucketed time axes
        have no fixed length a sequence split could be guaranteed to
        divide (the long-lived pool/store is what per-chip HBM rides on).  Entry metadata (``pos/l0/l1`` pages) is replicated: block
        tables, free list and history indirection stay host-global so the
        scheduler and ``PageAllocator`` are unchanged under TP.
        seq_shard=True (long_500k, batch too small to shard) puts the
        KV/conv sequence axis on the mesh instead."""
        dp = self.dp
        serve = self.mode == "serve" and not seq_shard

        def one(path, leaf):
            name = _path_str(path).rsplit("/", 1)[-1]
            nd = leaf.ndim
            if name in ("k_pages", "v_pages"):
                # paged entry stream [P, page, Hkv, dh]: shard the head
                # axis; page geometry stays device-uniform so one global
                # block table addresses every shard.  GQA fallback (heads
                # don't divide TP): shard the page axis instead — reads
                # gather cross-device, but per-chip store memory stays
                # 1/TP rather than silently replicating.
                if leaf.shape[2] % self.model_size == 0:
                    spec = (None, None, "model", None)
                else:
                    spec = ("model", None, None, None)
            elif name in ("k_scales", "v_scales"):
                # quantized-page scales [P, page, Hkv]: ride the payload's
                # sharding so code and scale for an entry-head pair stay
                # on the same chip
                if leaf.shape[2] % self.model_size == 0:
                    spec = (None, None, "model")
                else:
                    spec = ("model", None, None)
            elif name in ("pos_pages", "l0_pages", "l1_pages"):
                spec = (None,) * nd                   # replicated metadata
            elif name in ("k", "v"):
                lead = (None,) * (nd - 4)
                seq_axes = (("data", "model") if not self.has_pod
                            else ("pod", "data", "model"))
                bhtd = (layout == "bhtd"
                        and leaf.shape[nd - 2] > leaf.shape[nd - 3])
                heads = leaf.shape[nd - 3] if bhtd else leaf.shape[nd - 2]
                if serve and heads % self.model_size == 0:
                    # [..., B, Hkv, T, dh] / [..., B, T, Hkv, dh]
                    spec = lead + ((dp, "model", None, None) if bhtd
                                   else (dp, None, "model", None))
                elif serve and seq_fallback:
                    # GQA fallback (Hkv < TP or non-dividing): keep the
                    # sequence split — per-chip KV stays ~1/TP instead of
                    # replicating (bit-identity is then fp-tolerance only,
                    # like the row-parallel wqkv fallback it accompanies)
                    spec = lead + ((dp, None, "model", None) if bhtd
                                   else (dp, "model", None, None))
                elif serve:
                    # transient cache with non-dividing heads: replicate
                    # (its bucketed time extents can't carry a guaranteed
                    # divisible sequence split)
                    spec = lead + (dp, None, None, None)
                elif bhtd:
                    # [..., B, Hkv, T, dh] (local ring caches stay bthd)
                    spec = lead + ((None, None, seq_axes, None) if seq_shard
                                   else (dp, None, "model", None))
                elif seq_shard:
                    spec = lead + (None, seq_axes, None, None)
                else:
                    spec = lead + (dp, "model", None, None)
            elif name == "ssm":
                # [..., B, H, P, N]
                lead = (None,) * (nd - 4)
                spec = lead + (None if seq_shard else dp, "model", None, None)
            elif name == "conv_x":
                lead = (None,) * (nd - 3)
                spec = lead + (None if seq_shard else dp, None, "model")
            elif name == "conv_bc":
                lead = (None,) * (nd - 3)
                spec = lead + (None if seq_shard else dp, None, None)
            else:
                spec = (None,) * nd
            return NamedSharding(self.mesh,
                                 _fitted_spec(self.mesh, leaf.shape, spec))

        return jax.tree_util.tree_map_with_path(one, cache_tree)


# ---------------------------------------------------------------------------
# Active-policy plumbing (model code calls ``hint``)
# ---------------------------------------------------------------------------
_ACTIVE: Optional[ShardingPolicy] = None


@contextlib.contextmanager
def set_policy(policy: Optional[ShardingPolicy]):
    global _ACTIVE
    prev, _ACTIVE = _ACTIVE, policy
    try:
        yield policy
    finally:
        _ACTIVE = prev


def active_policy() -> Optional[ShardingPolicy]:
    return _ACTIVE


def hint(x: jnp.ndarray, name: str) -> jnp.ndarray:
    """Apply the active policy's sharding constraint for ``name`` (no-op
    when no policy is active or the tensor rank doesn't match the rule).
    Axes that don't divide their mesh product are dropped from the spec
    (not the whole constraint): a batch-1 prefill on a data>1 mesh keeps
    its replicated-over-model pins — losing them entirely lets GSPMD pick
    divergent layouts — while e.g. GQA KV heads below the serve TP degree
    just stay replicated at the hint site (the cache in/out shardings
    carry the sequence-split fallback)."""
    pol = _ACTIVE
    if pol is None:
        return x
    spec = pol.spec(name)
    if spec is None or len(spec) != x.ndim:
        return x
    fitted = _fitted_spec(pol.mesh, x.shape, spec)
    return jax.lax.with_sharding_constraint(x, NamedSharding(pol.mesh,
                                                             fitted))
