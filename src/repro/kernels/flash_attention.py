"""Fused attention Pallas kernel (TPU target; interpret=True on CPU).

Implements the paper's Alg. 2 dataflow on the TPU memory hierarchy:
  * online softmax features (running max m, running Σexp l) carried in VMEM
    scratch across KV tiles — the decoupled, incremental reduction;
  * **KV-head packing**: all G = Hq/Hkv query heads of one KV group are
    packed into the query-row dimension of a single grid cell, so each KV
    tile loaded from HBM is reused G× (the paper's multi-head packing,
    §3.2, re-targeted from DSP columns to MXU rows);
  * causal / sliding-window / valid-length masking by absolute position, so
    SkipGPT gather-mode (compacted query subsets) works unchanged.

Layouts: q [BH, R, dh] where BH = B·Hkv and R packs (G, Tq) rows;
k/v [BH, Tk, dh]; q_pos int32 [BH, R]; kv_len int32 [BH, 1].
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1.0e30
DEFAULT_BQ = 128
DEFAULT_BK = 128


def _flash_kernel(qpos_ref, kvlen_ref, q_ref, k_ref, v_ref, o_ref,
                  m_scr, l_scr, acc_scr, *, bk: int, causal: bool,
                  window: int, scale: float):
    j = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32) * scale              # [bq, dh]
    k = k_ref[0].astype(jnp.float32)                      # [bk, dh]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [bq, bk]

    kv_pos = j * bk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    q_pos = qpos_ref[0][:, None]                          # [bq, 1]
    mask = kv_pos < kvlen_ref[0, 0]
    if causal:
        mask &= kv_pos <= q_pos
    if window:
        mask &= kv_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                                   # [bq, 1]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)                                # [bq, bk]
    alpha = jnp.exp(m_prev - m_new)                       # [bq, 1]
    l_scr[...] = l_scr[...] * alpha + p.sum(axis=-1, keepdims=True)
    v = v_ref[0].astype(jnp.float32)                      # [bk, dh]
    pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_scr[...] = acc_scr[...] * alpha + pv
    m_scr[...] = m_new

    @pl.when(j == nk - 1)
    def _finalize():
        o_ref[0] = (acc_scr[...] /
                    jnp.maximum(l_scr[...], 1e-20)).astype(o_ref.dtype)


def flash_attention_packed(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                           q_pos: jnp.ndarray, kv_len: jnp.ndarray, *,
                           causal: bool = True, window: int = 0,
                           scale: float, bq: int = DEFAULT_BQ,
                           bk: int = DEFAULT_BK,
                           interpret: bool = False) -> jnp.ndarray:
    """q: [BH, R, dh]; k/v: [BH, Tk, dh]; q_pos: [BH, R]; kv_len: [BH, 1]."""
    BH, R, dh = q.shape
    Tk = k.shape[1]
    bq = min(bq, R)
    bk = min(bk, Tk)

    # pad R and Tk to block multiples; padded q rows get position -1 (fully
    # masked -> guarded divide), padded kv masked via kv_len.
    Rp = -(-R // bq) * bq
    Tp = -(-Tk // bk) * bk
    if Rp != R:
        q = jnp.pad(q, ((0, 0), (0, Rp - R), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, Rp - R)), constant_values=-1)
    if Tp != Tk:
        k = jnp.pad(k, ((0, 0), (0, Tp - Tk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, Tp - Tk), (0, 0)))

    grid = (BH, Rp // bq, Tp // bk)
    kernel = functools.partial(_flash_kernel, bk=bk, causal=causal,
                               window=window, scale=scale)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq), lambda b, i, j: (b, i)),          # q_pos
            pl.BlockSpec((1, 1), lambda b, i, j: (b, 0)),           # kv_len
            pl.BlockSpec((1, bq, dh), lambda b, i, j: (b, i, 0)),   # q
            pl.BlockSpec((1, bk, dh), lambda b, i, j: (b, j, 0)),   # k
            pl.BlockSpec((1, bk, dh), lambda b, i, j: (b, j, 0)),   # v
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Rp, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),    # m
            pltpu.VMEM((bq, 1), jnp.float32),    # l
            pltpu.VMEM((bq, dh), jnp.float32),   # acc
        ],
        interpret=interpret,
    )(q_pos, kv_len, q, k, v)
    return out[:, :R]
