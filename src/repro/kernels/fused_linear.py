"""Unified fused linear-pipeline Pallas kernel family (paper Alg. 1 + §4.2).

One k-loop matmul kernel parameterized along three axes, so every linear
op of the routed block runs as a single VMEM-resident pipeline:

  * **prologue** — the RMSNorm elementwise phase applied to the activation
    tile *inside* the k-loop from injected ``mean_sq`` statistics
    (Alg. 1 ll. 11–15: the reduction was computed earlier, fused with the
    router; the normalized activation never round-trips through HBM).
  * **weight path** — dense bf16/f32, *or* int4 codes with per-group
    power-of-2 scales accumulated in the BFP fixed-point domain
    (paper §4.2): the (optionally normalized) activation tile feeds the
    FP→BFP row-quantization directly, then int8×int4 products accumulate
    in int32 with one FP reconstruction per (row, K-group).
  * **epilogue** — optional SwiGLU/GeGLU gating over a widened
    ``[gate | up]`` output (stored as ``[K, 2, F]`` so one weight tile
    carries both halves of an output block), optional per-row gate
    multiplier, optional residual add, and optional incremental emission
    of Σy² of the written residual stream — the *next* block's norm
    reduction (the paper's incremental-reduction carry) comes out of this
    kernel for free.

This subsumes the former ``rmsnorm_matmul`` kernel (prologue-only,
dense-only) and composes with the hybrid float-fixed path that the paper
actually deploys.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.int4_matmul import MBITS, _bfp_quantize_rows

DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 512


def _act(x: jnp.ndarray, act: Optional[str]) -> jnp.ndarray:
    """Epilogue activation dispatch — shared with ref.fused_linear_ref so
    the oracle and the kernel can never diverge on a new activation."""
    if act is None:
        return x
    if act == "silu":
        return jax.nn.silu(x)
    if act == "gelu":
        return jax.nn.gelu(x)
    raise ValueError(f"unknown epilogue activation {act!r}")


def _fused_linear_kernel(*refs, prologue: bool, int4: bool, glu: bool,
                         act: Optional[str], has_res: bool, has_gmul: bool,
                         emit_sq: bool, eps: float, out_dtype):
    it = iter(refs)
    x_ref = next(it)
    ms_ref = next(it) if prologue else None
    g_ref = next(it) if prologue else None
    w_ref = next(it)
    s_ref = next(it) if int4 else None
    res_ref = next(it) if has_res else None
    gm_ref = next(it) if has_gmul else None
    o_ref = next(it)
    sq_ref = next(it) if emit_sq else None
    acc_scr = next(it)
    sq_scr = next(it) if emit_sq else None

    j = pl.program_id(1)
    k = pl.program_id(2)
    nj = pl.num_programs(1)
    nk = pl.num_programs(2)

    @pl.when(k == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    if emit_sq:
        @pl.when(jnp.logical_and(j == 0, k == 0))
        def _init_sq():
            sq_scr[...] = jnp.zeros_like(sq_scr)

    x = x_ref[...].astype(jnp.float32)                      # [bm, bk]
    if prologue:
        # RMSNorm elementwise phase from the injected reduction — the
        # normalized tile exists only in VMEM.
        x = x * jax.lax.rsqrt(ms_ref[...] + eps) \
              * g_ref[...].astype(jnp.float32)

    if int4:
        mant, pe = _bfp_quantize_rows(x)                    # BFP domain
        w = w_ref[...]                                      # int8 codes
        if glu:
            w = w.reshape(w.shape[0], -1)                   # [bk, 2·bn]
        prod = jax.lax.dot_general(
            mant.astype(jnp.int32), w.astype(jnp.int32),
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32)               # fixed point
        s = s_ref[...]
        if glu:
            s = s.reshape(1, -1)
        acc_scr[...] += (prod.astype(jnp.float32)
                         * (pe * (2.0 ** -MBITS)) * s)
    else:
        w = w_ref[...].astype(jnp.float32)
        if glu:
            w = w.reshape(w.shape[0], -1)                   # [bk, 2·bn]
        acc_scr[...] += jax.lax.dot_general(
            x, w, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _fin():
        acc = acc_scr[...]
        if glu:
            bn = acc.shape[-1] // 2
            y = _act(acc[:, :bn], act) * acc[:, bn:]
        else:
            y = _act(acc, act)
        if has_gmul:
            y = y * gm_ref[...]
        if has_res:
            y = y + res_ref[...].astype(jnp.float32)
        if emit_sq:
            sq_scr[...] += (y * y).sum(axis=-1, keepdims=True)
            @pl.when(j == nj - 1)
            def _emit():
                sq_ref[...] = sq_scr[...]
        o_ref[...] = y.astype(out_dtype)


def fused_linear_pallas(x: jnp.ndarray, w: Optional[jnp.ndarray] = None,
                        w_codes: Optional[jnp.ndarray] = None,
                        scale: Optional[jnp.ndarray] = None, *,
                        mean_sq: Optional[jnp.ndarray] = None,
                        gamma: Optional[jnp.ndarray] = None,
                        eps: float = 1e-5,
                        glu: bool = False, act: Optional[str] = None,
                        residual: Optional[jnp.ndarray] = None,
                        gate_mul: Optional[jnp.ndarray] = None,
                        emit_sq: bool = False,
                        bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
                        bk: int = DEFAULT_BK, interpret: bool = False
                        ) -> Tuple[jnp.ndarray, Optional[jnp.ndarray]]:
    """x: [M, K] × weight [K', N] -> (out [M, F], Σy² [M] f32 or None).

    Exactly one of ``w`` (dense) or ``(w_codes, scale)`` (int4 codes in
    [-8, 7] stored as int8; scale [K'/G, N]) must be given.  ``K' >= K``
    covers group-padded quantized weights (the trailing rows are zero
    codes); x is zero-padded up to K'.  With ``glu`` the weight is the
    widened ``[gate | up]`` matrix (N == 2F) and the output is
    ``act(x·Wg) * (x·Wu)`` of width F; otherwise F == N and ``act`` (if
    any) applies elementwise.  ``mean_sq`` [M] + ``gamma`` [K] enable the
    RMSNorm prologue; ``gate_mul`` [M] scales rows before the optional
    ``residual`` [M, F] add; ``emit_sq`` returns Σy² per row of the final
    output (the next block's norm reduction, pre-division)."""
    int4 = w_codes is not None
    assert (w is None) == int4, "exactly one of w / (w_codes, scale)"
    M, K = x.shape
    wt = w_codes if int4 else w
    Kw, N = wt.shape
    assert Kw >= K
    prologue = mean_sq is not None
    if prologue:
        assert gamma is not None

    if int4:
        rows = scale.shape[0]
        assert Kw % rows == 0, (Kw, rows)
        bk = Kw // rows                                     # K-tile == group
    else:
        bk = min(bk, Kw)

    F = N // 2 if glu else N
    bm = min(bm, M)
    bn = min(bn, F)
    Mp = -(-M // bm) * bm
    Fp = -(-F // bn) * bn
    Kp = -(-Kw // bk) * bk

    if glu:                                                 # [K, 2, F]
        wt = wt.reshape(Kw, 2, F)
        if int4:
            scale = scale.reshape(scale.shape[0], 2, F)
    if Kp != Kw or Kp != K:
        x = jnp.pad(x, ((0, 0), (0, Kp - K)))
        if Kp != Kw:
            wt = jnp.pad(wt, ((0, Kp - Kw),) + ((0, 0),) * (wt.ndim - 1))
        if prologue:
            gamma = jnp.pad(gamma, (0, Kp - K))
    if Mp != M:
        x = jnp.pad(x, ((0, Mp - M), (0, 0)))
        if prologue:
            mean_sq = jnp.pad(mean_sq, (0, Mp - M), constant_values=1.0)
        if residual is not None:
            residual = jnp.pad(residual, ((0, Mp - M), (0, 0)))
        if gate_mul is not None:
            gate_mul = jnp.pad(gate_mul, (0, Mp - M))
    if Fp != F:
        pads = ((0, 0),) * (wt.ndim - 1) + ((0, Fp - F),)
        wt = jnp.pad(wt, pads)
        if int4:
            scale = jnp.pad(scale, pads)
        if residual is not None:
            residual = jnp.pad(residual, ((0, 0), (0, Fp - F)))

    grid = (Mp // bm, Fp // bn, Kp // bk)
    wb = 2 * bn if glu else bn

    in_specs = [pl.BlockSpec((bm, bk), lambda i, j, k: (i, k))]
    inputs = [x]
    if prologue:
        in_specs += [pl.BlockSpec((bm, 1), lambda i, j, k: (i, 0)),
                     pl.BlockSpec((1, bk), lambda i, j, k: (0, k))]
        inputs += [mean_sq.astype(jnp.float32)[:, None], gamma[None, :]]
    if glu:
        in_specs.append(pl.BlockSpec((bk, 2, bn), lambda i, j, k: (k, 0, j)))
    else:
        in_specs.append(pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)))
    inputs.append(wt)
    if int4:
        if glu:
            in_specs.append(
                pl.BlockSpec((1, 2, bn), lambda i, j, k: (k, 0, j)))
        else:
            in_specs.append(pl.BlockSpec((1, bn), lambda i, j, k: (k, j)))
        inputs.append(scale)
    if residual is not None:
        in_specs.append(pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)))
        inputs.append(residual)
    if gate_mul is not None:
        in_specs.append(pl.BlockSpec((bm, 1), lambda i, j, k: (i, 0)))
        inputs.append(gate_mul.astype(jnp.float32)[:, None])

    out_specs = [pl.BlockSpec((bm, bn), lambda i, j, k: (i, j))]
    out_shape = [jax.ShapeDtypeStruct((Mp, Fp), x.dtype)]
    if emit_sq:
        out_specs.append(pl.BlockSpec((bm, 1), lambda i, j, k: (i, 0)))
        out_shape.append(jax.ShapeDtypeStruct((Mp, 1), jnp.float32))

    scratch = [pltpu.VMEM((bm, wb), jnp.float32)]
    if emit_sq:
        scratch.append(pltpu.VMEM((bm, 1), jnp.float32))

    kernel = functools.partial(
        _fused_linear_kernel, prologue=prologue, int4=int4, glu=glu,
        act=act, has_res=residual is not None,
        has_gmul=gate_mul is not None, emit_sq=emit_sq, eps=eps,
        out_dtype=x.dtype)
    out = pl.pallas_call(
        kernel, grid=grid, in_specs=in_specs, out_specs=out_specs,
        out_shape=out_shape, scratch_shapes=scratch,
        interpret=interpret)(*inputs)
    if emit_sq:
        return out[0][:M, :F], out[1][:M, 0]
    return out[0][:M, :F], None
