"""Fused router + RMSNorm-statistics Pallas kernels (paper Alg. 1).

Two kernels realize the paper's decoupled-reduction dataflow:

1. ``router_stats``: one pass over each activation tile produces BOTH the
   router logits (X·W_θ) and the RMSNorm reduction (Σx²) — lines 4–7 of
   Alg. 1.  The router weight is lane-padded to 128 columns so the matmul
   is MXU-shaped; the caller slices the 2 real logits.

2. ``rmsnorm_matmul``: the element-wise normalization phase is applied to
   the X tile *inside* the k-loop of the following projection matmul
   (prologue fusion) — lines 11–15 of Alg. 1: the normalized tile never
   round-trips to HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANE = 128
DEFAULT_BT = 256
DEFAULT_BD = 512


# ---------------------------------------------------------------------------
# Kernel 1: router logits + Σx² in one pass
# ---------------------------------------------------------------------------

def _router_stats_kernel(x_ref, w_ref, logit_ref, sq_ref,
                         logit_scr, sq_scr, *, d_total: int):
    j = pl.program_id(1)
    nd = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        logit_scr[...] = jnp.zeros_like(logit_scr)
        sq_scr[...] = jnp.zeros_like(sq_scr)

    x = x_ref[...].astype(jnp.float32)                    # [bt, bd]
    w = w_ref[...].astype(jnp.float32)                    # [bd, LANE]
    logit_scr[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    sq_scr[...] += (x * x).sum(axis=-1, keepdims=True)

    @pl.when(j == nd - 1)
    def _fin():
        logit_ref[...] = logit_scr[...]
        sq_ref[...] = sq_scr[...] / d_total               # mean square


def router_stats_pallas(x: jnp.ndarray, w: jnp.ndarray, *,
                        bt: int = DEFAULT_BT, bd: int = DEFAULT_BD,
                        interpret: bool = False):
    """x: [T, D]; w: [D, 2] -> (logits [T, 2] f32, mean_sq [T] f32)."""
    T, D = x.shape
    wp = jnp.zeros((D, LANE), jnp.float32).at[:, :2].set(
        w.astype(jnp.float32))
    bt = min(bt, T)
    bd = min(bd, D)
    Tp = -(-T // bt) * bt
    Dp = -(-D // bd) * bd
    if Tp != T:
        x = jnp.pad(x, ((0, Tp - T), (0, 0)))
    if Dp != D:
        x = jnp.pad(x, ((0, 0), (0, Dp - D)))
        wp = jnp.pad(wp, ((0, Dp - D), (0, 0)))

    grid = (Tp // bt, Dp // bd)
    logits, sq = pl.pallas_call(
        functools.partial(_router_stats_kernel, d_total=D),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, bd), lambda i, j: (i, j)),
            pl.BlockSpec((bd, LANE), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bt, LANE), lambda i, j: (i, 0)),
            pl.BlockSpec((bt, 1), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Tp, LANE), jnp.float32),
            jax.ShapeDtypeStruct((Tp, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bt, LANE), jnp.float32),
            pltpu.VMEM((bt, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x, wp)
    return logits[:T, :2], sq[:T, 0]


# ---------------------------------------------------------------------------
# Kernel 2: normalization fused into the following matmul's k-loop
# ---------------------------------------------------------------------------

def _rmsnorm_matmul_kernel(x_ref, ms_ref, g_ref, w_ref, o_ref, acc_scr, *,
                           eps: float, out_dtype):
    k = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(k == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    x = x_ref[...].astype(jnp.float32)                    # [bm, bk]
    ms = ms_ref[...]                                      # [bm, 1]
    g = g_ref[...].astype(jnp.float32)                    # [1, bk]
    xn = x * jax.lax.rsqrt(ms + eps) * g
    w = w_ref[...].astype(jnp.float32)                    # [bk, bn]
    acc_scr[...] += jax.lax.dot_general(
        xn, w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _fin():
        o_ref[...] = acc_scr[...].astype(out_dtype)


def rmsnorm_matmul_pallas(x: jnp.ndarray, mean_sq: jnp.ndarray,
                          gamma: jnp.ndarray, w: jnp.ndarray, *,
                          eps: float = 1e-5, bm: int = 128, bn: int = 128,
                          bk: int = 512, interpret: bool = False
                          ) -> jnp.ndarray:
    """x: [M, K]; mean_sq: [M]; gamma: [K]; w: [K, N] -> rmsnorm(x)·w."""
    M, K = x.shape
    N = w.shape[1]
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    Mp, Np, Kp = (-(-M // bm) * bm, -(-N // bn) * bn, -(-K // bk) * bk)
    if Mp != M:
        x = jnp.pad(x, ((0, Mp - M), (0, 0)))
        mean_sq = jnp.pad(mean_sq, (0, Mp - M), constant_values=1.0)
    if Kp != K:
        x = jnp.pad(x, ((0, 0), (0, Kp - K)))
        gamma = jnp.pad(gamma, (0, Kp - K))
        w = jnp.pad(w, ((0, Kp - K), (0, 0)))
    if Np != N:
        w = jnp.pad(w, ((0, 0), (0, Np - N)))

    grid = (Mp // bm, Np // bn, Kp // bk)
    out = pl.pallas_call(
        functools.partial(_rmsnorm_matmul_kernel, eps=eps, out_dtype=x.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bm, 1), lambda i, j, k: (i, 0)),
            pl.BlockSpec((1, bk), lambda i, j, k: (0, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, mean_sq[:, None], gamma[None, :], w)
    return out[:M, :N]
