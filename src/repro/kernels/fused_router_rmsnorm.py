"""Fused router + RMSNorm-statistics Pallas kernel (paper Alg. 1 ll. 4–7).

``router_stats``: one pass over each activation tile produces BOTH the
router logits (X·W_θ) and the RMSNorm reduction (Σx²).  The router weight
is lane-padded to 128 columns so the matmul is MXU-shaped; the caller
slices the 2 real logits.

The matching *elementwise* phase (Alg. 1 ll. 11–15 — normalization applied
inside the k-loop of the following projection) lives in
``repro/kernels/fused_linear.py``, which subsumed the old standalone
``rmsnorm_matmul`` kernel and extends it to the int4-BFP weight path and
the SwiGLU/residual epilogues.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANE = 128
DEFAULT_BT = 256
DEFAULT_BD = 512


# ---------------------------------------------------------------------------
# Kernel 1: router logits + Σx² in one pass
# ---------------------------------------------------------------------------

def _router_stats_kernel(x_ref, w_ref, logit_ref, sq_ref,
                         logit_scr, sq_scr, *, d_total: int):
    j = pl.program_id(1)
    nd = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        logit_scr[...] = jnp.zeros_like(logit_scr)
        sq_scr[...] = jnp.zeros_like(sq_scr)

    x = x_ref[...].astype(jnp.float32)                    # [bt, bd]
    w = w_ref[...].astype(jnp.float32)                    # [bd, LANE]
    logit_scr[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    sq_scr[...] += (x * x).sum(axis=-1, keepdims=True)

    @pl.when(j == nd - 1)
    def _fin():
        logit_ref[...] = logit_scr[...]
        sq_ref[...] = sq_scr[...] / d_total               # mean square


def router_stats_pallas(x: jnp.ndarray, w: jnp.ndarray, *,
                        bt: int = DEFAULT_BT, bd: int = DEFAULT_BD,
                        interpret: bool = False):
    """x: [T, D]; w: [D, 2] -> (logits [T, 2] f32, mean_sq [T] f32)."""
    T, D = x.shape
    wp = jnp.zeros((D, LANE), jnp.float32).at[:, :2].set(
        w.astype(jnp.float32))
    bt = min(bt, T)
    bd = min(bd, D)
    Tp = -(-T // bt) * bt
    Dp = -(-D // bd) * bd
    if Tp != T:
        x = jnp.pad(x, ((0, Tp - T), (0, 0)))
    if Dp != D:
        x = jnp.pad(x, ((0, 0), (0, Dp - D)))
        wp = jnp.pad(wp, ((0, Dp - D), (0, 0)))

    grid = (Tp // bt, Dp // bd)
    logits, sq = pl.pallas_call(
        functools.partial(_router_stats_kernel, d_total=D),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, bd), lambda i, j: (i, j)),
            pl.BlockSpec((bd, LANE), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bt, LANE), lambda i, j: (i, 0)),
            pl.BlockSpec((bt, 1), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Tp, LANE), jnp.float32),
            jax.ShapeDtypeStruct((Tp, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bt, LANE), jnp.float32),
            pltpu.VMEM((bt, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x, wp)
    return logits[:T, :2], sq[:T, 0]
