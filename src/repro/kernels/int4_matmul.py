"""Mixed-precision matmul Pallas kernel: bf16 activations × int4 weights
with Block-Floating-Point fixed-point accumulation (paper §4.2).

Per (M-tile row, K-group):
  1. the activation tile is converted to BFP — a shared power-of-2 exponent
     per row plus int8 mantissas (the paper's FP→BFP conversion);
  2. int8 × int4 products accumulate in **int32** (the fixed-point
     accumulation tree; on TPU this is the MXU's native int8 path — the
     throughput analogue of DSP overpacking, see DESIGN.md);
  3. one floating-point reconstruction per (row, group):
     acc_fp += acc_int · 2^(e_row - MBITS) · w_scale[group].

Weight codes are stored as int8 in [-8, 7] (int4 value domain); the dry-run
byte accounting treats them at 4 bits (DESIGN.md).  Scales are powers of 2
when cfg.quant.pow2_scales so step 3 is exponent arithmetic only.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

MBITS = 7          # int8 mantissa: values in [-128, 127], scale 2^7
DEFAULT_BM = 128
DEFAULT_BN = 128


def _bfp_quantize_rows(x: jnp.ndarray):
    """x: [bm, G] fp32 -> (mant int8 [bm, G], exp fp32 [bm, 1] = 2^e)."""
    amax = jnp.abs(x).max(axis=-1, keepdims=True)
    e = jnp.ceil(jnp.log2(jnp.maximum(amax, 1e-30)))
    e = jnp.where(amax == 0, 0.0, e)
    pe = jnp.exp2(e)
    mant = jnp.clip(jnp.round(x * (2.0 ** MBITS) / pe), -128, 127)
    return mant.astype(jnp.int8), pe


def _int4_kernel(x_ref, w_ref, s_ref, o_ref, acc_scr, *, out_dtype):
    k = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(k == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    x = x_ref[...].astype(jnp.float32)                    # [bm, G]
    mant, pe = _bfp_quantize_rows(x)
    w = w_ref[...]                                        # [G, bn] int8 codes
    prod = jax.lax.dot_general(
        mant.astype(jnp.int32), w.astype(jnp.int32),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)                 # fixed-point acc
    scale = s_ref[...]                                    # [1, bn]
    acc_scr[...] += (prod.astype(jnp.float32)
                     * (pe * (2.0 ** -MBITS))             # [bm, 1]
                     * scale)                             # [1, bn]

    @pl.when(k == nk - 1)
    def _fin():
        o_ref[...] = acc_scr[...].astype(out_dtype)


def int4_matmul_pallas(x: jnp.ndarray, w_codes: jnp.ndarray,
                       scale: jnp.ndarray, *, bm: int = DEFAULT_BM,
                       bn: int = DEFAULT_BN,
                       interpret: bool = False) -> jnp.ndarray:
    """x: [M, K] (bf16/f32); w_codes: [K, N] int8 codes in [-8, 7];
    scale: [K/G, N] fp32.  Returns [M, N] in x.dtype."""
    M, K = x.shape
    Kw, N = w_codes.shape
    assert K == Kw
    G = K // scale.shape[0]
    assert K % G == 0
    bm = min(bm, M)
    bn = min(bn, N)
    Mp = -(-M // bm) * bm
    Np = -(-N // bn) * bn
    if Mp != M:
        x = jnp.pad(x, ((0, Mp - M), (0, 0)))
    if Np != N:
        w_codes = jnp.pad(w_codes, ((0, 0), (0, Np - N)))
        scale = jnp.pad(scale, ((0, 0), (0, Np - N)))

    grid = (Mp // bm, Np // bn, K // G)
    out = pl.pallas_call(
        functools.partial(_int4_kernel, out_dtype=x.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, G), lambda i, j, k: (i, k)),
            pl.BlockSpec((G, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w_codes, scale)
    return out[:M, :N]
