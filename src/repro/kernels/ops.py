"""Public jit'd wrappers around the Pallas kernels.

Handles layout packing (GQA head packing), padding, backend dispatch
(interpret=True off-TPU so CPU tests execute the kernel bodies), and the
pure-jnp fallbacks used by the dry-run lowering.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_packed
from repro.kernels.fused_linear import fused_linear_pallas
from repro.kernels.fused_router_rmsnorm import router_stats_pallas
from repro.kernels.int4_matmul import int4_matmul_pallas
from repro.kernels.paged_attention import paged_attention_packed


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def _pack_heads(q, k, v, q_positions, kv_valid_len):
    B, Tq, Hq, dh = q.shape
    Tk, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    # q rows pack (G, Tq): every KV tile is reused by all G grouped q-heads.
    qp = (q.reshape(B, Tq, Hkv, G, dh)
          .transpose(0, 2, 3, 1, 4)
          .reshape(B * Hkv, G * Tq, dh))
    kp = k.transpose(0, 2, 1, 3).reshape(B * Hkv, Tk, dh)
    vp = v.transpose(0, 2, 1, 3).reshape(B * Hkv, Tk, dh)
    pos = jnp.broadcast_to(q_positions[:, None, None, :],
                           (B, Hkv, G, Tq)).reshape(B * Hkv, G * Tq)
    if kv_valid_len is None:
        kv_len = jnp.full((B * Hkv, 1), Tk, jnp.int32)
    else:
        kv_len = jnp.broadcast_to(kv_valid_len[:, None, None],
                                  (B, Hkv, 1)).reshape(B * Hkv, 1)
    return qp, kp, vp, pos, kv_len, (B, Tq, Hq, Hkv, G, dh)


def flash_attention(q, k, v, *, q_positions, causal: bool = True,
                    window: int = 0, kv_valid_len=None,
                    softmax_scale: Optional[float] = None) -> jnp.ndarray:
    """q: [B,Tq,Hq,dh]; k/v: [B,Tk,Hkv,dh] -> [B,Tq,Hq,dh]."""
    scale = softmax_scale if softmax_scale is not None \
        else 1.0 / math.sqrt(q.shape[-1])
    qp, kp, vp, pos, kv_len, meta = _pack_heads(
        q, k, v, q_positions, kv_valid_len)
    B, Tq, Hq, Hkv, G, dh = meta
    out = flash_attention_packed(qp, kp, vp, pos, kv_len, causal=causal,
                                 window=window, scale=scale,
                                 interpret=_interpret())
    return (out.reshape(B, Hkv, G, Tq, dh)
            .transpose(0, 3, 1, 2, 4)
            .reshape(B, Tq, Hq, dh))


def decode_attention(q, k, v, *, q_positions, window: int = 0,
                     kv_valid_len=None,
                     softmax_scale: Optional[float] = None) -> jnp.ndarray:
    """Single-token decode: q [B,1,Hq,dh] against a [B,Tk,Hkv,dh] cache.
    The packed layout makes this flash-decoding: the G grouped q-heads are
    the rows, the KV length is the reduction."""
    return flash_attention(q, k, v, q_positions=q_positions, causal=True,
                           window=window, kv_valid_len=kv_valid_len,
                           softmax_scale=softmax_scale)


def paged_decode_attention(q, k_pages, v_pages, block_table, eff_pos,
                           k_tok, v_tok, *, q_positions,
                           softmax_scale: Optional[float] = None,
                           k_scales=None, v_scales=None, kv_dtype=None
                           ) -> jnp.ndarray:
    """Single-token decode against the paged KV store.

    The kernel walks each slot's block table (physical pages resolved via
    scalar prefetch) with history-buffer masking by effective position and
    returns raw online-softmax state; the in-flight token's KV — committed
    to the store only at end-of-step — is folded in here with one more
    online-softmax update.

    q: [B, 1, Hq, dh]; k/v pages: [P, ps, Hkv, dh] (int8 codes when
    ``kv_dtype`` is set, with ``k_scales``/``v_scales`` [P, ps, Hkv]);
    block_table: [B, J]; eff_pos: [B, J·ps]; k_tok/v_tok: [B, 1, Hkv, dh]
    (always full precision — in-flight KV is quantized only at commit);
    q_positions: [B, 1].
    """
    B, _, Hq, dh = q.shape
    P, ps, Hkv, _ = k_pages.shape
    J = block_table.shape[1]
    G = Hq // Hkv
    scale = softmax_scale if softmax_scale is not None \
        else 1.0 / math.sqrt(dh)

    qp = (q.reshape(B, 1, Hkv, G, dh)
          .transpose(0, 2, 3, 1, 4)
          .reshape(B * Hkv, G, dh))
    pos = jnp.broadcast_to(q_positions[:, None, :],
                           (B, Hkv, G)).reshape(B * Hkv, G)
    acc, m, l = paged_attention_packed(
        qp, k_pages, v_pages, block_table.astype(jnp.int32),
        eff_pos.reshape(B, J, ps), pos.astype(jnp.int32),
        scale=scale, interpret=_interpret(),
        k_scales=k_scales, v_scales=v_scales, kv_dtype=kv_dtype)

    # fold in the current token (always causally valid: key pos == q pos)
    kt = k_tok.reshape(B, Hkv, dh)
    kt = jnp.broadcast_to(kt[:, :, None], (B, Hkv, G, dh)).reshape(
        B * Hkv, G, dh)
    vt = v_tok.astype(jnp.float32).reshape(B, Hkv, dh)
    vt = jnp.broadcast_to(vt[:, :, None], (B, Hkv, G, dh)).reshape(
        B * Hkv, G, dh)
    s_tok = jnp.einsum("bgd,bgd->bg", qp.astype(jnp.float32) * scale,
                       kt.astype(jnp.float32))
    m2 = jnp.maximum(m, s_tok)
    alpha = jnp.exp(m - m2)
    p_tok = jnp.exp(s_tok - m2)
    l2 = l * alpha + p_tok
    out = (acc * alpha[..., None] + p_tok[..., None] * vt) \
        / jnp.maximum(l2, 1e-20)[..., None]
    return (out.reshape(B, Hkv, G, dh)
            .reshape(B, 1, Hq, dh).astype(q.dtype))


# ---------------------------------------------------------------------------
# int4 matmul (BFP accumulation)
# ---------------------------------------------------------------------------

def int4_matmul(x: jnp.ndarray, w_codes: jnp.ndarray, scale: jnp.ndarray,
                use_kernel: bool = False) -> jnp.ndarray:
    """x: [..., K] × int4-coded [Kw, N] -> [..., N].

    ``Kw >= K`` covers group-padded quantized weights (quantize_rtn pads
    the final group with zero codes when K is not a group multiple); the
    activation is zero-padded to match."""
    lead = x.shape[:-1]
    K = x.shape[-1]
    Kw, N = w_codes.shape
    x2 = x.reshape(-1, K)
    if Kw != K:
        x2 = jnp.pad(x2, ((0, 0), (0, Kw - K)))
    if use_kernel:
        out = int4_matmul_pallas(x2, w_codes, scale, interpret=_interpret())
    else:
        # jnp fallback: dequantize-and-matmul; XLA keeps the int8 weight
        # feed (weight HBM bytes = 1/2 of bf16; accounted at 4-bit in the
        # roofline, DESIGN.md).
        G = Kw // scale.shape[0]
        w = (w_codes.astype(x.dtype).reshape(Kw // G, G, N)
             * scale[:, None, :].astype(x.dtype)).reshape(Kw, N)
        out = x2 @ w
    return out.reshape(*lead, N)


# ---------------------------------------------------------------------------
# Fused router + RMSNorm statistics
# ---------------------------------------------------------------------------

def ssd_scan(xh, dt, A_log, Bm, Cm, chunk: int) -> jnp.ndarray:
    """Mamba-2 SSD chunk scan (state carried in VMEM across chunks)."""
    from repro.kernels.ssd_scan import ssd_scan_pallas
    return ssd_scan_pallas(xh, dt, A_log, Bm, Cm, chunk,
                           interpret=_interpret())


def fused_router_rmsnorm_stats(x: jnp.ndarray, w: jnp.ndarray,
                               b: jnp.ndarray):
    """x: [B, T, D] -> (router logits [B, T, 2] f32, mean_sq [B, T] f32)."""
    B, T, D = x.shape
    logits, ms = router_stats_pallas(x.reshape(B * T, D), w,
                                     interpret=_interpret())
    return logits.reshape(B, T, 2) + b, ms.reshape(B, T)


def fused_linear(params, x: jnp.ndarray, *, mean_sq=None, gamma=None,
                 eps: float = 1e-5, glu: bool = False, act=None,
                 residual=None, gate_mul=None, emit_sq: bool = False,
                 use_kernel: bool = True):
    """Fused linear pipeline over a (possibly quantized) linear param dict.

    x: [..., K]; params: {"w"} (dense) or {"w_int", "scale"} (int4-BFP).
    ``mean_sq`` [...] + ``gamma`` [K] fuse the RMSNorm elementwise phase
    into the k-loop (Alg. 1 ll. 11–15); ``glu``/``act`` apply the
    SwiGLU/GeGLU epilogue over a widened [gate|up] weight; ``gate_mul``
    [...] and ``residual`` [..., F] fuse the routed-residual write; with
    ``emit_sq`` the second return is Σy² per row (f32) — the next block's
    norm reduction (incremental-reduction carry).  Returns (out, sq|None).
    """
    lead = x.shape[:-1]
    K = x.shape[-1]
    x2 = x.reshape(-1, K)
    kw = dict(
        mean_sq=None if mean_sq is None else mean_sq.reshape(-1),
        gamma=gamma, eps=eps, glu=glu, act=act,
        residual=None if residual is None
        else residual.reshape(-1, residual.shape[-1]),
        gate_mul=None if gate_mul is None else gate_mul.reshape(-1),
        emit_sq=emit_sq)
    if "w_int" in params:
        args = dict(w_codes=params["w_int"], scale=params["scale"])
    else:
        args = dict(w=params["w"])
    if use_kernel:
        out, sq = fused_linear_pallas(x2, **args, **kw,
                                      interpret=_interpret())
    else:
        out, sq = ref.fused_linear_ref(x2, **args, **kw)
    F = out.shape[-1]
    out = out.reshape(*lead, F)
    return out, (None if sq is None else sq.reshape(*lead))
