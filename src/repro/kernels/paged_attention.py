"""Paged decode-attention Pallas kernel (TPU target; interpret=True on CPU).

Decode attention against the paged KV store (``repro/kvcache/paged.py``):
the grid's inner dimension walks one slot's *block table* — each step's KV
tile is a physical page, resolved through the scalar-prefetched table in
the BlockSpec index_map (the history-buffer indirection: the same physical
page can appear in several layers' walks).  Masking is by *effective
position* (``repro/kvcache/history.py``): entries invalid at the querying
layer carry a sentinel position the causal test can never admit, so the
pruned-token history is skipped without any per-entry gather.

Online-softmax machinery (running max ``m``, running Σexp ``l`` in VMEM
scratch) is the same dataflow as ``kernels/flash_attention.py``; this
kernel returns the *raw* (acc, m, l) triple so the caller can fold in the
current token's in-flight KV (which is only committed to the store at the
end of the decode step) with one more online-softmax update.

Layouts: q [BH, R, dh] where BH = B·Hkv and R packs the G = Hq/Hkv grouped
query heads; k/v pages [P, ps, Hkv, dh]; block_table int32 [B, J];
eff_pos int32 [B, J, ps]; q_pos int32 [BH, R].
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1.0e30


def _paged_kernel(bt_ref, qpos_ref, effpos_ref, q_ref, k_ref, v_ref,
                  o_ref, m_ref, l_ref, m_scr, l_scr, acc_scr, *,
                  scale: float):
    j = pl.program_id(1)
    nj = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32) * scale              # [R, dh]
    k = k_ref[0, :, 0].astype(jnp.float32)                # [ps, dh]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [R, ps]

    kv_pos = effpos_ref[0, 0][None, :]                    # [1, ps]
    mask = kv_pos <= qpos_ref[0][:, None]                 # [R, ps]
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                                   # [R, 1]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * alpha + p.sum(axis=-1, keepdims=True)
    v = v_ref[0, :, 0].astype(jnp.float32)                # [ps, dh]
    pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_scr[...] = acc_scr[...] * alpha + pv
    m_scr[...] = m_new

    @pl.when(j == nj - 1)
    def _finalize():
        # raw triple — the caller merges the in-flight token and divides
        o_ref[0] = acc_scr[...]
        m_ref[0] = m_scr[..., 0]
        l_ref[0] = l_scr[..., 0]


def paged_attention_packed(q: jnp.ndarray, k_pages: jnp.ndarray,
                           v_pages: jnp.ndarray, block_table: jnp.ndarray,
                           eff_pos: jnp.ndarray, q_pos: jnp.ndarray, *,
                           scale: float, interpret: bool = False):
    """q: [BH, R, dh]; k/v pages: [P, ps, Hkv, dh]; block_table: [B, J];
    eff_pos: [B, J, ps]; q_pos: [BH, R] (-1 = padded row).

    Returns the unnormalized online-softmax state over the paged history:
    (acc [BH, R, dh] f32, m [BH, R] f32, l [BH, R] f32)."""
    BH, R, dh = q.shape
    P, ps, Hkv, _ = k_pages.shape
    B, J = block_table.shape
    assert BH == B * Hkv, (BH, B, Hkv)

    Rp = max(8, R)                       # sublane-friendly row count
    if Rp != R:
        q = jnp.pad(q, ((0, 0), (0, Rp - R), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, Rp - R)), constant_values=-1)

    grid = (BH, J)
    kernel = functools.partial(_paged_kernel, scale=scale)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Rp), lambda b, j, bt: (b, 0)),          # q_pos
            pl.BlockSpec((1, 1, ps),
                         lambda b, j, bt: (b // Hkv, j, 0)),         # eff_pos
            pl.BlockSpec((1, Rp, dh), lambda b, j, bt: (b, 0, 0)),   # q
            pl.BlockSpec((1, ps, 1, dh),
                         lambda b, j, bt: (bt[b // Hkv, j], 0,
                                           b % Hkv, 0)),             # k page
            pl.BlockSpec((1, ps, 1, dh),
                         lambda b, j, bt: (bt[b // Hkv, j], 0,
                                           b % Hkv, 0)),             # v page
        ],
        out_specs=[
            pl.BlockSpec((1, Rp, dh), lambda b, j, bt: (b, 0, 0)),
            pl.BlockSpec((1, Rp), lambda b, j, bt: (b, 0)),
            pl.BlockSpec((1, Rp), lambda b, j, bt: (b, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((Rp, 1), jnp.float32),    # m
            pltpu.VMEM((Rp, 1), jnp.float32),    # l
            pltpu.VMEM((Rp, dh), jnp.float32),   # acc
        ],
    )
    acc, m, l = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((BH, Rp, dh), jnp.float32),
            jax.ShapeDtypeStruct((BH, Rp), jnp.float32),
            jax.ShapeDtypeStruct((BH, Rp), jnp.float32),
        ],
        interpret=interpret,
    )(block_table, q_pos, eff_pos, q, k_pages, v_pages)
    return acc[:, :R], m[:, :R], l[:, :R]
