"""Paged decode-attention Pallas kernel (TPU target; interpret=True on CPU).

Decode attention against the paged KV store (``repro/kvcache/paged.py``):
the grid's inner dimension walks one slot's *block table* — each step's KV
tile is a physical page, resolved through the scalar-prefetched table in
the BlockSpec index_map (the history-buffer indirection: the same physical
page can appear in several layers' walks).  Masking is by *effective
position* (``repro/kvcache/history.py``): entries invalid at the querying
layer carry a sentinel position the causal test can never admit, so the
pruned-token history is skipped without any per-entry gather.

Online-softmax machinery (running max ``m``, running Σexp ``l`` in VMEM
scratch) is the same dataflow as ``kernels/flash_attention.py``; this
kernel returns the *raw* (acc, m, l) triple so the caller can fold in the
current token's in-flight KV (which is only committed to the store at the
end of the decode step) with one more online-softmax update.

Layouts: q [BH, R, dh] where BH = B·Hkv and R packs the G = Hq/Hkv grouped
query heads; k/v pages [P, ps, Hkv, dh]; block_table int32 [B, J];
eff_pos int32 [B, J, ps]; q_pos int32 [BH, R].
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1.0e30


def _page_dequant(codes, scale, kv_dtype):
    """codes [ps, dhp] int8 + scale [ps] -> f32 [ps, dh].  int4 payloads
    pack dims d (low nibble) and d + dh//2 (high nibble) into byte d, so
    the unpack is a concat along the head dim (kvcache/paged.py)."""
    if kv_dtype == "int4":
        c = codes.astype(jnp.int32)
        lo = (c << 28) >> 28                  # arithmetic shifts sign-extend
        hi = (c << 24) >> 28
        codes = jnp.concatenate([lo, hi], axis=-1)
    return codes.astype(jnp.float32) * scale[:, None]


def _paged_kernel(bt_ref, qpos_ref, effpos_ref, q_ref, k_ref, v_ref,
                  *rest, scale: float, kv_dtype=None):
    if kv_dtype is None:
        ks_ref = vs_ref = None
        o_ref, m_ref, l_ref, m_scr, l_scr, acc_scr = rest
    else:
        (ks_ref, vs_ref, o_ref, m_ref, l_ref,
         m_scr, l_scr, acc_scr) = rest
    j = pl.program_id(1)
    nj = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32) * scale              # [R, dh]
    k = k_ref[0, :, 0]                                    # [ps, dh(p)]
    if kv_dtype is None:
        k = k.astype(jnp.float32)
    else:
        k = _page_dequant(k, ks_ref[0, :, 0], kv_dtype)   # in-walk dequant
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [R, ps]

    kv_pos = effpos_ref[0, 0][None, :]                    # [1, ps]
    mask = kv_pos <= qpos_ref[0][:, None]                 # [R, ps]
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                                   # [R, 1]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * alpha + p.sum(axis=-1, keepdims=True)
    v = v_ref[0, :, 0]                                    # [ps, dh(p)]
    if kv_dtype is None:
        v = v.astype(jnp.float32)
    else:
        v = _page_dequant(v, vs_ref[0, :, 0], kv_dtype)
    pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_scr[...] = acc_scr[...] * alpha + pv
    m_scr[...] = m_new

    @pl.when(j == nj - 1)
    def _finalize():
        # raw triple — the caller merges the in-flight token and divides
        o_ref[0] = acc_scr[...]
        m_ref[0] = m_scr[..., 0]
        l_ref[0] = l_scr[..., 0]


def paged_attention_packed(q: jnp.ndarray, k_pages: jnp.ndarray,
                           v_pages: jnp.ndarray, block_table: jnp.ndarray,
                           eff_pos: jnp.ndarray, q_pos: jnp.ndarray, *,
                           scale: float, interpret: bool = False,
                           k_scales=None, v_scales=None, kv_dtype=None):
    """q: [BH, R, dh]; k/v pages: [P, ps, Hkv, dh]; block_table: [B, J];
    eff_pos: [B, J, ps]; q_pos: [BH, R] (-1 = padded row).

    With a quantized store (``kv_dtype`` "int8"/"int4"), pages hold int8
    codes ([P, ps, Hkv, dh] or nibble-packed [P, ps, Hkv, dh//2]) and
    ``k_scales``/``v_scales`` [P, ps, Hkv] ride the same block-table
    index map — dequantization happens inside the page walk, so HBM
    traffic is the code bytes, never the f32 rows.

    Returns the unnormalized online-softmax state over the paged history:
    (acc [BH, R, dh] f32, m [BH, R] f32, l [BH, R] f32)."""
    BH, R, dh = q.shape
    P, ps, Hkv, dhp = k_pages.shape
    B, J = block_table.shape
    assert BH == B * Hkv, (BH, B, Hkv)
    assert (kv_dtype is None) == (k_scales is None), \
        "quantized pages need kv_dtype AND scales"

    Rp = max(8, R)                       # sublane-friendly row count
    if Rp != R:
        q = jnp.pad(q, ((0, 0), (0, Rp - R), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, Rp - R)), constant_values=-1)

    grid = (BH, J)
    kernel = functools.partial(_paged_kernel, scale=scale,
                               kv_dtype=kv_dtype)

    def page_spec(width):
        return pl.BlockSpec((1, ps, 1) + ((width,) if width else ()),
                            (lambda b, j, bt: (bt[b // Hkv, j], 0, b % Hkv, 0)
                             ) if width else
                            (lambda b, j, bt: (bt[b // Hkv, j], 0, b % Hkv)))

    in_specs = [
        pl.BlockSpec((1, Rp), lambda b, j, bt: (b, 0)),          # q_pos
        pl.BlockSpec((1, 1, ps),
                     lambda b, j, bt: (b // Hkv, j, 0)),         # eff_pos
        pl.BlockSpec((1, Rp, dh), lambda b, j, bt: (b, 0, 0)),   # q
        page_spec(dhp),                                          # k page
        page_spec(dhp),                                          # v page
    ]
    operands = [q_pos, eff_pos, q, k_pages, v_pages]
    if kv_dtype is not None:
        in_specs += [page_spec(0), page_spec(0)]                 # scales
        operands += [k_scales, v_scales]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, Rp, dh), lambda b, j, bt: (b, 0, 0)),
            pl.BlockSpec((1, Rp), lambda b, j, bt: (b, 0)),
            pl.BlockSpec((1, Rp), lambda b, j, bt: (b, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((Rp, 1), jnp.float32),    # m
            pltpu.VMEM((Rp, 1), jnp.float32),    # l
            pltpu.VMEM((Rp, dh), jnp.float32),   # acc
        ],
    )
    acc, m, l = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((BH, Rp, dh), jnp.float32),
            jax.ShapeDtypeStruct((BH, Rp), jnp.float32),
            jax.ShapeDtypeStruct((BH, Rp), jnp.float32),
        ],
        interpret=interpret,
    )(block_table, *operands)
    return acc[:, :R], m[:, :R], l[:, :R]
