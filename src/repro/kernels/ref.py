"""Pure-jnp oracles for every Pallas kernel (tests assert allclose against
these; benchmarks reuse them for the Table-1 accuracy reproduction)."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1.0e30
MBITS = 7


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                        q_positions: jnp.ndarray,
                        causal: bool = True, window: int = 0,
                        kv_valid_len: Optional[jnp.ndarray] = None,
                        softmax_scale: Optional[float] = None) -> jnp.ndarray:
    """Dense GQA attention oracle.  q: [B,Tq,Hq,dh]; k/v: [B,Tk,Hkv,dh]."""
    B, Tq, Hq, dh = q.shape
    Tk, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(dh)
    qg = q.reshape(B, Tq, Hkv, G, dh).astype(jnp.float32) * scale
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k.astype(jnp.float32))
    kv_pos = jnp.arange(Tk)
    mask = jnp.ones((B, Tq, Tk), bool)
    qp = q_positions[:, :, None]
    if causal:
        mask &= kv_pos[None, None, :] <= qp
    if window:
        mask &= kv_pos[None, None, :] > qp - window
    if kv_valid_len is not None:
        mask &= kv_pos[None, None, :] < kv_valid_len[:, None, None]
    s = jnp.where(mask[:, None, None, :, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    # rows with no valid key -> zero output (mirrors the kernel's guard)
    p = jnp.where(mask.any(-1)[:, None, None, :, None], p, 0.0)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(B, Tq, Hq, dh).astype(q.dtype)


def _dequant_pages_ref(pages: jnp.ndarray, scales: jnp.ndarray,
                       kv_dtype: str) -> jnp.ndarray:
    """Exact dequant of int8/int4 page payloads (mirrors
    ``kvcache.paged.dequantize_entries`` without importing it — the
    oracle stays self-contained)."""
    if kv_dtype == "int4":
        c = pages.astype(jnp.int32)
        pages = jnp.concatenate([(c << 28) >> 28, (c << 24) >> 28], axis=-1)
    return pages.astype(jnp.float32) * scales[..., None]


def paged_attention_ref(q: jnp.ndarray, k_pages: jnp.ndarray,
                        v_pages: jnp.ndarray, block_table: jnp.ndarray,
                        eff_pos: jnp.ndarray, k_tok: jnp.ndarray,
                        v_tok: jnp.ndarray, *, q_positions: jnp.ndarray,
                        softmax_scale: Optional[float] = None,
                        k_scales=None, v_scales=None,
                        kv_dtype=None) -> jnp.ndarray:
    """Paged decode-attention oracle: dense gather of each slot's page
    chain + the in-flight token, masked by effective position.

    q: [B, 1, Hq, dh]; k/v pages: [P, ps, Hkv, dh]; block_table: [B, J];
    eff_pos: [B, J·ps] (history-buffer validity, MASKED = int32 max);
    k_tok/v_tok: [B, 1, Hkv, dh]; q_positions: [B, 1].  With ``kv_dtype``
    set, pages are int8/int4 codes and ``k_scales``/``v_scales``
    [P, ps, Hkv] dequantize them up front (the whole-pool dequant the
    kernel's in-walk dequant must match)."""
    B, _, Hq, dh = q.shape
    P, ps, Hkv, _ = k_pages.shape
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(dh)
    G = Hq // Hkv
    if kv_dtype is not None:
        k_pages = _dequant_pages_ref(k_pages, k_scales, kv_dtype)
        v_pages = _dequant_pages_ref(v_pages, v_scales, kv_dtype)

    def chain(pages):
        flat = pages[block_table.reshape(-1)]            # [B·J, ps, Hkv, dh]
        return flat.reshape(B, -1, Hkv, dh)

    k = jnp.concatenate([chain(k_pages), k_tok.astype(k_pages.dtype)], 1)
    v = jnp.concatenate([chain(v_pages), v_tok.astype(v_pages.dtype)], 1)
    pos = jnp.concatenate(
        [eff_pos, q_positions.astype(jnp.int32)], axis=1)  # [B, E+1]
    qg = q.reshape(B, 1, Hkv, G, dh).astype(jnp.float32) * scale
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k.astype(jnp.float32))
    mask = pos[:, None, :] <= q_positions[..., None]       # [B, 1, E+1]
    s = jnp.where(mask[:, None, None, :, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(mask.any(-1)[:, None, None, :, None], p, 0.0)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(B, 1, Hq, dh).astype(q.dtype)


# ---------------------------------------------------------------------------
# int4 × bf16 matmul
# ---------------------------------------------------------------------------

def int4_matmul_ref(x: jnp.ndarray, w_codes: jnp.ndarray,
                    scale: jnp.ndarray) -> jnp.ndarray:
    """Exact-dequant fp32 oracle (the accuracy target)."""
    K, N = w_codes.shape
    G = K // scale.shape[0]
    w = (w_codes.astype(jnp.float32).reshape(K // G, G, N)
         * scale[:, None, :]).reshape(K, N)
    return (x.astype(jnp.float32) @ w).astype(x.dtype)


def _bfp_matmul_f32(xf: jnp.ndarray, w_codes: jnp.ndarray,
                    scale: jnp.ndarray) -> jnp.ndarray:
    """fp32-in/fp32-out emulation of the BFP fixed-point accumulation
    (shared per-row-per-group exponent, int8 mantissas, int32 accumulate,
    one FP reconstruction per group).  ``w_codes`` may be group-padded
    (Kw >= K, trailing rows zero); xf is zero-padded to match."""
    M, K = xf.shape
    Kw, N = w_codes.shape
    G = Kw // scale.shape[0]
    if Kw != K:
        xf = jnp.pad(xf, ((0, 0), (0, Kw - K)))
    xg = xf.reshape(M, Kw // G, G)
    amax = jnp.abs(xg).max(axis=-1, keepdims=True)
    e = jnp.ceil(jnp.log2(jnp.maximum(amax, 1e-30)))
    e = jnp.where(amax == 0, 0.0, e)
    pe = jnp.exp2(e)                                       # [M, K/G, 1]
    mant = jnp.clip(jnp.round(xg * (2.0 ** MBITS) / pe), -128, 127)
    wg = w_codes.reshape(Kw // G, G, N).astype(jnp.int32)
    prod = jnp.einsum("mcg,cgn->mcn", mant.astype(jnp.int32), wg)  # int32
    recon = (prod.astype(jnp.float32) * pe * (2.0 ** -MBITS)
             * scale[None, :, :])                          # [M, K/G, N]
    return recon.sum(axis=1)


def bfp_matmul_ref(x: jnp.ndarray, w_codes: jnp.ndarray,
                   scale: jnp.ndarray) -> jnp.ndarray:
    """Bit-accurate BFP oracle in the input dtype (the kernel must match
    this closely)."""
    return _bfp_matmul_f32(x.astype(jnp.float32), w_codes,
                           scale).astype(x.dtype)


# ---------------------------------------------------------------------------
# Fused router + RMSNorm stats
# ---------------------------------------------------------------------------

def router_stats_ref(x: jnp.ndarray, w: jnp.ndarray):
    """x: [T, D]; w: [D, 2] -> (logits f32 [T, 2], mean_sq f32 [T])."""
    xf = x.astype(jnp.float32)
    return xf @ w.astype(jnp.float32), (xf * xf).mean(axis=-1)


# ---------------------------------------------------------------------------
# Fused linear pipeline (norm prologue × {dense, int4-BFP} × epilogue)
# ---------------------------------------------------------------------------

def fused_linear_ref(x, w=None, w_codes=None, scale=None, *, mean_sq=None,
                     gamma=None, eps: float = 1e-5, glu: bool = False,
                     act=None, residual=None, gate_mul=None,
                     emit_sq: bool = False):
    """Oracle for ``fused_linear_pallas``: same arithmetic pipeline in
    plain jnp — RMSNorm elementwise phase from injected ``mean_sq``, the
    matmul (exact fp32 for dense weights, the bit-level BFP emulation for
    int4 codes), GLU / activation epilogue, gate multiplier, residual add
    and the Σy² reduction of the written rows."""
    from repro.kernels.fused_linear import _act

    xf = x.astype(jnp.float32)
    if mean_sq is not None:
        xf = xf * jax.lax.rsqrt(mean_sq[:, None] + eps) \
                * gamma.astype(jnp.float32)
    if w_codes is not None:
        y = _bfp_matmul_f32(xf, w_codes, scale)
    else:
        y = xf @ w.astype(jnp.float32)

    if glu:
        F = y.shape[-1] // 2
        y = _act(y[:, :F], act) * y[:, F:]
    else:
        y = _act(y, act)
    if gate_mul is not None:
        y = y * gate_mul.astype(jnp.float32)[:, None]
    if residual is not None:
        y = y + residual.astype(jnp.float32)
    sq = (y * y).sum(axis=-1) if emit_sq else None
    return y.astype(x.dtype), sq
