"""Mamba-2 SSD chunk-scan Pallas kernel (TPU target; interpret on CPU).

One grid cell = one (batch·head, chunk).  The SSD state [N, P] lives in
VMEM scratch and carries across the chunk dimension (innermost grid axis),
so the recurrence never round-trips HBM — the NPE-style latency-hiding
dataflow applied to the state-space recurrence (DESIGN.md).

Within-chunk cumulative sums are computed as lower-triangular matmuls
(MXU-friendly; Mosaic has no native scan), exactly the formulation of the
SSD paper's hardware-efficient algorithm:

  cum      = L @ dA                      (L = strictly-lower+diag ones)
  y_intra  = ((C Bᵀ) ⊙ seg(cum)) @ (dt·x)
  y_inter  = (C @ state) ⊙ exp(cum)
  state'   = state·exp(cum_Q) + (B ⊙ w)ᵀ @ (dt·x)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_BIG = -1.0e30


def _ssd_kernel(alog_ref, x_ref, dt_ref, b_ref, c_ref, y_ref, state_scr,
                *, Q: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    a = -jnp.exp(alog_ref[0, 0])                       # scalar A < 0
    x = x_ref[0, 0].astype(jnp.float32)                # [Q, P]
    dt = dt_ref[0, 0].astype(jnp.float32)              # [Q, 1]
    bm = b_ref[0, 0].astype(jnp.float32)               # [Q, N]
    cm = c_ref[0, 0].astype(jnp.float32)               # [Q, N]

    dA = dt * a                                        # [Q, 1]
    iota = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    iota_t = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    tril = (iota >= iota_t).astype(jnp.float32)        # inclusive lower tri
    cum = jax.lax.dot_general(tril, dA, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)  # [Q,1]

    seg = jnp.exp(cum - cum.T)                         # [Qi, Qj]
    seg = jnp.where(iota >= iota_t, seg, 0.0)
    scores = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32) * seg
    dtx = x * dt                                       # [Q, P]
    y = jax.lax.dot_general(scores, dtx, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    state = state_scr[...]                             # [N, P]
    y += jax.lax.dot_general(cm, state, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32) * jnp.exp(cum)
    w = jnp.exp(cum[-1:] - cum)                        # [Q, 1]
    s_local = jax.lax.dot_general(bm * w, dtx, (((0,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    state_scr[...] = state * jnp.exp(cum[-1, 0]) + s_local
    y_ref[0] = y.astype(y_ref.dtype)


def ssd_scan_pallas(xh: jnp.ndarray, dt: jnp.ndarray, A_log: jnp.ndarray,
                    Bm: jnp.ndarray, Cm: jnp.ndarray, chunk: int,
                    interpret: bool = False) -> jnp.ndarray:
    """xh [B,T,H,P], dt [B,T,H], A_log [H], Bm/Cm [B,T,H,N] -> y [B,T,H,P].

    (Final-state output is left to the jnp path; the kernel covers the
    throughput-critical full-sequence scan.)"""
    B, T, H, P = xh.shape
    N = Bm.shape[-1]
    Q = min(chunk, T)
    pad = (-T) % Q
    if pad:
        z = lambda a: jnp.pad(a, [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2))
        xh, dt, Bm, Cm = z(xh), z(dt), z(Bm), z(Cm)
    Tp = T + pad
    nc = Tp // Q
    BH = B * H

    def to_bh(a, feat):
        # [B, T, H, F] -> [BH, nc, Q, F]
        a = a.transpose(0, 2, 1, 3).reshape(BH, nc, Q, feat)
        return a

    xb = to_bh(xh, P)
    bb = to_bh(Bm, N)
    cb = to_bh(Cm, N)
    dtb = dt.transpose(0, 2, 1).reshape(BH, nc, Q, 1)
    alog = jnp.tile(A_log, B).reshape(BH, 1)

    out = pl.pallas_call(
        functools.partial(_ssd_kernel, Q=Q),
        grid=(BH, nc),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, c: (b, 0)),               # A_log
            pl.BlockSpec((1, 1, Q, P), lambda b, c: (b, c, 0, 0)),   # x
            pl.BlockSpec((1, 1, Q, 1), lambda b, c: (b, c, 0, 0)),   # dt
            pl.BlockSpec((1, 1, Q, N), lambda b, c: (b, c, 0, 0)),   # B
            pl.BlockSpec((1, 1, Q, N), lambda b, c: (b, c, 0, 0)),   # C
        ],
        out_specs=pl.BlockSpec((1, Q, P), lambda b, c: (b, c, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, nc * Q, P), jnp.float32),
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(alog, xb.reshape(BH, nc, Q, P), dtb, bb, cb)
    y = out.reshape(B, H, Tp, P).transpose(0, 2, 1, 3)[:, :T]
    return y
