from repro.kvcache.cache import CompactKVStore, DenseKVStore  # noqa: F401
from repro.kvcache.history import (HistoryAccounting,  # noqa: F401
                                   effective_positions, fresh_mask,
                                   next_fresh_layer)
from repro.kvcache.layout import TokenWiseLayout, transaction_model  # noqa: F401
from repro.kvcache.paged import (PageAllocator, PageStats,  # noqa: F401
                                 can_page, commit_decode, gather_view,
                                 init_store, pack_prefill)
