from repro.kvcache.cache import CompactKVStore, DenseKVStore  # noqa: F401
from repro.kvcache.layout import TokenWiseLayout, transaction_model  # noqa: F401
