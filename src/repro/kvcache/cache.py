"""KV-cache stores realizing the paper's §4.4 memory system.

``DenseKVStore``   — baseline: one [T, Hkv, dh] K/V pair *per layer*
                     (no storage savings; every layer's view materialized).
``CompactKVStore`` — the paper's design: per layer, only the KV entries of
                     *executed* tokens are stored (plus the dense layer-0
                     base), and ONE rolling dense view buffer serves
                     attention (the URAM invariance-buffer analogue).
                     Moving from layer l to l+1 scatters layer (l+1)'s
                     compact entries into the view — all other entries are
                     invariant (the paper's cross-layer KV invariance).

Storage accounting here backs the paper's "up to 25.4 % KV storage
reduction" claim (benchmarks/bench_kv_storage.py) and the serve engine's
traffic model.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class KVStats:
    dense_entries: int = 0       # what the baseline would store
    stored_entries: int = 0      # what we actually store
    view_entries: int = 0        # rolling view buffer size
    scattered_entries: int = 0   # view-update traffic (entries)

    @property
    def saved_fraction(self) -> float:
        if self.dense_entries == 0:
            return 0.0
        return 1.0 - self.stored_entries / self.dense_entries


class DenseKVStore:
    """Per-layer dense KV (the paper's baseline)."""

    def __init__(self, num_layers: int, heads: int, head_dim: int):
        self.L, self.H, self.D = num_layers, heads, head_dim
        self.k: List[List[np.ndarray]] = [[] for _ in range(num_layers)]
        self.v: List[List[np.ndarray]] = [[] for _ in range(num_layers)]
        self.stats = KVStats()

    def append(self, layer: int, k: np.ndarray, v: np.ndarray,
               executed: bool) -> None:
        # dense baseline stores every layer's entry regardless of routing
        self.k[layer].append(np.asarray(k))
        self.v[layer].append(np.asarray(v))
        self.stats.dense_entries += 1
        self.stats.stored_entries += 1

    def view(self, layer: int) -> Tuple[np.ndarray, np.ndarray]:
        return (np.stack(self.k[layer]), np.stack(self.v[layer]))


class CompactKVStore:
    """Compact per-layer store + rolling dense view (paper §4.4)."""

    def __init__(self, num_layers: int, heads: int, head_dim: int):
        self.L, self.H, self.D = num_layers, heads, head_dim
        # compact store: per layer, list of (token_idx, k, v)
        self.entries: List[Dict[int, Tuple[np.ndarray, np.ndarray]]] = \
            [dict() for _ in range(num_layers)]
        self._views_valid_layer: Optional[int] = None
        self._view_k: List[np.ndarray] = []
        self._view_v: List[np.ndarray] = []
        self.stats = KVStats()
        self._tokens = 0

    # -- write path (during decode of one token across layers) ------------
    def append(self, layer: int, k: np.ndarray, v: np.ndarray,
               executed: bool) -> None:
        """Called at each attention layer for the newly decoded token.
        Layer 0 is the dense base case; other layers store only when the
        token executed attention there (its KV is otherwise invariant —
        the paper's key observation)."""
        self.stats.dense_entries += 1
        tok = self._tokens
        if layer == 0:
            self.entries[0][tok] = (np.asarray(k), np.asarray(v))
            self.stats.stored_entries += 1
        elif executed:
            self.entries[layer][tok] = (np.asarray(k), np.asarray(v))
            self.stats.stored_entries += 1
        if layer == self.L - 1:
            self._tokens += 1

    # -- read path ---------------------------------------------------------
    def view(self, layer: int) -> Tuple[np.ndarray, np.ndarray]:
        """Dense [T, H, D] view for attention at ``layer``.

        Consecutive-layer access (the common case) updates the previous
        view by scattering only layer ``layer``'s compact entries — the
        invariance-buffer path.  Non-consecutive access rebuilds from
        layer 0 (the paper's Case-2: buffer invalidated)."""
        T = self._tokens
        if self._views_valid_layer is not None and \
                layer == self._views_valid_layer and \
                len(self._view_k) == T:
            pass                         # cached view is current
        elif self._views_valid_layer is not None and \
                layer == self._views_valid_layer + 1 and \
                len(self._view_k) == T:
            for tok, (k, v) in self.entries[layer].items():
                if tok < len(self._view_k):
                    self._view_k[tok] = k
                    self._view_v[tok] = v
                    self.stats.scattered_entries += 1
        else:
            self._view_k = [None] * T
            self._view_v = [None] * T
            for l in range(layer + 1):
                for tok, (k, v) in self.entries[l].items():
                    if tok < T:
                        self._view_k[tok] = k
                        self._view_v[tok] = v
                        self.stats.scattered_entries += 1
        self._views_valid_layer = layer
        self.stats.view_entries = max(self.stats.view_entries, T)
        if T == 0:
            z = np.zeros((0, self.H, self.D), np.float32)
            return z, z
        return (np.stack(self._view_k), np.stack(self._view_v))

    def extend_view_with(self, k: np.ndarray, v: np.ndarray
                         ) -> Tuple[np.ndarray, np.ndarray]:
        """View including the in-flight token (not yet committed).

        ``_views_valid_layer is None`` (no view ever built) is spelled out
        instead of the old ``or 0`` so the two states read differently;
        either way ``view()`` now rebuilds when its cached buffer is stale
        (fewer entries than committed tokens) rather than returning it."""
        if self._views_valid_layer is None:
            kk, vv = self.view(0)        # build the dense base from scratch
        else:
            kk, vv = self.view(self._views_valid_layer)
        return (np.concatenate([kk, k[None]], 0),
                np.concatenate([vv, v[None]], 0))
