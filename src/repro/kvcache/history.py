"""Cross-layer history-buffer indirection + hit accounting (paper §4.4.2).

The paged store (``repro/kvcache/paged.py``) keeps ONE physical entry per
(token, executed-layer) pair.  This module owns the *indirection* that
lets every attention layer read the right entry without an irregular
cross-layer gather:

* each entry's metadata is its token position ``pos`` and validity
  interval ``[l0, l1)`` over the attention-layer index — ``l0`` is the
  layer that wrote it (the token's execution), ``l1`` the token's next
  execution (or ``n_layers``: still current);
* attention at layer ``a`` turns metadata into *effective positions*:
  a valid entry keeps its token position (so the ordinary causal mask
  admits it), an invalid one is pushed to ``MASKED_POS`` (masked the same
  way padded KV already is).  Exactly one entry per token is valid at any
  layer, so masked attention over the full entry stream equals dense
  attention over per-layer caches.

Host-side ``HistoryAccounting`` measures the buffer's effect from the
execution-gate log: a *hit* is a (layer, token) read served by an entry
written at an earlier layer (the on-chip reuse that supplements HBM
bandwidth in the paper's Fig. 9); the aggregate hit rate equals the
compact store's storage-saved fraction by construction.
"""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp
import numpy as np

# Sentinel "position" for invalid entries: the causal mask (kv_pos <= q_pos)
# can never admit it.  Matches chunked_attention's padding sentinel.
MASKED_POS = np.iinfo(np.int32).max


def fresh_mask(gates: jnp.ndarray, reuse: bool) -> jnp.ndarray:
    """[nA, ...] execution gates -> bool mask of layers that write a fresh
    entry.  The first attention layer is the dense base (always fresh);
    with reuse disabled every layer writes."""
    g = jnp.asarray(gates).astype(bool)
    if not reuse:
        return jnp.ones_like(g)
    return g.at[0].set(True)


def next_fresh_layer(fresh: jnp.ndarray) -> jnp.ndarray:
    """For each (layer a, ...) the index of the next fresh layer > a, or
    ``nA`` when none (the entry stays current forever).  This is each
    written entry's ``l1``; rows where ``fresh`` is False are don't-care
    (their scatter is dropped)."""
    nA = fresh.shape[0]
    lead = jnp.arange(nA, dtype=jnp.int32).reshape(
        (nA,) + (1,) * (fresh.ndim - 1))
    idxs = jnp.where(fresh, lead, nA)
    # suffix minimum, exclusive of the current layer
    suffix = jax.lax.associative_scan(jnp.minimum, jnp.flip(idxs, 0), axis=0)
    suffix = jnp.flip(suffix, 0)
    return jnp.concatenate(
        [suffix[1:], jnp.full_like(idxs[:1], nA)], axis=0)


def effective_positions(pos: jnp.ndarray, l0: jnp.ndarray, l1: jnp.ndarray,
                        in_fill: jnp.ndarray, layer: jnp.ndarray
                        ) -> jnp.ndarray:
    """Entry metadata -> per-layer effective KV positions.

    pos/l0/l1/in_fill: [S, E] gathered entry metadata (logical order);
    ``layer``: scalar attention-layer index.  Valid entries keep their
    token position; everything else becomes MASKED_POS."""
    valid = in_fill & (l0 <= layer) & (layer < l1)
    return jnp.where(valid, pos, MASKED_POS).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Host-side hit accounting
# ---------------------------------------------------------------------------

def host_fresh_mask(gates: np.ndarray, reuse: bool) -> np.ndarray:
    """Numpy mirror of :func:`fresh_mask` for host-side bookkeeping:
    [nA, ...] gate log -> bool mask of (layer, token) entries the compact
    store physically writes."""
    g = np.asarray(gates, np.float32) > 0.5
    if not reuse:
        return np.ones_like(g)
    g[0] = True
    return g


def fresh_counts(gates: np.ndarray, valid_len: int, reuse: bool
                 ) -> np.ndarray:
    """[nA, T] prompt gate log -> per-layer fresh-entry counts over the
    first ``valid_len`` tokens.  The single host-side freshness
    definition shared by ``HistoryAccounting``, the paged prefill entry
    accounting (``paged.prefill_entry_count``) and warm-prefix admission
    (splitting a gate log at the shared-prefix boundary)."""
    return host_fresh_mask(gates, reuse)[:, :valid_len].sum(
        axis=1).astype(np.int64)


class HistoryAccounting:
    """Per-layer history-buffer hit rates, fed from the live gate log.

    For each decode step at layer ``a``, attention reads one entry per
    context token; the read *hits* the history buffer when that token's
    current entry was written at a layer < a (i.e. the token was pruned at
    ``a`` — cross-layer invariance serves it on-chip).  ``fresh_count``
    tracks, per slot and layer, how many context tokens are fresh at that
    layer, so hits = context − fresh without replaying old gates."""

    def __init__(self, n_layers: int, max_slots: int, reuse: bool = True):
        self.nA = n_layers
        self.reuse = reuse
        self._fresh = np.zeros((max_slots, n_layers), np.int64)
        self._ctx = np.zeros((max_slots,), np.int64)
        self.hits = np.zeros((n_layers,), np.int64)
        self.reads = np.zeros((n_layers,), np.int64)

    def _fresh_of(self, gates: np.ndarray) -> np.ndarray:
        return host_fresh_mask(gates, self.reuse)

    def on_prefill(self, slot: int, gates: np.ndarray, valid_len: int
                   ) -> None:
        """gates: [nA, T] prompt execution gates (may include padding)."""
        self._fresh[slot] = fresh_counts(gates, valid_len, self.reuse)
        self._ctx[slot] = valid_len
        # prefill attention at layer a reads a triangular number of
        # entries; count the final-state reads only (decode is the regime
        # the paper's buffer targets), i.e. start accounting at decode.

    def on_decode_step(self, slot: int, gates_col: np.ndarray) -> None:
        """gates_col: [nA] this step's gates for ``slot``.  Reads happen
        against the pre-step context; then the new token's entries join."""
        self.reads += self._ctx[slot]
        self.hits += self._ctx[slot] - self._fresh[slot]
        f = self._fresh_of(gates_col[:, None])[:, 0]
        self._fresh[slot] += f
        self._ctx[slot] += 1

    def on_release(self, slot: int) -> None:
        self._fresh[slot] = 0
        self._ctx[slot] = 0

    # -- results ------------------------------------------------------------
    @property
    def per_layer_hit_rate(self) -> List[float]:
        return [float(h / r) if r else 0.0
                for h, r in zip(self.hits, self.reads)]

    @property
    def hit_rate(self) -> float:
        r = int(self.reads.sum())
        return float(self.hits.sum() / r) if r else 0.0
