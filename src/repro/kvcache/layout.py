"""Token-wise KV memory-mapping + transaction model (paper §4.4.1, Fig. 9).

The FPGA maps each token's KV contiguously within one HBM pseudo-channel
and round-robins tokens across channels; reused (cross-layer) entries
fragment bursts under the conventional interleaved layout.  This module
models the three layouts' effective bandwidth the same way the paper's
Fig. 9 does, re-parameterized for the memory system at hand, and is used
by ``benchmarks/bench_bandwidth.py``.  On TPU the identical argument
applies one level up (tokens ↔ chips — see DESIGN.md), so the model is
labeled in generic "ports".
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence

import numpy as np


@dataclasses.dataclass
class TokenWiseLayout:
    num_ports: int = 16
    entry_bytes: int = 256 * 2            # one token-layer KV entry
    burst_bytes: int = 512                # AXI-equivalent burst granule
    page_miss_penalty: float = 2.5        # row-buffer thrash multiplier
    page_size: int = 16                   # entries per KV page (paged store)

    def port_of(self, token: int) -> int:
        return token % self.num_ports

    # ---------------------------------------------------------------------
    # All layouts are scored in the same unit: *rounds of burst time*, with
    # up to num_ports reads served per round when they map to distinct
    # ports.  The dense ideal is reads/num_ports rounds.
    def ideal_rounds(self, n_reads: int) -> float:
        bursts = -(-self.entry_bytes // self.burst_bytes)
        return n_reads / self.num_ports * bursts

    def interleaved_transactions(self, reads: Sequence[Dict]) -> float:
        """Layer-major interleave: fully port-parallel, but cross-layer
        reuse hops memory regions => row-buffer (page-miss) multiplier on
        the fraction of layer-discontinuous reads."""
        bursts = -(-self.entry_bytes // self.burst_bytes)
        last_layer: Dict[int, int] = {}
        penalized = 0
        for r in reads:
            p = self.port_of(r["token"])
            if p in last_layer and last_layer[p] != r["layer"]:
                penalized += 1
            last_layer[p] = r["layer"]
        n = len(reads)
        miss_frac = penalized / n if n else 0.0
        return (n / self.num_ports) * bursts * (
            1.0 + miss_frac * (self.page_miss_penalty - 1.0))

    def tokenwise_transactions(self, reads: Sequence[Dict]) -> float:
        """Token-major mapping: full bursts (no page misses), but concurrent
        reads hitting one port serialize — round width shrinks on
        conflicts (paper Fig. 6(b))."""
        bursts = -(-self.entry_bytes // self.burst_bytes)
        rounds = 0
        i = 0
        reads = list(reads)
        while i < len(reads):
            busy = set()
            while i < len(reads) and len(busy) < self.num_ports:
                p = self.port_of(reads[i]["token"])
                if p in busy:
                    break                      # port conflict ends the round
                busy.add(p)
                i += 1
            rounds += 1
        return rounds * bursts

    def invariance_buffer_transactions(self, reads: Sequence[Dict]
                                       ) -> float:
        """Paper design: reused entries served on-chip; HBM sees only the
        current layer's fresh entries — port-aligned by construction
        (round-robin over fresh tokens)."""
        bursts = -(-self.entry_bytes // self.burst_bytes)
        fresh = sum(1 for r in reads if r["fresh"])
        return (fresh / self.num_ports) * bursts

    # -- page-granular transactions (the paged entry-stream store) --------
    def _page_walk_rounds(self, n_entries: int,
                          page_size: int = 0) -> float:
        """One sequential walk of the compact entry stream: entries pack
        ``page_size`` per page, each page one full-burst chain in a single
        port, pages round-robined across ports (no row misses, no
        conflicts — the stream is append-ordered by construction)."""
        ps = page_size or self.page_size
        bursts_per_page = -(-ps * self.entry_bytes // self.burst_bytes)
        pages = -(-n_entries // ps)
        return (pages / self.num_ports) * bursts_per_page

    def paged_transactions(self, gates: "np.ndarray", page_size: int = 0,
                           on_chip_history: bool = True) -> float:
        """HBM transaction time for decoding against the paged store.

        gates: [L, T] execution mask.  The store holds one entry per
        (token, executed layer) — ``E = T + Σ_{l>0} gates[l]`` entries.
        Every layer's attention is a masked walk of the whole stream:
        without the on-chip history buffer HBM replays the walk L times
        (page-granular but L·E entry reads); with it the stream is read
        once and later layers hit on-chip."""
        L, T = gates.shape
        fresh = np.asarray(gates, np.float64).copy()
        fresh[0] = 1.0
        E = int(T + fresh[1:].sum())
        walk = self._page_walk_rounds(E, page_size)
        return walk if on_chip_history else L * walk


def transaction_model(gates: np.ndarray, layout: TokenWiseLayout
                      ) -> Dict[str, float]:
    """gates: [L, T] execution mask (1 = fresh KV at that layer).
    Returns normalized effective-bandwidth estimates for the three layouts
    (higher = better), mirroring Fig. 9's dense / interleaved / token-wise /
    +invariance-buffer comparison."""
    L, T = gates.shape
    reads: List[Dict] = []
    for l in range(L):
        # attention at layer l reads every token's most recent entry
        last_exec = np.zeros(T, dtype=int)
        for t in range(T):
            ex = np.nonzero(gates[: l + 1, t])[0]
            last_exec[t] = ex[-1] if len(ex) else 0
        for t in range(T):
            reads.append({"token": t, "layer": int(last_exec[t]),
                          "fresh": bool(gates[l, t])})
    ideal = layout.ideal_rounds(len(reads))
    controller_eff = 0.887         # paper's measured dense ceiling (88.7 %)
    out = {
        "dense_baseline": controller_eff,
        "interleaved_reuse": controller_eff * ideal / max(
            layout.interleaved_transactions(reads), 1e-9),
        "tokenwise_reuse": controller_eff * ideal / max(
            layout.tokenwise_transactions(reads), 1e-9),
        # reused entries come from on-chip supply: HBM time covers fresh
        # entries only (+2% residual non-consecutive traffic), so the
        # *effective* aggregate can exceed the dense ceiling — the paper's
        # 467.8 GB/s > 460 GB/s observation.
        "invariance_buffer": controller_eff * ideal / max(
            layout.invariance_buffer_transactions(reads) + 0.02 * ideal,
            1e-9),
        # paged entry-stream store (serve-engine kv_mode="paged"): paging
        # alone trades bandwidth for memory (each layer re-walks the
        # stream); the on-chip history buffer reads it once and serves
        # every later layer's reuse hits locally.
        "paged_tokenwise": controller_eff * ideal / max(
            layout.paged_transactions(gates, on_chip_history=False), 1e-9),
        "paged_history": controller_eff * ideal / max(
            layout.paged_transactions(gates, on_chip_history=True)
            + 0.02 * ideal, 1e-9),
    }
    return out


def history_hit_accounting(gates: np.ndarray) -> Dict[str, object]:
    """History-buffer hit accounting from an execution-gate log.

    gates: [L, T].  At layer l each context token costs one entry read;
    the read *hits* the on-chip history when the token's current entry was
    written at an earlier layer (gate off ⇒ inherited).  Returns per-layer
    hit fractions plus the aggregate rate — which equals the compact
    store's saved fraction by construction."""
    g = (np.asarray(gates, np.float64) > 0.5)
    L, T = g.shape
    fresh = g.copy()
    fresh[0] = True
    hits = T - fresh.sum(axis=1)                  # per layer
    reads = np.full((L,), float(T))
    return {
        "per_layer": (hits / reads).tolist(),
        "hits": float(hits.sum()),
        "reads": float(reads.sum()),
        "hit_rate": float(hits.sum() / reads.sum()) if T else 0.0,
    }
