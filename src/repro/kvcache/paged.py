"""Paged KV-cache with a proactive pruned-token history buffer (paper §4.4).

The dense slot pool (``serve/engine.py::init_pool``) preallocates
``max_slots × max_len`` KV rows *per attention layer* — the uniform/static
layout the paper argues against.  This module replaces it with the paper's
memory system:

* **Entry stream** — the unit of storage is one *(token, layer)* KV entry,
  and a token stores an entry only at the attention layers where it
  actually executed (layer 0 is the dense base).  A pruned token's KV is
  invariant until it re-executes (cross-layer KV invariance, §2.1 Eq. 2),
  so one physical entry serves every layer in its validity interval —
  store-once, reference-many.  Total entries ≈ ``T·(1 + keep·(L−1))``
  instead of ``T·L``: the compact store's 25.4 % saving, realized in live
  decode memory.

* **Pages** — entries append token-major into fixed-size pages drawn from
  a global free list (``PageAllocator``): alloc-on-demand during decode,
  full release on eviction.  Per-slot *block tables* map logical entry
  index → physical page, so slots never alias pages.

* **History-buffer indirection** — each entry carries metadata
  ``(pos, l0, l1)``: the token position and the half-open layer interval
  ``[l0, l1)`` it is valid for.  Attention at layer *a* reads the whole
  stream and masks by validity (``repro/kvcache/history.py``), which keeps
  the HBM access pattern a *sequential page walk* (the high-locality
  on-chip reuse the paper's URAM buffer provides) instead of an irregular
  cross-layer gather.

Device-side state (the "store") is a flat dict of arrays; the block
tables, free list and fill counters are host-side (``PageAllocator``) and
passed into each jitted step — the host is the FPGA-controller analogue
that *proactively* guarantees page capacity before a step runs, so the
jitted step never allocates.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ATTN, ModelConfig
from repro.kvcache import history

Store = Dict[str, jnp.ndarray]

# Quantized page payloads (ROADMAP item 3): per-entry-per-head scales in
# the BFP power-of-two idiom of quant/int4.py.  "int4" packs two codes
# per byte along the head dim: byte d holds dims d (low nibble) and
# d + dh//2 (high nibble), so dequant is a concat, not an interleave.
KV_DTYPES = (None, "int8", "int4")
_QMAX = {"int8": 127.0, "int4": 7.0}


def can_page(cfg: ModelConfig) -> bool:
    """Paged mode covers the paper's target stacks: every layer's mixer is
    global attention (LOCAL ring buffers are already window-bounded and SSM
    state is O(1) — neither gains from paging), and routing is masked-mode
    (gather-mode prefill executes the top-capacity set, which the logged
    argmax gates do not describe, so entry freshness would be wrong)."""
    all_global = all(k == ATTN for k in cfg.layer_pattern)
    gather = cfg.skip.enabled and cfg.skip.mode == "gather"
    return all_global and not gather


def reuse_enabled(cfg: ModelConfig) -> bool:
    """True when entry freshness follows the routing gates (layer 0 dense +
    executed layers).  Otherwise every layer writes (dense storage)."""
    return (cfg.skip.enabled and cfg.skip.kv_reuse
            and cfg.skip.route_attention)


def num_attention_layers(cfg: ModelConfig) -> int:
    return len(cfg.attention_layers)


# ---------------------------------------------------------------------------
# Host-side allocator
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PageStats:
    pages_total: int = 0
    pages_in_use: int = 0
    pages_peak: int = 0
    entries_appended: int = 0        # live compact-store writes
    entries_dense: int = 0           # what per-layer dense stores would write


class PageAllocator:
    """Free-list page allocator + per-slot block tables (host side).

    ``slot_entry_capacity`` bounds one slot's entry count (worst case:
    ``max_len × n_attn_layers`` — every token fresh at every layer), fixing
    the block-table width ``J``.  Pages are allocated on demand as a slot's
    fill crosses page boundaries and returned to the free list wholesale on
    eviction.

    **Prefix sharing** (refcounts): a page is *referenced* by every slot
    chain it appears in plus every published prefix record pinning it
    (``ref_pages``/``deref_pages``).  ``refcount[p]`` tracks the total; a
    page returns to the free list only when its refcount drops to zero, so
    ``release``/``trim`` can never reclaim a page another slot (or the
    prefix cache) still reads — the copy-on-write discipline is that
    shared pages are immutable and a slot's first divergent append always
    lands in a private page (``alias_into`` only aliases *full* shared
    pages; the partial boundary page is COW-copied via
    ``copy_page_masked``).
    """

    def __init__(self, num_pages: int, page_size: int, max_slots: int,
                 slot_entry_capacity: int):
        if num_pages < 1 or page_size < 1:
            raise ValueError("num_pages and page_size must be >= 1")
        self.num_pages = num_pages
        self.page_size = page_size
        self.max_slots = max_slots
        self.pages_per_slot = -(-slot_entry_capacity // page_size)
        self._free: List[int] = list(range(num_pages - 1, -1, -1))
        self._chains: Dict[int, List[int]] = {s: [] for s in range(max_slots)}
        self.block_table = np.zeros((max_slots, self.pages_per_slot),
                                    np.int32)
        self.fill = np.zeros((max_slots,), np.int32)
        self.refcount = np.zeros((num_pages,), np.int32)
        self.stats = PageStats(pages_total=num_pages)

    # -- queries ------------------------------------------------------------
    @property
    def free_pages(self) -> int:
        return len(self._free)

    def capacity(self, slot: int) -> int:
        """Entry capacity currently backed by allocated pages."""
        return len(self._chains[slot]) * self.page_size

    def pages_for(self, n_entries: int) -> int:
        return -(-n_entries // self.page_size)

    def chain(self, slot: int) -> Tuple[int, ...]:
        """``slot``'s current page chain, in stream order (a copy — the
        prefix cache snapshots it at publish time)."""
        return tuple(self._chains[slot])

    def max_chain_pages(self) -> int:
        """Longest allocated page chain — the live width of the stream
        walk (decode only needs block-table columns up to this)."""
        return max((len(c) for c in self._chains.values()), default=0)

    def can_reserve(self, slot: int, n_entries: int) -> bool:
        """Would ``ensure(slot, n_entries)`` succeed right now?"""
        if n_entries > self.pages_per_slot * self.page_size:
            return False
        short = self.pages_for(n_entries) - len(self._chains[slot])
        return short <= self.free_pages

    # -- mutation -----------------------------------------------------------
    def ensure(self, slot: int, n_entries: int) -> bool:
        """Grow ``slot``'s chain until it can hold ``n_entries`` entries.
        Returns False (no partial allocation) if the free list is short."""
        if not self.can_reserve(slot, n_entries):
            return False
        chain = self._chains[slot]
        while len(chain) * self.page_size < n_entries:
            page = self._free.pop()
            self.refcount[page] = 1
            self.block_table[slot, len(chain)] = page
            chain.append(page)
        in_use = self.num_pages - len(self._free)
        self.stats.pages_in_use = in_use
        self.stats.pages_peak = max(self.stats.pages_peak, in_use)
        return True

    def _drop_ref(self, page: int) -> bool:
        """Drop one reference; return the page to the free list iff that
        was the last one.  Returns True when the page was freed."""
        self.refcount[page] -= 1
        assert self.refcount[page] >= 0, f"page {page}: refcount underflow"
        if self.refcount[page] == 0:
            self._free.append(page)
            return True
        return False

    def alias_into(self, slot: int, pages: Sequence[int]) -> None:
        """Warm-prefix admission: extend ``slot``'s *empty* chain with
        shared (fully-filled) pages — one new reference each.  The
        caller then COW-copies the partial boundary page (if any) into a
        private page via ``ensure`` + ``copy_page_masked`` and seeds the
        fill with ``seed_fill``; all subsequent appends target entry
        indices past the shared region, so shared pages are never
        written."""
        chain = self._chains[slot]
        assert not chain and self.fill[slot] == 0, \
            f"slot {slot}: alias_into needs an empty chain"
        for page in pages:
            assert self.refcount[page] > 0, \
                f"page {page}: aliasing an unreferenced page"
            self.refcount[page] += 1
            self.block_table[slot, len(chain)] = page
            chain.append(page)

    def seed_fill(self, slot: int, n_entries: int) -> None:
        """Adopt ``n_entries`` already-materialized entries (the shared
        prefix) as ``slot``'s starting fill.  Deliberately *not* counted
        in ``entries_appended`` — the whole point is that these entries
        were never stored again."""
        assert n_entries <= self.capacity(slot), (n_entries, slot)
        self.fill[slot] = n_entries

    def ref_pages(self, pages: Sequence[int]) -> None:
        """Pin pages on behalf of a published prefix record."""
        for page in pages:
            assert self.refcount[page] > 0, \
                f"page {page}: pinning an unreferenced page"
            self.refcount[page] += 1

    def deref_pages(self, pages: Sequence[int]) -> int:
        """Drop a prefix record's pins; frees pages nobody else holds.
        Returns the number of pages returned to the free list."""
        freed = sum(1 for page in pages if self._drop_ref(page))
        self.stats.pages_in_use = self.num_pages - len(self._free)
        return freed

    def append(self, slot: int, n_entries: int, dense_entries: int) -> None:
        """Record ``n_entries`` committed writes (capacity must already be
        ensured).  ``dense_entries`` is the per-layer-dense baseline count
        for the same tokens (savings accounting)."""
        self.fill[slot] += n_entries
        if self.fill[slot] > self.capacity(slot):
            # deferred import: repro.serve.__init__ imports PageAllocator,
            # so a module-level import here would be a cycle
            from repro.serve.errors import PageExhausted
            raise PageExhausted(
                f"slot {slot}: fill {self.fill[slot]} exceeds page capacity "
                f"{self.capacity(slot)} — ensure() not called proactively",
                slot=slot, free_pages=self.free_pages,
                pages_total=self.num_pages)
        self.stats.entries_appended += n_entries
        self.stats.entries_dense += dense_entries

    def hide_pages(self, n: int = 0) -> List[int]:
        """Fault injection (``serve/faults.py`` kind ``"oom"``): pop ``n``
        pages (0 = all) off the free list so reservations fail exactly as
        if residents had filled the pool.  Returns the hidden pages; the
        caller MUST hand them back to :meth:`unhide_pages` within the same
        engine iteration — the pair restores the free list byte-identical,
        so leak accounting stays exact."""
        n = len(self._free) if n <= 0 else min(n, len(self._free))
        hidden = [self._free.pop() for _ in range(n)]
        self.stats.pages_in_use = self.num_pages - len(self._free)
        return hidden

    def unhide_pages(self, pages: List[int]) -> None:
        """Return pages taken by :meth:`hide_pages`, restoring the free
        list to its exact pre-hide order (pop/push are both LIFO)."""
        self._free.extend(reversed(pages))
        self.stats.pages_in_use = self.num_pages - len(self._free)

    def release(self, slot: int) -> int:
        """Evict: drop ``slot``'s reference on every page of its chain
        (pages return to the free list only when nobody else — another
        chain or a prefix-record pin — still references them).  Returns
        the number of pages detached from the chain."""
        chain = self._chains[slot]
        n = len(chain)
        for page in reversed(chain):
            self._drop_ref(page)
        chain.clear()
        self.block_table[slot] = 0
        self.fill[slot] = 0
        self.stats.pages_in_use = self.num_pages - len(self._free)
        return n

    def trim(self, slot: int) -> int:
        """Speculative-window rollback: return the tail pages a draft's
        up-front ``ensure`` reserved beyond what the committed fill
        actually uses (docs/speculative.md).  Tentative entries need no
        device-side erase — the verifier rewrites the stream from the
        pre-window fill and ``in_fill`` masks anything beyond — but the
        *pages* backing the rejected tail must come back to the free
        list, or every partially-accepted window leaks page headroom
        until eviction.  Shared pages never reach the tail (a slot's
        fill never drops below its aliased-prefix entry count), and
        ``_drop_ref`` would keep a still-referenced page off the free
        list even if one did.  Returns the number of pages detached."""
        chain = self._chains[slot]
        keep = self.pages_for(int(self.fill[slot]))
        tail = chain[keep:]
        if not tail:
            return 0
        del chain[keep:]
        for page in reversed(tail):
            self._drop_ref(page)
        self.block_table[slot, keep:keep + len(tail)] = 0
        self.stats.pages_in_use = self.num_pages - len(self._free)
        return len(tail)

    def check_conservation(self, pinned: Optional[Dict[int, int]] = None
                           ) -> None:
        """Assert the refcount conservation invariant: every page is
        either on the free list with refcount 0, or off it with refcount
        equal to its chain-membership count plus its prefix-record pins
        (``pinned``: page -> pin count).  Raises AssertionError on any
        leak or double-free; cheap enough for tests and debug asserts."""
        pinned = pinned or {}
        expected = np.zeros((self.num_pages,), np.int64)
        for chain in self._chains.values():
            for page in chain:
                expected[page] += 1
        for page, n in pinned.items():
            expected[page] += n
        free = set(self._free)
        assert len(free) == len(self._free), "free list has duplicates"
        for page in range(self.num_pages):
            if page in free:
                assert self.refcount[page] == 0 and expected[page] == 0, \
                    f"page {page}: free but referenced"
            else:
                assert self.refcount[page] == expected[page] > 0, \
                    (f"page {page}: refcount {self.refcount[page]} != "
                     f"holders {expected[page]}")

    @property
    def saved_fraction(self) -> float:
        """Live compact-store saving (matches CompactKVStore.saved_fraction
        replayed over the same gate log)."""
        if not self.stats.entries_dense:
            return 0.0
        return 1.0 - self.stats.entries_appended / self.stats.entries_dense


# ---------------------------------------------------------------------------
# Device-side store
# ---------------------------------------------------------------------------

def init_store(cfg: ModelConfig, num_pages: int, page_size: int,
               dtype=None, kv_dtype: Optional[str] = None) -> Store:
    """Unified page pool shared by every slot and every attention layer.

    ``kv_dtype`` selects the page payload format: None keeps full
    ``cfg.dtype`` rows; "int8"/"int4" store fixed-point codes plus one
    power-of-two scale per (entry, head) in ``k_scales``/``v_scales``
    (the BFP idiom of quant/int4.py), dequantized during the block-table
    walk."""
    if kv_dtype not in KV_DTYPES:
        raise ValueError(f"kv_dtype must be one of {KV_DTYPES}, "
                         f"got {kv_dtype!r}")
    dt = jnp.dtype(dtype or cfg.dtype)
    Hkv, dh = cfg.num_kv_heads, cfg.resolved_head_dim
    P, ps = num_pages, page_size
    if kv_dtype == "int4" and dh % 2:
        raise ValueError("int4 paged KV needs an even head_dim")
    dh_payload = dh if kv_dtype != "int4" else dh // 2
    kv_dt = dt if kv_dtype is None else jnp.int8
    store = {
        "k_pages": jnp.zeros((P, ps, Hkv, dh_payload), kv_dt),
        "v_pages": jnp.zeros((P, ps, Hkv, dh_payload), kv_dt),
        # per-entry history metadata: token position + validity [l0, l1)
        "pos_pages": jnp.full((P, ps), history.MASKED_POS, jnp.int32),
        "l0_pages": jnp.zeros((P, ps), jnp.int32),
        "l1_pages": jnp.zeros((P, ps), jnp.int32),
    }
    if kv_dtype is not None:
        store["k_scales"] = jnp.ones((P, ps, Hkv), jnp.float32)
        store["v_scales"] = jnp.ones((P, ps, Hkv), jnp.float32)
    return store


def infer_kv_dtype(store: Store, cfg: ModelConfig) -> Optional[str]:
    """Recover the page payload format from the store's structure, so
    downstream consumers (model steps, commit, gather) adapt without
    threading a config flag: scales present + full head dim -> int8;
    scales + halved head dim -> the nibble-packed int4 payload."""
    if "k_scales" not in store:
        return None
    return ("int8" if store["k_pages"].shape[-1] == cfg.resolved_head_dim
            else "int4")


def quantize_entries(k: jnp.ndarray, v: jnp.ndarray, kv_dtype: str):
    """[..., Hkv, dh] f32/bf16 KV rows -> (k_codes, v_codes, k_scale,
    v_scale).  Scales are per (entry, head), power-of-two (BFP idiom:
    exact-by-shift dequant on fixed-point hardware)."""
    qmax = _QMAX[kv_dtype]

    def quant(x):
        x = x.astype(jnp.float32)
        amax = jnp.max(jnp.abs(x), axis=-1)                     # [..., Hkv]
        scale = jnp.exp2(jnp.ceil(jnp.log2(
            jnp.maximum(amax / qmax, 1e-12))))
        scale = jnp.where(amax > 0, scale, 1.0)
        codes = jnp.clip(jnp.round(x / scale[..., None]),
                         -qmax, qmax).astype(jnp.int8)
        if kv_dtype == "int4":
            dh = codes.shape[-1]
            lo = codes[..., :dh // 2] & 0x0F
            hi = codes[..., dh // 2:] & 0x0F
            codes = (lo | (hi << 4)).astype(jnp.int8)
        return codes, scale

    k_codes, k_scale = quant(k)
    v_codes, v_scale = quant(v)
    return k_codes, v_codes, k_scale, v_scale


def dequantize_entries(codes: jnp.ndarray, scale: jnp.ndarray,
                       kv_dtype: str) -> jnp.ndarray:
    """Invert ``quantize_entries`` for one pool: codes [..., Hkv, dhp] +
    scale [..., Hkv] -> f32 [..., Hkv, dh]."""
    if kv_dtype == "int4":
        c = codes.astype(jnp.int32)
        lo = (c << 28) >> 28                      # sign-extend low nibble
        hi = (c << 24) >> 28                      # sign-extend high nibble
        codes = jnp.concatenate([lo, hi], axis=-1)
    return codes.astype(jnp.float32) * scale[..., None].astype(jnp.float32)


def store_bytes(store: Store, data_only: bool = True) -> int:
    if data_only:
        keys = tuple(k for k in ("k_pages", "v_pages", "k_scales",
                                 "v_scales") if k in store)
    else:
        keys = tuple(store)
    return sum(store[k].size * store[k].dtype.itemsize for k in keys)


def entry_bytes(cfg: ModelConfig, kv_dtype: Optional[str] = None) -> int:
    """Payload bytes one (token, layer) entry costs: K+V codes plus
    scales.  The fp16/bf16 baseline is 2·Hkv·dh·itemsize."""
    Hkv, dh = cfg.num_kv_heads, cfg.resolved_head_dim
    if kv_dtype is None:
        return 2 * Hkv * dh * np.dtype(cfg.dtype).itemsize
    per_head = dh if kv_dtype == "int8" else dh // 2
    return 2 * Hkv * (per_head + 4)               # int8 codes + f32 scale


def gather_view(store: Store, block_table: jnp.ndarray,
                with_kv: bool = True,
                kv_dtype: Optional[str] = None) -> Dict[str, jnp.ndarray]:
    """Resolve each slot's page chain into logical entry order.

    block_table: [S, J] int32.  Returns arrays of shape [S, J·ps(, ...)]
    — the per-step read view (metadata always; K/V only on the jnp path,
    the Pallas kernel walks the block table itself).  With a quantized
    store the K/V view is dequantized here (the jnp-path analogue of the
    kernel's in-walk dequant)."""
    S, J = block_table.shape
    ps = store["pos_pages"].shape[1]

    def take(leaf):
        return jnp.take(leaf, block_table.reshape(-1), axis=0).reshape(
            (S, J * ps) + leaf.shape[2:])

    out = {"pos": take(store["pos_pages"]),
           "l0": take(store["l0_pages"]),
           "l1": take(store["l1_pages"])}
    if with_kv:
        if kv_dtype is None:
            out["k"] = take(store["k_pages"])
            out["v"] = take(store["v_pages"])
        else:
            out["k"] = dequantize_entries(take(store["k_pages"]),
                                          take(store["k_scales"]), kv_dtype)
            out["v"] = dequantize_entries(take(store["v_pages"]),
                                          take(store["v_scales"]), kv_dtype)
    return out


def _flat_targets(block_table: jnp.ndarray, e: jnp.ndarray,
                  valid: jnp.ndarray, page_size: int,
                  num_pages: int) -> jnp.ndarray:
    """Logical per-slot entry index -> flat physical index into the pools
    (out-of-range sentinel where invalid; scatters use mode='drop').
    block_table: [S, J]; e, valid: [S, N] (slot-major)."""
    J = block_table.shape[1]
    j = jnp.clip(e // page_size, 0, J - 1)
    pages = jnp.take_along_axis(block_table, j, axis=1)          # [S, N]
    phys = pages * page_size + e % page_size
    return jnp.where(valid, phys, num_pages * page_size)


def _scatter(store: Store, idx: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
             pos: jnp.ndarray, l0: jnp.ndarray, l1: jnp.ndarray,
             kv_dtype: Optional[str] = None) -> Store:
    """Write entries at flat physical indices (OOB indices dropped).

    The single write choke point: with a quantized store, full-precision
    KV rows are quantized here and both the codes and the per-entry
    scales land in one scatter."""
    P, ps = store["pos_pages"].shape
    flat = idx.reshape(-1)

    def put(pages, vals):
        out = pages.reshape((P * ps,) + pages.shape[2:]).at[flat].set(
            vals.reshape((-1,) + pages.shape[2:]).astype(pages.dtype),
            mode="drop")
        return out.reshape(pages.shape)

    out = dict(store)
    if kv_dtype is None:
        out["k_pages"] = put(store["k_pages"], k)
        out["v_pages"] = put(store["v_pages"], v)
    else:
        kc, vc, k_sc, v_sc = quantize_entries(k, v, kv_dtype)
        out["k_pages"] = put(store["k_pages"], kc)
        out["v_pages"] = put(store["v_pages"], vc)
        out["k_scales"] = put(store["k_scales"], k_sc)
        out["v_scales"] = put(store["v_scales"], v_sc)
    out["pos_pages"] = put(store["pos_pages"], pos)
    out["l0_pages"] = put(store["l0_pages"], l0)
    out["l1_pages"] = put(store["l1_pages"], l1)
    return out


# ---------------------------------------------------------------------------
# Prefill packing (one slot)
# ---------------------------------------------------------------------------

def prefill_views_from_cache(cache: Dict, cfg: ModelConfig) -> jnp.ndarray:
    """Stack the prefill cache's per-layer KV views into stack order.

    cache: the pytree ``prefill`` collects (batch 1, possibly right-padded
    prompt).  Returns (k_views, v_views): [nA, T, Hkv, dh]."""
    def stage_kv(stage, lead):
        ks, vs = [], []
        for k_pos in range(cfg.stage_len):
            entry = stage[f"pos{k_pos}"]
            ks.append(entry["k"])
            vs.append(entry["v"])
        # each leaf: [1, T, H, d] (stage0) or [S-1, 1, T, H, d] (stages)
        k = jnp.stack(ks, axis=1 if lead else 0)
        v = jnp.stack(vs, axis=1 if lead else 0)
        return k, v

    k0, v0 = stage_kv(cache["stage0"], lead=False)      # [nAs, 1, T, H, d]
    ks, vs = [k0[:, 0]], [v0[:, 0]]
    if cfg.num_stages > 1:
        kr, vr = stage_kv(cache["stages"], lead=True)   # [S-1, nAs, 1, T,..]
        ks.append(kr.reshape((-1,) + kr.shape[2:])[:, 0])
        vs.append(vr.reshape((-1,) + vr.shape[2:])[:, 0])
    return jnp.concatenate(ks, 0), jnp.concatenate(vs, 0)


def pack_prefill(store: Store, cache: Dict, gates: jnp.ndarray,
                 valid_len: jnp.ndarray, block_table: jnp.ndarray,
                 cfg: ModelConfig, start_token=0, start_entry=0,
                 kv_dtype: Optional[str] = None) -> Store:
    """Scatter one prefilled prompt's compact entries into its pages.

    gates: [nA, T] execution gates (T may include right-padding; tokens at
    index >= valid_len are dropped).  Entries are token-major — token t's
    fresh layers are contiguous — so decode appends simply continue the
    stream.  Freshness: layer 0 dense + gated layers (or every layer when
    reuse is disabled).

    ``cache`` is any prefill-layout KV collection whose time extent is
    >= T: the monolithic ``prefill`` cache (bucket-padded), or the
    chunked-prefill staging cache (``model.init_chunk_cache``, padded to
    a chunk multiple) with ``gates`` as the concatenated per-chunk gate
    log — the packed entry stream is identical either way because both
    the views and the gates are per-token state.

    Warm-prefix admission packs only the cold suffix: ``start_token``
    drops tokens below it (their entries are shared pages) and
    ``start_entry`` offsets the stream so the suffix lands right after
    the adopted prefix entries.  Both may be traced scalars."""
    k_views, v_views = prefill_views_from_cache(cache, cfg)
    nA, T = gates.shape
    # the cache may carry decode headroom (pad_to); entries only exist for
    # the gate-logged positions
    k_views = k_views[:, :T]
    v_views = v_views[:, :T]
    ps = store["pos_pages"].shape[1]
    P = store["pos_pages"].shape[0]

    fresh = history.fresh_mask(gates, reuse_enabled(cfg))       # [nA, T]
    fresh &= (jnp.arange(T)[None, :] < valid_len)
    fresh &= (jnp.arange(T)[None, :] >= start_token)
    freshT = fresh.T                                            # [T, nA]
    e = (jnp.cumsum(freshT.reshape(-1).astype(jnp.int32)) -
         freshT.reshape(-1)).reshape(T, nA)                     # excl. cumsum
    e = e + jnp.asarray(start_entry, jnp.int32)
    l1 = history.next_fresh_layer(fresh).T                      # [T, nA]

    idx = _flat_targets(block_table[None], e.reshape(1, T * nA),
                        freshT.reshape(1, T * nA), ps, P)       # [1, T·nA]
    idx = idx.reshape(T, nA)
    pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[:, None], (T, nA))
    l0 = jnp.broadcast_to(jnp.arange(nA, dtype=jnp.int32)[None, :], (T, nA))
    return _scatter(store, idx,
                    k_views.swapaxes(0, 1), v_views.swapaxes(0, 1),
                    pos, l0, l1, kv_dtype=kv_dtype)


def prefill_entry_count(gates: np.ndarray, valid_len: int,
                        reuse: bool) -> int:
    """Host-side mirror of ``pack_prefill``'s entry count."""
    return int(history.fresh_counts(gates, valid_len, reuse).sum())


# ---------------------------------------------------------------------------
# Prefix sharing: copy-on-write + warm-prefix reconstruction
# ---------------------------------------------------------------------------

def copy_page_masked(store: Store, src, dst, keep) -> Store:
    """COW-copy page ``src`` into private page ``dst``, keeping only the
    first ``keep`` in-page entries (the shared-prefix portion of a
    partial boundary page).  Entries past ``keep`` are reset — position
    to MASKED_POS, payload to zero — so the copy carries nothing of the
    donor slot's divergent suffix.  ``src``/``dst``/``keep`` may be
    traced scalars."""
    ps = store["pos_pages"].shape[1]
    m = jnp.arange(ps) < keep
    out = {}
    for key, leaf in store.items():
        row = leaf[src]
        mask = m.reshape((ps,) + (1,) * (row.ndim - 1))
        blank = (jnp.full_like(row, history.MASKED_POS)
                 if key == "pos_pages" else jnp.zeros_like(row))
        out[key] = leaf.at[dst].set(jnp.where(mask, row, blank))
    return out


def views_from_pages(store: Store, block_table: jnp.ndarray,
                     fill: jnp.ndarray, cfg: ModelConfig, cap: int,
                     kv_dtype: Optional[str] = None):
    """Invert one slot's entry stream into per-layer prefill views.

    block_table: [J] the slot's page-chain row; fill: scalar entry
    count; cap: static time extent of the produced views.  For each
    attention layer the entry valid at that layer scatters back to its
    token position — the exact inverse of ``pack_prefill`` (cross-layer
    reuse means one physical entry may serve many layers).  Quantized
    stores are dequantized during the gather, so the views are always
    full precision.  Returns (k_views, v_views): [nA, cap, Hkv, dh];
    positions the stream doesn't cover stay zero (matching a fresh
    ``init_chunk_cache``)."""
    view = gather_view(store, block_table[None], with_kv=True,
                       kv_dtype=kv_dtype)
    k, v = view["k"][0], view["v"][0]                 # [E, Hkv, dh]
    in_fill = (jnp.arange(k.shape[0]) < fill)[None]   # [1, E]
    ks, vs = [], []
    for a in range(num_attention_layers(cfg)):
        eff = history.effective_positions(
            view["pos"], view["l0"], view["l1"], in_fill, a)[0]
        # MASKED_POS (and anything >= cap) falls off the scatter
        ks.append(jnp.zeros((cap,) + k.shape[1:], k.dtype)
                  .at[eff].set(k, mode="drop"))
        vs.append(jnp.zeros((cap,) + v.shape[1:], v.dtype)
                  .at[eff].set(v, mode="drop"))
    return jnp.stack(ks), jnp.stack(vs)


def chunk_cache_from_views(k_views: jnp.ndarray, v_views: jnp.ndarray,
                           cfg: ModelConfig, dtype=None) -> Dict:
    """Inverse of ``prefill_views_from_cache``: per-layer views
    [nA, cap, Hkv, dh] -> a batch-1 chunked-prefill staging cache
    (``model.init_chunk_cache`` layout) holding them, so a warm-prefix
    admission resumes chunked prefill exactly where the shared prefix's
    prefill left off."""
    sl, S = cfg.stage_len, cfg.num_stages
    assert k_views.shape[0] == S * sl, (k_views.shape, S, sl)
    dt = jnp.dtype(dtype or cfg.dtype)

    stage0 = {f"pos{k}": {"k": k_views[k].astype(dt)[None],
                          "v": v_views[k].astype(dt)[None]}
              for k in range(sl)}                     # [1, cap, Hkv, dh]
    cache: Dict = {"stage0": stage0}
    if S > 1:
        cache["stages"] = {
            f"pos{k}": {                              # [S-1, 1, cap, ...]
                "k": jnp.stack([k_views[s * sl + k].astype(dt)[None]
                                for s in range(1, S)]),
                "v": jnp.stack([v_views[s * sl + k].astype(dt)[None]
                                for s in range(1, S)])}
            for k in range(sl)}
    return cache


# ---------------------------------------------------------------------------
# Decode commit (all slots, one token each)
# ---------------------------------------------------------------------------

def commit_decode(store: Store, buf_k: jnp.ndarray, buf_v: jnp.ndarray,
                  gates: jnp.ndarray, t: jnp.ndarray,
                  block_table: jnp.ndarray, fill: jnp.ndarray,
                  active: jnp.ndarray, cfg: ModelConfig,
                  kv_dtype: Optional[str] = None) -> Store:
    """Append this step's fresh entries for every active slot.

    buf_k/buf_v: [nA, S, Hkv, dh] — each attention layer's token view
    (fresh or inherited) collected during the stack pass; only fresh
    layers' views are written.  gates: [nA, S]; t/fill/active: [S]."""
    nA, S = gates.shape
    ps = store["pos_pages"].shape[1]
    P = store["pos_pages"].shape[0]

    fresh = history.fresh_mask(gates, reuse_enabled(cfg))       # [nA, S]
    fresh &= active[None, :]
    e = fill[None, :] + jnp.cumsum(fresh.astype(jnp.int32), 0) - fresh
    l1 = history.next_fresh_layer(fresh)                        # [nA, S]
    idx = _flat_targets(block_table, e.swapaxes(0, 1),
                        fresh.swapaxes(0, 1), ps, P).swapaxes(0, 1)
    pos = jnp.broadcast_to(t[None, :], (nA, S))
    l0 = jnp.broadcast_to(jnp.arange(nA, dtype=jnp.int32)[:, None], (nA, S))
    return _scatter(store, idx, buf_k, buf_v, pos, l0, l1,
                    kv_dtype=kv_dtype)
