"""Paged KV-cache with a proactive pruned-token history buffer (paper §4.4).

The dense slot pool (``serve/engine.py::init_pool``) preallocates
``max_slots × max_len`` KV rows *per attention layer* — the uniform/static
layout the paper argues against.  This module replaces it with the paper's
memory system:

* **Entry stream** — the unit of storage is one *(token, layer)* KV entry,
  and a token stores an entry only at the attention layers where it
  actually executed (layer 0 is the dense base).  A pruned token's KV is
  invariant until it re-executes (cross-layer KV invariance, §2.1 Eq. 2),
  so one physical entry serves every layer in its validity interval —
  store-once, reference-many.  Total entries ≈ ``T·(1 + keep·(L−1))``
  instead of ``T·L``: the compact store's 25.4 % saving, realized in live
  decode memory.

* **Pages** — entries append token-major into fixed-size pages drawn from
  a global free list (``PageAllocator``): alloc-on-demand during decode,
  full release on eviction.  Per-slot *block tables* map logical entry
  index → physical page, so slots never alias pages.

* **History-buffer indirection** — each entry carries metadata
  ``(pos, l0, l1)``: the token position and the half-open layer interval
  ``[l0, l1)`` it is valid for.  Attention at layer *a* reads the whole
  stream and masks by validity (``repro/kvcache/history.py``), which keeps
  the HBM access pattern a *sequential page walk* (the high-locality
  on-chip reuse the paper's URAM buffer provides) instead of an irregular
  cross-layer gather.

Device-side state (the "store") is a flat dict of arrays; the block
tables, free list and fill counters are host-side (``PageAllocator``) and
passed into each jitted step — the host is the FPGA-controller analogue
that *proactively* guarantees page capacity before a step runs, so the
jitted step never allocates.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ATTN, ModelConfig
from repro.kvcache import history

Store = Dict[str, jnp.ndarray]


def can_page(cfg: ModelConfig) -> bool:
    """Paged mode covers the paper's target stacks: every layer's mixer is
    global attention (LOCAL ring buffers are already window-bounded and SSM
    state is O(1) — neither gains from paging), and routing is masked-mode
    (gather-mode prefill executes the top-capacity set, which the logged
    argmax gates do not describe, so entry freshness would be wrong)."""
    all_global = all(k == ATTN for k in cfg.layer_pattern)
    gather = cfg.skip.enabled and cfg.skip.mode == "gather"
    return all_global and not gather


def reuse_enabled(cfg: ModelConfig) -> bool:
    """True when entry freshness follows the routing gates (layer 0 dense +
    executed layers).  Otherwise every layer writes (dense storage)."""
    return (cfg.skip.enabled and cfg.skip.kv_reuse
            and cfg.skip.route_attention)


def num_attention_layers(cfg: ModelConfig) -> int:
    return len(cfg.attention_layers)


# ---------------------------------------------------------------------------
# Host-side allocator
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PageStats:
    pages_total: int = 0
    pages_in_use: int = 0
    pages_peak: int = 0
    entries_appended: int = 0        # live compact-store writes
    entries_dense: int = 0           # what per-layer dense stores would write


class PageAllocator:
    """Free-list page allocator + per-slot block tables (host side).

    ``slot_entry_capacity`` bounds one slot's entry count (worst case:
    ``max_len × n_attn_layers`` — every token fresh at every layer), fixing
    the block-table width ``J``.  Pages are allocated on demand as a slot's
    fill crosses page boundaries and returned to the free list wholesale on
    eviction; a page is only ever owned by one slot at a time.
    """

    def __init__(self, num_pages: int, page_size: int, max_slots: int,
                 slot_entry_capacity: int):
        if num_pages < 1 or page_size < 1:
            raise ValueError("num_pages and page_size must be >= 1")
        self.num_pages = num_pages
        self.page_size = page_size
        self.max_slots = max_slots
        self.pages_per_slot = -(-slot_entry_capacity // page_size)
        self._free: List[int] = list(range(num_pages - 1, -1, -1))
        self._chains: Dict[int, List[int]] = {s: [] for s in range(max_slots)}
        self.block_table = np.zeros((max_slots, self.pages_per_slot),
                                    np.int32)
        self.fill = np.zeros((max_slots,), np.int32)
        self.stats = PageStats(pages_total=num_pages)

    # -- queries ------------------------------------------------------------
    @property
    def free_pages(self) -> int:
        return len(self._free)

    def capacity(self, slot: int) -> int:
        """Entry capacity currently backed by allocated pages."""
        return len(self._chains[slot]) * self.page_size

    def pages_for(self, n_entries: int) -> int:
        return -(-n_entries // self.page_size)

    def max_chain_pages(self) -> int:
        """Longest allocated page chain — the live width of the stream
        walk (decode only needs block-table columns up to this)."""
        return max((len(c) for c in self._chains.values()), default=0)

    def can_reserve(self, slot: int, n_entries: int) -> bool:
        """Would ``ensure(slot, n_entries)`` succeed right now?"""
        if n_entries > self.pages_per_slot * self.page_size:
            return False
        short = self.pages_for(n_entries) - len(self._chains[slot])
        return short <= self.free_pages

    # -- mutation -----------------------------------------------------------
    def ensure(self, slot: int, n_entries: int) -> bool:
        """Grow ``slot``'s chain until it can hold ``n_entries`` entries.
        Returns False (no partial allocation) if the free list is short."""
        if not self.can_reserve(slot, n_entries):
            return False
        chain = self._chains[slot]
        while len(chain) * self.page_size < n_entries:
            page = self._free.pop()
            self.block_table[slot, len(chain)] = page
            chain.append(page)
        in_use = self.num_pages - len(self._free)
        self.stats.pages_in_use = in_use
        self.stats.pages_peak = max(self.stats.pages_peak, in_use)
        return True

    def append(self, slot: int, n_entries: int, dense_entries: int) -> None:
        """Record ``n_entries`` committed writes (capacity must already be
        ensured).  ``dense_entries`` is the per-layer-dense baseline count
        for the same tokens (savings accounting)."""
        self.fill[slot] += n_entries
        if self.fill[slot] > self.capacity(slot):
            # deferred import: repro.serve.__init__ imports PageAllocator,
            # so a module-level import here would be a cycle
            from repro.serve.errors import PageExhausted
            raise PageExhausted(
                f"slot {slot}: fill {self.fill[slot]} exceeds page capacity "
                f"{self.capacity(slot)} — ensure() not called proactively",
                slot=slot, free_pages=self.free_pages,
                pages_total=self.num_pages)
        self.stats.entries_appended += n_entries
        self.stats.entries_dense += dense_entries

    def hide_pages(self, n: int = 0) -> List[int]:
        """Fault injection (``serve/faults.py`` kind ``"oom"``): pop ``n``
        pages (0 = all) off the free list so reservations fail exactly as
        if residents had filled the pool.  Returns the hidden pages; the
        caller MUST hand them back to :meth:`unhide_pages` within the same
        engine iteration — the pair restores the free list byte-identical,
        so leak accounting stays exact."""
        n = len(self._free) if n <= 0 else min(n, len(self._free))
        hidden = [self._free.pop() for _ in range(n)]
        self.stats.pages_in_use = self.num_pages - len(self._free)
        return hidden

    def unhide_pages(self, pages: List[int]) -> None:
        """Return pages taken by :meth:`hide_pages`, restoring the free
        list to its exact pre-hide order (pop/push are both LIFO)."""
        self._free.extend(reversed(pages))
        self.stats.pages_in_use = self.num_pages - len(self._free)

    def release(self, slot: int) -> int:
        """Evict: return every page of ``slot`` to the free list."""
        chain = self._chains[slot]
        n = len(chain)
        self._free.extend(reversed(chain))
        chain.clear()
        self.block_table[slot] = 0
        self.fill[slot] = 0
        self.stats.pages_in_use = self.num_pages - len(self._free)
        return n

    def trim(self, slot: int) -> int:
        """Speculative-window rollback: return the tail pages a draft's
        up-front ``ensure`` reserved beyond what the committed fill
        actually uses (docs/speculative.md).  Tentative entries need no
        device-side erase — the verifier rewrites the stream from the
        pre-window fill and ``in_fill`` masks anything beyond — but the
        *pages* backing the rejected tail must come back to the free
        list, or every partially-accepted window leaks page headroom
        until eviction.  Returns the number of pages freed."""
        chain = self._chains[slot]
        keep = self.pages_for(int(self.fill[slot]))
        tail = chain[keep:]
        if not tail:
            return 0
        del chain[keep:]
        self._free.extend(reversed(tail))
        self.block_table[slot, keep:keep + len(tail)] = 0
        self.stats.pages_in_use = self.num_pages - len(self._free)
        return len(tail)

    @property
    def saved_fraction(self) -> float:
        """Live compact-store saving (matches CompactKVStore.saved_fraction
        replayed over the same gate log)."""
        if not self.stats.entries_dense:
            return 0.0
        return 1.0 - self.stats.entries_appended / self.stats.entries_dense


# ---------------------------------------------------------------------------
# Device-side store
# ---------------------------------------------------------------------------

def init_store(cfg: ModelConfig, num_pages: int, page_size: int,
               dtype=None) -> Store:
    """Unified page pool shared by every slot and every attention layer."""
    dt = jnp.dtype(dtype or cfg.dtype)
    Hkv, dh = cfg.num_kv_heads, cfg.resolved_head_dim
    P, ps = num_pages, page_size
    return {
        "k_pages": jnp.zeros((P, ps, Hkv, dh), dt),
        "v_pages": jnp.zeros((P, ps, Hkv, dh), dt),
        # per-entry history metadata: token position + validity [l0, l1)
        "pos_pages": jnp.full((P, ps), history.MASKED_POS, jnp.int32),
        "l0_pages": jnp.zeros((P, ps), jnp.int32),
        "l1_pages": jnp.zeros((P, ps), jnp.int32),
    }


def store_bytes(store: Store, data_only: bool = True) -> int:
    keys = ("k_pages", "v_pages") if data_only else tuple(store)
    return sum(store[k].size * store[k].dtype.itemsize for k in keys)


def gather_view(store: Store, block_table: jnp.ndarray,
                with_kv: bool = True) -> Dict[str, jnp.ndarray]:
    """Resolve each slot's page chain into logical entry order.

    block_table: [S, J] int32.  Returns arrays of shape [S, J·ps(, ...)]
    — the per-step read view (metadata always; K/V only on the jnp path,
    the Pallas kernel walks the block table itself)."""
    S, J = block_table.shape
    ps = store["pos_pages"].shape[1]

    def take(leaf):
        return jnp.take(leaf, block_table.reshape(-1), axis=0).reshape(
            (S, J * ps) + leaf.shape[2:])

    out = {"pos": take(store["pos_pages"]),
           "l0": take(store["l0_pages"]),
           "l1": take(store["l1_pages"])}
    if with_kv:
        out["k"] = take(store["k_pages"])
        out["v"] = take(store["v_pages"])
    return out


def _flat_targets(block_table: jnp.ndarray, e: jnp.ndarray,
                  valid: jnp.ndarray, page_size: int,
                  num_pages: int) -> jnp.ndarray:
    """Logical per-slot entry index -> flat physical index into the pools
    (out-of-range sentinel where invalid; scatters use mode='drop').
    block_table: [S, J]; e, valid: [S, N] (slot-major)."""
    J = block_table.shape[1]
    j = jnp.clip(e // page_size, 0, J - 1)
    pages = jnp.take_along_axis(block_table, j, axis=1)          # [S, N]
    phys = pages * page_size + e % page_size
    return jnp.where(valid, phys, num_pages * page_size)


def _scatter(store: Store, idx: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
             pos: jnp.ndarray, l0: jnp.ndarray, l1: jnp.ndarray) -> Store:
    """Write entries at flat physical indices (OOB indices dropped)."""
    P, ps, Hkv, dh = store["k_pages"].shape
    flat = idx.reshape(-1)

    def put(pages, vals):
        out = pages.reshape((P * ps,) + pages.shape[2:]).at[flat].set(
            vals.reshape((-1,) + pages.shape[2:]), mode="drop")
        return out.reshape(pages.shape)

    return {
        "k_pages": put(store["k_pages"], k.astype(store["k_pages"].dtype)),
        "v_pages": put(store["v_pages"], v.astype(store["v_pages"].dtype)),
        "pos_pages": put(store["pos_pages"], pos),
        "l0_pages": put(store["l0_pages"], l0),
        "l1_pages": put(store["l1_pages"], l1),
    }


# ---------------------------------------------------------------------------
# Prefill packing (one slot)
# ---------------------------------------------------------------------------

def prefill_views_from_cache(cache: Dict, cfg: ModelConfig) -> jnp.ndarray:
    """Stack the prefill cache's per-layer KV views into stack order.

    cache: the pytree ``prefill`` collects (batch 1, possibly right-padded
    prompt).  Returns (k_views, v_views): [nA, T, Hkv, dh]."""
    def stage_kv(stage, lead):
        ks, vs = [], []
        for k_pos in range(cfg.stage_len):
            entry = stage[f"pos{k_pos}"]
            ks.append(entry["k"])
            vs.append(entry["v"])
        # each leaf: [1, T, H, d] (stage0) or [S-1, 1, T, H, d] (stages)
        k = jnp.stack(ks, axis=1 if lead else 0)
        v = jnp.stack(vs, axis=1 if lead else 0)
        return k, v

    k0, v0 = stage_kv(cache["stage0"], lead=False)      # [nAs, 1, T, H, d]
    ks, vs = [k0[:, 0]], [v0[:, 0]]
    if cfg.num_stages > 1:
        kr, vr = stage_kv(cache["stages"], lead=True)   # [S-1, nAs, 1, T,..]
        ks.append(kr.reshape((-1,) + kr.shape[2:])[:, 0])
        vs.append(vr.reshape((-1,) + vr.shape[2:])[:, 0])
    return jnp.concatenate(ks, 0), jnp.concatenate(vs, 0)


def pack_prefill(store: Store, cache: Dict, gates: jnp.ndarray,
                 valid_len: jnp.ndarray, block_table: jnp.ndarray,
                 cfg: ModelConfig) -> Store:
    """Scatter one prefilled prompt's compact entries into its pages.

    gates: [nA, T] execution gates (T may include right-padding; tokens at
    index >= valid_len are dropped).  Entries are token-major — token t's
    fresh layers are contiguous — so decode appends simply continue the
    stream.  Freshness: layer 0 dense + gated layers (or every layer when
    reuse is disabled).

    ``cache`` is any prefill-layout KV collection whose time extent is
    >= T: the monolithic ``prefill`` cache (bucket-padded), or the
    chunked-prefill staging cache (``model.init_chunk_cache``, padded to
    a chunk multiple) with ``gates`` as the concatenated per-chunk gate
    log — the packed entry stream is identical either way because both
    the views and the gates are per-token state."""
    k_views, v_views = prefill_views_from_cache(cache, cfg)
    nA, T = gates.shape
    # the cache may carry decode headroom (pad_to); entries only exist for
    # the gate-logged positions
    k_views = k_views[:, :T]
    v_views = v_views[:, :T]
    ps = store["pos_pages"].shape[1]
    P = store["pos_pages"].shape[0]

    fresh = history.fresh_mask(gates, reuse_enabled(cfg))       # [nA, T]
    fresh &= (jnp.arange(T)[None, :] < valid_len)
    freshT = fresh.T                                            # [T, nA]
    e = (jnp.cumsum(freshT.reshape(-1).astype(jnp.int32)) -
         freshT.reshape(-1)).reshape(T, nA)                     # excl. cumsum
    l1 = history.next_fresh_layer(fresh).T                      # [T, nA]

    idx = _flat_targets(block_table[None], e.reshape(1, T * nA),
                        freshT.reshape(1, T * nA), ps, P)       # [1, T·nA]
    idx = idx.reshape(T, nA)
    pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[:, None], (T, nA))
    l0 = jnp.broadcast_to(jnp.arange(nA, dtype=jnp.int32)[None, :], (T, nA))
    return _scatter(store, idx,
                    k_views.swapaxes(0, 1), v_views.swapaxes(0, 1),
                    pos, l0, l1)


def prefill_entry_count(gates: np.ndarray, valid_len: int,
                        reuse: bool) -> int:
    """Host-side mirror of ``pack_prefill``'s entry count."""
    g = np.asarray(gates, np.float32)[:, :valid_len]
    if not reuse:
        return g.shape[0] * valid_len
    return int(valid_len + g[1:].sum())


# ---------------------------------------------------------------------------
# Decode commit (all slots, one token each)
# ---------------------------------------------------------------------------

def commit_decode(store: Store, buf_k: jnp.ndarray, buf_v: jnp.ndarray,
                  gates: jnp.ndarray, t: jnp.ndarray,
                  block_table: jnp.ndarray, fill: jnp.ndarray,
                  active: jnp.ndarray, cfg: ModelConfig) -> Store:
    """Append this step's fresh entries for every active slot.

    buf_k/buf_v: [nA, S, Hkv, dh] — each attention layer's token view
    (fresh or inherited) collected during the stack pass; only fresh
    layers' views are written.  gates: [nA, S]; t/fill/active: [S]."""
    nA, S = gates.shape
    ps = store["pos_pages"].shape[1]
    P = store["pos_pages"].shape[0]

    fresh = history.fresh_mask(gates, reuse_enabled(cfg))       # [nA, S]
    fresh &= active[None, :]
    e = fill[None, :] + jnp.cumsum(fresh.astype(jnp.int32), 0) - fresh
    l1 = history.next_fresh_layer(fresh)                        # [nA, S]
    idx = _flat_targets(block_table, e.swapaxes(0, 1),
                        fresh.swapaxes(0, 1), ps, P).swapaxes(0, 1)
    pos = jnp.broadcast_to(t[None, :], (nA, S))
    l0 = jnp.broadcast_to(jnp.arange(nA, dtype=jnp.int32)[:, None], (nA, S))
    return _scatter(store, idx, buf_k, buf_v, pos, l0, l1)
