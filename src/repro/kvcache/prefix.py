"""Host-side prefix cache: refcounted sharing of prompt-prefix pages.

Production traffic is dominated by shared system prompts and multi-turn
reuse (ROADMAP item 3; FlightLLM makes the same bandwidth-locality
argument the paper's §4.4 history buffer does).  This module is the
host-side registry that turns one request's prefill into reusable pages
for the next:

* After a cold prefill completes, the engine *publishes* the prompt at
  every ``block``-token boundary: each boundary becomes a
  :class:`PrefixRecord` — the token prefix, its execution gates, its
  entry count, and the page-chain prefix that physically holds those
  entries.  Publishing pins the pages via ``PageAllocator.ref_pages``,
  so they survive the owning slot's release.

* At admission the engine *probes* with the new prompt; the longest
  matching record (capped at ``len(prompt) - 1`` — at least one cold
  token must remain to produce decode logits) is aliased into the new
  slot's block table (``alias_into``), its partial boundary page is
  copy-on-write-copied (``copy_page_masked``), and prefill runs only on
  the cold suffix.

* Under page pressure the engine evicts least-recently-used records
  (``evict_one``) before preempting residents; a record in use by an
  in-flight admission is pinned (``in_use``) and never evicted.

Records never copy KV to the host: the pages themselves are the store,
and ``paged.views_from_pages`` reconstructs the staging cache on device
when a warm suffix prefill needs attention context.  Keys are BLAKE2b
digests of the raw token prefix, chained per block; the record keeps the
exact token tuple and lookup verifies it, so a digest collision can
never alias the wrong prefix.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.kvcache.paged import PageAllocator, prefill_entry_count


def _digest(tokens: Sequence[int]) -> bytes:
    h = hashlib.blake2b(digest_size=16)
    h.update(np.asarray(tokens, np.int64).tobytes())
    return h.digest()


@dataclasses.dataclass
class PrefixRecord:
    key: bytes
    tokens: Tuple[int, ...]          # the exact prefix (collision guard)
    entries: int                     # packed entry count E_s for the prefix
    pages: Tuple[int, ...]           # page-chain prefix holding the entries
    gates: np.ndarray                # [nA, Ts] prefix execution gates
    in_use: int = 0                  # in-flight warm admissions reading it
    stamp: int = 0                   # LRU clock

    @property
    def length(self) -> int:
        return len(self.tokens)


class PrefixCache:
    """LRU registry of published prompt prefixes over a PageAllocator."""

    def __init__(self, alloc: PageAllocator, block: int, reuse: bool,
                 max_records: int = 256):
        if block < 1:
            raise ValueError("prefix block must be >= 1 token")
        self.alloc = alloc
        self.block = block
        self.reuse = reuse
        self.max_records = max_records
        self._records: Dict[bytes, PrefixRecord] = {}
        self._clock = 0
        self.hits = 0
        self.misses = 0
        self.tokens_saved = 0

    # -- queries ------------------------------------------------------------
    def __len__(self) -> int:
        return len(self._records)

    def lookup(self, tokens: Sequence[int]) -> Optional[PrefixRecord]:
        """Longest published record matching a strict prefix of ``tokens``
        (at most ``len(tokens) - 1``: the final prompt token always
        prefills cold so admission still produces first-token logits)."""
        toks = tuple(int(t) for t in tokens)
        top = ((len(toks) - 1) // self.block) * self.block
        for ts in range(top, 0, -self.block):
            rec = self._records.get(_digest(toks[:ts]))
            if rec is not None and rec.tokens == toks[:ts]:
                self._clock += 1
                rec.stamp = self._clock
                self.hits += 1
                self.tokens_saved += rec.length
                return rec
        self.misses += 1
        return None

    def pin(self, rec: PrefixRecord) -> None:
        rec.in_use += 1

    def unpin(self, rec: PrefixRecord) -> None:
        rec.in_use -= 1
        assert rec.in_use >= 0, "prefix record unpinned below zero"

    def page_pins(self) -> Dict[int, int]:
        """page -> number of records pinning it (for
        ``PageAllocator.check_conservation``)."""
        pins: Dict[int, int] = {}
        for rec in self._records.values():
            for page in rec.pages:
                pins[page] = pins.get(page, 0) + 1
        return pins

    # -- mutation -----------------------------------------------------------
    def publish(self, tokens: Sequence[int], gates: np.ndarray,
                chain: Sequence[int]) -> int:
        """Register every block boundary of a completed cold prefill.

        ``gates``: [nA, T] the prompt's execution gates; ``chain``: the
        owning slot's page chain right after prefill packed (entry
        stream token-major, so ``chain[:pages_for(E_s)]`` holds exactly
        the first-``Ts``-tokens' entries plus at most one partial
        boundary page).  Returns the number of new records."""
        toks = tuple(int(t) for t in tokens)
        gates = np.asarray(gates)
        added = 0
        for ts in range(self.block, len(toks) + 1, self.block):
            key = _digest(toks[:ts])
            rec = self._records.get(key)
            if rec is not None and rec.tokens == toks[:ts]:
                self._clock += 1
                rec.stamp = self._clock       # refresh, already pinned
                continue
            entries = prefill_entry_count(gates, ts, self.reuse)
            pages = tuple(chain[:self.alloc.pages_for(entries)])
            self.alloc.ref_pages(pages)
            self._clock += 1
            self._records[key] = PrefixRecord(
                key=key, tokens=toks[:ts], entries=entries, pages=pages,
                gates=gates[:, :ts].copy(), stamp=self._clock)
            added += 1
        while len(self._records) > self.max_records:
            if self.evict_one() is None:
                break
        return added

    def evict_one(self) -> Optional[int]:
        """Drop the least-recently-used unpinned record; returns the
        number of pages actually freed (None when nothing is evictable).
        Longer records are preferred victims at equal stamps so a nested
        shorter prefix — more broadly shareable — outlives its
        extensions."""
        victim = None
        for rec in self._records.values():
            if rec.in_use:
                continue
            if victim is None or (rec.stamp, -rec.length) < (
                    victim.stamp, -victim.length):
                victim = rec
        if victim is None:
            return None
        del self._records[victim.key]
        return self.alloc.deref_pages(victim.pages)

    def clear(self) -> int:
        """Drop every record (snapshot resume: pins are not serialized —
        the restored allocator owns only chain references).  Returns the
        number of pages freed."""
        freed = 0
        for rec in list(self._records.values()):
            assert rec.in_use == 0, "clearing a pinned prefix record"
            del self._records[rec.key]
            freed += self.alloc.deref_pages(rec.pages)
        return freed
