import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run (deliverable e): lower + compile every
(architecture × input shape) against the production mesh and record
memory_analysis / cost_analysis / collective schedule for §Dry-run and
§Roofline.

The two lines above run before ANY other import — jax locks the device
count at first init.  This module is the ONLY place that requests 512
placeholder devices.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod-only-cell ...]
Results cached as JSON under results/dryrun/.
"""
import argparse
import dataclasses
import json
import sys
import time
import traceback
from pathlib import Path

import jax

from repro.configs import ASSIGNED_ARCHS, SHAPES, get_config
from repro.distributed.sharding import ShardingPolicy
from repro.launch import specs as specs_lib
from repro.launch.mesh import make_production_mesh
from repro.roofline.analysis import analyze_compiled, model_flops_for

RESULTS_DIR = Path(__file__).resolve().parents[3] / "results" / "dryrun"


def cell_id(arch: str, shape: str, multi_pod: bool, variant: str = "") -> str:
    pod = "pod2" if multi_pod else "pod1"
    v = f"-{variant}" if variant else ""
    return f"{arch}__{shape}__{pod}{v}"


def run_cell(arch: str, shape: str, multi_pod: bool,
             overrides: dict | None = None, variant: str = "",
             zero1: bool = False, microbatches: int | None = None,
             no_sp: bool = False) -> dict:
    cfg = get_config(arch)
    if overrides:
        quant_over = {k[6:]: v for k, v in overrides.items()
                      if k.startswith("quant_")}
        plain = {k: v for k, v in overrides.items()
                 if not k.startswith("quant_")}
        if quant_over:
            plain["quant"] = dataclasses.replace(cfg.quant, **quant_over)
        cfg = dataclasses.replace(cfg, **plain)
    rec: dict = {"arch": arch, "shape": shape,
                 "mesh": "2x16x16" if multi_pod else "16x16",
                 "variant": variant or "baseline"}
    if shape not in cfg.supported_shapes():
        rec["status"] = "skipped"
        rec["reason"] = ("long-context decode requires sub-quadratic "
                        "attention (DESIGN.md §Arch-applicability)")
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    kind = SHAPES[shape]["kind"]
    mode = "train" if kind == "train" else "serve"
    policy = ShardingPolicy(mesh, cfg, mode=mode, zero1=zero1)
    if no_sp:
        from jax.sharding import PartitionSpec as P
        policy.overrides["residual"] = P(policy.dp, None, None)
        policy.overrides["kv_view"] = P(policy.dp, None, None, None)
    if kind == "train":
        fn, args, in_sh, out_sh, donate = specs_lib.build_train_step(
            cfg, policy, shape, microbatches=microbatches)
    else:
        fn, args, in_sh, out_sh, donate = specs_lib.build_step(
            cfg, policy, shape)

    t0 = time.time()
    with mesh:
        jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=donate)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    tokens = SHAPES[shape]["global_batch"] * (
        SHAPES[shape]["seq_len"] if kind != "decode" else 1)
    chips = mesh.devices.size
    analysis = analyze_compiled(compiled, chips=chips,
                                model_flops=model_flops_for(cfg, kind, tokens),
                                shape_kind=kind)
    rec.update(status="ok", lower_s=round(t_lower, 1),
               compile_s=round(t_compile, 1), kind=kind,
               tokens=tokens, **analysis)
    return rec


def save(rec: dict, multi_pod: bool) -> Path:
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    p = RESULTS_DIR / (cell_id(rec["arch"], rec["shape"], multi_pod,
                               rec.get("variant", "")
                               if rec.get("variant") != "baseline" else "")
                       + ".json")
    p.write_text(json.dumps(rec, indent=1, default=float))
    return p


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="every (arch x shape) on the single-pod mesh")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--variant", default="",
                    help="tag for optimization variants (hillclimbs)")
    ap.add_argument("--override", action="append", default=[],
                    help="cfg override key=value (python literal); "
                         "quant_* keys override QuantConfig fields")
    ap.add_argument("--zero1", action="store_true",
                    help="ZeRO-1 param sharding (weight-stationary train)")
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--no-sp", action="store_true",
                    help="disable sequence-parallel residual carry")
    args = ap.parse_args()

    overrides = {}
    for ov in args.override:
        k, v = ov.split("=", 1)
        import ast
        try:
            overrides[k] = ast.literal_eval(v)
        except (ValueError, SyntaxError):
            overrides[k] = v

    cells = []
    if args.all:
        for arch in ASSIGNED_ARCHS:
            for shape in SHAPES:
                cells.append((arch, shape, args.multi_pod))
    else:
        assert args.arch and args.shape
        cells.append((args.arch, args.shape, args.multi_pod))

    failures = 0
    for arch, shape, mp in cells:
        out = RESULTS_DIR / (cell_id(arch, shape, mp, args.variant) + ".json")
        if args.skip_existing and out.exists():
            prev = json.loads(out.read_text())
            if prev.get("status") in ("ok", "skipped"):
                print(f"[cached ] {out.name}")
                continue
        try:
            rec = run_cell(arch, shape, mp, overrides or None, args.variant,
                           zero1=args.zero1, microbatches=args.microbatches,
                           no_sp=args.no_sp)
        except Exception as e:
            traceback.print_exc()
            rec = {"arch": arch, "shape": shape,
                   "mesh": "2x16x16" if mp else "16x16",
                   "variant": args.variant or "baseline",
                   "status": "error", "error": f"{type(e).__name__}: {e}"}
            failures += 1
        p = save(rec, mp)
        if rec["status"] == "ok":
            print(f"[ok {rec['compile_s']:7.1f}s] {p.name}  "
                  f"bottleneck={rec['bottleneck']}  "
                  f"flops/dev={rec['hlo_flops_per_dev']:.3e}  "
                  f"bytes/dev={rec['hlo_bytes_per_dev']:.3e}  "
                  f"coll/dev={rec['collective_bytes_per_dev']:.3e}")
            ma = rec.get("memory_analysis") or {}
            if ma:
                print("           memory_analysis:", {
                    k: f"{v/1e9:.2f}GB" for k, v in ma.items()
                    if "size" in k})
        else:
            print(f"[{rec['status']:7s}] {p.name}  {rec.get('reason', rec.get('error', ''))[:120]}")
        sys.stdout.flush()
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
