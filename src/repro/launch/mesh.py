"""Production mesh construction.

A FUNCTION (not a module constant) so importing this module never touches
jax device state.  The dry-run sets XLA_FLAGS for 512 placeholder host
devices *before* any jax import (see dryrun.py).
"""
from __future__ import annotations

import jax

from repro.distributed.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_test_mesh(data: int = 2, model: int = 2):
    """Small mesh for CPU integration tests (requires
    XLA_FLAGS=--xla_force_host_platform_device_count>=data*model)."""
    return make_mesh((data, model), ("data", "model"))


def make_serve_mesh(tp: int, data: int = 1):
    """Serving mesh: ``model`` is the tensor-parallel axis the serve-mode
    ``ShardingPolicy`` head-shards attention/KV over; ``data`` replicates
    (or batch-shards) the engine across the remaining devices.  Used by
    ``launch/serve.py --tp N`` and the sharded-serve tests."""
    need = tp * data
    have = len(jax.devices())
    if have < need:
        raise ValueError(
            f"--tp {tp} (x data {data}) needs {need} devices, have {have}; "
            f"on CPU set XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{need}")
    return make_mesh((data, tp), ("data", "model"))
