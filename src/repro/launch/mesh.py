"""Production mesh construction.

A FUNCTION (not a module constant) so importing this module never touches
jax device state.  The dry-run sets XLA_FLAGS for 512 placeholder host
devices *before* any jax import (see dryrun.py).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(data: int = 2, model: int = 2):
    """Small mesh for CPU integration tests (requires
    XLA_FLAGS=--xla_force_host_platform_device_count>=data*model)."""
    return jax.make_mesh((data, model), ("data", "model"))
