"""Serving launcher: batched generation with the SkipOPU inference
pipeline (gather-mode routing + cross-layer KV reuse).

  PYTHONPATH=src python -m repro.launch.serve --arch llama2-7b --smoke \
      --batch 4 --prompt-len 64 --new-tokens 32
"""
import argparse
import dataclasses

import jax
import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--gather", action="store_true",
                    help="compacted (gather) prefill execution")
    ap.add_argument("--int4", action="store_true",
                    help="quantize weights to int4 (paper §4.2)")
    ap.add_argument("--continuous", action="store_true",
                    help="continuous batching over a --batch-slot KV pool "
                         "(mixed prompt lengths; see docs/serving.md)")
    ap.add_argument("--paged-kv", action="store_true",
                    help="paged KV store + history buffer instead of the "
                         "dense slot pool (see docs/kvcache.md)")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--kv-dtype", default=None,
                    choices=("int8", "int4"),
                    help="quantize paged-KV page payloads (per-entry "
                         "pow2 scales; requires --paged-kv; see "
                         "docs/kvcache.md)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="refcounted prompt-prefix sharing with "
                         "copy-on-write pages: warm admissions skip the "
                         "shared prefill (requires --paged-kv; see "
                         "docs/kvcache.md)")
    ap.add_argument("--prefix-block", type=int, default=16,
                    help="prefix-cache publish granularity in tokens")
    ap.add_argument("--decode-steps", type=int, default=0,
                    help="fuse this many decode iterations into one "
                         "device-resident dispatch (0 = config default; "
                         "1 = per-token parity; requires --continuous; "
                         "see docs/serving.md)")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="self-speculative decoding: draft this many "
                         "tokens per window with the layer-skip draft "
                         "pass, verify them in one chunked dispatch "
                         "(0 = off; requires --continuous, incompatible "
                         "with --decode-steps; see docs/speculative.md)")
    ap.add_argument("--draft-keep", type=float, default=None,
                    help="draft-pass router keep-rate lever in (0, 1]: "
                         "lower = cheaper, more aggressively skipped "
                         "drafts at lower acceptance (default: serve "
                         "keep rate; requires --spec-k)")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="chunked prefill: process prompts this many "
                         "tokens at a time, interleaved with resident "
                         "decode steps (0 = monolithic; requires "
                         "--continuous; see docs/serving.md)")
    ap.add_argument("--use-kernels", action="store_true",
                    help="Pallas kernel path incl. the fused linear "
                         "pipeline (interpret mode off-TPU — slow on "
                         "CPU, for end-to-end validation)")
    ap.add_argument("--tp", type=int, default=0,
                    help="tensor-parallel degree: serve over a (1, N) "
                         "device mesh with head-sharded attention/KV and "
                         "column/row-split linears (requires --continuous; "
                         "token output is identical to --tp 0 — see "
                         "docs/distributed.md)")
    ap.add_argument("--trace-out", default=None, metavar="FILE",
                    help="write a Chrome trace-event JSON of the run "
                         "(open in Perfetto / chrome://tracing; requires "
                         "--continuous; see docs/observability.md)")
    ap.add_argument("--metrics-out", default=None, metavar="FILE",
                    help="write the run's metrics snapshot (.prom suffix "
                         "= Prometheus text format, else JSON; requires "
                         "--continuous)")
    # robustness / lifecycle flags (docs/robustness.md; all require
    # --continuous)
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="per-request wall-clock budget from submission; "
                         "past it a request finishes with reason "
                         "'deadline' and releases its slot/pages at the "
                         "next step boundary")
    ap.add_argument("--snapshot-dir", default=None, metavar="DIR",
                    help="write crash-consistent engine snapshots at "
                         "quiescent step boundaries (resume with --resume)")
    ap.add_argument("--snapshot-every", type=int, default=1,
                    help="boundaries between snapshots (default 1)")
    ap.add_argument("--resume", action="store_true",
                    help="resume from the newest snapshot under "
                         "--snapshot-dir instead of submitting fresh "
                         "requests; at temperature 0 the survivors' "
                         "tokens are bit-identical to the uninterrupted "
                         "run")
    ap.add_argument("--kill-at", type=int, default=None, metavar="N",
                    help="inject a SimulatedKill at step boundary N "
                         "(after its snapshot) — exits with code 3; used "
                         "by tools/kill_resume_smoke.py")
    ap.add_argument("--watchdog-timeout-s", type=float, default=None,
                    help="hard bound on one dispatch+sync; past it the "
                         "run aborts with HungDispatch (trace attached)")
    ap.add_argument("--max-queue-depth", type=int, default=None,
                    help="shed new submissions once the queue is this "
                         "deep")
    ap.add_argument("--max-queue-delay-s", type=float, default=None,
                    help="shed new submissions once the queue head has "
                         "waited past this bound")
    ap.add_argument("--max-preemptions", type=int, default=None,
                    help="per-request eviction retry budget; past it a "
                         "victim keeps its partial tokens (reason "
                         "'preempt_budget') instead of requeueing")
    ap.add_argument("--results-out", default=None, metavar="FILE",
                    help="write per-request results (tokens, finish "
                         "reason) as JSON — the kill/resume smoke "
                         "compares these across runs")
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.models import model as model_lib
    from repro.serve.config import (EngineConfig, KVConfig, ObsConfig,
                                    RobustnessConfig, SchedulingConfig,
                                    SpecConfig)
    from repro.serve.engine import ContinuousBatchingEngine, ServeEngine

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    if args.use_kernels:
        cfg = dataclasses.replace(cfg, use_kernels=True)
    if args.gather:
        cfg = dataclasses.replace(
            cfg, skip=dataclasses.replace(cfg.skip, mode="gather"))
    params = model_lib.init_params(jax.random.PRNGKey(0), cfg)
    if args.int4:
        from repro.quant import quantize_params
        params = quantize_params(params, cfg.quant.group_size,
                                 cfg.quant.pow2_scales)

    rng = np.random.default_rng(0)
    max_len = args.prompt_len + args.new_tokens
    if args.prefill_chunk and not args.continuous:
        raise SystemExit("--prefill-chunk requires --continuous")
    if args.decode_steps and not args.continuous:
        raise SystemExit("--decode-steps requires --continuous")
    if args.spec_k and not args.continuous:
        raise SystemExit("--spec-k requires --continuous")
    if args.spec_k and args.decode_steps:
        raise SystemExit("--spec-k and --decode-steps are mutually "
                         "exclusive (both own the decode cadence)")
    if (args.kv_dtype or args.prefix_cache) and not args.paged_kv:
        raise SystemExit("--kv-dtype/--prefix-cache require --paged-kv")
    if args.draft_keep is not None and not args.spec_k:
        raise SystemExit("--draft-keep requires --spec-k")
    if args.tp and not args.continuous:
        raise SystemExit("--tp requires --continuous")
    if (args.trace_out or args.metrics_out) and not args.continuous:
        raise SystemExit("--trace-out/--metrics-out require --continuous")
    robust = (args.deadline_s, args.snapshot_dir, args.kill_at,
              args.watchdog_timeout_s, args.max_queue_depth,
              args.max_queue_delay_s, args.max_preemptions,
              args.results_out, args.resume or None)
    if any(v is not None for v in robust) and not args.continuous:
        raise SystemExit("robustness flags (--deadline-s/--snapshot-dir/"
                         "--resume/--kill-at/...) require --continuous")
    mesh = None
    if args.tp:
        from repro.launch.mesh import make_serve_mesh
        mesh = make_serve_mesh(args.tp)
        print(f"tensor-parallel serving: mesh {dict(zip(mesh.axis_names, mesh.devices.shape))}")
    if args.continuous:
        from repro.serve.errors import SimulatedKill
        from repro.serve.faults import Fault, Watchdog
        faults = ([Fault("kill", step=args.kill_at)]
                  if args.kill_at is not None else None)
        watchdog = (Watchdog(timeout_s=args.watchdog_timeout_s)
                    if args.watchdog_timeout_s is not None else None)
        eng = ContinuousBatchingEngine(cfg, params, config=EngineConfig(
            kv=KVConfig(
                kv_mode="paged" if args.paged_kv else "dense",
                page_size=args.page_size,
                kv_dtype=args.kv_dtype,
                prefix_cache=args.prefix_cache,
                prefix_block=args.prefix_block),
            scheduling=SchedulingConfig(
                max_slots=args.batch, max_len=max_len,
                prefill_chunk=args.prefill_chunk,
                decode_steps=args.decode_steps or None),
            spec=SpecConfig(spec_k=args.spec_k,
                            draft_keep=args.draft_keep),
            robustness=RobustnessConfig(
                faults=faults, watchdog=watchdog,
                snapshot_dir=args.snapshot_dir,
                snapshot_every=args.snapshot_every,
                max_queue_depth=args.max_queue_depth,
                max_queue_delay_s=args.max_queue_delay_s,
                max_preemptions=args.max_preemptions),
            obs=ObsConfig(trace=args.trace_out, mesh=mesh),
            temperature=args.temperature))
        if args.resume:
            at = eng.resume()
            print(f"resumed from snapshot boundary {at} "
                  f"under {args.snapshot_dir}")
        else:
            # mixed-length synthetic traffic: 2x oversubscribed slots
            for _ in range(2 * args.batch):
                ln = int(rng.integers(max(args.prompt_len // 4, 1),
                                      args.prompt_len + 1))
                eng.submit(rng.integers(0, cfg.vocab_size, (ln,),
                                        dtype=np.int32),
                           max_new_tokens=args.new_tokens,
                           deadline_s=args.deadline_s)
        try:
            out = eng.run()
        except SimulatedKill as e:
            print(f"simulated kill: {e}")
            raise SystemExit(3)
        s = out["stats"]
        print(f"prefill: {s.prefill_tokens} tok in {s.prefill_s:.2f}s | "
              f"decode: {s.decode_tok_per_s:.1f} tok/s | "
              f"requests: {s.requests_completed} | "
              f"KV storage saved≈{s.kv_saved_fraction:.1%} (measured) | "
              f"compiles: {s.compiles}")
        if args.spec_k:
            print(f"speculative: k={args.spec_k} "
                  f"draft_keep={eng.draft_keep:.2f} | "
                  f"{s.spec_windows} windows | acceptance "
                  f"{s.spec_acceptance_rate:.1%} "
                  f"({s.spec_tokens_accepted}/{s.spec_tokens_drafted}) | "
                  f"rolled back {s.spec_entries_rolled_back} entries")
        if eng.decode_steps > 1:
            print(f"fused decode: {eng.decode_steps} steps/dispatch | "
                  f"{s.decode_dispatches} dispatches | host "
                  f"{s.host_s:.2f}s vs device-wait {s.device_s:.2f}s")
        if args.prefill_chunk:
            worst = max(r.max_decode_stall_s for r in out["results"].values())
            print(f"chunked prefill: {s.prefill_chunks} chunks | "
                  f"{s.interleaved_steps} interleaved steps | worst "
                  f"decode stall {worst*1e3:.1f}ms")
        if s.kv_mode == "paged":
            print(f"paged KV: peak {s.pages_peak}/{s.pages_total} pages "
                  f"(×{s.page_size} entries) | live entry "
                  f"saving {s.kv_entries_saved_fraction:.1%} | history "
                  f"hit rate {s.history_hit_rate:.1%} | "
                  f"preemptions {s.preemptions}")
        if args.kv_dtype:
            print(f"quantized KV: {args.kv_dtype} page payloads "
                  "(pow2 per-entry scales)")
        if args.prefix_cache:
            print(f"prefix cache: {s.prefix_hits} warm / "
                  f"{s.prefix_misses} cold admissions | "
                  f"{s.prefix_tokens_saved} prefill tokens skipped | "
                  f"{s.prefix_records} records resident")
        if (s.faults_injected or s.requests_cancelled or s.deadline_exceeded
                or s.requests_shed or s.snapshots or s.resumes):
            print(f"robustness: faults {s.faults_injected} | retries "
                  f"{s.dispatch_retries} | deadline {s.deadline_exceeded} "
                  f"| cancelled {s.requests_cancelled} | shed "
                  f"{s.requests_shed} | snapshots {s.snapshots} | "
                  f"resumes {s.resumes}")
        for uid, r in sorted(out["results"].items()):
            print(f"  req {uid}: T0={r.prompt_len} +{r.decode_tokens} "
                  f"TTFT {r.ttft_s*1e3:.1f}ms ({r.finish_reason})")
        if args.results_out:
            import json
            import pathlib
            rpath = pathlib.Path(args.results_out)
            rpath.parent.mkdir(parents=True, exist_ok=True)
            rpath.write_text(json.dumps(
                {str(uid): {"tokens": [int(t) for t in r.tokens],
                            "prompt_len": r.prompt_len,
                            "finish_reason": r.finish_reason}
                 for uid, r in sorted(out["results"].items())}, indent=1))
            print(f"results written to {args.results_out}")
        if args.trace_out:
            print(f"trace written to {args.trace_out} "
                  "(open in https://ui.perfetto.dev)")
        if args.metrics_out:
            import pathlib
            mpath = pathlib.Path(args.metrics_out)
            mpath.parent.mkdir(parents=True, exist_ok=True)
            if mpath.suffix == ".prom":
                mpath.write_text(out["metrics"].to_prometheus())
            else:
                import json
                mpath.write_text(json.dumps(out["metrics"].snapshot(),
                                            indent=2))
            print(f"metrics written to {args.metrics_out}")
        return

    prompts = rng.integers(
        0, cfg.vocab_size, (args.batch, args.prompt_len), dtype=np.int32)
    eng = ServeEngine(cfg, params, max_len=max_len,
                      temperature=args.temperature)
    out = eng.generate(prompts, args.new_tokens)
    s = out["stats"]
    print(f"prefill: {s.prefill_tokens} tok in {s.prefill_s:.2f}s | "
          f"decode: {s.decode_tok_per_s:.1f} tok/s | "
          f"attn keep≈{s.attn_keep_frac:.2f} | "
          f"KV storage saved≈{s.kv_saved_fraction:.1%} (measured; "
          f"analytic≈{s.kv_saved_analytic:.1%})")
    print("sample:", out["tokens"][0, :16])


if __name__ == "__main__":
    main()
