"""Serving launcher: batched generation with the SkipOPU inference
pipeline (gather-mode routing + cross-layer KV reuse).

  PYTHONPATH=src python -m repro.launch.serve --arch llama2-7b --smoke \
      --batch 4 --prompt-len 64 --new-tokens 32
"""
import argparse
import dataclasses

import jax
import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--gather", action="store_true",
                    help="compacted (gather) prefill execution")
    ap.add_argument("--int4", action="store_true",
                    help="quantize weights to int4 (paper §4.2)")
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.models import model as model_lib
    from repro.serve.engine import ServeEngine

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    if args.gather:
        cfg = dataclasses.replace(
            cfg, skip=dataclasses.replace(cfg.skip, mode="gather"))
    params = model_lib.init_params(jax.random.PRNGKey(0), cfg)
    if args.int4:
        from repro.quant import quantize_params
        params = quantize_params(params, cfg.quant.group_size,
                                 cfg.quant.pow2_scales)

    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab_size, (args.batch, args.prompt_len), dtype=np.int32)
    eng = ServeEngine(cfg, params,
                      max_len=args.prompt_len + args.new_tokens,
                      temperature=args.temperature)
    out = eng.generate(prompts, args.new_tokens)
    s = out["stats"]
    print(f"prefill: {s.prefill_tokens} tok in {s.prefill_s:.2f}s | "
          f"decode: {s.decode_tok_per_s:.1f} tok/s | "
          f"attn keep≈{s.attn_keep_frac:.2f} | "
          f"KV storage saved≈{s.kv_saved_fraction:.1%}")
    print("sample:", out["tokens"][0, :16])


if __name__ == "__main__":
    main()
