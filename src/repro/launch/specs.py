"""ShapeDtypeStruct input specs + step-function builders for every
(arch × shape × mesh) cell.  Pure AOT: nothing here allocates device memory
— params/optimizer/cache shapes come from ``jax.eval_shape`` and the dry-run
lowers against the structs (the shannon/kernels pattern).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import SHAPES, ModelConfig
from repro.distributed.sharding import ShardingPolicy, set_policy
from repro.models import model as model_lib
from repro.optim import adamw_init, adamw_update, apply_updates, cosine_schedule


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def batch_specs(cfg: ModelConfig, batch: int, seq: int) -> Dict[str, Any]:
    """Model-input ShapeDtypeStructs for one (global_batch, seq)."""
    if cfg.frontend == "token":
        d: Dict[str, Any] = {"tokens": _sds((batch, seq), jnp.int32)}
    else:
        # modality frontend is a stub: precomputed frame/patch embeddings
        d = {"embeds": _sds((batch, seq), cfg.dtype)}
        d["embeds"] = _sds((batch, seq, cfg.d_model), cfg.dtype)
    if cfg.pos_embedding == "mrope":
        d["positions"] = _sds((3, batch, seq), jnp.int32)
    return d


def train_batch_specs(cfg: ModelConfig, batch: int, seq: int) -> Dict[str, Any]:
    d = batch_specs(cfg, batch, seq)
    d["labels"] = _sds((batch, seq), jnp.int32)
    return d


def input_specs(cfg: ModelConfig, shape_name: str) -> Dict[str, Any]:
    s = SHAPES[shape_name]
    if s["kind"] == "train":
        return train_batch_specs(cfg, s["global_batch"], s["seq_len"])
    if s["kind"] == "prefill":
        return batch_specs(cfg, s["global_batch"], s["seq_len"])
    return batch_specs(cfg, s["global_batch"], 1)           # decode


def _logits_sharding(cfg: ModelConfig, policy: ShardingPolicy,
                     batch: int) -> NamedSharding:
    dpsz = 1
    for a in policy.dp:
        dpsz *= policy.mesh.shape[a]
    b_ax = policy.dp if batch % dpsz == 0 else None
    v_ax = "model" if cfg.vocab_size % policy.mesh.shape["model"] == 0 else None
    return NamedSharding(policy.mesh, P(b_ax, v_ax))


def _batch_shardings(batch_tree, policy: ShardingPolicy):
    mesh, dp = policy.mesh, policy.dp

    def one(path, leaf):
        name = str(path[-1].key)
        B = leaf.shape[0] if name != "positions" else leaf.shape[1]
        dpsz = 1
        for a in dp:
            dpsz *= mesh.shape[a]
        ax = dp if B % dpsz == 0 else None
        if name == "positions":
            return NamedSharding(mesh, P(None, ax, None))
        spec = (ax,) + (None,) * (leaf.ndim - 1)
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(one, batch_tree)


# ---------------------------------------------------------------------------
# Step builders: return (fn, example_args, in_shardings, out_shardings,
# donate_argnums)
# ---------------------------------------------------------------------------

def serve_cfg(cfg: ModelConfig) -> ModelConfig:
    """Inference-time configuration: gather (compacted) execution for the
    prefill pass — the SkipOPU selective-execution pipeline."""
    return dataclasses.replace(
        cfg, skip=dataclasses.replace(cfg.skip, mode="gather"), remat=False)


def build_train_step(cfg: ModelConfig, policy: ShardingPolicy,
                     shape_name: str, lr: float = 3e-4,
                     microbatches: Optional[int] = None):
    s = SHAPES[shape_name]
    n_params = cfg.param_count()
    # ≥200B: bf16 momentum + factored second moment (see optim/adamw.py)
    lowmem = n_params > 2e11
    if microbatches is None:
        microbatches = 32 if lowmem else (16 if n_params > 3e10 else 8)
    acc_dtype = jnp.bfloat16 if lowmem else jnp.float32
    batch_tree = train_batch_specs(cfg, s["global_batch"], s["seq_len"])
    params_shapes = jax.eval_shape(partial(model_lib.init_params, cfg=cfg),
                                   jax.random.PRNGKey(0))
    opt_shapes = jax.eval_shape(partial(adamw_init, lowmem=lowmem),
                                params_shapes)
    rng_shape = jax.eval_shape(lambda: jax.random.PRNGKey(0))
    schedule = cosine_schedule(lr, 100, 10_000)
    B = s["global_batch"]
    mb = microbatches if B % microbatches == 0 else 1

    def split_mb(batch):
        def one(path, leaf):
            name = str(path[-1].key)
            if name == "positions":                 # [3, B, T]
                return leaf.reshape(leaf.shape[0], mb, B // mb,
                                    *leaf.shape[2:]).swapaxes(0, 1)
            return leaf.reshape(mb, B // mb, *leaf.shape[1:])
        return jax.tree_util.tree_map_with_path(one, batch)

    def train_step(params, opt_state, batch, rng):
        with set_policy(policy):
            grad_fn = jax.value_and_grad(model_lib.train_loss, has_aux=True)
            if mb == 1:
                (loss, metrics), grads = grad_fn(params, batch, rng, cfg)
            else:
                # gradient accumulation: bounds activation memory to one
                # microbatch (the per-device global batch doesn't fit HBM
                # at train_4k otherwise)
                mb_batch = split_mb(batch)
                acc0 = jax.tree_util.tree_map(
                    lambda p: jnp.zeros(p.shape, acc_dtype), params)
                if policy.zero1:
                    # ZeRO-2: keep the accumulator data-sharded so each
                    # microbatch's gradient reduction lowers to a
                    # reduce-scatter (half the all-reduce bytes); the
                    # updated params all-gather once per step.
                    saved = policy.fsdp
                    policy.fsdp = policy.opt_fsdp
                    try:
                        acc_specs = policy.param_specs(params)
                    finally:
                        policy.fsdp = saved
                    acc0 = jax.tree_util.tree_map(
                        jax.lax.with_sharding_constraint, acc0, acc_specs)

                def body(carry, xs):
                    acc, k = carry
                    bslice, i = xs
                    (loss, metrics), g = grad_fn(
                        params, bslice, jax.random.fold_in(k, i), cfg)
                    acc = jax.tree_util.tree_map(
                        lambda a, gi: a + (gi / mb).astype(acc_dtype),
                        acc, g)
                    return (acc, k), (loss, metrics)

                (grads, _), (losses, metricses) = jax.lax.scan(
                    body, (acc0, rng), (mb_batch, jnp.arange(mb)))
                metrics = jax.tree_util.tree_map(
                    lambda m: m.mean(), metricses)
            updates, opt_state = adamw_update(grads, opt_state, params,
                                              schedule)
            params = apply_updates(params, updates)
        return params, opt_state, metrics

    p_sh = policy.param_specs(params_shapes)
    o_sh = policy.opt_state_specs(opt_shapes)
    rep = NamedSharding(policy.mesh, P())
    in_sh = (p_sh, o_sh, _batch_shardings(batch_tree, policy), rep)
    out_sh = (p_sh, o_sh,
              jax.tree_util.tree_map(lambda _: rep,
                                     {"loss": 0, "xent": 0, "router_loss": 0,
                                      "moe_lb_loss": 0, "keep_frac": 0}))
    args = (params_shapes, opt_shapes, batch_tree, rng_shape)
    return train_step, args, in_sh, out_sh, (0, 1)


def _param_shapes(cfg: ModelConfig):
    """Parameter ShapeDtypeStructs — int4-coded when cfg.quant.enabled
    (the paper's W4 deployment: the dry-run lowers against the quantized
    tree so weight HBM/collective bytes reflect int4 storage)."""
    def init(key):
        p = model_lib.init_params(key, cfg)
        if cfg.quant.enabled:
            from repro.quant import quantize_params
            p = quantize_params(p, cfg.quant.group_size,
                                cfg.quant.pow2_scales)
        return p

    return jax.eval_shape(init, jax.random.PRNGKey(0))


def build_prefill_step(cfg: ModelConfig, policy: ShardingPolicy,
                       shape_name: str):
    cfg = serve_cfg(cfg)
    s = SHAPES[shape_name]
    batch_tree = batch_specs(cfg, s["global_batch"], s["seq_len"])
    params_shapes = _param_shapes(cfg)

    def prefill_step(params, batch):
        with set_policy(policy):
            logits, cache, stats = model_lib.prefill(params, batch, cfg)
        return logits, cache, {"keep": stats["keep_frac_sum"]}

    in_sh = (policy.param_specs(params_shapes),
             _batch_shardings(batch_tree, policy))
    args = (params_shapes, batch_tree)
    cache_shapes = jax.eval_shape(lambda p, b: prefill_step(p, b)[1],
                                  params_shapes, batch_tree)
    rep = NamedSharding(policy.mesh, P())
    out_sh = (_logits_sharding(cfg, policy, s["global_batch"]),
              policy.cache_specs(cache_shapes),
              {"keep": rep})
    return prefill_step, args, in_sh, out_sh, ()


def build_serve_step(cfg: ModelConfig, policy: ShardingPolicy,
                     shape_name: str):
    """decode_* / long_*: one new token against a seq_len-deep KV cache."""
    cfg = serve_cfg(cfg)
    s = SHAPES[shape_name]
    B, T = s["global_batch"], s["seq_len"]
    batch_tree = batch_specs(cfg, B, 1)
    params_shapes = _param_shapes(cfg)
    cache_shapes = jax.eval_shape(
        partial(model_lib.init_decode_cache, cfg, B, T))
    seq_shard = shape_name.startswith("long")
    if seq_shard:
        # serve-mode hints now default to head-sharded KV (the continuous
        # engine's split); the long shapes keep the sequence split the
        # seq_shard cache_specs build, so pin the in-step hints to match.
        seq = (("pod", "data", "model") if policy.has_pod
               else ("data", "model"))
        policy.overrides.setdefault("kv_cache_step", P(None, seq, None, None))
        policy.overrides.setdefault("kv_cache_step_bhtd",
                                    P(None, None, seq, None))
        policy.overrides.setdefault("kv_heads", P(None, None, None, None))
        policy.overrides.setdefault("kv_view", P(None, None, None, None))

    def serve_step(params, cache, batch, t):
        with set_policy(policy):
            logits, cache, stats = model_lib.decode_step(params, cache,
                                                         batch, t, cfg)
        return logits, cache, {"keep": stats["keep_frac_sum"]}

    cache_sh = policy.cache_specs(cache_shapes, seq_shard=seq_shard,
                                  layout=cfg.kv_cache_layout)
    rep = NamedSharding(policy.mesh, P())
    in_sh = (policy.param_specs(params_shapes), cache_sh,
             _batch_shardings(batch_tree, policy), rep)
    out_sh = (_logits_sharding(cfg, policy, B), cache_sh, {"keep": rep})
    args = (params_shapes, cache_shapes, batch_tree,
            _sds((), jnp.int32))
    return serve_step, args, in_sh, out_sh, (1,)


def build_step(cfg: ModelConfig, policy: ShardingPolicy, shape_name: str):
    kind = SHAPES[shape_name]["kind"]
    if kind == "train":
        return build_train_step(cfg, policy, shape_name)
    if kind == "prefill":
        return build_prefill_step(cfg, policy, shape_name)
    return build_serve_step(cfg, policy, shape_name)
