"""Training launcher.

Single-process usage (CPU smoke / examples):
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-8b --smoke \
      --steps 50 --batch 8 --seq 128

Cluster usage mirrors the dry-run configuration: the same ShardingPolicy /
mesh / step builder lower the identical program on real TPU pods (the
launcher also sets the XLA latency-hiding-scheduler flags that enable
compute/communication overlap on device).
"""
import os

TPU_PERF_FLAGS = (
    " --xla_tpu_enable_latency_hiding_scheduler=true"
    " --xla_tpu_enable_async_collective_fusion=true"
    " --xla_tpu_overlap_compute_collective_tc=true"
)
if os.environ.get("REPRO_TPU"):
    os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + TPU_PERF_FLAGS

import argparse
import dataclasses
import json

import jax


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--mesh", default=None,
                    help="data,model e.g. 2,2 (needs that many devices)")
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.distributed.sharding import ShardingPolicy
    from repro.train.trainer import Trainer, TrainerConfig

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    policy = None
    if args.mesh:
        d, m = (int(x) for x in args.mesh.split(","))
        mesh = jax.make_mesh((d, m), ("data", "model"))
        policy = ShardingPolicy(mesh, cfg, mode="train")

    tcfg = TrainerConfig(seq_len=args.seq, global_batch=args.batch,
                         steps=args.steps, lr=args.lr,
                         ckpt_dir=args.ckpt_dir,
                         grad_compression=args.grad_compression)
    trainer = Trainer(cfg, tcfg, policy)
    state = trainer.run(resume=args.resume)
    for m in trainer.metrics_log:
        print(json.dumps(m))
    print(f"final loss: {trainer.metrics_log[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
