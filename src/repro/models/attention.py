"""Attention: GQA projections (RoPE/M-RoPE, qk-norm) + chunked online-softmax
attention.

The chunked scan is the XLA realization of the paper's Alg. 2 (deep-fused
self-attention): softmax row statistics (running max, running Σexp) are
accumulated *incrementally per KV tile* so no full attention row is ever
materialized — identical update rule to FlashAttention, which the paper
itself adopts.  The Pallas kernel in ``repro/kernels/flash_attention.py`` is
the TPU-tiled version of the same dataflow; this module is the pure-jnp
path XLA can fuse (and the oracle the kernel is tested against).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers
from repro.models.layers import Params

NEG_INF = -1.0e30


# ---------------------------------------------------------------------------
# Projections
# ---------------------------------------------------------------------------

def attention_init(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 2)
    d, ai, ki = cfg.d_model, cfg.attn_inner_dim, cfg.kv_inner_dim
    p: Params = {
        # widened [q | k | v] projection: one k-loop serves all three
        # (the fused norm-prologue then runs once per block, not thrice)
        "wqkv": layers.linear_init(ks[0], d, ai + 2 * ki, cfg),
        "wo": layers.linear_init(ks[1], ai, d, cfg),
    }
    if cfg.qk_norm:
        p["qnorm"] = layers.rms_head_norm_init(cfg.resolved_head_dim, cfg)
        p["knorm"] = layers.rms_head_norm_init(cfg.resolved_head_dim, cfg)
    return p


def _wq(params: Params, cfg: ModelConfig) -> Params:
    if "wqkv" in params:
        return layers.slice_linear(params["wqkv"], 0, cfg.attn_inner_dim)
    return params["wq"]                                   # legacy split


def _wkv(params: Params, cfg: ModelConfig) -> Tuple[Params, Params]:
    ai, ki = cfg.attn_inner_dim, cfg.kv_inner_dim
    if "wqkv" in params:
        return (layers.slice_linear(params["wqkv"], ai, ai + ki),
                layers.slice_linear(params["wqkv"], ai + ki, ai + 2 * ki))
    return params["wk"], params["wv"]                     # legacy split


def _finish_q(params, q, positions, cfg: ModelConfig) -> jnp.ndarray:
    B, T = q.shape[:2]
    q = q.reshape(B, T, cfg.num_heads, cfg.resolved_head_dim)
    if cfg.qk_norm:
        q = layers.rms_head_norm(params["qnorm"], q, cfg.norm_eps)
    return layers.apply_rope(q, positions, cfg)


def _finish_kv(params, k, v, positions, cfg: ModelConfig):
    B, T = k.shape[:2]
    k = k.reshape(B, T, cfg.num_kv_heads, cfg.resolved_head_dim)
    v = v.reshape(B, T, cfg.num_kv_heads, cfg.resolved_head_dim)
    if cfg.qk_norm:
        k = layers.rms_head_norm(params["knorm"], k, cfg.norm_eps)
    k = layers.apply_rope(k, positions, cfg)
    return k, v


def project_q(params: Params, x: jnp.ndarray, positions: jnp.ndarray,
              cfg: ModelConfig, *, norm: Optional[Params] = None,
              stats: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """x: [B, T, D] -> q: [B, T, Hq, dh] (rope'd, qk-normed).

    With ``norm``/``stats`` the RMSNorm elementwise phase fuses into the
    projection's k-loop (x is un-normalized; stats is the injected
    reduction).  Without them x must already be normalized."""
    if norm is not None and layers.fuse_norm_linear(cfg):
        q, _ = layers.linear_fused(_wq(params, cfg), x, cfg,
                                   norm=norm, stats=stats)
    else:
        if norm is not None:
            x = layers.norm_apply(norm, x, cfg, stats=stats)
        q = layers.linear_apply(_wq(params, cfg), x, cfg)
    return _finish_q(params, q, positions, cfg)


def project_kv(params: Params, x: jnp.ndarray, positions: jnp.ndarray,
               cfg: ModelConfig, *, norm: Optional[Params] = None,
               stats: Optional[jnp.ndarray] = None
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: [B, T, D] -> (k, v): [B, T, Hkv, dh].  K is stored post-RoPE so that
    cross-layer KV reuse (paper §2.1) inherits rotated keys unchanged.
    ``norm``/``stats`` fuse the norm prologue as in ``project_q``."""
    wk, wv = _wkv(params, cfg)
    if norm is not None and layers.fuse_norm_linear(cfg):
        ki = cfg.kv_inner_dim
        if "wqkv" in params:
            ai = cfg.attn_inner_dim
            wkv = layers.slice_linear(params["wqkv"], ai, ai + 2 * ki)
            kv, _ = layers.linear_fused(wkv, x, cfg, norm=norm, stats=stats)
            k, v = kv[..., :ki], kv[..., ki:]
        else:
            # legacy split weights: two prologue-fused calls (a merged
            # view would re-concatenate the weights on every step)
            k, _ = layers.linear_fused(wk, x, cfg, norm=norm, stats=stats)
            v, _ = layers.linear_fused(wv, x, cfg, norm=norm, stats=stats)
    else:
        if norm is not None:
            x = layers.norm_apply(norm, x, cfg, stats=stats)
        k = layers.linear_apply(wk, x, cfg)
        v = layers.linear_apply(wv, x, cfg)
    return _finish_kv(params, k, v, positions, cfg)


def project_qkv(params: Params, x: jnp.ndarray, positions: jnp.ndarray,
                cfg: ModelConfig, *, norm: Optional[Params] = None,
                stats: Optional[jnp.ndarray] = None
                ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Single widened projection producing q, k, v in one k-loop pass —
    with ``norm``/``stats``, the normalized activation lives only in VMEM
    (Alg. 1 prologue fusion; composes with int4-BFP weights)."""
    ai, ki = cfg.attn_inner_dim, cfg.kv_inner_dim
    if "wqkv" not in params:                              # legacy split
        q = project_q(params, x, positions, cfg, norm=norm, stats=stats)
        k, v = project_kv(params, x, positions, cfg, norm=norm, stats=stats)
        return q, k, v
    if norm is not None and layers.fuse_norm_linear(cfg):
        qkv, _ = layers.linear_fused(params["wqkv"], x, cfg,
                                     norm=norm, stats=stats)
    else:
        if norm is not None:
            x = layers.norm_apply(norm, x, cfg, stats=stats)
        qkv = layers.linear_apply(params["wqkv"], x, cfg)
    q = _finish_q(params, qkv[..., :ai], positions, cfg)
    k, v = _finish_kv(params, qkv[..., ai:ai + ki], qkv[..., ai + ki:],
                      positions, cfg)
    return q, k, v


def output_proj(params: Params, o: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    B, T = o.shape[:2]
    return layers.linear_apply(params["wo"], o.reshape(B, T, cfg.attn_inner_dim), cfg)


def output_proj_fused(params: Params, o: jnp.ndarray, cfg: ModelConfig, *,
                      residual: jnp.ndarray,
                      gate_mul: Optional[jnp.ndarray] = None,
                      emit_sq: bool = False):
    """Fused o-projection epilogue: y = (o·Wo)·gate + residual in one
    kernel, optionally emitting Σy² of the written residual stream — the
    next block's norm reduction (incremental-reduction carry).  Returns
    (new residual stream, Σy²|None)."""
    B, T = o.shape[:2]
    return layers.linear_fused(
        params["wo"], o.reshape(B, T, cfg.attn_inner_dim), cfg,
        residual=residual, gate_mul=gate_mul, emit_sq=emit_sq)


# ---------------------------------------------------------------------------
# Chunked online-softmax attention (Alg. 2 dataflow)
# ---------------------------------------------------------------------------

def _mask_for_chunk(q_pos: jnp.ndarray, kv_pos: jnp.ndarray, *, causal: bool,
                    window: int, kv_valid_len: Optional[jnp.ndarray],
                    batch: int) -> jnp.ndarray:
    """Boolean [B, Tq, Ck] mask (True = attend).  kv_pos is [Ck] (shared) or
    [B, Ck] (per-sequence — ragged decode over ring/slot caches)."""
    qp = q_pos[:, :, None]           # [B, Tq, 1]
    kp = kv_pos[:, None, :] if kv_pos.ndim == 2 else kv_pos[None, None, :]
    m = jnp.ones((batch, q_pos.shape[1], kv_pos.shape[-1]), bool)
    if causal:
        m &= kp <= qp
    if window:
        m &= kp > qp - window
    if kv_valid_len is not None:
        m &= kp < kv_valid_len[:, None, None]
    return m


def chunked_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                      q_positions: jnp.ndarray,
                      causal: bool = True,
                      window: int = 0,
                      kv_valid_len: Optional[jnp.ndarray] = None,
                      chunk: int = 1024,
                      softmax_scale: Optional[float] = None,
                      kv_positions: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Online-softmax attention over KV chunks.

    q: [B, Tq, Hq, dh] — Tq may be a *gathered subset* of positions (SkipGPT
       gather mode); ``q_positions`` [B, Tq] carries original indices for the
       causal/window masks.
    k, v: [B, Tk, Hkv, dh] — the (possibly reused) per-layer KV view.
    kv_positions: optional explicit [Tk] or [B, Tk] absolute positions
       (ring-buffer caches; per-sequence for ragged decode); default
       arange(Tk).
    Returns [B, Tq, Hq, dh].
    """
    B, Tq, Hq, dh = q.shape
    Tk, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(dh)

    # scale in fp32, then back to the storage dtype: the QK/PV dots run on
    # bf16 operands with fp32 accumulation (preferred_element_type) so the
    # KV cache is never materialized in fp32 (2× HBM traffic otherwise).
    qT = (q.astype(jnp.float32) * scale).astype(q.dtype)
    qT = qT.reshape(B, Tq, Hkv, G, dh).transpose(0, 2, 3, 1, 4)  # [B,Hkv,G,Tq,dh]

    chunk = min(chunk, Tk)
    pad = (-Tk) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        if kv_positions is not None:
            pads = [(0, 0)] * (kv_positions.ndim - 1) + [(0, pad)]
            kv_positions = jnp.pad(kv_positions, pads,
                                   constant_values=jnp.iinfo(jnp.int32).max)
        elif kv_valid_len is None:
            # padded tail masked via kv_valid_len
            kv_valid_len = jnp.full((B,), Tk, jnp.int32)
    nc = k.shape[1] // chunk
    kc = k.transpose(1, 0, 2, 3).reshape(nc, chunk, B, Hkv, dh)
    vc = v.transpose(1, 0, 2, 3).reshape(nc, chunk, B, Hkv, dh)

    m0 = jnp.full((B, Hkv, G, Tq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G, Tq), jnp.float32)
    a0 = jnp.zeros((B, Hkv, G, Tq, dh), jnp.float32)

    def body(carry, inp):
        m, l, acc = carry
        ci, k_c, v_c = inp
        k_c = k_c.transpose(1, 0, 2, 3)                    # [B,chunk,Hkv,dh]
        v_c = v_c.transpose(1, 0, 2, 3)
        s = jnp.einsum("bhgqd,bkhd->bhgqk", qT, k_c,
                       preferred_element_type=jnp.float32)
        if kv_positions is not None:
            kv_pos = jax.lax.dynamic_slice_in_dim(
                kv_positions, ci * chunk, chunk, axis=kv_positions.ndim - 1)
        else:
            kv_pos = ci * chunk + jnp.arange(chunk)
        mask = _mask_for_chunk(q_positions, kv_pos, causal=causal,
                               window=window, kv_valid_len=kv_valid_len,
                               batch=B)                     # [B,Tq,chunk]
        s = jnp.where(mask[:, None, None, :, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhgqk,bkhd->bhgqd", p.astype(v_c.dtype), v_c,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    if nc == 1:
        (m, l, acc), _ = body((m0, l0, a0), (jnp.int32(0), kc[0], vc[0]))
    else:
        (m, l, acc), _ = jax.lax.scan(
            body, (m0, l0, a0), (jnp.arange(nc), kc, vc))

    out = acc / jnp.maximum(l, 1e-20)[..., None]            # [B,Hkv,G,Tq,dh]
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, Tq, Hq, dh)
    return out.astype(q.dtype)


def decode_attention_bhtd(q, k, v, *, q_positions, cfg: ModelConfig,
                          kv_valid_len=None) -> jnp.ndarray:
    """Single-token attention against a head-major [B, Hkv, T, dh] cache —
    the dots consume the cache directly (no per-layer relayout transpose).
    q: [B, 1, Hq, dh]."""
    B, _, Hq, dh = q.shape
    Hkv, Tk = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(dh)
    qT = (q.astype(jnp.float32) * scale).astype(q.dtype)
    qT = qT.reshape(B, Hkv, G, dh)
    s = jnp.einsum("bhgd,bhkd->bhgk", qT, k,
                   preferred_element_type=jnp.float32)
    kv_pos = jnp.arange(Tk)
    mask = kv_pos[None, :] < kv_valid_len[:, None] if kv_valid_len is not None \
        else jnp.ones((B, Tk), bool)
    mask &= kv_pos[None, :] <= q_positions[:, :1]
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bhkd->bhgd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, 1, Hq, dh).astype(q.dtype)


def attention_core(q, k, v, *, q_positions, cfg: ModelConfig,
                   causal: bool = True, window: int = 0,
                   kv_valid_len=None) -> jnp.ndarray:
    """Dispatch between the Pallas kernel and the chunked-jnp path."""
    if cfg.use_kernels and q.shape[1] > 1:
        from repro.kernels import ops as kops
        return kops.flash_attention(
            q, k, v, q_positions=q_positions, causal=causal, window=window,
            kv_valid_len=kv_valid_len)
    if cfg.use_kernels and q.shape[1] == 1:
        from repro.kernels import ops as kops
        return kops.decode_attention(
            q, k, v, q_positions=q_positions, window=window,
            kv_valid_len=kv_valid_len)
    # decode (Tq == 1): single-block attention — scores are [B, Hq, 1, Tk]
    # (tiny), and the KV length stays a *contraction* dim that GSPMD shards
    # sequence-parallel instead of a scan axis it would have to replicate.
    chunk = k.shape[1] if q.shape[1] == 1 else cfg.attn_chunk
    return chunked_attention(
        q, k, v, q_positions=q_positions, causal=causal, window=window,
        kv_valid_len=kv_valid_len, chunk=chunk)


def reference_attention(q, k, v, *, q_positions, causal=True, window=0,
                        kv_valid_len=None, softmax_scale=None) -> jnp.ndarray:
    """Dense O(Tq·Tk) oracle (tests only)."""
    B, Tq, Hq, dh = q.shape
    Tk, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(dh)
    qg = q.reshape(B, Tq, Hkv, G, dh).astype(jnp.float32) * scale
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k.astype(jnp.float32))
    mask = _mask_for_chunk(q_positions, jnp.arange(Tk), causal=causal,
                           window=window, kv_valid_len=kv_valid_len, batch=B)
    s = jnp.where(mask[:, None, None, :, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(B, Tq, Hq, dh).astype(q.dtype)
