"""Shared neural-net layers: norms, positional embeddings, (quantizable)
linear projections, activations.

Everything is pure-functional: ``*_init(key, ...) -> params`` and
``*_apply(params, x, ...) -> y``.  Params are plain nested dicts of
``jnp.ndarray`` so the whole model is a pytree.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

Params = Dict[str, jnp.ndarray]


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def trunc_normal(key, shape, scale: float, dtype) -> jnp.ndarray:
    """Truncated-normal init (±2σ) with fan-in scaling handled by caller."""
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * scale).astype(dtype)


# ---------------------------------------------------------------------------
# Linear (optionally int4-quantized per paper §4.2)
# ---------------------------------------------------------------------------

def linear_init(key, in_dim: int, out_dim: int, cfg: ModelConfig,
                scale: Optional[float] = None) -> Params:
    scale = scale if scale is not None else 1.0 / math.sqrt(in_dim)
    return {"w": trunc_normal(key, (in_dim, out_dim), scale, _dtype(cfg))}


def linear_apply(params: Params, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """Dispatches dense vs int4-quantized weights.

    Quantized params carry ``w_int`` (int8 storage of int4 codes),
    ``scale`` [K/G, N] (power-of-2 when cfg.quant.pow2_scales — the BFP domain).
    """
    if "w_int" in params:
        from repro.kernels import ops as kops
        return kops.int4_matmul(x, params["w_int"], params["scale"],
                                use_kernel=cfg.use_kernels)
    return x @ params["w"]


def slice_linear(params: Params, lo: int, hi: int) -> Params:
    """Output-column slice of a (possibly quantized) linear param dict —
    the legacy split views over merged wqkv / w_gu weights.  Per-group
    scales index output columns, so slicing preserves the BFP grouping."""
    if "w_int" in params:
        return {"w_int": params["w_int"][:, lo:hi],
                "scale": params["scale"][:, lo:hi]}
    return {"w": params["w"][:, lo:hi]}


def fuse_norm_linear(cfg: ModelConfig) -> bool:
    """True when the fused norm-prologue linear pipeline dispatches: the
    Pallas path is on and the norm is RMS (the carried reduction is a
    single Σx²; layernorm's (μ, σ²) pair stays on the unfused path)."""
    return cfg.use_kernels and cfg.fuse_linear and cfg.norm_type == "rmsnorm"


def linear_fused(params: Params, x: jnp.ndarray, cfg: ModelConfig, *,
                 norm: Optional[Params] = None,
                 stats: Optional[jnp.ndarray] = None,
                 glu: bool = False, act: Optional[str] = None,
                 residual: Optional[jnp.ndarray] = None,
                 gate_mul: Optional[jnp.ndarray] = None,
                 emit_sq: bool = False):
    """One fused-pipeline matmul (norm-prologue × weight × epilogue).

    Callers pass the *un-normalized* activation plus the injected norm
    reduction (``stats`` == mean(x²)); the elementwise phase runs inside
    the kernel's k-loop.  Only dispatched when ``fuse_norm_linear(cfg)``
    (callers keep the composed norm_apply + linear_apply path otherwise)."""
    from repro.kernels import ops as kops
    return kops.fused_linear(
        params, x,
        mean_sq=None if norm is None else stats,
        gamma=None if norm is None else norm["gamma"],
        eps=cfg.norm_eps, glu=glu, act=act, residual=residual,
        gate_mul=gate_mul, emit_sq=emit_sq)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def norm_init(dim: int, cfg: ModelConfig) -> Params:
    p = {"gamma": jnp.ones((dim,), _dtype(cfg))}
    if cfg.norm_type == "layernorm":
        p["beta"] = jnp.zeros((dim,), _dtype(cfg))
    return p


def norm_apply(params: Params, x: jnp.ndarray, cfg: ModelConfig,
               stats: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """RMSNorm / LayerNorm with fp32 statistics.

    ``stats`` lets the caller inject *precomputed* normalization statistics —
    the decoupled-reduction path of the paper's Alg. 1 (statistics are
    accumulated during the router matmul, elementwise phase runs later).
    For rmsnorm stats == mean(x²); for layernorm stats == (mean, var).
    """
    xf = x.astype(jnp.float32)
    if cfg.norm_type == "rmsnorm":
        ms = jnp.mean(xf * xf, axis=-1, keepdims=True) if stats is None \
            else stats[..., None]
        y = xf * jax.lax.rsqrt(ms + cfg.norm_eps)
        return (y * params["gamma"].astype(jnp.float32)).astype(x.dtype)
    if stats is None:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    else:
        mu, var = stats[0][..., None], stats[1][..., None]
    y = (xf - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
    y = y * params["gamma"].astype(jnp.float32) + params["beta"].astype(jnp.float32)
    return y.astype(x.dtype)


def norm_stats(x: jnp.ndarray, cfg: ModelConfig):
    """The reduction phase alone (paper Alg. 1 line 6).

    Layernorm variance uses the two-pass mean((x−μ)²) form — the one-pass
    E[x²]−μ² form cancels catastrophically for large-offset activations
    and diverged from ``norm_apply``'s own unfused computation."""
    xf = x.astype(jnp.float32)
    if cfg.norm_type == "rmsnorm":
        return jnp.mean(xf * xf, axis=-1)
    mu = jnp.mean(xf, axis=-1)
    var = jnp.mean(jnp.square(xf - mu[..., None]), axis=-1)
    return (mu, var)


def rms_head_norm_init(dim: int, cfg: ModelConfig) -> Params:
    return {"gamma": jnp.ones((dim,), _dtype(cfg))}


def rms_head_norm(params: Params, x: jnp.ndarray, eps: float) -> jnp.ndarray:
    """Per-head qk-norm (RMS over head_dim)."""
    xf = x.astype(jnp.float32)
    ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps)
            * params["gamma"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings (RoPE, partial RoPE, M-RoPE)
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, rotary_pct: float, theta: float) -> jnp.ndarray:
    rot_dim = int(head_dim * rotary_pct) // 2 * 2
    inv = 1.0 / (theta ** (jnp.arange(0, rot_dim, 2, dtype=jnp.float32) / rot_dim))
    return inv  # [rot_dim // 2]


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, cfg: ModelConfig,
               ) -> jnp.ndarray:
    """x: [..., T, H, D]; positions: [B, T] (rope) or [3, B, T] (mrope)."""
    if cfg.pos_embedding not in ("rope", "mrope"):
        return x
    d = x.shape[-1]
    inv = rope_freqs(d, cfg.rotary_pct, cfg.rope_theta)      # [R/2]
    half = inv.shape[0]
    if cfg.pos_embedding == "mrope":
        # Sections (t, h, w) partition the R/2 frequency slots; each section
        # consumes its own position stream (Qwen2-VL M-RoPE).
        sec = cfg.mrope_sections
        assert sum(sec) == half, (sec, half)
        pos_f = positions.astype(jnp.float32)                # [3, B, T]
        freq_parts = []
        off = 0
        for s_i, n in enumerate(sec):
            freq_parts.append(pos_f[s_i][..., None] * inv[off:off + n])
            off += n
        freqs = jnp.concatenate(freq_parts, axis=-1)          # [B, T, R/2]
    else:
        freqs = positions.astype(jnp.float32)[..., None] * inv  # [B, T, R/2]
    cos = jnp.cos(freqs)[..., None, :]                        # [B, T, 1, R/2]
    sin = jnp.sin(freqs)[..., None, :]
    rot = 2 * half
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    x1, x2 = x_rot[..., :half], x_rot[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out1 = xf1 * cos - xf2 * sin
    out2 = xf2 * cos + xf1 * sin
    out = jnp.concatenate([out1, out2], axis=-1).astype(x.dtype)
    if x_pass.shape[-1]:
        out = jnp.concatenate([out, x_pass], axis=-1)
    return out


def sinusoidal_positions(positions: jnp.ndarray, dim: int) -> jnp.ndarray:
    """[B, T] -> [B, T, dim] classic sinusoidal table (MusicGen-style)."""
    half = dim // 2
    freq = jnp.exp(-math.log(10_000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GeGLU / GELU)
# ---------------------------------------------------------------------------

def mlp_init(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> Params:
    d_ff = d_ff or cfg.d_ff
    k1, k2 = jax.random.split(key, 2)
    glu = cfg.mlp_act in ("swiglu", "geglu")
    if glu:
        # widened [gate | up] projection: one matmul feeds the GLU epilogue
        p = {"gu": linear_init(k1, cfg.d_model, 2 * d_ff, cfg)}
    else:
        p = {"up": linear_init(k1, cfg.d_model, d_ff, cfg)}
    p["down"] = linear_init(k2, d_ff, cfg.d_model, cfg)
    return p


def mlp_act_name(cfg: ModelConfig) -> Optional[str]:
    return {"swiglu": "silu", "geglu": "gelu", "gelu_mlp": "gelu"}.get(
        cfg.mlp_act, "gelu")


def mlp_apply(params: Params, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """Dense MLP on an already-normalized activation (unfused path).
    Accepts both the merged ``gu`` layout and legacy split gate/up."""
    if "gu" in params:
        gu = linear_apply(params["gu"], x, cfg)
        F = gu.shape[-1] // 2
        g, up = gu[..., :F], gu[..., F:]
        h = (jax.nn.silu(g) if cfg.mlp_act == "swiglu"
             else jax.nn.gelu(g)) * up
    else:
        up = linear_apply(params["up"], x, cfg)
        if cfg.mlp_act == "swiglu":
            h = jax.nn.silu(linear_apply(params["gate"], x, cfg)) * up
        elif cfg.mlp_act == "geglu":
            h = jax.nn.gelu(linear_apply(params["gate"], x, cfg)) * up
        else:
            h = jax.nn.gelu(up)
    return linear_apply(params["down"], h, cfg)


def mlp_apply_fused(params: Params, x: jnp.ndarray, cfg: ModelConfig, *,
                    norm: Params, stats: jnp.ndarray,
                    residual: Optional[jnp.ndarray] = None,
                    gate_mul: Optional[jnp.ndarray] = None,
                    emit_sq: bool = False):
    """Fused-pipeline dense MLP on the *un-normalized* activation:
    norm-prologue × widened [gate|up] × GLU epilogue, then the down
    projection with the gate-multiplier/residual/Σy² epilogue.  The
    normalized activation and the GLU intermediate never round-trip HBM
    separately from their matmuls.  Returns (y_or_residual_out, Σy²|None).
    """
    glu = "gu" in params
    h, _ = linear_fused(params["gu"] if glu else params["up"], x, cfg,
                        norm=norm, stats=stats, glu=glu,
                        act=mlp_act_name(cfg))
    return linear_fused(params["down"], h, cfg, residual=residual,
                        gate_mul=gate_mul, emit_sq=emit_sq)


def mlp_fusable(params: Params) -> bool:
    """Dense-MLP param dicts the fused pipeline understands: merged
    [gate|up] or plain up/down.  MoE keeps its scatter-dispatch path and
    legacy *split* GLU params fall back to the composed ops (run them
    through ``merge_legacy_linear_params`` to enable fusion)."""
    return "gu" in params or ("up" in params and "down" in params
                              and "gate" not in params)


def _concat_linears(parts) -> Params:
    """Column-concat linear param dicts.  All-quantized parts concat in
    the code domain; a mixed dense/int4 list (quantize_params' size
    threshold can split a legacy wq/wk/wv trio) is dequantized to a dense
    merge — correctness over storage for that corner."""
    if all("w_int" in p for p in parts) and len(
            {p["w_int"].shape[0] for p in parts}) == 1:
        return {"w_int": jnp.concatenate([p["w_int"] for p in parts], 1),
                "scale": jnp.concatenate([p["scale"] for p in parts], 1)}
    from repro.quant import dequantize

    dense = [p for p in parts if "w" in p]
    k = dense[0]["w"].shape[0] if dense else parts[0]["w_int"].shape[0]
    dt = dense[0]["w"].dtype if dense else jnp.float32
    ws = [p["w"] if "w" in p
          else dequantize(p["w_int"], p["scale"], k=k).astype(dt)
          for p in parts]
    return {"w": jnp.concatenate(ws, axis=1)}


def merge_legacy_linear_params(params: Params) -> Params:
    """Weight-merge shim: convert legacy split projections — attention
    {wq, wk, wv} and GLU-MLP {gate, up} — into the merged ``wqkv`` /
    ``gu`` layouts the fused pipeline uses.  Works on dense and
    int4-quantized trees (checkpoints from either era load fine)."""
    def walk(tree):
        if not isinstance(tree, dict):
            return tree
        out = {k: walk(v) for k, v in tree.items()}
        if {"wq", "wk", "wv"} <= set(out):
            out["wqkv"] = _concat_linears(
                [out.pop("wq"), out.pop("wk"), out.pop("wv")])
        if {"gate", "up", "down"} <= set(out) and isinstance(
                out["gate"], dict) and ("w" in out["gate"]
                                        or "w_int" in out["gate"]):
            out["gu"] = _concat_linears([out.pop("gate"), out.pop("up")])
        return out

    return walk(params)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def embedding_init(key, cfg: ModelConfig) -> Params:
    p = {"table": trunc_normal(key, (cfg.vocab_size, cfg.d_model), 0.02, _dtype(cfg))}
    return p


def embed(params: Params, tokens: jnp.ndarray) -> jnp.ndarray:
    return params["table"][tokens]


def unembed(params: Params, head_params: Optional[Params], x: jnp.ndarray,
            cfg: ModelConfig) -> jnp.ndarray:
    if cfg.tie_embeddings:
        return x @ params["table"].T
    return linear_apply(head_params, x, cfg)
