"""LanguageModel: init / train loss / prefill / decode-step over the
heterogeneous layer stack, with the SkipGPT routing + KV-reuse pipeline
threaded through every layer.

Public entry points (all pure functions of (cfg, params, ...)):
  init_params            — parameter pytree
  train_loss             — chunked-softmax LM loss + router/MoE aux losses
  prefill                — forward pass that builds the per-layer KV caches
  decode_step            — one-token autoregressive step over those caches
  init_decode_cache      — zero caches for decode-only lowering (dry-run)
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ATTN, LOCAL, MAMBA, ModelConfig
from repro.distributed.sharding import hint
from repro.models import layers, ssm as ssm_mod, transformer
from repro.models.layers import Params


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def init_params(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 4)
    p: Params = {
        "embed": layers.embedding_init(ks[0], cfg),
        "stack": transformer.stack_init(ks[1], cfg),
        "final_norm": layers.norm_init(cfg.d_model, cfg),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = layers.linear_init(ks[2], cfg.d_model, cfg.vocab_size,
                                          cfg, scale=0.02)
    return p


# ---------------------------------------------------------------------------
# Input plumbing
# ---------------------------------------------------------------------------

def _positions(batch: Dict[str, jnp.ndarray], B: int, T: int,
               cfg: ModelConfig) -> jnp.ndarray:
    if "positions" in batch:
        return batch["positions"]
    pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
    if cfg.pos_embedding == "mrope":
        return jnp.broadcast_to(pos[None], (3, B, T))
    return pos


def _embed_inputs(params: Params, batch: Dict[str, jnp.ndarray],
                  positions: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    if cfg.frontend == "token":
        x = layers.embed(params["embed"], batch["tokens"])
    else:
        # audio/vlm stub: the modality frontend is out of scope (paper
        # backbone only); precomputed frame/patch embeddings come in.
        x = batch["embeds"].astype(jnp.dtype(cfg.dtype))
    if cfg.pos_embedding == "sinusoidal":
        pos = positions if positions.ndim == 2 else positions[0]
        x = x + layers.sinusoidal_positions(pos, cfg.d_model).astype(x.dtype)
    return hint(x, "activation")


# ---------------------------------------------------------------------------
# Stack application
# ---------------------------------------------------------------------------

def _apply_stack(params: Params, x: jnp.ndarray, positions: jnp.ndarray,
                 cfg: ModelConfig, rng: Optional[jax.Array], train: bool,
                 collect_cache: bool
                 ) -> Tuple[jnp.ndarray, Dict, Optional[Dict],
                            Optional[jnp.ndarray]]:
    """Returns (x, stats, cache, carried_sq) — the trailing element is the
    fused pipeline's incremental-reduction carry of the final residual
    stream (mean-square per token; feeds the final norm for free)."""
    stack = params["stack"]
    S = cfg.num_stages
    r0 = jax.random.fold_in(rng, 0) if rng is not None else None

    def stage0_fn(sp, x):
        x = hint(x, "residual")
        return transformer.stage_forward(
            sp, x, None, positions, cfg, r0, train, collect_cache, True)

    if cfg.remat:
        stage0_fn = jax.checkpoint(stage0_fn)
    x, view, stats, cache0, sq = stage0_fn(stack["stage0"], x)
    gates = stats.pop("attn_gate", None)    # [nA_stage, B, T] or None
    cache: Optional[Dict] = {"stage0": cache0} if collect_cache else None

    if S > 1:
        keys = (jax.random.split(jax.random.fold_in(rng, 1), S - 1)
                if rng is not None else None)

        def body(carry, xs):
            x, view, sq = carry
            x = hint(x, "residual")
            if view is not None:
                view = (hint(view[0], "kv_view"), hint(view[1], "kv_view"))
            if keys is not None:
                sp, k = xs
            else:
                sp, k = xs, None
            x, view, s, c, sq = transformer.stage_forward(
                sp, x, view, positions, cfg, k, train, collect_cache, False,
                carried_sq=sq)
            g = s.pop("attn_gate", None)
            if view is not None:
                view = (hint(view[0], "kv_view"), hint(view[1], "kv_view"))
            return (hint(x, "residual"), view, sq), (s, c, g)

        if cfg.remat:
            body = jax.checkpoint(body)
        if cfg.scan_layers:
            xs = (stack["stages"], keys) if keys is not None else stack["stages"]
            (x, view, sq), (s_scan, c_scan, g_scan) = jax.lax.scan(
                body, (x, view, sq), xs)
            stats = jax.tree_util.tree_map(lambda a, b: a + b.sum(axis=0),
                                           stats, s_scan)
            if collect_cache:
                cache["stages"] = c_scan
            if gates is not None:
                gates = jnp.concatenate([gates[None], g_scan], axis=0)
        else:
            # unrolled (dry-run accounting mode: XLA cost_analysis does not
            # multiply while-loop bodies by trip count)
            c_list, g_list = [], []
            for i in range(S - 1):
                sp = jax.tree_util.tree_map(lambda l: l[i], stack["stages"])
                xs = (sp, keys[i]) if keys is not None else sp
                (x, view, sq), (s, c, g) = body((x, view, sq), xs)
                stats = jax.tree_util.tree_map(lambda a, b: a + b, stats, s)
                c_list.append(c)
                g_list.append(g)
            if collect_cache:
                cache["stages"] = jax.tree_util.tree_map(
                    lambda *ls: jnp.stack(ls), *c_list)
            if gates is not None:
                gates = jnp.concatenate(
                    [gates[None]] + [g[None] for g in g_list], axis=0)
        if gates is not None:
            # [S, nA_stage, B, T] -> [L_attn, B, T] in stack order
            gates = gates.reshape((-1,) + gates.shape[-2:])
    if gates is not None:
        stats["attn_gate"] = gates
    return x, stats, cache, sq


# ---------------------------------------------------------------------------
# Training loss (chunked softmax cross-entropy)
# ---------------------------------------------------------------------------

def _xent_chunk(x: jnp.ndarray, labels: jnp.ndarray, weights: jnp.ndarray,
                params: Params, cfg: ModelConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: [B, Tc, D] -> (sum nll, sum weight).  Bounds peak logits memory to
    one sequence chunk (important for the 262k-vocab archs)."""
    logits = layers.unembed(params["embed"], params.get("lm_head"), x, cfg)
    logits = hint(logits, "logits").astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = (lse - ll) * weights
    return nll.sum(), weights.sum()


def chunked_xent(x: jnp.ndarray, labels: jnp.ndarray, weights: jnp.ndarray,
                 params: Params, cfg: ModelConfig) -> jnp.ndarray:
    B, T, D = x.shape
    C = min(cfg.xent_chunk, T)
    if T % C:
        C = T
    nc = T // C
    if nc == 1:
        nll, w = _xent_chunk(x, labels, weights, params, cfg)
        return nll / jnp.maximum(w, 1.0)

    def chunk_fn(xc, lc, wc, params):
        return _xent_chunk(xc, lc, wc, params, cfg)

    if cfg.remat:
        chunk_fn = jax.checkpoint(chunk_fn)

    def body(carry, inp):
        xc, lc, wc = inp
        nll, w = chunk_fn(xc, lc, wc, params)
        return (carry[0] + nll, carry[1] + w), None

    xs = (x.reshape(B, nc, C, D).swapaxes(0, 1),
          labels.reshape(B, nc, C).swapaxes(0, 1),
          weights.reshape(B, nc, C).swapaxes(0, 1))
    (nll, w), _ = jax.lax.scan(body, (jnp.float32(0.0), jnp.float32(0.0)), xs)
    return nll / jnp.maximum(w, 1.0)


def train_loss(params: Params, batch: Dict[str, jnp.ndarray],
               rng: Optional[jax.Array], cfg: ModelConfig
               ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    if cfg.frontend == "token":
        B, T = batch["tokens"].shape
    else:
        B, T = batch["embeds"].shape[:2]
    positions = _positions(batch, B, T, cfg)
    x = _embed_inputs(params, batch, positions, cfg)
    x, stats, _, sq = _apply_stack(params, x, positions, cfg, rng, True, False)
    x = layers.norm_apply(params["final_norm"], x, cfg, stats=sq)

    labels = batch["labels"]
    weights = batch.get("loss_weights",
                        jnp.ones(labels.shape, jnp.float32))
    xent = chunked_xent(x, labels, weights, params, cfg)

    router_loss = stats["router_loss"]
    moe_lb = stats["moe_lb_loss"]
    loss = (xent + cfg.skip.router_loss_weight * router_loss
            + cfg.moe_lb_weight * moe_lb)
    keep = stats["keep_frac_sum"] / jnp.maximum(stats["n_routed"], 1.0)
    metrics = {"loss": loss, "xent": xent, "router_loss": router_loss,
               "moe_lb_loss": moe_lb, "keep_frac": keep}
    return loss, metrics


# ---------------------------------------------------------------------------
# Prefill
# ---------------------------------------------------------------------------

def _pad_cache_to(cache: Dict, T: int, pad_to: int, cfg: ModelConfig) -> Dict:
    """Grow dense KV leaves from length T to pad_to (decode headroom)."""
    def one(path, leaf):
        names = [getattr(p, "key", None) for p in path]
        if names and names[-1] in ("k", "v"):
            axis = leaf.ndim - 3                  # [.., T, Hkv, dh]
            if leaf.shape[axis] == T and pad_to > T:
                pads = [(0, 0)] * leaf.ndim
                pads[axis] = (0, pad_to - T)
                return jnp.pad(leaf, pads)
        return leaf

    return jax.tree_util.tree_map_with_path(one, cache)


def prefill(params: Params, batch: Dict[str, jnp.ndarray], cfg: ModelConfig,
            pad_to: Optional[int] = None,
            last_index: Optional[jnp.ndarray] = None
            ) -> Tuple[jnp.ndarray, Dict, Dict]:
    """Returns (last-position logits [B, V], cache, stats).

    ``last_index``: optional [B] int32 index of each sequence's final *real*
    token — bucketed prefill right-pads prompts to a shared length, and the
    next-token logits must come from the real last position, not the pad."""
    if cfg.frontend == "token":
        B, T = batch["tokens"].shape
    else:
        B, T = batch["embeds"].shape[:2]
    positions = _positions(batch, B, T, cfg)
    x = _embed_inputs(params, batch, positions, cfg)
    # named_scope: groups the prompt-phase stack in device profiles (the
    # engine's TraceAnnotation covers the host-side dispatch)
    with jax.named_scope("prefill_stack"):
        x, stats, cache, sq = _apply_stack(params, x, positions, cfg, None,
                                           False, True)
    x = layers.norm_apply(params["final_norm"], x, cfg, stats=sq)
    if last_index is None:
        xl = x[:, -1:, :]
    else:
        xl = x[jnp.arange(B), last_index.astype(jnp.int32)][:, None, :]
    logits = layers.unembed(params["embed"], params.get("lm_head"),
                            xl, cfg)[:, 0]
    if pad_to is not None:
        cache = _pad_cache_to(cache, T, pad_to, cfg)
    return logits, cache, stats


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

def init_decode_cache(cfg: ModelConfig, batch: int, max_len: int,
                      dtype=None) -> Dict:
    """Zero caches shaped for decode-only lowering (the dry-run's
    ``decode_*`` shapes: one new token against a seq_len-deep cache)."""
    dt = jnp.dtype(dtype or cfg.dtype)
    Hkv, dh = cfg.num_kv_heads, cfg.resolved_head_dim
    di, g, n = cfg.d_inner_ssm, cfg.ssm_groups, cfg.ssm_state
    nh, pd = cfg.ssm_nheads, cfg.ssm_headdim

    def entry(kind: str) -> Dict[str, jnp.ndarray]:
        if kind == MAMBA:
            return {
                "conv_x": jnp.zeros((batch, cfg.ssm_conv - 1, di), dt),
                "conv_bc": jnp.zeros((batch, cfg.ssm_conv - 1, 2 * g * n), dt),
                "ssm": jnp.zeros((batch, nh, pd, n), jnp.float32),
            }
        L = (min(cfg.window_size, max_len) if kind == LOCAL and cfg.window_size
             else max_len)
        if cfg.kv_cache_layout == "bhtd" and not (
                kind == LOCAL and cfg.window_size):
            return {"k": jnp.zeros((batch, Hkv, L, dh), dt),
                    "v": jnp.zeros((batch, Hkv, L, dh), dt)}
        return {"k": jnp.zeros((batch, L, Hkv, dh), dt),
                "v": jnp.zeros((batch, L, Hkv, dh), dt)}

    stage = {f"pos{k}": entry(cfg.block_kind(k)) for k in range(cfg.stage_len)}
    cache: Dict[str, Any] = {"stage0": stage}
    if cfg.num_stages > 1:
        cache["stages"] = jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(
                a[None], (cfg.num_stages - 1,) + a.shape), stage)
    return cache


def init_chunk_cache(cfg: ModelConfig, batch: int, cap_len: int,
                     dtype=None) -> Dict:
    """Staging cache for chunked (resumable) prefill: per-layer dense KV
    views in *prefill layout* ([B, cap_len, Hkv, dh] time-major regardless
    of ``cfg.kv_cache_layout`` — the layout ``prefill`` collects, which
    ``serve.engine.pool_insert`` / ``kvcache.paged.pack_prefill`` already
    consume).  ``cap_len`` is normally ``max_len`` rounded up to a chunk
    multiple so the right-padded final chunk always fits.  Only valid for
    all-global-attn stacks (``serve.scheduler.can_chunk_prefill``)."""
    dt = jnp.dtype(dtype or cfg.dtype)
    Hkv, dh = cfg.num_kv_heads, cfg.resolved_head_dim

    def entry(kind: str) -> Dict[str, jnp.ndarray]:
        if kind != ATTN:
            raise ValueError(
                f"chunked prefill requires an all-global-attn stack; "
                f"got a {kind!r} layer")
        return {"k": jnp.zeros((batch, cap_len, Hkv, dh), dt),
                "v": jnp.zeros((batch, cap_len, Hkv, dh), dt)}

    stage = {f"pos{k}": entry(cfg.block_kind(k)) for k in range(cfg.stage_len)}
    cache: Dict[str, Any] = {"stage0": stage}
    if cfg.num_stages > 1:
        cache["stages"] = jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(
                a[None], (cfg.num_stages - 1,) + a.shape), stage)
    return cache


def slice_cache_time(cache: Dict, length: int) -> Dict:
    """Truncate dense KV leaves to ``length`` along time (the inverse of
    ``_pad_cache_to`` — used to shed a chunked-prefill staging cache's
    chunk-multiple overhang before pool insertion)."""
    def one(path, leaf):
        names = [getattr(p, "key", None) for p in path]
        if names and names[-1] in ("k", "v"):
            axis = leaf.ndim - 3                  # [.., T, Hkv, dh]
            if leaf.shape[axis] > length:
                return jax.lax.slice_in_dim(leaf, 0, length, axis=axis)
        return leaf

    return jax.tree_util.tree_map_with_path(one, cache)


def _chunk_stack(params: Params, cache: Dict, batch: Dict[str, jnp.ndarray],
                 t0: jnp.ndarray, cfg: ModelConfig
                 ) -> Tuple[jnp.ndarray, Dict, Dict]:
    """Shared stack pass of ``prefill_chunk`` / ``verify_chunk``: C tokens
    at offset ``t0`` over the chunk staging cache, appending each layer's
    merged KV view at [t0, t0+C).  Returns (final-normed activations
    [B, C, D], new cache, stats) with ``stats['attn_gate']``
    [n_attn_layers, B, C]."""
    B, C = batch["tokens"].shape if cfg.frontend == "token" \
        else batch["embeds"].shape[:2]
    t0 = jnp.broadcast_to(jnp.atleast_1d(jnp.asarray(t0, jnp.int32)), (B,))
    pos = t0[:, None] + jnp.arange(C, dtype=jnp.int32)[None, :]
    if cfg.pos_embedding == "mrope":
        pos = jnp.broadcast_to(pos[None], (3, B, C))
    x = _embed_inputs(params, batch, pos, cfg)

    stack = params["stack"]
    x, kv_prev, c0, stats, sq = transformer.stage_prefill_chunk(
        stack["stage0"], cache["stage0"], x, None, t0, pos, cfg)
    gates = stats.pop("attn_gate", None)      # [nA_stage, B, C]
    new_cache: Dict[str, Any] = {"stage0": c0}

    if cfg.num_stages > 1:
        def body(carry, xs):
            x, kv_prev, sq = carry
            sp, ce = xs
            x, kv_prev, c, s, sq = transformer.stage_prefill_chunk(
                sp, ce, x, kv_prev, t0, pos, cfg, carried_sq=sq)
            g = s.pop("attn_gate", None)
            return (x, kv_prev, sq), (c, s, g)

        if cfg.scan_layers:
            (x, kv_prev, sq), (cs, s_scan, g_scan) = jax.lax.scan(
                body, (x, kv_prev, sq), (stack["stages"], cache["stages"]))
            new_cache["stages"] = cs
            stats = jax.tree_util.tree_map(lambda a, b: a + b.sum(axis=0),
                                           stats, s_scan)
            gates = jnp.concatenate([gates[None], g_scan], axis=0)
        else:
            c_list, g_list = [], []
            for i in range(cfg.num_stages - 1):
                sl = lambda l: l[i]
                xs = (jax.tree_util.tree_map(sl, stack["stages"]),
                      jax.tree_util.tree_map(sl, cache["stages"]))
                (x, kv_prev, sq), (c, s, g) = body((x, kv_prev, sq), xs)
                stats = jax.tree_util.tree_map(lambda a, b: a + b, stats, s)
                c_list.append(c)
                g_list.append(g)
            new_cache["stages"] = jax.tree_util.tree_map(
                lambda *ls: jnp.stack(ls), *c_list)
            gates = jnp.concatenate(
                [gates[None]] + [g[None] for g in g_list], axis=0)
        # [S, nA_stage, B, C] -> [L_attn, B, C] in stack order
        gates = gates.reshape((-1,) + gates.shape[-2:])

    stats["attn_gate"] = gates
    x = layers.norm_apply(params["final_norm"], x, cfg, stats=sq)
    return x, new_cache, stats


def prefill_chunk(params: Params, cache: Dict, batch: Dict[str, jnp.ndarray],
                  t0: jnp.ndarray, cfg: ModelConfig,
                  last_index: Optional[jnp.ndarray] = None
                  ) -> Tuple[jnp.ndarray, Dict, Dict]:
    """One chunk of resumable prefill: C tokens appended at offset ``t0``.

    The C-token sibling of ``decode_step``: ``cache`` (from
    ``init_chunk_cache``) holds every layer's dense KV view of positions
    [0, t0); this call computes the chunk's activations attending over
    cached-prefix + chunk, appends each layer's merged view at
    [t0, t0+C), and returns (logits [B, V] at ``last_index`` within the
    chunk (default: the chunk's final position), new cache, stats).
    ``stats['attn_gate']`` is [n_attn_layers, B, C] — the same per-token
    execution-gate log monolithic ``prefill`` emits, chunk column-slice
    by column-slice, so paged entry packing is unchanged.  Requires
    masked-mode routing on an all-global-attn stack; the final chunk may
    be right-padded (pass ``last_index`` = real length − 1) — pad columns
    compute garbage that causal masking keeps out of every real token."""
    x, new_cache, stats = _chunk_stack(params, cache, batch, t0, cfg)
    B = x.shape[0]
    if last_index is None:
        xl = x[:, -1:, :]
    else:
        xl = x[jnp.arange(B), last_index.astype(jnp.int32)][:, None, :]
    logits = layers.unembed(params["embed"], params.get("lm_head"),
                            xl, cfg)[:, 0]
    return logits, new_cache, stats


def verify_chunk(params: Params, cache: Dict, batch: Dict[str, jnp.ndarray],
                 t0: jnp.ndarray, cfg: ModelConfig
                 ) -> Tuple[jnp.ndarray, Dict, Dict]:
    """Speculative verification: ``prefill_chunk`` with *every* column
    unembedded.  Feeding the window [f0, d_1..d_k] at positions
    [t0, t0+k] returns logits [B, k+1, V] whose column j is the
    verifier's next-token distribution after the prefix ending at the
    j-th fed token — so column j judges draft d_{j+1} and column ``a``
    supplies the correction after accepting ``a`` drafts
    (``serve/sampling.py``).  KV for the whole window lands at
    [t0, t0+C) exactly like a prefill chunk; rows past the accepted
    prefix are dead weight the next window overwrites, masked until then
    by decode's ``kv_valid_len`` (docs/speculative.md)."""
    x, new_cache, stats = _chunk_stack(params, cache, batch, t0, cfg)
    logits = layers.unembed(params["embed"], params.get("lm_head"), x, cfg)
    return logits, new_cache, stats


def decode_step(params: Params, cache: Dict, batch: Dict[str, jnp.ndarray],
                t: jnp.ndarray, cfg: ModelConfig
                ) -> Tuple[jnp.ndarray, Dict, Dict]:
    """One token for every sequence.  batch: {'tokens': [B, 1]} (or
    {'embeds': [B, 1, D]}); t: [B] int32 per-sequence positions — a scalar
    broadcasts to the whole batch (lock-step decode).  Returns
    (logits [B, V], new cache, stats); ``stats['attn_gate']`` is the
    [n_attn_layers, B] execution-gate log over the attention stack."""
    if cfg.frontend == "token":
        B = batch["tokens"].shape[0]
    else:
        B = batch["embeds"].shape[0]
    t = jnp.broadcast_to(jnp.atleast_1d(jnp.asarray(t, jnp.int32)), (B,))
    pos = t[:, None]
    if cfg.pos_embedding == "mrope":
        pos = jnp.broadcast_to(pos[None], (3, B, 1))
    x = _embed_inputs(params, batch, pos, cfg)

    stack = params["stack"]
    x, kv_prev, c0, stats, sq = transformer.stage_decode(
        stack["stage0"], cache["stage0"], x, None, t, pos, cfg)
    g0 = stats.pop("attn_gate", None)
    gates = g0                      # [nA, B] or None (attention-free stage)
    new_cache: Dict[str, Any] = {"stage0": c0}

    if cfg.num_stages > 1:
        def body(carry, xs):
            x, kv_prev, sq = carry
            sp, ce = xs
            x, kv_prev, c, s, sq = transformer.stage_decode(
                sp, ce, x, kv_prev, t, pos, cfg, carried_sq=sq)
            g = s.pop("attn_gate", None)
            return (x, kv_prev, sq), (c, s, g)

        if cfg.scan_layers:
            (x, kv_prev, sq), (cs, s_scan, g_scan) = jax.lax.scan(
                body, (x, kv_prev, sq), (stack["stages"], cache["stages"]))
            new_cache["stages"] = cs
            stats = jax.tree_util.tree_map(lambda a, b: a + b.sum(axis=0),
                                           stats, s_scan)
            if gates is not None:
                gates = jnp.concatenate([gates[None], g_scan], axis=0)
        else:
            c_list, g_list = [], []
            for i in range(cfg.num_stages - 1):
                sl = lambda l: l[i]
                xs = (jax.tree_util.tree_map(sl, stack["stages"]),
                      jax.tree_util.tree_map(sl, cache["stages"]))
                (x, kv_prev, sq), (c, s, g) = body((x, kv_prev, sq), xs)
                stats = jax.tree_util.tree_map(lambda a, b: a + b, stats, s)
                c_list.append(c)
                g_list.append(g)
            new_cache["stages"] = jax.tree_util.tree_map(
                lambda *ls: jnp.stack(ls), *c_list)
            if gates is not None:
                gates = jnp.concatenate(
                    [gates[None]] + [g[None] for g in g_list], axis=0)
        if gates is not None:
            # [S, nA, B] -> [L_attn, B] in stack order (stage0 first)
            gates = gates.reshape(-1, B)

    if gates is not None:
        stats["attn_gate"] = gates
    # the last block's fused epilogue already produced the final norm's
    # reduction (incremental-reduction carry)
    x = layers.norm_apply(params["final_norm"], x, cfg, stats=sq)
    logits = layers.unembed(params["embed"], params.get("lm_head"), x, cfg)
    return logits[:, 0], new_cache, stats


def paged_decode_step(params: Params, store: Dict,
                      batch: Dict[str, jnp.ndarray], t: jnp.ndarray,
                      block_table: jnp.ndarray, fill: jnp.ndarray,
                      cfg: ModelConfig,
                      commit_mask: Optional[jnp.ndarray] = None
                      ) -> Tuple[jnp.ndarray, Dict, Dict]:
    """One token for every slot against the paged KV store.

    The dense-pool twin of ``decode_step``: past tokens' KV lives in the
    shared store-once entry stream (``repro/kvcache/paged.py``) instead of
    per-layer ``[B, Tmax]`` caches.  ``block_table`` [B, J] and ``fill``
    [B] come from the host-side ``PageAllocator`` (which has proactively
    guaranteed page capacity for this step's ≤ n_attn_layers appends).
    Slots with ``fill == 0`` are inactive: they decode garbage but commit
    nothing.  ``commit_mask`` [B] overrides that default commit gate —
    ``paged_decode_loop`` passes its per-slot active mask so a slot that
    finishes mid-loop stops appending entries.  Returns (logits [B, V],
    new store, stats) with ``stats['attn_gate']`` as in ``decode_step``."""
    from repro.kvcache import paged as paged_mod

    assert paged_mod.can_page(cfg), f"{cfg.name}: not a pageable stack"
    if cfg.frontend == "token":
        B = batch["tokens"].shape[0]
    else:
        B = batch["embeds"].shape[0]
    t = jnp.broadcast_to(jnp.atleast_1d(jnp.asarray(t, jnp.int32)), (B,))
    pos = t[:, None]
    if cfg.pos_embedding == "mrope":
        pos = jnp.broadcast_to(pos[None], (3, B, 1))
    x = _embed_inputs(params, batch, pos, cfg)

    # resolve the page chains once per step (the store is frozen until the
    # end-of-step commit; the current token rides along as an explicit
    # (k_t, v_t) pair inside each layer)
    kv_dtype = paged_mod.infer_kv_dtype(store, cfg)
    view = paged_mod.gather_view(store, block_table,
                                 with_kv=not cfg.use_kernels,
                                 kv_dtype=kv_dtype)
    E = view["pos"].shape[1]
    paged_ctx = dict(view)
    paged_ctx["in_fill"] = jnp.arange(E)[None, :] < fill[:, None]
    if cfg.use_kernels:
        paged_ctx["k_pages"] = store["k_pages"]
        paged_ctx["v_pages"] = store["v_pages"]
        paged_ctx["block_table"] = block_table
        if kv_dtype is not None:
            paged_ctx["k_scales"] = store["k_scales"]
            paged_ctx["v_scales"] = store["v_scales"]

    stack = params["stack"]
    nA_stage = sum(1 for k in range(cfg.stage_len)
                   if cfg.block_kind(k) != MAMBA)
    x, kv_prev, s0, sq = transformer.stage_decode_paged(
        stack["stage0"], x, None, t, pos, cfg, paged_ctx,
        jnp.int32(0))
    gates = s0.pop("attn_gate")
    buf_k, buf_v = s0.pop("kv_token")
    stats = s0

    if cfg.num_stages > 1:
        def body(carry, xs):
            x, kv_prev, sq = carry
            sp, si = xs
            x, kv_prev, s, sq = transformer.stage_decode_paged(
                sp, x, kv_prev, t, pos, cfg, paged_ctx, si * nA_stage,
                carried_sq=sq)
            g = s.pop("attn_gate")
            kt = s.pop("kv_token")
            return (x, kv_prev, sq), (s, g, kt)

        idxs = jnp.arange(1, cfg.num_stages, dtype=jnp.int32)
        if cfg.scan_layers:
            (x, kv_prev, sq), (s_scan, g_scan, kt_scan) = jax.lax.scan(
                body, (x, kv_prev, sq), (stack["stages"], idxs))
            stats = jax.tree_util.tree_map(lambda a, b: a + b.sum(axis=0),
                                           stats, s_scan)
            gates = jnp.concatenate([gates[None], g_scan], axis=0)
            buf_k = jnp.concatenate([buf_k[None], kt_scan[0]], axis=0)
            buf_v = jnp.concatenate([buf_v[None], kt_scan[1]], axis=0)
        else:
            g_list, k_list, v_list = [], [], []
            for i in range(cfg.num_stages - 1):
                sp = jax.tree_util.tree_map(lambda l: l[i], stack["stages"])
                (x, kv_prev, sq), (s, g, kt) = body((x, kv_prev, sq),
                                                    (sp, idxs[i]))
                stats = jax.tree_util.tree_map(lambda a, b: a + b, stats, s)
                g_list.append(g[None])
                k_list.append(kt[0][None])
                v_list.append(kt[1][None])
            gates = jnp.concatenate([gates[None]] + g_list, axis=0)
            buf_k = jnp.concatenate([buf_k[None]] + k_list, axis=0)
            buf_v = jnp.concatenate([buf_v[None]] + v_list, axis=0)
        gates = gates.reshape(-1, B)
        buf_k = buf_k.reshape((-1,) + buf_k.shape[-3:])
        buf_v = buf_v.reshape((-1,) + buf_v.shape[-3:])

    if commit_mask is None:
        commit_mask = fill > 0
    store = paged_mod.commit_decode(store, buf_k, buf_v, gates, t,
                                    block_table, fill, commit_mask, cfg,
                                    kv_dtype=kv_dtype)
    stats["attn_gate"] = gates
    x = layers.norm_apply(params["final_norm"], x, cfg, stats=sq)
    logits = layers.unembed(params["embed"], params.get("lm_head"), x, cfg)
    return logits[:, 0], store, stats


# ---------------------------------------------------------------------------
# Device-resident multi-step decode (one jitted dispatch per N tokens)
# ---------------------------------------------------------------------------

def _entry_active(feed: jnp.ndarray, active: jnp.ndarray,
                  stop: jnp.ndarray) -> jnp.ndarray:
    """A deferred first token (sampled inside the prefill dispatch, never
    seen by the host) may itself be the stop token: kill the slot before
    it decodes, so it emits nothing and appends no KV."""
    return active & ~((stop >= 0) & (feed == stop))


def _loop_finish(tok: jnp.ndarray, t: jnp.ndarray, emitted: jnp.ndarray,
                 active: jnp.ndarray, budget: jnp.ndarray,
                 stop: jnp.ndarray, max_len: int) -> jnp.ndarray:
    """Per-slot finish detection, replicating the host engine's
    ``_advance_slot`` conditions: stop token sampled, generation budget
    exhausted (``emitted`` already counts this step's token), or the next
    write position reaching the pool's max_len."""
    hit_stop = (stop >= 0) & (tok == stop)
    return active & ~(hit_stop | (emitted >= budget) | (t + 1 >= max_len))


def decode_loop(params: Params, cache: Dict, feed: jnp.ndarray,
                t: jnp.ndarray, active: jnp.ndarray, budget: jnp.ndarray,
                stop: jnp.ndarray, rng: jnp.ndarray, *, n_steps: int,
                cfg: ModelConfig, max_len: int, temperature: float = 0.0,
                top_k: int = 0) -> Tuple[Dict, Dict]:
    """``n_steps`` fused decode iterations under one jit (``lax.scan``):
    per-step token sampling, stop-token/length detection and position
    advance all happen on device, so the host syncs once per dispatch
    instead of once per token (the serving-loop analogue of the paper's
    latency hiding: control decisions overlap in-flight compute).

    Inputs (all [B] over the slot pool): ``feed`` the token each slot
    feeds next, ``t`` its write position, ``active`` slot liveness,
    ``budget`` how many tokens the slot may still emit, ``stop`` its stop
    token id (-1 = none).  A slot that finishes mid-loop freezes its
    (feed, t) pair: every subsequent iteration then recomputes — and
    rewrites, bit-identically — the KV entry it already wrote at ``t``
    instead of appending, so a finished slot stops growing its cache row
    with no per-step host intervention.  Inactive slots compute garbage
    that never escapes: their sampled tokens are masked by
    ``step_active`` and their KV rewrite is idempotent.

    Returns (new cache, out) with stacked per-step outputs —
    ``tokens``/``step_active`` [n_steps, B], ``attn_gate``
    [n_steps, L_attn, B] (None for gate-free stacks) — plus the final
    ``feed``/``t``/``active``/``emitted`` carry and the advanced ``rng``
    (one split per step, mirroring the single-step engine's sequence)."""
    from repro.serve.sampling import split_sample

    feed = jnp.asarray(feed, jnp.int32)
    t = jnp.asarray(t, jnp.int32)
    budget = jnp.asarray(budget, jnp.int32)
    stop = jnp.asarray(stop, jnp.int32)
    active = _entry_active(feed, jnp.asarray(active, bool), stop)

    def body(carry, _):
        cache, feed, t, active, emitted, rng = carry
        logits, cache, stats = decode_step(
            params, cache, {"tokens": feed[:, None]}, t, cfg)
        rng, tok = split_sample(logits, rng, temperature, top_k)
        emitted = emitted + active.astype(jnp.int32)
        nxt = _loop_finish(tok, t, emitted, active, budget, stop, max_len)
        ys = (tok, active, stats.get("attn_gate"))
        feed = jnp.where(nxt, tok, feed)
        t = jnp.where(nxt, t + 1, t)
        return (cache, feed, t, nxt, emitted, rng), ys

    init = (cache, feed, t, active, jnp.zeros_like(budget), rng)
    with jax.named_scope(f"decode_epoch_x{n_steps}"):
        (cache, feed, t, active, emitted, rng), \
            (toks, step_active, gates) = \
            jax.lax.scan(body, init, None, length=n_steps)
    return cache, {"tokens": toks, "step_active": step_active,
                   "attn_gate": gates, "feed": feed, "t": t,
                   "active": active, "emitted": emitted, "rng": rng}


def paged_decode_loop(params: Params, store: Dict, feed: jnp.ndarray,
                      t: jnp.ndarray, fill: jnp.ndarray,
                      active: jnp.ndarray, budget: jnp.ndarray,
                      stop: jnp.ndarray, rng: jnp.ndarray,
                      block_table: jnp.ndarray, *, n_steps: int,
                      cfg: ModelConfig, max_len: int,
                      temperature: float = 0.0, top_k: int = 0
                      ) -> Tuple[Dict, Dict]:
    """``decode_loop``'s paged-store twin: N fused ``paged_decode_step``
    iterations with the entry-stream fill advancing on device — each
    active slot appends its measured fresh-entry count (layer-0 dense +
    executed layers, exactly the host ``PageAllocator`` accounting the
    engine replays from the returned gate log after the sync).  A slot
    that finishes mid-loop drops out of the commit mask, so it stops
    appending entries; the host must have pre-reserved page headroom for
    ``n_steps`` worst-case appends per active slot (``block_table`` must
    span that reservation).  Returns (new store, out) as ``decode_loop``
    plus the final per-slot ``fill``."""
    from repro.kvcache import history as history_mod
    from repro.kvcache import paged as paged_mod
    from repro.serve.sampling import split_sample

    reuse = paged_mod.reuse_enabled(cfg)
    feed = jnp.asarray(feed, jnp.int32)
    t = jnp.asarray(t, jnp.int32)
    fill = jnp.asarray(fill, jnp.int32)
    budget = jnp.asarray(budget, jnp.int32)
    stop = jnp.asarray(stop, jnp.int32)
    active = _entry_active(feed, jnp.asarray(active, bool), stop)

    def body(carry, _):
        store, feed, t, fill, active, emitted, rng = carry
        logits, store, stats = paged_decode_step(
            params, store, {"tokens": feed[:, None]}, t, block_table, fill,
            cfg, commit_mask=active & (fill > 0))
        rng, tok = split_sample(logits, rng, temperature, top_k)
        gates = stats["attn_gate"]                             # [nA, B]
        n_fresh = history_mod.fresh_mask(gates, reuse).astype(
            jnp.int32).sum(axis=0)
        fill = fill + jnp.where(active, n_fresh, 0)
        emitted = emitted + active.astype(jnp.int32)
        nxt = _loop_finish(tok, t, emitted, active, budget, stop, max_len)
        ys = (tok, active, gates)
        feed = jnp.where(nxt, tok, feed)
        t = jnp.where(nxt, t + 1, t)
        return (store, feed, t, fill, nxt, emitted, rng), ys

    init = (store, feed, t, fill, active, jnp.zeros_like(budget), rng)
    with jax.named_scope(f"paged_decode_epoch_x{n_steps}"):
        (store, feed, t, fill, active, emitted, rng), \
            (toks, step_active, gates) = jax.lax.scan(body, init, None,
                                                      length=n_steps)
    return store, {"tokens": toks, "step_active": step_active,
                   "attn_gate": gates, "feed": feed, "t": t, "fill": fill,
                   "active": active, "emitted": emitted, "rng": rng}


# ---------------------------------------------------------------------------
# Speculative decoding: draft loops + paged verify/commit
# ---------------------------------------------------------------------------

def draft_loop(params: Params, cache: Dict, feed: jnp.ndarray,
               t: jnp.ndarray, rng: jnp.ndarray, *, n_steps: int,
               cfg: ModelConfig, temperature: float = 0.0,
               top_k: int = 0) -> Tuple[Dict, Dict]:
    """Speculative draft: ``n_steps`` fused decode iterations under the
    (usually skip-biased) draft parameters, proposing one token per step.

    Unlike ``decode_loop`` there is no stop/budget/length masking: a
    window is short (γ ≤ spec_k, pre-clamped by the host against
    max_len) and the host truncates emission at acceptance time, so a
    draft chain running past a stop token is dead weight, never an
    error.  Per-step draft *logits* are stacked alongside the tokens so
    temperature>0 acceptance can reconstruct the exact draft
    distribution each proposal was drawn from.  Draft KV lands in the
    cache rows the verify chunk immediately overwrites.  Returns
    (cache, out): ``tokens`` [n, B], ``logits`` [n, B, V], final
    ``feed``/``t`` and the advanced ``rng``."""
    from repro.serve.sampling import split_sample

    feed = jnp.asarray(feed, jnp.int32)
    t = jnp.asarray(t, jnp.int32)

    def body(carry, _):
        cache, feed, t, rng = carry
        logits, cache, _ = decode_step(
            params, cache, {"tokens": feed[:, None]}, t, cfg)
        rng, tok = split_sample(logits, rng, temperature, top_k)
        return (cache, tok, t + 1, rng), (tok, logits)

    with jax.named_scope(f"draft_x{n_steps}"):
        (cache, feed, t, rng), (toks, logits) = jax.lax.scan(
            body, (cache, feed, t, rng), None, length=n_steps)
    return cache, {"tokens": toks, "logits": logits, "feed": feed,
                   "t": t, "rng": rng}


def paged_draft_loop(params: Params, store: Dict, feed: jnp.ndarray,
                     t: jnp.ndarray, fill: jnp.ndarray,
                     active: jnp.ndarray, rng: jnp.ndarray,
                     block_table: jnp.ndarray, *, n_steps: int,
                     cfg: ModelConfig, temperature: float = 0.0,
                     top_k: int = 0) -> Tuple[Dict, Dict]:
    """``draft_loop`` against the paged store: tentative entries append
    at the live fill (the committed prefix below the window's entry
    count stays untouched), fill advancing on device via the measured
    fresh-entry count.  Every entry appended here is *tentative*:
    ``paged_verify_chunk`` reads only the pre-window prefix, and
    ``commit_verified`` rewrites the stream from the pre-window fill
    with verifier KV for the accepted columns only — so a rejected
    draft leaves no live residue (docs/speculative.md).  The host must
    have pre-reserved page headroom for ``n_steps`` worst-case appends.
    Returns the final ``fill`` so the host can count rolled-back
    entries."""
    from repro.kvcache import history as history_mod
    from repro.kvcache import paged as paged_mod
    from repro.serve.sampling import split_sample

    reuse = paged_mod.reuse_enabled(cfg)
    feed = jnp.asarray(feed, jnp.int32)
    t = jnp.asarray(t, jnp.int32)
    fill = jnp.asarray(fill, jnp.int32)
    active = jnp.asarray(active, bool)

    def body(carry, _):
        store, feed, t, fill, rng = carry
        logits, store, stats = paged_decode_step(
            params, store, {"tokens": feed[:, None]}, t, block_table, fill,
            cfg, commit_mask=active & (fill > 0))
        rng, tok = split_sample(logits, rng, temperature, top_k)
        n_fresh = history_mod.fresh_mask(stats["attn_gate"], reuse).astype(
            jnp.int32).sum(axis=0)
        fill = fill + jnp.where(active, n_fresh, 0)
        return (store, tok, t + 1, fill, rng), (tok, logits)

    with jax.named_scope(f"paged_draft_x{n_steps}"):
        (store, feed, t, fill, rng), (toks, logits) = jax.lax.scan(
            body, (store, feed, t, fill, rng), None, length=n_steps)
    return store, {"tokens": toks, "logits": logits, "feed": feed,
                   "t": t, "fill": fill, "rng": rng}


def paged_verify_chunk(params: Params, store: Dict,
                       batch: Dict[str, jnp.ndarray], t0: jnp.ndarray,
                       block_table: jnp.ndarray, fill: jnp.ndarray,
                       cfg: ModelConfig) -> Tuple[jnp.ndarray, Dict]:
    """Speculative verification against the paged store — read-only.

    The C-token sibling of ``paged_decode_step``: the window's C = k+1
    fed tokens attend over the *committed* entry prefix (entries below
    ``fill`` — the engine passes the pre-draft fill, so the draft loop's
    tentative entries are invisible here) plus the window's own
    in-flight KV, which rides along explicitly inside each layer.
    Nothing is committed: the per-layer token views come back in
    ``stats['kv_token']`` ([nA, B, C, Hkv, dh] each) for
    ``commit_verified`` to append after host-side acceptance.  Returns
    (logits [B, C, V], stats) with ``stats['attn_gate']`` [nA, B, C]."""
    from repro.kvcache import paged as paged_mod

    assert paged_mod.can_page(cfg), f"{cfg.name}: not a pageable stack"
    B, C = batch["tokens"].shape if cfg.frontend == "token" \
        else batch["embeds"].shape[:2]
    t0 = jnp.broadcast_to(jnp.atleast_1d(jnp.asarray(t0, jnp.int32)), (B,))
    pos = t0[:, None] + jnp.arange(C, dtype=jnp.int32)[None, :]
    if cfg.pos_embedding == "mrope":
        pos = jnp.broadcast_to(pos[None], (3, B, C))
    x = _embed_inputs(params, batch, pos, cfg)

    # always the jnp concat path: the Pallas decode kernel is
    # single-query, and a k+1-wide window doesn't need it
    view = paged_mod.gather_view(store, block_table, with_kv=True,
                                 kv_dtype=paged_mod.infer_kv_dtype(store,
                                                                   cfg))
    E = view["pos"].shape[1]
    paged_ctx = dict(view)
    paged_ctx["in_fill"] = jnp.arange(E)[None, :] < fill[:, None]

    stack = params["stack"]
    nA_stage = sum(1 for k in range(cfg.stage_len)
                   if cfg.block_kind(k) != MAMBA)
    x, kv_prev, s0, sq = transformer.stage_verify_paged(
        stack["stage0"], x, None, pos, cfg, paged_ctx, jnp.int32(0))
    gates = s0.pop("attn_gate")
    buf_k, buf_v = s0.pop("kv_token")
    stats = s0

    if cfg.num_stages > 1:
        def body(carry, xs):
            x, kv_prev, sq = carry
            sp, si = xs
            x, kv_prev, s, sq = transformer.stage_verify_paged(
                sp, x, kv_prev, pos, cfg, paged_ctx, si * nA_stage,
                carried_sq=sq)
            g = s.pop("attn_gate")
            kt = s.pop("kv_token")
            return (x, kv_prev, sq), (s, g, kt)

        idxs = jnp.arange(1, cfg.num_stages, dtype=jnp.int32)
        if cfg.scan_layers:
            (x, kv_prev, sq), (s_scan, g_scan, kt_scan) = jax.lax.scan(
                body, (x, kv_prev, sq), (stack["stages"], idxs))
            stats = jax.tree_util.tree_map(lambda a, b: a + b.sum(axis=0),
                                           stats, s_scan)
            gates = jnp.concatenate([gates[None], g_scan], axis=0)
            buf_k = jnp.concatenate([buf_k[None], kt_scan[0]], axis=0)
            buf_v = jnp.concatenate([buf_v[None], kt_scan[1]], axis=0)
        else:
            g_list, k_list, v_list = [], [], []
            for i in range(cfg.num_stages - 1):
                sp = jax.tree_util.tree_map(lambda l: l[i], stack["stages"])
                (x, kv_prev, sq), (s, g, kt) = body((x, kv_prev, sq),
                                                    (sp, idxs[i]))
                stats = jax.tree_util.tree_map(lambda a, b: a + b, stats, s)
                g_list.append(g[None])
                k_list.append(kt[0][None])
                v_list.append(kt[1][None])
            gates = jnp.concatenate([gates[None]] + g_list, axis=0)
            buf_k = jnp.concatenate([buf_k[None]] + k_list, axis=0)
            buf_v = jnp.concatenate([buf_v[None]] + v_list, axis=0)
        gates = gates.reshape((-1, B) + gates.shape[-1:])
        buf_k = buf_k.reshape((-1,) + buf_k.shape[-4:])
        buf_v = buf_v.reshape((-1,) + buf_v.shape[-4:])

    stats["attn_gate"] = gates
    stats["kv_token"] = (buf_k, buf_v)
    x = layers.norm_apply(params["final_norm"], x, cfg, stats=sq)
    logits = layers.unembed(params["embed"], params.get("lm_head"), x, cfg)
    return logits, stats


def commit_verified(store: Dict, buf_k: jnp.ndarray, buf_v: jnp.ndarray,
                    gates: jnp.ndarray, t0: jnp.ndarray,
                    block_table: jnp.ndarray, fill0: jnp.ndarray,
                    committed: jnp.ndarray, active: jnp.ndarray,
                    cfg: ModelConfig) -> Tuple[Dict, jnp.ndarray]:
    """Post-acceptance paged commit: rewrite the entry stream from the
    pre-window ``fill0`` with the *verifier's* KV for exactly the
    leading ``committed`` columns of the window (per slot), in the same
    token-major order a never-speculated engine appends — so the
    committed stream is indistinguishable from plain decoding, and every
    tentative draft entry at index ≥ post-commit fill is dead (masked by
    ``in_fill`` at read time, overwritten by the next window's draft).

    buf_k/buf_v: [nA, S, C, Hkv, dh] (``paged_verify_chunk`` views);
    gates: [nA, S, C]; t0/fill0/committed: [S]; ``active`` [S] masks
    slots outside the window.  Returns (store, per-slot post-commit
    fill)."""
    from repro.kvcache import history as history_mod
    from repro.kvcache import paged as paged_mod

    reuse = paged_mod.reuse_enabled(cfg)
    C = gates.shape[-1]
    fill = jnp.asarray(fill0, jnp.int32)
    committed = jnp.asarray(committed, jnp.int32)
    active = jnp.asarray(active, bool)
    t0 = jnp.asarray(t0, jnp.int32)

    kv_dtype = paged_mod.infer_kv_dtype(store, cfg)

    def body(carry, xs):
        store, fill = carry
        bk, bv, g, j = xs
        mask = active & (j < committed)
        store = paged_mod.commit_decode(store, bk, bv, g, t0 + j,
                                        block_table, fill, mask, cfg,
                                        kv_dtype=kv_dtype)
        n_fresh = history_mod.fresh_mask(g, reuse).astype(
            jnp.int32).sum(axis=0)
        fill = fill + jnp.where(mask, n_fresh, 0)
        return (store, fill), None

    xs = (jnp.moveaxis(buf_k, 2, 0), jnp.moveaxis(buf_v, 2, 0),
          jnp.moveaxis(gates, 2, 0), jnp.arange(C, dtype=jnp.int32))
    with jax.named_scope(f"commit_verified_x{C}"):
        (store, fill), _ = jax.lax.scan(body, (store, fill), xs)
    return store, fill
