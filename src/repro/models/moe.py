"""Top-k MoE with static-capacity scatter dispatch (GShard/Mixtral-style),
expert-parallel shardable, plus Arctic's dense-residual composition.

Dispatch is scatter-based rather than one-hot-einsum so the dispatch buffer
stays O(E·C·D) — the [N, E, C] one-hot tensor would be ~100× larger at the
assigned shapes.  The SkipGPT FFN router composes *outside* this module: it
decides whether a token enters the MoE block at all (DESIGN.md §4).
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import hint
from repro.models import layers
from repro.models.layers import Params


def moe_init(key, cfg: ModelConfig) -> Params:
    E, d, f = cfg.num_experts, cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 5)
    dt = jnp.dtype(cfg.dtype)
    glu = cfg.mlp_act in ("swiglu", "geglu")
    s_in, s_out = 1.0 / math.sqrt(d), 1.0 / math.sqrt(f)
    p: Params = {
        "gate": layers.trunc_normal(ks[0], (d, E), s_in, dt),
        "w_up": layers.trunc_normal(ks[1], (E, d, f), s_in, dt),
        "w_down": layers.trunc_normal(ks[2], (E, f, d), s_out, dt),
    }
    if glu:
        p["w_gate"] = layers.trunc_normal(ks[3], (E, d, f), s_in, dt)
    if cfg.dense_residual:
        p["dense"] = layers.mlp_init(ks[4], cfg)
    return p


def _expert_ffn(params: Params, h: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """h: [E, C, D] -> [E, C, D] batched per-expert GLU."""
    up = jnp.einsum("ecd,edf->ecf", h, params["w_up"])
    if "w_gate" in params:
        g = jnp.einsum("ecd,edf->ecf", h, params["w_gate"])
        act = jax.nn.silu(g) if cfg.mlp_act == "swiglu" else jax.nn.gelu(g)
        mid = act * up
    else:
        mid = jax.nn.gelu(up)
    return jnp.einsum("ecf,efd->ecd", mid, params["w_down"])


def moe_apply(params: Params, x: jnp.ndarray, cfg: ModelConfig
              ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """x: [B, T, D] -> (y, aux).  aux carries the load-balance loss + stats."""
    B, T, D = x.shape
    E, K = cfg.num_experts, cfg.top_k
    N = B * T
    xf = x.reshape(N, D)

    gate_logits = (xf.astype(jnp.float32) @ params["gate"].astype(jnp.float32))
    probs = jax.nn.softmax(gate_logits, axis=-1)             # [N, E]
    top_p, top_e = jax.lax.top_k(probs, K)                   # [N, K]
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    capacity = int(math.ceil(cfg.moe_capacity_factor * N * K / E))
    capacity = max(8, -(-capacity // 8) * 8)                 # round up to 8

    # Position of each (token, slot) within its expert: token-major priority.
    flat_e = top_e.reshape(-1)                               # [N*K] slot-major? no:
    # reshape is row-major => slots of token i come before token i+1 — the
    # paper's routing is token-order too (prefill streams tokens in order).
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)      # [N*K, E]
    pos = jnp.cumsum(onehot, axis=0) - 1                     # [N*K, E]
    pos_in_e = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]
    keep = pos_in_e < capacity
    dest = jnp.where(keep, flat_e * capacity + pos_in_e, E * capacity)

    dest = dest.reshape(N, K)
    buf = jnp.zeros((E * capacity, D), x.dtype)
    for j in range(K):                                       # K static & small
        buf = buf.at[dest[:, j]].add(xf, mode="drop")
    buf = hint(buf.reshape(E, capacity, D), "moe_buffer")

    out_buf = _expert_ffn(params, buf, cfg)
    out_buf = hint(out_buf, "moe_buffer").reshape(E * capacity, D)

    y = jnp.zeros((N, D), x.dtype)
    for j in range(K):
        gathered = jnp.take(out_buf, dest[:, j], axis=0, mode="fill",
                            fill_value=0)
        y = y + gathered * top_p[:, j].astype(x.dtype)[:, None]

    if "dense" in params:                                    # Arctic residual
        y = y + layers.mlp_apply(params["dense"], x, cfg).reshape(N, D)

    # Switch-style load-balance loss.
    me = probs.mean(axis=0)                                  # mean gate prob
    ce = jnp.zeros((E,), jnp.float32).at[flat_e].add(1.0) / (N * K)
    lb_loss = E * jnp.sum(me * ce)
    dropped = 1.0 - keep.mean()
    aux = {"moe_lb_loss": lb_loss, "moe_drop_frac": dropped}
    return y.reshape(B, T, D), aux
