"""Mamba-2 / SSD (state-space duality) block — chunked scan forward and a
single-token decode step.

The chunked SSD computes, per chunk of length Q:
  intra-chunk: a masked attention-like product  C_i · B_j · decay(i,j) · (dt_j x_j)
  inter-chunk: a running state  S ← S·exp(ΣdA) + Σ_j decay(end,j)·B_j ⊗ (dt_j x_j)
which is the sub-quadratic form used for the `mamba2-2.7b` and `jamba` archs.

Projections are kept *separate per component* (z, x, B/C, dt) so the inner
dimension (heads × headdim) tensor-parallels cleanly over the `model` mesh
axis while the small B/C/dt streams stay replicated — see
`distributed/sharding.py`.

SkipGPT adaptation (DESIGN.md §Arch-applicability): token routing on SSM
layers uses *masked-contribution* semantics — a skipped token's dt is zeroed
(no state update, no output) and it rides the residual stream.  KV reuse is
inapplicable (no KV cache).
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import hint
from repro.models import layers
from repro.models.layers import Params


def _dims(cfg: ModelConfig):
    di = cfg.d_inner_ssm
    g, n = cfg.ssm_groups, cfg.ssm_state
    nh, p = cfg.ssm_nheads, cfg.ssm_headdim
    return di, g, n, nh, p


def conv_dim(cfg: ModelConfig) -> int:
    di, g, n, _, _ = _dims(cfg)
    return di + 2 * g * n


def ssm_init(key, cfg: ModelConfig) -> Params:
    di, g, n, nh, p = _dims(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 8)
    dt = jnp.dtype(cfg.dtype)
    # dt bias init so softplus(dt_bias) spans [1e-3, 1e-1] (mamba2 default).
    u = jax.random.uniform(ks[5], (nh,), jnp.float32)
    dt0 = jnp.exp(u * (math.log(0.1) - math.log(1e-3)) + math.log(1e-3))
    dt_bias = dt0 + jnp.log(-jnp.expm1(-dt0))                # inverse softplus
    return {
        "in_proj_z": layers.linear_init(ks[0], d, di, cfg),
        "in_proj_x": layers.linear_init(ks[1], d, di, cfg),
        "in_proj_bc": layers.linear_init(ks[2], d, 2 * g * n, cfg),
        "in_proj_dt": layers.linear_init(ks[3], d, nh, cfg),
        "conv_x_w": layers.trunc_normal(ks[4], (cfg.ssm_conv, di),
                                        1.0 / math.sqrt(cfg.ssm_conv), dt),
        "conv_x_b": jnp.zeros((di,), dt),
        "conv_bc_w": layers.trunc_normal(ks[6], (cfg.ssm_conv, 2 * g * n),
                                         1.0 / math.sqrt(cfg.ssm_conv), dt),
        "conv_bc_b": jnp.zeros((2 * g * n,), dt),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh, dtype=jnp.float32)),
        "dt_bias": dt_bias.astype(jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "norm": {"gamma": jnp.ones((di,), dt)},
        "out_proj": layers.linear_init(ks[7], di, d, cfg),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 init_state: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Depthwise causal conv1d.  x: [B, T, C]; w: [W, C]."""
    W = w.shape[0]
    if init_state is None:
        xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([init_state, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(W))
    return jax.nn.silu(out + b)


def _expand_groups(m: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """[..., G, N] -> [..., nh, N] broadcast heads within a group."""
    di, g, n, nh, p = _dims(cfg)
    return jnp.repeat(m, nh // g, axis=-2)


def ssd_scan(xh: jnp.ndarray, dt: jnp.ndarray, A_log: jnp.ndarray,
             Bm: jnp.ndarray, Cm: jnp.ndarray, chunk: int,
             init_state: Optional[jnp.ndarray] = None
             ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD.

    xh [B,T,H,P], dt [B,T,H] (≥0, already masked for skipped tokens),
    A_log [H], Bm/Cm [B,T,H,N].  Returns (y [B,T,H,P], state [B,H,P,N]).
    """
    B, T, H, Pd = xh.shape
    N = Bm.shape[-1]
    Q = min(chunk, T)
    pad = (-T) % Q
    if pad:
        z = lambda a: jnp.pad(a, [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2))
        xh, dt, Bm, Cm = z(xh), z(dt), z(Bm), z(Cm)
    Tp = T + pad
    nc = Tp // Q

    def chunkify(a):
        a = a.reshape(B, nc, Q, *a.shape[2:])
        return jnp.moveaxis(a, 1, 0)                    # [nc, B, Q, ...]

    xc, dtc, Bc, Cc = map(chunkify, (xh, dt, Bm, Cm))
    dA = dtc * (-jnp.exp(A_log))                        # [nc,B,Q,H]
    cum = jnp.cumsum(dA, axis=2)                        # within-chunk cumsum

    s0 = init_state if init_state is not None else \
        jnp.zeros((B, H, Pd, N), jnp.float32)

    idx = jnp.arange(Q)
    tri = idx[:, None] >= idx[None, :]                  # [Qi, Qj] causal

    def body(state, inp):
        xq, dtq, bq, cq, cumq = inp                     # [B,Q,...]
        state = hint(state, "ssm_state")
        dtx = xq.astype(jnp.float32) * dtq[..., None]   # [B,Q,H,P]
        # --- intra-chunk (attention-like) ---
        seg = jnp.exp(cumq[:, :, None, :] - cumq[:, None, :, :])  # [B,Qi,Qj,H]
        seg = jnp.where(tri[None, :, :, None], seg, 0.0)
        scores = jnp.einsum("bihn,bjhn->bijh", cq.astype(jnp.float32),
                            bq.astype(jnp.float32)) * seg
        y = jnp.einsum("bijh,bjhp->bihp", scores, dtx)
        # --- inter-chunk (carried state) ---
        y = y + jnp.einsum("bihn,bhpn->bihp", cq.astype(jnp.float32), state) \
            * jnp.exp(cumq)[..., None]
        decay_end = jnp.exp(cumq[:, -1, :])             # [B,H]
        w = jnp.exp(cumq[:, -1:, :] - cumq)             # [B,Q,H]
        s_local = jnp.einsum("bjhn,bjhp->bhpn",
                             bq.astype(jnp.float32) * w[..., None], dtx)
        state = state * decay_end[:, :, None, None] + s_local
        return state, y

    if nc == 1:
        state, y = body(s0, (xc[0], dtc[0], Bc[0], Cc[0], cum[0]))
        ys = y[None]
    else:
        state, ys = jax.lax.scan(body, s0, (xc, dtc, Bc, Cc, cum))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, Tp, H, Pd)[:, :T]
    return y, state


def ssm_apply(params: Params, x: jnp.ndarray, cfg: ModelConfig,
              gate_mask: Optional[jnp.ndarray] = None,
              conv_state: Optional[Tuple] = None,
              ssm_state: Optional[jnp.ndarray] = None,
              ) -> Tuple[jnp.ndarray, Tuple]:
    """Full-sequence forward.  x: [B, T, D]; gate_mask: [B, T] 0/1 keep mask
    (SkipGPT masked-contribution routing).

    Returns (y, ((conv_x_hist, conv_bc_hist), ssm_state))."""
    di, g, n, nh, p = _dims(cfg)
    B, T, D = x.shape
    z = layers.linear_apply(params["in_proj_z"], x, cfg)
    xin = layers.linear_apply(params["in_proj_x"], x, cfg)
    bc = layers.linear_apply(params["in_proj_bc"], x, cfg)
    dt = layers.linear_apply(params["in_proj_dt"], x, cfg)

    cs_x, cs_bc = conv_state if conv_state is not None else (None, None)
    W = cfg.ssm_conv

    def hist(raw, cs):
        h = raw if cs is None else jnp.concatenate([cs, raw], axis=1)
        if h.shape[1] < W - 1:
            h = jnp.pad(h, ((0, 0), (W - 1 - h.shape[1], 0), (0, 0)))
        return h[:, -(W - 1):, :]
    new_conv_state = (hist(xin, cs_x), hist(bc, cs_bc))
    xin = _causal_conv(xin, params["conv_x_w"], params["conv_x_b"], cs_x)
    bc = _causal_conv(bc, params["conv_bc_w"], params["conv_bc_b"], cs_bc)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # [B,T,H]
    if gate_mask is not None:
        dt = dt * gate_mask.astype(jnp.float32)[..., None]

    xh = xin.reshape(B, T, nh, p)
    Bc_, Cc_ = jnp.split(bc, 2, axis=-1)
    Bm = _expand_groups(Bc_.reshape(B, T, g, n), cfg)
    Cm = _expand_groups(Cc_.reshape(B, T, g, n), cfg)

    y, state = ssd_scan(xh, dt, params["A_log"], Bm, Cm, cfg.ssm_chunk,
                        init_state=ssm_state)
    y = y + params["D"][None, None, :, None] * xh.astype(jnp.float32)
    if gate_mask is not None:
        y = y * gate_mask.astype(jnp.float32)[..., None, None]
    y = y.reshape(B, T, di).astype(x.dtype)

    y = y * jax.nn.silu(z)
    y = layers.rms_head_norm(params["norm"], y, cfg.norm_eps)
    out = layers.linear_apply(params["out_proj"], y, cfg)
    return out, (new_conv_state, state)


def ssm_step(params: Params, x: jnp.ndarray, cfg: ModelConfig,
             conv_state: Tuple[jnp.ndarray, jnp.ndarray],
             ssm_state: jnp.ndarray,
             gate_mask: Optional[jnp.ndarray] = None,
             ) -> Tuple[jnp.ndarray, Tuple]:
    """Single-token decode.  x: [B, 1, D]; conv_state: (x_hist [B,W-1,di],
    bc_hist [B,W-1,2gn]) pre-activation inputs; ssm_state: [B, H, P, N]."""
    di, g, n, nh, p = _dims(cfg)
    B = x.shape[0]
    z = layers.linear_apply(params["in_proj_z"], x, cfg)
    xin = layers.linear_apply(params["in_proj_x"], x, cfg)
    bc = layers.linear_apply(params["in_proj_bc"], x, cfg)
    dt = layers.linear_apply(params["in_proj_dt"], x, cfg)

    cs_x, cs_bc = conv_state

    def step_conv(raw, cs, w, b):
        window = jnp.concatenate([cs, raw], axis=1)          # [B, W, C]
        out = jax.nn.silu(jnp.einsum("bwc,wc->bc", window, w) + b)
        return out[:, None, :], window[:, 1:, :]

    xin, new_cs_x = step_conv(xin, cs_x, params["conv_x_w"], params["conv_x_b"])
    bc, new_cs_bc = step_conv(bc, cs_bc, params["conv_bc_w"], params["conv_bc_b"])

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])[:, 0]  # [B,H]
    if gate_mask is not None:
        dt = dt * gate_mask.astype(jnp.float32)[:, None]
    dA = jnp.exp(dt * (-jnp.exp(params["A_log"])))           # [B,H]

    xh = xin.reshape(B, nh, p).astype(jnp.float32)
    Bc_, Cc_ = jnp.split(bc, 2, axis=-1)
    Bm = _expand_groups(Bc_.reshape(B, g, n), cfg)           # [B,H,N]
    Cm = _expand_groups(Cc_.reshape(B, g, n), cfg)

    upd = jnp.einsum("bhp,bhn->bhpn", xh * dt[..., None], Bm.astype(jnp.float32))
    new_state = ssm_state * dA[:, :, None, None] + upd
    y = jnp.einsum("bhn,bhpn->bhp", Cm.astype(jnp.float32), new_state)
    y = y + params["D"][None, :, None] * xh
    if gate_mask is not None:
        y = y * gate_mask.astype(jnp.float32)[:, None, None]
    y = y.reshape(B, 1, di).astype(x.dtype)

    y = y * jax.nn.silu(z)
    y = layers.rms_head_norm(params["norm"], y, cfg.norm_eps)
    out = layers.linear_apply(params["out_proj"], y, cfg)
    return out, ((new_cs_x, new_cs_bc), new_state)
