"""Layer-stack composition: heterogeneous super-block scan.

Layers are grouped into *stages* of ``cfg.stage_len`` layers (the lcm of the
interleave pattern and the MoE period) so arbitrary patterns — Gemma-3's
5 local : 1 global, Jamba's 1 attn : 7 mamba with every-2nd-layer MoE —
compile as ONE scanned super-block.  Stage 0 runs unrolled: it anchors the
cross-layer KV-reuse recursion (the view base case) and the decode-time
single-token view carry.

Caches:
  * global-attention layers: dense per-layer KV view [B, Tmax, Hkv, dh]
  * local (sliding-window) layers: ring buffer [B, W, Hkv, dh] written at
    ``pos % W`` — this is what makes ``long_500k`` decoding feasible
  * mamba layers: (conv history, SSD state)
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ATTN, LOCAL, MAMBA, ModelConfig
from repro.core import skip_block
from repro.distributed.sharding import hint
from repro.models import attention as attn_mod
from repro.models import layers, moe as moe_mod, ssm as ssm_mod
from repro.models.layers import Params


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _routed_init(key, cfg: ModelConfig, inner) -> Params:
    from repro.core import routing
    k1, k2 = jax.random.split(key)
    return {
        "router": routing.router_init(k1, cfg),
        "norm": layers.norm_init(cfg.d_model, cfg),
        "inner": inner,
    }


def block_init(key, cfg: ModelConfig, pos_in_stage: int) -> Params:
    """One layer's parameters.  ``pos_in_stage`` determines kind/MoE (stage
    structure repeats identically across stages)."""
    kind = cfg.block_kind(pos_in_stage)
    is_moe = cfg.is_moe_layer(pos_in_stage)
    ks = jax.random.split(key, 4)
    p: Params = {}
    if kind == MAMBA:
        p["mixer"] = _routed_init(ks[0], cfg, ssm_mod.ssm_init(ks[1], cfg))
    else:
        p["mixer"] = _routed_init(ks[0], cfg, attn_mod.attention_init(ks[1], cfg))
    if is_moe:
        p["ffn"] = _routed_init(ks[2], cfg, moe_mod.moe_init(ks[3], cfg))
    elif cfg.d_ff:
        p["ffn"] = _routed_init(ks[2], cfg, layers.mlp_init(ks[3], cfg))
    return p


def stage_init(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, cfg.stage_len)
    return {f"pos{k}": block_init(ks[k], cfg, k) for k in range(cfg.stage_len)}


def stack_init(key, cfg: ModelConfig) -> Params:
    """{'stage0': stage params, 'stages': stacked [S-1, ...] params}."""
    S = cfg.num_stages
    ks = jax.random.split(key, S)
    p: Params = {"stage0": stage_init(ks[0], cfg)}
    if S > 1:
        stacked = jax.vmap(lambda k: stage_init(k, cfg))(jnp.stack(ks[1:]))
        p["stages"] = stacked
    return p


# ---------------------------------------------------------------------------
# Stage forward (prefill / train)
# ---------------------------------------------------------------------------

_ZERO_STATS = lambda: {"router_loss": jnp.float32(0.0),
                       "keep_frac_sum": jnp.float32(0.0),
                       "n_routed": jnp.float32(0.0),
                       "moe_lb_loss": jnp.float32(0.0),
                       "n_moe": jnp.float32(0.0)}


def _acc_stats(acc: Dict, s: Dict, routed_kind: bool) -> Dict:
    acc = dict(acc)
    acc["router_loss"] += s.get("router_loss", 0.0)
    if routed_kind:
        acc["keep_frac_sum"] += s.get("keep_frac", 0.0)
        acc["n_routed"] += 1.0
    if "moe_lb_loss" in s:
        acc["moe_lb_loss"] += s["moe_lb_loss"]
        acc["n_moe"] += 1.0
    return acc


def _ffn_inner(cfg: ModelConfig, is_moe: bool):
    if is_moe:
        return lambda p, xn: moe_mod.moe_apply(p, xn, cfg)
    return lambda p, xn: (layers.mlp_apply(p, xn, cfg), {})


def stage_forward(stage_params: Params, x: jnp.ndarray,
                  view: Optional[Tuple], positions: jnp.ndarray,
                  cfg: ModelConfig, rng: Optional[jax.Array], train: bool,
                  collect_cache: bool, is_stage0: bool,
                  carried_sq: Optional[jnp.ndarray] = None
                  ) -> Tuple[jnp.ndarray, Optional[Tuple], Dict, Dict,
                             Optional[jnp.ndarray]]:
    """Apply one super-block.  Returns (x, view, stats, cache, carried_sq);
    stats carries ``attn_gate`` [n_attn_in_stage, B, T] — the per-layer
    execution gates (the paged KV engine packs prefill entries from them).
    ``carried_sq`` threads the fused-epilogue Σy²/D of the residual stream
    between blocks (the incremental-reduction carry): each fused block's
    norm reduction is paid for by the previous block's epilogue."""
    stats = _ZERO_STATS()
    cache: Dict[str, Any] = {}
    gates: List[jnp.ndarray] = []
    T = x.shape[1]
    for k in range(cfg.stage_len):
        bp = stage_params[f"pos{k}"]
        kind = cfg.block_kind(k)
        is_moe = cfg.is_moe_layer(k)
        r_mix = (jax.random.fold_in(rng, 2 * k) if rng is not None else None)
        r_ffn = (jax.random.fold_in(rng, 2 * k + 1) if rng is not None else None)

        if kind == MAMBA:
            x, states, s = skip_block.routed_ssm(
                bp["mixer"], x, cfg, rng=r_mix, train=train,
                carried_sq=carried_sq)
            carried_sq = None            # SSM blocks don't emit the carry
            stats = _acc_stats(stats, s, cfg.skip.route_ssm)
            if collect_cache:
                cache[f"pos{k}"] = {"conv_x": states[0][0],
                                    "conv_bc": states[0][1],
                                    "ssm": states[1]}
        else:
            window = cfg.window_size if kind == LOCAL else 0
            # Local layers keep their own (window-bounded) view; the global
            # cross-layer reuse chain only threads through matching kinds.
            x, view, s = skip_block.routed_attention(
                bp["mixer"], x, view, positions, cfg, rng=r_mix, train=train,
                window=window, carried_sq=carried_sq)
            carried_sq = s.pop("res_sq", None)
            gates.append(s["attn_gate"])
            stats = _acc_stats(stats, s, cfg.skip.route_attention)
            if collect_cache:
                if kind == LOCAL and cfg.window_size and T > cfg.window_size:
                    cache[f"pos{k}"] = {
                        "k": _ring_from_linear(view[0], cfg.window_size),
                        "v": _ring_from_linear(view[1], cfg.window_size)}
                else:
                    cache[f"pos{k}"] = {"k": view[0], "v": view[1]}

        if "ffn" in bp:
            x, s = skip_block.routed_mlp(
                bp["ffn"], x, cfg, inner_fn=_ffn_inner(cfg, is_moe),
                rng=r_ffn, train=train, carried_sq=carried_sq)
            carried_sq = s.pop("res_sq", None)
            stats = _acc_stats(stats, s, cfg.skip.route_mlp)
    if gates:
        stats["attn_gate"] = jnp.stack(gates)
    return x, view, stats, cache, carried_sq


def _ring_from_linear(kv: jnp.ndarray, W: int) -> jnp.ndarray:
    """[B, T, H, d] -> ring buffer [B, W, H, d]: slot s holds the latest
    position ≡ s (mod W)."""
    T = kv.shape[1]
    if T <= W:
        return jnp.pad(kv, ((0, 0), (0, W - T), (0, 0), (0, 0)))
    tail = kv[:, T - W:]                                 # positions T-W..T-1
    shift = (T - W) % W
    return jnp.roll(tail, shift, axis=1)


def ring_positions(t: jnp.ndarray, W: int) -> jnp.ndarray:
    """Absolute position stored in each ring slot after writing position t.
    slot s holds p = t - ((t - s) mod W);  p < 0 => empty."""
    s = jnp.arange(W)
    return t - ((t - s) % W)


# ---------------------------------------------------------------------------
# Stage decode step
# ---------------------------------------------------------------------------

def stage_decode(stage_params: Params, cache: Dict, x: jnp.ndarray,
                 kv_prev: Optional[Tuple], t: jnp.ndarray,
                 positions: jnp.ndarray, cfg: ModelConfig,
                 carried_sq: Optional[jnp.ndarray] = None
                 ) -> Tuple[jnp.ndarray, Optional[Tuple], Dict, Dict,
                            Optional[jnp.ndarray]]:
    """One super-block, one token per sequence.  ``t``: [B] int32 (or scalar,
    broadcast — lock-step decode).  Returns (x, kv_prev, new_cache, stats,
    carried_sq); stats carries ``attn_gate`` [n_attn_in_stage, B] — the
    per-layer execution gates the serve engine logs for measured KV-storage
    accounting.  ``carried_sq`` is the fused-epilogue reduction carry."""
    stats = _ZERO_STATS()
    new_cache: Dict[str, Any] = {}
    gates: List[jnp.ndarray] = []
    for k in range(cfg.stage_len):
        bp = stage_params[f"pos{k}"]
        ce = cache[f"pos{k}"]
        kind = cfg.block_kind(k)
        is_moe = cfg.is_moe_layer(k)

        if kind == MAMBA:
            x, states, s = skip_block.routed_ssm_decode(
                bp["mixer"], x, cfg, conv_state=(ce["conv_x"], ce["conv_bc"]),
                ssm_state=ce["ssm"], carried_sq=carried_sq)
            carried_sq = None
            new_cache[f"pos{k}"] = {"conv_x": states[0][0],
                                    "conv_bc": states[0][1],
                                    "ssm": states[1]}
            stats = _acc_stats(stats, s, cfg.skip.route_ssm)
        elif kind == LOCAL and ce["k"].shape[1] == cfg.window_size:
            x, kc, vc, kv_prev_l, s = _ring_attention_decode(
                bp["mixer"], x, ce["k"], ce["v"], t, kv_prev, positions, cfg,
                carried_sq=carried_sq)
            carried_sq = s.pop("res_sq", None)
            new_cache[f"pos{k}"] = {"k": kc, "v": vc}
            kv_prev = kv_prev_l
            gates.append(s["attn_gate"])
            stats = _acc_stats(stats, s, cfg.skip.route_attention)
        else:
            window = cfg.window_size if kind == LOCAL else 0
            x, kc, vc, kv_prev, s = skip_block.routed_attention_decode(
                bp["mixer"], x, ce["k"], ce["v"], t, kv_prev, positions, cfg,
                window=window, carried_sq=carried_sq)
            carried_sq = s.pop("res_sq", None)
            new_cache[f"pos{k}"] = {"k": kc, "v": vc}
            gates.append(s["attn_gate"])
            stats = _acc_stats(stats, s, cfg.skip.route_attention)

        if "ffn" in bp:
            x, s = skip_block.routed_mlp_decode(
                bp["ffn"], x, cfg, inner_fn=_ffn_inner(cfg, is_moe),
                carried_sq=carried_sq)
            carried_sq = s.pop("res_sq", None)
            stats = _acc_stats(stats, s, cfg.skip.route_mlp)
    if gates:
        stats["attn_gate"] = jnp.stack(gates)
    return x, kv_prev, new_cache, stats, carried_sq


def stage_prefill_chunk(stage_params: Params, cache: Dict, x: jnp.ndarray,
                        kv_prev: Optional[Tuple], t0: jnp.ndarray,
                        positions: jnp.ndarray, cfg: ModelConfig,
                        carried_sq: Optional[jnp.ndarray] = None
                        ) -> Tuple[jnp.ndarray, Optional[Tuple], Dict, Dict,
                                   Optional[jnp.ndarray]]:
    """One super-block over one prefill *chunk* of C tokens (resumable
    prefill — see ``model.prefill_chunk``).  Requires an all-global-attn
    stack with masked-mode routing (``serve.scheduler.can_chunk_prefill``).

    ``cache`` holds each layer's dense KV view of the already-prefilled
    prefix in prefill (time-major) layout; the chunk's merged view is
    appended at [t0, t0+C) so the cache stays exactly what monolithic
    prefill would have collected.  ``kv_prev`` threads the chunk tokens'
    cross-layer reuse view between layers and ``carried_sq`` the fused
    pipeline's Σy²/D reduction carry, both restricted to the chunk —
    per-token state, so chunk boundaries cannot perturb them."""
    stats = _ZERO_STATS()
    new_cache: Dict[str, Any] = {}
    gates: List[jnp.ndarray] = []
    for k in range(cfg.stage_len):
        bp = stage_params[f"pos{k}"]
        ce = cache[f"pos{k}"]
        assert cfg.block_kind(k) == ATTN, \
            "chunked prefill requires an all-global-attn stack"
        x, kc, vc, kv_prev, s = skip_block.routed_attention_chunk(
            bp["mixer"], x, ce["k"], ce["v"], t0, kv_prev, positions, cfg,
            carried_sq=carried_sq)
        carried_sq = s.pop("res_sq", None)
        new_cache[f"pos{k}"] = {"k": kc, "v": vc}
        gates.append(s["attn_gate"])
        stats = _acc_stats(stats, s, cfg.skip.route_attention)
        if "ffn" in bp:
            x, s = skip_block.routed_mlp(
                bp["ffn"], x, cfg, inner_fn=_ffn_inner(cfg, cfg.is_moe_layer(k)),
                rng=None, train=False, carried_sq=carried_sq)
            carried_sq = s.pop("res_sq", None)
            stats = _acc_stats(stats, s, cfg.skip.route_mlp)
    stats["attn_gate"] = jnp.stack(gates)
    return x, kv_prev, new_cache, stats, carried_sq


def stage_decode_paged(stage_params: Params, x: jnp.ndarray,
                       kv_prev: Optional[Tuple], t: jnp.ndarray,
                       positions: jnp.ndarray, cfg: ModelConfig,
                       paged: Dict, a_base: jnp.ndarray,
                       carried_sq: Optional[jnp.ndarray] = None
                       ) -> Tuple[jnp.ndarray, Optional[Tuple], Dict,
                                  Optional[jnp.ndarray]]:
    """One super-block against the paged KV store (decode, one token per
    sequence).  Requires ``kvcache.paged.can_page(cfg)`` — every mixer is
    global attention, so there is no per-stage dense cache: reads resolve
    through the shared entry stream in ``paged`` and writes are collected
    into per-layer token views the caller commits once per step.

    ``a_base``: attention-layer index of this stage's first layer (traced).
    Returns (x, kv_prev, stats, carried_sq) with stats['attn_gate']
    [nA_stage, B] and stats['kv_token'] = (k_t, v_t) [nA_stage, B, Hkv, dh]
    stacks."""
    stats = _ZERO_STATS()
    gates: List[jnp.ndarray] = []
    k_toks: List[jnp.ndarray] = []
    v_toks: List[jnp.ndarray] = []
    for k in range(cfg.stage_len):
        bp = stage_params[f"pos{k}"]
        kind = cfg.block_kind(k)
        assert kind == ATTN, "paged decode requires an all-global-attn stack"
        x, kv_prev, s = skip_block.routed_attention_decode_paged(
            bp["mixer"], x, t, kv_prev, positions, cfg,
            paged=paged, layer=a_base + len(gates), carried_sq=carried_sq)
        carried_sq = s.pop("res_sq", None)
        gates.append(s.pop("attn_gate"))
        k_toks.append(kv_prev[0][:, 0])
        v_toks.append(kv_prev[1][:, 0])
        stats = _acc_stats(stats, s, cfg.skip.route_attention)
        if "ffn" in bp:
            x, s = skip_block.routed_mlp_decode(
                bp["ffn"], x, cfg, inner_fn=_ffn_inner(cfg, cfg.is_moe_layer(k)),
                carried_sq=carried_sq)
            carried_sq = s.pop("res_sq", None)
            stats = _acc_stats(stats, s, cfg.skip.route_mlp)
    stats["attn_gate"] = jnp.stack(gates)
    stats["kv_token"] = (jnp.stack(k_toks), jnp.stack(v_toks))
    return x, kv_prev, stats, carried_sq


def stage_verify_paged(stage_params: Params, x: jnp.ndarray,
                       kv_prev: Optional[Tuple], positions: jnp.ndarray,
                       cfg: ModelConfig, paged: Dict, a_base: jnp.ndarray,
                       carried_sq: Optional[jnp.ndarray] = None
                       ) -> Tuple[jnp.ndarray, Optional[Tuple], Dict,
                                  Optional[jnp.ndarray]]:
    """``stage_decode_paged``'s C-token verify twin (speculative
    decoding): one super-block over a k+1-token window, reads resolving
    through the committed entry stream, the store never written.  The
    *full-window* per-layer token views are collected instead of the
    single-token slice — stats['kv_token'] = (k, v)
    [nA_stage, B, C, Hkv, dh] stacks — so ``model.commit_verified`` can
    append exactly the accepted columns after the host's accept test.
    stats['attn_gate'] is [nA_stage, B, C]."""
    stats = _ZERO_STATS()
    gates: List[jnp.ndarray] = []
    k_toks: List[jnp.ndarray] = []
    v_toks: List[jnp.ndarray] = []
    for k in range(cfg.stage_len):
        bp = stage_params[f"pos{k}"]
        assert cfg.block_kind(k) == ATTN, \
            "paged verify requires an all-global-attn stack"
        x, kv_prev, s = skip_block.routed_attention_chunk_paged(
            bp["mixer"], x, kv_prev, positions, cfg,
            paged=paged, layer=a_base + len(gates), carried_sq=carried_sq)
        carried_sq = s.pop("res_sq", None)
        gates.append(s.pop("attn_gate"))
        k_toks.append(kv_prev[0])
        v_toks.append(kv_prev[1])
        stats = _acc_stats(stats, s, cfg.skip.route_attention)
        if "ffn" in bp:
            x, s = skip_block.routed_mlp(
                bp["ffn"], x, cfg,
                inner_fn=_ffn_inner(cfg, cfg.is_moe_layer(k)),
                rng=None, train=False, carried_sq=carried_sq)
            carried_sq = s.pop("res_sq", None)
            stats = _acc_stats(stats, s, cfg.skip.route_mlp)
    stats["attn_gate"] = jnp.stack(gates)
    stats["kv_token"] = (jnp.stack(k_toks), jnp.stack(v_toks))
    return x, kv_prev, stats, carried_sq


def _ring_attention_decode(p: Params, x, k_ring, v_ring, t, kv_prev,
                           positions, cfg: ModelConfig, carried_sq=None):
    """Sliding-window decode against a ring buffer cache [B, W, H, d].
    ``t``: [B] per-sequence positions (scalar broadcasts)."""
    from repro.core import kv_reuse, routing

    B = x.shape[0]
    W = cfg.window_size
    t = jnp.broadcast_to(jnp.atleast_1d(jnp.asarray(t, jnp.int32)), (B,))
    routed = cfg.skip.enabled and cfg.skip.route_attention
    logits, nstats = skip_block._router_and_stats(p, x, cfg, routed,
                                                  carried_sq)
    gate, p_keep = skip_block._gate(
        logits[:, 0] if logits is not None else None, None, cfg, False, (B,),
        routed)
    inner = p["inner"]
    fuse = layers.fuse_norm_linear(cfg)
    if fuse:
        q, k_new, v_new = attn_mod.project_qkv(
            inner, x, positions, cfg, norm=p["norm"], stats=nstats)
    else:
        xn = layers.norm_apply(p["norm"], x, cfg, stats=nstats)
        q = attn_mod.project_q(inner, xn, positions, cfg)
        k_new, v_new = attn_mod.project_kv(inner, xn, positions, cfg)
    if routed and cfg.skip.kv_reuse:
        k_t, v_t = kv_reuse.merge_token_view(kv_prev, k_new, v_new, gate)
    else:
        k_t, v_t = k_new, v_new

    slot = jnp.mod(t, W)                                 # [B]
    k_ring = skip_block._row_update(k_ring, k_t.astype(k_ring.dtype), slot,
                                    time_axis=0)
    v_ring = skip_block._row_update(v_ring, v_t.astype(v_ring.dtype), slot,
                                    time_axis=0)

    kv_pos = jax.vmap(ring_positions, in_axes=(0, None))(t, W)   # [B, W]
    mask_valid = kv_pos >= 0
    # emulate kv_valid_len via an explicit mask: map invalid slots to a
    # position beyond t so the causal mask kills them.
    q_pos = skip_block._q_index_positions(positions)
    eff_pos = jnp.where(mask_valid, kv_pos, (t + 1)[:, None])
    o = attn_mod.chunked_attention(
        q, k_ring, v_ring,
        q_positions=q_pos, causal=True, window=0,
        chunk=W, softmax_scale=None,
        kv_positions=eff_pos)
    stats = routing.router_stats(p_keep, gate, cfg) if routed else {
        "keep_frac": jnp.float32(1.0), "router_loss": jnp.float32(0.0)}
    x = skip_block._decode_output_epilogue(inner, o, x, gate, routed, fuse,
                                           cfg, stats)
    stats["attn_gate"] = gate
    return x, k_ring, v_ring, (k_t, v_t), stats
