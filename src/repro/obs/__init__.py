"""Engine observability: span tracing + metrics registry.

See docs/observability.md for the span taxonomy and metric catalog."""
from repro.obs.metrics import (DEFAULT_BUCKETS, Histogram,  # noqa: F401
                               MetricsRegistry)
from repro.obs.trace import (ENGINE_TID, NullTracer, Tracer,  # noqa: F401
                             as_tracer, jit_cache_size, request_tid)
