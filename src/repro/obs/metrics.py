"""Metrics registry: labeled counters / gauges / histograms / series.

The serving engines maintain one ``MetricsRegistry`` per ``run()`` as the
single source of truth for run accounting — ``ServeStats`` is a *derived
view* over it (``serve/engine.py::_finalize`` reads every counter field
out of the registry), so the flat stats dataclass keeps its meaning while
the registry adds what a flat aggregate cannot hold:

* **labeled series** — e.g. per-attention-layer keep rate
  (``attn_keep_rate{layer=i}``) and history hit rate;
* **histograms** — TTFT / TPOT / decode-stall / step-wall distributions,
  not just means;
* **time series** — keep rate and measured KV-saved fraction sampled per
  engine step, so routing/KV behaviour is visible *over* a run instead
  of as one end-of-run scalar.

Zero dependencies.  Snapshots export as JSON (``snapshot()``) and
Prometheus text exposition format (``to_prometheus()``; series are a
JSON-only concept — Prometheus scrapes would sample them as gauges).
"""
from __future__ import annotations

import bisect
from typing import Dict, List, Optional, Sequence, Tuple

# default histogram buckets: wall-second scales from 10us to ~2min
DEFAULT_BUCKETS: Tuple[float, ...] = (
    1e-5, 3e-5, 1e-4, 3e-4, 1e-3, 3e-3, 1e-2, 3e-2,
    0.1, 0.3, 1.0, 3.0, 10.0, 30.0, 120.0)

_KINDS = ("counter", "gauge", "histogram", "series")


def _label_key(labels: Dict[str, object]) -> str:
    """Canonical string key for a label set ('' = unlabeled)."""
    return ",".join(f"{k}={labels[k]}" for k in sorted(labels))


class Histogram:
    """Fixed-bucket histogram (cumulative counts, Prometheus-style)."""

    def __init__(self, buckets: Sequence[float] = DEFAULT_BUCKETS):
        self.bounds: Tuple[float, ...] = tuple(sorted(buckets))
        self.counts: List[int] = [0] * (len(self.bounds) + 1)  # +inf tail
        self.sum = 0.0
        self.count = 0

    def observe(self, v: float) -> None:
        self.counts[bisect.bisect_left(self.bounds, v)] += 1
        self.sum += v
        self.count += 1

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def to_dict(self) -> dict:
        return {"count": self.count, "sum": self.sum,
                "buckets": {("+Inf" if i == len(self.bounds)
                             else repr(self.bounds[i])): c
                            for i, c in enumerate(self.counts)}}


class _Family:
    """One metric name: kind + help string + per-label-set children."""

    def __init__(self, name: str, kind: str, help: str = ""):
        self.name = name
        self.kind = kind
        self.help = help
        self.children: Dict[str, object] = {}


class MetricsRegistry:
    """Flat-API registry (``inc`` / ``set`` / ``observe`` / ``record``).

    A metric's kind is fixed by its first use; reusing a name with a
    different kind raises (catches double-bookkeeping bugs early)."""

    def __init__(self):
        self._families: Dict[str, _Family] = {}

    def _family(self, name: str, kind: str, help: str) -> _Family:
        fam = self._families.get(name)
        if fam is None:
            fam = self._families[name] = _Family(name, kind, help)
        elif fam.kind != kind:
            raise ValueError(f"metric {name!r} is a {fam.kind}, not {kind}")
        if help and not fam.help:
            fam.help = help
        return fam

    # -- write API ---------------------------------------------------------
    def inc(self, name: str, v: float = 1.0, help: str = "",
            **labels) -> None:
        """Counter: monotonically accumulating value."""
        fam = self._family(name, "counter", help)
        k = _label_key(labels)
        fam.children[k] = fam.children.get(k, 0.0) + v

    def set(self, name: str, v: float, help: str = "", **labels) -> None:
        """Gauge: last-written value (the peak is tracked alongside and
        exported as ``<name>.max`` in snapshots)."""
        fam = self._family(name, "gauge", help)
        k = _label_key(labels)
        prev = fam.children.get(k)
        peak = v if prev is None else max(prev[1], v)
        fam.children[k] = (v, peak)

    def observe(self, name: str, v: float, help: str = "",
                buckets: Sequence[float] = DEFAULT_BUCKETS,
                **labels) -> None:
        """Histogram sample."""
        fam = self._family(name, "histogram", help)
        k = _label_key(labels)
        h = fam.children.get(k)
        if h is None:
            h = fam.children[k] = Histogram(buckets)
        h.observe(v)

    def record(self, name: str, x: float, v: float, help: str = "",
               **labels) -> None:
        """Time-series point (x = engine step index or wall seconds)."""
        fam = self._family(name, "series", help)
        k = _label_key(labels)
        fam.children.setdefault(k, []).append((float(x), float(v)))

    # -- read API ----------------------------------------------------------
    def value(self, name: str, default: float = 0.0, **labels) -> float:
        """Counter total / gauge last value for one label set."""
        fam = self._families.get(name)
        if fam is None:
            return default
        child = fam.children.get(_label_key(labels))
        if child is None:
            return default
        if fam.kind == "gauge":
            return child[0]
        if fam.kind == "counter":
            return child
        raise ValueError(f"value() on {fam.kind} metric {name!r}")

    def peak(self, name: str, default: float = 0.0, **labels) -> float:
        fam = self._families.get(name)
        if fam is None or fam.kind != "gauge":
            return default
        child = fam.children.get(_label_key(labels))
        return default if child is None else child[1]

    def histogram(self, name: str, **labels) -> Optional[Histogram]:
        fam = self._families.get(name)
        if fam is None:
            return None
        return fam.children.get(_label_key(labels))

    def series(self, name: str, **labels) -> List[Tuple[float, float]]:
        fam = self._families.get(name)
        if fam is None:
            return []
        return list(fam.children.get(_label_key(labels), []))

    # -- export ------------------------------------------------------------
    def snapshot(self) -> dict:
        """JSON-able snapshot of every metric, grouped by kind."""
        out: dict = {k + "s": {} for k in _KINDS}
        for fam in self._families.values():
            dst = out[fam.kind + "s"]
            if fam.kind == "counter":
                dst[fam.name] = dict(fam.children)
            elif fam.kind == "gauge":
                dst[fam.name] = {k: {"value": v, "max": p}
                                 for k, (v, p) in fam.children.items()}
            elif fam.kind == "histogram":
                dst[fam.name] = {k: h.to_dict()
                                 for k, h in fam.children.items()}
            else:
                dst[fam.name] = {k: [list(p) for p in pts]
                                 for k, pts in fam.children.items()}
        return out

    def to_prometheus(self) -> str:
        """Prometheus text exposition format (series exported as gauges
        at their last sample)."""
        lines: List[str] = []
        for fam in sorted(self._families.values(), key=lambda f: f.name):
            ptype = "gauge" if fam.kind == "series" else fam.kind
            if fam.help:
                lines.append(f"# HELP {fam.name} {fam.help}")
            lines.append(f"# TYPE {fam.name} {ptype}")
            for k in sorted(fam.children):
                child = fam.children[k]
                lab = "{%s}" % ",".join(
                    f'{p.split("=", 1)[0]}="{p.split("=", 1)[1]}"'
                    for p in k.split(",")) if k else ""
                if fam.kind == "counter":
                    lines.append(f"{fam.name}{lab} {child:g}")
                elif fam.kind == "gauge":
                    lines.append(f"{fam.name}{lab} {child[0]:g}")
                elif fam.kind == "series":
                    last = child[-1][1] if child else 0.0
                    lines.append(f"{fam.name}{lab} {last:g}")
                else:                                  # histogram
                    run = 0
                    for i, c in enumerate(child.counts):
                        run += c
                        le = ("+Inf" if i == len(child.bounds)
                              else f"{child.bounds[i]:g}")
                        extra = f',le="{le}"' if k else f'le="{le}"'
                        plab = ("{%s%s}" % (
                            lab[1:-1], extra) if k else "{%s}" % extra)
                        lines.append(f"{fam.name}_bucket{plab} {run}")
                    lines.append(f"{fam.name}_sum{lab} {child.sum:g}")
                    lines.append(f"{fam.name}_count{lab} {child.count}")
        return "\n".join(lines) + "\n"
