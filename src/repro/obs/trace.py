"""Zero-dependency span tracer emitting Chrome trace-event JSON.

The serving engines record two families of spans into one timeline
(loadable in Perfetto / ``chrome://tracing``):

* **engine track** (tid 0): one ``step`` span per run-loop iteration with
  ``plan`` / ``prefill`` / ``dispatch`` / ``sync`` / ``bookkeep``
  children — the host-side phase breakdown of every engine iteration —
  plus ``C`` counter series (queue depth, resident slots, free pages)
  and ``compile`` instants whenever a jitted dispatch added a new
  compiled variant (how pow2-epoch recompiles become visible).
* **request tracks** (tid = 1 + uid): the per-request lifecycle
  ``request ⊃ queued → prefill[chunk i] → decode[epoch j] → finish``,
  with ``preempt``/``requeue`` instants when paged backpressure evicts
  the request back into the queue.

Spans are emitted as matched ``"B"``/``"E"`` duration events (the
begin/end pairing is what ``tools/trace_summary.py`` and the schema test
validate); counters are ``"C"`` events and instants ``"i"``.  Timestamps
are microseconds of ``time.perf_counter`` since tracer creation —
monotonic, never NTP-skewed.

``NullTracer`` is the always-off twin every engine holds by default: the
same API as no-op methods, so the run loops trace unconditionally and
pay only a method call when tracing is off (the <3 % goodput bound
``benchmarks/bench_observability.py`` enforces covers tracing *on*).
"""
from __future__ import annotations

import contextlib
import json
import pathlib
from time import perf_counter
from typing import Dict, List, Optional, Union

import jax

ENGINE_TID = 0          # the engine run-loop track
_PID = 1                # single logical process


def request_tid(uid: int) -> int:
    """Track id for request ``uid`` (engine track is tid 0)."""
    return 1 + uid


class Tracer:
    """Chrome-trace-event span recorder (see module docstring).

    ``path``: optional default output file — ``ContinuousBatchingEngine``
    saves there at the end of every ``run()`` when the tracer was built
    from a path string.
    """

    enabled: bool = True

    def __init__(self, path: Optional[Union[str, pathlib.Path]] = None):
        self.path = pathlib.Path(path) if path is not None else None
        self._t0 = perf_counter()
        self.events: List[dict] = []
        self._open: Dict[int, List[str]] = {}     # tid -> open span names
        self._named: set = set()                  # tids with thread_name set
        self._event({"name": "process_name", "ph": "M", "pid": _PID, "tid": 0,
                     "args": {"name": "skipopu-serve"}})
        self.track(ENGINE_TID, "engine")

    # -- primitives --------------------------------------------------------
    def now_us(self) -> float:
        return (perf_counter() - self._t0) * 1e6

    def to_us(self, t: float) -> float:
        """Convert a raw ``perf_counter()`` reading to trace microseconds
        (for ``span_at`` bounds captured outside the tracer)."""
        return (t - self._t0) * 1e6

    def _event(self, ev: dict) -> None:
        self.events.append(ev)

    def track(self, tid: int, name: str) -> None:
        """Name a track once (``thread_name`` metadata event)."""
        if tid in self._named:
            return
        self._named.add(tid)
        self._event({"name": "thread_name", "ph": "M", "pid": _PID,
                     "tid": tid, "args": {"name": name}})

    def begin(self, name: str, tid: int = ENGINE_TID,
              ts: Optional[float] = None, **args) -> None:
        self._open.setdefault(tid, []).append(name)
        ev = {"name": name, "ph": "B", "pid": _PID, "tid": tid,
              "ts": self.now_us() if ts is None else ts}
        if args:
            ev["args"] = args
        self._event(ev)

    def end(self, tid: int = ENGINE_TID, ts: Optional[float] = None,
            **args) -> None:
        stack = self._open.get(tid)
        if not stack:
            raise RuntimeError(f"Tracer.end on tid {tid} with no open span")
        name = stack.pop()
        ev = {"name": name, "ph": "E", "pid": _PID, "tid": tid,
              "ts": self.now_us() if ts is None else ts}
        if args:
            ev["args"] = args
        self._event(ev)

    @contextlib.contextmanager
    def span(self, name: str, tid: int = ENGINE_TID, **args):
        self.begin(name, tid, **args)
        try:
            yield
        finally:
            self.end(tid)

    def span_at(self, name: str, tid: int, t0_us: float, t1_us: float,
                **args) -> None:
        """A span with explicit bounds, emitted after the fact (used for
        per-request decode epochs, whose extent is only known at the
        epoch sync)."""
        self.begin(name, tid, ts=t0_us, **args)
        self.end(tid, ts=max(t1_us, t0_us))

    def instant(self, name: str, tid: int = ENGINE_TID, **args) -> None:
        ev = {"name": name, "ph": "i", "s": "t", "pid": _PID, "tid": tid,
              "ts": self.now_us()}
        if args:
            ev["args"] = args
        self._event(ev)

    def counter(self, name: str, values: Dict[str, float],
                tid: int = ENGINE_TID) -> None:
        self._event({"name": name, "ph": "C", "pid": _PID, "tid": tid,
                     "ts": self.now_us(), "args": dict(values)})

    def annotate(self, name: str):
        """Context wrapping a jitted dispatch in a
        ``jax.profiler.TraceAnnotation`` so device-side profiles carry
        the engine's phase names too."""
        return jax.profiler.TraceAnnotation(name)

    # -- output ------------------------------------------------------------
    def open_spans(self) -> Dict[int, List[str]]:
        """Unclosed spans per tid (should be empty after a drained run)."""
        return {tid: list(s) for tid, s in self._open.items() if s}

    def to_json(self) -> dict:
        return {"traceEvents": list(self.events), "displayTimeUnit": "ms"}

    def save(self, path: Optional[Union[str, pathlib.Path]] = None) -> None:
        out = pathlib.Path(path) if path is not None else self.path
        if out is None:
            raise ValueError("no output path (pass one or build "
                             "Tracer(path=...))")
        out.parent.mkdir(parents=True, exist_ok=True)
        out.write_text(json.dumps(self.to_json()))


class NullTracer(Tracer):
    """The off switch: same API, records nothing, ``annotate`` is a
    no-op context.  The engines hold one of these unless ``trace=`` was
    passed, so tracing calls stay on the hot path unconditionally."""

    enabled = False

    def __init__(self):                                # no event buffer
        self.path = None
        self.events = []
        self._open = {}

    def track(self, tid, name):
        pass

    def begin(self, name, tid=ENGINE_TID, ts=None, **args):
        pass

    def end(self, tid=ENGINE_TID, ts=None, **args):
        pass

    @contextlib.contextmanager
    def span(self, name, tid=ENGINE_TID, **args):
        yield

    def span_at(self, name, tid, t0_us, t1_us, **args):
        pass

    def instant(self, name, tid=ENGINE_TID, **args):
        pass

    def counter(self, name, values, tid=ENGINE_TID):
        pass

    def annotate(self, name):
        return contextlib.nullcontext()

    def now_us(self) -> float:
        return 0.0

    def to_us(self, t: float) -> float:
        return 0.0


def as_tracer(trace) -> Tracer:
    """Normalize the engine's ``trace=`` argument: ``None`` -> NullTracer,
    a Tracer -> itself, a str/Path -> Tracer saving there after runs."""
    if trace is None:
        return NullTracer()
    if isinstance(trace, Tracer):
        return trace
    return Tracer(path=trace)


def jit_cache_size(fns) -> int:
    """Total compiled-variant count across jitted callables (0 for any
    without the private ``_cache_size`` probe).  The engine polls the
    delta per iteration to surface recompiles — e.g. a new power-of-two
    epoch length — as a counter + trace instants."""
    n = 0
    for f in fns:
        probe = getattr(f, "_cache_size", None)
        if probe is not None:
            try:
                n += int(probe())
            except Exception:
                pass
    return n
