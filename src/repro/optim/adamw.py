"""AdamW with fp32 moments over (possibly bf16) parameters.

Pure-functional: state is a pytree mirroring params, shards with the same
``ShardingPolicy.param_specs`` rules (ZeRO-style: moments live on the FSDP
shards).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Tuple, Union

import jax
import jax.numpy as jnp

Params = Any
OptState = Dict[str, Any]


def adamw_init(params: Params, lowmem: bool = False) -> OptState:
    """lowmem=True (the ≥200B MoE archs): bf16 first moment + Adafactor-style
    factored second moment for ≥2-D leaves — params+optimizer for a 480B
    model drop from ~14 B/param to ~4 B/param, which is what makes
    single-pod (256-chip) training of arctic/grok fit HBM at all."""
    if not lowmem:
        f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {
            "m": jax.tree_util.tree_map(f32, params),
            "v": jax.tree_util.tree_map(f32, params),
            "count": jnp.zeros((), jnp.int32),
        }

    def m_init(p):
        return jnp.zeros(p.shape, jnp.bfloat16)

    def v_init(p):
        if p.ndim >= 2:
            return {"row": jnp.zeros(p.shape[:-1], jnp.float32),
                    "col": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
        return jnp.zeros(p.shape, jnp.float32)

    return {
        "m": jax.tree_util.tree_map(m_init, params),
        "v": jax.tree_util.tree_map(v_init, params),
        "count": jnp.zeros((), jnp.int32),
    }


def adamw_update(grads: Params, state: OptState, params: Params,
                 lr: Union[float, jnp.ndarray, Callable],
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1,
                 grad_clip: float = 1.0) -> Tuple[Params, OptState]:
    count = state["count"] + 1
    lr_t = lr(count) if callable(lr) else lr

    # global-norm clip
    if grad_clip:
        gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                          for g in jax.tree_util.tree_leaves(grads)))
        scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gn, 1e-9))
    else:
        gn = jnp.float32(0.0)
        scale = 1.0

    bc1 = 1.0 - b1 ** count.astype(jnp.float32)
    bc2 = 1.0 - b2 ** count.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * g
        if isinstance(v, dict):                  # factored second moment
            g2 = g * g + 1e-30
            row = b2 * v["row"] + (1 - b2) * g2.mean(axis=-1)
            col = b2 * v["col"] + (1 - b2) * g2.mean(axis=-2)
            vhat = (row[..., :, None] * col[..., None, :]
                    / jnp.maximum(row.mean(axis=-1, keepdims=True)[..., None],
                                  1e-30))
            v_new = {"row": row, "col": col}
        else:
            vhat = b2 * v + (1 - b2) * g * g
            v_new = vhat
        step = (m_new / bc1) / (jnp.sqrt(vhat / bc2) + eps)
        if p.ndim >= 2:                      # no decay on norms/biases/scalars
            step = step + weight_decay * p.astype(jnp.float32)
        return (-lr_t * step).astype(p.dtype), m_new.astype(m.dtype), v_new

    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_m = tdef.flatten_up_to(state["m"])
    flat_v = tdef.flatten_up_to(state["v"])
    flat_p = tdef.flatten_up_to(params)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    updates = tdef.unflatten([o[0] for o in out])
    new_state = {
        "m": tdef.unflatten([o[1] for o in out]),
        "v": tdef.unflatten([o[2] for o in out]),
        "count": count,
    }
    return updates, new_state


def apply_updates(params: Params, updates: Params) -> Params:
    return jax.tree_util.tree_map(lambda p, u: p + u, params, updates)
