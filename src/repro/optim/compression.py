"""Error-feedback int8 gradient compression.

Wire format: per-chunk (256 elements) max-abs scales + int8 mantissas — a
3.9× reduction of gradient-reduction bytes on the data axis.  Error
feedback (residual carried to the next step) keeps convergence close to
uncompressed SGD/Adam (Seide et al. 1-bit SGD; Karimireddy et al. EF-SGD).

``compress_decompress`` is the lossy channel (quantize → dequantize) that
the trainer applies to gradients before the optimizer; ``ef_compress``
returns the residual for error feedback.  ``compressed_mean`` is the
shard_map collective form: all-gather int8 + local dequant-mean, moving
1/4 of the bf16 bytes over the wire.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

CHUNK = 256


def _quantize_leaf(g: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    flat = g.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % CHUNK
    if pad:
        flat = jnp.pad(flat, (0, pad))
    ch = flat.reshape(-1, CHUNK)
    scale = jnp.abs(ch).max(axis=1, keepdims=True) / 127.0
    scale = jnp.where(scale == 0, 1.0, scale)
    q = jnp.clip(jnp.round(ch / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize_leaf(q: jnp.ndarray, scale: jnp.ndarray, shape,
                     dtype) -> jnp.ndarray:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape).astype(dtype)


def compress_decompress(grads: Any) -> Any:
    """The lossy int8 channel, leafwise."""
    def one(g):
        if g.size < CHUNK:
            return g
        q, s = _quantize_leaf(g)
        return _dequantize_leaf(q, s, g.shape, g.dtype)

    return jax.tree_util.tree_map(one, grads)


def ef_compress(grads: Any, error: Optional[Any]) -> Tuple[Any, Any]:
    """Error-feedback compression: (decompressed grads, new residual)."""
    if error is None:
        error = jax.tree_util.tree_map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    def one(g, e):
        if g.size < CHUNK:
            return g, e
        corrected = g.astype(jnp.float32) + e
        q, s = _quantize_leaf(corrected)
        deq = _dequantize_leaf(q, s, g.shape, jnp.float32)
        return deq.astype(g.dtype), corrected - deq

    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_e = tdef.flatten_up_to(error)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (tdef.unflatten([o[0] for o in out]),
            tdef.unflatten([o[1] for o in out]))


def compressed_mean(x: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """shard_map collective: int8 all-gather + local dequant-mean.  Moves
    ~1/4 the bytes of a bf16 psum over the mesh axis."""
    q, s = _quantize_leaf(x)
    qs = jax.lax.all_gather(q, axis_name)        # int8 on the wire
    ss = jax.lax.all_gather(s, axis_name)
    n = qs.shape[0]
    deq = (qs.astype(jnp.float32) * ss).sum(axis=0) / n
    flat = deq.reshape(-1)
    sz = 1
    for d in x.shape:
        sz *= d
    return flat[:sz].reshape(x.shape).astype(x.dtype)
