from repro.quant.int4 import dequantize, quantize_params, quantize_rtn  # noqa: F401
