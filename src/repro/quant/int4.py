"""GPTQ-style symmetric INT4 weight quantization (paper §5.1) with
power-of-2 ("BFP-friendly") per-group scales (paper §4.2.2).

Round-to-nearest per group of ``group_size`` input-channel rows.  Power-of-2
scales put the dequantization into a shared-exponent domain so the matmul
kernel can accumulate int8×int4 products in *fixed point* and reconstruct
floating point once per group — the TPU analogue of the paper's BFP
accumulation tree.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, jnp.ndarray]

INT4_MIN, INT4_MAX = -8, 7


def quantize_rtn(w: jnp.ndarray, group_size: int = 128,
                 pow2_scales: bool = True) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """w: [K, N] -> (codes int8 in [-8, 7] of shape [ceil(K/G)·G, N],
    scales fp32 [ceil(K/G), N]).

    When K is not a group multiple the final group is zero-padded: the
    padding rows can never raise a group's amax (masked-amax equivalent —
    |0| <= any real amax) and the zero codes contribute nothing to the
    accumulation, so matmuls just zero-pad the activation's K to match
    (``kernels/ops.int4_matmul`` / ``fused_linear`` do this)."""
    K, N = w.shape
    G = min(group_size, K)
    Kp = -(-K // G) * G
    wf = w.astype(jnp.float32)
    if Kp != K:
        wf = jnp.pad(wf, ((0, Kp - K), (0, 0)))
    wg = wf.reshape(Kp // G, G, N)
    amax = jnp.abs(wg).max(axis=1)                       # [K/G, N]
    scale = amax / INT4_MAX
    if pow2_scales:
        # smallest power of 2 >= scale (exact BFP exponent domain)
        scale = jnp.exp2(jnp.ceil(jnp.log2(jnp.maximum(scale, 1e-12))))
    scale = jnp.where(amax == 0, 1.0, scale)
    codes = jnp.clip(jnp.round(wg / scale[:, None, :]), INT4_MIN, INT4_MAX)
    return codes.reshape(Kp, N).astype(jnp.int8), scale


def dequantize(codes: jnp.ndarray, scale: jnp.ndarray,
               k: int = 0) -> jnp.ndarray:
    """codes: [Kw, N] (possibly group-padded) -> [k or Kw, N] fp32."""
    Kw, N = codes.shape
    G = Kw // scale.shape[0]
    wg = codes.astype(jnp.float32).reshape(Kw // G, G, N) * scale[:, None, :]
    w = wg.reshape(Kw, N)
    return w[:k] if k else w


def quantize_params(params: Params, group_size: int = 128,
                    pow2_scales: bool = True,
                    min_size: int = 1 << 16) -> Params:
    """Replace every 2-D linear weight leaf named ``w`` with
    {w_int, scale} (large matrices only — routers/norms stay fp).

    Weights whose input dim is not a group multiple are group-padded by
    ``quantize_rtn`` (the matmul wrappers zero-pad the activation), so no
    eligible weight is silently skipped."""
    def walk(tree):
        if isinstance(tree, dict):
            out = {}
            for k, v in tree.items():
                if (k == "w" and hasattr(v, "ndim") and v.ndim == 2
                        and v.size >= min_size):
                    codes, scale = quantize_rtn(v, group_size, pow2_scales)
                    out["w_int"] = codes
                    out["scale"] = scale
                else:
                    out[k] = walk(v)
            return out
        return tree

    return walk(params)
