"""GPTQ-style symmetric INT4 weight quantization (paper §5.1) with
power-of-2 ("BFP-friendly") per-group scales (paper §4.2.2).

Round-to-nearest per group of ``group_size`` input-channel rows.  Power-of-2
scales put the dequantization into a shared-exponent domain so the matmul
kernel can accumulate int8×int4 products in *fixed point* and reconstruct
floating point once per group — the TPU analogue of the paper's BFP
accumulation tree.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, jnp.ndarray]

INT4_MIN, INT4_MAX = -8, 7


def quantize_rtn(w: jnp.ndarray, group_size: int = 128,
                 pow2_scales: bool = True) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """w: [K, N] -> (codes int8 in [-8, 7] of shape [K, N],
    scales fp32 [K/G, N])."""
    K, N = w.shape
    G = min(group_size, K)
    assert K % G == 0, (K, G)
    wg = w.astype(jnp.float32).reshape(K // G, G, N)
    amax = jnp.abs(wg).max(axis=1)                       # [K/G, N]
    scale = amax / INT4_MAX
    if pow2_scales:
        # smallest power of 2 >= scale (exact BFP exponent domain)
        scale = jnp.exp2(jnp.ceil(jnp.log2(jnp.maximum(scale, 1e-12))))
    scale = jnp.where(amax == 0, 1.0, scale)
    codes = jnp.clip(jnp.round(wg / scale[:, None, :]), INT4_MIN, INT4_MAX)
    return codes.reshape(K, N).astype(jnp.int8), scale


def dequantize(codes: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    K, N = codes.shape
    G = K // scale.shape[0]
    wg = codes.astype(jnp.float32).reshape(K // G, G, N) * scale[:, None, :]
    return wg.reshape(K, N)


def quantize_params(params: Params, group_size: int = 128,
                    pow2_scales: bool = True,
                    min_size: int = 1 << 16) -> Params:
    """Replace every 2-D linear weight leaf named ``w`` with
    {w_int, scale} (large matrices only — routers/norms stay fp)."""
    def walk(tree):
        if isinstance(tree, dict):
            out = {}
            for k, v in tree.items():
                if (k == "w" and hasattr(v, "ndim") and v.ndim == 2
                        and v.size >= min_size and v.shape[0] % group_size == 0):
                    codes, scale = quantize_rtn(v, group_size, pow2_scales)
                    out["w_int"] = codes
                    out["scale"] = scale
                else:
                    out[k] = walk(v)
            return out
        return tree

    return walk(params)
