from repro.roofline.analysis import (HW, analyze_compiled,  # noqa: F401
                                     collective_bytes_from_hlo,
                                     roofline_terms)
from repro.roofline.linear_bytes import (fusion_report,  # noqa: F401
                                         linear_pipeline_bytes, tp_sweep)
