from repro.roofline.analysis import (HW, analyze_compiled,  # noqa: F401
                                     collective_bytes_from_hlo,
                                     roofline_terms)
