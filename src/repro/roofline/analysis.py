"""Roofline analysis from the compiled dry-run artifact (§Roofline).

Three terms per (arch × shape × mesh), in seconds:

  compute    = HLO_FLOPs_per_device   / peak_FLOP/s_per_chip
  memory     = HLO_bytes_per_device   / HBM_bw_per_chip
  collective = collective_bytes_per_device / ICI_link_bw

Sources: ``compiled.cost_analysis()`` (flops, bytes accessed) and the
post-SPMD HLO text (collective operand/result sizes — cost_analysis does not
cover comm).  All sizes in the partitioned module are per-device.

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI (assignment-specified).
"""
from __future__ import annotations

import json
import re
from typing import Dict, Optional, Tuple

HW = {
    "peak_flops": 197e12,     # bf16 FLOP/s per chip
    "hbm_bw": 819e9,          # B/s per chip
    "ici_bw": 50e9,           # B/s per link
}

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 0.5, "u4": 0.5, "pred": 1, "c64": 8, "c128": 16,
}

# matches e.g. ``bf16[16,4096]`` / ``f32[]``
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(dtype: str, dims: str) -> float:
    if dtype not in _DTYPE_BYTES:
        return 0.0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, float]:
    """Per-device bytes moved by each collective kind.

    Accounting (ring-algorithm equivalents, per device):
      all-reduce      2 × operand bytes (reduce-scatter + all-gather)
      all-gather      result bytes
      reduce-scatter  operand bytes
      all-to-all      operand bytes
      collective-permute  operand bytes
    Async ``*-start`` forms are counted once; ``*-done`` ignored.
    """
    out = {k: 0.0 for k in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        ls = line.lstrip()
        if "fusion" in ls[:60]:
            continue
        m = re.search(
            r"=\s+(.+?)\s+(all-reduce|all-gather|reduce-scatter|all-to-all|"
            r"collective-permute)(?:-start)?\(", ls)
        if not m:
            continue
        if re.search(r"(all-reduce|all-gather|reduce-scatter|all-to-all|"
                     r"collective-permute)-done", ls):
            continue
        result_part, kind = m.group(1), m.group(2)
        # operand shapes: inside the call parens
        call = ls[m.end():]
        operand_bytes = sum(_shape_bytes(d, s)
                            for d, s in _SHAPE_RE.findall(call))
        result_bytes = sum(_shape_bytes(d, s)
                           for d, s in _SHAPE_RE.findall(result_part))
        if kind == "all-reduce":
            b = 2.0 * operand_bytes
        elif kind == "all-gather":
            b = result_bytes
        else:
            b = operand_bytes
        out[kind] += b
        out["count"] += 1
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


def roofline_terms(flops_per_dev: float, bytes_per_dev: float,
                   coll_bytes_per_dev: float) -> Dict[str, float]:
    t_c = flops_per_dev / HW["peak_flops"]
    t_m = bytes_per_dev / HW["hbm_bw"]
    t_x = coll_bytes_per_dev / HW["ici_bw"]
    terms = {"compute_s": t_c, "memory_s": t_m, "collective_s": t_x}
    dom = max(terms, key=terms.get)
    terms["bottleneck"] = dom.replace("_s", "")
    bound = max(t_c, t_m, t_x)
    terms["roofline_fraction_of_bound"] = (
        t_c / bound if bound > 0 else 0.0)   # compute share of the bound
    return terms


def memory_analysis_dict(compiled) -> Dict[str, float]:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    if ma is None:
        return {}
    keys = ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes")
    return {k: float(getattr(ma, k)) for k in keys if hasattr(ma, k)}


def analyze_compiled(compiled, *, chips: int, model_flops: float,
                     shape_kind: str) -> Dict:
    """Full §Roofline record for one compiled cell.

    Primary flops/bytes/collective figures come from the loop-aware static
    HLO analysis (hlo_cost.py) — XLA's cost_analysis counts while-loop
    bodies once, silently dropping the scanned layer stack.  The raw
    cost_analysis numbers are recorded alongside for reference.
    """
    from repro.roofline.hlo_cost import hlo_static_cost

    cost = compiled.cost_analysis() or {}
    text = compiled.as_text()
    static = hlo_static_cost(text)
    flops = float(static["flops"])
    byts = float(static["bytes"])
    coll_total = float(static["collective_total"])
    terms = roofline_terms(flops, byts, coll_total)
    mem = memory_analysis_dict(compiled)
    useful = model_flops / (flops * chips) if flops else 0.0
    return {
        "chips": chips,
        "hlo_flops_per_dev": flops,
        "hlo_bytes_per_dev": byts,
        "collective_bytes_per_dev": coll_total,
        "collective_breakdown": static["collectives"],
        "collective_op_count": static["collective_ops"],
        "unknown_trip_loops": static["unknown_loops"],
        "xla_cost_analysis_flops": float(cost.get("flops", 0.0)),
        "xla_cost_analysis_bytes": float(cost.get("bytes accessed", 0.0)),
        "model_flops_global": model_flops,
        "useful_flops_ratio": useful,
        **terms,
        "memory_analysis": mem,
    }


def model_flops_for(cfg, shape_kind: str, tokens: int) -> float:
    """MODEL_FLOPS = 6·N·D (train) / 2·N·D (inference); N = active params."""
    n_active = cfg.param_count(active_only=True)
    factor = 6.0 if shape_kind == "train" else 2.0
    return factor * n_active * tokens
