"""Loop-aware static cost analysis over post-SPMD HLO text.

XLA's ``compiled.cost_analysis()`` visits every computation ONCE — a
``lax.scan`` body (our layer stack, attention KV chunks, SSD chunks, xent
chunks) is counted a single time regardless of trip count, which silently
underestimates flops/bytes/collectives by up to the layer count.  This
module re-derives the three roofline inputs from the HLO text with while
loops multiplied by their trip counts (XLA annotates
``backend_config={"known_trip_count":{"n":...}}`` on scheduled whiles):

  * flops       — dot ops (2·B·M·N·K from dot_dimension_numbers) + 1/elem
                  for elementwise arithmetic + reduce inputs.  Descends into
                  fusion computations (a fusion executes its body per call).
  * bytes       — operand + result bytes of *materialized* ops only (fusion
                  call-sites, not their internals) ≈ HBM traffic of the
                  fused module.
  * collectives — all-reduce(×2) / all-gather(result) / reduce-scatter(in) /
                  all-to-all(in) / collective-permute(in), per-device bytes.

All shapes in the post-SPMD module are per-device, so every figure here is
per-device.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 0.5, "u4": 0.5, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\](?:\{[^}]*\})?")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CALLED_RE = re.compile(r"(?:to_apply|calls|body|condition)=\{?%?([\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')

_ELEMWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "negate",
    "abs", "exponential", "exponential-minus-one", "log", "log-plus-one",
    "tanh", "rsqrt", "sqrt", "power", "floor", "ceil", "round-nearest-afz",
    "round-nearest-even", "sign", "cosine", "sine", "logistic", "atan2",
    "remainder", "clamp", "select", "compare", "and", "or", "xor", "not",
}
_ZERO_BYTE_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
}
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all")


def _text_elems_bytes(text: str) -> Tuple[float, float]:
    e = b = 0.0
    for d, s in _SHAPE_RE.findall(text):
        n = 1
        if s:
            for x in s.split(","):
                n *= int(x)
        e += n
        b += n * _DTYPE_BYTES.get(d, 0)
    return e, b


@dataclass
class _Op:
    name: str
    kind: str
    result_text: str
    operand_names: List[str]
    attrs: str
    called: List[str] = field(default_factory=list)
    is_root: bool = False


@dataclass
class _Computation:
    name: str
    ops: List[_Op] = field(default_factory=list)


_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_OP_LINE_RE = re.compile(
    r"^\s*(ROOT\s+)?%([\w.\-]+)\s*=\s*"
    r"((?:\([^=]*?\)|[\w\[\],{}]+))\s+"      # result type (maybe tuple)
    r"([\w\-]+)"                              # opcode
    r"\((.*?)\)"                              # operand list
    r"(.*)$",                                 # attrs
    re.DOTALL)


def parse_computations(hlo: str):
    comps: Dict[str, _Computation] = {}
    shapes: Dict[str, str] = {}               # op name -> result type text
    entry_name: Optional[str] = None
    cur: Optional[_Computation] = None
    for raw in hlo.splitlines():
        ls = re.sub(r"/\*.*?\*/", "", raw).strip()
        if not ls or ls.startswith("//") or ls.startswith("HloModule"):
            continue
        if ls.endswith("{") and "->" in ls:
            m = _HEADER_RE.match(ls)
            if m:
                cur = _Computation(m.group(1))
                comps[cur.name] = cur
                if ls.startswith("ENTRY"):
                    entry_name = cur.name
                continue
        if "=" not in ls or cur is None:
            continue
        om = _OP_LINE_RE.match(ls)
        if not om:
            continue
        root, name, result_text, kind, call_text, attrs = om.groups()
        op = _Op(name, kind, result_text,
                 _OPERAND_RE.findall(call_text), attrs,
                 is_root=bool(root))
        op.called = _CALLED_RE.findall(attrs)
        cur.ops.append(op)
        shapes[name] = result_text
    _build_upcast_aliases(comps, shapes)
    return comps, shapes, entry_name


def _dtype_of(text: str) -> Optional[str]:
    m = _SHAPE_RE.search(text)
    return m.group(1) if m else None


def _is_upcast(src_text: str, dst_text: str) -> bool:
    s, d = _dtype_of(src_text), _dtype_of(dst_text)
    return (s in _DTYPE_BYTES and d in _DTYPE_BYTES
            and _DTYPE_BYTES[d] > _DTYPE_BYTES[s])


# name -> source name for values that are pure upcasts (CPU-backend f32
# materializations of bf16 tensors that would not exist on TPU — the MXU
# consumes bf16 directly).  Resolution follows copies/bitcasts and loop-carry
# tuples (tuple → while-body parameter → get-tuple-element chains).
_ALIASES: Dict[str, str] = {}


def _build_upcast_aliases(comps, shapes) -> None:
    _ALIASES.clear()
    ops_by_name: Dict[str, _Op] = {}
    tuple_elems: Dict[Tuple[str, int], str] = {}
    param_owner: Dict[str, str] = {}          # parameter op name -> comp name
    body_init: Dict[str, str] = {}            # body comp name -> init tuple op
    while_body: Dict[str, str] = {}           # while op name -> body comp name
    root_of: Dict[str, str] = {}              # comp name -> root op name

    def comp_is_pure_upcast(comp: _Computation) -> bool:
        real = [o for o in comp.ops
                if o.kind not in ("parameter", "bitcast", "constant",
                                  "copy")]
        return len(real) == 1 and real[0].kind == "convert"

    for comp in comps.values():
        for op in comp.ops:
            ops_by_name[op.name] = op
            if op.is_root:
                root_of[comp.name] = op.name
            if op.kind == "tuple":
                for i, n in enumerate(op.operand_names):
                    tuple_elems[(op.name, i)] = n
            elif op.kind == "parameter":
                param_owner[op.name] = comp.name
            elif op.kind == "while":
                m = re.search(r"body=\{?%?([\w.\-]+)", op.attrs)
                if m and op.operand_names:
                    body_init[m.group(1)] = op.operand_names[0]
                    while_body[op.name] = m.group(1)

    def resolve(name: str, depth: int = 0) -> str:
        if depth > 64 or name not in ops_by_name:
            return name
        op = ops_by_name[name]
        if op.kind in ("copy", "bitcast") and op.operand_names:
            return resolve(op.operand_names[0], depth + 1)
        if op.kind == "convert" and op.operand_names and \
                _is_upcast(shapes.get(op.operand_names[0], ""), op.result_text):
            return resolve(op.operand_names[0], depth + 1)
        if op.kind == "fusion" and len(op.operand_names) == 1 and op.called \
                and all(c in comps and comp_is_pure_upcast(comps[c])
                        for c in op.called):
            return resolve(op.operand_names[0], depth + 1)
        if op.kind == "get-tuple-element" and op.operand_names:
            m = re.search(r"index=(\d+)", op.attrs)
            if m:
                idx = int(m.group(1))
                src = op.operand_names[0]
                if (src, idx) in tuple_elems:
                    return resolve(tuple_elems[(src, idx)], depth + 1)
                if src in param_owner:         # loop-carry parameter
                    init = body_init.get(param_owner[src])
                    if init and (init, idx) in tuple_elems:
                        return resolve(tuple_elems[(init, idx)], depth + 1)
                if src in while_body:          # GTE of while result
                    rt = root_of.get(while_body[src])
                    if rt and (rt, idx) in tuple_elems:
                        return resolve(tuple_elems[(rt, idx)], depth + 1)
        return name

    for name, op in ops_by_name.items():
        if op.kind in ("convert", "fusion", "get-tuple-element", "copy",
                       "bitcast"):
            r = resolve(name)
            if r != name and r in shapes and \
                    _is_upcast(shapes[r], shapes.get(name, "")):
                _ALIASES[name] = r


def resolved_shape_text(name: str, shapes: Dict[str, str]) -> str:
    return shapes.get(_ALIASES.get(name, name), shapes.get(name, ""))


def resolved_bytes(name: str, shapes: Dict[str, str]) -> float:
    return _text_elems_bytes(resolved_shape_text(name, shapes))[1]


def _is_upcast_op(op: _Op) -> bool:
    """True when the op itself is a pure upcast (counts zero bytes — it
    would not exist on TPU)."""
    return op.name in _ALIASES and op.kind in ("convert", "fusion")


def _dims(attrs: str, key: str) -> List[int]:
    m = re.search(key + r"=\{([\d,]*)\}", attrs)
    return [int(x) for x in m.group(1).split(",")] if m and m.group(1) else []


def _dot_flops(op: _Op, shapes: Dict[str, str]) -> float:
    if len(op.operand_names) < 2:
        return 0.0
    st = [shapes.get(n, "") for n in op.operand_names[:2]]
    mm = [_SHAPE_RE.search(s) for s in st]
    if not all(mm):
        return 0.0
    lhs = [int(x) for x in mm[0].group(2).split(",")] if mm[0].group(2) else []
    rhs = [int(x) for x in mm[1].group(2).split(",")] if mm[1].group(2) else []
    lc, lb = _dims(op.attrs, "lhs_contracting_dims"), _dims(op.attrs, "lhs_batch_dims")
    rc, rb = _dims(op.attrs, "rhs_contracting_dims"), _dims(op.attrs, "rhs_batch_dims")
    k = 1
    for d in lc:
        k *= lhs[d]
    b = 1
    for d in lb:
        b *= lhs[d]
    m_ = 1
    for i, d in enumerate(lhs):
        if i not in lc and i not in lb:
            m_ *= d
    n_ = 1
    for i, d in enumerate(rhs):
        if i not in rc and i not in rb:
            n_ *= d
    return 2.0 * b * m_ * n_ * k


def _operand_bytes(op: _Op, shapes: Dict[str, str]) -> float:
    return sum(resolved_bytes(n, shapes) for n in op.operand_names)


def _elems_of(text: str) -> float:
    return _text_elems_bytes(text)[0]


def _slice_bytes(op: _Op, shapes: Dict[str, str]) -> float:
    """Bytes of a dynamic-slice result, at the *resolved* dtype of the
    sliced operand (normalizes CPU f32-upcasted buffers back to bf16)."""
    elems = _elems_of(op.result_text)
    if op.operand_names:
        src = shapes.get(_ALIASES.get(op.operand_names[0],
                                      op.operand_names[0]), "")
        d = _dtype_of(src)
        if d in _DTYPE_BYTES:
            return elems * _DTYPE_BYTES[d]
    return _text_elems_bytes(op.result_text)[1]


def _fusion_bytes(comp: _Computation, shapes: Dict[str, str],
                  call_op: Optional[_Op] = None) -> float:
    """HBM traffic of one fusion execution.

    Reads: every fusion parameter used by a non-slicing interior op counts
    at full (alias-resolved) size; parameters consumed *only* as the sliced
    operand of dynamic-(update-)slice count at slice size (XLA aliases the
    buffer in place).  Writes: root result, except in-place DUS roots which
    write the update window only.  Pure-upcast chains count as bf16.
    """
    local = {op.name: op for op in comp.ops}
    params = {op.name for op in comp.ops if op.kind == "parameter"}

    def to_param(n: str, depth: int = 0) -> Optional[str]:
        """Resolve an interior value to the parameter it is a pure
        convert/bitcast/copy chain of (alias-transparent uses)."""
        if depth > 16:
            return None
        if n in params:
            return n
        op = local.get(n)
        if op is None or not op.operand_names:
            return None
        if op.kind in ("bitcast", "copy"):
            return to_param(op.operand_names[0], depth + 1)
        if op.kind == "convert":
            return to_param(op.operand_names[0], depth + 1)
        return None

    sliced_only = {}
    for op in comp.ops:
        if op.kind in ("bitcast", "copy", "convert"):
            continue                                # transparent links
        for i, n in enumerate(op.operand_names):
            p = to_param(n)
            if p is None:
                continue
            is_slice_use = (op.kind in ("dynamic-slice",
                                        "dynamic-update-slice") and i == 0)
            prev = sliced_only.get(p, True)
            sliced_only[p] = prev and is_slice_use

    def param_bytes(pname: str) -> float:
        # map the fusion parameter to the (alias-resolved) call-site operand
        if call_op is not None:
            m = re.match(r"param_(\d+)", pname)
            if m:
                i = int(m.group(1))
                if i < len(call_op.operand_names):
                    return resolved_bytes(call_op.operand_names[i], shapes)
        return _text_elems_bytes(shapes.get(pname, ""))[1]

    def value_bytes(n: str) -> float:
        op = local.get(n)
        if op is not None and op.kind == "parameter":
            return param_bytes(n)
        return resolved_bytes(n, shapes)

    reads = 0.0
    for n in params:
        if sliced_only.get(n) is False:
            reads += param_bytes(n)
        # unused params (not in sliced_only) cost nothing
    def effective(n: str, depth: int = 0) -> Optional[_Op]:
        """Chase convert/bitcast/copy chains to the producing op."""
        op = local.get(n)
        if op is None or depth > 16:
            return op
        if op.kind in ("convert", "bitcast", "copy") and op.operand_names:
            return effective(op.operand_names[0], depth + 1) or op
        return op

    def write_bytes_of(n: str) -> float:
        src = effective(n)
        if src is not None and src.kind == "dynamic-update-slice" \
                and len(src.operand_names) > 1:
            return value_bytes(src.operand_names[1])
        return value_bytes(n)

    writes = 0.0
    for op in comp.ops:
        if op.kind == "dynamic-slice":
            reads += _slice_bytes(op, shapes)
        elif op.kind == "dynamic-update-slice":
            if len(op.operand_names) > 1:
                reads += value_bytes(op.operand_names[1])
        if op.is_root:
            if op.kind == "tuple":
                for n in op.operand_names:
                    writes += write_bytes_of(n)
            else:
                writes += write_bytes_of(op.name)
    return reads + writes


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, float] = field(default_factory=dict)
    coll_ops: int = 0
    unknown_loops: int = 0

    def scaled(self, f: float) -> "Cost":
        return Cost(self.flops * f, self.bytes * f,
                    {k: v * f for k, v in self.coll.items()},
                    self.coll_ops, self.unknown_loops)

    def add(self, o: "Cost"):
        self.flops += o.flops
        self.bytes += o.bytes
        for k, v in o.coll.items():
            self.coll[k] = self.coll.get(k, 0.0) + v
        self.coll_ops += o.coll_ops
        self.unknown_loops += o.unknown_loops


def _trip_count_from_cond(cond: _Computation) -> Optional[int]:
    consts = []
    for op in cond.ops:
        if op.kind == "constant":
            m = re.search(r"constant\((-?\d+)\)",
                          "(" + ",".join(op.operand_names) + ")" + op.attrs)
            if m:
                consts.append(int(m.group(1)))
    # raw text fallback
    if not consts:
        return None
    n = max(consts)
    return n if 0 < n < 10_000_000 else None


def _comp_cost(comp: _Computation, comps, shapes, inside_fusion: bool,
               memo) -> Cost:
    key = (comp.name, inside_fusion)
    if key in memo:
        return memo[key]
    total = Cost()
    memo[key] = total
    for op in comp.ops:
        kind = op.kind
        base = kind[:-6] if kind.endswith("-start") else kind
        if base in _COLLECTIVES:
            ob = _operand_bytes(op, shapes)
            re_, rb = _text_elems_bytes(op.result_text)
            if base == "all-reduce":
                cb = 2.0 * ob
            elif base == "all-gather":
                # result at the resolved operand dtype (CPU upcasts bf16
                # operands to f32 — the TPU wire format stays bf16)
                d = _dtype_of(resolved_shape_text(op.operand_names[0], shapes)
                              if op.operand_names else op.result_text)
                cb = re_ * _DTYPE_BYTES.get(d, 4)
            else:
                cb = ob
            total.coll[base] = total.coll.get(base, 0.0) + cb
            total.coll_ops += 1
            total.bytes += ob + rb
            continue
        if kind.endswith("-done") or kind.endswith("-update-done"):
            continue
        if kind == "while":
            body = cond = None
            m = re.search(r"body=\{?%?([\w.\-]+)", op.attrs)
            if m:
                body = comps.get(m.group(1))
            m = re.search(r"condition=\{?%?([\w.\-]+)", op.attrs)
            if m:
                cond = comps.get(m.group(1))
            m = _TRIP_RE.search(op.attrs)
            trips = int(m.group(1)) if m else (
                _trip_count_from_cond(cond) if cond else None)
            if trips is None:
                trips = 1
                total.unknown_loops += 1
            if body is not None:
                total.add(_comp_cost(body, comps, shapes, False,
                                     memo).scaled(trips))
            continue
        if kind in ("call", "conditional", "async-start"):
            for cname in op.called:
                if cname in comps:
                    total.add(_comp_cost(comps[cname], comps, shapes, False,
                                         memo))
            continue
        if kind == "fusion":
            if _is_upcast_op(op):
                continue                          # CPU-only upcast
            fb = 0.0
            for cname in op.called:
                if cname in comps:
                    sub = _comp_cost(comps[cname], comps, shapes, True, memo)
                    total.flops += sub.flops
                    for ck, cv in sub.coll.items():
                        total.coll[ck] = total.coll.get(ck, 0.0) + cv
                    fb += _fusion_bytes(comps[cname], shapes, op)
            total.bytes += fb if fb else (
                _operand_bytes(op, shapes)
                + _text_elems_bytes(op.result_text)[1])
            continue
        if kind == "dot":
            total.flops += _dot_flops(op, shapes)
        elif kind in _ELEMWISE:
            total.flops += _text_elems_bytes(op.result_text)[0]
        elif kind in ("reduce", "reduce-window"):
            total.flops += sum(_text_elems_bytes(shapes.get(n, ""))[0]
                               for n in op.operand_names)
        if not inside_fusion and kind not in _ZERO_BYTE_OPS:
            if _is_upcast_op(op) or (kind == "copy" and op.name in _ALIASES):
                continue                          # CPU-only upcast artifacts
            rb = resolved_bytes(op.name, shapes)
            if kind in ("dynamic-slice", "slice", "gather"):
                # reads only the slice; XLA aliases where possible
                total.bytes += 2.0 * _slice_bytes(op, shapes)
            elif kind == "dynamic-update-slice":
                # in-place: read update + write region (buffer is aliased)
                ub = (resolved_bytes(op.operand_names[1], shapes)
                      if len(op.operand_names) > 1 else rb)
                total.bytes += 2.0 * ub
            elif kind == "scatter":
                ub = (resolved_bytes(op.operand_names[-1], shapes)
                      if op.operand_names else rb)
                total.bytes += 2.0 * ub
            else:
                total.bytes += _operand_bytes(op, shapes) + rb
    memo[key] = total
    return total


def hlo_static_cost(hlo_text: str) -> Dict[str, object]:
    comps, shapes, entry_name = parse_computations(hlo_text)
    entry = comps.get(entry_name) if entry_name else None
    if entry is None:
        called = {n for c in comps.values() for op in c.ops for n in op.called}
        rest = [c for n, c in comps.items() if n not in called]
        entry = max(rest, key=lambda c: len(c.ops)) if rest else None
    if entry is None:
        return {"flops": 0.0, "bytes": 0.0, "collectives": {},
                "collective_total": 0.0, "collective_ops": 0,
                "unknown_loops": 0}
    cost = _comp_cost(entry, comps, shapes, False, {})
    return {
        "flops": cost.flops,
        "bytes": cost.bytes,
        "collectives": dict(cost.coll),
        "collective_total": sum(cost.coll.values()),
        "collective_ops": cost.coll_ops,
        "unknown_loops": cost.unknown_loops,
    }
