"""HBM byte accounting for the routed block's *linear pipeline*
(paper Alg. 1 / §4.2), in the style of ``kvcache/layout.py``'s
transaction model: an explicit per-op tally of what the dispatch
strategy makes the memory system move, so the fusion win is measured
rather than asserted.

Two dispatch strategies over identical weights:

  * **unfused** — the composed op-by-op path: the norm reduction pass
    reads x; ``norm_apply`` reads x and writes the normalized activation;
    each of q/k/v (and gate/up) re-reads it; the GLU combine round-trips
    both halves; the submodule output y round-trips before the residual
    add re-reads x and writes the new stream; the next block's reduction
    reads it again.
  * **fused** — the ``kernels/fused_linear.py`` pipeline: one widened
    qkv (and [gate|up]) matmul reads x once with the norm's elementwise
    phase in its k-loop; the GLU epilogue keeps both halves in VMEM; the
    o/down projection folds gate · y + x in its epilogue and emits Σy²,
    so the next block's reduction pass disappears.

Weight traffic is identical under both strategies (every weight is read
exactly once per step) and reported separately: the fusion's win is the
eliminated *activation* round-trips, which is what the ≥20 % acceptance
gate is asserted on; total bytes (weights included) must still be
strictly below the unfused dispatch.
"""
from __future__ import annotations

from typing import Dict

from repro.configs.base import MAMBA, ModelConfig

STAT_BYTES = 4          # fp32 reduction carry / Σy² emission

# Producer-side writes of model-sharded tensors under tensor-parallel
# serving: the serve-mode ShardingPolicy column-splits every linear, so a
# chip writes only its 1/TP output slice.  Reads are NOT divided — the
# serve layout all-gathers the sharded activations before the (column-
# split) wo/down projections, so each chip reads the *full* o and h
# (the price of the psum-free, bit-identical layout; the collective's
# own wire bytes are out of scope for this HBM model).  Everything
# touching the [M, D] residual stream is replicated either way.
_TP_SHARDED_OPS = frozenset({
    "qkv_write",                              # attention inner (AI | KI)
    "g_u_write",                              # widened [gate|up] halves
    "h_write",                                # FFN hidden (F)
})


def _weight_bytes(cfg: ModelConfig, k: int, n: int) -> float:
    """One [k, n] linear's HBM weight bytes (int4 codes at 4 bit + fp32
    per-group scales when the quant path is on, else activation dtype)."""
    if cfg.quant.enabled:
        groups = -(-k // cfg.quant.group_size)
        return k * n * 0.5 + groups * n * STAT_BYTES
    return k * n * 2.0


def linear_pipeline_bytes(cfg: ModelConfig, batch: int, *,
                          fused: bool, tp: int = 1) -> Dict[str, float]:
    """Modeled HBM bytes for ONE decode step's linear pipeline.

    batch: decode rows (M).  Attention-core and KV-cache traffic is out of
    scope (identical under both strategies — see kvcache/layout.py for
    that model); Mamba mixers are skipped (their in/out projections are
    not routed through the fused pipeline yet).

    ``tp`` > 1 gives the *per-device* view under the serve-mode
    ``ShardingPolicy``: every linear weight is sharded 1/TP (column
    splits), a chip writes only its slice of the model-sharded
    intermediates, while reads of all-gathered activations and the
    replicated [M, D] residual-stream traffic are unchanged — so per-chip
    bytes approach weight_bytes/TP + full activations as TP grows (the
    sharded-serving bandwidth win the bench records: decode is
    weight-dominated, so totals still fall ~1/TP)."""
    M = batch
    D = cfg.d_model
    AI, KI, F = cfg.attn_inner_dim, cfg.kv_inner_dim, cfg.d_ff
    a = 2.0                                   # activation bytes (bf16)
    glu = cfg.mlp_act in ("swiglu", "geglu")

    ops: Dict[str, float] = {}

    def add(name: str, elems: float, bytes_per: float = a):
        ops[name] = ops.get(name, 0.0) + elems * bytes_per

    weight = 0.0
    for layer in range(cfg.num_layers):
        kind = cfg.block_kind(layer)
        if kind == MAMBA:
            continue
        moe = cfg.is_moe_layer(layer)
        # ---- attention block --------------------------------------------
        weight += _weight_bytes(cfg, D, AI + 2 * KI)      # wqkv
        weight += _weight_bytes(cfg, AI, D)               # wo
        add("router_read_x", M * D)                       # logits (+stats)
        if fused:
            add("qkv_read_x", M * D)                      # norm in k-loop
            add("qkv_write", M * (AI + 2 * KI))
            add("oproj_read_o", M * AI)
            add("oproj_read_residual", M * D)
            add("oproj_write_x", M * D)
            add("sq_emit", M, STAT_BYTES)
        else:
            add("norm_read_x", M * D)
            add("norm_write_xn", M * D)
            add("qkv_read_xn", 3 * M * D)                 # separate q/k/v
            add("qkv_write", M * (AI + 2 * KI))
            add("oproj_read_o", M * AI)
            add("oproj_write_y", M * D)
            add("residual_read_y", M * D)
            add("residual_read_x", M * D)
            add("residual_write_x", M * D)

        # ---- FFN block --------------------------------------------------
        if not cfg.d_ff or moe:
            # MoE keeps its scatter dispatch under both strategies; its
            # identical traffic cancels out of the comparison.
            continue
        nw = 2 * F if glu else F
        weight += _weight_bytes(cfg, D, nw)               # [gate|up] / up
        weight += _weight_bytes(cfg, F, D)                # down
        add("router_read_x", M * D)
        if fused:
            add("gu_read_x", M * D)
            add("h_write", M * F)                         # GLU in epilogue
            add("down_read_h", M * F)
            add("down_read_residual", M * D)
            add("down_write_x", M * D)
            add("sq_emit", M, STAT_BYTES)
        else:
            add("norm_read_x", M * D)
            add("norm_write_xn", M * D)
            # one read: the unfused dispatch also uses the merged [gate|up]
            # weight (a single matmul) — the legacy split-weight dispatch
            # would charge 2 reads here
            add("gu_read_xn", M * D)
            if glu:
                add("g_u_write", 2 * M * F)
                add("glu_read_g_u", 2 * M * F)
            else:
                add("g_u_write", M * F)
                add("glu_read_g_u", M * F)
            add("h_write", M * F)
            add("down_read_h", M * F)
            add("down_write_y", M * D)
            add("residual_read_y", M * D)
            add("residual_read_x", M * D)
            add("residual_write_x", M * D)

    if tp > 1:
        ops = {name: (b / tp if name in _TP_SHARDED_OPS else b)
               for name, b in ops.items()}
        weight /= tp
    act = sum(ops.values())
    return {
        "batch": M,
        "fused": fused,
        "tp": tp,
        "weight_bytes": weight,
        "activation_bytes": act,
        "total_bytes": weight + act,
        "breakdown": ops,
    }


def fusion_report(cfg: ModelConfig, batch: int,
                  tp: int = 1) -> Dict[str, object]:
    """Side-by-side fused/unfused accounting + the drop fractions the
    bench records and CI asserts on (``tp`` > 1: the per-device view)."""
    un = linear_pipeline_bytes(cfg, batch, fused=False, tp=tp)
    fu = linear_pipeline_bytes(cfg, batch, fused=True, tp=tp)
    act_drop = 1.0 - fu["activation_bytes"] / max(un["activation_bytes"], 1.0)
    tot_drop = 1.0 - fu["total_bytes"] / max(un["total_bytes"], 1.0)
    return {
        "unfused": un,
        "fused": fu,
        "activation_bytes_drop_frac": act_drop,
        "total_bytes_drop_frac": tot_drop,
    }


def tp_sweep(cfg: ModelConfig, batch: int,
             tps=(1, 2, 4, 8, 16)) -> Dict[str, object]:
    """Per-device HBM bytes of the fused decode-step pipeline across
    tensor-parallel degrees.  Weight traffic falls exactly 1/TP (every
    linear is sharded); totals fall ~1/TP while weights dominate decode.
    The bench records this as the sharded-serving trajectory and CI gates
    per-chip totals against the committed baseline."""
    base = linear_pipeline_bytes(cfg, batch, fused=True, tp=1)
    out = {"batch": batch, "tps": list(tps), "per_chip": {}}
    for tp in tps:
        r = linear_pipeline_bytes(cfg, batch, fused=True, tp=tp)
        out["per_chip"][str(tp)] = {
            "weight_bytes": r["weight_bytes"],
            "activation_bytes": r["activation_bytes"],
            "total_bytes": r["total_bytes"],
            "total_vs_tp1": r["total_bytes"] / max(base["total_bytes"], 1.0),
        }
    return out
