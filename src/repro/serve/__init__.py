from repro.serve.engine import (ContinuousBatchingEngine,  # noqa: F401
                                RequestHandle, RequestResult, ServeEngine,
                                ServeStats)
from repro.serve.scheduler import (PrefillChunk, Request,  # noqa: F401
                                   Scheduler, StepPlan, can_chunk_prefill)

# grouped engine configuration (the redesigned constructor surface;
# docs/serving.md)
from repro.serve.config import (EngineConfig, KVConfig,  # noqa: F401
                                ObsConfig, RobustnessConfig,
                                SchedulingConfig, SpecConfig)

# paged-KV engine mode building blocks (kv_mode="paged")
from repro.kvcache.history import HistoryAccounting  # noqa: F401
from repro.kvcache.paged import (KV_DTYPES, PageAllocator,  # noqa: F401
                                 can_page)
from repro.kvcache.prefix import PrefixCache, PrefixRecord  # noqa: F401

# robustness layer: typed errors, fault injection, crash-consistent
# snapshots (docs/robustness.md)
from repro.serve.errors import (AdmissionRejected,  # noqa: F401
                                ConfigError, DeadlineExceeded,
                                EngineAborted, HungDispatch, PageExhausted,
                                ServeError, SimulatedKill)
from repro.serve.faults import (Fault, FaultInjected,  # noqa: F401
                                FaultPlan, Watchdog)
from repro.serve.snapshot import (latest_snapshot_step,  # noqa: F401
                                  list_snapshot_steps, load_snapshot,
                                  save_snapshot)
