from repro.serve.engine import (ContinuousBatchingEngine,  # noqa: F401
                                RequestResult, ServeEngine, ServeStats)
from repro.serve.scheduler import Request, Scheduler  # noqa: F401

# paged-KV engine mode building blocks (kv_mode="paged")
from repro.kvcache.history import HistoryAccounting  # noqa: F401
from repro.kvcache.paged import PageAllocator, can_page  # noqa: F401
