from repro.serve.engine import ServeEngine, ServeStats  # noqa: F401
