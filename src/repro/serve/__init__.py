from repro.serve.engine import (ContinuousBatchingEngine,  # noqa: F401
                                RequestResult, ServeEngine, ServeStats)
from repro.serve.scheduler import (PrefillChunk, Request,  # noqa: F401
                                   Scheduler, StepPlan, can_chunk_prefill)

# paged-KV engine mode building blocks (kv_mode="paged")
from repro.kvcache.history import HistoryAccounting  # noqa: F401
from repro.kvcache.paged import PageAllocator, can_page  # noqa: F401
