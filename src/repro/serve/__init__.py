from repro.serve.engine import (ContinuousBatchingEngine,  # noqa: F401
                                RequestResult, ServeEngine, ServeStats)
from repro.serve.scheduler import Request, Scheduler  # noqa: F401
