"""Frozen, grouped engine configuration (docs/serving.md).

``ContinuousBatchingEngine`` grew one keyword argument per PR until its
constructor carried ~22 flat kwargs spanning five unrelated concerns.
This module is the redesigned surface: five small frozen dataclasses —
KV layout, scheduling shape, speculation, robustness, observability —
composed into one :class:`EngineConfig`, constructed as

    engine = ContinuousBatchingEngine(cfg, params, config=EngineConfig(
        kv=KVConfig(kv_mode="paged", kv_dtype="int8", prefix_cache=True),
        scheduling=SchedulingConfig(max_slots=8, max_len=1024),
    ))

Every cfg-independent validity rule lives in ``__post_init__`` here and
raises a typed :class:`~repro.serve.errors.ConfigError` (is-a
``ValueError``) *before* any device work; rules that need the
``ModelConfig`` (pageability, chunkability, bucketing, speculation
support) stay in the engine, where the model config is in scope.

The old flat kwargs still work — the engine maps them through
:meth:`EngineConfig.from_kwargs` and emits one ``DeprecationWarning``
per process.  Semantics are identical; see docs/serving.md for the
migration table.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

from repro.kvcache.paged import KV_DTYPES
from repro.serve.errors import ConfigError


@dataclasses.dataclass(frozen=True)
class KVConfig:
    """KV-cache layout: dense slot pool vs. paged entry stream, page
    payload precision, and prefix sharing.

    ``kv_dtype`` (None | "int8" | "int4") quantizes page payloads with
    per-(entry, head) power-of-two scales; ``prefix_cache`` turns on the
    refcounted prompt-prefix registry (``kvcache/prefix.py``) with
    records published every ``prefix_block`` tokens.  Both are
    paged-only levers."""
    kv_mode: str = "dense"
    page_size: int = 16
    num_pages: Optional[int] = None
    kv_dtype: Optional[str] = None
    prefix_cache: bool = False
    prefix_block: int = 16
    prefix_max_records: int = 256


@dataclasses.dataclass(frozen=True)
class SchedulingConfig:
    """Batch shape and dispatch cadence.  ``None`` for ``prefill_chunk``
    / ``decode_steps`` defers to the ModelConfig's serving defaults
    (``cfg.prefill_chunk`` / ``cfg.decode_steps_per_dispatch``)."""
    max_slots: int = 4
    max_len: int = 512
    prefill_buckets: Optional[Tuple[int, ...]] = None
    prefill_chunk: Optional[int] = None
    decode_steps: Optional[int] = None
    step_tokens: Optional[int] = None


@dataclasses.dataclass(frozen=True)
class SpecConfig:
    """Self-speculative decoding (docs/speculative.md)."""
    spec_k: int = 0
    draft_keep: Optional[float] = None


@dataclasses.dataclass(frozen=True)
class RobustnessConfig:
    """Fault injection, watchdog, snapshots and load shedding
    (docs/robustness.md)."""
    faults: Any = None
    watchdog: Any = None
    snapshot_dir: Optional[str] = None
    snapshot_every: int = 1
    max_queue_depth: Optional[int] = None
    max_queue_delay_s: Optional[float] = None
    max_preemptions: Optional[int] = None


@dataclasses.dataclass(frozen=True)
class ObsConfig:
    """Tracing and distributed placement (docs/observability.md,
    docs/distributed.md).  ``trace`` accepts a bool, a Tracer, or an
    output path, exactly like the old ``trace=`` kwarg."""
    trace: Any = None
    mesh: Any = None
    sharding_policy: Any = None


# legacy flat kwarg -> (EngineConfig group field, group attribute)
_LEGACY_MAP = {
    "max_slots": ("scheduling", "max_slots"),
    "max_len": ("scheduling", "max_len"),
    "prefill_buckets": ("scheduling", "prefill_buckets"),
    "prefill_chunk": ("scheduling", "prefill_chunk"),
    "decode_steps": ("scheduling", "decode_steps"),
    "step_tokens": ("scheduling", "step_tokens"),
    "kv_mode": ("kv", "kv_mode"),
    "page_size": ("kv", "page_size"),
    "num_pages": ("kv", "num_pages"),
    "kv_dtype": ("kv", "kv_dtype"),
    "prefix_cache": ("kv", "prefix_cache"),
    "prefix_block": ("kv", "prefix_block"),
    "spec_k": ("spec", "spec_k"),
    "draft_keep": ("spec", "draft_keep"),
    "faults": ("robustness", "faults"),
    "watchdog": ("robustness", "watchdog"),
    "snapshot_dir": ("robustness", "snapshot_dir"),
    "snapshot_every": ("robustness", "snapshot_every"),
    "max_queue_depth": ("robustness", "max_queue_depth"),
    "max_queue_delay_s": ("robustness", "max_queue_delay_s"),
    "max_preemptions": ("robustness", "max_preemptions"),
    "trace": ("obs", "trace"),
    "mesh": ("obs", "mesh"),
    "sharding_policy": ("obs", "sharding_policy"),
    "temperature": (None, "temperature"),
}


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Complete ``ContinuousBatchingEngine`` configuration."""
    kv: KVConfig = dataclasses.field(default_factory=KVConfig)
    scheduling: SchedulingConfig = dataclasses.field(
        default_factory=SchedulingConfig)
    spec: SpecConfig = dataclasses.field(default_factory=SpecConfig)
    robustness: RobustnessConfig = dataclasses.field(
        default_factory=RobustnessConfig)
    obs: ObsConfig = dataclasses.field(default_factory=ObsConfig)
    temperature: float = 0.0

    def __post_init__(self):
        kv, sched, spec = self.kv, self.scheduling, self.spec
        if kv.kv_mode not in ("dense", "paged"):
            raise ConfigError(f"unknown kv_mode {kv.kv_mode!r}")
        if kv.page_size < 1 or (kv.num_pages is not None
                                and kv.num_pages < 1):
            raise ConfigError("num_pages and page_size must be >= 1")
        if kv.kv_dtype not in KV_DTYPES:
            raise ConfigError(f"kv_dtype must be one of {KV_DTYPES}, "
                              f"got {kv.kv_dtype!r}")
        if kv.kv_mode != "paged":
            if kv.kv_dtype is not None:
                raise ConfigError("kv_dtype quantizes page payloads — a "
                                  "paged-KV lever; set kv_mode='paged' or "
                                  "leave it None")
            if kv.prefix_cache:
                raise ConfigError("prefix_cache shares page chains across "
                                  "slots — a paged-KV lever; set "
                                  "kv_mode='paged'")
        if kv.prefix_block < 1:
            raise ConfigError("prefix_block must be >= 1 token")
        if kv.prefix_max_records < 1:
            raise ConfigError("prefix_max_records must be >= 1")
        if sched.max_slots < 1 or sched.max_len < 1:
            raise ConfigError("max_slots and max_len must be >= 1")
        if sched.prefill_chunk is not None and sched.prefill_chunk < 0:
            raise ConfigError("prefill_chunk must be >= 0 (0 = monolithic)")
        if sched.decode_steps is not None and sched.decode_steps < 1:
            raise ConfigError("decode_steps must be >= 1 (1 = single-step)")
        if sched.step_tokens is not None and sched.step_tokens < 1:
            raise ConfigError("step_tokens must be >= 1")
        if spec.spec_k < 0:
            raise ConfigError("spec_k must be >= 0 (0 = off)")
        if spec.spec_k and (sched.decode_steps or 1) > 1:
            raise ConfigError(
                "spec_k and decode_steps > 1 are mutually exclusive — "
                "both amortize host overhead over multi-token "
                "dispatches; pick one")
        if spec.draft_keep is not None and not 0.0 < spec.draft_keep <= 1.0:
            raise ConfigError("draft_keep must be in (0, 1]")

    @classmethod
    def from_kwargs(cls, **kwargs) -> "EngineConfig":
        """Build from the legacy flat kwargs of the pre-redesign
        constructor (the deprecation shim's mapping; also handy for CLI
        front-ends holding a flat namespace).  Unknown names raise
        ``TypeError``, like any bad keyword argument."""
        groups = {"kv": {}, "scheduling": {}, "spec": {},
                  "robustness": {}, "obs": {}}
        top = {}
        for name, value in kwargs.items():
            if name not in _LEGACY_MAP:
                raise TypeError(
                    f"ContinuousBatchingEngine got an unexpected keyword "
                    f"argument {name!r}")
            group, attr = _LEGACY_MAP[name]
            if name == "prefill_buckets" and value is not None:
                value = tuple(int(b) for b in value)
            if group is None:
                top[attr] = value
            else:
                groups[group][attr] = value
        return cls(kv=KVConfig(**groups["kv"]),
                   scheduling=SchedulingConfig(**groups["scheduling"]),
                   spec=SpecConfig(**groups["spec"]),
                   robustness=RobustnessConfig(**groups["robustness"]),
                   obs=ObsConfig(**groups["obs"]), **top)
