"""Serving engines: the paper's end-to-end inference pipeline.

prefill (gather/compacted execution) → autoregressive decode with dynamic
routing and cross-layer KV reuse, with KV-storage accounting *measured*
from the per-step execution-gate log (``stats['attn_gate']``) instead of
the analytic keep-rate estimate.

Two engines share the jitted ``model.decode_step`` path:

``ServeEngine``
    Lock-step batch: one fixed batch, every sequence at the same position.
    Kept as the baseline the continuous engine is benchmarked against.

``ContinuousBatchingEngine``
    Slot-based continuous batching (the serving pattern SkipOPU's
    dynamically allocated compute pays off in): a fixed ``max_slots ×
    max_len`` KV pool allocated once, a FIFO request queue with prefill
    length-bucketing, per-sequence decode positions (``t: [B]``), and
    admission/eviction as requests start/stop — see
    ``repro/serve/scheduler.py`` and docs/serving.md.
"""
from __future__ import annotations

import dataclasses
import warnings
from functools import partial
from time import perf_counter
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import LOCAL, ModelConfig
from repro.core import kv_reuse
from repro.core.routing import draft_router_bias
from repro.distributed.sharding import ShardingPolicy, set_policy
from repro.kvcache import history as history_mod
from repro.kvcache import paged as paged_mod
from repro.models import model as model_lib
from repro.obs import (MetricsRegistry, as_tracer, jit_cache_size,
                       request_tid)
from repro.kvcache import prefix as prefix_mod
from repro.serve import snapshot as snapshot_mod
from repro.serve.config import EngineConfig
from repro.serve.errors import (AdmissionRejected, ConfigError, HungDispatch,
                                PageExhausted, SimulatedKill)
from repro.serve.faults import (FaultInjected, Watchdog, as_fault_plan,
                                sleep_stall)
from repro.serve import sampling as sampling_mod
from repro.serve.sampling import sample
from repro.serve.scheduler import (ActiveRequest, PrefillChunk, Request,
                                   Scheduler, can_bucket,
                                   can_chunk_prefill, can_speculate,
                                   default_buckets)

# sentinel distinguishing "caller passed this legacy kwarg" from its old
# default — the deprecation shim only routes *explicit* flat kwargs
# through EngineConfig.from_kwargs
_UNSET = object()
_legacy_warned = False


def _warn_legacy_kwargs(names) -> None:
    """One DeprecationWarning per process, naming the offending kwargs."""
    global _legacy_warned
    if _legacy_warned:
        return
    _legacy_warned = True
    warnings.warn(
        "flat ContinuousBatchingEngine kwargs ({}) are deprecated — pass "
        "config=EngineConfig(...) instead (semantics unchanged; migration "
        "table in docs/serving.md)".format(", ".join(names)),
        DeprecationWarning, stacklevel=3)


@dataclasses.dataclass
class ServeStats:
    """Aggregate engine statistics for one ``run()`` (or one lock-step
    ``generate()``).  Counters are totals over the run; times are wall
    seconds on the host driving the jitted steps.

    Fields:
      prefill_tokens    — prompt tokens prefilled (real tokens; bucket /
                          chunk padding excluded).
      decode_tokens     — tokens emitted (the first token of each request
                          — sampled from prefill logits — included).
      prefill_s         — wall time spent in prefill work (monolithic
                          prefills and prefill chunks alike).
      decode_s          — wall time spent in ragged decode steps.
      prefill_chunks    — prefill work units executed: one per chunk with
                          ``prefill_chunk > 0``, one per prompt otherwise.
      interleaved_steps — engine iterations in which a prefill chunk ran
                          in the same step as resident decodes (the
                          mixed prefill/decode steps chunked prefill
                          exists for; always 0 when no request ever
                          coexists with a prefill).
      attn_keep_frac    — mean decode-time attention keep rate from the
                          execution-gate log (1.0 = dense).
      kv_saved_fraction — measured compact-KV storage saving over this
                          run's execution gates (prompt and decode phases
                          both); ``kv_saved_analytic`` is the
                          configured-keep-rate estimate.
      requests_completed — requests drained to a RequestResult.
      decode_dispatches — jitted decode dispatches: one per ragged step in
                          single-step mode, one per N-step epoch with
                          ``decode_steps > 1`` (the host-overhead counter
                          the fused loop exists to shrink).
      device_s          — wall time the host spent *blocked* on device
                          results (the per-iteration sync); host_s is the
                          rest of the run-loop wall time — planning,
                          admission, bookkeeping and dispatch.  With the
                          fused loop host_s overlaps in-flight device
                          work instead of serializing with it.
      compiles          — jitted-dispatch cache growth observed during
                          the run (new compiled variants: prefill
                          buckets, pow2 epoch lengths, block-table
                          widths).  A steady-state run should show 0.

    All wall-clock fields are ``time.perf_counter`` intervals (monotonic
    — never skewed by NTP adjustment the way ``time.time`` deltas are).

    On the continuous engine this dataclass is a *derived view*: every
    counter field is read out of the run's ``MetricsRegistry`` at
    ``_finalize`` (``run()['metrics']`` exposes the registry itself,
    with histograms, per-layer series and time series the flat
    aggregate cannot hold — see docs/observability.md).

    Paged-mode extras (``kv_mode == "paged"``): page pool geometry
    (``page_size``/``pages_total``), ``pages_peak`` live-footprint peak,
    ``preemptions`` (OOM-safe mid-decode evictions), entry-stream write
    counters (``kv_entries_stored`` vs the per-layer-dense baseline
    ``kv_entries_dense``), and history-buffer hit rates measured from the
    gate log (aggregate + per attention layer)."""
    prefill_tokens: int = 0
    decode_tokens: int = 0
    prefill_s: float = 0.0
    decode_s: float = 0.0
    prefill_chunks: int = 0
    interleaved_steps: int = 0
    attn_keep_frac: float = 1.0
    kv_saved_fraction: float = 0.0        # measured from logged gates
    kv_saved_analytic: float = 0.0        # configured-keep-rate estimate
    requests_completed: int = 0
    # -- host-overhead counters (the fused-epoch loop's scoreboard) --------
    decode_dispatches: int = 0            # jitted decode dispatches (epochs)
    host_s: float = 0.0                   # host planning/bookkeeping wall
    device_s: float = 0.0                 # wall blocked on device syncs
    compiles: int = 0                     # new compiled variants this run
    # -- paged-KV engine mode (kv_mode == "paged") -------------------------
    kv_mode: str = "dense"
    page_size: int = 0
    pages_total: int = 0
    pages_peak: int = 0                   # peak pages in use (live footprint)
    preemptions: int = 0                  # OOM-safe mid-decode evictions
    kv_entries_stored: int = 0            # live compact-store writes
    kv_entries_dense: int = 0             # per-layer-dense baseline writes
    history_hit_rate: float = 0.0         # reads served by the history buf
    history_hits_per_layer: List[float] = dataclasses.field(
        default_factory=list)
    # -- prefix cache (kv.prefix_cache; docs/kvcache.md) -------------------
    prefix_hits: int = 0                  # warm-prefix admissions
    prefix_misses: int = 0                # cold admissions with cache on
    prefix_tokens_saved: int = 0          # prompt tokens skipped at prefill
    prefix_records: int = 0               # records resident at run end
    # -- speculative decoding (spec_k > 0; docs/speculative.md) ------------
    spec_windows: int = 0                 # draft+verify windows dispatched
    spec_tokens_drafted: int = 0          # draft proposals fed to verify
    spec_tokens_accepted: int = 0         # proposals the verifier kept
    spec_entries_rolled_back: int = 0     # tentative paged entries discarded
    spec_acceptance_rate: float = 0.0     # accepted / drafted (0 when off)
    # -- robustness / lifecycle (docs/robustness.md) -----------------------
    faults_injected: int = 0              # FaultPlan faults that fired
    dispatch_retries: int = 0             # iterations abandoned + replanned
    watchdog_strikes: int = 0             # straggler strikes (soft)
    requests_cancelled: int = 0           # finish_reason == "cancelled"
    deadline_exceeded: int = 0            # finish_reason == "deadline"
    requests_shed: int = 0                # submit()-time load shedding
    preempt_budget_exhausted: int = 0     # finish_reason == "preempt_budget"
    epoch_shrinks: int = 0                # adaptive decode_steps halvings
    snapshots: int = 0                    # boundary snapshots written
    resumes: int = 0                      # runs continued from a snapshot

    @property
    def decode_tok_per_s(self) -> float:
        return self.decode_tokens / self.decode_s if self.decode_s else 0.0

    @property
    def kv_entries_saved_fraction(self) -> float:
        """Live storage saving of the paged history buffer (matches the
        CompactKVStore accounting replayed over the same gates)."""
        if not self.kv_entries_dense:
            return 0.0
        return 1.0 - self.kv_entries_stored / self.kv_entries_dense


@dataclasses.dataclass
class RequestResult:
    """Per-request outcome + serving metrics.

    Fields:
      uid          — id returned by ``submit``.
      tokens       — generated token ids, stop token (if hit) included.
      prompt_len   — real prompt length T0 (padding excluded).
      ttft_s       — wall seconds from ``run()`` start (every request is
                     considered submitted when the run starts) to this
                     request's first token.  Under monolithic prefill
                     that is queue wait + one prefill; under chunked
                     prefill (``prefill_chunk > 0``) it spans all
                     ceil(T0/chunk) chunk steps *plus* the decode steps
                     interleaved between them — chunking deliberately
                     trades a little TTFT on the prefilling request for
                     bounded decode stalls on every resident one.
      decode_s     — wall seconds inside decode steps this request
                     participated in (other requests' prefill work
                     excluded).
      max_decode_stall_s — longest wall-clock gap between two of this
                     request's consecutive token emissions; the
                     head-of-line metric chunked prefill bounds (an
                     eager monolithic prefill of a long newcomer shows
                     up here for every resident).
      finish_reason — why generation ended:
                     "length" (budget), "stop" (stop token), "max_len"
                     (slot position hit the pool's max_len); or a
                     lifecycle outcome — "deadline" (per-request deadline
                     elapsed; tokens are the partial output), "cancelled"
                     (cooperative cancellation honored at a step/epoch
                     boundary), "preempt_budget" (preempted more than the
                     engine's ``max_preemptions`` retry budget allows).
      kv_stored / kv_dense — measured compact-store entry writes vs the
                     per-layer-dense baseline for this request's decode
                     steps."""
    uid: int
    tokens: np.ndarray                   # generated tokens (incl. stop token)
    prompt_len: int
    ttft_s: float                        # submit → first token
    decode_s: float                      # time in this request's decode steps
    finish_reason: str                   # "length"|"stop"|"max_len"|...
    kv_stored: int = 0                   # measured compact-store entries
    kv_dense: int = 0                    # dense-baseline entries
    max_decode_stall_s: float = 0.0      # worst inter-token emission gap

    @property
    def decode_tokens(self) -> int:
        return int(self.tokens.shape[0])

    @property
    def decode_tok_per_s(self) -> float:
        n = self.decode_tokens - 1       # first token is prefill's
        return n / self.decode_s if self.decode_s > 0 and n > 0 else 0.0

    @property
    def kv_saved_fraction(self) -> float:
        if self.kv_dense == 0:
            return 0.0
        return 1.0 - self.kv_stored / self.kv_dense


def analytic_kv_saved(cfg: ModelConfig) -> float:
    """Compact-store saving at the *configured* keep rate: layer 0 dense +
    keep_prob elsewhere.  The measured per-run figure comes from the decode
    gate log via kv_reuse.storage_saved_fraction."""
    L = max(len(cfg.attention_layers), 1)
    if not (cfg.skip.enabled and cfg.skip.kv_reuse):
        return 0.0
    return 1.0 - (1.0 + (L - 1) * cfg.skip.keep_prob) / L


def _measured_saved_fraction(gates_per_step: List[np.ndarray],
                             cfg: ModelConfig) -> float:
    """Lock-step gate log [L, B] per step -> measured storage saving."""
    if not gates_per_step or not (cfg.skip.enabled and cfg.skip.kv_reuse):
        return 0.0
    g = jnp.asarray(np.stack(gates_per_step, axis=-1))   # [L, B, steps]
    return float(kv_reuse.storage_saved_fraction(g))


class ServeEngine:
    """Lock-step batched engine (baseline; one shared decode position)."""

    def __init__(self, cfg: ModelConfig, params, max_len: int = 512,
                 temperature: float = 0.0):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.temperature = temperature
        self._decode = jax.jit(partial(model_lib.decode_step, cfg=cfg),
                               donate_argnums=(1,))
        self._prefill = jax.jit(partial(model_lib.prefill, cfg=cfg,
                                        pad_to=max_len))

    def generate(self, prompts: np.ndarray, max_new_tokens: int,
                 rng: Optional[jax.Array] = None) -> Dict[str, np.ndarray]:
        """prompts: [B, T0] int32 (right-aligned, no padding support needed
        for the synthetic workloads).  Returns tokens + stats."""
        cfg = self.cfg
        B, T0 = prompts.shape
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        stats = ServeStats()

        t0 = perf_counter()
        logits, cache, pstats = self._prefill(self.params,
                                              {"tokens": jnp.asarray(prompts)})
        jax.block_until_ready(logits)
        stats.prefill_s = perf_counter() - t0
        stats.prefill_tokens = B * T0

        out = np.zeros((B, max_new_tokens), np.int32)
        keep_acc, keep_n = 0.0, 0
        gates_per_step: List[np.ndarray] = []
        emitted = 0
        tok = sample(logits, rng, self.temperature)
        t0 = perf_counter()
        for i in range(max_new_tokens):
            out[:, i] = np.asarray(tok)
            emitted += B
            pos = T0 + i
            if pos >= self.max_len:
                break
            logits, cache, dstats = self._decode(
                self.params, cache, {"tokens": tok[:, None]},
                jnp.int32(pos))
            if "attn_gate" in dstats:
                gates_per_step.append(
                    np.asarray(dstats["attn_gate"], np.float32))
            keep_acc += float(dstats["keep_frac_sum"])
            keep_n += max(float(dstats["n_routed"]), 1.0)
            rng, sub = jax.random.split(rng)
            tok = sample(logits, sub, self.temperature)
        jax.block_until_ready(logits)
        stats.decode_s = perf_counter() - t0
        stats.decode_tokens = emitted           # tokens actually emitted

        stats.attn_keep_frac = keep_acc / max(keep_n, 1.0)
        stats.kv_saved_fraction = _measured_saved_fraction(gates_per_step, cfg)
        stats.kv_saved_analytic = analytic_kv_saved(cfg)
        return {"tokens": out, "stats": stats}


# ---------------------------------------------------------------------------
# Slot-pool plumbing
# ---------------------------------------------------------------------------

def init_pool(cfg: ModelConfig, max_slots: int, max_len: int) -> Dict:
    """The continuous engine's KV pool: ``max_slots`` cache rows allocated
    once (the paper's fixed on-chip KV history buffer analogue)."""
    return model_lib.init_decode_cache(cfg, max_slots, max_len)

def _align_kv_row(row: jnp.ndarray, target_shape, kind: str,
                  cfg: ModelConfig) -> jnp.ndarray:
    """Reshape one prefill k/v cache row (``[.., T, Hkv, dh]``, padded to
    max_len) to the pool's layout for its layer kind: head-major transpose
    for ``bhtd`` pools, truncation to the ring extent for window layers
    (positions < W: ring slot s ≡ position s, so the prefix IS the ring)."""
    if kind == LOCAL and cfg.window_size:
        W = target_shape[-3]
        if row.shape[-3] != W:
            row = jax.lax.slice_in_dim(row, 0, W, axis=row.ndim - 3)
    elif cfg.kv_cache_layout == "bhtd":
        row = row.swapaxes(-3, -2)           # prefill collects [.., T, H, d]
    return row


def pool_insert(pool: Dict, cache: Dict, slot, cfg: ModelConfig) -> Dict:
    """Scatter a single-request prefill cache (batch dim 1, KV padded to
    max_len) into row ``slot`` of the pool.  ``slot`` may be traced — the
    engine runs this jitted (donating the pool) so admission is one fused
    scatter, not an eager op per cache leaf."""
    def one(path, pl, nl):
        names = [getattr(p, "key", "") for p in path]
        stage_leaf = names[0] == "stages"
        row = jnp.take(nl, 0, axis=1 if stage_leaf else 0)
        if names[-1] in ("k", "v"):
            kind = cfg.block_kind(int(names[-2][3:]))
            tgt = pl.shape[2:] if stage_leaf else pl.shape[1:]
            if stage_leaf:
                tgt = (row.shape[0],) + tuple(tgt)
            row = _align_kv_row(row, tgt, kind, cfg)
        row = row.astype(pl.dtype)
        return pl.at[:, slot].set(row) if stage_leaf else pl.at[slot].set(row)

    return jax.tree_util.tree_map_with_path(one, pool, cache)


@dataclasses.dataclass
class _RunState:
    """Host-side state of one ``run()``, shared by the dense and paged
    loops (the consolidation of the per-loop ``finish``/``preempt``
    closures the PR-2 review flagged).  ``metrics`` is the run's
    source-of-truth registry — ``stats`` counter fields are derived from
    it at ``_finalize``."""
    stats: ServeStats
    results: Dict[int, RequestResult]
    t_run: float
    rng: jax.Array
    metrics: MetricsRegistry = dataclasses.field(
        default_factory=MetricsRegistry)
    keep_acc: float = 0.0
    keep_n: float = 0.0
    # -- observability bookkeeping -----------------------------------------
    step_idx: int = 0                     # cumulative inner decode steps
    disp_idx: int = 0                     # decode dispatches (epoch index)
    compiled_seen: int = 0                # jit cache size at run start
    traced: set = dataclasses.field(default_factory=set)     # request spans
    admitted: set = dataclasses.field(default_factory=set)   # prefill spans
    # paged-mode extras
    hist: Optional[history_mod.HistoryAccounting] = None
    # crash consistency: last boundary a snapshot was published at
    last_snap: int = -1
    # adaptive degradation (paged fused mode): cross-epoch decode_steps
    # cap remembered after a page-pressure shrink (0 = uncapped), and the
    # clean-epoch streak that grows it back (hysteresis)
    epoch_cap: int = 0
    clean_epochs: int = 0
    # chunked-prefill staging (at most one prompt in flight at a time)
    stage_cache: Optional[Dict] = None
    stage_gates: List[np.ndarray] = dataclasses.field(default_factory=list)
    # fused-epoch mode: first tokens sampled inside a prefill dispatch
    # whose values the host has not yet synced ({slot: device [1] int32});
    # the decode loop reads them straight off the device carry
    pending: Dict[int, object] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class _WarmAdmission:
    """Host state of one warm-prefix admission between the scheduler's
    probe (allocator work done, device work deferred) and the slot's
    first prefill chunk (COW copy + staging-cache reconstruction)."""
    rec: prefix_mod.PrefixRecord
    # boundary-page COW: (src shared page, dst private page, entries kept);
    # None when the shared prefix ends exactly on a page boundary
    copy: Optional[tuple] = None


class RequestHandle(int):
    """What ``submit()`` returns: the request uid (is-an ``int``, so every
    pre-streaming caller that compared / stored uids keeps working) plus
    the streaming surface.

    ``tokens()`` yields ``(token, step)`` pairs as the engine emits them,
    *driving the engine itself* when the buffer runs dry — iterating a
    handle interleaves engine iterations with consumption, no thread
    needed.  Emission granularity is the engine iteration (= epoch in
    fused mode): see docs/serving.md for the exact contract.
    """

    def __new__(cls, uid: int, engine):
        h = super().__new__(cls, uid)
        h.engine = engine
        return h

    @property
    def uid(self) -> int:
        return int(self)

    def done(self) -> bool:
        """True once the request has a final :class:`RequestResult`."""
        return int(self) in self.engine._stream_done

    def result(self) -> Optional["RequestResult"]:
        """The final result, or None while the request is still running
        (``tokens()`` / ``run()`` drive it to completion)."""
        return self.engine._stream_results.get(int(self))

    def tokens(self):
        """Iterate ``(token, step)`` pairs for this request, pumping the
        engine's run loop whenever no buffered token is ready."""
        return self.engine._stream_tokens(int(self))


class ContinuousBatchingEngine:
    """Continuous batching over a fixed slot pool (per-sequence positions).

    Requests are admitted into free KV slots, prefilled (length-bucketed
    where exact, or chunk-by-chunk with ``prefill_chunk > 0``), decoded
    concurrently — each sequence at its own position ``t[slot]`` — and
    evicted on stop-token / length, freeing the slot for the next queued
    request.  Both run loops consume ``Scheduler.plan_step`` plans: each
    engine iteration executes at most one prefill work unit alongside one
    ragged decode step over every resident slot, so with chunking on a
    long prompt can no longer stall resident decodes for its whole length
    (head-of-line blocking — see docs/serving.md).

    Constructor levers:
      max_slots / max_len  — KV pool geometry (slots × positions).
      temperature          — 0.0 = greedy sampling.
      prefill_buckets      — monolithic-prefill padding buckets (defaulted
                             when exact; unused once chunking is on).
      kv_mode              — "dense" slot pool or "paged" entry stream.
      page_size/num_pages  — paged-pool geometry.
      prefill_chunk        — chunk size in tokens; None defers to
                             ``cfg.prefill_chunk``; 0 = monolithic
                             (parity default).
      decode_steps         — decode iterations fused into one jitted
                             device-resident dispatch (``model.decode_loop``
                             / ``model.paged_decode_loop``); None defers to
                             ``cfg.decode_steps_per_dispatch``; 1 = the
                             single-step loops (parity default).  With
                             N > 1 sampling, stop/length detection and
                             position advance run on device, the host
                             syncs once per epoch, and its scheduling
                             work overlaps the in-flight dispatch — see
                             docs/serving.md.  Token output is identical
                             to N = 1 at temperature 0.
      spec_k               — self-speculative decoding (docs/
                             speculative.md): each decode iteration
                             drafts up to ``spec_k`` tokens per resident
                             with an aggressively-skipped forward, then
                             verifies the whole window in ONE chunked
                             dispatch — two dispatches emit up to
                             ``spec_k + 1`` tokens per slot.  0 = off
                             (parity default).  Requires
                             ``can_speculate(cfg)`` and is mutually
                             exclusive with ``decode_steps > 1`` (both
                             amortize host overhead over multi-token
                             dispatches).  Token output is identical to
                             plain decoding at temperature 0; at
                             temperature > 0 the per-token emission
                             distribution is preserved exactly
                             (speculative-sampling identity).
      draft_keep           — draft-pass router keep-rate override in
                             (0, 1]; values < 1 bias every router toward
                             skipping during the draft loop only (the
                             verify pass always runs the full model).
                             None/1.0 = draft with the configured
                             routing (self-drafting, acceptance-
                             friendly).
      step_tokens          — optional per-step token budget for
                             ``plan_step`` (decode slots cost 1 each, a
                             chunk its length); None = unbudgeted.
      trace                — observability: ``None`` (default, off — a
                             no-op ``NullTracer``), a ``repro.obs.Tracer``
                             to record into, or a path string — the
                             engine then builds a tracer and writes the
                             Chrome-trace JSON there at the end of every
                             ``run()`` (perfetto-loadable; span taxonomy
                             in docs/observability.md).  Independent of
                             tracing, every run fills a
                             ``MetricsRegistry`` returned as
                             ``run()['metrics']``.
      mesh                 — optional ``jax.sharding.Mesh`` with a
                             ``model`` axis: tensor-parallel sharded
                             serving.  Params are re-sharded under the
                             serve-mode ``ShardingPolicy`` (head-sharded
                             attention, column/row-split MLP) and the KV
                             slot pool / paged store is head-sharded over
                             ``model`` via ``ShardingPolicy.cache_specs``;
                             every jitted step carries explicit in/out
                             shardings.  Block tables, free list and the
                             scheduler stay host-side and replicated, so
                             engine semantics (and its token output) are
                             unchanged — see docs/distributed.md.
      sharding_policy      — optional pre-built serve-mode policy (defaults
                             to ``ShardingPolicy(mesh, cfg, mode="serve")``).

    Robustness levers (docs/robustness.md):
      faults               — a ``serve.faults.FaultPlan`` (or list of
                             ``Fault``) of scheduled injections consumed
                             at the engine's seams; None = no faults.
      watchdog             — a ``serve.faults.Watchdog``: per-dispatch
                             wall-time monitor; a sync past its hard
                             timeout raises ``HungDispatch`` with the
                             flushed trace path attached.
      snapshot_dir         — directory for crash-consistent boundary
                             snapshots (None = off); ``snapshot_every``
                             sets the cadence in engine iterations.
                             ``resume()`` restores the newest snapshot.
      max_queue_depth /    — load shedding: ``submit()`` raises
      max_queue_delay_s      ``AdmissionRejected`` when the queue is this
                             deep, or when the queue head has already
                             waited past the delay bound (the request
                             would only be joining a queue that is
                             already falling behind).
      max_preemptions      — retry budget: a request preempted more than
                             this many times finishes with reason
                             "preempt_budget" (partial tokens) instead of
                             requeueing forever; None = unlimited.
    """

    def __init__(self, cfg: ModelConfig, params, max_slots=_UNSET,
                 max_len=_UNSET, temperature=_UNSET,
                 prefill_buckets=_UNSET,
                 kv_mode=_UNSET, page_size=_UNSET,
                 num_pages=_UNSET,
                 prefill_chunk=_UNSET,
                 decode_steps=_UNSET,
                 spec_k=_UNSET,
                 draft_keep=_UNSET,
                 step_tokens=_UNSET,
                 trace=_UNSET,
                 mesh=_UNSET, sharding_policy=_UNSET,
                 faults=_UNSET, watchdog=_UNSET,
                 snapshot_dir=_UNSET,
                 snapshot_every=_UNSET,
                 max_queue_depth=_UNSET,
                 max_queue_delay_s=_UNSET,
                 max_preemptions=_UNSET,
                 kv_dtype=_UNSET, prefix_cache=_UNSET, prefix_block=_UNSET,
                 *, config: Optional[EngineConfig] = None):
        # -- deprecation shim: explicit flat kwargs -> EngineConfig --------
        legacy = {name: value for name, value in (
            ("max_slots", max_slots), ("max_len", max_len),
            ("temperature", temperature),
            ("prefill_buckets", prefill_buckets), ("kv_mode", kv_mode),
            ("page_size", page_size), ("num_pages", num_pages),
            ("prefill_chunk", prefill_chunk), ("decode_steps", decode_steps),
            ("spec_k", spec_k), ("draft_keep", draft_keep),
            ("step_tokens", step_tokens), ("trace", trace), ("mesh", mesh),
            ("sharding_policy", sharding_policy), ("faults", faults),
            ("watchdog", watchdog), ("snapshot_dir", snapshot_dir),
            ("snapshot_every", snapshot_every),
            ("max_queue_depth", max_queue_depth),
            ("max_queue_delay_s", max_queue_delay_s),
            ("max_preemptions", max_preemptions), ("kv_dtype", kv_dtype),
            ("prefix_cache", prefix_cache), ("prefix_block", prefix_block),
        ) if value is not _UNSET}
        if legacy:
            if config is not None:
                raise ConfigError(
                    "pass either config=EngineConfig(...) or the legacy "
                    "flat kwargs, not both (got config= plus "
                    f"{sorted(legacy)})")
            _warn_legacy_kwargs(sorted(legacy))
            config = EngineConfig.from_kwargs(**legacy)
        elif config is None:
            config = EngineConfig()
        self.config = config
        kvc, sch = config.kv, config.scheduling
        spc, rob, obs = config.spec, config.robustness, config.obs
        max_slots, max_len = sch.max_slots, sch.max_len
        temperature = config.temperature
        prefill_buckets, prefill_chunk = sch.prefill_buckets, sch.prefill_chunk
        decode_steps, step_tokens = sch.decode_steps, sch.step_tokens
        kv_mode, page_size = kvc.kv_mode, kvc.page_size
        num_pages = kvc.num_pages
        spec_k, draft_keep = spc.spec_k, spc.draft_keep
        trace, mesh = obs.trace, obs.mesh
        sharding_policy = obs.sharding_policy
        faults, watchdog = rob.faults, rob.watchdog
        snapshot_dir, snapshot_every = rob.snapshot_dir, rob.snapshot_every
        max_queue_depth = rob.max_queue_depth
        max_queue_delay_s = rob.max_queue_delay_s
        max_preemptions = rob.max_preemptions
        self.cfg = cfg
        self.tracer = as_tracer(trace)
        self.metrics: Optional[MetricsRegistry] = None   # last run's registry
        self._jitted: List = []          # every jitted step (compile probe)
        self.mesh = mesh
        self.policy: Optional[ShardingPolicy] = None
        self._param_sh = self._repl = None
        if mesh is not None:
            if cfg.frontend != "token":
                raise ValueError("sharded serving requires a token frontend")
            pol = sharding_policy or ShardingPolicy(mesh, cfg, mode="serve")
            if pol.mode != "serve":
                raise ValueError("ContinuousBatchingEngine requires a "
                                 "serve-mode ShardingPolicy")
            self.policy = pol
            self._repl = NamedSharding(mesh, P())
            self._param_sh = pol.param_specs(params)
            # weight-stationary re-shard onto the serve mesh (column-split
            # merged wqkv / [gate|up] with the GQA row-parallel fallback —
            # the PR-3 merged-tree rules)
            params = jax.device_put(params, self._param_sh)
        self.params = params
        self.max_slots = max_slots
        self.max_len = max_len
        self.temperature = temperature
        if kv_mode not in ("dense", "paged"):
            raise ValueError(f"unknown kv_mode {kv_mode!r}")
        if kv_mode == "paged" and not paged_mod.can_page(cfg):
            raise ValueError(
                f"{cfg.name}: paged KV requires an all-global-attention "
                "stack with masked-mode routing — use kv_mode='dense'")
        self.kv_mode = kv_mode
        self.prefill_chunk = int(cfg.prefill_chunk if prefill_chunk is None
                                 else prefill_chunk)
        if self.prefill_chunk < 0:
            raise ValueError("prefill_chunk must be >= 0 (0 = monolithic)")
        if self.prefill_chunk and not can_chunk_prefill(cfg):
            raise ValueError(
                f"{cfg.name}: chunked prefill requires an all-global-"
                "attention stack with masked-mode routing (resumable "
                "cache state) — use prefill_chunk=0")
        self.decode_steps = int(cfg.decode_steps_per_dispatch
                                if decode_steps is None else decode_steps)
        if self.decode_steps < 1:
            raise ValueError("decode_steps must be >= 1 (1 = single-step)")
        self.spec_k = int(spec_k)
        if self.spec_k < 0:
            raise ValueError("spec_k must be >= 0 (0 = off)")
        self.draft_keep = 1.0 if draft_keep is None else float(draft_keep)
        # test hook: callable (uid, drafts [k] int32) -> [k] replacing a
        # slot's draft proposals before verification (forces a host sync
        # of the draft tokens — test-only, not a serving lever)
        self.draft_override = None
        self.draft_params = params
        if self.spec_k:
            if not can_speculate(cfg):
                raise ValueError(
                    f"{cfg.name}: speculative decoding reuses the chunked-"
                    "prefill stack pass for verification — it requires an "
                    "all-global-attention stack with masked-mode routing "
                    "and the bthd cache layout (spec_k=0)")
            if self.decode_steps > 1:
                raise ValueError(
                    "spec_k and decode_steps > 1 are mutually exclusive — "
                    "both amortize host overhead over multi-token "
                    "dispatches; pick one")
            if not 0.0 < self.draft_keep <= 1.0:
                raise ValueError("draft_keep must be in (0, 1]")
            self.draft_params = draft_router_bias(params, self.draft_keep)
        self.step_tokens = step_tokens
        if prefill_buckets is not None and not can_bucket(cfg):
            raise ValueError(
                f"{cfg.name}: prefill bucketing pads prompts, which corrupts "
                "ring-buffer/SSM state and gather-mode capacity — this "
                "config requires exact-length prefill (prefill_buckets=None)")
        if (prefill_buckets is None and can_bucket(cfg)
                and not self.prefill_chunk):
            # chunked prefill quantizes shapes to the chunk size itself;
            # buckets only serve the monolithic path
            prefill_buckets = default_buckets(max_len)
        self.scheduler = Scheduler(max_slots, max_len,
                                   buckets=prefill_buckets,
                                   prefill_chunk=self.prefill_chunk)

        # -- jitted steps, with explicit in/out shardings under a policy ----
        # (``last_index`` is threaded positionally through thin wrappers:
        # pjit rejects kwargs once in_shardings are pinned)
        pol = self.policy
        rep = self._repl if pol is not None else None
        _jit = self._jit_step

        self._pool_sh = self._pcache_sh = None
        if pol is not None:
            self._pool_sh = pol.cache_specs(
                jax.eval_shape(partial(init_pool, cfg, max_slots, max_len)),
                layout=cfg.kv_cache_layout)
            self._warn_if_unsharded(self._pool_sh, "KV slot pool")
            # prefill collects time-major rows regardless of the pool
            # layout; the serve head-axis rule is layout-independent.
            # seq_fallback=False: these single-request caches are built at
            # *bucketed* lengths the max_len-derived spec tree must cover,
            # so a non-dividing head axis replicates rather than riding a
            # time split that some bucket wouldn't divide.
            self._pcache_sh = pol.cache_specs(
                jax.eval_shape(
                    lambda p: model_lib.prefill(
                        p, {"tokens": jnp.zeros((1, max_len), jnp.int32)},
                        cfg=cfg, pad_to=max_len)[1],
                    params),
                layout="bthd", seq_fallback=False)

        # first-token sampling is folded INTO the prefill dispatch (the
        # rng key rides along), so the completion path has no eager
        # sample and — in fused mode — no host sync at all
        def _prefill_fn(p, batch, last_index, rng):
            logits, cache, stats = model_lib.prefill(
                p, batch, cfg=cfg, pad_to=max_len, last_index=last_index)
            return sample(logits, rng, temperature), cache, stats

        self._decode = _jit(
            partial(model_lib.decode_step, cfg=cfg), donate=(1,),
            in_sh=(self._param_sh, self._pool_sh, rep, rep),
            out_sh=(rep, self._pool_sh, rep))
        self._prefill = _jit(
            _prefill_fn,
            in_sh=(self._param_sh, rep, rep, rep),
            out_sh=(rep, self._pcache_sh, rep))
        # chunked completions sample from the last chunk's logits in a
        # (tiny) jitted dispatch of their own
        self._sample_tok = _jit(
            lambda logits, rng: sample(logits, rng, temperature),
            in_sh=(rep, rep), out_sh=rep)
        # fused decode loops, compiled lazily per power-of-two epoch length
        self._dense_loops: Dict[int, object] = {}
        self._paged_loops: Dict[int, object] = {}
        # speculative draft loops (lazy per draft length) + the verify /
        # commit steps (single jits — their window width is shape-driven)
        self._spec_drafts: Dict[int, object] = {}
        self._spec_verify_fn = None
        self._spec_commit_fn = None
        self._spec_vc_fn = None
        self._insert = _jit(
            partial(pool_insert, cfg=cfg), donate=(0,),
            in_sh=(self._pool_sh, self._pcache_sh, rep),
            out_sh=self._pool_sh)
        if self.prefill_chunk:
            # staging cache capacity: max_len rounded up to a chunk
            # multiple, so the right-padded final chunk always fits
            C = self.prefill_chunk
            self._chunk_cap = -(-max_len // C) * C
            self._chunk_sh = None
            if pol is not None:
                self._chunk_sh = pol.cache_specs(
                    jax.eval_shape(partial(model_lib.init_chunk_cache,
                                           cfg, 1, self._chunk_cap)),
                    layout="bthd", seq_fallback=False)

            def _chunk_fn(p, cache, batch, t0, last_index):
                return model_lib.prefill_chunk(p, cache, batch, t0, cfg=cfg,
                                               last_index=last_index)

            self._chunk_step = _jit(
                _chunk_fn, donate=(1,),
                in_sh=(self._param_sh, self._chunk_sh, rep, rep, rep),
                out_sh=(rep, self._chunk_sh, rep))

            def _ins_staged(pool, cache, slot):
                return pool_insert(
                    pool, model_lib.slice_cache_time(cache, max_len),
                    slot, cfg)

            self._insert_staged = _jit(
                _ins_staged, donate=(0,),
                in_sh=(self._pool_sh, self._chunk_sh, rep),
                out_sh=self._pool_sh)
        self.kv_dtype = kvc.kv_dtype
        self.prefix: Optional[prefix_mod.PrefixCache] = None
        # persistent device page store (paged mode): stashed by the run
        # loops at clean exit so prefix records stay backed across runs
        self._store = None
        if kv_mode == "paged":
            self.n_attn = paged_mod.num_attention_layers(cfg)
            self.page_size = page_size
            # default pool: the dense pool's worst case (every token fresh
            # at every layer) — alloc-on-demand still keeps the *live*
            # footprint far below it; size it down to see backpressure.
            cap = max_len * self.n_attn
            self.num_pages = (num_pages if num_pages is not None
                              else max_slots * -(-cap // page_size))
            self.allocator = paged_mod.PageAllocator(
                self.num_pages, page_size, max_slots,
                slot_entry_capacity=cap)
            self._store_sh = None
            if pol is not None:
                self._store_sh = pol.cache_specs(jax.eval_shape(
                    partial(paged_mod.init_store, cfg, self.num_pages,
                            self.page_size, kv_dtype=self.kv_dtype)))
                self._warn_if_unsharded(self._store_sh, "paged KV store")

            def _prefill_paged_fn(p, batch, last_index, rng):
                logits, cache, stats = model_lib.prefill(
                    p, batch, cfg=cfg, last_index=last_index)
                return sample(logits, rng, temperature), cache, stats

            # paged prefill keeps the exact (bucketed) length — pages
            # replace the pool's max_len padding.  The spec tree from the
            # padded prefill cache applies unchanged (specs are
            # shape-independent; the head axis is identical).
            self._prefill_paged = _jit(
                _prefill_paged_fn,
                in_sh=(self._param_sh, rep, rep, rep),
                out_sh=(rep, self._pcache_sh, rep))
            pack_cache_sh = (self._chunk_sh if self.prefill_chunk
                             else self._pcache_sh)
            kv_dt = self.kv_dtype

            def _pack_fn(store, cache, gates, valid_len, bt_row,
                         start_token, start_entry):
                return paged_mod.pack_prefill(
                    store, cache, gates, valid_len, bt_row, cfg,
                    start_token=start_token, start_entry=start_entry,
                    kv_dtype=kv_dt)

            self._pack = _jit(
                _pack_fn, donate=(0,),
                in_sh=(self._store_sh, pack_cache_sh, rep, rep, rep,
                       rep, rep),
                out_sh=self._store_sh)
            self._decode_paged = _jit(
                partial(model_lib.paged_decode_step, cfg=cfg), donate=(1,),
                in_sh=(self._param_sh, self._store_sh, rep, rep, rep, rep),
                out_sh=(rep, self._store_sh, rep))
            if kvc.prefix_cache:
                if not can_chunk_prefill(cfg):
                    raise ConfigError(
                        f"{cfg.name}: prefix_cache resumes prefill from a "
                        "reconstructed staging cache — it requires the "
                        "chunk-resumable stack chunked prefill needs")
                self.prefix = prefix_mod.PrefixCache(
                    self.allocator, block=kvc.prefix_block,
                    reuse=paged_mod.reuse_enabled(cfg),
                    max_records=kvc.prefix_max_records)
                self.scheduler.prefix_probe = self._prefix_probe
                # in-flight warm admissions: slot -> _WarmAdmission
                self._warm_pending: Dict[int, _WarmAdmission] = {}
                # warm-suffix forward runs chunk-style even under
                # monolithic prefill: the suffix resumes mid-sequence, so
                # it needs the resumable staging-cache step.  Its cache
                # capacity covers the whole prompt region.
                self._warm_cap = (self._chunk_cap if self.prefill_chunk
                                  else max_len)
                self._warm_sh = None
                if pol is not None:
                    self._warm_sh = (
                        self._chunk_sh if self.prefill_chunk
                        else pol.cache_specs(
                            jax.eval_shape(partial(
                                model_lib.init_chunk_cache, cfg, 1,
                                self._warm_cap)),
                            layout="bthd", seq_fallback=False))
                warm_cap = self._warm_cap
                kv_dt = self.kv_dtype

                def _warm_fn(store, bt_row, fill):
                    kv_v, vv_v = paged_mod.views_from_pages(
                        store, bt_row, fill, cfg, warm_cap,
                        kv_dtype=kv_dt)
                    return paged_mod.chunk_cache_from_views(kv_v, vv_v, cfg)

                # shared-prefix entries -> batch-1 staging cache (the
                # exact inverse of pack_prefill; docs/kvcache.md)
                self._warm_cache = _jit(
                    _warm_fn, in_sh=(self._store_sh, rep, rep),
                    out_sh=self._warm_sh)
                self._cow_copy = _jit(
                    paged_mod.copy_page_masked, donate=(0,),
                    in_sh=(self._store_sh, rep, rep, rep),
                    out_sh=self._store_sh)
                if self.prefill_chunk:
                    self._warm_chunk_step = self._chunk_step
                else:
                    def _warm_chunk_fn(p, cache, batch, t0, last_index):
                        return model_lib.prefill_chunk(
                            p, cache, batch, t0, cfg=cfg,
                            last_index=last_index)

                    self._warm_chunk_step = _jit(
                        _warm_chunk_fn, donate=(1,),
                        in_sh=(self._param_sh, self._warm_sh, rep, rep,
                               rep),
                        out_sh=(rep, self._warm_sh, rep))
        self._uid = 0
        # -- streaming surface (docs/serving.md) ----------------------------
        self._streams: Dict[int, List] = {}      # uid -> [(token, step), ..]
        self._stream_pos: Dict[int, int] = {}    # uid -> emitted high-water
        self._stream_done: set = set()           # uids with a final result
        self._stream_results: Dict[int, RequestResult] = {}
        self._driver = None                      # active run-loop generator
        self._driver_rng = None
        self._driver_out: Optional[Dict] = None
        # -- robustness state (docs/robustness.md) --------------------------
        self.faults = as_fault_plan(faults)
        self.watchdog = watchdog
        self.snapshot_dir = snapshot_dir
        self.snapshot_every = max(1, int(snapshot_every))
        self.max_queue_depth = max_queue_depth
        self.max_queue_delay_s = max_queue_delay_s
        self.max_preemptions = max_preemptions
        self._cancelled: set = set()     # uids awaiting cooperative cancel
        self._shed_pending: List[str] = []   # shed reasons since last run
        self._resume = None              # (device_tree, host, step) to apply

    # -- jit plumbing ------------------------------------------------------
    def _jit_step(self, fn, donate=(), in_sh=None, out_sh=None):
        """jit with explicit in/out shardings under a mesh policy (pjit
        rejects kwargs once shardings are pinned, so callers thread every
        argument positionally).  Every jitted step is registered so the
        run loops can poll total compile-cache growth (the recompile
        counter)."""
        if self.policy is None:
            jitted = jax.jit(fn, donate_argnums=donate)
        else:
            jitted = jax.jit(fn, donate_argnums=donate,
                             in_shardings=in_sh, out_shardings=out_sh)
        self._jitted.append(jitted)
        return jitted

    def _dense_loop(self, n: int):
        """The jitted N-step dense decode loop (``model.decode_loop``),
        compiled once per epoch length; the pool rides the scan carry and
        is donated, so the cache updates in place across all N steps."""
        fn = self._dense_loops.get(n)
        if fn is None:
            cfg, max_len, temp = self.cfg, self.max_len, self.temperature

            def loop_fn(p, pool, feed, t, active, budget, stop, rng):
                return model_lib.decode_loop(
                    p, pool, feed, t, active, budget, stop, rng,
                    n_steps=n, cfg=cfg, max_len=max_len, temperature=temp)

            rep = self._repl
            fn = self._jit_step(
                loop_fn, donate=(1,),
                in_sh=(self._param_sh, self._pool_sh) + (rep,) * 6,
                out_sh=(self._pool_sh, rep))
            self._dense_loops[n] = fn
        return fn

    def _paged_loop(self, n: int):
        """``_dense_loop``'s paged twin (``model.paged_decode_loop``):
        the entry-stream fill advances on device, so the allocator replay
        happens once per epoch from the returned gate log."""
        fn = self._paged_loops.get(n)
        if fn is None:
            cfg, max_len, temp = self.cfg, self.max_len, self.temperature

            def loop_fn(p, store, feed, t, fill, active, budget, stop,
                        rng, block_table):
                return model_lib.paged_decode_loop(
                    p, store, feed, t, fill, active, budget, stop, rng,
                    block_table, n_steps=n, cfg=cfg, max_len=max_len,
                    temperature=temp)

            rep = self._repl
            fn = self._jit_step(
                loop_fn, donate=(1,),
                in_sh=(self._param_sh, self._store_sh) + (rep,) * 8,
                out_sh=(self._store_sh, rep))
            self._paged_loops[n] = fn
        return fn

    def _spec_draft(self, n: int):
        """The jitted n-step speculative draft loop for the engine's KV
        mode, compiled lazily per draft length (n <= spec_k, a handful
        of variants).  The pool/store is donated: draft KV writes are
        tentative — dense verify overwrites the window rows outright,
        and the paged verifier masks the tentative entries out before
        ``commit_verified`` rewrites them."""
        fn = self._spec_drafts.get(n)
        if fn is None:
            cfg, temp = self.cfg, self.temperature
            rep = self._repl
            if self.kv_mode == "paged":
                def draft_fn(p, store, feed, t, fill, active, rng, bt):
                    return model_lib.paged_draft_loop(
                        p, store, feed, t, fill, active, rng, bt,
                        n_steps=n, cfg=cfg, temperature=temp)

                fn = self._jit_step(
                    draft_fn, donate=(1,),
                    in_sh=(self._param_sh, self._store_sh) + (rep,) * 6,
                    out_sh=(self._store_sh, rep))
            else:
                def draft_fn(p, pool, feed, t, rng):
                    return model_lib.draft_loop(
                        p, pool, feed, t, rng, n_steps=n, cfg=cfg,
                        temperature=temp)

                fn = self._jit_step(
                    draft_fn, donate=(1,),
                    in_sh=(self._param_sh, self._pool_sh) + (rep,) * 3,
                    out_sh=(self._pool_sh, rep))
            self._spec_drafts[n] = fn
        return fn

    def _spec_verify(self):
        """The jitted verify step (the window width C is shape-driven,
        so one jit covers every draft length).  Dense mode donates the
        pool — the verifier's window rows ARE the committed state; paged
        mode reads the store without donating, since commit happens in
        the separate ``_spec_commit`` dispatch once the host knows each
        slot's accepted prefix.  The per-column argmax is computed on
        device so the temperature-0 sync never pulls [S, C, V] logits."""
        fn = self._spec_verify_fn
        if fn is None:
            cfg = self.cfg
            rep = self._repl
            if self.kv_mode == "paged":
                def vfn(p, store, batch, t0, bt, fill):
                    logits, stats = model_lib.paged_verify_chunk(
                        p, store, batch, t0, bt, fill, cfg=cfg)
                    return (jnp.argmax(logits, -1).astype(jnp.int32),
                            logits, stats)

                fn = self._jit_step(
                    vfn,
                    in_sh=(self._param_sh, self._store_sh) + (rep,) * 4,
                    out_sh=(rep, rep, rep))
            else:
                def vfn(p, pool, batch, t0):
                    logits, pool, stats = model_lib.verify_chunk(
                        p, pool, batch, t0, cfg=cfg)
                    return (jnp.argmax(logits, -1).astype(jnp.int32),
                            logits, pool, stats)

                fn = self._jit_step(
                    vfn, donate=(1,),
                    in_sh=(self._param_sh, self._pool_sh, rep, rep),
                    out_sh=(rep, rep, self._pool_sh, rep))
            self._spec_verify_fn = fn
        return fn

    def _spec_commit(self):
        """Paged tentative-commit (``model.commit_verified``): rewrite
        the entry stream from the pre-window fill with the verifier's KV
        for exactly the emitted columns — the device half of the
        rollback protocol (the host half is allocator replay + trim)."""
        fn = self._spec_commit_fn
        if fn is None:
            cfg = self.cfg
            rep = self._repl

            def cfn(store, bk, bv, gates, t0, bt, fill0, committed,
                    active):
                return model_lib.commit_verified(
                    store, bk, bv, gates, t0, bt, fill0, committed,
                    active, cfg=cfg)

            fn = self._jit_step(
                cfn, donate=(0,),
                in_sh=(self._store_sh,) + (rep,) * 8,
                out_sh=(self._store_sh, rep))
            self._spec_commit_fn = fn
        return fn

    def _spec_verify_commit(self):
        """Fused paged verify + greedy accept + tentative-commit: ONE
        dispatch where the two-phase path (``_spec_verify`` sync, host
        accept, ``_spec_commit`` dispatch) takes two — the greedy accept
        rule and ``_plan_emission``'s truncation (stop token, generation
        budget, ``max_len``) are pure elementwise arithmetic over the
        verifier's argmax chain, so at temperature 0 the device can
        decide the committed column count itself and rewrite the entry
        stream without waiting on the host.  The host still replays the
        acceptance from the synced argmax chain for bookkeeping and
        asserts it agrees (``_run_paged_spec``).  Temperature > 0 keeps
        the two-dispatch path: exact accept/resample needs host-side
        float64 probability arithmetic."""
        fn = self._spec_vc_fn
        if fn is None:
            cfg = self.cfg
            rep = self._repl

            def vcfn(p, store, batch, t0, bt, fill0, active, budget_cap,
                     len_cap, stop_tok):
                logits, stats = model_lib.paged_verify_chunk(
                    p, store, batch, t0, bt, fill0, cfg=cfg)
                tgt = jnp.argmax(logits, -1).astype(jnp.int32)    # [S, C]
                C = tgt.shape[1]
                if C > 1:
                    match = batch["tokens"][:, 1:] == tgt[:, :-1]
                    acc = jnp.where(match.all(axis=1), C - 1,
                                    jnp.argmin(match, axis=1)
                                    ).astype(jnp.int32)
                else:
                    acc = jnp.zeros(tgt.shape[:1], jnp.int32)
                # emitted chain == tgt[:, :acc+1]; truncate exactly as
                # _plan_emission does (stop inclusive, budget, max_len)
                cols = jnp.arange(C, dtype=jnp.int32)[None, :]
                is_stop = ((stop_tok[:, None] >= 0)
                           & (tgt == stop_tok[:, None]))
                stop_n = jnp.min(jnp.where(is_stop, cols, C), axis=1) + 1
                n = jnp.minimum(jnp.minimum(acc + 1, stop_n),
                                jnp.minimum(budget_cap, len_cap))
                committed = jnp.where(active, jnp.maximum(n, 1),
                                      0).astype(jnp.int32)
                bk, bv = stats["kv_token"]
                store2, _ = model_lib.commit_verified(
                    store, bk, bv, stats["attn_gate"], t0, bt, fill0,
                    committed, active, cfg=cfg)
                return store2, tgt, stats["attn_gate"], committed

            fn = self._jit_step(
                vcfn, donate=(1,),
                in_sh=(self._param_sh, self._store_sh) + (rep,) * 8,
                out_sh=(self._store_sh, rep, rep, rep))
            self._spec_vc_fn = fn
        return fn

    # -- sharding sanity ---------------------------------------------------
    def _warn_if_unsharded(self, sh_tree, what: str) -> None:
        """If no leaf of ``sh_tree`` landed on the model axis (head count
        and fallback axes all non-dividing), the structure replicates on
        every device — legal, but the ~1/TP per-chip KV memory the mesh
        was passed for is gone, so say it loudly instead of silently."""
        def axes(sh):
            out = []
            for ax in sh.spec:
                if ax is not None:
                    out.extend(ax if isinstance(ax, tuple) else (ax,))
            return out

        if not any("model" in axes(sh)
                   for sh in jax.tree_util.tree_leaves(sh_tree)):
            warnings.warn(
                f"sharded serving: the {what} has no dimension dividing "
                f"the mesh's model axis (size {self.policy.model_size}) "
                f"and is fully replicated per device — pick a TP degree "
                f"dividing the KV head count (or cache extents) to get "
                f"the ~1/TP per-chip KV footprint", stacklevel=3)

    # -- request intake ----------------------------------------------------
    def submit(self, tokens: np.ndarray, max_new_tokens: int,
               stop_token: Optional[int] = None,
               deadline_s: Optional[float] = None) -> "RequestHandle":
        """Queue one prompt; returns its :class:`RequestHandle` (an
        ``int`` subclass carrying the uid, so callers that treated the
        return value as a plain uid are unaffected).  Iterating
        ``handle.tokens()`` streams ``(token, step)`` pairs and drives
        the engine's run loop on demand; ``run()`` remains the drain-
        everything entry point.

        ``deadline_s`` is a wall-clock budget measured from submission:
        past it the request finishes with ``finish_reason == "deadline"``
        (partial tokens kept) and releases its slot/pages at the next
        step/epoch boundary.  Raises ``AdmissionRejected`` when the
        request can never be served (empty prompt, no decode headroom,
        paged worst-case KV over the pool) or when the engine is
        shedding load (``max_queue_depth`` / ``max_queue_delay_s``)."""
        uid = self._uid
        self._uid += 1
        req = Request(uid=uid, tokens=np.asarray(tokens, np.int32),
                      max_new_tokens=max_new_tokens, stop_token=stop_token,
                      deadline_s=deadline_s)
        tr = self.tracer
        tr.track(request_tid(uid), f"req {uid}")
        tr.instant("submit", request_tid(uid), prompt_len=req.prompt_len,
                   max_new=max_new_tokens)
        if self.kv_mode == "paged":
            # must cover both the lifetime worst case AND the admission
            # gate's requirement (prompt + one step of headroom) — a
            # request _can_place can never pass would park the queue
            # forever once accepted
            worst = max(self._worst_case_entries(req),
                        (req.prompt_len + 1) * self.n_attn)
            if self.allocator.pages_for(worst) > self.num_pages:
                raise AdmissionRejected(
                    f"request {uid}: worst-case KV ({worst} entries) "
                    f"exceeds the page pool ({self.num_pages} pages × "
                    f"{self.page_size}) — OOM-safe admission impossible",
                    reason="kv_worst_case", uid=uid)
        self._maybe_shed(req)
        self.scheduler.submit(req)
        return RequestHandle(uid, self)

    def _maybe_shed(self, req: Request) -> None:
        """Load shedding at the submit boundary: refuse to grow a queue
        that is over the depth bound or whose *head* has already waited
        past the delay bound (the head's age is the deterministic lower
        bound on what a newcomer would wait — if the oldest queued
        request is past the bound, everything behind it is too)."""
        q = self.scheduler.queue
        reason = detail = None
        if (self.max_queue_depth is not None
                and len(q) >= self.max_queue_depth):
            reason = "queue_depth"
            detail = (f"queue depth {len(q)} at the shed bound "
                      f"{self.max_queue_depth}")
        elif self.max_queue_delay_s is not None and q:
            head_age = perf_counter() - q[0].submit_s
            if head_age > self.max_queue_delay_s:
                reason = "queue_delay"
                detail = (f"queue head has waited {head_age:.3f}s > "
                          f"bound {self.max_queue_delay_s:.3f}s")
        if reason is not None:
            self._shed_pending.append(reason)
            self.tracer.instant("shed", request_tid(req.uid), reason=reason)
            raise AdmissionRejected(
                f"request {req.uid} shed: {detail}", reason=reason,
                uid=req.uid)

    def cancel(self, uid: int) -> None:
        """Cooperative cancellation: mark ``uid`` for removal at the next
        step/epoch boundary — a queued request is dropped, an in-flight
        prefill is aborted, a resident finishes with its partial tokens
        (``finish_reason == "cancelled"``) and its slot/pages released.
        Unknown or already-finished uids are a no-op."""
        self._cancelled.add(uid)
        self.tracer.instant("cancel", request_tid(uid))

    # -- crash-consistent snapshots (serve/snapshot.py) --------------------
    def resume(self, snapshot_dir: Optional[str] = None,
               step: Optional[int] = None) -> int:
        """Load the newest (or the given ``step``) boundary snapshot under
        ``snapshot_dir`` (default: the engine's own) — the next ``run()``
        continues from it: scheduler queue/residents, allocator chains,
        finished results and the device KV state are all restored, so at
        temperature 0 the surviving requests' tokens are bit-identical to
        the run the dead process would have completed.  Returns the
        boundary index restored.  Requests submitted to this engine
        before ``run()`` are merged into the restored queue in age
        order."""
        snap_dir = snapshot_dir or self.snapshot_dir
        if snap_dir is None:
            raise ValueError("resume() needs a snapshot_dir (argument or "
                             "constructor)")
        template = {"kv": self._init_kv_state(), "rng": jax.random.PRNGKey(0)}
        device_tree, host, at = snapshot_mod.load_snapshot(
            snap_dir, template, step)
        snapshot_mod.check_fingerprint(self, host)
        self._resume = (device_tree, host, at)
        return at

    def _init_kv_state(self):
        """Fresh device KV state for the engine's mode (the run loops and
        the snapshot restore template build it the same way)."""
        if self.kv_mode == "paged":
            return paged_mod.init_store(self.cfg, self.num_pages,
                                        self.page_size,
                                        kv_dtype=self.kv_dtype)
        return init_pool(self.cfg, self.max_slots, self.max_len)

    def _acquire_store(self):
        """Device page store for one paged run.  The store outlives a
        single ``run()`` call: published prefix records alias page
        payloads, so the run loops stash their final store back on the
        engine at clean exit and the next run picks it up here.  Stale
        entries in re-allocated pages are harmless — the attention kernel
        masks by chain fill exactly as it does for within-run page reuse.

        Ownership is taken eagerly (the stash is cleared before the run
        starts): if the run dies mid-flight the store may have been
        donated away, so the next run starts from a fresh zeroed pool —
        and must flush the prefix cache, whose records would otherwise
        alias blank pages."""
        store = self._store
        self._store = None
        if store is not None:
            return store
        if self.prefix is not None:
            for slot in list(self._warm_pending):
                self._abort_warm(slot)
            self.prefix.clear()
        store = paged_mod.init_store(self.cfg, self.num_pages,
                                     self.page_size,
                                     kv_dtype=self.kv_dtype)
        if self.policy is not None:
            # head-sharded page pools, replicated entry metadata — the
            # host-side PageAllocator stays global (see cache_specs)
            store = jax.device_put(store, self._store_sh)
        return store

    # -- paged-mode memory policy -------------------------------------------
    def _worst_case_entries(self, req: Request) -> int:
        """Upper bound on one request's lifetime entry count: every stored
        token fresh at every attention layer (the last generated token is
        emitted but never fed, so it stores nothing)."""
        toks = min(self.max_len, req.prompt_len + req.max_new_tokens - 1)
        return toks * self.n_attn

    def _can_place(self, req: Request) -> bool:
        """Admission gate: enough *free pages* for the prompt's worst-case
        entries plus one decode step of headroom.  The run loop reserves
        every resident's next-step headroom *before* admission, so the
        free list seen here is what is genuinely spare — a newcomer is
        never admitted into pages the residents are about to need (which
        would just get it preempted back, throwing its prefill away).
        (Admission allocates only the measured entries afterwards, so this
        never over-commits.)"""
        need = req.prompt_len * self.n_attn + self.n_attn
        pages = self.allocator.pages_for(need)
        if pages > self.allocator.pages_per_slot:
            return False
        # prefix records hold pages too: evict LRU records (never pinned
        # ones) before declaring the pool full — cached history must not
        # starve admission
        while pages > self.allocator.free_pages and self._reclaim_pages():
            pass
        return pages <= self.allocator.free_pages

    def _reclaim_pages(self) -> bool:
        """Page-pressure valve: drop one LRU prefix record.  Returns True
        when a record was evicted (its unshared pages returned to the
        free list) — callers loop until the reservation fits or this
        returns False, *then* fall back to preempting residents."""
        return (self.prefix is not None
                and self.prefix.evict_one() is not None)

    # -- prefix sharing (docs/kvcache.md) ----------------------------------
    def _prefix_probe(self, req: Request, slot: int) -> int:
        """Scheduler admission hook (``kv.prefix_cache``): find the
        longest published prefix of ``req``'s prompt and alias its pages
        into ``slot`` — full shared pages by reference (refcount bump, no
        copy), the partial boundary page queued for a device-side COW
        copy at the first suffix chunk (the probe runs inside
        ``plan_step`` with no store handle in scope; deferring is safe
        because nothing reads the slot's pages before that chunk).  The
        cold *suffix*'s worst-case pages are reserved here too, keeping
        the reservation inside the same plan_step that passed
        ``_can_place`` — the invariant the cold path maintains.  Returns
        the number of prompt tokens covered (0 = cold admission)."""
        rec = self.prefix.lookup(req.tokens)
        if rec is None:
            if self.metrics is not None:
                self.metrics.inc("prefix_misses_total")
            return 0
        alloc, nA = self.allocator, self.n_attn
        n_full, rem = divmod(rec.entries, alloc.page_size)
        worst = rec.entries + (req.prompt_len - rec.length) * nA + nA
        alloc.alias_into(slot, rec.pages[:n_full])
        if not alloc.ensure(slot, worst):
            # cannot happen after _can_place's full-prompt worst-case
            # check (worst - aliased <= full worst case), but fall back to
            # a cold admission rather than crash on an allocator surprise
            alloc.release(slot)
            return 0
        alloc.seed_fill(slot, rec.entries)
        self.prefix.pin(rec)
        copy = None
        if rem:
            copy = (int(rec.pages[n_full]),
                    int(alloc.block_table[slot, n_full]), rem)
        self._warm_pending[slot] = _WarmAdmission(rec=rec, copy=copy)
        return rec.length

    def _abort_warm(self, slot: int) -> None:
        """Drop the warm-admission state of an aborted in-flight prefill.
        The caller's ``allocator.release`` already dropped the chain's
        page references (shared pages just lose one refcount); this
        unpins the record so it is evictable again."""
        if self.prefix is None:
            return
        warm = self._warm_pending.pop(slot, None)
        if warm is not None:
            self.prefix.unpin(warm.rec)

    # -- main loop ---------------------------------------------------------
    def run(self, rng: Optional[jax.Array] = None
            ) -> Dict[str, object]:
        """Drain the queue.  Returns {'results': {uid: RequestResult},
        'stats': ServeStats, 'metrics': MetricsRegistry} (stats is a
        derived view over the registry; the registry adds histograms,
        gauges and per-layer/per-step series — see docs/observability.md).
        Under a mesh the sharding policy is active
        for the whole run, so every jitted step traces with the serve-mode
        activation/KV hints baked in (routing gates and the Σy² carry stay
        replicated; KV is head-sharded).

        Reimplemented on the streaming driver: the run loops are
        generators yielding once per engine iteration (the granularity
        ``RequestHandle.tokens`` observes), and ``run()`` simply pumps
        the shared driver to exhaustion — token output and metrics are
        identical to the pre-streaming blocking loops.  A partially
        consumed ``tokens()`` iteration resumes here: one driver serves
        both surfaces."""
        if self._driver is None:
            self._driver_rng = rng
        while self._pump():
            pass
        out, self._driver_out = self._driver_out, None
        return out

    def _make_driver(self, rng):
        """One generator wrapping the mode dispatch; ``yield`` marks
        engine-iteration boundaries, ``return`` carries the run dict."""
        with set_policy(self.policy):
            if self.kv_mode == "paged":
                if self.spec_k:
                    return (yield from self._run_paged_spec(rng))
                if self.decode_steps > 1:
                    return (yield from self._run_paged_fused(rng))
                return (yield from self._run_paged(rng))
            if self.spec_k:
                return (yield from self._run_dense_spec(rng))
            if self.decode_steps > 1:
                return (yield from self._run_dense_fused(rng))
            return (yield from self._run_dense(rng))

    def _pump(self) -> bool:
        """Advance the shared driver one engine iteration.  Returns False
        when the run completed (the result dict lands in
        ``self._driver_out``).  Engine errors tear the driver down before
        re-raising, so a subsequent ``run()`` starts fresh."""
        if self._driver is None:
            self._driver = self._make_driver(self._driver_rng)
        try:
            next(self._driver)
            return True
        except StopIteration as e:
            self._driver = None
            self._driver_rng = None
            self._driver_out = e.value
            return False
        except BaseException:
            self._driver = None
            self._driver_rng = None
            raise

    # -- streaming emission (docs/serving.md) ------------------------------
    def _emit_stream(self, uid: int, out_tokens: List[int],
                     step: int) -> None:
        """Append tokens past the uid's high-water mark to its stream
        buffer.  The watermark survives preemption (out_tokens resets,
        the mark does not), so every emitted index streams exactly once —
        at temperature 0 a preempted request re-derives the identical
        prefix; at temperature > 0 re-decoded tokens may diverge from
        what was already streamed (documented caveat)."""
        w = self._stream_pos.get(uid, 0)
        if len(out_tokens) > w:
            buf = self._streams.setdefault(uid, [])
            buf.extend((int(t), step) for t in out_tokens[w:])
            self._stream_pos[uid] = len(out_tokens)

    def _drain_stream(self, rs: _RunState) -> None:
        """Per-iteration emission sweep over the resident slots.  Slots
        with an unresolved deferred first token (fused mode's
        ``rs.pending``) are skipped — their out_tokens[0] is still the
        placeholder; the post-epoch resolve backfills it and the next
        sweep emits."""
        for slot, st in self.scheduler.active.items():
            if slot not in rs.pending:
                self._emit_stream(st.req.uid, st.out_tokens, rs.step_idx)

    def _record_result(self, rs: _RunState, res: "RequestResult") -> None:
        """Single choke point for finished requests: the run dict and the
        streaming surface see the same RequestResult."""
        rs.results[res.uid] = res
        self._stream_results[res.uid] = res
        self._stream_done.add(res.uid)

    def _stream_tokens(self, uid: int):
        """Yield ``(token, step)`` for ``uid``, pumping the engine when
        the buffer runs dry.  Ends when the request has a final result
        (or the engine drains without it ever being placeable)."""
        buf = self._streams.setdefault(uid, [])
        sent = 0
        while True:
            while sent < len(buf):
                yield buf[sent]
                sent += 1
            if uid in self._stream_done:
                return
            if not self._pump() and sent >= len(buf) \
                    and uid not in self._stream_done:
                return

    # -- observability plumbing (shared by all four run loops) -------------
    def _new_run_state(self, rng: Optional[jax.Array],
                       paged: bool) -> _RunState:
        """Fresh per-run state: the stats shell, the metrics registry
        (this run's source of truth — ``_finalize`` derives ServeStats
        from it), request-lifecycle span openings for everything already
        queued, and the compile-probe baseline."""
        if paged:
            stats = ServeStats(kv_mode="paged", page_size=self.page_size,
                               pages_total=self.num_pages)
            hist = history_mod.HistoryAccounting(
                self.n_attn, self.max_slots,
                paged_mod.reuse_enabled(self.cfg))
        else:
            stats, hist = ServeStats(), None
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        rs = _RunState(stats=stats, results={}, t_run=perf_counter(),
                       rng=rng, hist=hist)
        rs.compiled_seen = jit_cache_size(self._jitted)
        self.metrics = rs.metrics
        # credit submit-time load sheds to this run's registry (each one
        # already emitted its "shed" trace instant at submit)
        for _ in self._shed_pending:
            rs.metrics.inc("requests_shed_total")
        self._shed_pending.clear()
        tr = self.tracer
        for req in self.scheduler.queue:
            rs.traced.add(req.uid)
            tid = request_tid(req.uid)
            tr.track(tid, f"req {req.uid}")
            tr.begin("request", tid)
            tr.begin("queued", tid)
        return rs

    def _step_gauges(self, rs: _RunState) -> None:
        """Per-iteration scheduler/memory gauges + the trace counter row."""
        sched, m = self.scheduler, rs.metrics
        m.set("queue_depth", len(sched.queue))
        m.set("resident_slots", len(sched.active))
        vals = {"queue": len(sched.queue), "resident": len(sched.active)}
        if self.kv_mode == "paged":
            free = self.allocator.free_pages
            m.set("free_pages", free)
            m.set("pages_in_use", self.num_pages - free)
            vals["free_pages"] = free
        self.tracer.counter("sched", vals)

    def _note_admission(self, rs: _RunState) -> None:
        """Call right after ``plan_step``: if the FIFO head was just
        popped into a slot, close its queued span, open its prefill-phase
        span and observe its queue wait."""
        pf = self.scheduler.prefilling
        if pf is None or pf.req.uid in rs.admitted:
            return
        rs.admitted.add(pf.req.uid)
        if pf.req.submit_s:
            rs.metrics.observe("queue_wait_seconds",
                               perf_counter() - pf.req.submit_s)
        tid = request_tid(pf.req.uid)
        tr = self.tracer
        tr.end(tid)                       # queued
        tr.instant("admit", tid, slot=pf.slot)
        tr.begin("prefill", tid)

    def _poll_compiles(self, rs: _RunState) -> None:
        """Surface jit-cache growth (new prefill buckets, pow2 epoch
        lengths, block-table widths) as a counter + trace instants, so
        recompiles are attributable to the iteration that caused them."""
        n = jit_cache_size(self._jitted)
        if n > rs.compiled_seen:
            rs.metrics.inc("compiles_total", n - rs.compiled_seen)
            self.tracer.instant("compile", n_new=n - rs.compiled_seen)
            rs.compiled_seen = n

    def _record_step_series(self, rs: _RunState, lay_keep) -> None:
        """Per-step telemetry time series: per-layer attention-gate keep
        rate (``attn_keep_rate{layer=i}``) and the running measured
        KV-saved fraction, both indexed by cumulative decode step."""
        m = rs.metrics
        if lay_keep is not None:
            for i, v in enumerate(lay_keep):
                m.record("attn_keep_rate", rs.step_idx, float(v), layer=i)
        dense = m.value("kv_entries_dense_measured_total")
        if dense:
            m.record("kv_saved_fraction", rs.step_idx,
                     1.0 - m.value("kv_entries_stored_measured_total")
                     / dense)

    # -- robustness: boundary pass, fault seams, watchdog ------------------
    def _boundary(self, rs: _RunState, kv_state) -> None:
        """Step/epoch-boundary pass shared by all four run loops, run
        before each iteration's dispatch: (1) the request-lifecycle sweep
        (cooperative cancellation + deadline expiry — resources release
        within one step/epoch of the event); (2) a crash-consistent
        snapshot when due; (3) the injected host kill, which fires
        *after* the boundary snapshot so a resume loses nothing."""
        self._lifecycle(rs)
        self._maybe_snapshot(rs, kv_state)
        f = self.faults.take("kill", rs.disp_idx)
        if f is not None:
            rs.metrics.inc("faults_injected_total")
            self.tracer.instant("fault", kind="kill", step=rs.disp_idx)
            raise SimulatedKill(
                f"injected host kill at boundary {rs.disp_idx}: {f.message}",
                trace_path=self._flush_trace())

    def _expired(self, req: Request, now: float) -> Optional[str]:
        """The request's lifecycle verdict at ``now``: "cancelled",
        "deadline", or None (keep going)."""
        if req.uid in self._cancelled:
            return "cancelled"
        if (req.deadline_s is not None and req.submit_s
                and now - req.submit_s > req.deadline_s):
            return "deadline"
        return None

    def _lifecycle(self, rs: _RunState) -> None:
        """Sweep every request the engine holds — queued, mid-prefill,
        resident — for cancellation / deadline expiry and retire the hits
        (slot + pages released, typed finish reason, trace span closed)."""
        sched = self.scheduler
        now = perf_counter()
        for req in list(sched.queue):
            reason = self._expired(req, now)
            if reason is not None:
                sched.remove_queued(req.uid)
                self.tracer.end(request_tid(req.uid))    # queued span
                self._finish_unplaced(rs, req, reason)
        pf = sched.prefilling
        if pf is not None:
            reason = self._expired(pf.req, now)
            if reason is not None:
                sched.abort_prefill(requeue=False)
                if self.kv_mode == "paged":
                    self.allocator.release(pf.slot)
                    self._abort_warm(pf.slot)
                rs.stage_cache = None
                rs.stage_gates = []
                rs.admitted.discard(pf.req.uid)
                self.tracer.end(request_tid(pf.req.uid))  # prefill span
                self._finish_unplaced(rs, pf.req, reason)
        for slot in sorted(sched.active):
            st = sched.active[slot]
            reason = self._expired(st.req, now)
            if reason is not None:
                tok_dev = rs.pending.pop(slot, None)
                if tok_dev is not None:
                    # materialize the deferred first token so the partial
                    # result carries the real value, not the placeholder
                    tok = int(np.asarray(tok_dev)[0])
                    st.out_tokens[0] = tok
                    st.next_token = tok
                self._finish(rs, slot, reason)

    def _finish_unplaced(self, rs: _RunState, req: Request,
                         reason: str) -> None:
        """Retire a request that never (or no longer) holds a slot —
        removed from the queue or aborted mid-prefill — with an empty
        token result and a typed reason."""
        self._cancelled.discard(req.uid)
        self._record_result(rs, RequestResult(
            uid=req.uid, tokens=np.zeros((0,), np.int32),
            prompt_len=req.prompt_len, ttft_s=0.0, decode_s=0.0,
            finish_reason=reason))
        self._count_lifecycle(rs, reason)
        tid = request_tid(req.uid)
        self.tracer.instant("finish", tid, reason=reason, tokens=0)
        if req.uid in rs.traced:
            self.tracer.end(tid)          # close the request root span

    def _count_lifecycle(self, rs: _RunState, reason: str) -> None:
        if reason == "cancelled":
            rs.metrics.inc("requests_cancelled_total")
        elif reason == "deadline":
            rs.metrics.inc("deadline_exceeded_total")
        elif reason == "preempt_budget":
            rs.metrics.inc("preempt_budget_exhausted_total")

    def _maybe_snapshot(self, rs: _RunState, kv_state) -> None:
        """Publish a crash-consistent snapshot when one is due and the
        boundary is quiescent (no prefill in flight, no deferred first
        tokens, no staging cache) — at such a boundary host structures +
        device KV are the complete engine state (serve/snapshot.py)."""
        if self.snapshot_dir is None or kv_state is None:
            return
        if rs.disp_idx - max(rs.last_snap, 0) < self.snapshot_every \
                or rs.disp_idx == 0:
            return
        if (self.scheduler.prefilling is not None or rs.pending
                or rs.stage_cache is not None):
            return
        with self.tracer.span("snapshot", step=rs.disp_idx):
            host = snapshot_mod.encode_host_state(self, rs)
            snapshot_mod.save_snapshot(
                self.snapshot_dir, rs.disp_idx,
                {"kv": kv_state, "rng": rs.rng}, host)
        rs.last_snap = rs.disp_idx
        rs.metrics.inc("snapshots_total")
        self.tracer.instant("snapshot", step=rs.disp_idx)

    def _apply_resume(self, rs: _RunState, kv_state):
        """Consume a pending ``resume()``: rebuild the host state, swap
        in the restored device KV (re-placed under the engine's
        shardings when meshed), and reopen trace spans for the restored
        requests.  Returns the KV state the run loop should use."""
        if self._resume is None:
            return kv_state
        device_tree, host, at = self._resume
        self._resume = None
        snapshot_mod.apply_host_state(self, rs, host)
        rs.last_snap = at
        rs.rng = device_tree["rng"]
        kv = device_tree["kv"]
        if self.policy is not None:
            sh = (self._store_sh if self.kv_mode == "paged"
                  else self._pool_sh)
            kv = jax.device_put(kv, sh)
        tr = self.tracer
        for req in self.scheduler.queue:
            if req.uid not in rs.traced:
                rs.traced.add(req.uid)
                tid = request_tid(req.uid)
                tr.track(tid, f"req {req.uid}")
                tr.begin("request", tid)
                tr.begin("queued", tid)
        for st in self.scheduler.active.values():
            uid = st.req.uid
            rs.traced.add(uid)
            rs.admitted.add(uid)
            tid = request_tid(uid)
            tr.track(tid, f"req {uid}")
            tr.begin("request", tid)
        rs.metrics.inc("resumes_total")
        tr.instant("resume", step=at)
        return kv

    def _fault_dispatch(self, rs: _RunState) -> None:
        """Dispatch-seam fault: raise the scheduled ``FaultInjected``
        *before* the jitted call (donated buffers untouched) — the run
        loop's retry path abandons the iteration and re-plans."""
        f = self.faults.take("dispatch_error", rs.disp_idx)
        if f is not None:
            rs.metrics.inc("faults_injected_total")
            self.tracer.instant("fault", kind="dispatch_error",
                                step=rs.disp_idx)
            raise FaultInjected(f.message)

    def _fault_stall(self, rs: _RunState) -> None:
        """Sync-seam fault: sleep inside the sync span, emulating a hung
        device dispatch the watchdog then observes."""
        f = self.faults.take("stall", rs.disp_idx)
        if f is not None:
            rs.metrics.inc("faults_injected_total")
            self.tracer.instant("fault", kind="stall", step=rs.disp_idx,
                                stall_s=f.stall_s)
            sleep_stall(f.stall_s)

    def _fault_oom(self, rs: _RunState) -> List[int]:
        """Headroom-seam fault (paged): hide free pages for this
        iteration so reservations fail exactly as if residents had
        filled the pool; the run loop returns them via
        ``allocator.unhide_pages`` before admission."""
        f = self.faults.take("oom", rs.disp_idx)
        if f is None:
            return []
        hidden = self.allocator.hide_pages(f.pages)
        rs.metrics.inc("faults_injected_total")
        self.tracer.instant("fault", kind="oom", step=rs.disp_idx,
                            pages=len(hidden))
        return hidden

    def _watch(self, rs: _RunState, phase: str, seconds: float) -> None:
        """Feed one dispatch+sync wall time to the watchdog; a straggler
        strike is counted and traced, a hard-timeout breach flushes the
        trace and re-raises ``HungDispatch`` with its path attached."""
        wd = self.watchdog
        if wd is None:
            return
        try:
            if wd.observe(phase, seconds):
                rs.metrics.inc("watchdog_strikes_total")
                self.tracer.instant("watchdog", phase=phase,
                                    elapsed_s=round(seconds, 6),
                                    strikes=wd.strikes)
        except HungDispatch as e:
            rs.metrics.inc("watchdog_timeouts_total")
            self.tracer.instant("watchdog", phase=phase,
                                elapsed_s=round(seconds, 6), timeout=True)
            e.trace_path = self._flush_trace()
            raise

    def _flush_trace(self) -> Optional[str]:
        """Best-effort trace flush on the abort path (open spans and all)
        so the failure is diagnosable post-mortem; returns the path."""
        tr = self.tracer
        if tr.enabled and tr.path is not None:
            tr.save()
            return str(tr.path)
        return None

    # -- run-loop bookkeeping shared by both KV modes ----------------------
    @staticmethod
    def _make_result(st: ActiveRequest, reason: str) -> RequestResult:
        st.finish_reason = reason
        return RequestResult(
            uid=st.req.uid,
            tokens=np.asarray(st.out_tokens, np.int32),
            prompt_len=st.req.prompt_len,
            ttft_s=st.first_token_s - st.submit_s,
            decode_s=st.decode_s,
            finish_reason=reason,
            kv_stored=st.kv_stored,
            kv_dense=st.kv_dense,
            max_decode_stall_s=st.max_stall_s,
        )

    def _account_prefill(self, rs: _RunState, st: ActiveRequest) -> None:
        """Fold the prompt-phase gate log into the request's measured
        KV-storage accounting (layer-0 dense + executed layers — the same
        counting ``paged.prefill_entry_count`` uses for the entry stream).
        Resolved at finish time: the gate log may still be a device array
        from the prefill dispatch, and by now it is long since computed,
        so the conversion is a copy, not a pipeline stall."""
        if st.pf_gates is None:
            return
        T0 = st.req.prompt_len
        L = max(len(self.cfg.attention_layers), 1)
        measure = self.cfg.skip.enabled and self.cfg.skip.kv_reuse
        if measure:
            g = np.asarray(st.pf_gates, np.float32)[:, :T0]
            stored = T0 + int((g[1:] > 0.5).sum())
        else:
            stored = L * T0
        st.kv_dense += L * T0
        st.kv_stored += stored
        rs.metrics.inc("kv_entries_dense_measured_total", L * T0)
        rs.metrics.inc("kv_entries_stored_measured_total", stored)
        st.pf_gates = None

    def _finish(self, rs: _RunState, slot: int, reason: str) -> None:
        """Evict ``slot``'s request and record its result (paged mode also
        returns its pages and clears its history accounting)."""
        st = self.scheduler.release(slot)
        self._account_prefill(rs, st)
        if self.kv_mode == "paged":
            self.allocator.release(slot)
            rs.hist.on_release(slot)
        self._emit_stream(st.req.uid, st.out_tokens, rs.step_idx)
        res = self._make_result(st, reason)
        self._record_result(rs, res)
        self._cancelled.discard(st.req.uid)
        self._count_lifecycle(rs, reason)
        m = rs.metrics
        m.inc("requests_completed_total")
        m.observe("ttft_seconds", res.ttft_s)
        n = res.decode_tokens - 1
        if n > 0 and res.decode_s > 0:
            m.observe("tpot_seconds", res.decode_s / n)
        tid = request_tid(st.req.uid)
        self.tracer.instant("finish", tid, reason=reason,
                            tokens=res.decode_tokens)
        self.tracer.end(tid)              # close the request root span

    def _preempt_youngest(self, rs: _RunState, exclude: int) -> bool:
        """OOM backpressure (paged mode): evict the *youngest* request —
        by original ``submit_s``, which requeueing preserves — (≠
        ``exclude``) and put it back into the queue at its age-ordered
        position; its pages return to the free list and it will
        re-prefill from scratch when memory frees up.  An in-flight
        chunked prefill is always the newest admission and holds its
        worst-case reservation without yet being a resident, so it is
        aborted first (no decode progress lost; decode steps between the
        abort and the re-try keep the residents progressing, so this
        cannot livelock).

        Victim age is the request's original submission stamp, NOT its
        admission recency: under the old admission-order rule a
        re-admitted request became "newest" again and the same request
        could be re-victimized forever while genuinely younger residents
        ran to completion (the preemption-storm starvation the
        ``test_fault_tolerance.py`` fairness regression pins down).  A
        victim past the ``max_preemptions`` retry budget finishes with
        its partial tokens (reason "preempt_budget") instead of
        requeueing."""
        sched = self.scheduler
        m, tr = rs.metrics, self.tracer
        pf = sched.prefilling
        if pf is not None and pf.slot != exclude:
            sched.abort_prefill(requeue=False)
            self.allocator.release(pf.slot)
            self._abort_warm(pf.slot)
            rs.stage_cache = None
            rs.stage_gates = []
            m.inc("preemptions_total")
            rs.admitted.discard(pf.req.uid)
            pf.req.preempt_count += 1
            tid = request_tid(pf.req.uid)
            tr.end(tid)                   # abort the open prefill span
            tr.instant("preempt", tid, kind="prefill_abort",
                       count=pf.req.preempt_count)
            if self._budget_spent(pf.req):
                self._finish_unplaced(rs, pf.req, "preempt_budget")
            else:
                sched.requeue(pf.req)     # age-preserving re-admission
                tr.begin("queued", tid)
            return True
        victims = [s for s in sched.active if s != exclude]
        if not victims:
            return False
        slot = max(victims, key=lambda s: sched.active[s].req.submit_s)
        st = sched.release(slot)
        self.allocator.release(slot)
        rs.hist.on_release(slot)
        rs.pending.pop(slot, None)
        m.inc("preemptions_total")
        rs.admitted.discard(st.req.uid)
        st.req.preempt_count += 1
        tid = request_tid(st.req.uid)
        tr.instant("preempt", tid, kind="evict", slot=slot,
                   count=st.req.preempt_count)
        if self._budget_spent(st.req):
            self._account_prefill(rs, st)
            self._emit_stream(st.req.uid, st.out_tokens, rs.step_idx)
            self._record_result(rs, self._make_result(st, "preempt_budget"))
            self._cancelled.discard(st.req.uid)
            self._count_lifecycle(rs, "preempt_budget")
            tr.instant("finish", tid, reason="preempt_budget",
                       tokens=len(st.out_tokens))
            tr.end(tid)                   # close the request root span
        else:
            sched.requeue(st.req)         # age-preserving re-admission
            tr.begin("queued", tid)
        return True

    def _budget_spent(self, req: Request) -> bool:
        return (self.max_preemptions is not None
                and req.preempt_count > self.max_preemptions)

    def _activate_prefilled(self, rs: _RunState, req: Request, slot: int,
                            tok: int, now: float, tok_known: bool = True):
        """Register a freshly prefilled request.  Returns (state, reason):
        reason is "stop"/"length" when the first token already ends the
        request, else None.  ``tok_known=False`` (fused mode): ``tok`` is
        a placeholder — the real value is still a device array, the stop
        check happens on device at the next epoch's loop entry, and the
        host backfills the bookkeeping at the epoch sync."""
        rs.metrics.inc("prefill_tokens_total", req.prompt_len)
        rs.metrics.inc("decode_tokens_total")
        st = ActiveRequest(req=req, slot=slot, pos=req.prompt_len,
                           next_token=tok, out_tokens=[tok],
                           submit_s=rs.t_run, first_token_s=now,
                           last_emit_s=now)
        self.scheduler.activate(st)
        if tok_known and req.stop_token is not None \
                and tok == req.stop_token:
            return st, "stop"
        if req.max_new_tokens <= 1:
            return st, "length"
        return st, None

    def _advance_slot(self, rs: _RunState, st: ActiveRequest, tok: int,
                      g: Optional[np.ndarray], step_s: float,
                      measure: bool, n_layers: int) -> Optional[str]:
        """Post-decode bookkeeping for one resident (the fed token's KV
        was just written at st.pos).  Returns the finish reason or None."""
        m = rs.metrics
        st.decode_s += step_s
        now = perf_counter()
        if st.last_emit_s:
            gap = now - st.last_emit_s
            st.max_stall_s = max(st.max_stall_s, gap)
            m.observe("decode_stall_seconds", gap)
        st.last_emit_s = now
        if g is not None:
            stored = (1 + int(g[1:].sum()) if measure else n_layers)
            st.kv_dense += n_layers
            st.kv_stored += stored
            m.inc("kv_entries_dense_measured_total", n_layers)
            m.inc("kv_entries_stored_measured_total", stored)
        st.pos += 1
        st.out_tokens.append(tok)
        st.next_token = tok
        m.inc("decode_tokens_total")
        if st.req.stop_token is not None and tok == st.req.stop_token:
            return "stop"
        if len(st.out_tokens) >= st.req.max_new_tokens:
            return "length"
        if st.pos >= self.max_len:
            return "max_len"
        return None

    # -- prefill work units (monolithic or one chunk) ----------------------
    def _chunk_forward(self, rs: _RunState, work: PrefillChunk,
                       width: Optional[int] = None):
        """Run one staged prefill chunk.  Returns the chunk logits (valid
        only on the last chunk).  The gate log is accumulated as device
        arrays — paged packing consumes it at completion, and the dense
        path folds it into the measured KV-storage accounting at finish
        time; either way, never a per-chunk host sync.

        ``width`` overrides the dispatch width (warm-prefix suffix chunks
        in monolithic mode, where ``prefill_chunk == 0`` and the suffix
        runs through ``_warm_chunk_step`` at a pow2-padded width).  A warm
        admission pre-seeds ``rs.stage_cache`` from the shared pages, so
        the first-chunk init is guarded on it being absent."""
        C = self.prefill_chunk if width is None else width
        step = self._chunk_step if width is None else self._warm_chunk_step
        if work.is_first and rs.stage_cache is None:
            rs.stage_cache = model_lib.init_chunk_cache(
                self.cfg, 1, self._chunk_cap)
            if self.policy is not None:
                # place the fresh staging rows under their head-sharded
                # NamedShardings up front (donation then stays in place)
                rs.stage_cache = jax.device_put(rs.stage_cache,
                                                self._chunk_sh)
            rs.stage_gates = []
        c = len(work.tokens)
        padded = np.pad(work.tokens, (0, C - c))
        logits, rs.stage_cache, cstats = step(
            self.params, rs.stage_cache,
            {"tokens": jnp.asarray(padded[None])},
            jnp.int32(work.start),
            jnp.asarray([c - 1], jnp.int32))
        if "attn_gate" in cstats:
            rs.stage_gates.append(cstats["attn_gate"])
        return logits

    def _finish_prefill(self, rs: _RunState, work: PrefillChunk, tok_dev,
                        t0: float, pf_gates=None) -> None:
        """Activate a request whose prefill — first-token sampling folded
        into the prefill dispatch itself — just completed.  Single-step
        mode syncs the token here (this is the only host sync on the
        completion path; the per-token eager ``sample`` is gone).  Fused
        dense mode (``decode_steps > 1``) defers even that: the token
        stays a device array in ``rs.pending``, the next epoch's decode
        loop overlays it into the feed carry (with the stop check running
        on device at loop entry), and ``_resolve_pending`` backfills the
        host bookkeeping at the epoch sync.  ``pf_gates`` is the prompt's
        execution-gate log ([L, Tp], device or host), folded into the
        measured KV accounting at finish time by ``_account_prefill``."""
        defer = (self.decode_steps > 1 and self.kv_mode == "dense"
                 and work.req.max_new_tokens > 1)
        m = rs.metrics
        if defer:
            tok = 0                       # placeholder; device holds truth
        else:
            ts = perf_counter()
            tok = int(np.asarray(tok_dev)[0])
            m.inc("device_seconds_total", perf_counter() - ts)
        now = perf_counter()
        m.inc("prefill_chunks_total")
        m.inc("prefill_seconds_total", now - t0)
        self.scheduler.prefill_advance(work)
        st, reason = self._activate_prefilled(rs, work.req, work.slot, tok,
                                              now, tok_known=not defer)
        st.pf_gates = pf_gates
        self.tracer.end(request_tid(work.req.uid))    # prefill phase span
        if defer:
            rs.pending[work.slot] = tok_dev
        elif reason:
            self._finish(rs, work.slot, reason)

    def _resolve_pending(self, rs: _RunState) -> None:
        """Backfill host bookkeeping for first tokens deferred as device
        arrays by fused-mode ``_finish_prefill``.  Called at an epoch
        sync — the values are long since computed, so the conversion is
        a copy, not a stall.  A deferred first token that IS the stop
        token was entry-killed on device (the slot sat out the epoch, KV
        frozen), so finishing it here exactly mirrors the single-step
        engine's completion-time stop check."""
        for slot in list(rs.pending):
            tok_dev = rs.pending.pop(slot)
            st = self.scheduler.active.get(slot)
            if st is None or st.slot != slot:
                continue                  # stale (slot preempted/reused)
            tok = int(np.asarray(tok_dev)[0])
            st.out_tokens[0] = tok
            st.next_token = tok
            if (st.req.stop_token is not None and tok == st.req.stop_token
                    and len(st.out_tokens) == 1):
                self._finish(rs, slot, "stop")

    def _prefill_work_dense(self, rs: _RunState, work: PrefillChunk, pool):
        """Execute one dense-pool prefill work unit: either a legacy
        monolithic (bucketed) prefill + pool insert, or one staging-cache
        chunk (inserted into the pool on the last chunk)."""
        t0 = perf_counter()
        tr = self.tracer
        tid = request_tid(work.req.uid)
        if not self.prefill_chunk:
            with tr.span("prefill[0]", tid, tokens=work.req.prompt_len), \
                    tr.annotate("prefill"):
                padded, last = self.scheduler.pad_prompt(work.req.tokens)
                rs.rng, sub = jax.random.split(rs.rng)
                tok_dev, cache, pstats = self._prefill(
                    self.params, {"tokens": jnp.asarray(padded[None])},
                    jnp.asarray([last], jnp.int32), sub)
                pool = self._insert(pool, cache, jnp.int32(work.slot))
            pf_gates = pstats.get("attn_gate")
            if pf_gates is not None:
                pf_gates = pf_gates[:, 0]                         # [L, Tp]
        else:
            idx = work.start // self.prefill_chunk
            with tr.span(f"prefill[{idx}]", tid, tokens=len(work.tokens)), \
                    tr.annotate("prefill_chunk"):
                logits = self._chunk_forward(rs, work)
            if not work.is_last:
                # no sync: the chunk's compute overlaps the decode step
                # dispatched right after it (async dispatch stream), so
                # prefill time here attributes host-side dispatch only
                rs.metrics.inc("prefill_chunks_total")
                rs.metrics.inc("prefill_seconds_total", perf_counter() - t0)
                self.scheduler.prefill_advance(work)
                return pool
            pool = self._insert_staged(pool, rs.stage_cache,
                                       jnp.int32(work.slot))
            rs.stage_cache = None
            rs.rng, sub = jax.random.split(rs.rng)
            tok_dev = self._sample_tok(logits, sub)
            pf_gates = (jnp.concatenate(rs.stage_gates, axis=2)[:, 0]
                        if rs.stage_gates else None)
            rs.stage_gates = []
        self._finish_prefill(rs, work, tok_dev, t0, pf_gates)
        return pool

    def _prefill_work_paged(self, rs: _RunState, work: PrefillChunk, store):
        """Execute one paged prefill work unit: prefill (monolithic or one
        chunk), then pack the measured compact entries page-granular
        through the ``PageAllocator`` once the prompt completes.  Chunked
        mode reserves the prompt's worst-case pages at the first chunk —
        chunk steps span engine iterations whose resident decode appends
        also draw from the free list, so the completion-time pack must
        never find the admission-time pages gone."""
        cfg, alloc, nA = self.cfg, self.allocator, self.n_attn
        reuse = paged_mod.reuse_enabled(cfg)
        req, slot = work.req, work.slot
        if self.prefix is not None and slot in self._warm_pending:
            return self._prefill_work_warm(rs, work, store)
        t0 = perf_counter()
        tr = self.tracer
        tid = request_tid(req.uid)
        if not self.prefill_chunk:
            T0 = req.prompt_len
            with tr.span("prefill[0]", tid, tokens=T0), \
                    tr.annotate("prefill_paged"):
                padded, last = self.scheduler.pad_prompt(req.tokens)
                rs.rng, sub = jax.random.split(rs.rng)
                tok_dev, cache, pstats = self._prefill_paged(
                    self.params, {"tokens": jnp.asarray(padded[None])},
                    jnp.asarray([last], jnp.int32), sub)
            gates = np.asarray(pstats["attn_gate"], np.float32)[:, 0]
        else:
            # worst-case pages were reserved at admission time in
            # _run_paged (the reservation must not trail the _can_place
            # check across iterations)
            idx = work.start // self.prefill_chunk
            with tr.span(f"prefill[{idx}]", tid, tokens=len(work.tokens)), \
                    tr.annotate("prefill_chunk"):
                logits = self._chunk_forward(rs, work)
            if not work.is_last:
                # no sync: chunk compute overlaps this iteration's decode
                # step (see _prefill_work_dense)
                rs.metrics.inc("prefill_chunks_total")
                rs.metrics.inc("prefill_seconds_total", perf_counter() - t0)
                self.scheduler.prefill_advance(work)
                return store
            T0 = req.prompt_len
            cache = rs.stage_cache
            gates = np.concatenate(
                [np.asarray(g, np.float32) for g in rs.stage_gates],
                axis=2)[:, 0]                                     # [nA, Tp]
            rs.stage_cache = None
            rs.stage_gates = []
            rs.rng, sub = jax.random.split(rs.rng)
            tok_dev = self._sample_tok(logits, sub)
        n_ent = paged_mod.prefill_entry_count(gates, T0, reuse)
        if not alloc.ensure(slot, n_ent + nA):
            raise PageExhausted(
                "page reservation failed after a successful _can_place "
                "worst-case check — allocator bug", slot=slot,
                free_pages=alloc.free_pages, pages_total=self.num_pages)
        store = self._pack(store, cache, jnp.asarray(gates), jnp.int32(T0),
                           jnp.asarray(alloc.block_table[slot]),
                           jnp.int32(0), jnp.int32(0))
        alloc.append(slot, n_ent, nA * T0)
        rs.hist.on_prefill(slot, gates, T0)
        if self.prefix is not None:
            self.prefix.publish(req.tokens, gates, alloc.chain(slot))
        self._finish_prefill(rs, work, tok_dev, t0, gates)
        return store

    def _prefill_work_warm(self, rs: _RunState, work: PrefillChunk, store):
        """Warm-prefix prefill work unit: the scheduler already cut the
        prompt down to the cold suffix (``work.start`` == the record's
        token length), so this path never runs forward over the shared
        prefix.  On the first suffix chunk it materialises the state the
        admission probe deferred — the COW copy of the partial boundary
        page, then a batch-1 staging cache reconstructed from the shared
        entry stream (``views_from_pages``; dequantised exactly, since
        page scales are powers of two) — and from there the ordinary
        chunk-resumable prefill machinery takes over.  Completion packs
        *only the suffix entries* (``start_token``/``start_entry`` offsets
        into ``pack_prefill``), stitches the record's gate log to the
        suffix gates so history/accounting/publish see the full-prompt
        view, and republishes the now-longer chain."""
        cfg, alloc, nA = self.cfg, self.allocator, self.n_attn
        reuse = paged_mod.reuse_enabled(cfg)
        req, slot = work.req, work.slot
        warm = self._warm_pending[slot]
        rec = warm.rec
        Ts, E_s = rec.length, rec.entries
        t0 = perf_counter()
        tr = self.tracer
        tid = request_tid(req.uid)
        m = rs.metrics
        if work.is_first:
            if warm.copy is not None:
                src, dst, keep = warm.copy
                with tr.span("cow_copy", tid, entries=keep), \
                        tr.annotate("cow_copy"):
                    store = self._cow_copy(store, jnp.int32(src),
                                           jnp.int32(dst), jnp.int32(keep))
            with tr.span("warm_restore", tid, tokens=Ts, entries=E_s), \
                    tr.annotate("warm_restore"):
                rs.stage_cache = self._warm_cache(
                    store, jnp.asarray(alloc.block_table[slot]),
                    jnp.int32(E_s))
            rs.stage_gates = []
            m.inc("prefix_hits_total")
            m.inc("prefix_tokens_saved_total", Ts)
            tr.instant("prefix_hit", tid, warm_tokens=Ts, entries=E_s)
        c = len(work.tokens)
        if self.prefill_chunk:
            width = None
            idx = (work.start - Ts) // self.prefill_chunk
        else:
            # monolithic mode: one pow2-padded suffix dispatch through the
            # max_len-capacity warm chunk step (clamped so the padded
            # write never runs past the staging cache)
            width = 1 << max(3, (c - 1).bit_length())
            if Ts + width > self._warm_cap:
                width = c
            idx = 0
        with tr.span(f"prefill[{idx}]", tid, tokens=c, warm=Ts), \
                tr.annotate("prefill_chunk"):
            logits = self._chunk_forward(rs, work, width=width)
        if not work.is_last:
            m.inc("prefill_chunks_total")
            m.inc("prefill_seconds_total", perf_counter() - t0)
            self.scheduler.prefill_advance(work)
            return store
        T0 = req.prompt_len
        cache = rs.stage_cache
        suffix_gates = np.concatenate(
            [np.asarray(g, np.float32) for g in rs.stage_gates],
            axis=2)[:, 0]                              # [nA, >= T0 - Ts]
        gates = np.concatenate(
            [np.asarray(rec.gates, np.float32), suffix_gates], axis=1)
        rs.stage_cache = None
        rs.stage_gates = []
        rs.rng, sub = jax.random.split(rs.rng)
        tok_dev = self._sample_tok(logits, sub)
        n_suffix = int(history_mod.host_fresh_mask(
            suffix_gates, reuse)[:, :T0 - Ts].sum())
        if not alloc.ensure(slot, E_s + n_suffix + nA):
            raise PageExhausted(
                "warm-suffix page reservation failed after the probe's "
                "worst-case reservation — allocator bug", slot=slot,
                free_pages=alloc.free_pages, pages_total=self.num_pages)
        store = self._pack(store, cache, jnp.asarray(gates), jnp.int32(T0),
                           jnp.asarray(alloc.block_table[slot]),
                           jnp.int32(Ts), jnp.int32(E_s))
        alloc.append(slot, n_suffix, nA * (T0 - Ts))
        rs.hist.on_prefill(slot, gates, T0)
        self.prefix.publish(req.tokens, gates, alloc.chain(slot))
        self.prefix.unpin(rec)
        del self._warm_pending[slot]
        self._finish_prefill(rs, work, tok_dev, t0, gates)
        return store

    def _run_dense(self, rng: Optional[jax.Array] = None
                   ) -> Dict[str, object]:
        """Fixed ``max_slots × max_len`` pool (the original engine mode).

        Per iteration: consume ``plan_step`` plans — with chunking off the
        prefill plans are drained first (the legacy admission order:
        every placeable queued request prefills monolithically before the
        decode step); with ``prefill_chunk > 0`` exactly one chunk runs
        per iteration, so resident decodes proceed *between* chunks —
        then one ragged decode step over every resident slot."""
        cfg = self.cfg
        sched = self.scheduler
        rs = self._new_run_state(rng, paged=False)
        m, tr = rs.metrics, self.tracer
        L_attn = max(len(cfg.attention_layers), 1)
        measure = cfg.skip.enabled and cfg.skip.kv_reuse

        pool = init_pool(cfg, self.max_slots, self.max_len)
        if self.policy is not None:
            # commit every pool row to its NamedSharding before the first
            # donated step — host-side insert/evict then always sees (and
            # scatters into) head-sharded rows
            pool = jax.device_put(pool, self._pool_sh)
        pool = self._apply_resume(rs, pool)
        feed = np.zeros((self.max_slots,), np.int32)
        pos = np.zeros((self.max_slots,), np.int32)
        t_loop = perf_counter()

        while sched.has_work():
            self._boundary(rs, pool)
            if not sched.has_work():      # lifecycle sweep drained the run
                break
            tr.begin("step", idx=rs.disp_idx)
            self._step_gauges(rs)
            # -- prefill work from the step planner ------------------------
            pre_active = bool(sched.active)
            did_prefill = False
            while True:
                with tr.span("plan"):
                    plan = sched.plan_step(token_budget=self.step_tokens)
                self._note_admission(rs)
                if plan.prefill is None:
                    break
                with tr.span("prefill"):
                    pool = self._prefill_work_dense(rs, plan.prefill, pool)
                did_prefill = True
                if self.prefill_chunk:
                    break
            if did_prefill and pre_active:
                m.inc("interleaved_steps_total")

            if not sched.active:
                self._poll_compiles(rs)
                tr.end()                  # step
                self._drain_stream(rs)
                yield
                continue

            # -- one ragged decode step over the whole pool ----------------
            for slot, st in sched.active.items():
                feed[slot] = st.next_token
                pos[slot] = st.pos
            t0 = perf_counter()
            try:
                with tr.span("dispatch"), tr.annotate("decode_step"):
                    self._fault_dispatch(rs)
                    logits, pool, dstats = self._decode(
                        self.params, pool,
                        {"tokens": jnp.asarray(feed[:, None])},
                        jnp.asarray(pos))
                    rs.rng, sub = jax.random.split(rs.rng)
                    tok_dev = sample(logits, sub, self.temperature)
            except FaultInjected:
                # raised before the jitted call: pool untouched, no token
                # lost — abandon the iteration and re-plan (the retry path
                # a real transient dispatch failure would take)
                m.inc("dispatch_retries_total")
                self._poll_compiles(rs)
                tr.end()                  # step
                self._drain_stream(rs)
                yield
                continue
            m.inc("decode_dispatches_total")
            t_sync = perf_counter()
            with tr.span("sync"):
                self._fault_stall(rs)
                toks = np.asarray(tok_dev)
                gates = (np.asarray(dstats["attn_gate"], np.float32)
                         if "attn_gate" in dstats else None)
            now = perf_counter()
            m.inc("device_seconds_total", now - t_sync)
            step_s = now - t0
            m.inc("decode_seconds_total", step_s)
            m.observe("step_seconds", step_s)
            self._watch(rs, "decode_step", step_s)

            with tr.span("bookkeep"):
                cur = list(sched.active)
                if tr.enabled:
                    t0u, t1u = tr.to_us(t0), tr.to_us(now)
                    for slot in cur:
                        tr.span_at(f"decode[{rs.disp_idx}]",
                                   request_tid(sched.active[slot].req.uid),
                                   t0u, t1u, tokens=1)
                lay = (gates[:, cur].mean(axis=1) if gates is not None
                       else None)
                for slot in cur:
                    st = sched.active[slot]
                    g = gates[:, slot] if gates is not None else None
                    if g is not None:
                        rs.keep_acc += float(g.sum())
                        rs.keep_n += L_attn
                    reason = self._advance_slot(rs, st, int(toks[slot]), g,
                                                step_s, measure, L_attn)
                    if reason:
                        self._finish(rs, slot, reason)
                self._record_step_series(rs, lay)
            rs.step_idx += 1
            rs.disp_idx += 1
            self._poll_compiles(rs)
            tr.end()                      # step
            self._drain_stream(rs)
            yield

        m.inc("host_seconds_total",
              (perf_counter() - t_loop) - m.value("device_seconds_total"))
        return self._finalize(rs)

    def _finalize(self, rs: _RunState) -> Dict[str, object]:
        """Derive the run's ServeStats from the metrics registry (the flat
        dataclass is a *view* — every counter field reads out of the
        registry, which the returned dict carries too), fold per-request
        accounting into the aggregate KV numbers, and flush the trace."""
        stats, results, m = rs.stats, rs.results, rs.metrics
        stats.prefill_tokens = int(m.value("prefill_tokens_total"))
        stats.decode_tokens = int(m.value("decode_tokens_total"))
        stats.prefill_s = m.value("prefill_seconds_total")
        stats.decode_s = m.value("decode_seconds_total")
        stats.prefill_chunks = int(m.value("prefill_chunks_total"))
        stats.interleaved_steps = int(m.value("interleaved_steps_total"))
        stats.requests_completed = int(m.value("requests_completed_total"))
        stats.decode_dispatches = int(m.value("decode_dispatches_total"))
        stats.device_s = m.value("device_seconds_total")
        stats.host_s = m.value("host_seconds_total")
        stats.preemptions = int(m.value("preemptions_total"))
        stats.compiles = int(m.value("compiles_total"))
        stats.faults_injected = int(m.value("faults_injected_total"))
        stats.dispatch_retries = int(m.value("dispatch_retries_total"))
        stats.watchdog_strikes = int(m.value("watchdog_strikes_total"))
        stats.requests_cancelled = int(m.value("requests_cancelled_total"))
        stats.deadline_exceeded = int(m.value("deadline_exceeded_total"))
        stats.requests_shed = int(m.value("requests_shed_total"))
        stats.preempt_budget_exhausted = int(
            m.value("preempt_budget_exhausted_total"))
        stats.epoch_shrinks = int(m.value("epoch_shrinks_total"))
        stats.snapshots = int(m.value("snapshots_total"))
        stats.resumes = int(m.value("resumes_total"))
        stats.spec_windows = int(m.value("spec_windows_total"))
        stats.spec_tokens_drafted = int(m.value("spec_tokens_drafted_total"))
        stats.spec_tokens_accepted = int(
            m.value("spec_tokens_accepted_total"))
        stats.spec_entries_rolled_back = int(
            m.value("spec_entries_rolled_back_total"))
        if stats.spec_tokens_drafted:
            stats.spec_acceptance_rate = (stats.spec_tokens_accepted
                                          / stats.spec_tokens_drafted)
        stats.attn_keep_frac = (rs.keep_acc / rs.keep_n if rs.keep_n
                                else 1.0)
        tot_dense = sum(r.kv_dense for r in results.values())
        tot_stored = sum(r.kv_stored for r in results.values())
        stats.kv_saved_fraction = (1.0 - tot_stored / tot_dense
                                   if tot_dense else 0.0)
        stats.kv_saved_analytic = analytic_kv_saved(self.cfg)
        if self.kv_mode == "paged":
            alloc = self.allocator
            stats.pages_peak = alloc.stats.pages_peak
            stats.kv_entries_stored = alloc.stats.entries_appended
            stats.kv_entries_dense = alloc.stats.entries_dense
            stats.history_hit_rate = rs.hist.hit_rate
            stats.history_hits_per_layer = rs.hist.per_layer_hit_rate
            m.set("pages_peak", alloc.stats.pages_peak)
            for i, h in enumerate(rs.hist.per_layer_hit_rate):
                m.set("history_hit_rate", h, layer=i)
            if self.prefix is not None:
                stats.prefix_hits = int(m.value("prefix_hits_total"))
                stats.prefix_misses = int(m.value("prefix_misses_total"))
                stats.prefix_tokens_saved = int(
                    m.value("prefix_tokens_saved_total"))
                stats.prefix_records = len(self.prefix)
                m.set("prefix_records", len(self.prefix))
        if self.tracer.enabled and self.tracer.path is not None:
            self.tracer.save()
        return {"results": results, "stats": stats, "metrics": m}

    def _run_paged(self, rng: Optional[jax.Array] = None
                   ) -> Dict[str, object]:
        """Paged-pool mode: KV lives in the store-once entry stream
        (``repro/kvcache/paged.py``) with alloc-on-demand pages.

        Per iteration: (1) *proactively* guarantee one decode step of page
        headroom for every resident slot — preempting the youngest
        resident (requeued at the head of the FIFO) if the free list runs
        dry, so the step itself can never OOM; (2) consume one
        ``plan_step`` plan — admission is gated on genuinely spare pages
        via ``_can_place``, and at most one prefill work unit (a whole
        prompt, or one chunk with ``prefill_chunk > 0``) runs per
        iteration, the cadence this loop has always had; (3) one ragged
        decode step over all slots; (4) append the measured fresh entries
        and the history-buffer hit accounting from the returned gate log.
        """
        cfg = self.cfg
        sched = self.scheduler
        alloc = self.allocator
        nA = self.n_attn
        reuse = paged_mod.reuse_enabled(cfg)
        measure = cfg.skip.enabled and cfg.skip.kv_reuse
        rs = self._new_run_state(rng, paged=True)
        m, tr = rs.metrics, self.tracer

        store = self._apply_resume(rs, self._acquire_store())
        feed = np.zeros((self.max_slots,), np.int32)
        pos = np.zeros((self.max_slots,), np.int32)
        t_loop = perf_counter()

        while sched.has_work():
            self._boundary(rs, store)
            if not sched.has_work():      # lifecycle sweep drained the run
                break
            tr.begin("step", idx=rs.disp_idx)
            self._step_gauges(rs)
            # -- proactive headroom first: every resident can absorb one
            # full step before anyone new is let in (a newcomer admitted
            # into pages the residents need would be preempted right back,
            # throwing its prefill away)
            hidden = self._fault_oom(rs)
            with tr.span("headroom"):
                for slot in sorted(sched.active):
                    if slot not in sched.active:     # preempted below
                        continue
                    while not alloc.ensure(slot,
                                           int(alloc.fill[slot]) + nA):
                        if self._reclaim_pages():
                            continue
                        if not self._preempt_youngest(rs, exclude=slot):
                            if hidden:
                                # the injected OOM drove the pool all the
                                # way down to one resident; return the
                                # hidden pages instead of dying
                                alloc.unhide_pages(hidden)
                                hidden = []
                                continue
                            raise PageExhausted(
                                f"page pool exhausted with a single "
                                f"resident request (slot {slot}) — "
                                "submit() should have rejected it",
                                slot=slot, free_pages=alloc.free_pages,
                                pages_total=self.num_pages)
            if hidden:
                alloc.unhide_pages(hidden)

            # -- prefill work from the step planner: admission gated on
            # free pages, one work unit per iteration so each _can_place
            # check sees the pages the previous admission consumed
            pre_active = bool(sched.active)
            with tr.span("plan"):
                plan = sched.plan_step(can_place=self._can_place,
                                       token_budget=self.step_tokens)
            self._note_admission(rs)
            # reserve a newly admitted prompt's worst-case pages NOW,
            # inside the same iteration as its _can_place check: chunked
            # execution and budget deferrals can postpone the first
            # prefill work past intervening resident-headroom passes,
            # which would otherwise consume the very pages the admission
            # check counted as spare (ensure() is idempotent, so a
            # deferred prompt re-running this is a no-op)
            pf = sched.prefilling
            if (pf is not None and pf.done == 0
                    and (self.prefill_chunk
                         or self.step_tokens is not None)):
                if not alloc.ensure(pf.slot,
                                    pf.req.prompt_len * nA + nA):
                    raise RuntimeError(
                        "worst-case page reservation failed in the same "
                        "iteration as a successful _can_place admission "
                        "check — allocator bug")
            if plan.prefill is not None:
                with tr.span("prefill"):
                    store = self._prefill_work_paged(rs, plan.prefill,
                                                     store)
                if pre_active:
                    m.inc("interleaved_steps_total")

            if not sched.active:
                self._poll_compiles(rs)
                tr.end()                  # step
                self._drain_stream(rs)
                yield
                continue

            # -- one ragged decode step over the whole pool ----------------
            for slot, st in sched.active.items():
                feed[slot] = st.next_token
                pos[slot] = st.pos
            # bound the stream walk to the live chains instead of the
            # worst-case block-table width; power-of-two buckets keep the
            # number of compiled decode shapes logarithmic (the same
            # recompile-bounding trick as prefill length-bucketing)
            j_live = max(1, alloc.max_chain_pages())
            j_step = min(1 << (j_live - 1).bit_length(),
                         alloc.pages_per_slot)
            t0 = perf_counter()
            try:
                with tr.span("dispatch"), tr.annotate("paged_decode_step"):
                    self._fault_dispatch(rs)
                    logits, store, dstats = self._decode_paged(
                        self.params, store,
                        {"tokens": jnp.asarray(feed[:, None])},
                        jnp.asarray(pos),
                        jnp.asarray(alloc.block_table[:, :j_step]),
                        jnp.asarray(alloc.fill))
                    rs.rng, sub = jax.random.split(rs.rng)
                    tok_dev = sample(logits, sub, self.temperature)
            except FaultInjected:
                # pre-dispatch raise: store and allocator untouched —
                # abandon the iteration and re-plan (see _run_dense)
                m.inc("dispatch_retries_total")
                self._poll_compiles(rs)
                tr.end()                  # step
                self._drain_stream(rs)
                yield
                continue
            m.inc("decode_dispatches_total")
            t_sync = perf_counter()
            with tr.span("sync"):
                self._fault_stall(rs)
                toks = np.asarray(tok_dev)
                gates = np.asarray(dstats["attn_gate"], np.float32)
            now = perf_counter()
            m.inc("device_seconds_total", now - t_sync)
            step_s = now - t0
            m.inc("decode_seconds_total", step_s)
            m.observe("step_seconds", step_s)
            self._watch(rs, "decode_step", step_s)

            with tr.span("bookkeep"):
                cur = list(sched.active)
                if tr.enabled:
                    t0u, t1u = tr.to_us(t0), tr.to_us(now)
                    for slot in cur:
                        tr.span_at(f"decode[{rs.disp_idx}]",
                                   request_tid(sched.active[slot].req.uid),
                                   t0u, t1u, tokens=1)
                lay = gates[:, cur].mean(axis=1)
                for slot in cur:
                    st = sched.active[slot]
                    g = gates[:, slot]
                    fresh_n = int(1 + (g[1:] > 0.5).sum()) if reuse else nA
                    alloc.append(slot, fresh_n, nA)
                    rs.hist.on_decode_step(slot, g)
                    rs.keep_acc += float(g.sum())
                    rs.keep_n += nA
                    reason = self._advance_slot(rs, st, int(toks[slot]), g,
                                                step_s, measure, nA)
                    if reason:
                        self._finish(rs, slot, reason)
                self._record_step_series(rs, lay)
            rs.step_idx += 1
            rs.disp_idx += 1
            self._poll_compiles(rs)
            tr.end()                      # step
            self._drain_stream(rs)
            yield

        m.inc("host_seconds_total",
              (perf_counter() - t_loop) - m.value("device_seconds_total"))
        self._store = store
        return self._finalize(rs)

    # -- speculative decoding (spec_k > 0; docs/speculative.md) ------------
    def _window_gamma(self) -> int:
        """Draft length for this window, clamped so (a) every active
        slot can hold the window's C = γ+1 KV writes within ``max_len``
        (a verify write past the last row would clamp back onto
        committed rows) and (b) the window is not all waste when every
        resident is nearly out of generation budget.  0 = verify-only:
        a C=1 window, i.e. exactly one plain decode step."""
        g = self.spec_k
        rem_max = 1
        for st in self.scheduler.active.values():
            g = min(g, self.max_len - st.pos - 1)
            rem_max = max(rem_max,
                          st.req.max_new_tokens - len(st.out_tokens))
        return max(0, min(g, rem_max - 1))

    def _override_drafts(self, feed: np.ndarray, dout) -> jnp.ndarray:
        """Apply the ``draft_override`` test hook: sync the draft
        tokens, let the hook rewrite each active slot's proposals, and
        rebuild the verify feed host-side (the extra sync is the hook's
        cost — it exists for forcing accept/reject patterns in tests,
        not for serving)."""
        d = np.asarray(dout["tokens"]).T.copy()              # [S, γ]
        for slot, st in self.scheduler.active.items():
            d[slot] = np.asarray(
                self.draft_override(st.req.uid, d[slot].copy()),
                np.int32)
        return jnp.asarray(np.concatenate([feed[:, None], d], axis=1))

    def _accept_windows(self, rs: _RunState, cur: List[int], gamma: int,
                        drafts: np.ndarray, tgt: np.ndarray,
                        vlog: Optional[np.ndarray],
                        dlog: Optional[np.ndarray]):
        """Host acceptance for one window.  Returns ({slot: emitted
        tokens (pre-truncation)}, {slot: accepted draft count}).
        Temperature 0 takes the greedy prefix-match path (the chain is
        then bit-identical to plain greedy decoding by induction);
        temperature > 0 runs the exact accept/resample test per slot
        with uniforms drawn from the run's rng stream, preserving the
        per-token emission distribution (serve/sampling.py)."""
        emitted: Dict[int, List[int]] = {}
        accepted: Dict[int, int] = {}
        if self.temperature <= 0.0:
            acc, corr = sampling_mod.greedy_verify(tgt, drafts)
            for slot in cur:
                a = int(acc[slot])
                emitted[slot] = ([int(x) for x in drafts[slot, :a]]
                                 + [int(corr[slot])])
                accepted[slot] = a
            return emitted, accepted
        S = drafts.shape[0]
        rs.rng, ka, kf = jax.random.split(rs.rng, 3)
        u_acc = np.asarray(jax.random.uniform(ka, (S, max(gamma, 1))),
                           np.float64)
        u_fin = np.asarray(jax.random.uniform(kf, (S, gamma + 1)),
                           np.float64)
        p_t = sampling_mod.softmax_probs(vlog, self.temperature)
        p_d = (sampling_mod.softmax_probs(dlog, self.temperature)
               if gamma else None)
        for slot in cur:
            if gamma:
                a, toks = sampling_mod.speculative_accept_window(
                    drafts[slot], p_d[slot], p_t[slot], u_acc[slot],
                    u_fin[slot])
            else:
                a, toks = 0, [sampling_mod.inverse_cdf_sample(
                    p_t[slot, 0], float(u_fin[slot, 0]))]
            emitted[slot] = toks
            accepted[slot] = a
        return emitted, accepted

    def _plan_emission(self, st: ActiveRequest,
                       toks: List[int]) -> List[int]:
        """Truncate a window's emitted tokens to what ``_advance_slot``
        will actually append — stop token, generation budget and pool
        ``max_len`` all end the request mid-window.  The paged engine
        commits exactly this many verify columns (the emitted chain's
        KV minus the final token, whose KV is written when it is fed as
        the next window's first column — the plain engine's fill
        trajectory, entry for entry)."""
        keep: List[int] = []
        for tok in toks:
            keep.append(tok)
            if st.req.stop_token is not None and tok == st.req.stop_token:
                break
            if len(st.out_tokens) + len(keep) >= st.req.max_new_tokens:
                break
            if st.pos + len(keep) >= self.max_len:
                break
        return keep

    def _emission_caps(self, cur: List[int]):
        """[S]-vector emission-truncation bounds for the fused commit —
        the device-side mirror of ``_plan_emission``'s loop bounds:
        per-slot generation budget, ``max_len`` headroom and stop token
        (-1 = none).  Inactive slots keep the harmless defaults (their
        committed count is masked to 0 by ``active``)."""
        S = self.max_slots
        budget = np.ones((S,), np.int32)
        length = np.ones((S,), np.int32)
        stop = np.full((S,), -1, np.int32)
        for s in cur:
            st = self.scheduler.active[s]
            budget[s] = st.req.max_new_tokens - len(st.out_tokens)
            length[s] = self.max_len - st.pos
            if st.req.stop_token is not None:
                stop[s] = st.req.stop_token
        return jnp.asarray(budget), jnp.asarray(length), jnp.asarray(stop)

    def _spec_bookkeep(self, rs: _RunState, cur: List[int], gamma: int,
                       plan_emit: Dict[int, List[int]],
                       accepted: Dict[int, int], gates: np.ndarray,
                       window_s: float, t0: float, now: float,
                       n_layers: int, measure: bool,
                       per_tok=None) -> int:
        """Walk each slot's (truncated) emission in token order, applying
        exactly the per-token accounting the plain loops do — emitted
        token i pairs with verify gate column i, the gates of processing
        the token that *produced* it, matching the single-step engines'
        (token, gate) pairing.  ``per_tok`` is the paged hook (allocator
        append + history replay).  Returns the longest emission (the
        window's step-equivalent count)."""
        m, tr, sched = rs.metrics, self.tracer, self.scheduler
        m.inc("spec_windows_total")
        max_emit = 1
        t0u = t1u = None
        if tr.enabled:
            t0u, t1u = tr.to_us(t0), tr.to_us(now)
        lay_sum, lay_n = None, 0
        for slot in cur:
            st = sched.active[slot]
            keep = plan_emit[slot]
            a = accepted[slot]
            tid = request_tid(st.req.uid)
            if gamma:
                m.inc("spec_tokens_drafted_total", gamma)
                m.inc("spec_tokens_accepted_total", a)
                m.observe("spec_acceptance_rate", a / gamma)
            tr.instant("accept", tid, drafted=gamma, accepted=a,
                       emitted=len(keep))
            if tr.enabled:
                tr.span_at(f"decode[{rs.disp_idx}]", tid, t0u, t1u,
                           tokens=len(keep))
            share = window_s / len(keep)
            max_emit = max(max_emit, len(keep))
            reason = None
            for i, tok in enumerate(keep):
                g = gates[:, slot, i] if gates is not None else None
                if g is not None:
                    rs.keep_acc += float(g.sum())
                    rs.keep_n += n_layers
                if per_tok is not None:
                    per_tok(slot, g)
                reason = self._advance_slot(rs, st, int(tok), g, share,
                                            measure, n_layers)
                if reason and i != len(keep) - 1:
                    raise RuntimeError(
                        f"speculative window divergence on slot {slot}: "
                        f"_advance_slot finished ({reason!r}) at emitted "
                        f"token {i} but _plan_emission kept {len(keep)} "
                        "— the truncation rules no longer mirror the "
                        "finish conditions")
            if gates is not None:
                win = gates[:, slot, :len(keep)].sum(axis=1)
                lay_sum = win if lay_sum is None else lay_sum + win
                lay_n += len(keep)
            if reason:
                self._finish(rs, slot, reason)
        self._record_step_series(
            rs, lay_sum / lay_n if lay_n else None)
        return max_emit

    def _run_dense_spec(self, rng: Optional[jax.Array] = None
                        ) -> Dict[str, object]:
        """Dense-pool speculative loop (``spec_k > 0``).

        Per iteration: admission/prefill exactly as ``_run_dense``, then
        ONE draft+verify window instead of a single decode step: (1) a
        γ-step draft loop under ``draft_params`` proposes tokens (KV
        writes tentative); (2) one ``verify_chunk`` dispatch runs the
        full model over [feed, drafts], rewriting every window row with
        the verifier's KV — dense rollback is free, rows beyond the
        accepted prefix stay dead until ``kv_valid_len`` reaches them
        and the next window overwrites them first; (3) a single sync
        pulls drafts, per-column verify argmax and gates; (4) the host
        accept/resample emits accepted prefix + correction per slot.
        Two dispatches per window, up to spec_k+1 tokens per slot;
        temperature-0 token output is bit-identical to ``_run_dense``."""
        cfg = self.cfg
        sched = self.scheduler
        rs = self._new_run_state(rng, paged=False)
        m, tr = rs.metrics, self.tracer
        L_attn = max(len(cfg.attention_layers), 1)
        measure = cfg.skip.enabled and cfg.skip.kv_reuse

        pool = init_pool(cfg, self.max_slots, self.max_len)
        if self.policy is not None:
            pool = jax.device_put(pool, self._pool_sh)
        pool = self._apply_resume(rs, pool)
        feed = np.zeros((self.max_slots,), np.int32)
        pos = np.zeros((self.max_slots,), np.int32)
        t_loop = perf_counter()

        while sched.has_work():
            self._boundary(rs, pool)
            if not sched.has_work():      # lifecycle sweep drained the run
                break
            tr.begin("step", idx=rs.disp_idx)
            self._step_gauges(rs)
            pre_active = bool(sched.active)
            did_prefill = False
            while True:
                with tr.span("plan"):
                    plan = sched.plan_step(token_budget=self.step_tokens)
                self._note_admission(rs)
                if plan.prefill is None:
                    break
                with tr.span("prefill"):
                    pool = self._prefill_work_dense(rs, plan.prefill, pool)
                did_prefill = True
                if self.prefill_chunk:
                    break
            if did_prefill and pre_active:
                m.inc("interleaved_steps_total")

            if not sched.active:
                self._poll_compiles(rs)
                tr.end()                  # step
                self._drain_stream(rs)
                yield
                continue

            # -- one draft+verify window over the whole pool ---------------
            cur = sorted(sched.active)
            for slot in cur:
                st = sched.active[slot]
                feed[slot] = st.next_token
                pos[slot] = st.pos
            gamma = self._window_gamma()
            t0 = perf_counter()
            try:
                feed_dev = jnp.asarray(feed)
                pos_dev = jnp.asarray(pos)
                dout = None
                with tr.span("draft", k=gamma), tr.annotate("spec_draft"):
                    self._fault_dispatch(rs)
                    if gamma:
                        pool, dout = self._spec_draft(gamma)(
                            self.draft_params, pool, feed_dev, pos_dev,
                            rs.rng)
                        rs.rng = dout["rng"]
                        feed_chunk = jnp.concatenate(
                            [feed_dev[:, None], dout["tokens"].T], axis=1)
                        if self.draft_override is not None:
                            feed_chunk = self._override_drafts(feed, dout)
                    else:
                        feed_chunk = feed_dev[:, None]
                with tr.span("verify", k=gamma), tr.annotate("spec_verify"):
                    tgt_dev, vlog_dev, pool, vstats = self._spec_verify()(
                        self.params, pool, {"tokens": feed_chunk}, pos_dev)
            except FaultInjected:
                # raised before the jitted calls: pool untouched — abandon
                # the window and re-plan (see _run_dense)
                m.inc("dispatch_retries_total")
                self._poll_compiles(rs)
                tr.end()                  # step
                self._drain_stream(rs)
                yield
                continue
            m.inc("decode_dispatches_total", 2 if gamma else 1)
            t_sync = perf_counter()
            with tr.span("sync"):
                self._fault_stall(rs)
                tgt = np.asarray(tgt_dev)                     # [S, C]
                drafts = np.asarray(feed_chunk[:, 1:])        # [S, γ]
                gates = (np.asarray(vstats["attn_gate"], np.float32)
                         if vstats.get("attn_gate") is not None else None)
                dlog = (np.asarray(dout["logits"]).transpose(1, 0, 2)
                        if (gamma and self.temperature > 0.0) else None)
                vlog = (np.asarray(vlog_dev)
                        if self.temperature > 0.0 else None)
            now = perf_counter()
            m.inc("device_seconds_total", now - t_sync)
            window_s = now - t0
            m.inc("decode_seconds_total", window_s)
            m.observe("step_seconds", window_s)
            self._watch(rs, "decode_window", window_s)

            with tr.span("bookkeep"):
                emitted, accepted = self._accept_windows(
                    rs, cur, gamma, drafts, tgt, vlog, dlog)
                plan_emit = {
                    s: self._plan_emission(sched.active[s], emitted[s])
                    for s in cur}
                max_emit = self._spec_bookkeep(
                    rs, cur, gamma, plan_emit, accepted, gates,
                    window_s, t0, now, L_attn, measure)
            rs.step_idx += max_emit
            rs.disp_idx += 1
            self._poll_compiles(rs)
            tr.end()                      # step
            self._drain_stream(rs)
            yield

        m.inc("host_seconds_total",
              (perf_counter() - t_loop) - m.value("device_seconds_total"))
        return self._finalize(rs)

    def _ensure_window(self, rs: _RunState, gamma: int,
                       hidden: List[int]) -> None:
        """Grow every active slot's page chain to the speculative
        window's worst case (fill + (γ+1)·n_attn entries) BEFORE the
        block table is snapshotted — device-side appends past the
        ensured chain would read block-table zeros and scatter into
        physical page 0, corrupting another slot's committed entries.
        Preempt-youngest backpressure mirrors ``_run_paged``'s per-step
        headroom pass; ``hidden`` is the oom-fault seam's page list,
        returned to the pool in place when it is the only way out."""
        alloc, sched = self.allocator, self.scheduler
        need_per = (gamma + 1) * self.n_attn
        for slot in sorted(sched.active):
            if slot not in sched.active:          # preempted below
                continue
            while not alloc.ensure(slot,
                                   int(alloc.fill[slot]) + need_per):
                if self._reclaim_pages():
                    continue
                if not self._preempt_youngest(rs, exclude=slot):
                    if hidden:
                        alloc.unhide_pages(hidden)
                        hidden.clear()
                        continue
                    raise PageExhausted(
                        f"page pool exhausted with a single resident "
                        f"request (slot {slot}) — submit() should have "
                        "rejected it", slot=slot,
                        free_pages=alloc.free_pages,
                        pages_total=self.num_pages)

    def _run_paged_spec(self, rng: Optional[jax.Array] = None
                        ) -> Dict[str, object]:
        """Paged-store speculative loop: ``_run_dense_spec``'s twin with
        the tentative-commit KV protocol (docs/speculative.md).

        Window anatomy: (1) resident window headroom is page-reserved
        up front (``_ensure_window``) — before admission, so
        ``_can_place`` sees the free list net of the residents' window,
        and again after admission so a newly activated request is
        covered too; (2) the draft loop appends *tentative* entries
        past the pre-window fill; (3) the verifier reads the committed
        prefix only (``in_fill`` masks at the pre-window fill) and
        returns every window column's full-model KV; (4) after the
        single sync and host acceptance, ``commit_verified`` rewrites
        the stream from the pre-window fill with exactly the emitted
        columns — in plain-engine token-major order — while the host
        replays the allocator/history accounting per emitted token and
        ``trim`` returns the rejected tail's pages.  Zero leaked pages,
        zero stale tentative entries (test_speculative.py pins both).

        At temperature 0 the verify and commit dispatches are FUSED
        (``_spec_verify_commit``): the device computes the greedy accept
        and the emission truncation itself and rewrites the stream in
        the verify dispatch, halving the per-window dispatch count; the
        host replays the acceptance from the synced argmax chain and
        asserts agreement.  Temperature > 0 keeps the two-phase path."""
        cfg = self.cfg
        sched = self.scheduler
        alloc = self.allocator
        nA = self.n_attn
        reuse = paged_mod.reuse_enabled(cfg)
        measure = cfg.skip.enabled and cfg.skip.kv_reuse
        rs = self._new_run_state(rng, paged=True)
        m, tr = rs.metrics, self.tracer
        fused = self.temperature <= 0.0

        store = self._apply_resume(rs, self._acquire_store())
        feed = np.zeros((self.max_slots,), np.int32)
        pos = np.zeros((self.max_slots,), np.int32)
        act = np.zeros((self.max_slots,), bool)
        t_loop = perf_counter()

        def per_tok(slot, g):
            fresh_n = int(1 + (g[1:] > 0.5).sum()) if reuse else nA
            alloc.append(slot, fresh_n, nA)
            rs.hist.on_decode_step(slot, g)

        while sched.has_work():
            self._boundary(rs, store)
            if not sched.has_work():      # lifecycle sweep drained the run
                break
            tr.begin("step", idx=rs.disp_idx)
            self._step_gauges(rs)
            # -- resident window headroom before admission (_can_place
            # must see the free list net of what residents need)
            hidden = self._fault_oom(rs)
            gamma = self._window_gamma() if sched.active else 0
            with tr.span("headroom"):
                self._ensure_window(rs, gamma, hidden)

            pre_active = bool(sched.active)
            with tr.span("plan"):
                plan = sched.plan_step(can_place=self._can_place,
                                       token_budget=self.step_tokens)
            self._note_admission(rs)
            pf = sched.prefilling
            if (pf is not None and pf.done == 0
                    and (self.prefill_chunk
                         or self.step_tokens is not None)):
                if not alloc.ensure(pf.slot,
                                    pf.req.prompt_len * nA + nA):
                    raise RuntimeError(
                        "worst-case page reservation failed in the same "
                        "iteration as a successful _can_place admission "
                        "check — allocator bug")
            if plan.prefill is not None:
                with tr.span("prefill"):
                    store = self._prefill_work_paged(rs, plan.prefill,
                                                     store)
                if pre_active:
                    m.inc("interleaved_steps_total")

            if not sched.active:
                if hidden:
                    alloc.unhide_pages(hidden)
                self._poll_compiles(rs)
                tr.end()                  # step
                self._drain_stream(rs)
                yield
                continue

            # -- final headroom pass: covers a request activated by this
            # iteration's prefill (idempotent for the residents), at the
            # final γ — which the newcomer's position may have clamped
            gamma = self._window_gamma()
            with tr.span("headroom"):
                self._ensure_window(rs, gamma, hidden)
            if hidden:
                alloc.unhide_pages(hidden)

            # -- one draft+verify window over the live chains --------------
            cur = sorted(sched.active)
            for slot in cur:
                st = sched.active[slot]
                feed[slot] = st.next_token
                pos[slot] = st.pos
            act[:] = False
            act[cur] = True
            fill0 = alloc.fill.copy()
            j_live = max(1, alloc.max_chain_pages())
            j_step = min(1 << (j_live - 1).bit_length(),
                         alloc.pages_per_slot)
            bt = jnp.asarray(alloc.block_table[:, :j_step])
            fill_dev = jnp.asarray(fill0)
            t0 = perf_counter()
            try:
                feed_dev = jnp.asarray(feed)
                pos_dev = jnp.asarray(pos)
                dout = None
                with tr.span("draft", k=gamma), tr.annotate("spec_draft"):
                    self._fault_dispatch(rs)
                    if gamma:
                        store, dout = self._spec_draft(gamma)(
                            self.draft_params, store, feed_dev, pos_dev,
                            fill_dev, jnp.asarray(act), rs.rng, bt)
                        rs.rng = dout["rng"]
                        feed_chunk = jnp.concatenate(
                            [feed_dev[:, None], dout["tokens"].T], axis=1)
                        if self.draft_override is not None:
                            feed_chunk = self._override_drafts(feed, dout)
                    else:
                        feed_chunk = feed_dev[:, None]
                with tr.span("verify", k=gamma), tr.annotate("spec_verify"):
                    if fused:
                        caps = self._emission_caps(cur)
                        store, tgt_dev, gates_dev, committed_dev = (
                            self._spec_verify_commit()(
                                self.params, store,
                                {"tokens": feed_chunk}, pos_dev, bt,
                                fill_dev, jnp.asarray(act), *caps))
                    else:
                        tgt_dev, vlog_dev, vstats = self._spec_verify()(
                            self.params, store, {"tokens": feed_chunk},
                            pos_dev, bt, fill_dev)
            except FaultInjected:
                # raised before the jitted calls: store and allocator
                # untouched beyond idempotent reservations — abandon the
                # window and re-plan (see _run_dense)
                m.inc("dispatch_retries_total")
                self._poll_compiles(rs)
                tr.end()                  # step
                self._drain_stream(rs)
                yield
                continue
            m.inc("decode_dispatches_total", 2 if gamma else 1)
            t_sync = perf_counter()
            with tr.span("sync"):
                self._fault_stall(rs)
                tgt = np.asarray(tgt_dev)                     # [S, C]
                drafts = np.asarray(feed_chunk[:, 1:])        # [S, γ]
                gates = np.asarray(
                    gates_dev if fused else vstats["attn_gate"],
                    np.float32)
                committed_np = (np.asarray(committed_dev) if fused
                                else None)
                dfill = (np.asarray(dout["fill"]) if gamma
                         else fill0)
                dlog = (np.asarray(dout["logits"]).transpose(1, 0, 2)
                        if (gamma and self.temperature > 0.0) else None)
                vlog = (np.asarray(vlog_dev)
                        if self.temperature > 0.0 else None)
            now = perf_counter()
            m.inc("device_seconds_total", now - t_sync)
            window_s = now - t0
            m.inc("decode_seconds_total", window_s)
            m.observe("step_seconds", window_s)
            self._watch(rs, "decode_window", window_s)

            with tr.span("bookkeep"):
                emitted, accepted = self._accept_windows(
                    rs, cur, gamma, drafts, tgt, vlog, dlog)
                plan_emit = {
                    s: self._plan_emission(sched.active[s], emitted[s])
                    for s in cur}
            with tr.span("rollback", k=gamma):
                committed = np.zeros((self.max_slots,), np.int32)
                for s in cur:
                    committed[s] = len(plan_emit[s])
                if fused:
                    # the device already committed inside the verify
                    # dispatch; the host replay must agree column-for-
                    # column or the entry stream is corrupt
                    if not np.array_equal(committed_np, committed):
                        raise RuntimeError(
                            "fused spec commit divergence: device "
                            f"committed {committed_np.tolist()} vs host "
                            f"plan {committed.tolist()} — greedy accept "
                            "replay bug")
                else:
                    bk, bv = vstats["kv_token"]
                    store, _ = self._spec_commit()(
                        store, bk, bv, vstats["attn_gate"], pos_dev, bt,
                        fill_dev, jnp.asarray(committed),
                        jnp.asarray(act))
                # rolled back = tentative draft entries the commit does
                # not cover (the draft's fresh counts come from the
                # *draft* gates, the commit's from the verifier's — with
                # full acceptance under an unbiased draft the rewrite
                # covers everything and this is 0)
                rolled = 0
                for s in cur:
                    cf = int(fill0[s])
                    for i in range(len(plan_emit[s])):
                        g = gates[:, s, i]
                        cf += (int(1 + (g[1:] > 0.5).sum())
                               if reuse else nA)
                    rolled += max(0, int(dfill[s]) - cf)
                m.inc("spec_entries_rolled_back_total", rolled)
                max_emit = self._spec_bookkeep(
                    rs, cur, gamma, plan_emit, accepted, gates,
                    window_s, t0, now, nA, measure, per_tok=per_tok)
                for slot in cur:
                    if slot in sched.active:
                        alloc.trim(slot)
            rs.step_idx += max_emit
            rs.disp_idx += 1
            self._poll_compiles(rs)
            tr.end()                      # step
            self._drain_stream(rs)
            yield

        m.inc("host_seconds_total",
              (perf_counter() - t_loop) - m.value("device_seconds_total"))
        self._store = store
        return self._finalize(rs)

    # -- fused-epoch run loops (decode_steps > 1) --------------------------
    def _epoch_args(self, rem: Dict[int, int]):
        """Build the device-loop batch arrays from the resident set.
        ``rem[slot]`` is filled with each slot's epoch horizon —
        min(budget remaining, positions to max_len) — whose max picks the
        epoch length.  Returns (feed, pos, act, budget, stop, slots)."""
        S = self.max_slots
        feed = np.zeros((S,), np.int32)
        pos = np.zeros((S,), np.int32)
        act = np.zeros((S,), bool)
        budget = np.zeros((S,), np.int32)
        stop = np.full((S,), -1, np.int32)
        slots = []
        for slot, st in self.scheduler.active.items():
            feed[slot] = st.next_token
            pos[slot] = st.pos
            act[slot] = True
            b = st.req.max_new_tokens - len(st.out_tokens)
            budget[slot] = b
            if st.req.stop_token is not None:
                stop[slot] = st.req.stop_token
            rem[slot] = min(b, self.max_len - st.pos)
            slots.append(slot)
        return feed, pos, act, budget, stop, slots

    def _epoch_len(self, rem: Dict[int, int]) -> int:
        """Epoch length: ``decode_steps`` clipped to the longest resident
        horizon, rounded up to a power of two so the lazily compiled loop
        variants stay logarithmic in N (the same recompile-bounding trick
        as prefill length-bucketing)."""
        rem_max = max(rem.values())
        return min(self.decode_steps,
                   1 << max(0, rem_max - 1).bit_length())

    def _process_epoch(self, rs: _RunState, out: Dict, slots: List[int],
                       t_disp: float, per_step=None) -> None:
        """Epoch sync + bookkeeping replay: pull the stacked (tokens,
        step_active, gates) off the device and walk them in step order,
        applying exactly the per-token accounting the single-step loops
        do — ``step_active`` masks the steps a slot sat out after
        finishing mid-epoch (its KV frozen on device), so emission sets
        match the single-step engine token for token.  ``per_step`` is
        the paged hook (allocator append + history replay).  A host/device
        divergence in finish detection raises instead of silently
        desyncing the KV state."""
        cfg, sched = self.cfg, self.scheduler
        m, tr = rs.metrics, self.tracer
        L_attn = max(len(cfg.attention_layers), 1)
        measure = cfg.skip.enabled and cfg.skip.kv_reuse
        t_sync = perf_counter()
        with tr.span("sync"):
            self._fault_stall(rs)
            toks = np.asarray(out["tokens"])                     # [n, S]
            step_act = np.asarray(out["step_active"])            # [n, S]
            gates = (np.asarray(out["attn_gate"], np.float32)
                     if out["attn_gate"] is not None else None)  # [n, L, S]
            fin_act = np.asarray(out["active"])
        now = perf_counter()
        m.inc("device_seconds_total", now - t_sync)
        epoch_s = now - t_disp
        m.inc("decode_seconds_total", epoch_s)
        m.observe("step_seconds", epoch_s)
        self._watch(rs, "decode_epoch", epoch_s)
        n_run = toks.shape[0]
        step_s = epoch_s / n_run

        with tr.span("bookkeep"):
            # deferred first tokens first: their slots either join the
            # epoch replay below (normal) or were entry-killed on device
            # and finish here with the stop reason (step_active all False)
            self._resolve_pending(rs)

            if tr.enabled:
                t0u, t1u = tr.to_us(t_disp), tr.to_us(now)
                for slot in slots:
                    st = sched.active.get(slot)
                    if st is not None:
                        tr.span_at(f"decode[{rs.disp_idx}]",
                                   request_tid(st.req.uid), t0u, t1u,
                                   tokens=int(step_act[:, slot].sum()))

            for slot in slots:
                st = sched.active.get(slot)
                if st is None:
                    continue  # entry-killed pending slot, finished above
                reason = None
                for s in range(n_run):
                    if not step_act[s, slot]:
                        continue
                    g = gates[s, :, slot] if gates is not None else None
                    if g is not None:
                        rs.keep_acc += float(g.sum())
                        rs.keep_n += L_attn
                    if per_step is not None:
                        per_step(slot, g)
                    reason = self._advance_slot(rs, st, int(toks[s, slot]),
                                                g, step_s, measure, L_attn)
                    if reason:
                        self._finish(rs, slot, reason)
                        break
                if (reason is None) != bool(fin_act[slot]):
                    raise RuntimeError(
                        f"fused-epoch divergence on slot {slot}: host "
                        f"finish reason {reason!r} vs device active "
                        f"{bool(fin_act[slot])} — the device loop's stop/"
                        "length conditions no longer mirror _advance_slot")

            lay = None
            if gates is not None:
                msum = float(step_act.sum())
                if msum:
                    # per-layer keep rate over every executed (step, slot)
                    lay = (gates * step_act[:, None, :]).sum(axis=(0, 2)) \
                        / msum
            self._record_step_series(rs, lay)
        rs.step_idx += n_run
        rs.disp_idx += 1

    def _run_dense_fused(self, rng: Optional[jax.Array] = None
                         ) -> Dict[str, object]:
        """Dense-pool loop with the device-resident N-step decode epoch
        (``decode_steps > 1``).  Per iteration: (1) dispatch one
        ``model.decode_loop`` epoch over the residents — sampling,
        stop/length detection and position advance all on device, the
        pool donated through the scan carry; (2) while that epoch is in
        flight, run the host's scheduling work — admission, prefill
        dispatches (first token sampled inside the prefill dispatch and
        left on device), pool inserts — none of which blocks; (3) sync
        once and replay the epoch's per-token bookkeeping.  Token output
        is identical to ``_run_dense`` at temperature 0."""
        cfg = self.cfg
        sched = self.scheduler
        rs = self._new_run_state(rng, paged=False)
        m, tr = rs.metrics, self.tracer

        pool = init_pool(cfg, self.max_slots, self.max_len)
        if self.policy is not None:
            pool = jax.device_put(pool, self._pool_sh)
        pool = self._apply_resume(rs, pool)
        t_loop = perf_counter()

        while sched.has_work():
            self._boundary(rs, pool)
            if not sched.has_work():      # lifecycle sweep drained the run
                break
            tr.begin("step", idx=rs.disp_idx)
            self._step_gauges(rs)
            # -- (1) dispatch one N-step epoch over the residents ----------
            out = None
            slots: List[int] = []
            n_eff = 1
            if sched.active:
                rem: Dict[int, int] = {}
                feed, pos, act, budget, stop, slots = self._epoch_args(rem)
                n_eff = self._epoch_len(rem)
                feed_dev = jnp.asarray(feed)
                for slot, tok_dev in rs.pending.items():
                    if act[slot]:
                        # deferred first token: overlay the device value
                        # into the feed carry (no host sync)
                        feed_dev = feed_dev.at[slot].set(tok_dev[0])
                t_disp = perf_counter()
                try:
                    with tr.span("dispatch", n=n_eff), \
                            tr.annotate("decode_epoch"):
                        self._fault_dispatch(rs)
                        pool, out = self._dense_loop(n_eff)(
                            self.params, pool, feed_dev, jnp.asarray(pos),
                            jnp.asarray(act), jnp.asarray(budget),
                            jnp.asarray(stop), rs.rng)
                        rs.rng = out["rng"]
                except FaultInjected:
                    # pre-dispatch raise: pool untouched — abandon the
                    # epoch and re-plan (see _run_dense)
                    m.inc("dispatch_retries_total")
                    self._poll_compiles(rs)
                    tr.end()              # step
                    self._drain_stream(rs)
                    yield
                    continue
                m.inc("decode_dispatches_total")

            # -- (2) host scheduling work overlapping the in-flight epoch --
            pre_active = bool(sched.active)
            did_prefill = False
            with tr.span("plan"):
                while True:
                    plan = sched.plan_step(token_budget=self.step_tokens,
                                           decode_steps=n_eff)
                    self._note_admission(rs)
                    if plan.prefill is None:
                        break
                    with tr.span("prefill"):
                        pool = self._prefill_work_dense(rs, plan.prefill,
                                                        pool)
                    did_prefill = True
                    if self.prefill_chunk:
                        break
            if did_prefill and pre_active:
                m.inc("interleaved_steps_total")

            if out is None:
                self._poll_compiles(rs)
                tr.end()                  # step
                self._drain_stream(rs)
                yield
                continue

            # -- (3) one sync per epoch + bookkeeping replay ---------------
            self._process_epoch(rs, out, slots, t_disp)
            self._poll_compiles(rs)
            tr.end()                      # step
            self._drain_stream(rs)
            yield

        m.inc("host_seconds_total",
              (perf_counter() - t_loop) - m.value("device_seconds_total"))
        return self._finalize(rs)

    def _run_paged_fused(self, rng: Optional[jax.Array] = None
                         ) -> Dict[str, object]:
        """Paged-store loop with the device-resident N-step epoch
        (``model.paged_decode_loop``): the entry-stream fill advances on
        device, and the host replays the allocator/history accounting
        from the epoch's stacked gate log at the single sync.

        OOM safety moves from per-step to per-epoch granularity: before
        dispatch, every resident's worst case for the whole epoch
        (``fill + min(n_eff, horizon) × n_attn`` entries) is page-reserved
        up front.  If the free list can't cover it the epoch *shrinks*
        (halving ``n_eff``) before anyone is preempted — preemption
        (still youngest-first, requeued at the FIFO head) is the n_eff=1
        last resort, so backpressure costs epoch length before it costs
        a prefill."""
        cfg = self.cfg
        sched = self.scheduler
        alloc = self.allocator
        nA = self.n_attn
        reuse = paged_mod.reuse_enabled(cfg)
        rs = self._new_run_state(rng, paged=True)
        m, tr = rs.metrics, self.tracer

        store = self._apply_resume(rs, self._acquire_store())
        t_loop = perf_counter()

        def per_step(slot, g):
            fresh_n = int(1 + (g[1:] > 0.5).sum()) if reuse else nA
            alloc.append(slot, fresh_n, nA)
            rs.hist.on_decode_step(slot, g)

        while sched.has_work():
            self._boundary(rs, store)
            if not sched.has_work():      # lifecycle sweep drained the run
                break
            tr.begin("step", idx=rs.disp_idx)
            self._step_gauges(rs)
            out = None
            slots: List[int] = []
            n_eff = 1
            if sched.active:
                rem: Dict[int, int] = {}
                for slot, st in sched.active.items():
                    rem[slot] = min(
                        st.req.max_new_tokens - len(st.out_tokens),
                        self.max_len - st.pos)
                n_eff = self._epoch_len(rem)
                if rs.epoch_cap:
                    # adaptive degradation: sustained page pressure left a
                    # cross-epoch cap; start from it instead of
                    # re-discovering the shrink every iteration
                    n_eff = min(n_eff, rs.epoch_cap)
                # epoch-granular headroom: shrink before preempting
                hidden = self._fault_oom(rs)
                shrunk = False
                with tr.span("headroom"):
                    while True:
                        failed = None
                        for slot in sorted(sched.active):
                            need = (int(alloc.fill[slot])
                                    + min(n_eff, rem.get(slot, 1)) * nA)
                            if not alloc.ensure(slot, need):
                                failed = slot
                                break
                        if failed is None:
                            break
                        if self._reclaim_pages():
                            continue
                        if n_eff > 1:
                            n_eff //= 2
                            shrunk = True
                            continue
                        if not self._preempt_youngest(rs, exclude=failed):
                            if hidden:
                                alloc.unhide_pages(hidden)
                                hidden = []
                                continue
                            raise PageExhausted(
                                f"page pool exhausted with a single "
                                f"resident request (slot {failed}) — "
                                "submit() should have rejected it",
                                slot=failed, free_pages=alloc.free_pages,
                                pages_total=self.num_pages)
                if hidden:
                    alloc.unhide_pages(hidden)
                if shrunk:
                    # remember the length that fit; grow back only after
                    # consecutive clean epochs (hysteresis, so a storm
                    # doesn't thrash shrink/grow every iteration)
                    rs.epoch_cap = n_eff
                    rs.clean_epochs = 0
                    m.inc("epoch_shrinks_total")
                    tr.instant("epoch_shrink", n_eff=n_eff)
                elif rs.epoch_cap:
                    rs.clean_epochs += 1
                    if rs.clean_epochs >= 2:
                        grown = rs.epoch_cap * 2
                        rs.epoch_cap = (0 if grown >= self.decode_steps
                                        else grown)
                        rs.clean_epochs = 0
                feed, pos, act, budget, stop, slots = self._epoch_args({})
                j_live = max(1, alloc.max_chain_pages())
                j_step = min(1 << (j_live - 1).bit_length(),
                             alloc.pages_per_slot)
                t_disp = perf_counter()
                try:
                    with tr.span("dispatch", n=n_eff), \
                            tr.annotate("paged_decode_epoch"):
                        self._fault_dispatch(rs)
                        store, out = self._paged_loop(n_eff)(
                            self.params, store, jnp.asarray(feed),
                            jnp.asarray(pos), jnp.asarray(alloc.fill),
                            jnp.asarray(act), jnp.asarray(budget),
                            jnp.asarray(stop), rs.rng,
                            jnp.asarray(alloc.block_table[:, :j_step]))
                        rs.rng = out["rng"]
                except FaultInjected:
                    # pre-dispatch raise: store/allocator untouched —
                    # abandon the epoch and re-plan (see _run_dense)
                    m.inc("dispatch_retries_total")
                    self._poll_compiles(rs)
                    tr.end()              # step
                    self._drain_stream(rs)
                    yield
                    continue
                m.inc("decode_dispatches_total")

            # -- host scheduling work overlapping the in-flight epoch ------
            # (admission sees the free list net of the epoch reservation,
            # preserving the same-iteration _can_place invariant)
            pre_active = bool(sched.active)
            with tr.span("plan"):
                plan = sched.plan_step(can_place=self._can_place,
                                       token_budget=self.step_tokens,
                                       decode_steps=n_eff)
            self._note_admission(rs)
            pf = sched.prefilling
            if (pf is not None and pf.done == 0
                    and (self.prefill_chunk
                         or self.step_tokens is not None)):
                if not alloc.ensure(pf.slot,
                                    pf.req.prompt_len * nA + nA):
                    raise RuntimeError(
                        "worst-case page reservation failed in the same "
                        "iteration as a successful _can_place admission "
                        "check — allocator bug")
            if plan.prefill is not None:
                with tr.span("prefill"):
                    store = self._prefill_work_paged(rs, plan.prefill,
                                                     store)
                if pre_active:
                    m.inc("interleaved_steps_total")

            if out is None:
                self._poll_compiles(rs)
                tr.end()                  # step
                self._drain_stream(rs)
                yield
                continue

            self._process_epoch(rs, out, slots, t_disp, per_step=per_step)
            self._poll_compiles(rs)
            tr.end()                      # step
            self._drain_stream(rs)
            yield

        m.inc("host_seconds_total",
              (perf_counter() - t_loop) - m.value("device_seconds_total"))
        self._store = store
        return self._finalize(rs)
