"""Serving engines: the paper's end-to-end inference pipeline.

prefill (gather/compacted execution) → autoregressive decode with dynamic
routing and cross-layer KV reuse, with KV-storage accounting *measured*
from the per-step execution-gate log (``stats['attn_gate']``) instead of
the analytic keep-rate estimate.

Two engines share the jitted ``model.decode_step`` path:

``ServeEngine``
    Lock-step batch: one fixed batch, every sequence at the same position.
    Kept as the baseline the continuous engine is benchmarked against.

``ContinuousBatchingEngine``
    Slot-based continuous batching (the serving pattern SkipOPU's
    dynamically allocated compute pays off in): a fixed ``max_slots ×
    max_len`` KV pool allocated once, a FIFO request queue with prefill
    length-bucketing, per-sequence decode positions (``t: [B]``), and
    admission/eviction as requests start/stop — see
    ``repro/serve/scheduler.py`` and docs/serving.md.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import LOCAL, ModelConfig
from repro.core import kv_reuse
from repro.kvcache import history as history_mod
from repro.kvcache import paged as paged_mod
from repro.models import model as model_lib
from repro.serve.sampling import sample
from repro.serve.scheduler import (ActiveRequest, Request, Scheduler,
                                   can_bucket, default_buckets)


@dataclasses.dataclass
class ServeStats:
    prefill_tokens: int = 0
    decode_tokens: int = 0
    prefill_s: float = 0.0
    decode_s: float = 0.0
    attn_keep_frac: float = 1.0
    kv_saved_fraction: float = 0.0        # measured from logged gates
    kv_saved_analytic: float = 0.0        # configured-keep-rate estimate
    requests_completed: int = 0
    # -- paged-KV engine mode (kv_mode == "paged") -------------------------
    kv_mode: str = "dense"
    page_size: int = 0
    pages_total: int = 0
    pages_peak: int = 0                   # peak pages in use (live footprint)
    preemptions: int = 0                  # OOM-safe mid-decode evictions
    kv_entries_stored: int = 0            # live compact-store writes
    kv_entries_dense: int = 0             # per-layer-dense baseline writes
    history_hit_rate: float = 0.0         # reads served by the history buf
    history_hits_per_layer: List[float] = dataclasses.field(
        default_factory=list)

    @property
    def decode_tok_per_s(self) -> float:
        return self.decode_tokens / self.decode_s if self.decode_s else 0.0

    @property
    def kv_entries_saved_fraction(self) -> float:
        """Live storage saving of the paged history buffer (matches the
        CompactKVStore accounting replayed over the same gates)."""
        if not self.kv_entries_dense:
            return 0.0
        return 1.0 - self.kv_entries_stored / self.kv_entries_dense


@dataclasses.dataclass
class RequestResult:
    """Per-request outcome + serving metrics."""
    uid: int
    tokens: np.ndarray                   # generated tokens (incl. stop token)
    prompt_len: int
    ttft_s: float                        # submit → first token
    decode_s: float                      # time in this request's decode steps
    finish_reason: str                   # "length" | "stop" | "max_len"
    kv_stored: int = 0                   # measured compact-store entries
    kv_dense: int = 0                    # dense-baseline entries

    @property
    def decode_tokens(self) -> int:
        return int(self.tokens.shape[0])

    @property
    def decode_tok_per_s(self) -> float:
        n = self.decode_tokens - 1       # first token is prefill's
        return n / self.decode_s if self.decode_s > 0 and n > 0 else 0.0

    @property
    def kv_saved_fraction(self) -> float:
        if self.kv_dense == 0:
            return 0.0
        return 1.0 - self.kv_stored / self.kv_dense


def analytic_kv_saved(cfg: ModelConfig) -> float:
    """Compact-store saving at the *configured* keep rate: layer 0 dense +
    keep_prob elsewhere.  The measured per-run figure comes from the decode
    gate log via kv_reuse.storage_saved_fraction."""
    L = max(len(cfg.attention_layers), 1)
    if not (cfg.skip.enabled and cfg.skip.kv_reuse):
        return 0.0
    return 1.0 - (1.0 + (L - 1) * cfg.skip.keep_prob) / L


def _measured_saved_fraction(gates_per_step: List[np.ndarray],
                             cfg: ModelConfig) -> float:
    """Lock-step gate log [L, B] per step -> measured storage saving."""
    if not gates_per_step or not (cfg.skip.enabled and cfg.skip.kv_reuse):
        return 0.0
    g = jnp.asarray(np.stack(gates_per_step, axis=-1))   # [L, B, steps]
    return float(kv_reuse.storage_saved_fraction(g))


class ServeEngine:
    """Lock-step batched engine (baseline; one shared decode position)."""

    def __init__(self, cfg: ModelConfig, params, max_len: int = 512,
                 temperature: float = 0.0):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.temperature = temperature
        self._decode = jax.jit(partial(model_lib.decode_step, cfg=cfg),
                               donate_argnums=(1,))
        self._prefill = jax.jit(partial(model_lib.prefill, cfg=cfg,
                                        pad_to=max_len))

    def generate(self, prompts: np.ndarray, max_new_tokens: int,
                 rng: Optional[jax.Array] = None) -> Dict[str, np.ndarray]:
        """prompts: [B, T0] int32 (right-aligned, no padding support needed
        for the synthetic workloads).  Returns tokens + stats."""
        cfg = self.cfg
        B, T0 = prompts.shape
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        stats = ServeStats()

        t0 = time.time()
        logits, cache, pstats = self._prefill(self.params,
                                              {"tokens": jnp.asarray(prompts)})
        jax.block_until_ready(logits)
        stats.prefill_s = time.time() - t0
        stats.prefill_tokens = B * T0

        out = np.zeros((B, max_new_tokens), np.int32)
        keep_acc, keep_n = 0.0, 0
        gates_per_step: List[np.ndarray] = []
        emitted = 0
        tok = sample(logits, rng, self.temperature)
        t0 = time.time()
        for i in range(max_new_tokens):
            out[:, i] = np.asarray(tok)
            emitted += B
            pos = T0 + i
            if pos >= self.max_len:
                break
            logits, cache, dstats = self._decode(
                self.params, cache, {"tokens": tok[:, None]},
                jnp.int32(pos))
            if "attn_gate" in dstats:
                gates_per_step.append(
                    np.asarray(dstats["attn_gate"], np.float32))
            keep_acc += float(dstats["keep_frac_sum"])
            keep_n += max(float(dstats["n_routed"]), 1.0)
            rng, sub = jax.random.split(rng)
            tok = sample(logits, sub, self.temperature)
        jax.block_until_ready(logits)
        stats.decode_s = time.time() - t0
        stats.decode_tokens = emitted           # tokens actually emitted

        stats.attn_keep_frac = keep_acc / max(keep_n, 1.0)
        stats.kv_saved_fraction = _measured_saved_fraction(gates_per_step, cfg)
        stats.kv_saved_analytic = analytic_kv_saved(cfg)
        return {"tokens": out, "stats": stats}


# ---------------------------------------------------------------------------
# Slot-pool plumbing
# ---------------------------------------------------------------------------

def init_pool(cfg: ModelConfig, max_slots: int, max_len: int) -> Dict:
    """The continuous engine's KV pool: ``max_slots`` cache rows allocated
    once (the paper's fixed on-chip KV history buffer analogue)."""
    return model_lib.init_decode_cache(cfg, max_slots, max_len)

def _align_kv_row(row: jnp.ndarray, target_shape, kind: str,
                  cfg: ModelConfig) -> jnp.ndarray:
    """Reshape one prefill k/v cache row (``[.., T, Hkv, dh]``, padded to
    max_len) to the pool's layout for its layer kind: head-major transpose
    for ``bhtd`` pools, truncation to the ring extent for window layers
    (positions < W: ring slot s ≡ position s, so the prefix IS the ring)."""
    if kind == LOCAL and cfg.window_size:
        W = target_shape[-3]
        if row.shape[-3] != W:
            row = jax.lax.slice_in_dim(row, 0, W, axis=row.ndim - 3)
    elif cfg.kv_cache_layout == "bhtd":
        row = row.swapaxes(-3, -2)           # prefill collects [.., T, H, d]
    return row


def pool_insert(pool: Dict, cache: Dict, slot, cfg: ModelConfig) -> Dict:
    """Scatter a single-request prefill cache (batch dim 1, KV padded to
    max_len) into row ``slot`` of the pool.  ``slot`` may be traced — the
    engine runs this jitted (donating the pool) so admission is one fused
    scatter, not an eager op per cache leaf."""
    def one(path, pl, nl):
        names = [getattr(p, "key", "") for p in path]
        stage_leaf = names[0] == "stages"
        row = jnp.take(nl, 0, axis=1 if stage_leaf else 0)
        if names[-1] in ("k", "v"):
            kind = cfg.block_kind(int(names[-2][3:]))
            tgt = pl.shape[2:] if stage_leaf else pl.shape[1:]
            if stage_leaf:
                tgt = (row.shape[0],) + tuple(tgt)
            row = _align_kv_row(row, tgt, kind, cfg)
        row = row.astype(pl.dtype)
        return pl.at[:, slot].set(row) if stage_leaf else pl.at[slot].set(row)

    return jax.tree_util.tree_map_with_path(one, pool, cache)


class ContinuousBatchingEngine:
    """Continuous batching over a fixed slot pool (per-sequence positions).

    Requests are admitted into free KV slots, prefilled one at a time
    (length-bucketed where exact), decoded concurrently — each sequence at
    its own position ``t[slot]`` — and evicted on stop-token / length,
    freeing the slot for the next queued request.
    """

    def __init__(self, cfg: ModelConfig, params, max_slots: int = 4,
                 max_len: int = 512, temperature: float = 0.0,
                 prefill_buckets: Optional[Sequence[int]] = None,
                 kv_mode: str = "dense", page_size: int = 16,
                 num_pages: Optional[int] = None):
        self.cfg = cfg
        self.params = params
        self.max_slots = max_slots
        self.max_len = max_len
        self.temperature = temperature
        if kv_mode not in ("dense", "paged"):
            raise ValueError(f"unknown kv_mode {kv_mode!r}")
        if kv_mode == "paged" and not paged_mod.can_page(cfg):
            raise ValueError(
                f"{cfg.name}: paged KV requires an all-global-attention "
                "stack with masked-mode routing — use kv_mode='dense'")
        self.kv_mode = kv_mode
        if prefill_buckets is not None and not can_bucket(cfg):
            raise ValueError(
                f"{cfg.name}: prefill bucketing pads prompts, which corrupts "
                "ring-buffer/SSM state and gather-mode capacity — this "
                "config requires exact-length prefill (prefill_buckets=None)")
        if prefill_buckets is None and can_bucket(cfg):
            prefill_buckets = default_buckets(max_len)
        self.scheduler = Scheduler(max_slots, max_len,
                                   buckets=prefill_buckets)
        self._decode = jax.jit(partial(model_lib.decode_step, cfg=cfg),
                               donate_argnums=(1,))
        self._prefill = jax.jit(partial(model_lib.prefill, cfg=cfg,
                                        pad_to=max_len))
        self._insert = jax.jit(partial(pool_insert, cfg=cfg),
                               donate_argnums=(0,))
        if kv_mode == "paged":
            self.n_attn = paged_mod.num_attention_layers(cfg)
            self.page_size = page_size
            # default pool: the dense pool's worst case (every token fresh
            # at every layer) — alloc-on-demand still keeps the *live*
            # footprint far below it; size it down to see backpressure.
            cap = max_len * self.n_attn
            self.num_pages = (num_pages if num_pages is not None
                              else max_slots * -(-cap // page_size))
            self.allocator = paged_mod.PageAllocator(
                self.num_pages, page_size, max_slots,
                slot_entry_capacity=cap)
            # paged prefill keeps the exact (bucketed) length — pages
            # replace the pool's max_len padding
            self._prefill_paged = jax.jit(partial(model_lib.prefill,
                                                  cfg=cfg))
            self._pack = jax.jit(partial(paged_mod.pack_prefill, cfg=cfg),
                                 donate_argnums=(0,))
            self._decode_paged = jax.jit(
                partial(model_lib.paged_decode_step, cfg=cfg),
                donate_argnums=(1,))
        self._uid = 0

    # -- request intake ----------------------------------------------------
    def submit(self, tokens: np.ndarray, max_new_tokens: int,
               stop_token: Optional[int] = None) -> int:
        """Queue one prompt; returns its uid."""
        uid = self._uid
        self._uid += 1
        req = Request(uid=uid, tokens=np.asarray(tokens, np.int32),
                      max_new_tokens=max_new_tokens, stop_token=stop_token)
        if self.kv_mode == "paged":
            # must cover both the lifetime worst case AND the admission
            # gate's requirement (prompt + one step of headroom) — a
            # request _can_place can never pass would park the queue
            # forever once accepted
            worst = max(self._worst_case_entries(req),
                        (req.prompt_len + 1) * self.n_attn)
            if self.allocator.pages_for(worst) > self.num_pages:
                raise ValueError(
                    f"request {uid}: worst-case KV ({worst} entries) "
                    f"exceeds the page pool ({self.num_pages} pages × "
                    f"{self.page_size}) — OOM-safe admission impossible")
        self.scheduler.submit(req)
        return uid

    # -- paged-mode memory policy -------------------------------------------
    def _worst_case_entries(self, req: Request) -> int:
        """Upper bound on one request's lifetime entry count: every stored
        token fresh at every attention layer (the last generated token is
        emitted but never fed, so it stores nothing)."""
        toks = min(self.max_len, req.prompt_len + req.max_new_tokens - 1)
        return toks * self.n_attn

    def _can_place(self, req: Request) -> bool:
        """Admission gate: enough *free pages* for the prompt's worst-case
        entries plus one decode step of headroom.  The run loop reserves
        every resident's next-step headroom *before* admission, so the
        free list seen here is what is genuinely spare — a newcomer is
        never admitted into pages the residents are about to need (which
        would just get it preempted back, throwing its prefill away).
        (Admission allocates only the measured entries afterwards, so this
        never over-commits.)"""
        need = req.prompt_len * self.n_attn + self.n_attn
        pages = self.allocator.pages_for(need)
        return (pages <= self.allocator.pages_per_slot
                and pages <= self.allocator.free_pages)

    # -- main loop ---------------------------------------------------------
    def run(self, rng: Optional[jax.Array] = None
            ) -> Dict[str, object]:
        """Drain the queue.  Returns {'results': {uid: RequestResult},
        'stats': ServeStats}."""
        if self.kv_mode == "paged":
            return self._run_paged(rng)
        return self._run_dense(rng)

    # -- run-loop bookkeeping shared by both KV modes ----------------------
    @staticmethod
    def _make_result(st: ActiveRequest, reason: str) -> RequestResult:
        st.finish_reason = reason
        return RequestResult(
            uid=st.req.uid,
            tokens=np.asarray(st.out_tokens, np.int32),
            prompt_len=st.req.prompt_len,
            ttft_s=st.first_token_s - st.submit_s,
            decode_s=st.decode_s,
            finish_reason=reason,
            kv_stored=st.kv_stored,
            kv_dense=st.kv_dense,
        )

    def _activate_prefilled(self, req: Request, slot: int, tok: int,
                            t_run: float, now: float, stats: ServeStats):
        """Register a freshly prefilled request.  Returns (state, reason):
        reason is "stop"/"length" when the first token already ends the
        request, else None."""
        stats.prefill_tokens += req.prompt_len
        stats.decode_tokens += 1
        st = ActiveRequest(req=req, slot=slot, pos=req.prompt_len,
                           next_token=tok, out_tokens=[tok],
                           submit_s=t_run, first_token_s=now)
        self.scheduler.activate(st)
        if req.stop_token is not None and tok == req.stop_token:
            return st, "stop"
        if req.max_new_tokens <= 1:
            return st, "length"
        return st, None

    def _advance_slot(self, st: ActiveRequest, tok: int,
                      g: Optional[np.ndarray], step_s: float,
                      stats: ServeStats, measure: bool,
                      n_layers: int) -> Optional[str]:
        """Post-decode bookkeeping for one resident (the fed token's KV
        was just written at st.pos).  Returns the finish reason or None."""
        st.decode_s += step_s
        if g is not None:
            st.kv_dense += n_layers
            st.kv_stored += (1 + int(g[1:].sum()) if measure else n_layers)
        st.pos += 1
        st.out_tokens.append(tok)
        st.next_token = tok
        stats.decode_tokens += 1
        if st.req.stop_token is not None and tok == st.req.stop_token:
            return "stop"
        if len(st.out_tokens) >= st.req.max_new_tokens:
            return "length"
        if st.pos >= self.max_len:
            return "max_len"
        return None

    def _run_dense(self, rng: Optional[jax.Array] = None
                   ) -> Dict[str, object]:
        """Fixed ``max_slots × max_len`` pool (the original engine mode)."""
        cfg = self.cfg
        sched = self.scheduler
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        stats = ServeStats()
        results: Dict[int, RequestResult] = {}
        L_attn = max(len(cfg.attention_layers), 1)
        measure = cfg.skip.enabled and cfg.skip.kv_reuse

        pool = init_pool(cfg, self.max_slots, self.max_len)
        feed = np.zeros((self.max_slots,), np.int32)
        pos = np.zeros((self.max_slots,), np.int32)
        t_run = time.time()
        keep_acc, keep_n = 0.0, 0.0

        def finish(slot: int, reason: str) -> None:
            st = sched.release(slot)
            results[st.req.uid] = self._make_result(st, reason)
            stats.requests_completed += 1

        while sched.has_work():
            # -- admission: prefill queued requests into free slots --------
            for slot, req in sched.admit():
                padded, last = sched.pad_prompt(req.tokens)
                t0 = time.time()
                logits, cache, _ = self._prefill(
                    self.params, {"tokens": jnp.asarray(padded[None])},
                    last_index=jnp.asarray([last], jnp.int32))
                pool = self._insert(pool, cache, jnp.int32(slot))
                rng, sub = jax.random.split(rng)
                tok = int(np.asarray(sample(logits, sub, self.temperature))[0])
                now = time.time()
                stats.prefill_s += now - t0
                _, reason = self._activate_prefilled(req, slot, tok,
                                                     t_run, now, stats)
                if reason:
                    finish(slot, reason)

            if not sched.active:
                continue

            # -- one ragged decode step over the whole pool ----------------
            for slot, st in sched.active.items():
                feed[slot] = st.next_token
                pos[slot] = st.pos
            t0 = time.time()
            logits, pool, dstats = self._decode(
                self.params, pool, {"tokens": jnp.asarray(feed[:, None])},
                jnp.asarray(pos))
            rng, sub = jax.random.split(rng)
            toks = np.asarray(sample(logits, sub, self.temperature))
            gates = (np.asarray(dstats["attn_gate"], np.float32)
                     if "attn_gate" in dstats else None)
            step_s = time.time() - t0
            stats.decode_s += step_s

            for slot in list(sched.active):
                st = sched.active[slot]
                g = gates[:, slot] if gates is not None else None
                if g is not None:
                    keep_acc += float(g.sum())
                    keep_n += L_attn
                reason = self._advance_slot(st, int(toks[slot]), g, step_s,
                                            stats, measure, L_attn)
                if reason:
                    finish(slot, reason)

        stats.attn_keep_frac = keep_acc / keep_n if keep_n else 1.0
        tot_dense = sum(r.kv_dense for r in results.values())
        tot_stored = sum(r.kv_stored for r in results.values())
        stats.kv_saved_fraction = (1.0 - tot_stored / tot_dense
                                   if tot_dense else 0.0)
        stats.kv_saved_analytic = analytic_kv_saved(cfg)
        return {"results": results, "stats": stats}

    def _run_paged(self, rng: Optional[jax.Array] = None
                   ) -> Dict[str, object]:
        """Paged-pool mode: KV lives in the store-once entry stream
        (``repro/kvcache/paged.py``) with alloc-on-demand pages.

        Per iteration: (1) admit while the head request's worst-case prompt
        entries fit in free pages; (2) *proactively* guarantee one decode
        step of page headroom for every resident slot — preempting the
        youngest resident (requeued at the head of the FIFO) if the free
        list runs dry, so the step itself can never OOM; (3) one ragged
        decode step over all slots; (4) append the measured fresh entries
        and the history-buffer hit accounting from the returned gate log.
        """
        cfg = self.cfg
        sched = self.scheduler
        alloc = self.allocator
        nA = self.n_attn
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        reuse = paged_mod.reuse_enabled(cfg)
        measure = cfg.skip.enabled and cfg.skip.kv_reuse
        stats = ServeStats(kv_mode="paged", page_size=self.page_size,
                           pages_total=self.num_pages)
        hist = history_mod.HistoryAccounting(nA, self.max_slots, reuse)
        results: Dict[int, RequestResult] = {}

        store = paged_mod.init_store(cfg, self.num_pages, self.page_size)
        feed = np.zeros((self.max_slots,), np.int32)
        pos = np.zeros((self.max_slots,), np.int32)
        t_run = time.time()
        keep_acc, keep_n = 0.0, 0.0
        admit_seq: Dict[int, int] = {}
        seq = 0

        def finish(slot: int, reason: str) -> None:
            st = sched.release(slot)
            alloc.release(slot)
            hist.on_release(slot)
            admit_seq.pop(slot, None)
            results[st.req.uid] = self._make_result(st, reason)
            stats.requests_completed += 1

        def preempt_youngest(exclude: int) -> bool:
            """OOM backpressure: evict the most recently admitted resident
            (≠ ``exclude``) and requeue it — its pages return to the free
            list and it will re-prefill from scratch later."""
            victims = [s for s in sched.active if s != exclude]
            if not victims:
                return False
            slot = max(victims, key=lambda s: admit_seq[s])
            st = sched.release(slot)
            alloc.release(slot)
            hist.on_release(slot)
            admit_seq.pop(slot, None)
            sched.requeue_front(st.req)
            stats.preemptions += 1
            return True

        while sched.has_work():
            # -- proactive headroom first: every resident can absorb one
            # full step before anyone new is let in (a newcomer admitted
            # into pages the residents need would be preempted right back,
            # throwing its prefill away)
            for slot in sorted(sched.active):
                if slot not in sched.active:     # preempted below
                    continue
                while not alloc.ensure(slot, int(alloc.fill[slot]) + nA):
                    if not preempt_youngest(exclude=slot):
                        raise RuntimeError(
                            f"page pool exhausted with a single resident "
                            f"request (slot {slot}) — submit() should have "
                            "rejected it")

            # -- admission: gated on free pages, not just free slots.
            # One per iteration so each _can_place check sees the pages the
            # previous admission actually consumed.  Admission itself
            # reserves the newcomer's first-step headroom (the +nA below).
            for slot, req in sched.admit(can_place=self._can_place,
                                         limit=1):
                padded, last = sched.pad_prompt(req.tokens)
                T0 = req.prompt_len
                t0 = time.time()
                logits, cache, pstats = self._prefill_paged(
                    self.params, {"tokens": jnp.asarray(padded[None])},
                    last_index=jnp.asarray([last], jnp.int32))
                gates = np.asarray(pstats["attn_gate"], np.float32)[:, 0]
                n_ent = paged_mod.prefill_entry_count(gates, T0, reuse)
                if not alloc.ensure(slot, n_ent + nA):
                    raise RuntimeError(
                        "page reservation failed after a successful "
                        "_can_place worst-case check — allocator bug")
                store = self._pack(store, cache,
                                   jnp.asarray(gates), jnp.int32(T0),
                                   jnp.asarray(alloc.block_table[slot]))
                alloc.append(slot, n_ent, nA * T0)
                hist.on_prefill(slot, gates, T0)
                rng, sub = jax.random.split(rng)
                tok = int(np.asarray(sample(logits, sub, self.temperature))[0])
                now = time.time()
                stats.prefill_s += now - t0
                _, reason = self._activate_prefilled(req, slot, tok,
                                                     t_run, now, stats)
                admit_seq[slot] = seq
                seq += 1
                if reason:
                    finish(slot, reason)

            if not sched.active:
                continue

            # -- one ragged decode step over the whole pool ----------------
            for slot, st in sched.active.items():
                feed[slot] = st.next_token
                pos[slot] = st.pos
            # bound the stream walk to the live chains instead of the
            # worst-case block-table width; power-of-two buckets keep the
            # number of compiled decode shapes logarithmic (the same
            # recompile-bounding trick as prefill length-bucketing)
            j_live = max(1, alloc.max_chain_pages())
            j_step = min(1 << (j_live - 1).bit_length(),
                         alloc.pages_per_slot)
            t0 = time.time()
            logits, store, dstats = self._decode_paged(
                self.params, store, {"tokens": jnp.asarray(feed[:, None])},
                jnp.asarray(pos),
                jnp.asarray(alloc.block_table[:, :j_step]),
                jnp.asarray(alloc.fill))
            rng, sub = jax.random.split(rng)
            toks = np.asarray(sample(logits, sub, self.temperature))
            gates = np.asarray(dstats["attn_gate"], np.float32)
            step_s = time.time() - t0
            stats.decode_s += step_s

            for slot in list(sched.active):
                st = sched.active[slot]
                g = gates[:, slot]
                fresh_n = int(1 + (g[1:] > 0.5).sum()) if reuse else nA
                alloc.append(slot, fresh_n, nA)
                hist.on_decode_step(slot, g)
                keep_acc += float(g.sum())
                keep_n += nA
                reason = self._advance_slot(st, int(toks[slot]), g, step_s,
                                            stats, measure, nA)
                if reason:
                    finish(slot, reason)

        stats.attn_keep_frac = keep_acc / keep_n if keep_n else 1.0
        tot_dense = sum(r.kv_dense for r in results.values())
        tot_stored = sum(r.kv_stored for r in results.values())
        stats.kv_saved_fraction = (1.0 - tot_stored / tot_dense
                                   if tot_dense else 0.0)
        stats.kv_saved_analytic = analytic_kv_saved(cfg)
        stats.pages_peak = alloc.stats.pages_peak
        stats.kv_entries_stored = alloc.stats.entries_appended
        stats.kv_entries_dense = alloc.stats.entries_dense
        stats.history_hit_rate = hist.hit_rate
        stats.history_hits_per_layer = hist.per_layer_hit_rate
        return {"results": results, "stats": stats}
