"""Serving engines: the paper's end-to-end inference pipeline.

prefill (gather/compacted execution) → autoregressive decode with dynamic
routing and cross-layer KV reuse, with KV-storage accounting *measured*
from the per-step execution-gate log (``stats['attn_gate']``) instead of
the analytic keep-rate estimate.

Two engines share the jitted ``model.decode_step`` path:

``ServeEngine``
    Lock-step batch: one fixed batch, every sequence at the same position.
    Kept as the baseline the continuous engine is benchmarked against.

``ContinuousBatchingEngine``
    Slot-based continuous batching (the serving pattern SkipOPU's
    dynamically allocated compute pays off in): a fixed ``max_slots ×
    max_len`` KV pool allocated once, a FIFO request queue with prefill
    length-bucketing, per-sequence decode positions (``t: [B]``), and
    admission/eviction as requests start/stop — see
    ``repro/serve/scheduler.py`` and docs/serving.md.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import LOCAL, ModelConfig
from repro.core import kv_reuse
from repro.models import model as model_lib
from repro.serve.sampling import sample
from repro.serve.scheduler import (ActiveRequest, Request, Scheduler,
                                   can_bucket, default_buckets)


@dataclasses.dataclass
class ServeStats:
    prefill_tokens: int = 0
    decode_tokens: int = 0
    prefill_s: float = 0.0
    decode_s: float = 0.0
    attn_keep_frac: float = 1.0
    kv_saved_fraction: float = 0.0        # measured from logged gates
    kv_saved_analytic: float = 0.0        # configured-keep-rate estimate
    requests_completed: int = 0

    @property
    def decode_tok_per_s(self) -> float:
        return self.decode_tokens / self.decode_s if self.decode_s else 0.0


@dataclasses.dataclass
class RequestResult:
    """Per-request outcome + serving metrics."""
    uid: int
    tokens: np.ndarray                   # generated tokens (incl. stop token)
    prompt_len: int
    ttft_s: float                        # submit → first token
    decode_s: float                      # time in this request's decode steps
    finish_reason: str                   # "length" | "stop" | "max_len"
    kv_stored: int = 0                   # measured compact-store entries
    kv_dense: int = 0                    # dense-baseline entries

    @property
    def decode_tokens(self) -> int:
        return int(self.tokens.shape[0])

    @property
    def decode_tok_per_s(self) -> float:
        n = self.decode_tokens - 1       # first token is prefill's
        return n / self.decode_s if self.decode_s > 0 and n > 0 else 0.0

    @property
    def kv_saved_fraction(self) -> float:
        if self.kv_dense == 0:
            return 0.0
        return 1.0 - self.kv_stored / self.kv_dense


def analytic_kv_saved(cfg: ModelConfig) -> float:
    """Compact-store saving at the *configured* keep rate: layer 0 dense +
    keep_prob elsewhere.  The measured per-run figure comes from the decode
    gate log via kv_reuse.storage_saved_fraction."""
    L = max(len(cfg.attention_layers), 1)
    if not (cfg.skip.enabled and cfg.skip.kv_reuse):
        return 0.0
    return 1.0 - (1.0 + (L - 1) * cfg.skip.keep_prob) / L


def _measured_saved_fraction(gates_per_step: List[np.ndarray],
                             cfg: ModelConfig) -> float:
    """Lock-step gate log [L, B] per step -> measured storage saving."""
    if not gates_per_step or not (cfg.skip.enabled and cfg.skip.kv_reuse):
        return 0.0
    g = jnp.asarray(np.stack(gates_per_step, axis=-1))   # [L, B, steps]
    return float(kv_reuse.storage_saved_fraction(g))


class ServeEngine:
    """Lock-step batched engine (baseline; one shared decode position)."""

    def __init__(self, cfg: ModelConfig, params, max_len: int = 512,
                 temperature: float = 0.0):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.temperature = temperature
        self._decode = jax.jit(partial(model_lib.decode_step, cfg=cfg),
                               donate_argnums=(1,))
        self._prefill = jax.jit(partial(model_lib.prefill, cfg=cfg,
                                        pad_to=max_len))

    def generate(self, prompts: np.ndarray, max_new_tokens: int,
                 rng: Optional[jax.Array] = None) -> Dict[str, np.ndarray]:
        """prompts: [B, T0] int32 (right-aligned, no padding support needed
        for the synthetic workloads).  Returns tokens + stats."""
        cfg = self.cfg
        B, T0 = prompts.shape
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        stats = ServeStats()

        t0 = time.time()
        logits, cache, pstats = self._prefill(self.params,
                                              {"tokens": jnp.asarray(prompts)})
        jax.block_until_ready(logits)
        stats.prefill_s = time.time() - t0
        stats.prefill_tokens = B * T0

        out = np.zeros((B, max_new_tokens), np.int32)
        keep_acc, keep_n = 0.0, 0
        gates_per_step: List[np.ndarray] = []
        emitted = 0
        tok = sample(logits, rng, self.temperature)
        t0 = time.time()
        for i in range(max_new_tokens):
            out[:, i] = np.asarray(tok)
            emitted += B
            pos = T0 + i
            if pos >= self.max_len:
                break
            logits, cache, dstats = self._decode(
                self.params, cache, {"tokens": tok[:, None]},
                jnp.int32(pos))
            if "attn_gate" in dstats:
                gates_per_step.append(
                    np.asarray(dstats["attn_gate"], np.float32))
            keep_acc += float(dstats["keep_frac_sum"])
            keep_n += max(float(dstats["n_routed"]), 1.0)
            rng, sub = jax.random.split(rng)
            tok = sample(logits, sub, self.temperature)
        jax.block_until_ready(logits)
        stats.decode_s = time.time() - t0
        stats.decode_tokens = emitted           # tokens actually emitted

        stats.attn_keep_frac = keep_acc / max(keep_n, 1.0)
        stats.kv_saved_fraction = _measured_saved_fraction(gates_per_step, cfg)
        stats.kv_saved_analytic = analytic_kv_saved(cfg)
        return {"tokens": out, "stats": stats}


# ---------------------------------------------------------------------------
# Slot-pool plumbing
# ---------------------------------------------------------------------------

def init_pool(cfg: ModelConfig, max_slots: int, max_len: int) -> Dict:
    """The continuous engine's KV pool: ``max_slots`` cache rows allocated
    once (the paper's fixed on-chip KV history buffer analogue)."""
    return model_lib.init_decode_cache(cfg, max_slots, max_len)

def _align_kv_row(row: jnp.ndarray, target_shape, kind: str,
                  cfg: ModelConfig) -> jnp.ndarray:
    """Reshape one prefill k/v cache row (``[.., T, Hkv, dh]``, padded to
    max_len) to the pool's layout for its layer kind: head-major transpose
    for ``bhtd`` pools, truncation to the ring extent for window layers
    (positions < W: ring slot s ≡ position s, so the prefix IS the ring)."""
    if kind == LOCAL and cfg.window_size:
        W = target_shape[-3]
        if row.shape[-3] != W:
            row = jax.lax.slice_in_dim(row, 0, W, axis=row.ndim - 3)
    elif cfg.kv_cache_layout == "bhtd":
        row = row.swapaxes(-3, -2)           # prefill collects [.., T, H, d]
    return row


def pool_insert(pool: Dict, cache: Dict, slot, cfg: ModelConfig) -> Dict:
    """Scatter a single-request prefill cache (batch dim 1, KV padded to
    max_len) into row ``slot`` of the pool.  ``slot`` may be traced — the
    engine runs this jitted (donating the pool) so admission is one fused
    scatter, not an eager op per cache leaf."""
    def one(path, pl, nl):
        names = [getattr(p, "key", "") for p in path]
        stage_leaf = names[0] == "stages"
        row = jnp.take(nl, 0, axis=1 if stage_leaf else 0)
        if names[-1] in ("k", "v"):
            kind = cfg.block_kind(int(names[-2][3:]))
            tgt = pl.shape[2:] if stage_leaf else pl.shape[1:]
            if stage_leaf:
                tgt = (row.shape[0],) + tuple(tgt)
            row = _align_kv_row(row, tgt, kind, cfg)
        row = row.astype(pl.dtype)
        return pl.at[:, slot].set(row) if stage_leaf else pl.at[slot].set(row)

    return jax.tree_util.tree_map_with_path(one, pool, cache)


class ContinuousBatchingEngine:
    """Continuous batching over a fixed slot pool (per-sequence positions).

    Requests are admitted into free KV slots, prefilled one at a time
    (length-bucketed where exact), decoded concurrently — each sequence at
    its own position ``t[slot]`` — and evicted on stop-token / length,
    freeing the slot for the next queued request.
    """

    def __init__(self, cfg: ModelConfig, params, max_slots: int = 4,
                 max_len: int = 512, temperature: float = 0.0,
                 prefill_buckets: Optional[Sequence[int]] = None):
        self.cfg = cfg
        self.params = params
        self.max_slots = max_slots
        self.max_len = max_len
        self.temperature = temperature
        if prefill_buckets is not None and not can_bucket(cfg):
            raise ValueError(
                f"{cfg.name}: prefill bucketing pads prompts, which corrupts "
                "ring-buffer/SSM state and gather-mode capacity — this "
                "config requires exact-length prefill (prefill_buckets=None)")
        if prefill_buckets is None and can_bucket(cfg):
            prefill_buckets = default_buckets(max_len)
        self.scheduler = Scheduler(max_slots, max_len,
                                   buckets=prefill_buckets)
        self._decode = jax.jit(partial(model_lib.decode_step, cfg=cfg),
                               donate_argnums=(1,))
        self._prefill = jax.jit(partial(model_lib.prefill, cfg=cfg,
                                        pad_to=max_len))
        self._insert = jax.jit(partial(pool_insert, cfg=cfg),
                               donate_argnums=(0,))
        self._uid = 0

    # -- request intake ----------------------------------------------------
    def submit(self, tokens: np.ndarray, max_new_tokens: int,
               stop_token: Optional[int] = None) -> int:
        """Queue one prompt; returns its uid."""
        uid = self._uid
        self._uid += 1
        self.scheduler.submit(Request(uid=uid,
                                      tokens=np.asarray(tokens, np.int32),
                                      max_new_tokens=max_new_tokens,
                                      stop_token=stop_token))
        return uid

    # -- main loop ---------------------------------------------------------
    def run(self, rng: Optional[jax.Array] = None
            ) -> Dict[str, object]:
        """Drain the queue.  Returns {'results': {uid: RequestResult},
        'stats': ServeStats}."""
        cfg = self.cfg
        sched = self.scheduler
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        stats = ServeStats()
        results: Dict[int, RequestResult] = {}
        L_attn = max(len(cfg.attention_layers), 1)
        measure = cfg.skip.enabled and cfg.skip.kv_reuse

        pool = init_pool(cfg, self.max_slots, self.max_len)
        feed = np.zeros((self.max_slots,), np.int32)
        pos = np.zeros((self.max_slots,), np.int32)
        t_run = time.time()
        keep_acc, keep_n = 0.0, 0.0

        def finish(slot: int, reason: str) -> None:
            st = sched.release(slot)
            st.finish_reason = reason
            results[st.req.uid] = RequestResult(
                uid=st.req.uid,
                tokens=np.asarray(st.out_tokens, np.int32),
                prompt_len=st.req.prompt_len,
                ttft_s=st.first_token_s - st.submit_s,
                decode_s=st.decode_s,
                finish_reason=reason,
                kv_stored=st.kv_stored,
                kv_dense=st.kv_dense,
            )
            stats.requests_completed += 1

        while sched.has_work():
            # -- admission: prefill queued requests into free slots --------
            for slot, req in sched.admit():
                padded, last = sched.pad_prompt(req.tokens)
                t0 = time.time()
                logits, cache, _ = self._prefill(
                    self.params, {"tokens": jnp.asarray(padded[None])},
                    last_index=jnp.asarray([last], jnp.int32))
                pool = self._insert(pool, cache, jnp.int32(slot))
                rng, sub = jax.random.split(rng)
                tok = int(np.asarray(sample(logits, sub, self.temperature))[0])
                now = time.time()
                stats.prefill_s += now - t0
                stats.prefill_tokens += req.prompt_len
                stats.decode_tokens += 1
                st = ActiveRequest(req=req, slot=slot, pos=req.prompt_len,
                                   next_token=tok, out_tokens=[tok],
                                   submit_s=t_run, first_token_s=now)
                sched.activate(st)
                if req.stop_token is not None and tok == req.stop_token:
                    finish(slot, "stop")
                elif req.max_new_tokens <= 1:
                    finish(slot, "length")

            if not sched.active:
                continue

            # -- one ragged decode step over the whole pool ----------------
            for slot, st in sched.active.items():
                feed[slot] = st.next_token
                pos[slot] = st.pos
            t0 = time.time()
            logits, pool, dstats = self._decode(
                self.params, pool, {"tokens": jnp.asarray(feed[:, None])},
                jnp.asarray(pos))
            rng, sub = jax.random.split(rng)
            toks = np.asarray(sample(logits, sub, self.temperature))
            gates = (np.asarray(dstats["attn_gate"], np.float32)
                     if "attn_gate" in dstats else None)
            step_s = time.time() - t0
            stats.decode_s += step_s

            for slot in list(sched.active):
                st = sched.active[slot]
                st.decode_s += step_s
                # the fed token's KV was just written at st.pos
                if gates is not None:
                    keep_acc += float(gates[:, slot].sum())
                    keep_n += L_attn
                    st.kv_dense += L_attn
                    st.kv_stored += (1 + int(gates[1:, slot].sum())
                                     if measure else L_attn)
                st.pos += 1
                tok = int(toks[slot])
                st.out_tokens.append(tok)
                st.next_token = tok
                stats.decode_tokens += 1
                if st.req.stop_token is not None and tok == st.req.stop_token:
                    finish(slot, "stop")
                elif len(st.out_tokens) >= st.req.max_new_tokens:
                    finish(slot, "length")
                elif st.pos >= self.max_len:
                    finish(slot, "max_len")

        stats.attn_keep_frac = keep_acc / keep_n if keep_n else 1.0
        tot_dense = sum(r.kv_dense for r in results.values())
        tot_stored = sum(r.kv_stored for r in results.values())
        stats.kv_saved_fraction = (1.0 - tot_stored / tot_dense
                                   if tot_dense else 0.0)
        stats.kv_saved_analytic = analytic_kv_saved(cfg)
        return {"results": results, "stats": stats}
