"""Batched serving engine: the paper's end-to-end inference pipeline.

prefill (gather/compacted execution) → autoregressive decode with dynamic
routing and cross-layer KV reuse, while a ``CompactKVStore`` tracks the
storage/traffic the SkipOPU memory system would see (feeding the Fig. 8 /
Fig. 9 / 25.4 %-storage reproductions).

The jit'd decode path is the same ``model.decode_step`` the dry-run lowers
— this engine adds request batching, sampling, stop handling, and the
bookkeeping layers.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import kv_reuse
from repro.kvcache.cache import KVStats
from repro.models import model as model_lib
from repro.serve.sampling import sample


@dataclasses.dataclass
class ServeStats:
    prefill_tokens: int = 0
    decode_tokens: int = 0
    prefill_s: float = 0.0
    decode_s: float = 0.0
    attn_keep_frac: float = 1.0
    kv_saved_fraction: float = 0.0

    @property
    def decode_tok_per_s(self) -> float:
        return self.decode_tokens / self.decode_s if self.decode_s else 0.0


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, max_len: int = 512,
                 temperature: float = 0.0):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.temperature = temperature
        self._decode = jax.jit(partial(model_lib.decode_step, cfg=cfg),
                               donate_argnums=(1,))
        self._prefill = jax.jit(partial(model_lib.prefill, cfg=cfg,
                                        pad_to=max_len))
        # per-(layer, step) execution gates for the storage accounting
        self._gate_log: List[np.ndarray] = []

    def generate(self, prompts: np.ndarray, max_new_tokens: int,
                 rng: Optional[jax.Array] = None) -> Dict[str, np.ndarray]:
        """prompts: [B, T0] int32 (right-aligned, no padding support needed
        for the synthetic workloads).  Returns tokens + stats."""
        cfg = self.cfg
        B, T0 = prompts.shape
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        stats = ServeStats()

        t0 = time.time()
        logits, cache, pstats = self._prefill(self.params,
                                              {"tokens": jnp.asarray(prompts)})
        jax.block_until_ready(logits)
        stats.prefill_s = time.time() - t0
        stats.prefill_tokens = B * T0

        out = np.zeros((B, max_new_tokens), np.int32)
        keep_acc, keep_n = 0.0, 0
        gates_per_step = []
        tok = sample(logits, rng, self.temperature)
        t0 = time.time()
        for i in range(max_new_tokens):
            out[:, i] = np.asarray(tok)
            pos = T0 + i
            if pos >= self.max_len:
                break
            logits, cache, dstats = self._decode(
                self.params, cache, {"tokens": tok[:, None]},
                jnp.int32(pos))
            if "attn_gate" in dstats:
                g = np.asarray(dstats["attn_gate"], np.float32)
                gates_per_step.append(g)
            keep_acc += float(dstats["keep_frac_sum"])
            keep_n += max(float(dstats["n_routed"]), 1.0)
            rng, sub = jax.random.split(rng)
            tok = sample(logits, sub, self.temperature)
        jax.block_until_ready(logits)
        stats.decode_s = time.time() - t0
        stats.decode_tokens = B * max_new_tokens

        stats.attn_keep_frac = keep_acc / max(keep_n, 1.0)
        stats.kv_saved_fraction = self.kv_storage_saved(T0 + max_new_tokens)
        return {"tokens": out, "stats": stats}

    # ------------------------------------------------------------------
    def kv_storage_saved(self, total_len: int) -> float:
        """Analytic compact-store saving at the configured keep rate:
        layer 0 dense + keep_prob elsewhere (kv_reuse.storage_saved_fraction
        gives the exact per-run figure in the benchmark)."""
        L = max(len(self.cfg.attention_layers), 1)
        if not (self.cfg.skip.enabled and self.cfg.skip.kv_reuse):
            return 0.0
        keep = self.cfg.skip.keep_prob
        stored = 1.0 + (L - 1) * keep
        return 1.0 - stored / L
