"""Typed error hierarchy for the serving engines.

SkipOPU's dynamically allocated computation makes resource demand
unpredictable at serve time — page consumption depends on per-token
routing decisions — so admission rejection, OOM backpressure and
preemption are *normal-path* scheduling events in this engine, not rare
errors.  This module gives each of them a type a caller can catch and
act on, replacing the bare ``RuntimeError``/``ValueError`` raises that
used to flow out of ``serve/engine.py``, ``serve/scheduler.py`` and
``kvcache/paged.py``.

The hierarchy deliberately double-inherits from the builtin types the
old raises used (``AdmissionRejected`` is-a ``ValueError``,
``PageExhausted``/``EngineAborted`` are-a ``RuntimeError``), so callers
written against the old contract keep working while new callers can
catch the precise class.

    ServeError(Exception)
    ├── ConfigError(ServeError, ValueError)         bad EngineConfig field
    ├── AdmissionRejected(ServeError, ValueError)   submit() refused
    ├── PageExhausted(ServeError, RuntimeError)     paged KV out of memory
    ├── DeadlineExceeded(ServeError, TimeoutError)  per-request deadline hit
    └── EngineAborted(ServeError, RuntimeError)     run() cannot continue
        ├── HungDispatch                            watchdog fired
        └── SimulatedKill                           fault-injected host kill

Recovery contracts per type live in docs/robustness.md.
"""
from __future__ import annotations

from typing import Optional


class ServeError(Exception):
    """Base class of every serving-layer error."""


class ConfigError(ServeError, ValueError):
    """An ``EngineConfig`` (or legacy engine kwarg) is invalid — out of
    range, or a combination the engine cannot serve (e.g. ``spec_k`` with
    ``decode_steps > 1``, paged-only levers under ``kv_mode='dense'``).
    Raised at construction time, before any device work.  Is-a
    ``ValueError`` because these conditions raised bare ``ValueError``
    before the config redesign."""


class AdmissionRejected(ServeError, ValueError):
    """``submit()`` refused the request — it can never be served (prompt
    too long for the pool, worst-case KV exceeding the page pool) or the
    engine is shedding load (queue-delay bound exceeded).  The request
    was NOT enqueued; the caller owns retry/redirect policy.

    ``reason`` is a stable machine-readable tag: ``"prompt_too_long"``,
    ``"kv_worst_case"``, ``"queue_depth"``, ``"queue_delay"``,
    ``"empty_prompt"``."""

    def __init__(self, message: str, reason: str = "rejected",
                 uid: Optional[int] = None):
        super().__init__(message)
        self.reason = reason
        self.uid = uid


class PageExhausted(ServeError, RuntimeError):
    """The paged KV free list cannot cover a required reservation and no
    recovery path (epoch shrink, preemption) remains — e.g. a single
    resident's own growth exceeds the pool, which OOM-safe admission
    should have made impossible.  Carries the allocator geometry for
    diagnosis."""

    def __init__(self, message: str, slot: Optional[int] = None,
                 free_pages: Optional[int] = None,
                 pages_total: Optional[int] = None):
        super().__init__(message)
        self.slot = slot
        self.free_pages = free_pages
        self.pages_total = pages_total


class DeadlineExceeded(ServeError, TimeoutError):
    """A request's deadline elapsed.  The engine normally *returns* this
    condition as ``RequestResult.finish_reason == "deadline"`` rather
    than raising; the exception type exists for callers that poll or
    cancel synchronously."""

    def __init__(self, message: str, uid: Optional[int] = None,
                 elapsed_s: float = 0.0, deadline_s: float = 0.0):
        super().__init__(message)
        self.uid = uid
        self.elapsed_s = elapsed_s
        self.deadline_s = deadline_s


class EngineAborted(ServeError, RuntimeError):
    """``run()`` cannot make further progress and is tearing down.  The
    trace (if tracing was on and had an output path) is flushed before
    the raise and its path attached, so the failure is diagnosable
    post-mortem with ``tools/trace_summary.py``."""

    def __init__(self, message: str, trace_path: Optional[str] = None):
        super().__init__(message)
        self.trace_path = trace_path


class HungDispatch(EngineAborted):
    """The watchdog declared a device dispatch hung: one sync exceeded
    the hard timeout (``watchdog_s``).  Carries the phase and the
    observed wall time."""

    def __init__(self, message: str, phase: str = "dispatch",
                 elapsed_s: float = 0.0,
                 trace_path: Optional[str] = None):
        super().__init__(message, trace_path=trace_path)
        self.phase = phase
        self.elapsed_s = elapsed_s


class SimulatedKill(EngineAborted):
    """Fault-injected host death at a step boundary (``FaultPlan`` kind
    ``"kill"``).  Raised *after* the boundary snapshot, so a
    kill-and-resume test (or a real restart) loses nothing — see
    ``serve/snapshot.py`` and docs/robustness.md."""
