"""Deterministic fault injection + dispatch watchdog for the serve engine.

Every recovery path the engine claims — OOM-safe preemption, dispatch
retry, hung-dispatch detection, kill-and-resume — is exercised by tests
through this module instead of hoped-for.  A ``FaultPlan`` schedules
faults by (kind, engine iteration) and the engine consults it at its
existing seams in all four run loops:

  ====================  =====================================================
  kind                  seam and recovery contract
  ====================  =====================================================
  ``"oom"``             headroom/admission seam (paged): ``pages`` free pages
                        are hidden from the allocator for that iteration, so
                        ``ensure()`` fails exactly as if residents had filled
                        the pool → the engine's normal backpressure runs
                        (epoch shrink, then youngest-by-submit preemption).
                        Pages are returned at the end of the iteration.
  ``"dispatch_error"``  dispatch seam: ``FaultInjected`` raised *before* the
                        jitted call (donated buffers untouched) → the loop
                        abandons the iteration, counts it, and re-plans; no
                        token is lost, survivors are bit-identical.
  ``"stall"``           sync seam: the host sleeps ``stall_s`` inside the
                        sync span, emulating a hung device dispatch → the
                        ``Watchdog`` observes the inflated sync and either
                        records a straggler strike or (past its hard
                        timeout) raises ``HungDispatch`` with the PR-7
                        trace attached.
  ``"kill"``            step boundary, *after* the boundary snapshot:
                        ``SimulatedKill`` propagates out of ``run()``
                        uncaught, emulating process death.  A fresh engine
                        ``resume()``s from the snapshot directory and the
                        survivors' tokens are bit-identical.
  ====================  =====================================================

Faults fire exactly once (pop semantics); ``fired`` / ``unfired()``
expose what actually triggered so tests can assert the plan was
consumed.  Scheduling is by the engine's iteration counter
(``_RunState.disp_idx``), which is deterministic for a fixed workload —
no wall clock, no randomness.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

from repro.serve.errors import HungDispatch, ServeError


class FaultInjected(ServeError, RuntimeError):
    """The injected dispatch exception (kind ``"dispatch_error"``).
    Raised at the dispatch seam and caught by the run loop's retry path;
    escaping to the caller means the recovery path regressed."""


@dataclasses.dataclass(frozen=True)
class Fault:
    """One scheduled fault.

    Fields:
      kind    — "oom" | "dispatch_error" | "stall" | "kill".
      step    — engine iteration (dispatch/epoch index) at which to fire.
      pages   — "oom": free pages to hide for that iteration (0 = all).
      stall_s — "stall": seconds the sync seam sleeps.
      message — carried into the raised exception / trace instant.
    """
    kind: str
    step: int
    pages: int = 0
    stall_s: float = 0.0
    message: str = "injected fault"

    KINDS = ("oom", "dispatch_error", "stall", "kill")

    def __post_init__(self):
        if self.kind not in self.KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r} "
                             f"(one of {self.KINDS})")
        if self.step < 0:
            raise ValueError("fault step must be >= 0")


class FaultPlan:
    """A deterministic schedule of faults, consumed by the engine seams.

    ``take(kind, step)`` pops (at most one per call) a matching fault —
    a fault fires exactly once.  An empty plan (``FaultPlan()``) is inert
    and costs a dict lookup per seam, so the engine consults it
    unconditionally."""

    def __init__(self, faults: Optional[List[Fault]] = None):
        self._pending: List[Fault] = sorted(faults or [],
                                            key=lambda f: f.step)
        self.fired: List[Fault] = []

    def __bool__(self) -> bool:
        return bool(self._pending)

    def take(self, kind: str, step: int) -> Optional[Fault]:
        """Pop the first pending fault of ``kind`` scheduled at or before
        ``step`` (late seams still fire a fault whose exact iteration was
        skipped — e.g. an "oom" scheduled into an iteration that had no
        residents)."""
        for i, f in enumerate(self._pending):
            if f.kind == kind and f.step <= step:
                self.fired.append(self._pending.pop(i))
                return self.fired[-1]
            if f.step > step:
                break
        return None

    def unfired(self) -> List[Fault]:
        """Faults that never triggered (a test asserting full consumption
        catches seams that silently stopped consulting the plan)."""
        return list(self._pending)


def as_fault_plan(faults) -> FaultPlan:
    """Normalize the engine's ``faults=`` argument: None -> empty plan,
    a FaultPlan -> itself, an iterable of Fault -> a plan over it."""
    if faults is None:
        return FaultPlan()
    if isinstance(faults, FaultPlan):
        return faults
    return FaultPlan(list(faults))


def sleep_stall(seconds: float) -> None:
    """The injected stall (its own function so tests can monkeypatch the
    clock if they ever need a faster suite)."""
    time.sleep(seconds)


class Watchdog:
    """Hung-dispatch detection built on the ``StragglerMonitor`` idiom
    (``train/fault_tolerance.py``): per-dispatch wall-time tracking
    against a trailing median, plus a *hard* timeout that converts a hung
    sync into a diagnosable ``HungDispatch`` failure.

    Two thresholds:
      * ``timeout_s`` — absolute bound on one dispatch+sync; exceeding it
        raises (after the engine flushes its trace, whose path rides on
        the exception).  ``None`` disables the hard bound.
      * ``factor`` × trailing median — a *strike* (recorded, surfaced as
        the ``watchdog_strikes_total`` counter and a ``watchdog`` trace
        instant), mirroring ``StragglerMonitor.observe``.  Needs
        ``min_samples`` observations before it judges, so cold-start
        compile steps don't count.
    """

    def __init__(self, timeout_s: Optional[float] = None,
                 factor: float = 10.0, window: int = 20,
                 min_samples: int = 5):
        self.timeout_s = timeout_s
        self.factor = factor
        self.window = window
        self.min_samples = min_samples
        self._times: List[float] = []
        self.strikes = 0

    def observe(self, phase: str, seconds: float) -> bool:
        """Record one dispatch+sync wall time.  Returns True when it
        counts as a straggler strike; raises ``HungDispatch`` when it
        breaches the hard timeout."""
        if self.timeout_s is not None and seconds > self.timeout_s:
            raise HungDispatch(
                f"{phase} took {seconds:.3f}s, watchdog timeout is "
                f"{self.timeout_s:.3f}s — dispatch declared hung",
                phase=phase, elapsed_s=seconds)
        self._times.append(seconds)
        if len(self._times) > self.window:
            self._times.pop(0)
        if len(self._times) < self.min_samples:
            return False
        med = sorted(self._times[:-1])[len(self._times[:-1]) // 2]
        if seconds > self.factor * med:
            self.strikes += 1
            return True
        return False
