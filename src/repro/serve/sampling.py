"""Token sampling."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sample(logits: jnp.ndarray, rng, temperature: float = 0.0,
           top_k: int = 0) -> jnp.ndarray:
    """logits: [B, V] -> [B] int32."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    lf = logits.astype(jnp.float32) / temperature
    if top_k:
        vals, _ = jax.lax.top_k(lf, top_k)
        lf = jnp.where(lf < vals[:, -1:], -1e30, lf)
    return jax.random.categorical(rng, lf).astype(jnp.int32)


def split_sample(logits: jnp.ndarray, rng, temperature: float = 0.0,
                 top_k: int = 0):
    """One decode step's sampling under a carried rng: split the key
    exactly once — mirroring the host engines' per-step split, so the
    device-resident decode loop consumes the same key sequence — and
    sample.  Returns (new_rng, tokens [B] int32)."""
    rng, sub = jax.random.split(rng)
    return rng, sample(logits, sub, temperature, top_k)
