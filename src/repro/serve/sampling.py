"""Token sampling — plain per-step sampling plus the speculative-decoding
accept/resample primitives (docs/speculative.md).

The speculative helpers are deliberately *pure numpy on the host*: the
engine computes acceptance once per window after its single sync, and the
property tests fuzz the exact same functions against the analytic
distribution oracle (``emitted_distribution``) with no device in the loop.
"""
from __future__ import annotations

from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def sample(logits: jnp.ndarray, rng, temperature: float = 0.0,
           top_k: int = 0) -> jnp.ndarray:
    """logits: [B, V] -> [B] int32."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    lf = logits.astype(jnp.float32) / temperature
    if top_k:
        vals, _ = jax.lax.top_k(lf, top_k)
        lf = jnp.where(lf < vals[:, -1:], -1e30, lf)
    return jax.random.categorical(rng, lf).astype(jnp.int32)


def split_sample(logits: jnp.ndarray, rng, temperature: float = 0.0,
                 top_k: int = 0):
    """One decode step's sampling under a carried rng: split the key
    exactly once — mirroring the host engines' per-step split, so the
    device-resident decode loop consumes the same key sequence — and
    sample.  Returns (new_rng, tokens [B] int32)."""
    rng, sub = jax.random.split(rng)
    return rng, sample(logits, sub, temperature, top_k)


# -- speculative decoding: accept / resample (host-side, numpy) -----------
#
# One verify window feeds C = k+1 tokens [f0, d_1..d_k] at positions
# t..t+k; column j of the verifier's logits is the target model's
# response to the prefix ending in the j-th fed token, so draft d_{j+1}
# is judged against column j and the correction after accepting ``a``
# drafts comes from column ``a``.

def greedy_verify(target_tokens: np.ndarray, draft_tokens: np.ndarray
                  ) -> Tuple[np.ndarray, np.ndarray]:
    """Greedy (temperature-0) window acceptance.

    target_tokens: [B, k+1] — per-column argmax of the verify logits.
    draft_tokens:  [B, k]   — the draft loop's proposals.
    Returns (accepted [B] int, correction [B] int32): ``accepted[b]`` is
    the length of the longest prefix of drafts matching the verifier's
    argmax chain, and ``correction[b] = target_tokens[b, accepted[b]]``
    is the bonus/correction token — so every window emits
    ``accepted + 1`` tokens and the emitted chain is exactly what plain
    greedy decoding would have produced (induction on the prefix)."""
    target_tokens = np.asarray(target_tokens)
    draft_tokens = np.asarray(draft_tokens)
    match = draft_tokens == target_tokens[:, :-1]
    accepted = np.cumprod(match, axis=1).sum(axis=1).astype(np.int64)
    correction = np.take_along_axis(
        target_tokens, accepted[:, None], axis=1)[:, 0].astype(np.int32)
    return accepted, correction


def softmax_probs(logits: np.ndarray, temperature: float) -> np.ndarray:
    """Numerically stable host softmax over the last axis at the given
    temperature (> 0), in float64 so the exactness oracle holds tight."""
    z = np.asarray(logits, np.float64) / float(temperature)
    z -= z.max(axis=-1, keepdims=True)
    e = np.exp(z)
    return e / e.sum(axis=-1, keepdims=True)


def residual_distribution(p_draft: np.ndarray, p_target: np.ndarray
                          ) -> np.ndarray:
    """Rejection-path distribution ``norm(max(0, p_target - p_draft))``.
    Degenerate case (p_draft ≥ p_target everywhere, zero residual mass —
    only possible when the distributions coincide) falls back to
    ``p_target``, which is the correct limit."""
    res = np.maximum(np.asarray(p_target, np.float64)
                     - np.asarray(p_draft, np.float64), 0.0)
    s = res.sum(axis=-1, keepdims=True)
    safe = np.where(s > 0.0, res / np.where(s == 0.0, 1.0, s), p_target)
    return safe


def emitted_distribution(p_draft: np.ndarray, p_target: np.ndarray
                         ) -> np.ndarray:
    """Analytic marginal of the first emitted token under
    accept-with-prob-min(1, pt/pd) + residual resample:

        P(emit v) = min(pd[v], pt[v]) + (1 - Σ_u min(pd[u], pt[u])) · res[v]

    The speculative-sampling identity says this equals ``p_target``
    exactly — the oracle the Hypothesis fuzz asserts against."""
    mn = np.minimum(np.asarray(p_draft, np.float64),
                    np.asarray(p_target, np.float64))
    res = residual_distribution(p_draft, p_target)
    return mn + (1.0 - mn.sum(axis=-1, keepdims=True)) * res


def inverse_cdf_sample(p: np.ndarray, u: float) -> int:
    """Deterministic categorical draw: smallest index whose CDF exceeds
    ``u`` (ties broken low, u ∈ [0, 1))."""
    cdf = np.cumsum(np.asarray(p, np.float64))
    return int(np.searchsorted(cdf, u, side="right").clip(0, len(p) - 1))


def speculative_accept_window(draft_tokens: np.ndarray,
                              p_draft: np.ndarray,
                              p_target: np.ndarray,
                              u_accept: np.ndarray,
                              u_final: np.ndarray
                              ) -> Tuple[int, List[int]]:
    """Stochastic (temperature > 0) window acceptance for ONE sequence.

    draft_tokens: [k] — drafted tokens.
    p_draft:      [k, V] — draft-model distribution each was drawn from.
    p_target:     [k+1, V] — verifier distribution per column.
    u_accept:     [k] uniforms for the accept tests.
    u_final:      [k+1] uniforms — u_final[j] drives the resample after a
                  rejection at draft j, u_final[k] the all-accept bonus.
    Returns (n_accepted, emitted tokens).  Emitted-token marginals match
    sampling every token from ``p_target`` directly (the identity
    ``emitted_distribution`` pins down per position)."""
    draft_tokens = np.asarray(draft_tokens)
    k = draft_tokens.shape[0]
    emitted: List[int] = []
    for j in range(k):
        d = int(draft_tokens[j])
        pd = float(p_draft[j, d])
        pt = float(p_target[j, d])
        ratio = 1.0 if pd <= 0.0 else min(1.0, pt / pd)
        if float(u_accept[j]) < ratio and pt > 0.0:
            emitted.append(d)
            continue
        res = residual_distribution(p_draft[j], p_target[j])
        emitted.append(inverse_cdf_sample(res, float(u_final[j])))
        return j, emitted
    emitted.append(inverse_cdf_sample(p_target[k], float(u_final[k])))
    return k, emitted
