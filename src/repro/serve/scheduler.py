"""Request scheduler for the continuous-batching serve engine.

The scheduler is the software realization of SkipOPU's dynamically
allocated compute: a fixed pool of KV-cache *slots* (the on-chip KV
history buffer analogue — ``max_slots × max_len`` arrays allocated once)
is multiplexed over an unbounded FIFO stream of requests.  A request is
*admitted* when a slot frees up, prefilled into its slot, decoded
interleaved with every other resident request (each at its own position
``t[slot]``), and *evicted* on stop-token / length, immediately releasing
the slot to the next queued request.

Prefill length-bucketing: prompts are right-padded to a small set of
bucket lengths so the jitted prefill compiles once per bucket instead of
once per prompt length (the shape-polymorphism tax of XLA).  Bucketing is
exact for masked-mode global-attention stacks — pads sit *after* the real
tokens, so causal masking keeps every real position byte-identical — and
is disabled (exact-length prefill) for stacks where padding perturbs
state (SSM scans, ring-buffer local attention, gather-mode routing whose
static capacity depends on T).

Chunked prefill (``prefill_chunk > 0``): instead of prefilling a prompt
monolithically — which stalls every resident decode slot for the whole
prompt (head-of-line blocking) — the prompt is split into fixed-size
chunks that ``plan_step()`` schedules *between* decode steps: each
engine iteration advances the one in-flight prefill by at most one chunk
while every resident still decodes, so no slot ever waits more than one
chunk's worth of work for its next token.  This is the scheduler-level
analogue of the paper's latency-hiding claim: prefill (the "reduction"
of a new request into cache state) is interleaved with adjacent decode
work instead of serializing in front of it.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from time import perf_counter
from typing import Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import ATTN, ModelConfig
from repro.serve.errors import AdmissionRejected


@dataclasses.dataclass
class Request:
    """One generation request.

    Fields:
      uid            — engine-assigned id; the key of the final
                       ``RequestResult`` in ``run()['results']``.
      tokens         — ``[T0]`` int32 prompt token ids.
      max_new_tokens — generation budget, *including* the first token
                       sampled from the prefill logits.
      stop_token     — optional token id that ends generation early (it
                       is still emitted as the last output token).
      submit_s       — ``perf_counter`` stamp set by ``Scheduler.submit``
                       (feeds the engine's queue-wait histogram, and is the
                       request's *age* for preemption-victim ordering —
                       preserved across requeues, so a preempted request
                       never loses its FIFO seniority).
      deadline_s     — optional wall-clock budget measured from submit;
                       past it the engine finishes the request with
                       ``finish_reason == "deadline"`` and releases its
                       resources at the next step/epoch boundary.
      preempt_count  — times this request has been preempted (OOM victim
                       or aborted in-flight prefill); against the
                       engine's ``max_preemptions`` retry budget.
    """
    uid: int
    tokens: np.ndarray               # [T0] int32 prompt
    max_new_tokens: int
    stop_token: Optional[int] = None
    submit_s: float = 0.0
    deadline_s: Optional[float] = None
    preempt_count: int = 0

    @property
    def prompt_len(self) -> int:
        return int(np.asarray(self.tokens).shape[-1])


@dataclasses.dataclass
class ActiveRequest:
    """Engine-side state of an admitted request."""
    req: Request
    slot: int
    pos: int                         # cache position the next token writes to
    next_token: int = 0              # token fed at ``pos`` next decode step
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    # measured compact-KV accounting (from the decode attn_gate log)
    kv_stored: int = 0               # per-layer entries actually written
    kv_dense: int = 0                # what a dense per-layer store would write
    submit_s: float = 0.0
    first_token_s: float = 0.0
    # time spent in decode steps this request participated in (other
    # requests' interleaved admission prefills excluded)
    decode_s: float = 0.0
    # decode-stall tracking: wall time of the longest gap between two
    # consecutive token emissions (what an eagerly scheduled monolithic
    # prefill of *another* request inflates)
    last_emit_s: float = 0.0
    max_stall_s: float = 0.0
    finish_reason: str = ""
    # prompt-phase execution-gate log ([L_attn, >=T0], device array or np)
    # captured at prefill completion so the measured KV-storage accounting
    # covers the *whole* request, prompt included; resolved lazily at
    # finish time — never a host sync on the hot path
    pf_gates: Optional[object] = None


def default_buckets(max_len: int, lo: int = 16) -> Tuple[int, ...]:
    """Powers of two from ``lo`` up to (and including) max_len."""
    out: List[int] = []
    b = lo
    while b < max_len:
        out.append(b)
        b *= 2
    out.append(max_len)
    return tuple(out)


def can_bucket(cfg: ModelConfig) -> bool:
    """Padding-exactness condition (see module docstring)."""
    all_global = all(k == ATTN for k in cfg.layer_pattern)
    gather = cfg.skip.enabled and cfg.skip.mode == "gather"
    return all_global and not gather


def can_chunk_prefill(cfg: ModelConfig) -> bool:
    """Chunk-exactness condition: chunked prefill must be *resumable* (the
    cached prefix fully determines the next chunk's state) and the final
    chunk's right-padding must be inert.  Both hold exactly for the
    bucketable stacks — all-global attention with masked-mode routing:
    the per-layer KV views in the cache are the complete cross-layer
    reuse state, and pads sit after the real tokens where causal masking
    kills them.  Ring-buffer windows and SSM scans carry state that
    cannot be split at arbitrary offsets, and gather-mode routing's
    static capacity depends on the prefill extent, so those stacks
    require monolithic (exact-length) prefill."""
    return can_bucket(cfg)


def can_speculate(cfg: ModelConfig) -> bool:
    """Self-speculative decoding exactness condition: the draft/verify
    window reuses the chunked-prefill stack pass (``model.verify_chunk``
    is ``prefill_chunk``'s all-columns sibling), so the chunk-exactness
    condition must hold, and the dense verify path overwrites the pool's
    window rows with a time-axis ``dynamic_update_slice`` that assumes
    the ``bthd`` cache layout (head-major pools would need a transposed
    write the chunk stack does not emit)."""
    return can_chunk_prefill(cfg) and cfg.kv_cache_layout == "bthd"


@dataclasses.dataclass
class PrefillChunk:
    """One unit of prefill work handed to the engine by ``plan_step``.

    With chunking off this is the whole prompt (``is_first and is_last``);
    with ``prefill_chunk > 0`` it is one C-token slice (the final slice
    may be shorter — the engine right-pads it to C and masks)."""
    req: Request
    slot: int
    start: int                       # token offset of this chunk
    tokens: np.ndarray               # [c] real tokens (c <= prefill_chunk)
    is_first: bool
    is_last: bool


@dataclasses.dataclass
class StepPlan:
    """One engine iteration's worth of work: every resident decode slot
    plus at most one prefill chunk (the scheduler-level interleaving that
    removes prefill head-of-line blocking).

    ``decode_steps`` is the iteration's *epoch length*: with the fused
    device-resident decode loop (``decode_steps_per_dispatch > 1``) each
    resident slot decodes up to N tokens per dispatch, so one plan covers
    an N-step epoch and each decode slot costs N budget tokens."""
    decode_slots: List[int]
    prefill: Optional[PrefillChunk]
    decode_steps: int = 1

    @property
    def tokens(self) -> int:
        """Tokens this step computes (the planner's budget currency)."""
        n = len(self.decode_slots) * self.decode_steps
        return n + (len(self.prefill.tokens) if self.prefill else 0)


@dataclasses.dataclass
class _InflightPrefill:
    """Host-side progress of the one prompt currently being prefilled."""
    req: Request
    slot: int
    done: int = 0                    # tokens already prefilled
    deferred: int = 0                # consecutive budget deferrals
    # warm-prefix admission: tokens adopted from a shared prefix-cache
    # record (``done`` starts here — those tokens never prefill).  0 on
    # a cold admission.
    warm: int = 0


class Scheduler:
    """FIFO queue + slot free-list + prefill length-bucketing + the
    chunked-prefill step planner.

    The engine drives one iteration as: (optional paged-memory headroom
    pass) → ``plan_step()`` → execute the returned prefill chunk, if any
    → one ragged decode step over the resident slots.  ``plan_step``
    owns admission: it pops the FIFO head into a free slot (gated on the
    engine's ``can_place`` memory predicate) and then metes the prompt
    out one chunk per call, so decode steps run *between* chunks.
    """

    def __init__(self, max_slots: int, max_len: int,
                 buckets: Optional[Sequence[int]] = None,
                 prefill_chunk: int = 0):
        if max_slots < 1:
            raise ValueError("max_slots must be >= 1")
        if prefill_chunk < 0:
            raise ValueError("prefill_chunk must be >= 0 (0 = monolithic)")
        self.max_slots = max_slots
        self.max_len = max_len
        self.buckets = tuple(sorted(buckets)) if buckets else None
        self.prefill_chunk = prefill_chunk
        self.queue: Deque[Request] = deque()
        self._free: List[int] = list(range(max_slots - 1, -1, -1))
        self.active: Dict[int, ActiveRequest] = {}
        self._prefilling: Optional[_InflightPrefill] = None
        # optional warm-prefix hook, set by the paged engine when its
        # prefix cache is on: ``prefix_probe(request, slot) -> int``
        # returns the number of prompt tokens a published prefix already
        # covers (0 = cold).  ``plan_step`` starts the in-flight prefill
        # at that offset, so only the cold suffix is ever chunked or
        # charged against the token budget.
        self.prefix_probe = None

    # -- queue -------------------------------------------------------------
    def submit(self, req: Request) -> None:
        if req.prompt_len < 1:
            raise AdmissionRejected(f"request {req.uid}: empty prompt",
                                    reason="empty_prompt", uid=req.uid)
        if req.prompt_len + 1 > self.max_len:
            raise AdmissionRejected(
                f"request {req.uid}: prompt_len={req.prompt_len} leaves no "
                f"decode headroom within max_len={self.max_len}",
                reason="prompt_too_long", uid=req.uid)
        req.submit_s = perf_counter()
        self.queue.append(req)

    def remove_queued(self, uid: int) -> Optional[Request]:
        """Remove (and return) a still-queued request — the cheap half of
        cooperative cancellation; returns None when ``uid`` is not in the
        queue (already admitted, finished, or unknown)."""
        for i, req in enumerate(self.queue):
            if req.uid == uid:
                del self.queue[i]
                return req
        return None

    @property
    def free_slots(self) -> int:
        return len(self._free)

    def has_work(self) -> bool:
        return bool(self.queue or self.active or self._prefilling)

    # -- step planning ------------------------------------------------------
    def plan_step(self, can_place=None,
                  token_budget: Optional[int] = None,
                  decode_steps: int = 1) -> StepPlan:
        """Plan one engine iteration.

        Admission: when no prefill is in flight, the FIFO head is popped
        into a free slot iff ``can_place(request)`` passes (the paged
        engine's free-page gate; FIFO order is preserved — a blocked head
        back-pressures the queue).  The in-flight prompt then yields one
        ``PrefillChunk`` per call (the whole prompt when chunking is off).

        ``token_budget`` caps the step's token count (decode slots each
        cost ``decode_steps``; the chunk costs its length).  An
        over-budget chunk is deferred — decode-only step — but never
        twice in a row, and never when there is no decode work to
        prioritize, so prefill cannot starve.  Newly activated requests
        join the decode set only on the *next* plan (the engine decodes
        the live resident set, which includes a request the moment its
        last chunk completes).

        N-step epoch contract (``decode_steps > 1``, the fused
        device-resident decode loop): one plan covers an *epoch* of up to
        ``decode_steps`` decode iterations executed in a single device
        dispatch.  The scheduler sees the world only at epoch boundaries
        — finished slots are released, admissions happen, and preemption
        victims are chosen once per dispatch, not once per token; a slot
        stays resident (and its pages reserved) for the whole epoch even
        if it finishes mid-loop, where the device-side active mask stops
        it from computing or appending KV."""
        if self._prefilling is None and self.queue and self._free:
            if can_place is None or can_place(self.queue[0]):
                req = self.queue.popleft()
                slot = self._free.pop()
                warm = (int(self.prefix_probe(req, slot))
                        if self.prefix_probe is not None else 0)
                self._prefilling = _InflightPrefill(
                    req=req, slot=slot, done=warm, warm=warm)
        decode_slots = sorted(self.active)
        chunk: Optional[PrefillChunk] = None
        if self._prefilling is not None:
            pf = self._prefilling
            T0 = pf.req.prompt_len
            C = self.prefill_chunk if self.prefill_chunk else T0
            c = min(C, T0 - pf.done)
            over = (token_budget is not None and decode_slots
                    and len(decode_slots) * decode_steps + c > token_budget)
            if over and pf.deferred < 1:
                pf.deferred += 1
            else:
                pf.deferred = 0
                toks = np.asarray(pf.req.tokens, np.int32)
                chunk = PrefillChunk(
                    req=pf.req, slot=pf.slot, start=pf.done,
                    tokens=toks[pf.done:pf.done + c],
                    is_first=pf.done == pf.warm, is_last=pf.done + c >= T0)
        return StepPlan(decode_slots=decode_slots, prefill=chunk,
                        decode_steps=decode_steps)

    def prefill_advance(self, chunk: PrefillChunk) -> None:
        """Record that ``chunk`` was executed; the in-flight state clears
        on the last chunk (the engine then activates the request)."""
        pf = self._prefilling
        assert pf is not None and pf.slot == chunk.slot, "no such prefill"
        pf.done += len(chunk.tokens)
        if pf.done >= pf.req.prompt_len:
            self._prefilling = None

    @property
    def prefilling(self) -> Optional[_InflightPrefill]:
        """The in-flight prefill, if any (chunked mode can span engine
        iterations; monolithic prefill completes within its own)."""
        return self._prefilling

    def abort_prefill(self, requeue: bool = True) -> _InflightPrefill:
        """Cancel the in-flight prefill: its slot returns to the free
        list and (unless ``requeue=False`` — cancellation) the request
        goes back into the FIFO at its age-ordered position, where it
        will re-prefill from scratch.  The paged engine uses this as OOM
        backpressure — the in-flight prompt is the newest admission and
        has no decode progress to lose, so it is the cheapest victim
        when residents need page headroom."""
        pf = self._prefilling
        assert pf is not None, "no prefill in flight"
        self._prefilling = None
        self._free.append(pf.slot)
        if requeue:
            self.requeue(pf.req)
        return pf

    # -- admission / eviction ---------------------------------------------
    def admit(self, can_place=None,
              limit: Optional[int] = None) -> List[Tuple[int, Request]]:
        """Pop FIFO requests into free slots.  Returns [(slot, request)].

        ``can_place(request) -> bool``: optional admission predicate beyond
        slot availability — the paged KV engine passes its free-page check
        here, so admission is gated on *memory*, not just slots.  FIFO
        order is preserved: when the head of the queue cannot be placed,
        admission stops (backpressure) rather than skipping ahead.
        ``limit`` caps admissions per call (a stateful ``can_place`` that
        only reflects *committed* allocations needs limit=1 so each check
        sees the previous admission's consumption)."""
        admitted: List[Tuple[int, Request]] = []
        while self.queue and self._free:
            if limit is not None and len(admitted) >= limit:
                break
            if can_place is not None and not can_place(self.queue[0]):
                break
            slot = self._free.pop()
            admitted.append((slot, self.queue.popleft()))
        return admitted

    def requeue(self, req: Request) -> None:
        """Put a preempted request back into the queue at its
        *age-ordered* position: before every queued request submitted
        later, after every one submitted earlier.  The old behavior
        (append at head) inverted the order of two requests preempted in
        the same storm and — combined with victim selection by admission
        recency — let a single request be re-victimized forever while
        later arrivals ran to completion.  Ordering by the original
        ``submit_s`` (which requeue never touches) makes re-admission
        FIFO-fair: a thrice-preempted request still finishes before
        later arrivals (regression-tested in test_fault_tolerance.py)."""
        for i, queued in enumerate(self.queue):
            if queued.submit_s > req.submit_s:
                self.queue.insert(i, req)
                return
        self.queue.append(req)

    def activate(self, state: ActiveRequest) -> None:
        self.active[state.slot] = state

    def release(self, slot: int) -> ActiveRequest:
        """Evict the request in ``slot`` and return the slot to the pool."""
        state = self.active.pop(slot)
        self._free.append(slot)
        return state

    # -- bucketing ---------------------------------------------------------
    def bucket_for(self, prompt_len: int) -> int:
        """Padded prefill length for a prompt (identity when unbucketed)."""
        if self.buckets is None:
            return prompt_len
        for b in self.buckets:
            if b >= prompt_len:
                return min(b, self.max_len)
        return self.max_len

    def pad_prompt(self, tokens: np.ndarray) -> Tuple[np.ndarray, int]:
        """Right-pad to the bucket length.  Returns (padded [Tb], last_idx)."""
        tokens = np.asarray(tokens, np.int32).reshape(-1)
        T0 = tokens.shape[0]
        Tb = self.bucket_for(T0)
        if Tb > T0:
            tokens = np.pad(tokens, (0, Tb - T0))
        return tokens, T0 - 1
