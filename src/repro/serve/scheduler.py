"""Request scheduler for the continuous-batching serve engine.

The scheduler is the software realization of SkipOPU's dynamically
allocated compute: a fixed pool of KV-cache *slots* (the on-chip KV
history buffer analogue — ``max_slots × max_len`` arrays allocated once)
is multiplexed over an unbounded FIFO stream of requests.  A request is
*admitted* when a slot frees up, prefilled into its slot, decoded
interleaved with every other resident request (each at its own position
``t[slot]``), and *evicted* on stop-token / length, immediately releasing
the slot to the next queued request.

Prefill length-bucketing: prompts are right-padded to a small set of
bucket lengths so the jitted prefill compiles once per bucket instead of
once per prompt length (the shape-polymorphism tax of XLA).  Bucketing is
exact for masked-mode global-attention stacks — pads sit *after* the real
tokens, so causal masking keeps every real position byte-identical — and
is disabled (exact-length prefill) for stacks where padding perturbs
state (SSM scans, ring-buffer local attention, gather-mode routing whose
static capacity depends on T).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import ATTN, ModelConfig


@dataclasses.dataclass
class Request:
    """One generation request."""
    uid: int
    tokens: np.ndarray               # [T0] int32 prompt
    max_new_tokens: int
    stop_token: Optional[int] = None

    @property
    def prompt_len(self) -> int:
        return int(np.asarray(self.tokens).shape[-1])


@dataclasses.dataclass
class ActiveRequest:
    """Engine-side state of an admitted request."""
    req: Request
    slot: int
    pos: int                         # cache position the next token writes to
    next_token: int = 0              # token fed at ``pos`` next decode step
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    # measured compact-KV accounting (from the decode attn_gate log)
    kv_stored: int = 0               # per-layer entries actually written
    kv_dense: int = 0                # what a dense per-layer store would write
    submit_s: float = 0.0
    first_token_s: float = 0.0
    # time spent in decode steps this request participated in (other
    # requests' interleaved admission prefills excluded)
    decode_s: float = 0.0
    finish_reason: str = ""


def default_buckets(max_len: int, lo: int = 16) -> Tuple[int, ...]:
    """Powers of two from ``lo`` up to (and including) max_len."""
    out: List[int] = []
    b = lo
    while b < max_len:
        out.append(b)
        b *= 2
    out.append(max_len)
    return tuple(out)


def can_bucket(cfg: ModelConfig) -> bool:
    """Padding-exactness condition (see module docstring)."""
    all_global = all(k == ATTN for k in cfg.layer_pattern)
    gather = cfg.skip.enabled and cfg.skip.mode == "gather"
    return all_global and not gather


class Scheduler:
    """FIFO queue + slot free-list + prefill length-bucketing."""

    def __init__(self, max_slots: int, max_len: int,
                 buckets: Optional[Sequence[int]] = None):
        if max_slots < 1:
            raise ValueError("max_slots must be >= 1")
        self.max_slots = max_slots
        self.max_len = max_len
        self.buckets = tuple(sorted(buckets)) if buckets else None
        self.queue: Deque[Request] = deque()
        self._free: List[int] = list(range(max_slots - 1, -1, -1))
        self.active: Dict[int, ActiveRequest] = {}

    # -- queue -------------------------------------------------------------
    def submit(self, req: Request) -> None:
        if req.prompt_len < 1:
            raise ValueError(f"request {req.uid}: empty prompt")
        if req.prompt_len + 1 > self.max_len:
            raise ValueError(
                f"request {req.uid}: prompt_len={req.prompt_len} leaves no "
                f"decode headroom within max_len={self.max_len}")
        self.queue.append(req)

    @property
    def free_slots(self) -> int:
        return len(self._free)

    def has_work(self) -> bool:
        return bool(self.queue or self.active)

    # -- admission / eviction ---------------------------------------------
    def admit(self, can_place=None,
              limit: Optional[int] = None) -> List[Tuple[int, Request]]:
        """Pop FIFO requests into free slots.  Returns [(slot, request)].

        ``can_place(request) -> bool``: optional admission predicate beyond
        slot availability — the paged KV engine passes its free-page check
        here, so admission is gated on *memory*, not just slots.  FIFO
        order is preserved: when the head of the queue cannot be placed,
        admission stops (backpressure) rather than skipping ahead.
        ``limit`` caps admissions per call (a stateful ``can_place`` that
        only reflects *committed* allocations needs limit=1 so each check
        sees the previous admission's consumption)."""
        admitted: List[Tuple[int, Request]] = []
        while self.queue and self._free:
            if limit is not None and len(admitted) >= limit:
                break
            if can_place is not None and not can_place(self.queue[0]):
                break
            slot = self._free.pop()
            admitted.append((slot, self.queue.popleft()))
        return admitted

    def requeue_front(self, req: Request) -> None:
        """Put a preempted request back at the head of the queue (it will
        re-prefill from scratch when memory frees up)."""
        self.queue.appendleft(req)

    def activate(self, state: ActiveRequest) -> None:
        self.active[state.slot] = state

    def release(self, slot: int) -> ActiveRequest:
        """Evict the request in ``slot`` and return the slot to the pool."""
        state = self.active.pop(slot)
        self._free.append(slot)
        return state

    # -- bucketing ---------------------------------------------------------
    def bucket_for(self, prompt_len: int) -> int:
        """Padded prefill length for a prompt (identity when unbucketed)."""
        if self.buckets is None:
            return prompt_len
        for b in self.buckets:
            if b >= prompt_len:
                return min(b, self.max_len)
        return self.max_len

    def pad_prompt(self, tokens: np.ndarray) -> Tuple[np.ndarray, int]:
        """Right-pad to the bucket length.  Returns (padded [Tb], last_idx)."""
        tokens = np.asarray(tokens, np.int32).reshape(-1)
        T0 = tokens.shape[0]
        Tb = self.bucket_for(T0)
        if Tb > T0:
            tokens = np.pad(tokens, (0, Tb - T0))
        return tokens, T0 - 1
