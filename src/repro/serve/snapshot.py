"""Crash-consistent engine snapshots: serialize a running
``ContinuousBatchingEngine`` at an epoch boundary, survive a host kill,
and ``resume()`` with bit-identical survivor tokens.

Reuses the ``train/checkpoint.py`` machinery and its two load-bearing
properties:

* **atomic publish** — the snapshot is written to ``serve_XXXXXXXX.tmp``,
  fsynced, then renamed; a writer killed mid-snapshot never corrupts the
  latest good snapshot (the ``PreemptionGuard`` idiom's precondition);
* **template restore** — device arrays (the KV slot pool or paged store,
  plus the run's RNG key) round-trip through the same
  ``_flatten``/dtype-cast path training checkpoints use, so bf16 pools
  restore bit-exact (bf16 → f32 → bf16 is lossless) and a sharded engine
  re-places leaves under its own NamedShardings.

Layout (one directory per boundary)::

    snapshot_dir/serve_00000012.tmp/  -> written, fsynced, renamed to
    snapshot_dir/serve_00000012/
        host.json      scheduler queue/active/free, allocator chains,
                       finished results, lifecycle ages, fingerprint
        arrays.npz     KV pool/store leaves + RNG (template-restored)

**What a snapshot means.**  Snapshots are taken only at *quiescent* step
boundaries: no prefill chunk in flight, no deferred first tokens pending
on device.  At such a boundary the host structures (scheduler, allocator,
per-request token lists) plus the device KV state are the *complete*
engine state, so a resumed run re-executes exactly the decode steps the
dead process ran after the boundary — at temperature 0 the tokens are
bit-identical (asserted in tests/test_fault_tolerance.py).  Wall-clock
fields are stored as *elapsed* intervals and rebased onto the resuming
process's clock, so queue-age ordering (preemption fairness, FIFO
re-admission) survives the restart.
"""
from __future__ import annotations

import json
import os
import shutil
from pathlib import Path
from time import perf_counter
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve.scheduler import ActiveRequest, Request
from repro.train.checkpoint import _flatten

SNAP_PREFIX = "serve_"


# ---------------------------------------------------------------------------
# Atomic directory write / template read (the checkpoint idiom)
# ---------------------------------------------------------------------------

def save_snapshot(snap_dir, step: int, device_tree: Any,
                  host_state: Dict[str, Any], keep: int = 3) -> Path:
    """Atomically publish one snapshot; prunes to the newest ``keep``."""
    snap_dir = Path(snap_dir)
    snap_dir.mkdir(parents=True, exist_ok=True)
    final = snap_dir / f"{SNAP_PREFIX}{step:08d}"
    tmp = snap_dir / f"{SNAP_PREFIX}{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()
    arrays, (treedef, keys) = _flatten(device_tree)
    np.savez(tmp / "arrays.npz", **arrays)
    manifest = dict(host_state)
    manifest["_snapshot"] = {
        "step": step,
        "treedef": str(treedef),
        "keys": keys,
        "shapes": {k: list(v.shape) for k, v in arrays.items()},
        "dtypes": {k: str(v.dtype) for k, v in arrays.items()},
    }
    with open(tmp / "host.json", "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)                       # atomic publish
    if keep:
        steps = sorted(list_snapshot_steps(snap_dir))
        for old in steps[:-keep]:
            shutil.rmtree(snap_dir / f"{SNAP_PREFIX}{old:08d}",
                          ignore_errors=True)
    return final


def list_snapshot_steps(snap_dir) -> List[int]:
    p = Path(snap_dir)
    if not p.exists():
        return []
    return sorted(int(d.name[len(SNAP_PREFIX):]) for d in p.iterdir()
                  if d.is_dir() and d.name.startswith(SNAP_PREFIX)
                  and not d.name.endswith(".tmp"))


def latest_snapshot_step(snap_dir) -> Optional[int]:
    steps = list_snapshot_steps(snap_dir)
    return steps[-1] if steps else None


def load_snapshot(snap_dir, device_template: Any,
                  step: Optional[int] = None
                  ) -> Tuple[Any, Dict[str, Any], int]:
    """Restore ``(device_tree, host_state, step)`` from the newest (or
    the given) snapshot, casting leaves through ``device_template``'s
    dtypes exactly as ``train/checkpoint.load_checkpoint`` does."""
    if step is None:
        step = latest_snapshot_step(snap_dir)
        if step is None:
            raise FileNotFoundError(f"no engine snapshot under {snap_dir}")
    d = Path(snap_dir) / f"{SNAP_PREFIX}{step:08d}"
    with open(d / "host.json") as f:
        host = json.load(f)
    data = np.load(d / "arrays.npz")
    flat_t, treedef = jax.tree_util.tree_flatten(device_template)
    raw = [data[f"leaf_{i:05d}"] for i in range(len(flat_t))]

    def restore(leaf, tmpl):
        if hasattr(tmpl, "dtype") and jnp.issubdtype(tmpl.dtype,
                                                     jax.dtypes.prng_key):
            return jax.random.wrap_key_data(jnp.asarray(leaf))
        return jnp.asarray(leaf.astype(tmpl.dtype))

    device_tree = treedef.unflatten(
        [restore(l, t) for l, t in zip(raw, flat_t)])
    return device_tree, host, step


# ---------------------------------------------------------------------------
# Host-state encode / decode (the engine's scheduler + allocator + results)
# ---------------------------------------------------------------------------

def _encode_request(req: Request, now: float) -> dict:
    return {
        "uid": req.uid,
        "tokens": np.asarray(req.tokens, np.int32).tolist(),
        "max_new_tokens": req.max_new_tokens,
        "stop_token": req.stop_token,
        "age_s": max(0.0, now - req.submit_s) if req.submit_s else 0.0,
        "deadline_s": req.deadline_s,
        "preempt_count": req.preempt_count,
    }


def _decode_request(d: dict, now: float) -> Request:
    return Request(uid=d["uid"],
                   tokens=np.asarray(d["tokens"], np.int32),
                   max_new_tokens=d["max_new_tokens"],
                   stop_token=d["stop_token"],
                   submit_s=now - d["age_s"],
                   deadline_s=d.get("deadline_s"),
                   preempt_count=d.get("preempt_count", 0))


def _encode_active(st: ActiveRequest, now: float) -> dict:
    d = {
        "req": _encode_request(st.req, now),
        "slot": st.slot,
        "pos": st.pos,
        "next_token": st.next_token,
        "out_tokens": [int(t) for t in st.out_tokens],
        "kv_stored": st.kv_stored,
        "kv_dense": st.kv_dense,
        "run_age_s": max(0.0, now - st.submit_s) if st.submit_s else 0.0,
        "ttft_s": (st.first_token_s - st.submit_s
                   if st.first_token_s else -1.0),
        "decode_s": st.decode_s,
        "max_stall_s": st.max_stall_s,
        "pf_gates": None,
    }
    if st.pf_gates is not None:
        # prompt-phase gate log, resolved to 0/1 ints (the accounting
        # only thresholds it at 0.5)
        g = np.asarray(st.pf_gates, np.float32)
        d["pf_gates"] = (g > 0.5).astype(np.int32).tolist()
    return d


def _decode_active(d: dict, now: float) -> ActiveRequest:
    submit_s = now - d["run_age_s"]
    st = ActiveRequest(
        req=_decode_request(d["req"], now),
        slot=d["slot"], pos=d["pos"], next_token=d["next_token"],
        out_tokens=list(d["out_tokens"]),
        kv_stored=d["kv_stored"], kv_dense=d["kv_dense"],
        submit_s=submit_s,
        first_token_s=(submit_s + d["ttft_s"] if d["ttft_s"] >= 0 else 0.0),
        decode_s=d["decode_s"], max_stall_s=d["max_stall_s"],
        # stall tracking restarts at the resume boundary (the dead
        # process's wall time is not comparable)
        last_emit_s=now,
    )
    if d["pf_gates"] is not None:
        st.pf_gates = np.asarray(d["pf_gates"], np.float32)
    return st


def encode_host_state(engine, rs) -> Dict[str, Any]:
    """Everything outside the device arrays that ``resume()`` needs,
    JSON-able.  ``rs`` is the engine's ``_RunState``; requires a
    quiescent boundary (no in-flight prefill, no pending device
    tokens) — the engine guards this."""
    now = perf_counter()
    sched = engine.scheduler
    host: Dict[str, Any] = {
        "fingerprint": {
            "cfg": engine.cfg.name,
            "kv_mode": engine.kv_mode,
            "max_slots": engine.max_slots,
            "max_len": engine.max_len,
            "decode_steps": engine.decode_steps,
            "prefill_chunk": engine.prefill_chunk,
            "temperature": engine.temperature,
            "page_size": getattr(engine, "page_size", 0),
            "num_pages": getattr(engine, "num_pages", 0),
            "kv_dtype": getattr(engine, "kv_dtype", None),
        },
        "uid": engine._uid,
        "queue": [_encode_request(r, now) for r in sched.queue],
        "active": {str(s): _encode_active(st, now)
                   for s, st in sched.active.items()},
        "free_slots": list(sched._free),
        "results": {str(uid): {
            "uid": r.uid,
            "tokens": np.asarray(r.tokens, np.int32).tolist(),
            "prompt_len": r.prompt_len,
            "ttft_s": r.ttft_s,
            "decode_s": r.decode_s,
            "finish_reason": r.finish_reason,
            "kv_stored": r.kv_stored,
            "kv_dense": r.kv_dense,
            "max_decode_stall_s": r.max_decode_stall_s,
        } for uid, r in rs.results.items()},
        "rs": {
            "step_idx": rs.step_idx,
            "disp_idx": rs.disp_idx,
            "keep_acc": rs.keep_acc,
            "keep_n": rs.keep_n,
            "run_age_s": max(0.0, now - rs.t_run),
        },
    }
    if engine.kv_mode == "paged":
        alloc = engine.allocator
        host["alloc"] = {
            "free": list(alloc._free),
            "chains": {str(s): list(c) for s, c in alloc._chains.items()},
            "fill": alloc.fill.tolist(),
            "stats": {
                "pages_peak": alloc.stats.pages_peak,
                "entries_appended": alloc.stats.entries_appended,
                "entries_dense": alloc.stats.entries_dense,
            },
        }
        host["hist"] = {
            "fresh": rs.hist._fresh.tolist(),
            "ctx": rs.hist._ctx.tolist(),
            "hits": rs.hist.hits.tolist(),
            "reads": rs.hist.reads.tolist(),
        }
    return host


def check_fingerprint(engine, host: Dict[str, Any]) -> None:
    """Refuse to resume onto an engine whose geometry differs from the
    one that wrote the snapshot (a silent mismatch would corrupt the KV
    interpretation, not just the stats)."""
    fp = host["fingerprint"]
    mine = {
        "cfg": engine.cfg.name, "kv_mode": engine.kv_mode,
        "max_slots": engine.max_slots, "max_len": engine.max_len,
        "decode_steps": engine.decode_steps,
        "prefill_chunk": engine.prefill_chunk,
        "temperature": engine.temperature,
        "page_size": getattr(engine, "page_size", 0),
        "num_pages": getattr(engine, "num_pages", 0),
        "kv_dtype": getattr(engine, "kv_dtype", None),
    }
    diffs = {k: (fp.get(k), mine[k]) for k in mine if fp.get(k) != mine[k]}
    if diffs:
        raise ValueError(
            f"snapshot fingerprint mismatch (snapshot vs engine): {diffs}")


def apply_host_state(engine, rs, host: Dict[str, Any]) -> None:
    """Rebuild the scheduler / allocator / accounting from a snapshot's
    host state, rebasing wall-clock ages onto this process's clock."""
    from repro.serve.engine import RequestResult     # local: avoid cycle
    now = perf_counter()
    sched = engine.scheduler
    # requests submitted to the resuming engine before run() merge into
    # the restored queue in age order (their stamps are later than every
    # rebased snapshot age, so they land at the tail)
    fresh = list(sched.queue)
    sched.queue.clear()
    for d in host["queue"]:
        sched.queue.append(_decode_request(d, now))
    for req in fresh:
        sched.requeue(req)
    sched.active = {int(s): _decode_active(d, now)
                    for s, d in host["active"].items()}
    sched._free = list(host["free_slots"])
    sched._prefilling = None
    engine._uid = max(engine._uid, host["uid"])
    rs.results.update({int(uid): RequestResult(**d)
                       for uid, d in host["results"].items()})
    for r in rs.results.values():
        r.tokens = np.asarray(r.tokens, np.int32)
    h = host["rs"]
    rs.step_idx = h["step_idx"]
    rs.disp_idx = h["disp_idx"]
    rs.keep_acc = h["keep_acc"]
    rs.keep_n = h["keep_n"]
    rs.t_run = now - h["run_age_s"]
    if engine.kv_mode == "paged":
        alloc = engine.allocator
        if getattr(engine, "prefix", None) is not None:
            # prefix records from this engine's pre-resume life pin pages
            # of the allocator state about to be replaced — drop them
            # against the OLD state first.  Record pins are never
            # serialized: a snapshot's pages are owned by slot chains
            # only, so the restored cache starts cold (and the dead
            # engine's record-only pages return to the free list below).
            for w in engine._warm_pending.values():
                engine.prefix.unpin(w.rec)
            engine._warm_pending.clear()
            engine.prefix.clear()
        a = host["alloc"]
        alloc._chains = {int(s): list(c) for s, c in a["chains"].items()}
        alloc.fill = np.asarray(a["fill"], np.int32)
        alloc.block_table[:] = 0
        # refcounts rebuild from chain membership alone (shared prefix
        # pages sit in several chains; record pins are forgotten)
        alloc.refcount[:] = 0
        chained = set()
        for s, chain in alloc._chains.items():
            for j, page in enumerate(chain):
                alloc.block_table[s, j] = page
                alloc.refcount[page] += 1
                chained.add(page)
        # free list = the snapshot's stack order, then any page the dead
        # engine's prefix records were keeping off it
        stored = [int(p) for p in a["free"]]
        seen = set(stored) | chained
        alloc._free = stored + [p for p in range(alloc.num_pages)
                                if p not in seen]
        alloc.stats.pages_in_use = alloc.num_pages - len(alloc._free)
        alloc.stats.pages_peak = a["stats"]["pages_peak"]
        alloc.stats.entries_appended = a["stats"]["entries_appended"]
        alloc.stats.entries_dense = a["stats"]["entries_dense"]
        rs.hist._fresh = np.asarray(host["hist"]["fresh"], np.int64)
        rs.hist._ctx = np.asarray(host["hist"]["ctx"], np.int64)
        rs.hist.hits = np.asarray(host["hist"]["hits"], np.int64)
        rs.hist.reads = np.asarray(host["hist"]["reads"], np.int64)
