from repro.train.checkpoint import (load_checkpoint, save_checkpoint,  # noqa: F401
                                    latest_step)
from repro.train.trainer import Trainer, TrainerConfig  # noqa: F401
