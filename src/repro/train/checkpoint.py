"""Checkpointing: atomic, resharding-capable, preemption-safe.

Layout (one directory per step)::

    ckpt_dir/step_000123.tmp/   -> written, fsynced, then renamed to
    ckpt_dir/step_000123/
        manifest.json           tree structure, dtypes, shapes, data cursor
        arrays.npz              leaves as host numpy (gathered)

Properties needed at scale and provided here:
  * atomic publish (tmp dir + rename) — a killed writer never corrupts the
    latest checkpoint (preemption safety);
  * resharding restore — leaves are saved as *logical* (global) arrays and
    re-placed under whatever mesh/sharding the restoring job passes in, so
    a 512-chip checkpoint restores onto 256 chips or 8 CPU devices
    (elastic scaling);
  * the data-pipeline cursor and RNG key ride along, so restart resumes the
    exact token stream (bitwise-identical training continuation, which the
    integration test asserts).
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _to_numpy(leaf) -> np.ndarray:
    """npz-safe encoding: PRNG keys -> raw key data; ml_dtypes floats
    (bf16/fp8) -> float32 (the loader casts back via the template dtype)."""
    if hasattr(leaf, "dtype") and jnp.issubdtype(leaf.dtype, jax.dtypes.prng_key):
        return np.asarray(jax.random.key_data(leaf))
    arr = np.asarray(jax.device_get(leaf))
    if arr.dtype.kind == "V" or str(arr.dtype) in ("bfloat16", "float8_e4m3fn",
                                                   "float8_e5m2"):
        return arr.astype(np.float32)
    try:
        np.can_cast(arr.dtype, arr.dtype)        # probe exotic dtypes
    except TypeError:
        return arr.astype(np.float32)
    if str(arr.dtype) not in ("float64", "float32", "float16", "int64",
                              "int32", "int16", "int8", "uint64", "uint32",
                              "uint16", "uint8", "bool"):
        return arr.astype(np.float32)
    return arr


def _flatten(tree) -> Tuple[Dict[str, np.ndarray], Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    arrays = {}
    keys = []
    for i, (path, leaf) in enumerate(flat):
        k = f"leaf_{i:05d}"
        arrays[k] = _to_numpy(leaf)
        keys.append(jax.tree_util.keystr(path))
    return arrays, (treedef, keys)


def save_checkpoint(ckpt_dir: str, step: int, state: Dict[str, Any]) -> Path:
    """state: {'params': ..., 'opt_state': ..., 'data_step': int,
    'rng': key, ...} — any pytree of arrays + ints."""
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()
    arrays, (treedef, keys) = _flatten(state)
    np.savez(tmp / "arrays.npz", **arrays)
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "keys": keys,
        "shapes": {k: list(v.shape) for k, v in arrays.items()},
        "dtypes": {k: str(v.dtype) for k, v in arrays.items()},
    }
    with open(tmp / "manifest.json", "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)                      # atomic publish
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    p = Path(ckpt_dir)
    if not p.exists():
        return None
    steps = [int(d.name.split("_")[1]) for d in p.iterdir()
             if d.is_dir() and d.name.startswith("step_")
             and not d.name.endswith(".tmp")]
    return max(steps) if steps else None


def load_checkpoint(ckpt_dir: str, template: Dict[str, Any],
                    step: Optional[int] = None,
                    shardings: Optional[Any] = None) -> Tuple[Dict, int]:
    """Restore into the structure of ``template``; if ``shardings`` (a
    matching pytree of NamedSharding) is given, leaves are placed onto the
    current mesh — reshard-on-restore."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    d = Path(ckpt_dir) / f"step_{step:08d}"
    data = np.load(d / "arrays.npz")
    flat_t, treedef = jax.tree_util.tree_flatten(template)
    raw = [data[f"leaf_{i:05d}"] for i in range(len(flat_t))]

    def restore(l, t, s=None):
        if hasattr(t, "dtype") and jnp.issubdtype(t.dtype,
                                                  jax.dtypes.prng_key):
            return jax.random.wrap_key_data(jnp.asarray(l))
        arr = l.astype(t.dtype)
        return jax.device_put(arr, s) if s is not None else jnp.asarray(arr)

    if shardings is not None:
        flat_s = treedef.flatten_up_to(shardings)
        leaves = [restore(l, t, s) for l, t, s in zip(raw, flat_t, flat_s)]
    else:
        leaves = [restore(l, t) for l, t in zip(raw, flat_t)]
    return treedef.unflatten(leaves), step
