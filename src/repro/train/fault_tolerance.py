"""Fault-tolerance machinery: preemption handling, straggler mitigation,
elastic re-configuration.

On a real multi-pod deployment these hook into the cluster scheduler; here
they are implemented against wall-clock + signals so the control logic is
real and testable on CPU:

  * ``PreemptionGuard`` — SIGTERM/SIGINT flip a flag; the training loop
    checkpoints and exits cleanly at the next step boundary.
  * ``StragglerMonitor`` — per-step deadline tracking; steps slower than
    ``factor`` × a trailing median are recorded; after ``budget`` strikes
    the runner requests a re-configuration (in production: evict the slow
    host and resume from the last checkpoint on the surviving mesh — which
    ``load_checkpoint(..., shardings=new_mesh_specs)`` supports directly).
  * ``ElasticPlan`` — maps a surviving device count to the nearest valid
    (data, model) mesh and recomputes per-host batch partitions.
"""
from __future__ import annotations

import signal
import statistics
import time
from dataclasses import dataclass, field
from typing import List, Optional, Tuple


class PreemptionGuard:
    def __init__(self, signals=(signal.SIGTERM, signal.SIGINT)):
        self._requested = False
        self._prev = {}
        self._signals = signals

    def __enter__(self):
        for s in self._signals:
            try:
                self._prev[s] = signal.signal(s, self._handler)
            except ValueError:                 # non-main thread (tests)
                pass
        return self

    def _handler(self, signum, frame):
        self._requested = True

    @property
    def preempted(self) -> bool:
        return self._requested

    def request(self):                          # test hook
        self._requested = True

    def __exit__(self, *exc):
        for s, h in self._prev.items():
            signal.signal(s, h)
        return False


@dataclass
class StragglerMonitor:
    factor: float = 2.0
    window: int = 20
    budget: int = 3
    _times: List[float] = field(default_factory=list)
    strikes: int = 0

    def observe(self, step_seconds: float) -> bool:
        """Returns True when this step counts as a straggler event."""
        self._times.append(step_seconds)
        if len(self._times) > self.window:
            self._times.pop(0)
        if len(self._times) < 5:
            return False
        med = statistics.median(self._times[:-1])
        if step_seconds > self.factor * med:
            self.strikes += 1
            return True
        return False

    @property
    def reconfigure_requested(self) -> bool:
        return self.strikes >= self.budget


@dataclass(frozen=True)
class ElasticPlan:
    """Nearest valid mesh for a surviving chip count (model parallelism is
    kept fixed — weights reshard along the data axis only, which the
    checkpoint reshard-restore handles)."""
    model: int = 16

    def mesh_for(self, surviving_chips: int) -> Tuple[int, int]:
        data = max(1, surviving_chips // self.model)
        # largest power-of-two data axis that fits (keeps batch divisible)
        p = 1
        while p * 2 <= data:
            p *= 2
        return (p, self.model)

    def host_partition(self, global_batch: int, hosts: int
                       ) -> List[Tuple[int, int]]:
        per = global_batch // hosts
        return [(i * per, (i + 1) * per) for i in range(hosts)]
