"""Training loop: jit'd step, checkpoint/restart, preemption + straggler
hooks, metric logging.  Works on any mesh (CPU test meshes included) or
unsharded single-device.
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.data.pipeline import SyntheticLMDataset
from repro.distributed.sharding import ShardingPolicy, set_policy
from repro.models import model as model_lib
from repro.optim import adamw_init, adamw_update, apply_updates, cosine_schedule
from repro.train import checkpoint as ckpt_lib
from repro.train.fault_tolerance import PreemptionGuard, StragglerMonitor


@dataclass
class TrainerConfig:
    seq_len: int = 256
    global_batch: int = 8
    steps: int = 100
    lr: float = 3e-4
    warmup: int = 20
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    log_every: int = 10
    seed: int = 0
    grad_compression: bool = False


class Trainer:
    def __init__(self, cfg: ModelConfig, tcfg: TrainerConfig,
                 policy: Optional[ShardingPolicy] = None):
        self.cfg = cfg
        self.tcfg = tcfg
        self.policy = policy
        self.dataset = SyntheticLMDataset(cfg, tcfg.seq_len,
                                          tcfg.global_batch, tcfg.seed)
        self.schedule = cosine_schedule(tcfg.lr, tcfg.warmup, tcfg.steps)
        self._step_fn = self._build_step()
        self.metrics_log: list = []

    # ------------------------------------------------------------------
    def _build_step(self) -> Callable:
        cfg, policy = self.cfg, self.policy
        schedule = self.schedule
        compress = self.tcfg.grad_compression

        def step(params, opt_state, batch, rng):
            with set_policy(policy):
                (loss, metrics), grads = jax.value_and_grad(
                    model_lib.train_loss, has_aux=True)(params, batch, rng, cfg)
                if compress:
                    from repro.optim.compression import compress_decompress
                    grads = compress_decompress(grads)
                updates, opt_state = adamw_update(grads, opt_state, params,
                                                  schedule)
                params = apply_updates(params, updates)
            return params, opt_state, metrics

        if policy is not None:
            return jax.jit(step, donate_argnums=(0, 1))
        return jax.jit(step, donate_argnums=(0, 1))

    def init_state(self) -> Dict[str, Any]:
        key = jax.random.PRNGKey(self.tcfg.seed)
        params = model_lib.init_params(key, self.cfg)
        if self.policy is not None:
            specs = self.policy.param_specs(params)
            params = jax.tree_util.tree_map(jax.device_put, params, specs)
        return {
            "params": params,
            "opt_state": adamw_init(params),
            "data_step": jnp.zeros((), jnp.int32),
            "rng": jax.random.PRNGKey(self.tcfg.seed + 1),
        }

    # ------------------------------------------------------------------
    def run(self, state: Optional[Dict] = None,
            resume: bool = False) -> Dict[str, Any]:
        tcfg = self.tcfg
        if state is None:
            state = self.init_state()
            if resume and tcfg.ckpt_dir and \
                    ckpt_lib.latest_step(tcfg.ckpt_dir) is not None:
                state, _ = ckpt_lib.load_checkpoint(tcfg.ckpt_dir, state)
        start = int(state["data_step"])
        straggler = StragglerMonitor()
        with PreemptionGuard() as guard:
            for step in range(start, tcfg.steps):
                t0 = time.time()
                batch = {k: jnp.asarray(v)
                         for k, v in self.dataset.batch(step).items()}
                rng = jax.random.fold_in(state["rng"], step)
                params, opt_state, metrics = self._step_fn(
                    state["params"], state["opt_state"], batch, rng)
                state = {"params": params, "opt_state": opt_state,
                         "data_step": jnp.asarray(step + 1, jnp.int32),
                         "rng": state["rng"]}
                dt = time.time() - t0
                straggler.observe(dt)
                if step % tcfg.log_every == 0 or step == tcfg.steps - 1:
                    m = {k: float(v) for k, v in metrics.items()}
                    m.update(step=step, sec=round(dt, 3))
                    self.metrics_log.append(m)
                if tcfg.ckpt_dir and ((step + 1) % tcfg.ckpt_every == 0
                                      or guard.preempted
                                      or step == tcfg.steps - 1):
                    ckpt_lib.save_checkpoint(tcfg.ckpt_dir, step + 1, state)
                if guard.preempted:
                    break
        state["straggler_strikes"] = straggler.strikes
        return state
