import os
import sys

# kernels + models validate on CPU; smoke tests must see ONE device
# (the dry-run alone requests 512 — never set that here).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
