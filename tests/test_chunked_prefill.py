"""Chunked prefill (resumable prefill + mixed prefill/decode steps).

Token identity: chunked prefill must be bit-compatible with monolithic
prefill — per-token router gates, cross-layer KV-view merges and the
fused pipeline's Σy² carry only ever read their own token's column, and
attention reads the same per-layer view values — on both the dense-pool
and paged engines, with and without the Pallas kernel path, including
chunk sizes that do not divide the prompt.  Scheduling: the step planner
interleaves at most one chunk per engine iteration with a full resident
decode step, so no resident slot is ever starved by a long prompt.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model as M
from repro.serve.engine import ContinuousBatchingEngine
from repro.serve.scheduler import (Request, Scheduler, can_chunk_prefill)

KEY = jax.random.PRNGKey(0)


def _cfg(name="llama2-7b", **over):
    cfg = get_config(name).smoke()
    return dataclasses.replace(cfg, **over) if over else cfg


def _prompts(cfg, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, (l,), dtype=np.int32)
            for l in lens]


def _chunked_prefill(params, cfg, p, C, cap=None):
    """Drive model.prefill_chunk over a prompt; returns (logits, cache,
    gates [L, 1, Tp])."""
    T0 = len(p)
    cap = cap if cap is not None else -(-T0 // C) * C
    cache = M.init_chunk_cache(cfg, 1, cap)
    gates = []
    logits = None
    for s in range(0, T0, C):
        chunk = p[s:s + C]
        c = len(chunk)
        padded = np.pad(chunk, (0, C - c))
        logits, cache, st = M.prefill_chunk(
            params, cache, {"tokens": jnp.asarray(padded[None])},
            jnp.int32(s), cfg, last_index=jnp.asarray([c - 1], jnp.int32))
        gates.append(np.asarray(st["attn_gate"], np.float32))
    return logits, cache, np.concatenate(gates, axis=2)


# ---------------------------------------------------------------------------
# Model level: chunked == monolithic, bit for bit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("T0,C", [(21, 8), (16, 16), (13, 4), (7, 16)])
def test_prefill_chunk_matches_monolithic(T0, C):
    """Logits, per-layer cache views and the execution-gate log must all
    match monolithic prefill — including non-dividing chunk sizes (the
    final chunk is right-padded and masked) and a single oversized
    chunk (T0 < C)."""
    cfg = _cfg()
    params = M.init_params(KEY, cfg)
    (p,) = _prompts(cfg, [T0])
    lg_mono, cache_mono, st_mono = M.prefill(
        params, {"tokens": jnp.asarray(p[None])}, cfg)
    lg_ch, cache_ch, g_ch = _chunked_prefill(params, cfg, p, C)

    np.testing.assert_array_equal(np.asarray(st_mono["attn_gate"]),
                                  g_ch[:, :, :T0])
    np.testing.assert_allclose(np.asarray(lg_ch, np.float32),
                               np.asarray(lg_mono, np.float32),
                               rtol=2e-2, atol=2e-2)
    assert int(jnp.argmax(lg_ch[0])) == int(jnp.argmax(lg_mono[0]))
    # every layer's dense KV view is reproduced position by position
    for key in ("k", "v"):
        a = np.asarray(cache_mono["stage0"]["pos0"][key], np.float32)
        b = np.asarray(cache_ch["stage0"]["pos0"][key], np.float32)
        np.testing.assert_allclose(a[:, :T0], b[:, :T0], rtol=1e-5,
                                   atol=1e-5)


def test_prefill_chunk_carry_equivalence_under_kernels():
    """The fused pipeline's Σy² incremental-reduction carry threads
    through chunk boundaries exactly: under use_kernels the chunked
    logits (whose final norm consumes the carried reduction) match the
    monolithic kernel path."""
    cfg = _cfg(use_kernels=True)
    params = M.init_params(KEY, cfg)
    (p,) = _prompts(cfg, [19])
    lg_mono, _, st_mono = M.prefill(params, {"tokens": jnp.asarray(p[None])},
                                    cfg)
    lg_ch, _, g_ch = _chunked_prefill(params, cfg, p, 8)
    np.testing.assert_array_equal(np.asarray(st_mono["attn_gate"]),
                                  g_ch[:, :, :19])
    np.testing.assert_allclose(np.asarray(lg_ch, np.float32),
                               np.asarray(lg_mono, np.float32),
                               rtol=2e-2, atol=2e-2)
    assert int(jnp.argmax(lg_ch[0])) == int(jnp.argmax(lg_mono[0]))


def test_init_chunk_cache_rejects_hybrid_stack():
    cfg = get_config("jamba-v0.1-52b").smoke()
    with pytest.raises(ValueError, match="all-global-attn"):
        M.init_chunk_cache(cfg, 1, 32)


# ---------------------------------------------------------------------------
# Engine level: chunked == monolithic token identity
# ---------------------------------------------------------------------------

def _run_engine(cfg, params, prompts, max_new=5, max_slots=2, max_len=48,
                **kw):
    eng = ContinuousBatchingEngine(cfg, params, max_slots=max_slots,
                                   max_len=max_len, **kw)
    uids = [eng.submit(p, max_new_tokens=max_new) for p in prompts]
    out = eng.run()
    assert out["stats"].requests_completed == len(prompts)
    return {u: out["results"][u].tokens for u in uids}, out["stats"]


@pytest.mark.parametrize("kv_mode", ["dense", "paged"])
@pytest.mark.parametrize("use_kernels", [False, True])
def test_engine_chunked_token_identity(kv_mode, use_kernels):
    """Chunked == monolithic on both engines, jnp and kernel paths, with
    prompts longer/shorter than the chunk and non-dividing lengths."""
    cfg = _cfg(use_kernels=True) if use_kernels else _cfg()
    params = M.init_params(KEY, cfg)
    prompts = _prompts(cfg, [9, 21, 5, 30])
    mono, s_mono = _run_engine(cfg, params, prompts, kv_mode=kv_mode)
    chunked, s_ch = _run_engine(cfg, params, prompts, kv_mode=kv_mode,
                                prefill_chunk=8)
    for u in mono:
        np.testing.assert_array_equal(mono[u], chunked[u])
    # 9->2, 21->3, 5->1, 30->4 chunks of 8
    assert s_ch.prefill_chunks == 10
    assert s_mono.prefill_chunks == len(prompts)
    assert s_ch.interleaved_steps > 0


def test_engine_chunked_token_identity_bhtd():
    """Head-major pool layout: the staging cache stays time-major and the
    insert-time transpose must still land every chunk correctly."""
    cfg = _cfg(kv_cache_layout="bhtd")
    params = M.init_params(KEY, cfg)
    prompts = _prompts(cfg, [9, 21, 30])
    mono, _ = _run_engine(cfg, params, prompts)
    chunked, _ = _run_engine(cfg, params, prompts, prefill_chunk=8)
    for u in mono:
        np.testing.assert_array_equal(mono[u], chunked[u])


def test_engine_chunked_paged_entry_stream_identical():
    """Gate-log equivalence across chunk boundaries: the paged entry
    stream packed from accumulated chunk gates must count exactly the
    entries the monolithic pack counts (same compact-store saving)."""
    cfg = _cfg()
    params = M.init_params(KEY, cfg)
    prompts = _prompts(cfg, [13, 27])
    _, s_mono = _run_engine(cfg, params, prompts, kv_mode="paged")
    _, s_ch = _run_engine(cfg, params, prompts, kv_mode="paged",
                          prefill_chunk=8)
    assert s_ch.kv_entries_stored == s_mono.kv_entries_stored
    assert s_ch.kv_entries_dense == s_mono.kv_entries_dense
    assert s_ch.history_hit_rate == pytest.approx(s_mono.history_hit_rate)


@pytest.mark.parametrize("num_pages", [13, 12])
def test_engine_chunked_paged_prefill_abort_under_pressure(num_pages):
    """A chunked prefill spans engine iterations while holding its
    worst-case page reservation without yet being a resident; when a
    resident's headroom pass runs the free list dry, the in-flight
    prefill must be *aborted* (pages released, request requeued) instead
    of the engine dying on 'pool exhausted' — and the retried prefill
    must leave tokens identical (regression: pools sized so the abort
    path actually fires)."""
    cfg = _cfg()
    params = M.init_params(KEY, cfg)
    prompts = _prompts(cfg, [10, 12, 8])
    mono, _ = _run_engine(cfg, params, prompts, max_new=8, max_slots=3,
                          max_len=32)
    chunked, s = _run_engine(cfg, params, prompts, max_new=8, max_slots=3,
                             max_len=32, kv_mode="paged", prefill_chunk=4,
                             num_pages=num_pages, page_size=4)
    for u in mono:
        np.testing.assert_array_equal(mono[u], chunked[u])
    assert s.preemptions > 0          # the abort path actually ran


@pytest.mark.parametrize("kw", [
    dict(prefill_chunk=4, step_tokens=4),
    dict(prefill_chunk=4, step_tokens=3),
    dict(step_tokens=4),                  # budget-deferred monolithic
])
def test_engine_paged_budget_deferral_reserves_at_admission(kw):
    """Regression (code review): a step_tokens budget can defer the first
    prefill work unit past the admission iteration; the worst-case page
    reservation must happen in the same iteration as the _can_place
    check, or the intervening resident-headroom pass consumes the pages
    the check counted as spare and the run dies on a spurious
    'allocator bug' RuntimeError."""
    cfg = _cfg()
    params = M.init_params(KEY, cfg)
    prompts = _prompts(cfg, [10, 12, 8])
    mono, _ = _run_engine(cfg, params, prompts, max_new=8, max_slots=3,
                          max_len=32)
    chunked, s = _run_engine(cfg, params, prompts, max_new=8, max_slots=3,
                             max_len=32, kv_mode="paged", num_pages=12,
                             page_size=4, **kw)
    for u in mono:
        np.testing.assert_array_equal(mono[u], chunked[u])


def test_engine_rejects_chunking_on_unchunkable_cfg():
    """Ring-buffer and SSM state cannot resume at arbitrary offsets and
    gather-mode capacity depends on the prefill extent — the exactness
    guard must refuse chunking there."""
    cfg = get_config("gemma3-12b").smoke()
    params = M.init_params(KEY, cfg)
    with pytest.raises(ValueError, match="prefill_chunk=0"):
        ContinuousBatchingEngine(cfg, params, max_slots=1, max_len=32,
                                 prefill_chunk=8)
    g = _cfg()
    g = dataclasses.replace(g, skip=dataclasses.replace(g.skip,
                                                        mode="gather"))
    assert not can_chunk_prefill(g)
    assert can_chunk_prefill(_cfg())
    assert not can_chunk_prefill(get_config("jamba-v0.1-52b").smoke())


def test_cfg_prefill_chunk_lever_is_engine_default():
    """The config lever seeds the engine default; the constructor arg
    overrides it."""
    cfg = _cfg(prefill_chunk=8)
    params = M.init_params(KEY, cfg)
    eng = ContinuousBatchingEngine(cfg, params, max_slots=1, max_len=32)
    assert eng.prefill_chunk == 8
    eng = ContinuousBatchingEngine(cfg, params, max_slots=1, max_len=32,
                                   prefill_chunk=0)
    assert eng.prefill_chunk == 0


# ---------------------------------------------------------------------------
# Scheduler: step planning, budget, starvation guard, decode-not-starved
# ---------------------------------------------------------------------------

def test_plan_step_metes_out_chunks():
    sched = Scheduler(max_slots=2, max_len=64, prefill_chunk=8)
    sched.submit(Request(uid=0, tokens=np.zeros(21, np.int32),
                         max_new_tokens=4))
    seen = []
    while True:
        plan = sched.plan_step()
        if plan.prefill is None:
            break
        seen.append((plan.prefill.start, len(plan.prefill.tokens),
                     plan.prefill.is_first, plan.prefill.is_last))
        sched.prefill_advance(plan.prefill)
    assert seen == [(0, 8, True, False), (8, 8, False, False),
                    (16, 5, False, True)]
    assert not sched.has_work() or sched.queue  # in-flight state cleared


def test_plan_step_whole_prompt_when_chunking_off():
    sched = Scheduler(max_slots=1, max_len=64)
    sched.submit(Request(uid=0, tokens=np.zeros(21, np.int32),
                         max_new_tokens=4))
    plan = sched.plan_step()
    assert plan.prefill.is_first and plan.prefill.is_last
    assert len(plan.prefill.tokens) == 21
    assert plan.tokens == 21


def test_plan_step_budget_defers_but_never_starves():
    """An over-budget chunk yields a decode-only step once, then runs
    regardless (prefill cannot be starved by the budget)."""
    from repro.serve.scheduler import ActiveRequest
    sched = Scheduler(max_slots=2, max_len=64, prefill_chunk=8)
    sched.activate(ActiveRequest(
        req=Request(uid=9, tokens=np.zeros(4, np.int32), max_new_tokens=32),
        slot=0, pos=4))
    sched.submit(Request(uid=0, tokens=np.zeros(16, np.int32),
                         max_new_tokens=4))
    plan = sched.plan_step(token_budget=4)      # 1 decode + 8 > 4 -> defer
    assert plan.prefill is None and plan.decode_slots == [0]
    plan = sched.plan_step(token_budget=4)      # starvation guard fires
    assert plan.prefill is not None
    sched.prefill_advance(plan.prefill)
    # without decode work the budget never blocks prefill
    sched2 = Scheduler(max_slots=1, max_len=64, prefill_chunk=8)
    sched2.submit(Request(uid=1, tokens=np.zeros(16, np.int32),
                          max_new_tokens=4))
    assert sched2.plan_step(token_budget=1).prefill is not None


def test_plan_step_admission_respects_can_place():
    sched = Scheduler(max_slots=2, max_len=64, prefill_chunk=8)
    sched.submit(Request(uid=0, tokens=np.zeros(8, np.int32),
                         max_new_tokens=4))
    plan = sched.plan_step(can_place=lambda r: False)
    assert plan.prefill is None and sched.queue      # backpressure
    plan = sched.plan_step(can_place=lambda r: True)
    assert plan.prefill is not None and not sched.queue


def test_decode_not_starved_by_long_prompt():
    """While a long prompt prefills chunk by chunk, a resident slot keeps
    emitting a token every engine iteration: its worst inter-token gap
    (in iterations) is 1 — far below the ceil(T0/chunk) bound — which is
    visible as interleaved_steps covering every chunk of the long
    prompt's prefill."""
    cfg = _cfg()
    params = M.init_params(KEY, cfg)
    rng = np.random.default_rng(0)
    short = rng.integers(0, cfg.vocab_size, (4,), dtype=np.int32)
    long_p = rng.integers(0, cfg.vocab_size, (32,), dtype=np.int32)
    eng = ContinuousBatchingEngine(cfg, params, max_slots=2, max_len=48,
                                   prefill_chunk=8)
    u_short = eng.submit(short, max_new_tokens=12)
    u_long = eng.submit(long_p, max_new_tokens=2)
    out = eng.run()
    s = out["stats"]
    # the long prompt needs ceil(32/8)=4 chunks; the short request was
    # resident for at least 3 of them (its own prefill takes the first
    # iteration), each an interleaved prefill+decode step
    assert s.prefill_chunks == 1 + 4
    assert s.interleaved_steps >= 3
    assert out["results"][u_short].tokens.shape[0] == 12
    assert out["results"][u_long].tokens.shape[0] == 2
    # stall instrumentation is populated (the wall-clock comparison with
    # the eager baseline is CI-gated in benchmarks/bench_chunked_prefill)
    assert out["results"][u_short].max_decode_stall_s > 0.0
