"""Error-feedback int8 gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim.compression import (compress_decompress, ef_compress)

KEY = jax.random.PRNGKey(0)


def test_int8_channel_error_bound():
    g = {"w": jax.random.normal(KEY, (1024,)) * 0.01}
    out = compress_decompress(g)
    err = np.abs(np.asarray(out["w"]) - np.asarray(g["w"]))
    scale = np.abs(np.asarray(g["w"])).reshape(-1, 256).max(1) / 127
    assert np.all(err.reshape(-1, 256) <= scale[:, None] / 2 + 1e-8)


def test_small_leaves_passthrough():
    g = {"b": jnp.ones((8,))}
    out = compress_decompress(g)
    np.testing.assert_array_equal(np.asarray(out["b"]), np.asarray(g["b"]))


def test_error_feedback_accumulates_to_truth():
    """Repeatedly sending the same gradient with EF must converge: the sum
    of decompressed messages approaches n * g (bias correction)."""
    g = {"w": jax.random.normal(KEY, (512,)) * 1e-3}
    err = None
    total = np.zeros(512, np.float32)
    n = 20
    for _ in range(n):
        sent, err = ef_compress(g, err)
        total += np.asarray(sent["w"], np.float32)
    np.testing.assert_allclose(total / n, np.asarray(g["w"]), rtol=0.02,
                               atol=1e-6)


def test_ef_residual_bounded():
    g = {"w": jax.random.normal(KEY, (2048,))}
    err = None
    for _ in range(10):
        _, err = ef_compress(g, err)
    scale = np.abs(np.asarray(g["w"])).reshape(-1, 256).max(1) / 127
    assert np.abs(np.asarray(err["w"])).max() <= 2 * scale.max()
