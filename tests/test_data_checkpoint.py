"""Data pipeline determinism + checkpoint atomicity/resume."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.pipeline import SyntheticLMDataset
from repro.train import checkpoint as ck


@pytest.fixture
def cfg():
    return get_config("qwen3-8b").smoke()


def test_dataset_deterministic(cfg):
    ds1 = SyntheticLMDataset(cfg, 32, 4, seed=7)
    ds2 = SyntheticLMDataset(cfg, 32, 4, seed=7)
    b1, b2 = ds1.batch(13), ds2.batch(13)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = ds1.batch(14)
    assert not np.array_equal(b1["tokens"], b3["tokens"])


def test_dataset_host_sharding_consistent(cfg):
    ds = SyntheticLMDataset(cfg, 16, 8, seed=3)
    full = ds.batch(5)
    parts = [ds.host_batch(5, h, 4) for h in range(4)]
    merged = np.concatenate([p["tokens"] for p in parts], axis=0)
    np.testing.assert_array_equal(full["tokens"], merged)


def test_dataset_labels_are_shifted(cfg):
    ds = SyntheticLMDataset(cfg, 32, 2, seed=0)
    b = ds.batch(0)
    # labels[t] is the next token of the same underlying stream
    assert b["tokens"].shape == b["labels"].shape == (2, 32)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_checkpoint_roundtrip(tmp_path, cfg):
    state = {
        "params": {"a": jnp.arange(6, dtype=jnp.bfloat16).reshape(2, 3),
                   "b": {"c": jnp.ones((4,), jnp.float32)}},
        "data_step": jnp.asarray(17, jnp.int32),
        "rng": jax.random.PRNGKey(5),
    }
    ck.save_checkpoint(tmp_path, 17, state)
    assert ck.latest_step(tmp_path) == 17
    restored, step = ck.load_checkpoint(tmp_path, state)
    assert step == 17
    np.testing.assert_array_equal(np.asarray(restored["params"]["a"],
                                             np.float32),
                                  np.asarray(state["params"]["a"],
                                             np.float32))
    assert int(restored["data_step"]) == 17


def test_checkpoint_atomic_publish(tmp_path):
    """A leftover .tmp dir never shadows the committed checkpoint."""
    state = {"x": jnp.zeros((2,))}
    ck.save_checkpoint(tmp_path, 1, state)
    (tmp_path / "step_00000002.tmp").mkdir()     # simulated dead writer
    assert ck.latest_step(tmp_path) == 1
    restored, step = ck.load_checkpoint(tmp_path, state)
    assert step == 1


def test_checkpoint_keeps_multiple_steps(tmp_path):
    state = {"x": jnp.zeros((2,))}
    for s in (1, 2, 5):
        ck.save_checkpoint(tmp_path, s, {"x": jnp.full((2,), float(s))})
    restored, step = ck.load_checkpoint(tmp_path, state, step=2)
    assert float(restored["x"][0]) == 2.0
    assert ck.latest_step(tmp_path) == 5
