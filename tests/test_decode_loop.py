"""Device-resident N-step decode epochs (``model.decode_loop`` /
``model.paged_decode_loop`` and the engine's ``decode_steps > 1`` mode).

Acceptance bar: the fused loops are a pure dispatch-granularity change —
token output must be bit-identical to the single-step engine (greedy)
across dense/paged KV, chunked prefill, forced preemption and kernels,
with strictly fewer jitted decode dispatches; and a slot that finishes
mid-epoch must stop appending KV *inside* the scan (frozen (feed, t)
carry dense-side, commit-mask drop paged-side).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.routing import neutral_router_bias
from repro.models import model as M
from repro.serve.engine import ContinuousBatchingEngine, init_pool, \
    pool_insert
from repro.serve.sampling import split_sample
from repro.serve.scheduler import Scheduler, StepPlan

KEY = jax.random.PRNGKey(0)


def _cfg(name="llama2-7b", **over):
    cfg = get_config(name).smoke()
    return dataclasses.replace(cfg, **over) if over else cfg


def _params(cfg):
    # neutral bias: the router actually skips, so the gate log (and the
    # KV freeze it drives) is exercised, not just the dense fast path
    return neutral_router_bias(M.init_params(KEY, cfg))


def _prompts(cfg, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, (l,), dtype=np.int32)
            for l in lens]


# ---------------------------------------------------------------------------
# Model level: the scan must replay the single-step path exactly.
# ---------------------------------------------------------------------------

def _seed_pool(cfg, params, prompts, max_len):
    """Prefill each prompt alone and scatter into a slot pool; returns
    (pool, first-token feed, positions)."""
    pool = init_pool(cfg, len(prompts), max_len)
    feed, pos = [], []
    for s, p in enumerate(prompts):
        lg, cache, _ = M.prefill(params, {"tokens": jnp.asarray(p[None])},
                                 cfg, pad_to=max_len)
        pool = pool_insert(pool, cache, s, cfg)
        feed.append(int(jnp.argmax(lg[0])))
        pos.append(len(p))
    return pool, np.asarray(feed, np.int32), np.asarray(pos, np.int32)


def test_decode_loop_matches_sequential_steps():
    """n_steps fused iterations == n sequential decode_step + sample calls:
    same tokens, same final cache, same rng stream."""
    cfg = _cfg()
    params = _params(cfg)
    max_len, n = 24, 5
    prompts = _prompts(cfg, [6, 9])
    pool, feed, pos = _seed_pool(cfg, params, prompts, max_len)
    ref_pool = pool                              # eager calls don't donate
    B = len(prompts)
    act = np.ones((B,), bool)
    budget = np.full((B,), n + 1, np.int32)      # no one finishes early
    stop = np.full((B,), -1, np.int32)
    rng = jax.random.PRNGKey(3)

    new_pool, out = M.decode_loop(params, pool, feed, pos, act, budget,
                                  stop, rng, n_steps=n, cfg=cfg,
                                  max_len=max_len)
    toks = np.asarray(out["tokens"])                       # [n, B]

    step = jax.jit(lambda p, c, f, t: M.decode_step(
        p, c, {"tokens": f[:, None]}, t, cfg))
    f, t = jnp.asarray(feed), jnp.asarray(pos)
    for i in range(n):
        logits, ref_pool, _ = step(params, ref_pool, f, t)
        rng, tok = split_sample(logits, rng)
        np.testing.assert_array_equal(toks[i], np.asarray(tok))
        f, t = tok, t + 1
    np.testing.assert_array_equal(np.asarray(out["feed"]), np.asarray(f))
    np.testing.assert_array_equal(np.asarray(out["t"]), np.asarray(t))
    assert np.asarray(out["step_active"]).all()
    for a, b in zip(jax.tree_util.tree_leaves(new_pool),
                    jax.tree_util.tree_leaves(ref_pool)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_decode_loop_mid_stop_freezes_kv():
    """A slot finishing mid-scan (stop token sampled) must freeze its
    (feed, t) carry: positions past its stop point stay untouched in the
    cache — the finished slot stops appending KV with the other slot
    still decoding."""
    cfg = _cfg()
    params = _params(cfg)
    max_len, n = 24, 6
    prompts = _prompts(cfg, [6, 9])
    pool, feed, pos = _seed_pool(cfg, params, prompts, max_len)
    k_init = np.asarray(pool["stage0"]["pos0"]["k"])       # [S, T, H, d]
    B = len(prompts)
    act = np.ones((B,), bool)
    budget = np.full((B,), n + 1, np.int32)
    rng = jax.random.PRNGKey(3)

    # free-running reference epoch → pick slot 0's mid-epoch token as the
    # stop token (whatever its first occurrence is)
    free_pool, ref = M.decode_loop(params, pool, feed, pos, act, budget,
                                   np.full((B,), -1, np.int32), rng,
                                   n_steps=n, cfg=cfg, max_len=max_len)
    ref_toks = np.asarray(ref["tokens"])                   # [n, B]
    stop_tok = int(ref_toks[2, 0])
    k_stop = int(np.argmax(ref_toks[:, 0] == stop_tok))   # first hit
    assert k_stop < n - 1, "stop must fire mid-epoch for the test to bite"
    if stop_tok in ref_toks[:, 1]:
        pytest.skip("stop token collides with the other slot's stream")

    stop = np.asarray([stop_tok, -1], np.int32)
    new_pool, out = M.decode_loop(params, pool, feed, pos, act,
                                  budget, stop, rng, n_steps=n, cfg=cfg,
                                  max_len=max_len)
    sa = np.asarray(out["step_active"])                    # [n, B]
    assert sa[:k_stop + 1, 0].all() and not sa[k_stop + 1:, 0].any()
    assert sa[:, 1].all()
    # slot 0's tokens match the free run up to (and including) the stop
    np.testing.assert_array_equal(np.asarray(out["tokens"])[:k_stop + 1, 0],
                                  ref_toks[:k_stop + 1, 0])
    # position carry froze at the stop point
    t_stop = int(pos[0]) + k_stop
    assert int(np.asarray(out["t"])[0]) == t_stop
    assert not bool(np.asarray(out["active"])[0])
    # the KV row stopped growing: positions past t_stop are untouched
    # (bit-identical to the pre-loop pool), while the free-running epoch
    # overwrote them — and the live slot kept appending in both
    k_frozen = np.asarray(new_pool["stage0"]["pos0"]["k"])
    k_free = np.asarray(free_pool["stage0"]["pos0"]["k"])
    np.testing.assert_array_equal(k_frozen[0, t_stop + 1:],
                                  k_init[0, t_stop + 1:])
    np.testing.assert_array_equal(k_frozen[0, :t_stop + 1],
                                  k_free[0, :t_stop + 1])
    assert not np.array_equal(k_free[0, t_stop + 1: int(pos[0]) + n],
                              k_init[0, t_stop + 1: int(pos[0]) + n])
    np.testing.assert_array_equal(k_frozen[1], k_free[1])


# ---------------------------------------------------------------------------
# Engine level: fused epochs vs the single-step engine, bit for bit.
# ---------------------------------------------------------------------------

def _run(cfg, params, prompts, budgets, stop_token=None, **kw):
    eng = ContinuousBatchingEngine(cfg, params, max_slots=2, max_len=48,
                                   **kw)
    uids = [eng.submit(p, n, stop_token=stop_token)
            for p, n in zip(prompts, budgets)]
    return eng, uids, eng.run(jax.random.PRNGKey(7))


def _assert_identical(ref, fused):
    for uid in ref["results"]:
        a, b = ref["results"][uid], fused["results"][uid]
        np.testing.assert_array_equal(a.tokens, b.tokens)
        assert a.finish_reason == b.finish_reason
        assert (a.kv_stored, a.kv_dense) == (b.kv_stored, b.kv_dense)
    sa, sb = ref["stats"], fused["stats"]
    assert sa.decode_tokens == sb.decode_tokens
    assert sa.requests_completed == sb.requests_completed
    assert sa.kv_saved_fraction == pytest.approx(sb.kv_saved_fraction)
    assert sb.decode_dispatches < sa.decode_dispatches


@pytest.mark.parametrize("kv_mode,chunk,n_steps", [
    ("dense", 0, 4),
    ("dense", 8, 8),
    ("paged", 0, 8),
    ("paged", 8, 8),
])
def test_fused_engine_token_identity(kv_mode, chunk, n_steps):
    """N-step epochs emit the exact single-step token streams — mixed
    budgets (incl. max_new=1), a stop token that fires mid-run, chunked
    prefill interleaving, both KV modes — with fewer dispatches."""
    cfg = _cfg()
    params = _params(cfg)
    prompts = _prompts(cfg, [10, 5, 9, 14, 7])
    budgets = [6, 1, 9, 4, 7]
    _, _, ref = _run(cfg, params, prompts, budgets, stop_token=9,
                     kv_mode=kv_mode, prefill_chunk=chunk)
    enf, _, fused = _run(cfg, params, prompts, budgets, stop_token=9,
                         kv_mode=kv_mode, prefill_chunk=chunk,
                         decode_steps=n_steps)
    _assert_identical(ref, fused)
    if kv_mode == "paged":
        # device-side fill advance replayed host-side: every page returned
        assert enf.allocator.free_pages == enf.num_pages


def test_fused_engine_identity_with_kernels():
    cfg = _cfg(use_kernels=True)
    params = _params(cfg)
    prompts = _prompts(cfg, [10, 14, 6])
    _, _, ref = _run(cfg, params, prompts, [6, 8, 4], stop_token=9)
    _, _, fused = _run(cfg, params, prompts, [6, 8, 4], stop_token=9,
                       decode_steps=8)
    _assert_identical(ref, fused)


def test_fused_paged_preemption_identity():
    """Page pressure inside fused mode: the epoch first shrinks, then
    preempts (youngest-first) — and the token streams still match the
    dense single-step engine exactly."""
    cfg = _cfg()
    params = _params(cfg)
    prompts = _prompts(cfg, [8, 8], seed=1)
    _, ud, ref = _run(cfg, params, prompts, [16, 16])
    # 8 pages: enough spare for both prompts to be admitted concurrently
    # (6 would make the epoch reservation defer the second admission and
    # dodge preemption entirely), yet too few for both to finish resident
    eng, up, fused = _run(cfg, params, prompts, [16, 16], kv_mode="paged",
                          page_size=8, num_pages=8, decode_steps=8)
    assert fused["stats"].preemptions >= 1
    assert fused["stats"].requests_completed == 2
    for a, b in zip(ud, up):
        np.testing.assert_array_equal(ref["results"][a].tokens,
                                      fused["results"][b].tokens)
    assert eng.allocator.free_pages == eng.num_pages


def test_fused_deferred_first_token_stop():
    """Dense fused mode defers first tokens on device; when that deferred
    token IS the stop token the slot must be entry-killed inside the loop
    (no emissions, no KV appends) and finished with reason "stop" — the
    exact single-step completion-path behaviour."""
    cfg = _cfg()
    params = _params(cfg)
    prompts = _prompts(cfg, [10, 7])
    # discover request 0's first token from an unconstrained run
    _, uids, probe = _run(cfg, params, prompts, [4, 6])
    first_tok = int(probe["results"][uids[0]].tokens[0])
    _, ur, ref = _run(cfg, params, prompts, [4, 6], stop_token=first_tok)
    _, uf, fused = _run(cfg, params, prompts, [4, 6], stop_token=first_tok,
                        decode_steps=8)
    assert ref["results"][ur[0]].finish_reason == "stop"
    assert len(ref["results"][ur[0]].tokens) == 1
    _assert_identical(ref, fused)


def test_prefill_kv_accounting_measured():
    """Warm-start measured-saving regression (the bench anomaly): with
    max_new_tokens=1 there are no decode steps, so any measured saving
    must come from the *prompt-phase* gate log — which used to be dropped
    on the floor (measured 0.000 vs analytic 0.125).  With a skipping
    router it must now land in the paper's regime; both KV modes agree."""
    cfg = _cfg()
    params = _params(cfg)
    prompts = _prompts(cfg, [10, 14, 6])
    fracs = []
    for mode in ("dense", "paged"):
        _, _, out = _run(cfg, params, prompts, [1, 1, 1], kv_mode=mode)
        s = out["stats"]
        assert 0.0 < s.kv_saved_fraction < 0.5, mode
        for r in out["results"].values():
            assert r.kv_dense > 0
        fracs.append(s.kv_saved_fraction)
    assert fracs[0] == pytest.approx(fracs[1])


def test_warmstart_keeps_everything_measured_zero():
    """The flip side: warm-started router biases keep every token, so the
    *measured* saving is genuinely 0.0 (the analytic figure is an
    estimate, not ground truth) — pin it so the bench row's meaning
    stays documented."""
    cfg = _cfg()
    params = M.init_params(KEY, cfg)             # warm-start bias
    _, _, out = _run(cfg, params, _prompts(cfg, [10, 14]), [4, 4])
    assert out["stats"].kv_saved_fraction == 0.0
    assert out["stats"].kv_saved_analytic > 0.0


# ---------------------------------------------------------------------------
# Scheduler + config plumbing
# ---------------------------------------------------------------------------

def test_plan_step_epoch_token_budget():
    """Each decode slot costs ``decode_steps`` budget tokens: a chunk that
    fits alongside single-step decodes is deferred under an N-step epoch
    (but never twice — the anti-starvation rule is epoch-agnostic)."""
    assert StepPlan(decode_slots=[0, 1], prefill=None,
                    decode_steps=8).tokens == 16
    sched = Scheduler(max_slots=4, max_len=64, prefill_chunk=8)
    from repro.serve.scheduler import ActiveRequest, Request
    for slot in (0, 1):
        req = Request(uid=slot, tokens=np.zeros((4,), np.int32),
                      max_new_tokens=4)
        sched._free.remove(slot)
        sched.active[slot] = ActiveRequest(
            req=req, slot=slot, pos=4, next_token=0, out_tokens=[0],
            submit_s=0.0, first_token_s=0.0)
    sched.submit(Request(uid=9, tokens=np.zeros((8,), np.int32),
                         max_new_tokens=4))
    # budget 12: 2 slots × 1 step + 8-token chunk = 10 fits single-step
    plan = sched.plan_step(token_budget=12, decode_steps=1)
    assert plan.prefill is not None and plan.tokens <= 12
    sched.abort_prefill()
    sched.submit(Request(uid=10, tokens=np.zeros((8,), np.int32),
                         max_new_tokens=4))
    # same budget, 8-step epoch: 2 × 8 + 8 = 24 > 12 → deferred once...
    plan = sched.plan_step(token_budget=12, decode_steps=8)
    assert plan.prefill is None
    assert plan.decode_steps == 8
    # ...but not twice (prefill must not starve)
    plan = sched.plan_step(token_budget=12, decode_steps=8)
    assert plan.prefill is not None


def test_decode_steps_validation_and_config_default():
    cfg = _cfg()
    params = M.init_params(KEY, cfg)
    with pytest.raises(ValueError, match="decode_steps"):
        ContinuousBatchingEngine(cfg, params, max_slots=2, max_len=32,
                                 decode_steps=0)
    cfg8 = dataclasses.replace(cfg, decode_steps_per_dispatch=8)
    eng = ContinuousBatchingEngine(cfg8, params, max_slots=2, max_len=32)
    assert eng.decode_steps == 8                 # cfg lever is the default
    eng = ContinuousBatchingEngine(cfg8, params, max_slots=2, max_len=32,
                                   decode_steps=1)
    assert eng.decode_steps == 1                 # ctor arg overrides
