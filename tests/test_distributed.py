"""Distributed-path tests: run in a subprocess with 8 forced host devices
(the main pytest process keeps 1 device for the smoke tests).

Covers: sharded train step on a (4,2) mesh, reshard-on-restore onto a
different mesh (elastic), shard_map int8-compressed mean, GPipe pipeline
over a mesh axis, and AbstractMesh-based spec construction for every arch
on the production meshes.

Multi-device topologies are *simulated* with XLA host-device splitting;
when the host cannot provide them (splitting unsupported / fewer simulated
devices than required) the whole module skips instead of failing — tier-1
must stay green on a 1-CPU host.
"""
import functools
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
REQUIRED_DEVICES = 8


def _env():
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count="
                        f"{REQUIRED_DEVICES}")
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = SRC
    return env


@functools.lru_cache(maxsize=1)
def _simulated_device_count() -> int:
    r = subprocess.run(
        [sys.executable, "-c", "import jax; print(jax.device_count())"],
        capture_output=True, text=True, timeout=300, env=_env())
    try:
        return int(r.stdout.strip()) if r.returncode == 0 else 0
    except ValueError:
        return 0


def _run(script: str):
    if _simulated_device_count() < REQUIRED_DEVICES:
        if os.environ.get("REQUIRE_MULTIDEVICE"):
            pytest.fail(
                f"REQUIRE_MULTIDEVICE is set but the host simulates only "
                f"{_simulated_device_count()} devices — the multi-device "
                f"CI job must be able to split {REQUIRED_DEVICES} host "
                f"devices")
        pytest.skip(f"host cannot simulate {REQUIRED_DEVICES} devices "
                    f"(got {_simulated_device_count()})")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                       capture_output=True, text=True, timeout=900,
                       env=_env())
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


@pytest.mark.slow
def test_sharded_train_and_elastic_reshard(tmp_path):
    _run(f"""
    import dataclasses, jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config
    from repro.distributed.sharding import ShardingPolicy
    from repro.train.trainer import Trainer, TrainerConfig
    from repro.train import checkpoint as ck

    from repro.distributed.compat import make_mesh
    cfg = dataclasses.replace(get_config("qwen3-8b").smoke(), num_layers=2)
    mesh = make_mesh((4, 2), ("data", "model"))
    pol = ShardingPolicy(mesh, cfg, mode="train")
    tc = TrainerConfig(seq_len=32, global_batch=4, steps=6, lr=1e-3,
                       ckpt_dir=r'{tmp_path}/ck', ckpt_every=3, log_every=2)
    with mesh:
        tr = Trainer(cfg, tc, pol)
        state = tr.run()
    l0 = tr.metrics_log[0]["loss"]; l1 = tr.metrics_log[-1]["loss"]
    assert np.isfinite(l1), l1

    # elastic: restore the 4x2 checkpoint onto a 2x2 mesh
    mesh2 = make_mesh((2, 2), ("data", "model"))
    pol2 = ShardingPolicy(mesh2, cfg, mode="train")
    template = {{"params": jax.tree_util.tree_map(np.asarray, state["params"])}}
    specs = {{"params": pol2.param_specs(state["params"])}}
    with mesh2:
        restored, step = ck.load_checkpoint(r'{tmp_path}/ck',
            {{"params": state["params"], "opt_state": state["opt_state"],
              "data_step": state["data_step"], "rng": state["rng"]}})
    a = jax.tree_util.tree_leaves(restored["params"])[0]
    print("elastic restore ok", step)
    """)


@pytest.mark.slow
def test_compressed_mean_shard_map():
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.distributed.compat import make_mesh, shard_map
    from repro.optim.compression import compressed_mean
    mesh = make_mesh((8,), ("data",))
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 1024)) * 0.01
    def f(xs):
        return compressed_mean(xs[0], "data")
    out = jax.jit(shard_map(f, mesh, in_specs=P("data"),
                  out_specs=P(), check=False))(x)
    ref = x.mean(axis=0)
    err = float(jnp.abs(out - ref).max())
    assert err < 2e-4, err
    print("compressed mean ok", err)
    """)


@pytest.mark.slow
def test_pipeline_over_axis():
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.distributed.compat import make_mesh
    from repro.distributed.pipeline import pipeline_apply
    S, M, mbsz, D = 4, 6, 2, 8
    mesh = make_mesh((4,), ("pod",))
    ws = jax.random.normal(jax.random.PRNGKey(0), (S, D, D)) * 0.3
    x = jax.random.normal(jax.random.PRNGKey(1), (M, mbsz, D))
    def stage(w, x):
        return jnp.tanh(x @ w)
    out = pipeline_apply(stage, ws, x, mesh, axis="pod")
    # oracle: sequential application of all stages
    y = x
    for s in range(S):
        y = jnp.tanh(y @ ws[s])
    np.testing.assert_allclose(np.asarray(out), np.asarray(y),
                               rtol=2e-5, atol=2e-5)
    print("pipeline ok")
    """)


def _skip_unless_abstract_mesh():
    """The spec-construction tests build device-free production meshes via
    jax.sharding.AbstractMesh, which the oldest supported jax predates —
    they skip on that CI matrix leg (and still run, never skip, in the
    multi-device job, which installs the latest jax)."""
    from repro.distributed.compat import has_abstract_mesh
    if not has_abstract_mesh():
        pytest.skip("jax.sharding.AbstractMesh unavailable on this jax "
                    "(oldest-pin compat leg)")


def test_param_specs_all_archs_production_meshes():
    _skip_unless_abstract_mesh()
    _run("""
    import jax
    from repro.configs import ASSIGNED_ARCHS, get_config
    from repro.distributed.compat import abstract_mesh
    from repro.distributed.sharding import ShardingPolicy
    from repro.models import model as M
    from functools import partial

    for axes in ((("data", 16), ("model", 16)),
                 (("pod", 2), ("data", 16), ("model", 16))):
        mesh = abstract_mesh(axes)
        for arch in ASSIGNED_ARCHS:
            cfg = get_config(arch)
            for mode in ("train", "serve"):
                pol = ShardingPolicy(mesh, cfg, mode=mode)
                shapes = jax.eval_shape(partial(M.init_params, cfg=cfg),
                                        jax.random.PRNGKey(0))
                specs = pol.param_specs(shapes)
                # every spec must divide its dim exactly
                def check(path, leaf, spec):
                    for d, ax in zip(leaf.shape, spec.spec):
                        if ax is None: continue
                        sz = 1
                        for a in (ax if isinstance(ax, tuple) else (ax,)):
                            sz *= dict(axes)[a]
                        assert d % sz == 0, (arch, mode, path, leaf.shape, spec)
                jax.tree_util.tree_map_with_path(check, shapes, specs)
    print("specs ok")
    """)


def test_param_specs_merged_wqkv_and_gu_production_meshes():
    """The merged-tree rules the sharded serve path stands on: merged
    ``wqkv`` gets the column split when the q/kv slices divide the model
    axis, the GQA row-parallel fallback otherwise (never full replication
    of a 2-D weight), and the widened ``[gate|up]`` always column-splits."""
    _skip_unless_abstract_mesh()
    _run("""
    import jax
    from functools import partial
    from repro.configs import ASSIGNED_ARCHS, get_config
    from repro.distributed.compat import abstract_mesh
    from repro.distributed.sharding import ShardingPolicy
    from repro.models import model as M

    def axes_of(spec):
        out = []
        for ax in spec:
            if ax is None: continue
            out.extend(ax if isinstance(ax, tuple) else (ax,))
        return out

    checked = 0
    for axes in ((("data", 16), ("model", 16)),
                 (("pod", 2), ("data", 16), ("model", 16))):
        mesh = abstract_mesh(axes)
        for arch in ASSIGNED_ARCHS:
            cfg = get_config(arch)
            pol = ShardingPolicy(mesh, cfg, mode="serve")
            shapes = jax.eval_shape(partial(M.init_params, cfg=cfg),
                                    jax.random.PRNGKey(0))
            specs = pol.param_specs(shapes)

            def check(path, leaf, sh):
                name = "/".join(str(getattr(p, "key", "")) for p in path)
                spec = tuple(sh.spec) + (None,) * (leaf.ndim
                                                   - len(tuple(sh.spec)))
                tp = dict(axes)["model"]
                if name.endswith("wqkv/w"):
                    kdim = leaf.ndim - 2          # skip scan-stack lead
                    col_ok = (cfg.attn_inner_dim % tp == 0
                              and cfg.kv_inner_dim % tp == 0
                              and cfg.num_kv_heads >= tp)
                    if col_ok:
                        assert "model" in axes_of((spec[-1],)), (arch, spec)
                    else:
                        assert "model" in axes_of((spec[kdim],)), (arch, spec)
                    return 1
                if name.endswith("gu/w"):
                    assert "model" in axes_of((spec[-1],)), (arch, spec)
                    return 1
                return 0

            counts = jax.tree_util.tree_map_with_path(check, shapes, specs)
            checked += sum(jax.tree_util.tree_leaves(counts))
    assert checked > 0, "no merged wqkv/gu leaves found"
    print("merged trees ok", checked)
    """)


def test_cache_specs_slot_pool_and_paged_store_production_meshes():
    """Serve-mode ``cache_specs`` over the continuous engine's slot pool
    and the paged KV store on the production meshes: KV head axes go over
    ``model``, entry metadata (pos/l0/l1) and everything the host mutates
    stay replicated, and every sharded dim divides its axes exactly."""
    _skip_unless_abstract_mesh()
    _run("""
    import jax
    from functools import partial
    from repro.configs import get_config
    from repro.distributed.compat import abstract_mesh
    from repro.distributed.sharding import ShardingPolicy
    from repro.kvcache import paged as paged_mod
    from repro.models import model as M

    def axes_of(spec):
        out = []
        for ax in spec:
            if ax is None: continue
            out.extend(ax if isinstance(ax, tuple) else (ax,))
        return out

    for axes in ((("data", 16), ("model", 16)),
                 (("pod", 2), ("data", 16), ("model", 16))):
        mesh = abstract_mesh(axes)
        sizes = dict(axes)
        cfg = get_config("llama2-7b")       # 32 KV heads: clean 16-way split
        pol = ShardingPolicy(mesh, cfg, mode="serve")

        pool = jax.eval_shape(partial(M.init_decode_cache, cfg, 32, 2048))
        pool_sh = pol.cache_specs(pool, layout=cfg.kv_cache_layout)
        k = pool_sh["stage0"]["pos0"]["k"]
        k_leaf = pool["stage0"]["pos0"]["k"]
        # [slots, T, Hkv, dh]: head axis on model, batch on data
        assert tuple(k.spec)[2] == "model", k.spec
        assert "model" not in axes_of((tuple(k.spec)[1],)), k.spec

        store = jax.eval_shape(partial(paged_mod.init_store, cfg, 256, 64))
        st_sh = pol.cache_specs(store)
        assert tuple(st_sh["k_pages"].spec)[2] == "model"
        assert tuple(st_sh["v_pages"].spec)[2] == "model"
        for meta in ("pos_pages", "l0_pages", "l1_pages"):
            assert not axes_of(tuple(st_sh[meta].spec)), (meta, st_sh[meta])

        # divisibility: every sharded dim divides its mesh axes
        def check(path, leaf, sh):
            spec = tuple(sh.spec) + (None,) * (leaf.ndim
                                               - len(tuple(sh.spec)))
            for d, ax in zip(leaf.shape, spec):
                if ax is None: continue
                sz = 1
                for a in (ax if isinstance(ax, tuple) else (ax,)):
                    sz *= sizes[a]
                assert d % sz == 0, (path, leaf.shape, spec)
        jax.tree_util.tree_map_with_path(check, pool, pool_sh)
        jax.tree_util.tree_map_with_path(check, store, st_sh)
    print("cache specs ok")
    """)
