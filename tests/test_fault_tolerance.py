"""Fault-injection matrix + request-lifecycle hardening
(``serve/faults.py``, ``serve/errors.py``, ``serve/snapshot.py``).

Acceptance bar (docs/robustness.md): every injected fault is survivable
with **bit-identical** survivor tokens at temperature 0, no page/slot
leaks, and counters that agree with the emitted trace instants; a host
kill at a step boundary is recoverable from the crash-consistent
snapshot by a *fresh* engine; deadline/cancellation release resources
within one step/epoch boundary; preemption victims are chosen (and
re-admitted) by original submission age so a preemption storm cannot
starve an old request.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.routing import neutral_router_bias
from repro.models import model as M
from repro.obs import Tracer, request_tid
from repro.serve.engine import ContinuousBatchingEngine
from repro.serve.errors import (AdmissionRejected, EngineAborted,
                                HungDispatch, PageExhausted, ServeError,
                                SimulatedKill)
from repro.serve.faults import (Fault, FaultInjected, FaultPlan, Watchdog,
                                as_fault_plan)
from repro.serve import snapshot as snap
from repro.serve.scheduler import Request, Scheduler

KEY = jax.random.PRNGKey(0)


def _cfg(**over):
    cfg = get_config("llama2-7b").smoke()
    return dataclasses.replace(cfg, **over) if over else cfg


def _prompts(cfg, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, (l,), dtype=np.int32)
            for l in lens]


@pytest.fixture(scope="module")
def cfg():
    return _cfg()


@pytest.fixture(scope="module")
def params(cfg):
    # neutral bias => the router actually skips, so the paged/Δ-KV
    # machinery (and its fault seams) is exercised, not bypassed
    return neutral_router_bias(M.init_params(KEY, cfg))


WORKLOAD_LENS = [9, 16, 5, 21]
MAX_NEW = 6

# the four engine paths the fault matrix must cover
MATRIX = [(False, False), (False, True), (True, False), (True, True)]
_IDS = ["dense-single", "dense-fused", "paged-single", "paged-fused"]


def _make_engine(cfg, params, *, paged, fused, **kw):
    if paged:
        kw.setdefault("kv_mode", "paged")
        kw.setdefault("page_size", 8)
    return ContinuousBatchingEngine(
        cfg, params, max_slots=2, max_len=48,
        decode_steps=4 if fused else 1, **kw)


@pytest.fixture(scope="module")
def engines(cfg, params):
    """One engine per (paged, fused) path, shared across the matrix tests
    (the jitted steps stay warm, so only the first run per path pays the
    compiles).  Each engine's first run is the fault-free baseline the
    faulted reruns are compared against bit-for-bit."""
    cache = {}

    def get(paged, fused):
        key = (paged, fused)
        if key not in cache:
            eng = _make_engine(cfg, params, paged=paged, fused=fused)
            prompts = _prompts(cfg, WORKLOAD_LENS)
            uids = [eng.submit(p, max_new_tokens=MAX_NEW) for p in prompts]
            out = eng.run()
            clean = [np.asarray(out["results"][u].tokens) for u in uids]
            assert all(len(t) == MAX_NEW for t in clean)
            cache[key] = (eng, clean)
        return cache[key]

    return get


def _fault_run(eng, cfg, faults):
    """Re-run the shared engine's workload with a fault plan + in-memory
    tracer attached; restores the engine's inert plan afterwards."""
    eng.faults = as_fault_plan(faults)
    eng.tracer = tr = Tracer()
    uids = [eng.submit(p, max_new_tokens=MAX_NEW)
            for p in _prompts(cfg, WORKLOAD_LENS)]
    try:
        out = eng.run()
    finally:
        plan = eng.faults
        eng.faults = FaultPlan()
    return out, uids, plan, tr


def _instants(tr, name):
    return [e for e in tr.events if e.get("ph") == "i"
            and e.get("name") == name]


def _assert_no_leaks(eng):
    assert not eng.scheduler.active and not eng.scheduler.queue
    assert eng.scheduler.prefilling is None
    assert eng.scheduler.free_slots == eng.max_slots
    if eng.kv_mode == "paged":
        assert eng.allocator.free_pages == eng.num_pages
        assert (eng.allocator.fill == 0).all()


# ---------------------------------------------------------------------------
# FaultPlan / Watchdog unit semantics
# ---------------------------------------------------------------------------

def test_fault_plan_pops_once_and_fires_late():
    plan = FaultPlan([Fault("oom", step=3, pages=2),
                      Fault("oom", step=5),
                      Fault("kill", step=4)])
    assert plan and plan.take("oom", 0) is None       # not due yet
    assert plan.take("dispatch_error", 99) is None    # kind mismatch
    f = plan.take("oom", 7)                           # late seam still fires
    assert f is not None and f.step == 3 and f.pages == 2
    assert plan.take("oom", 4) is None                # step-5 one not due
    assert plan.take("oom", 5).step == 5              # ...now it is
    assert [f.kind for f in plan.fired] == ["oom", "oom"]
    assert [f.kind for f in plan.unfired()] == ["kill"]
    assert plan and plan.take("kill", 4) and not plan


def test_fault_validation_and_normalization():
    with pytest.raises(ValueError, match="unknown fault kind"):
        Fault("meteor", step=0)
    with pytest.raises(ValueError, match="step"):
        Fault("oom", step=-1)
    assert not as_fault_plan(None)
    p = FaultPlan([Fault("kill", 0)])
    assert as_fault_plan(p) is p
    assert as_fault_plan([Fault("kill", 0)]).take("kill", 0)


def test_watchdog_strikes_and_hard_timeout():
    wd = Watchdog(timeout_s=1.0, factor=4.0, window=8, min_samples=3)
    for _ in range(4):
        assert not wd.observe("step", 0.01)           # steady state
    assert wd.observe("step", 0.1)                    # 10x median: strike
    assert wd.strikes == 1
    assert not wd.observe("step", 0.012)              # recovery: no strike
    with pytest.raises(HungDispatch) as ei:
        wd.observe("step", 1.5)                       # hard bound
    assert ei.value.phase == "step" and ei.value.elapsed_s == 1.5
    assert isinstance(ei.value, EngineAborted)


def test_watchdog_cold_start_immune():
    wd = Watchdog(factor=2.0, min_samples=5)
    # first observations are compile-dominated and wildly bimodal; no
    # strike may fire before min_samples
    for s in (5.0, 0.01, 0.01, 0.01):
        assert not wd.observe("step", s)


# ---------------------------------------------------------------------------
# Typed error hierarchy (back-compat: old except ValueError/RuntimeError
# call sites keep working)
# ---------------------------------------------------------------------------

def test_error_hierarchy_and_exports():
    import repro.serve as S
    for name in ("ServeError", "AdmissionRejected", "PageExhausted",
                 "DeadlineExceeded", "EngineAborted", "HungDispatch",
                 "SimulatedKill", "Fault", "FaultPlan", "Watchdog"):
        assert hasattr(S, name), name
    assert issubclass(AdmissionRejected, ValueError)
    assert issubclass(AdmissionRejected, ServeError)
    assert issubclass(PageExhausted, RuntimeError)
    assert issubclass(SimulatedKill, EngineAborted)
    assert issubclass(HungDispatch, EngineAborted)
    assert issubclass(FaultInjected, ServeError)


def test_admission_rejection_carries_reason(cfg, params):
    eng = _make_engine(cfg, params, paged=True, fused=False, num_pages=6)
    with pytest.raises(AdmissionRejected) as ei:
        eng.submit(_prompts(cfg, [40])[0], max_new_tokens=8)
    assert ei.value.reason == "kv_worst_case" and ei.value.uid == 0


# ---------------------------------------------------------------------------
# Scheduler: age-preserving re-admission (the starvation fix)
# ---------------------------------------------------------------------------

def test_requeue_is_age_ordered_not_front():
    sched = Scheduler(2, 32)
    a, b, c = (Request(uid=i, tokens=np.arange(4, dtype=np.int32),
                       max_new_tokens=2) for i in range(3))
    for r in (a, b, c):
        sched.submit(r)
    sched.queue.popleft()                             # a admitted...
    sched.queue.popleft()                             # ...and b
    sched.requeue(b)                                  # b preempted
    assert [r.uid for r in sched.queue] == [1, 2]     # before younger c
    sched.requeue(a)                                  # a preempted too
    assert [r.uid for r in sched.queue] == [0, 1, 2]  # full age order
    # submit_s is the *original* stamp: requeueing must not refresh it
    assert a.submit_s < b.submit_s < c.submit_s


# ---------------------------------------------------------------------------
# The fault matrix: each fault kind x all four engine paths.
# Survivors must be bit-identical to the fault-free baseline, nothing
# may leak, and the counters must agree with the trace instants.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("paged,fused", MATRIX, ids=_IDS)
@pytest.mark.parametrize("kind", ["dispatch_error", "stall", "oom"])
def test_fault_matrix_bit_identical_survivors(kind, paged, fused,
                                              engines, cfg):
    if kind == "oom" and not paged:
        pytest.skip("page-alloc OOM is a paged-KV seam")
    eng, clean = engines(paged, fused)
    # a fused run takes ~1/decode_steps as many iterations (epochs) as a
    # single-step run — schedule its faults into iterations that exist
    d, s = (1, 2) if fused else (2, 5)
    faults = {
        "dispatch_error": [Fault("dispatch_error", step=d),
                           Fault("dispatch_error", step=s)],
        "stall": [Fault("stall", step=d, stall_s=0.05)],
        "oom": [Fault("oom", step=d, pages=0)],   # hide ALL free pages
    }[kind]
    out, uids, plan, tr = _fault_run(eng, cfg, faults)

    assert not plan.unfired(), plan.unfired()     # every fault triggered
    for u, want in zip(uids, clean):
        r = out["results"][u]
        assert r.finish_reason == "length"
        np.testing.assert_array_equal(np.asarray(r.tokens), want)
    _assert_no_leaks(eng)

    s, m = out["stats"], out["metrics"]
    assert s.faults_injected == len(faults)
    assert s.faults_injected == len(_instants(tr, "fault"))
    if kind == "dispatch_error":
        assert s.dispatch_retries == len(faults)
        assert m.value("dispatch_retries_total") == len(faults)
    if kind == "oom" and not fused:
        # hiding the whole free list forces the normal OOM backpressure
        assert s.preemptions >= 1 or s.faults_injected == 1
    if kind == "oom" and fused:
        # fused path degrades first: epoch shrink before preemption
        assert s.epoch_shrinks == len(_instants(tr, "epoch_shrink"))


@pytest.mark.parametrize("paged,fused", MATRIX, ids=_IDS)
def test_kill_and_resume_bit_identical(paged, fused, engines, cfg, params,
                                       tmp_path):
    eng, clean = engines(paged, fused)
    snap_dir = str(tmp_path / "snaps")
    eng.snapshot_dir = snap_dir
    # fused epochs cover decode_steps tokens per boundary, so the whole
    # run spans only a handful of boundaries — kill early enough to fire
    eng.faults = as_fault_plan([Fault("kill", step=2 if fused else 6,
                                      message="pulled the plug")])
    uids = [eng.submit(p, max_new_tokens=MAX_NEW)
            for p in _prompts(cfg, WORKLOAD_LENS)]
    try:
        with pytest.raises(SimulatedKill, match="pulled the plug"):
            eng.run()
        assert eng.metrics.value("faults_injected_total") == 1
        assert eng.metrics.value("snapshots_total") >= 1
        assert snap.latest_snapshot_step(snap_dir) is not None
    finally:
        # the killed engine is dead to us: drop its leftover state so the
        # shared fixture stays clean for any later test on this path
        eng.snapshot_dir = None
        eng.faults = FaultPlan()
        eng.scheduler = Scheduler(eng.max_slots, eng.max_len,
                                  buckets=eng.scheduler.buckets,
                                  prefill_chunk=eng.prefill_chunk)
        if paged:
            eng.allocator = type(eng.allocator)(
                eng.num_pages, eng.page_size, eng.max_slots,
                slot_entry_capacity=eng.max_len * eng.n_attn)

    # a *fresh* engine (fresh process, same geometry) resumes and drains
    eng2 = _make_engine(cfg, params, paged=paged, fused=fused,
                        snapshot_dir=snap_dir)
    at = eng2.resume()
    assert at >= 1
    out = eng2.run()
    assert out["stats"].resumes == 1
    # every request — finished pre-kill (restored results) or surviving
    # (recomputed) — must match the fault-free baseline bit for bit
    assert sorted(out["results"]) == sorted(uids)
    for u, want in zip(uids, clean):
        r = out["results"][u]
        assert r.finish_reason == "length"
        np.testing.assert_array_equal(np.asarray(r.tokens), want)
    _assert_no_leaks(eng2)


def test_resume_fingerprint_rejects_geometry_change(cfg, params, engines,
                                                    tmp_path):
    eng, _ = engines(False, False)
    snap_dir = str(tmp_path / "snaps")
    eng.snapshot_dir = snap_dir
    uids = [eng.submit(p, max_new_tokens=MAX_NEW)
            for p in _prompts(cfg, WORKLOAD_LENS)]
    try:
        eng.run()
    finally:
        eng.snapshot_dir = None
    assert uids and snap.latest_snapshot_step(snap_dir) is not None
    other = ContinuousBatchingEngine(cfg, params, max_slots=3, max_len=48,
                                     snapshot_dir=snap_dir)
    with pytest.raises(ValueError, match="fingerprint"):
        other.resume()


# ---------------------------------------------------------------------------
# Lifecycle: deadlines, cancellation, shedding, retry budget
# ---------------------------------------------------------------------------

def test_deadline_expires_queued_request(engines, cfg):
    eng, clean = engines(False, False)
    prompts = _prompts(cfg, WORKLOAD_LENS)
    uids = [eng.submit(p, max_new_tokens=MAX_NEW) for p in prompts[:2]]
    doomed = eng.submit(prompts[2], max_new_tokens=MAX_NEW,
                        deadline_s=0.0)          # expired on arrival
    out = eng.run()
    r = out["results"][doomed]
    assert r.finish_reason == "deadline" and len(r.tokens) == 0
    assert out["stats"].deadline_exceeded == 1
    for u, want in zip(uids, clean[:2]):
        np.testing.assert_array_equal(np.asarray(out["results"][u].tokens),
                                      want)
    _assert_no_leaks(eng)


def test_cancel_resident_keeps_partial_and_releases(engines, cfg):
    """Mid-run cancellation of a *resident*: the request finishes with
    the tokens it had at the next boundary (reason "cancelled"), its
    slot/pages are released within one step, survivors are unaffected."""
    eng, clean = engines(True, False)
    eng.tracer = tr = Tracer()
    prompts = _prompts(cfg, WORKLOAD_LENS)
    victim = eng.submit(prompts[0], max_new_tokens=MAX_NEW)
    keeper = eng.submit(prompts[1], max_new_tokens=MAX_NEW)
    real_boundary = eng._boundary
    fired = []

    def hook(rs, kv_state):
        real_boundary(rs, kv_state)
        resident = {st.req.uid for st in eng.scheduler.active.values()}
        # cancel early (the sweep acts at the NEXT boundary): by the time
        # a later boundary sweeps, a 6-token request may have finished
        if not fired and victim in resident and rs.disp_idx >= 2:
            eng.cancel(victim)
            fired.append(rs.disp_idx)

    eng._boundary = hook
    try:
        out = eng.run()
    finally:
        eng._boundary = real_boundary
        eng.tracer = Tracer()
    assert fired, "victim never became resident"
    r = out["results"][victim]
    assert r.finish_reason == "cancelled"
    assert 0 < len(r.tokens) < MAX_NEW
    # the partial prefix is the real greedy prefix, not garbage
    np.testing.assert_array_equal(np.asarray(r.tokens),
                                  clean[0][:len(r.tokens)])
    np.testing.assert_array_equal(
        np.asarray(out["results"][keeper].tokens), clean[1])
    assert out["stats"].requests_cancelled == 1
    assert len(_instants(tr, "cancel")) == 1
    _assert_no_leaks(eng)


def test_cancel_unknown_uid_is_noop(engines, cfg):
    eng, clean = engines(False, False)
    eng.cancel(10_000)
    uid = eng.submit(_prompts(cfg, WORKLOAD_LENS)[0],
                     max_new_tokens=MAX_NEW)
    out = eng.run()
    np.testing.assert_array_equal(np.asarray(out["results"][uid].tokens),
                                  clean[0])
    assert out["stats"].requests_cancelled == 0


def test_shed_on_queue_depth(engines, cfg):
    eng, _ = engines(False, False)
    eng.max_queue_depth = 2
    prompts = _prompts(cfg, WORKLOAD_LENS)
    try:
        ok = [eng.submit(p, max_new_tokens=2) for p in prompts[:2]]
        with pytest.raises(AdmissionRejected) as ei:
            eng.submit(prompts[2], max_new_tokens=2)
        assert ei.value.reason == "queue_depth"
        # shedding rejects the newcomer, never the queued work
        assert [r.uid for r in eng.scheduler.queue] == ok
        out = eng.run()
    finally:
        eng.max_queue_depth = None
    assert out["stats"].requests_shed == 1
    assert all(out["results"][u].finish_reason == "length" for u in ok)


def test_shed_on_queue_delay(engines, cfg):
    import time
    eng, _ = engines(False, False)
    eng.max_queue_delay_s = 0.01
    prompts = _prompts(cfg, WORKLOAD_LENS)
    try:
        head = eng.submit(prompts[0], max_new_tokens=2)
        time.sleep(0.03)                     # head now past the bound
        with pytest.raises(AdmissionRejected) as ei:
            eng.submit(prompts[1], max_new_tokens=2)
        assert ei.value.reason == "queue_delay"
        out = eng.run()
    finally:
        eng.max_queue_delay_s = None
    assert out["stats"].requests_shed == 1
    assert out["results"][head].finish_reason == "length"


def test_preempt_budget_finishes_with_partial(cfg, params):
    """max_preemptions=0: the first eviction retires the victim with its
    partial tokens (reason "preempt_budget") instead of requeueing."""
    eng = _make_engine(cfg, params, paged=True, fused=False,
                       max_preemptions=0)
    prompts = _prompts(cfg, [8, 8], seed=1)
    a = eng.submit(prompts[0], max_new_tokens=12)
    b = eng.submit(prompts[1], max_new_tokens=12)
    real_boundary = eng._boundary
    forced = []

    def hook(rs, kv_state):
        real_boundary(rs, kv_state)
        if (not forced and rs.disp_idx >= 4
                and len(eng.scheduler.active) == 2
                and eng.scheduler.prefilling is None):
            assert eng._preempt_youngest(rs, exclude=-1)
            forced.append(rs.disp_idx)

    eng._boundary = hook
    try:
        out = eng.run()
    finally:
        eng._boundary = real_boundary
    assert forced
    rb = out["results"][b]                       # b is youngest-by-submit
    assert rb.finish_reason == "preempt_budget"
    assert len(rb.tokens) < 12
    assert out["results"][a].finish_reason == "length"
    assert out["stats"].preempt_budget_exhausted == 1
    assert out["stats"].preemptions == 1
    _assert_no_leaks(eng)


def test_fairness_thrice_preempted_beats_later_arrivals(cfg, params):
    """The starvation regression: a request evicted three times is still
    re-admitted by *original submission age*, so it finishes before
    requests that arrived after it (under the old admission-recency
    victim rule it was re-victimized forever)."""
    eng = _make_engine(cfg, params, paged=True, fused=False)
    eng.tracer = tr = Tracer()
    prompts = _prompts(cfg, [8, 8, 8, 8], seed=2)
    a = eng.submit(prompts[0], max_new_tokens=24)    # oldest, long-running
    b = eng.submit(prompts[1], max_new_tokens=12)    # the storm victim
    late = [eng.submit(p, max_new_tokens=12) for p in prompts[2:]]
    real_boundary = eng._boundary
    forced = []

    def hook(rs, kv_state):
        real_boundary(rs, kv_state)
        resident = {st.req.uid for st in eng.scheduler.active.values()}
        if (len(forced) < 3 and b in resident and a in resident
                and eng.scheduler.prefilling is None):
            assert eng._preempt_youngest(rs, exclude=-1)
            forced.append(rs.disp_idx)
            # age order: b re-enters the queue AHEAD of the later arrivals
            assert [r.uid for r in eng.scheduler.queue][0] == b

    eng._boundary = hook
    try:
        out = eng.run()
    finally:
        eng._boundary = real_boundary
        eng.tracer = Tracer()
    assert len(forced) == 3, forced
    assert out["stats"].preemptions == 3
    for u in (a, b, *late):
        assert out["results"][u].finish_reason == "length"
    # b finished before both later arrivals despite three evictions
    finish_ts = {e["tid"]: e["ts"] for e in _instants(tr, "finish")}
    for u in late:
        assert finish_ts[request_tid(b)] < finish_ts[request_tid(u)], \
            (finish_ts, b, u)
    _assert_no_leaks(eng)


# ---------------------------------------------------------------------------
# Watchdog wired into the engine
# ---------------------------------------------------------------------------

def test_watchdog_converts_stall_into_hung_dispatch(cfg, params, tmp_path):
    trace_path = tmp_path / "hung.json"
    eng = _make_engine(
        cfg, params, paged=False, fused=False,
        trace=str(trace_path),
        watchdog=Watchdog(timeout_s=0.25),
        faults=[Fault("stall", step=0, stall_s=0.5)])
    eng.submit(_prompts(cfg, [8])[0], max_new_tokens=4)
    with pytest.raises(HungDispatch, match="declared hung") as ei:
        eng.run()
    # the PR-7 trace is flushed on the abort path and rides the exception
    assert ei.value.trace_path == str(trace_path)
    assert trace_path.exists()
    assert eng.metrics.value("watchdog_timeouts_total") == 1
    assert eng.faults.fired and eng.faults.fired[0].kind == "stall"


# ---------------------------------------------------------------------------
# Snapshot store unit semantics (engine-independent)
# ---------------------------------------------------------------------------

def test_snapshot_roundtrip_prune_and_select(tmp_path):
    d = str(tmp_path)
    key = jax.random.PRNGKey(7)
    tree = {"kv": {"k": jax.numpy.arange(6, dtype=jax.numpy.bfloat16),
                   "t": np.arange(3, dtype=np.int32)},
            "rng": key}
    for step in (2, 4, 6, 8):
        snap.save_snapshot(d, step, tree, {"step": step}, keep=3)
    assert snap.list_snapshot_steps(d) == [4, 6, 8]   # pruned to keep=3
    assert snap.latest_snapshot_step(d) == 8
    template = {"kv": {"k": jax.numpy.zeros(6, jax.numpy.bfloat16),
                       "t": np.zeros(3, np.int32)},
                "rng": jax.random.PRNGKey(0)}
    restored, host, at = snap.load_snapshot(d, template, step=6)
    assert at == 6 and host["step"] == 6
    np.testing.assert_array_equal(
        np.asarray(restored["kv"]["k"], np.float32),
        np.asarray(tree["kv"]["k"], np.float32))
    assert restored["kv"]["k"].dtype == jax.numpy.bfloat16
    np.testing.assert_array_equal(
        jax.random.key_data(restored["rng"]), jax.random.key_data(key))
    # tokens drawn from the restored key are the crash-consistency bar
    np.testing.assert_array_equal(
        np.asarray(jax.random.uniform(restored["rng"], (4,))),
        np.asarray(jax.random.uniform(key, (4,))))
    with pytest.raises(FileNotFoundError):
        snap.load_snapshot(d, template, step=2)       # pruned away


def test_page_hide_unhide_restores_free_list_order():
    from repro.kvcache.paged import PageAllocator
    a = PageAllocator(num_pages=8, page_size=4, max_slots=2,
                      slot_entry_capacity=16)
    before = list(a._free)
    hidden = a.hide_pages(3)
    assert len(hidden) == 3 and a.free_pages == 5
    a.unhide_pages(hidden)
    assert list(a._free) == before                    # exact order back
    hidden = a.hide_pages(0)                          # 0 = hide everything
    assert a.free_pages == 0 and len(hidden) == 8
    a.unhide_pages(hidden)
    assert list(a._free) == before
