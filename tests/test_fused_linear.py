"""Fused hybrid linear pipeline: property tests across the prologue ×
weight-path × epilogue matrix vs the ref.py oracles (a deterministic
parametrized grid always runs; hypothesis fuzzes the same checker when
installed), the legacy weight-merge shim, and end-to-end
``use_kernels=True`` ≡ pure-jnp decode identity for the dense and int4
engines (interpret mode on CPU)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import routing
from repro.kernels import ops, ref
from repro.models import layers
from repro.models import model as M
from repro.quant import quantize_params, quantize_rtn

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                        # pragma: no cover
    HAVE_HYPOTHESIS = False

KEY = jax.random.PRNGKey(0)


def _mx(a, b):
    return float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).max())


# ---------------------------------------------------------------------------
# Property checker: kernel == oracle over the full configuration matrix
# ---------------------------------------------------------------------------

def _check_case(seed: int, M_: int, K: int, F: int, prologue: bool,
                int4: bool, epilogue: str, act, group: int = 64):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((M_, K)),
                    jnp.float32).astype(jnp.bfloat16)
    N = 2 * F if epilogue == "glu" else F
    w = jnp.asarray(rng.standard_normal((K, N)) * 0.04, jnp.float32)
    kw = {"act": act}
    if prologue:
        kw["mean_sq"] = jnp.asarray(
            (np.asarray(x, np.float32) ** 2).mean(-1))
        kw["gamma"] = jnp.asarray(
            1.0 + 0.1 * rng.standard_normal(K), jnp.float32)
    if epilogue == "glu":
        kw["glu"] = True
    if epilogue == "residual":
        kw["residual"] = jnp.asarray(
            rng.standard_normal((M_, F)), jnp.float32).astype(jnp.bfloat16)
        kw["gate_mul"] = jnp.asarray(
            (rng.random(M_) > 0.5).astype(np.float32))
        kw["emit_sq"] = True

    if int4:
        codes, scale = quantize_rtn(w, group, pow2_scales=True)
        params = {"w_int": codes, "scale": scale}
        args = dict(w_codes=codes, scale=scale)
    else:
        params = {"w": w}
        args = dict(w=w)

    out, sq = ops.fused_linear(params, x, **kw)
    oref, sq_ref = ref.fused_linear_ref(x, **args, **kw)
    scale_mag = max(1.0, float(jnp.abs(oref.astype(jnp.float32)).max()))
    assert _mx(out, oref) <= 1e-4 * scale_mag
    if sq_ref is not None:
        np.testing.assert_allclose(np.asarray(sq), np.asarray(sq_ref),
                                   rtol=1e-4, atol=1e-4)
    else:
        assert sq is None


_GRID = [
    # seed, M, K, F, prologue, int4, epilogue, act, group
    (0, 37, 300, 70, True, False, "glu", "silu", 64),
    (1, 64, 128, 32, True, True, "glu", "gelu", 128),
    (2, 7, 200, 130, False, True, "residual", None, 32),
    (3, 48, 256, 96, True, True, "residual", "silu", 64),
    (4, 1, 64, 32, False, False, "none", None, 64),
    (5, 70, 300, 96, True, True, "none", "gelu", 128),
    (6, 33, 64, 130, False, False, "residual", "gelu", 64),
    (7, 16, 200, 32, True, False, "none", None, 64),
]


@pytest.mark.parametrize("seed,M_,K,F,prologue,int4,epilogue,act,group",
                         _GRID)
def test_fused_linear_matches_oracle_grid(seed, M_, K, F, prologue, int4,
                                          epilogue, act, group):
    _check_case(seed, M_, K, F, prologue, int4, epilogue, act, group)


if HAVE_HYPOTHESIS:
    @given(st.data())
    @settings(max_examples=12, deadline=None)
    def test_fused_linear_matches_oracle_fuzz(data):
        epilogue = data.draw(st.sampled_from(["none", "glu", "residual"]))
        act = (data.draw(st.sampled_from(["silu", "gelu"]))
               if epilogue == "glu"
               else data.draw(st.sampled_from([None, "silu", "gelu"])))
        _check_case(
            seed=data.draw(st.integers(0, 10_000)),
            M_=data.draw(st.integers(1, 70)),
            K=data.draw(st.sampled_from([64, 128, 200, 300])),
            F=data.draw(st.sampled_from([32, 96, 130])),
            prologue=data.draw(st.booleans()),
            int4=data.draw(st.booleans()),
            epilogue=epilogue, act=act,
            group=data.draw(st.sampled_from([32, 64, 128])))


def test_fused_linear_leading_dims_and_jnp_dispatch():
    """[B, T, K] leading dims flatten/unflatten; use_kernel=False routes
    to the same oracle arithmetic."""
    rng = np.random.default_rng(7)
    B, T, K, F = 2, 5, 96, 40
    x = jnp.asarray(rng.standard_normal((B, T, K)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((K, F)) * 0.05, jnp.float32)
    res = jnp.asarray(rng.standard_normal((B, T, F)), jnp.float32)
    gm = jnp.asarray((rng.random((B, T)) > 0.5).astype(np.float32))
    ok, sqk = ops.fused_linear({"w": w}, x, residual=res, gate_mul=gm,
                               emit_sq=True, use_kernel=True)
    oj, sqj = ops.fused_linear({"w": w}, x, residual=res, gate_mul=gm,
                               emit_sq=True, use_kernel=False)
    assert ok.shape == (B, T, F) and sqk.shape == (B, T)
    np.testing.assert_allclose(np.asarray(ok), np.asarray(oj),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(sqk), np.asarray(sqj),
                               rtol=1e-5, atol=1e-5)


def test_emitted_sq_equals_next_norm_stats():
    """The epilogue's Σy²/D carry must equal the next block's norm_stats
    reduction of the written residual stream (fp32, pre-cast)."""
    ks = jax.random.split(KEY, 3)
    M_, K, F = 33, 128, 128
    x = jax.random.normal(ks[0], (M_, K), jnp.float32)
    w = jax.random.normal(ks[1], (K, F), jnp.float32) * 0.05
    res = jax.random.normal(ks[2], (M_, F), jnp.float32)
    out, sq = ops.fused_linear({"w": w}, x, residual=res, emit_sq=True)
    cfg = get_config("qwen3-8b").smoke()
    direct = layers.norm_stats(out, cfg)          # rmsnorm: mean(y²)
    np.testing.assert_allclose(np.asarray(sq) / F, np.asarray(direct),
                               rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# Legacy weight-merge shim
# ---------------------------------------------------------------------------

def test_merge_legacy_linear_params():
    cfg = get_config("qwen3-8b").smoke()
    ks = jax.random.split(KEY, 5)
    d, ai, ki, f = (cfg.d_model, cfg.attn_inner_dim, cfg.kv_inner_dim,
                    cfg.d_ff)
    legacy = {
        "mixer": {"inner": {
            "wq": layers.linear_init(ks[0], d, ai, cfg),
            "wk": layers.linear_init(ks[1], d, ki, cfg),
            "wv": layers.linear_init(ks[2], d, ki, cfg),
            "wo": layers.linear_init(ks[3], ai, d, cfg)}},
        "ffn": {"inner": {
            "gate": layers.linear_init(ks[4], d, f, cfg),
            "up": layers.linear_init(ks[0], d, f, cfg),
            "down": layers.linear_init(ks[1], f, d, cfg)}},
    }
    merged = layers.merge_legacy_linear_params(legacy)
    inner = merged["mixer"]["inner"]
    assert set(inner) == {"wqkv", "wo"}
    assert inner["wqkv"]["w"].shape == (d, ai + 2 * ki)
    np.testing.assert_array_equal(
        np.asarray(inner["wqkv"]["w"][:, :ai]),
        np.asarray(legacy["mixer"]["inner"]["wq"]["w"]))
    ffn = merged["ffn"]["inner"]
    assert set(ffn) == {"gu", "down"}
    np.testing.assert_array_equal(
        np.asarray(ffn["gu"]["w"][:, f:]),
        np.asarray(legacy["ffn"]["inner"]["up"]["w"]))
    assert layers.mlp_fusable(ffn)


def test_merge_legacy_mixed_quantization():
    """quantize_params' size threshold can quantize wq but leave the
    smaller wk/wv dense on a legacy GQA tree — the merge shim must
    dequantize the mixed trio into a dense wqkv instead of crashing."""
    rng = np.random.default_rng(5)
    d, ai, ki = 64, 64, 16
    wq = jnp.asarray(rng.standard_normal((d, ai)) * 0.05, jnp.float32)
    codes, scale = quantize_rtn(wq, 32, pow2_scales=True)
    legacy = {"inner": {
        "wq": {"w_int": codes, "scale": scale},
        "wk": {"w": jnp.asarray(rng.standard_normal((d, ki)) * 0.05,
                                jnp.float32)},
        "wv": {"w": jnp.asarray(rng.standard_normal((d, ki)) * 0.05,
                                jnp.float32)},
        "wo": {"w": jnp.asarray(rng.standard_normal((ai, d)) * 0.05,
                                jnp.float32)}}}
    merged = layers.merge_legacy_linear_params(legacy)["inner"]
    assert set(merged) == {"wqkv", "wo"}
    assert merged["wqkv"]["w"].shape == (d, ai + 2 * ki)
    # the quantized slice round-trips through dequantization
    from repro.quant import dequantize
    np.testing.assert_allclose(np.asarray(merged["wqkv"]["w"][:, :ai]),
                               np.asarray(dequantize(codes, scale, k=d)),
                               rtol=1e-6)
    np.testing.assert_array_equal(
        np.asarray(merged["wqkv"]["w"][:, ai:ai + ki]),
        np.asarray(legacy["inner"]["wk"]["w"]))


def test_quantized_merged_weights_slice_consistently():
    """Slicing a quantized merged wqkv must equal quantizing the slices:
    per-group scales are per-output-column, so the BFP domain commutes
    with the column split."""
    rng = np.random.default_rng(3)
    d, ai, ki = 128, 128, 64
    w = jnp.asarray(rng.standard_normal((d, ai + 2 * ki)) * 0.05,
                    jnp.float32)
    codes, scale = quantize_rtn(w, 64, pow2_scales=True)
    merged = {"w_int": codes, "scale": scale}
    sliced = layers.slice_linear(merged, ai, ai + ki)
    codes_k, scale_k = quantize_rtn(w[:, ai:ai + ki], 64, pow2_scales=True)
    np.testing.assert_array_equal(np.asarray(sliced["w_int"]),
                                  np.asarray(codes_k))
    np.testing.assert_array_equal(np.asarray(sliced["scale"]),
                                  np.asarray(scale_k))


# ---------------------------------------------------------------------------
# End-to-end decode identity: use_kernels=True ≡ pure-jnp
# ---------------------------------------------------------------------------

def _greedy_decode(params, cfg, toks, steps=3, forced=None):
    """Prefill + ``steps`` decode steps.  ``forced`` [B, steps] pins the
    fed tokens (teacher forcing) so different numeric paths stay aligned;
    otherwise each step feeds its own argmax."""
    T = toks.shape[1]
    lg, cache, _ = M.prefill(params, {"tokens": toks}, cfg, pad_to=T + steps)
    logits = [lg]
    tok = lg.argmax(-1)[:, None] if forced is None else forced[:, :1]
    for s in range(steps):
        lg, cache, _ = M.decode_step(params, cache, {"tokens": tok},
                                     jnp.int32(T + s), cfg)
        logits.append(lg)
        if forced is None:
            tok = lg.argmax(-1)[:, None]
        elif s + 1 < steps:
            tok = forced[:, s + 1:s + 2]
    return logits


@pytest.mark.parametrize("mode", ["masked", "gather"])
def test_decode_identity_dense_engine(mode):
    base = get_config("qwen3-8b").smoke()
    base = dataclasses.replace(
        base, skip=dataclasses.replace(base.skip, mode=mode))
    params = routing.neutral_router_bias(M.init_params(KEY, base))
    toks = jax.random.randint(KEY, (2, 24), 0, base.vocab_size)
    lj = _greedy_decode(params, dataclasses.replace(base, use_kernels=False),
                        toks)
    lk = _greedy_decode(params, dataclasses.replace(base, use_kernels=True),
                        toks)
    for a, b in zip(lj, lk):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=0.05, atol=0.05)
        assert (a.argmax(-1) == b.argmax(-1)).all()


def test_decode_identity_int4_engine():
    """int4 engine: the fused kernel path must stay inside the BFP-regime
    tolerance of the exact-dequant jnp path, and restructuring the
    dispatch (fuse_linear on/off, both on the kernel path) must not move
    the greedy tokens."""
    base = get_config("qwen3-8b").smoke()
    params = quantize_params(M.init_params(KEY, base), group_size=64,
                             min_size=1 << 12)
    toks = jax.random.randint(KEY, (2, 24), 0, base.vocab_size)
    # teacher-forced continuation keeps the three numeric paths aligned
    # (self-fed greedy would diverge after any BFP-noise tie-break and
    # make later logits incomparable)
    forced = jax.random.randint(jax.random.PRNGKey(9), (2, 3), 0,
                                base.vocab_size)
    lj = _greedy_decode(params, dataclasses.replace(base, use_kernels=False),
                        toks, forced=forced)
    lk = _greedy_decode(params, dataclasses.replace(base, use_kernels=True),
                        toks, forced=forced)
    lu = _greedy_decode(params, dataclasses.replace(
        base, use_kernels=True, fuse_linear=False), toks, forced=forced)
    agree, total = 0, 0
    for a, b, c in zip(lj, lk, lu):
        a = np.asarray(a, np.float32)
        b = np.asarray(b, np.float32)
        c = np.asarray(c, np.float32)
        # kernel (BFP fixed-point) vs jnp (exact dequant): Table-1 regime
        assert np.linalg.norm(b - a) / np.linalg.norm(a) < 0.1
        # fused vs per-op kernel dispatch: same arithmetic domain
        assert np.linalg.norm(b - c) / np.linalg.norm(c) < 0.1
        # near-ties may flip under BFP rounding: require the fused pick to
        # sit in the unfused top-5 (and mostly agree exactly)
        top5_c = np.argsort(c, axis=-1)[:, -5:]
        for row, pick in enumerate(b.argmax(-1)):
            assert pick in top5_c[row]
        agree += int((b.argmax(-1) == c.argmax(-1)).sum())
        total += b.shape[0]
    assert agree / total >= 0.75, f"argmax agreement {agree}/{total}"


# The paged-decode fused prologue is covered end-to-end by
# tests/test_paged_kv.py::test_paged_decode_matches_dense_and_compact_store
# with use_kernels=True, which now dispatches through the fused pipeline
# (cfg.fuse_linear defaults on).
