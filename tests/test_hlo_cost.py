"""Unit tests for the loop-aware HLO static cost analyzer (the §Roofline
source of truth)."""
import jax
import jax.numpy as jnp
import pytest

from repro.roofline.hlo_cost import hlo_static_cost
from repro.roofline.analysis import roofline_terms, HW


def test_scan_flops_match_unrolled():
    def scanned(x, w):
        def body(c, _):
            return c @ w, None
        c, _ = jax.lax.scan(body, x, None, length=7)
        return c

    def unrolled(x, w):
        for _ in range(7):
            x = x @ w
        return x

    sh = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    c1 = hlo_static_cost(jax.jit(scanned).lower(sh, sh).compile().as_text())
    c2 = hlo_static_cost(jax.jit(unrolled).lower(sh, sh).compile().as_text())
    expected = 7 * 2 * 128 ** 3
    assert abs(c1["flops"] - expected) / expected < 0.01
    assert abs(c2["flops"] - expected) / expected < 0.01
    assert c1["unknown_loops"] == 0


def test_nested_scan_multiplication():
    def f(x, w):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None
            ci, _ = jax.lax.scan(inner, c, None, length=3)
            return ci, None
        c, _ = jax.lax.scan(outer, x, None, length=5)
        return c

    sh = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    c = hlo_static_cost(jax.jit(f).lower(sh, sh).compile().as_text())
    expected = 15 * 2 * 64 ** 3
    assert abs(c["flops"] - expected) / expected < 0.02


def test_bf16_upcast_normalization():
    """CPU upcasts bf16 dot operands to f32; bytes must count at bf16."""
    def f(x, w):
        return (x @ w).astype(jnp.bfloat16)

    sh = jax.ShapeDtypeStruct((256, 256), jnp.bfloat16)
    c = hlo_static_cost(jax.jit(f).lower(sh, sh).compile().as_text())
    # reads 2 × 128KB (bf16) + intermediate/result writes; an f32-counted
    # version would be ≥ 4 × that.
    assert c["bytes"] < 1.3e6, c["bytes"]


def test_roofline_terms_bottleneck():
    t = roofline_terms(HW["peak_flops"], 0.0, 0.0)
    assert t["bottleneck"] == "compute" and abs(t["compute_s"] - 1.0) < 1e-9
    t = roofline_terms(0.0, HW["hbm_bw"], 0.0)
    assert t["bottleneck"] == "memory"
    t = roofline_terms(0.0, 0.0, HW["ici_bw"])
    assert t["bottleneck"] == "collective"
