"""Per-kernel allclose vs the pure-jnp oracles (ref.py), with shape/dtype
sweeps.  interpret=True executes the Pallas kernel bodies on CPU."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.quant import dequantize, quantize_rtn

KEY = jax.random.PRNGKey(0)


def _mx(a, b):
    return float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).max())


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,Tq,Tk,Hq,Hkv,dh", [
    (1, 16, 16, 4, 4, 32),        # MHA square
    (2, 64, 96, 8, 2, 64),        # GQA rectangular
    (1, 13, 40, 6, 3, 80),        # odd shapes -> padding paths
    (2, 128, 256, 4, 1, 128),     # MQA, block-sized
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, Tq, Tk, Hq, Hkv, dh, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Tq, Hq, dh), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, Tk, Hkv, dh), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, Tk, Hkv, dh), jnp.float32).astype(dtype)
    qpos = jnp.broadcast_to(jnp.arange(Tk - Tq, Tk)[None], (B, Tq))
    out = ops.flash_attention(q, k, v, q_positions=qpos, causal=True)
    oref = ref.flash_attention_ref(q, k, v, q_positions=qpos, causal=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    assert _mx(out, oref) < tol


def test_flash_attention_window():
    ks = jax.random.split(KEY, 3)
    B, T, H, dh = 2, 64, 4, 32
    q = jax.random.normal(ks[0], (B, T, H, dh))
    k = jax.random.normal(ks[1], (B, T, H, dh))
    v = jax.random.normal(ks[2], (B, T, H, dh))
    qpos = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
    out = ops.flash_attention(q, k, v, q_positions=qpos, window=9)
    oref = ref.flash_attention_ref(q, k, v, q_positions=qpos, window=9)
    assert _mx(out, oref) < 2e-5


def test_decode_attention_valid_len():
    ks = jax.random.split(KEY, 3)
    B, Tk, Hq, Hkv, dh = 3, 128, 8, 2, 64
    q = jax.random.normal(ks[0], (B, 1, Hq, dh))
    k = jax.random.normal(ks[1], (B, Tk, Hkv, dh))
    v = jax.random.normal(ks[2], (B, Tk, Hkv, dh))
    vl = jnp.array([17, 64, 128], jnp.int32)
    qpos = jnp.full((B, 1), 10_000)
    out = ops.decode_attention(q, k, v, q_positions=qpos, kv_valid_len=vl)
    oref = ref.flash_attention_ref(q, k, v, q_positions=qpos,
                                   kv_valid_len=vl)
    assert _mx(out, oref) < 2e-5


# ---------------------------------------------------------------------------
# int4 matmul (BFP accumulation)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("M,K,N,G", [
    (32, 128, 64, 128),
    (96, 256, 192, 128),
    (128, 512, 128, 64),
    (7, 128, 33, 32),             # ragged M/N -> padding
])
def test_int4_kernel_matches_bfp_oracle(M, K, N, G):
    ks = jax.random.split(KEY, 2)
    x = jax.random.normal(ks[0], (M, K), jnp.float32).astype(jnp.bfloat16)
    w = jax.random.normal(ks[1], (K, N), jnp.float32) * 0.02
    codes, scale = quantize_rtn(w, G, pow2_scales=True)
    out_k = ops.int4_matmul(x, codes, scale, use_kernel=True)
    out_o = ref.bfp_matmul_ref(x, codes, scale)
    # kernel implements the oracle's arithmetic exactly (same BFP domain)
    assert _mx(out_k, out_o) <= 1e-5 * max(1.0, float(jnp.abs(out_o).max()))


def test_int4_accuracy_vs_exact():
    ks = jax.random.split(KEY, 2)
    x = jax.random.normal(ks[0], (64, 512), jnp.float32).astype(jnp.bfloat16)
    w = jax.random.normal(ks[1], (512, 128), jnp.float32) * 0.02
    codes, scale = quantize_rtn(w, 128, True)
    out_k = ops.int4_matmul(x, codes, scale, use_kernel=True)
    exact = ref.int4_matmul_ref(x, codes, scale)
    rel = float(jnp.linalg.norm(out_k.astype(jnp.float32) - exact.astype(jnp.float32))
                / jnp.linalg.norm(exact.astype(jnp.float32)))
    assert rel < 0.05                            # paper Table-1 regime


def test_int4_jnp_fallback_matches_exact():
    ks = jax.random.split(KEY, 2)
    x = jax.random.normal(ks[0], (16, 128), jnp.float32)
    w = jax.random.normal(ks[1], (128, 32), jnp.float32) * 0.02
    codes, scale = quantize_rtn(w, 64, True)
    out = ops.int4_matmul(x, codes, scale, use_kernel=False)
    exact = ref.int4_matmul_ref(x, codes, scale)
    assert _mx(out, exact) < 1e-4


# ---------------------------------------------------------------------------
# fused router + rmsnorm
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("T,D", [(50, 300), (256, 512), (3, 64), (1024, 4096)])
def test_router_stats_kernel(T, D):
    ks = jax.random.split(KEY, 2)
    x = jax.random.normal(ks[0], (1, T, D), jnp.float32).astype(jnp.bfloat16)
    w = jax.random.normal(ks[1], (D, 2), jnp.float32) * 0.02
    b = jnp.array([0.0, 1.0])
    lg, ms = ops.fused_router_rmsnorm_stats(x, w, b)
    lg_r, ms_r = ref.router_stats_ref(x.reshape(T, D), w)
    assert _mx(lg.reshape(T, 2), lg_r + b) < 1e-4
    assert _mx(ms.reshape(T), ms_r) < 1e-5


@pytest.mark.parametrize("M,K,N", [(64, 300, 128), (128, 512, 256), (9, 70, 30)])
def test_fused_linear_norm_prologue(M, K, N):
    """The prologue-only configuration (the old rmsnorm_matmul kernel,
    now subsumed by fused_linear)."""
    ks = jax.random.split(KEY, 3)
    x = jax.random.normal(ks[0], (M, K), jnp.float32).astype(jnp.bfloat16)
    g = 1.0 + 0.1 * jax.random.normal(ks[1], (K,))
    w = jax.random.normal(ks[2], (K, N), jnp.float32) * 0.05
    ms = (x.astype(jnp.float32) ** 2).mean(-1)
    out, _ = ops.fused_linear({"w": w}, x, mean_sq=ms, gamma=g)
    oref, _ = ref.fused_linear_ref(x, w=w, mean_sq=ms, gamma=g)
    assert _mx(out, oref) < 1e-4


def test_fused_linear_full_pipeline_int4():
    """Prologue × int4-BFP × SwiGLU, then down-proj with gate/residual/Σy²
    epilogue — the complete hybrid pipeline against its oracle."""
    ks = jax.random.split(KEY, 6)
    M, K, F = 48, 256, 96
    x = jax.random.normal(ks[0], (M, K), jnp.float32).astype(jnp.bfloat16)
    g = 1.0 + 0.1 * jax.random.normal(ks[1], (K,))
    ms = (x.astype(jnp.float32) ** 2).mean(-1)
    w_gu = jax.random.normal(ks[2], (K, 2 * F), jnp.float32) * 0.05
    w_dn = jax.random.normal(ks[3], (F, K), jnp.float32) * 0.05
    res = jax.random.normal(ks[4], (M, K), jnp.float32).astype(jnp.bfloat16)
    gm = (jax.random.uniform(ks[5], (M,)) > 0.4).astype(jnp.float32)
    cg, sg = quantize_rtn(w_gu, 128, pow2_scales=True)
    cd, sd = quantize_rtn(w_dn, 32, pow2_scales=True)
    pg = {"w_int": cg, "scale": sg}
    pd = {"w_int": cd, "scale": sd}

    h, _ = ops.fused_linear(pg, x, mean_sq=ms, gamma=g, glu=True, act="silu")
    y, sq = ops.fused_linear(pd, h, residual=res, gate_mul=gm, emit_sq=True)
    h_r, _ = ref.fused_linear_ref(x, w_codes=cg, scale=sg, mean_sq=ms,
                                  gamma=g, glu=True, act="silu")
    y_r, sq_r = ref.fused_linear_ref(h_r, w_codes=cd, scale=sd, residual=res,
                                     gate_mul=gm, emit_sq=True)
    assert _mx(h, h_r) < 1e-4
    assert _mx(y, y_r) < 1e-4
    np.testing.assert_allclose(np.asarray(sq), np.asarray(sq_r),
                               rtol=1e-4, atol=1e-4)
