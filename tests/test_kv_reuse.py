"""Cross-layer KV reuse: the scan-carried view must equal the paper's
recursive fallback (Eq. 2), and the compact store + rolling view must equal
the dense store."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import kv_reuse
from repro.kvcache.cache import CompactKVStore, DenseKVStore


def _brute_force_view(kvs, gates, layer):
    """K_l[i] = kv of the most recent executed layer ≤ l (layer 0 dense)."""
    L, B, T = gates.shape[0], kvs.shape[1], kvs.shape[2]
    out = np.array(kvs[0])
    for l in range(1, layer + 1):
        m = gates[l].astype(bool)
        out[m] = kvs[l][m]
    return out


def test_merge_view_matches_recursion():
    rng = np.random.default_rng(0)
    L, B, T, H, D = 5, 2, 7, 3, 4
    kvs = rng.standard_normal((L, B, T, H, D)).astype(np.float32)
    gates = (rng.random((L, B, T)) < 0.6).astype(np.float32)
    gates[0] = 1.0                               # dense base

    view = None
    for l in range(L):
        if l == 0:
            view = kv_reuse.init_view(jnp.asarray(kvs[l]), jnp.asarray(kvs[l]))
        else:
            view = kv_reuse.merge_view(view, jnp.asarray(kvs[l]),
                                       jnp.asarray(kvs[l]),
                                       jnp.asarray(gates[l]))
        expect = _brute_force_view(kvs, gates, l)
        np.testing.assert_allclose(np.asarray(view[0]), expect, rtol=1e-6)


def test_merge_view_gathered_equals_masked():
    rng = np.random.default_rng(1)
    B, T, H, D = 2, 8, 2, 4
    base = rng.standard_normal((B, T, H, D)).astype(np.float32)
    new = rng.standard_normal((B, T, H, D)).astype(np.float32)
    # pick 5 kept tokens per row
    idx = np.stack([np.sort(rng.choice(T, 5, replace=False)) for _ in range(B)])
    gate = np.zeros((B, T), np.float32)
    for b in range(B):
        gate[b, idx[b]] = 1.0
    dense = kv_reuse.merge_view((jnp.asarray(base), jnp.asarray(base)),
                                jnp.asarray(new), jnp.asarray(new),
                                jnp.asarray(gate))
    kg = jnp.take_along_axis(jnp.asarray(new),
                             jnp.asarray(idx)[:, :, None, None], axis=1)
    gathered = kv_reuse.merge_view_gathered(
        (jnp.asarray(base), jnp.asarray(base)), kg, kg, jnp.asarray(idx), T)
    np.testing.assert_allclose(np.asarray(dense[0]), np.asarray(gathered[0]))


def test_merge_token_view_decode():
    kv_prev = (jnp.ones((2, 1, 2, 4)), jnp.ones((2, 1, 2, 4)))
    k_new = jnp.full((2, 1, 2, 4), 5.0)
    gate = jnp.array([1.0, 0.0])
    k, v = kv_reuse.merge_token_view(kv_prev, k_new, k_new, gate)
    assert float(k[0].mean()) == 5.0 and float(k[1].mean()) == 1.0


def test_storage_saved_fraction():
    gates = np.ones((4, 1, 10), np.float32)
    gates[1:, :, :] = 0.0                        # everything reused
    frac = kv_reuse.storage_saved_fraction(jnp.asarray(gates))
    assert abs(float(frac) - 0.75) < 1e-6        # store layer0 only


def test_compact_store_equals_dense_view():
    rng = np.random.default_rng(2)
    L, H, D, steps = 4, 2, 3, 12
    comp = CompactKVStore(L, H, D)
    dense = DenseKVStore(L, H, D)
    kv_hist = []                                 # per token per layer kv
    for t in range(steps):
        gates = rng.random(L) < 0.6
        gates[0] = True
        per_layer = []
        cur = None
        for l in range(L):
            fresh = rng.standard_normal((H, D)).astype(np.float32)
            cur = fresh if (gates[l] or cur is None) else cur
            comp.append(l, cur, cur, executed=bool(gates[l]))
            dense.append(l, cur, cur, executed=bool(gates[l]))
            per_layer.append(cur)
        kv_hist.append(per_layer)
    for l in range(L):
        ck, _ = comp.view(l)
        dk, _ = dense.view(l)
        np.testing.assert_allclose(ck, dk, rtol=1e-6)
    assert comp.stats.saved_fraction > 0.05
    assert dense.stats.saved_fraction == 0.0
