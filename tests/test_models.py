"""Model-level behaviour: prefill↔decode consistency, gather≡masked
equivalence, chunked attention vs dense reference, gather-mode FLOP
reduction semantics."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import attention as attn
from repro.models import model as M

KEY = jax.random.PRNGKey(0)


def _drop_free(cfg):
    return dataclasses.replace(cfg, moe_capacity_factor=8.0)


@pytest.mark.parametrize("arch", ["qwen3-8b", "gemma3-12b", "grok-1-314b",
                                  "jamba-v0.1-52b", "mamba2-2.7b"])
def test_prefill_decode_consistency(arch):
    cfg = _drop_free(get_config(arch).smoke())
    params = M.init_params(KEY, cfg)
    B, T = 2, 24
    toks = jax.random.randint(KEY, (B, T), 0, cfg.vocab_size)
    if cfg.frontend == "token":
        full = {"tokens": toks}
        part, last = {"tokens": toks[:, :-1]}, {"tokens": toks[:, -1:]}
    else:
        emb = jax.random.normal(KEY, (B, T, cfg.d_model), jnp.float32)
        full, part, last = ({"embeds": emb}, {"embeds": emb[:, :-1]},
                            {"embeds": emb[:, -1:]})
    lg_full, _, _ = M.prefill(params, full, cfg)
    _, cache, _ = M.prefill(params, part, cfg, pad_to=T)
    lg_step, _, _ = M.decode_step(params, cache, last, jnp.int32(T - 1), cfg)
    np.testing.assert_allclose(np.asarray(lg_step, np.float32),
                               np.asarray(lg_full, np.float32),
                               rtol=0.05, atol=0.05)


def test_gather_equals_masked_at_capacity():
    """With capacity ≥ kept count, compacted (gather) execution must equal
    masked execution exactly — the static-shape realization is lossless."""
    cfg = _drop_free(get_config("qwen3-8b").smoke())
    cfg_m = dataclasses.replace(
        cfg, skip=dataclasses.replace(cfg.skip, mode="masked",
                                      keep_prob=1.0))
    cfg_g = dataclasses.replace(
        cfg, skip=dataclasses.replace(cfg.skip, mode="gather",
                                      keep_prob=1.0))
    params = M.init_params(KEY, cfg)
    toks = jax.random.randint(KEY, (2, 32), 0, cfg.vocab_size)
    lg_m, _, _ = M.prefill(params, {"tokens": toks}, cfg_m)
    lg_g, _, _ = M.prefill(params, {"tokens": toks}, cfg_g)
    np.testing.assert_allclose(np.asarray(lg_g, np.float32),
                               np.asarray(lg_m, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_skip_disabled_matches_dense():
    cfg = get_config("qwen3-8b").smoke()
    cfg_off = dataclasses.replace(
        cfg, skip=dataclasses.replace(cfg.skip, enabled=False))
    params = M.init_params(KEY, cfg_off)
    toks = jax.random.randint(KEY, (1, 16), 0, cfg.vocab_size)
    loss, m = M.train_loss(params, {"tokens": toks, "labels": toks},
                           jax.random.PRNGKey(1), cfg_off)
    assert float(m["keep_frac"]) == 1.0
    assert float(m["router_loss"]) == 0.0


def test_chunked_attention_equals_reference():
    ks = jax.random.split(KEY, 3)
    B, Tq, Tk, Hq, Hkv, dh = 2, 32, 48, 4, 2, 16
    q = jax.random.normal(ks[0], (B, Tq, Hq, dh))
    k = jax.random.normal(ks[1], (B, Tk, Hkv, dh))
    v = jax.random.normal(ks[2], (B, Tk, Hkv, dh))
    qpos = jnp.broadcast_to(jnp.arange(Tk - Tq, Tk)[None], (B, Tq))
    for chunk in (8, 16, 48, 64):
        out = attn.chunked_attention(q, k, v, q_positions=qpos, chunk=chunk)
        oref = attn.reference_attention(q, k, v, q_positions=qpos)
        np.testing.assert_allclose(np.asarray(out), np.asarray(oref),
                                   rtol=2e-5, atol=2e-5)


def test_sliding_window_restricts_context():
    """A far-away KV perturbation must not affect windowed attention."""
    ks = jax.random.split(KEY, 3)
    B, T, H, dh = 1, 32, 2, 8
    q = jax.random.normal(ks[0], (B, T, H, dh))
    k = jax.random.normal(ks[1], (B, T, H, dh))
    v = jax.random.normal(ks[2], (B, T, H, dh))
    qpos = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
    out1 = attn.chunked_attention(q, k, v, q_positions=qpos, window=4,
                                  chunk=8)
    k2 = k.at[:, 0].add(100.0)                    # outside every window ≥ 4
    v2 = v.at[:, 0].add(100.0)
    out2 = attn.chunked_attention(q, k2, v2, q_positions=qpos, window=4,
                                  chunk=8)
    np.testing.assert_allclose(np.asarray(out1[:, 8:]),
                               np.asarray(out2[:, 8:]), rtol=1e-5, atol=1e-5)


def test_layernorm_stats_injection_large_offset():
    """Stats-injected norm_apply must match the direct computation even on
    large-offset activations: the old one-pass E[x²]−μ² variance cancelled
    catastrophically (variance 1 on mean 1e4 has E[x²]≈1e8) and diverged
    from norm_apply's own two-pass path."""
    from repro.models import layers

    cfg = dataclasses.replace(get_config("musicgen-medium").smoke())
    assert cfg.norm_type == "layernorm"
    x = 1.0e4 + jax.random.normal(KEY, (2, 16, 256), jnp.float32)
    p = layers.norm_init(256, cfg)
    direct = layers.norm_apply(p, x, cfg)
    injected = layers.norm_apply(p, x, cfg, stats=layers.norm_stats(x, cfg))
    np.testing.assert_allclose(np.asarray(injected), np.asarray(direct),
                               rtol=1e-5, atol=1e-5)
    # the variance itself must be ~1, not a cancellation artifact
    _, var = layers.norm_stats(x, cfg)
    assert float(jnp.abs(var - 1.0).max()) < 0.2


def test_rmsnorm_stats_injection_matches_direct():
    from repro.models import layers

    cfg = get_config("qwen3-8b").smoke()
    x = jax.random.normal(KEY, (2, 8, 128), jnp.float32) * 3.0
    p = layers.norm_init(128, cfg)
    direct = layers.norm_apply(p, x, cfg)
    injected = layers.norm_apply(p, x, cfg, stats=layers.norm_stats(x, cfg))
    np.testing.assert_allclose(np.asarray(injected), np.asarray(direct),
                               rtol=1e-6, atol=1e-6)


def test_mrope_positions_change_output():
    cfg = get_config("qwen2-vl-2b").smoke()
    params = M.init_params(KEY, cfg)
    B, T = 1, 8
    emb = jax.random.normal(KEY, (B, T, cfg.d_model), jnp.float32)
    pos1 = jnp.broadcast_to(jnp.arange(T)[None, None], (3, B, T)).astype(jnp.int32)
    pos2 = pos1.at[1].set(pos1[1] * 3)            # different spatial stream
    lg1, _, _ = M.prefill(params, {"embeds": emb, "positions": pos1}, cfg)
    lg2, _, _ = M.prefill(params, {"embeds": emb, "positions": pos2}, cfg)
    assert float(jnp.abs(lg1.astype(jnp.float32)
                         - lg2.astype(jnp.float32)).max()) > 1e-4
