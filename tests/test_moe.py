"""MoE dispatch: drop-free capacity must reproduce the exact dense
per-token expert mixture; load-balance loss behaves; Arctic's dense
residual composes."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import moe

KEY = jax.random.PRNGKey(0)


def _cfg(**kw):
    cfg = get_config("grok-1-314b").smoke()
    return dataclasses.replace(cfg, **kw)


def dense_moe_oracle(params, x, cfg):
    """Per-token dense evaluation of the same top-k mixture."""
    B, T, D = x.shape
    xf = x.reshape(-1, D)
    logits = xf.astype(jnp.float32) @ params["gate"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    top_p, top_e = jax.lax.top_k(probs, cfg.top_k)
    top_p = top_p / top_p.sum(-1, keepdims=True)
    outs = []
    E = cfg.num_experts
    for e in range(E):
        h = xf @ params["w_up"][e]
        if "w_gate" in params:
            g = xf @ params["w_gate"][e]
            act = jax.nn.silu(g) if cfg.mlp_act == "swiglu" else jax.nn.gelu(g)
            h = act * h
        else:
            h = jax.nn.gelu(h)
        outs.append(h @ params["w_down"][e])
    stack = jnp.stack(outs, 1)                   # [N, E, D]
    y = jnp.zeros_like(xf)
    for j in range(cfg.top_k):
        y = y + jnp.take_along_axis(
            stack, top_e[:, j][:, None, None], axis=1)[:, 0] \
            * top_p[:, j].astype(xf.dtype)[:, None]
    return y.reshape(B, T, D)


def test_moe_matches_dense_oracle_drop_free():
    cfg = _cfg(moe_capacity_factor=8.0)
    p = moe.moe_init(KEY, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model),
                          jnp.float32).astype(jnp.bfloat16)
    y, aux = moe.moe_apply(p, x, cfg)
    y_ref = dense_moe_oracle(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32),
                               rtol=5e-2, atol=5e-2)
    assert float(aux["moe_drop_frac"]) == 0.0


def test_moe_capacity_drops_tokens():
    cfg = _cfg(moe_capacity_factor=0.25)
    p = moe.moe_init(KEY, cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 64, cfg.d_model),
                          jnp.float32).astype(jnp.bfloat16)
    _, aux = moe.moe_apply(p, x, cfg)
    assert float(aux["moe_drop_frac"]) > 0.0


def test_moe_lb_loss_uniform_vs_skewed():
    cfg = _cfg(moe_capacity_factor=8.0)
    p = moe.moe_init(KEY, cfg)
    # skew the gate so everything routes to expert 0: positive activations
    # against a positive-only column of gate weight
    p_skew = dict(p)
    p_skew["gate"] = jnp.zeros_like(p["gate"]).at[:, 0].set(0.5)
    x = jnp.abs(jax.random.normal(jax.random.PRNGKey(3),
                                  (2, 32, cfg.d_model),
                                  jnp.float32)).astype(jnp.bfloat16)
    _, aux_u = moe.moe_apply(p, x, cfg)
    _, aux_s = moe.moe_apply(p_skew, x, cfg)
    assert float(aux_s["moe_lb_loss"]) > float(aux_u["moe_lb_loss"])


def test_arctic_dense_residual_present():
    cfg = get_config("arctic-480b").smoke()
    p = moe.moe_init(KEY, cfg)
    assert "dense" in p
    x = jax.random.normal(jax.random.PRNGKey(4), (1, 8, cfg.d_model),
                          jnp.float32).astype(jnp.bfloat16)
    y, _ = moe.moe_apply(p, x, cfg)
    assert np.isfinite(np.asarray(y, np.float32)).all()
