"""Observability subsystem (``repro/obs``): metrics-registry semantics,
trace-schema validity, engine stats invariants, and cross-path metric
identity.

Acceptance bar: the registry is the run's source of truth and
``ServeStats`` a derived view over it, so (a) every counter field of the
stats dataclass must equal its registry reading, (b) count-valued
metrics (tokens, requests, chunks) must be identical across dense/paged
× single-step/fused on the same workload (wall-clock metrics obviously
differ), and (c) the emitted trace must be structurally valid Chrome
trace-event JSON — every ``B`` matched by an ``E``, engine phase spans
nested under their ``step``, loadable by ``tools/trace_summary.py``.
"""
import dataclasses
import json
import os
import sys

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.routing import neutral_router_bias
from repro.models import model as M
from repro.obs import MetricsRegistry, NullTracer, Tracer, as_tracer
from repro.serve.engine import ContinuousBatchingEngine

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "tools"))
import trace_summary  # noqa: E402

KEY = jax.random.PRNGKey(0)


def _cfg(**over):
    cfg = get_config("llama2-7b").smoke()
    return dataclasses.replace(cfg, **over) if over else cfg


def _params(cfg):
    # neutral bias => the router skips, so gate-derived metrics (keep
    # rate, measured KV saving) are exercised, not identically 1.0/0.0
    return neutral_router_bias(M.init_params(KEY, cfg))


def _workload(cfg, n=6, seed=0):
    rng = np.random.default_rng(seed)
    lens = rng.integers(4, 12, size=n)
    return [rng.integers(0, cfg.vocab_size, (int(l),), dtype=np.int32)
            for l in lens]


def _run_engine(cfg, params, *, kv_mode="dense", decode_steps=None,
                trace=None, max_new=8, **kw):
    eng = ContinuousBatchingEngine(cfg, params, max_slots=3, max_len=48,
                                   kv_mode=kv_mode,
                                   decode_steps=decode_steps,
                                   trace=trace, **kw)
    for p in _workload(cfg):
        eng.submit(p, max_new_tokens=max_new)
    return eng, eng.run(KEY)


# ---------------------------------------------------------------------------
# MetricsRegistry unit semantics
# ---------------------------------------------------------------------------

def test_registry_counter_gauge_histogram_series():
    m = MetricsRegistry()
    m.inc("c", 2.0)
    m.inc("c", 3.0)
    assert m.value("c") == 5.0
    m.set("g", 7.0)
    m.set("g", 4.0)
    assert m.value("g") == 4.0 and m.peak("g") == 7.0
    for v in (0.001, 0.02, 5.0):
        m.observe("h", v)
    h = m.histogram("h")
    assert h.count == 3 and abs(h.sum - 5.021) < 1e-9
    m.record("s", 0, 0.5, layer=1)
    m.record("s", 1, 0.25, layer=1)
    assert m.series("s", layer=1) == [(0.0, 0.5), (1.0, 0.25)]
    assert m.series("s", layer=2) == []


def test_registry_labels_are_independent_series():
    m = MetricsRegistry()
    m.inc("tok", 1, layer=0)
    m.inc("tok", 2, layer=1)
    assert m.value("tok", layer=0) == 1 and m.value("tok", layer=1) == 2
    assert m.value("tok") == 0.0          # unlabeled child never written


def test_registry_kind_conflict_raises():
    m = MetricsRegistry()
    m.inc("x")
    with pytest.raises(ValueError):
        m.set("x", 1.0)


def test_registry_snapshot_and_prometheus_roundtrip():
    m = MetricsRegistry()
    m.inc("req_total", 3)
    m.set("depth", 2.0)
    m.observe("lat_seconds", 0.01, layer=1)
    m.record("keep", 0, 0.75, layer=0)
    snap = m.snapshot()
    json.loads(json.dumps(snap))                       # JSON-able
    assert snap["counters"]["req_total"][""] == 3
    assert snap["gauges"]["depth"][""]["max"] == 2.0
    prom = m.to_prometheus()
    assert "# TYPE req_total counter" in prom
    assert 'lat_seconds_bucket{layer="1",le="+Inf"} 1' in prom
    assert "req_total 3" in prom.splitlines()


# ---------------------------------------------------------------------------
# Tracer unit semantics
# ---------------------------------------------------------------------------

def test_tracer_balanced_spans_and_nesting():
    tr = Tracer()
    with tr.span("outer"):
        with tr.span("inner"):
            pass
    tr.instant("mark", foo=1)
    assert tr.open_spans() == {}
    spans = trace_summary.pair_spans(tr.events)[0]
    by_name = {s["name"]: s for s in spans}
    assert by_name["inner"]["depth"] == 1
    assert by_name["outer"]["depth"] == 0
    assert by_name["outer"]["dur"] >= by_name["inner"]["dur"]


def test_tracer_unbalanced_end_raises():
    tr = Tracer()
    with pytest.raises(RuntimeError):
        tr.end()


def test_null_tracer_records_nothing():
    tr = as_tracer(None)
    assert isinstance(tr, NullTracer) and not tr.enabled
    with tr.span("x"):
        tr.instant("y")
        with tr.annotate("z"):
            pass
    assert tr.events == [] and tr.open_spans() == {}


def test_as_tracer_path_roundtrip(tmp_path):
    out = tmp_path / "t.json"
    tr = as_tracer(str(out))
    assert tr.enabled and tr.path == out


# ---------------------------------------------------------------------------
# Engine stats invariants (derived-view + accounting consistency)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kv_mode,steps", [("dense", None), ("dense", 4),
                                           ("paged", None), ("paged", 4)])
def test_stats_invariants(kv_mode, steps):
    cfg = _cfg()
    _, out = _run_engine(cfg, _params(cfg), kv_mode=kv_mode,
                         decode_steps=steps)
    s, m, results = out["stats"], out["metrics"], out["results"]
    # decode_tokens == sum of per-request emitted tokens
    assert s.decode_tokens == sum(r.tokens.shape[0]
                                  for r in results.values())
    assert s.requests_completed == len(results)
    # wall-clock sanity: the device wait is part of the measured
    # prefill/decode wall time, and host bookkeeping is non-negative
    assert 0.0 <= s.device_s <= s.decode_s + s.prefill_s + 1e-6
    assert s.host_s >= 0.0
    # derived view: every counter field reads out of the registry
    assert s.decode_tokens == int(m.value("decode_tokens_total"))
    assert s.prefill_tokens == int(m.value("prefill_tokens_total"))
    assert s.decode_dispatches == int(m.value("decode_dispatches_total"))
    assert s.requests_completed == int(m.value("requests_completed_total"))
    assert s.preemptions == int(m.value("preemptions_total"))
    assert s.compiles == int(m.value("compiles_total")) and s.compiles > 0
    # distributions exist and count what the scalars count
    assert m.histogram("ttft_seconds").count == len(results)
    assert m.value("queue_depth") == 0.0          # drained at loop exit
    # telemetry series: per-layer keep rate + measured KV-saved fraction
    n_layers = len(cfg.attention_layers)
    assert len(m.series("attn_keep_rate", layer=n_layers - 1)) > 0
    ks = m.series("kv_saved_fraction")
    assert ks and all(0.0 <= v <= 1.0 for _, v in ks)


def test_cross_path_metric_identity():
    """Count-valued metrics must agree across dense/paged ×
    single-step/fused on one workload (same tokens in, same tokens out —
    only wall-clock and dispatch-granularity metrics may differ)."""
    cfg = _cfg()
    params = _params(cfg)
    runs = {}
    for kv_mode in ("dense", "paged"):
        for steps in (None, 4):
            _, out = _run_engine(cfg, params, kv_mode=kv_mode,
                                 decode_steps=steps)
            runs[(kv_mode, steps)] = out
    ref = runs[("dense", None)]["metrics"]
    for key, out in runs.items():
        m = out["metrics"]
        for name in ("decode_tokens_total", "prefill_tokens_total",
                     "requests_completed_total"):
            assert m.value(name) == ref.value(name), (key, name)
        # greedy token output identical too (the metric identity is not
        # coincidental — it is the same generation)
        for uid, r in ref_results(runs).items():
            np.testing.assert_array_equal(out["results"][uid].tokens, r)


def ref_results(runs):
    return {uid: r.tokens
            for uid, r in runs[("dense", None)]["results"].items()}


def test_preemption_requeue_consistency():
    """Forced paged preemption: the counter, the requeue, and the trace
    instants must all tell the same story, and every request still
    completes."""
    cfg = _cfg()
    tr = Tracer()
    eng = ContinuousBatchingEngine(cfg, _params(cfg), max_slots=2,
                                   max_len=48, kv_mode="paged",
                                   num_pages=18, page_size=8, trace=tr)
    for p in _workload(cfg, n=5):
        eng.submit(p, max_new_tokens=10)
    out = eng.run(KEY)
    s, m = out["stats"], out["metrics"]
    assert s.requests_completed == 5 == len(out["results"])
    preempt_events = [ev for ev in tr.events
                      if ev.get("ph") == "i" and ev["name"] == "preempt"]
    assert s.preemptions == int(m.value("preemptions_total")) \
        == len(preempt_events)
    assert tr.open_spans() == {}


# ---------------------------------------------------------------------------
# Trace schema validation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kv_mode,steps", [("dense", None), ("paged", 4)])
def test_trace_schema_valid(tmp_path, kv_mode, steps):
    cfg = _cfg()
    path = tmp_path / "trace.json"
    eng, out = _run_engine(cfg, _params(cfg), kv_mode=kv_mode,
                           decode_steps=steps, trace=str(path))
    assert path.exists()                  # auto-saved at _finalize
    events = trace_summary.load_events(str(path))
    data = json.loads(path.read_text())
    assert data["displayTimeUnit"] == "ms"
    # every span balanced, per track (raises on mismatch)
    spans = trace_summary.pair_spans(events)
    # engine phase spans nest under their step span
    for s in spans[trace_summary.ENGINE_TID]:
        if s["name"] == "step":
            assert s["depth"] == 0
        else:
            assert s["depth"] >= 1, s
    # request lifecycle: one root span per submitted request, with its
    # queued/prefill phases and decode epochs inside it
    names = trace_summary.track_names(events)
    req_tids = [t for t, n in names.items() if n.startswith("req ")]
    assert len(req_tids) == len(out["results"])
    for tid in req_tids:
        by = {}
        for s in spans[tid]:
            by.setdefault(s["name"], []).append(s)
        assert len(by["request"]) == 1
        root = by["request"][0]
        assert root["depth"] == 0
        for name, group in by.items():
            if name == "request":
                continue
            for s in group:
                assert s["ts"] >= root["ts"] - 1e-6
                assert s["ts"] + s["dur"] <= root["ts"] + root["dur"] + 1e-6
        assert any(n.startswith("decode[") for n in by)
    # the CLI consumes it end to end
    summary = trace_summary.summarize(events)
    assert summary["n_requests"] == len(out["results"])
    assert summary["n_steps"] > 0
    assert sum(int(c.get("n_new", 1)) for c in summary["compiles"]) \
        == out["stats"].compiles
    assert trace_summary.main([str(path), "--json"]) == 0


@pytest.mark.parametrize("kv_mode", ["dense", "paged"])
def test_spec_trace_schema(tmp_path, kv_mode):
    """Speculative runs must trace their window anatomy: draft/verify
    (and, paged, rollback) spans nested under the step span, one
    ``accept`` instant per slot-window on the request's track, and the
    roll-up ``tools/trace_summary.py`` builds from those instants must
    agree with the engine's own counters."""
    cfg = _cfg()
    path = tmp_path / "spec.json"
    eng, out = _run_engine(cfg, _params(cfg), kv_mode=kv_mode,
                           spec_k=4, trace=str(path))
    st, m = out["stats"], out["metrics"]
    events = trace_summary.load_events(str(path))
    spans = trace_summary.pair_spans(events)     # raises if unbalanced

    names = {s["name"] for s in spans[trace_summary.ENGINE_TID]}
    assert {"draft", "verify"} <= names
    if kv_mode == "paged":
        assert "rollback" in names
    for s in spans[trace_summary.ENGINE_TID]:
        if s["name"] in ("draft", "verify", "rollback"):
            assert s["depth"] >= 1               # inside its step span

    # ServeStats is a derived view over the registry for spec counters too
    assert st.spec_windows == int(m.value("spec_windows_total")) > 0
    assert st.spec_tokens_drafted == \
        int(m.value("spec_tokens_drafted_total"))
    assert st.spec_tokens_accepted == \
        int(m.value("spec_tokens_accepted_total"))
    assert st.spec_entries_rolled_back == \
        int(m.value("spec_entries_rolled_back_total"))

    # accept instants: one per slot-window, each on a request track,
    # totals matching the counters exactly
    track = trace_summary.track_names(events)
    accepts = [ev for ev in events
               if ev.get("ph") == "i" and ev.get("name") == "accept"]
    assert accepts
    assert all(track.get(ev.get("tid", 0), "").startswith("req ")
               for ev in accepts)
    assert len(accepts) >= st.spec_windows
    summary = trace_summary.summarize(events)
    spec = summary["speculative"]
    assert spec is not None
    assert spec["windows"] == len(accepts)
    assert spec["tokens_drafted"] == st.spec_tokens_drafted
    assert spec["tokens_accepted"] == st.spec_tokens_accepted
    assert spec["acceptance_rate"] == pytest.approx(st.spec_acceptance_rate)
    # emitted tokens counted by the instants == decode tokens generated
    # minus each request's first token (that one comes off the prefill
    # logits, before any speculative window runs)
    assert spec["tokens_emitted"] == \
        st.decode_tokens - st.requests_completed
    # the draft/verify phases are part of the accounted step breakdown
    assert summary["phase_us"].get("draft", 0) > 0
    assert summary["phase_us"].get("verify", 0) > 0
    assert trace_summary.main([str(path), "--json"]) == 0


def test_spec_trace_absent_without_speculation(tmp_path):
    """A plain run must not emit speculative schema elements — the
    summary's speculative section stays None."""
    cfg = _cfg()
    path = tmp_path / "plain.json"
    _run_engine(cfg, _params(cfg), trace=str(path))
    events = trace_summary.load_events(str(path))
    assert not any(ev.get("name") == "accept" for ev in events
                   if ev.get("ph") == "i")
    assert trace_summary.summarize(events)["speculative"] is None


def test_tracing_off_is_default_and_run_has_metrics():
    cfg = _cfg()
    eng, out = _run_engine(cfg, _params(cfg))
    assert isinstance(eng.tracer, NullTracer)
    assert eng.tracer.events == []
    assert out["metrics"] is eng.metrics  # registry still populated
    assert out["stats"].decode_tokens > 0
